"""Paper Fig 9: time-varying traces — ingest accelerates lambda1 ->
lambda2 at tau q/s^2 with CV^2=8; agile elasticity keeps SLO high while
accuracy adapts downward faster for higher tau."""
from __future__ import annotations

import numpy as np

from benchmarks.common import banner, save, table
from repro.configs import get_config
from repro.serving import policies, profiler, simulator, traces

TAUS = (250, 500, 5000)
LAMBDA2 = (4800, 6800, 7800)
LAMBDA1 = 2500


def run() -> dict:
    banner("bench_acceleration (paper Fig 9)")
    cfg = get_config("ofa_resnet")
    prof = profiler.build_profile(cfg)
    scfg = simulator.SimConfig(n_workers=8, slo=0.036)
    pols = [policies.SlackFit(), policies.INFaaSMinCost()]
    idxs = np.linspace(0, prof.n_pareto - 1, 6).round().astype(int)
    pols += [policies.ClipperFixed(int(i), f"clipper+({prof.accs[i]:.2f})")
             for i in idxs[-2:]]

    results = {}
    rows_print = []
    for lam2 in LAMBDA2:
        for tau in TAUS:
            dur = (lam2 - LAMBDA1) / tau + 4.0
            arr = traces.time_varying_trace(LAMBDA1, lam2, tau, 8.0,
                                            min(dur, 30.0), seed=13)
            rows = []
            for pol in pols:
                res = simulator.simulate(arr, prof, pol, scfg)
                rows.append({"policy": pol.name, "slo": res.slo_attainment,
                             "acc": res.mean_acc})
            results[f"l2{lam2}_tau{tau}"] = rows
            sf = rows[0]
            rows_print.append([lam2, tau, f"{sf['slo']:.4f}", f"{sf['acc']:.2f}"])

    print(table(["lambda2", "tau", "slackfit SLO", "slackfit acc"], rows_print))
    sf_slos = [r[2] for r in rows_print]
    # accuracy decreases with tau at fixed lambda2 (paper's trend)
    acc_by_tau = {tau: float(np.mean([float(r[3]) for r in rows_print
                                      if r[1] == tau])) for tau in TAUS}
    print("mean slackfit acc by tau:", acc_by_tau)
    payload = {"grid": results, "acc_by_tau": acc_by_tau,
               "claims": {
                   "high_slo_under_acceleration":
                       min(float(s) for s in sf_slos) >= 0.991,
                   "acc_decreases_with_tau":
                       acc_by_tau[TAUS[0]] >= acc_by_tau[TAUS[-1]],
               }}
    save("acceleration", payload)
    return payload


if __name__ == "__main__":
    run()
