"""Paper Fig 1a + Fig 5b: model-switch loading cost vs inference vs
SubNetAct in-place actuation.

Loading latencies are analytic (weight bytes / effective PCIe+setup
bandwidth — the paper's measured 2080Ti numbers calibrate the
HardwareProfile); actuation latency is MEASURED on a real tiny JAX
supernet on this host: the cost of switching the control tuple between
two jitted calls, which is the entire SubNetAct actuation mechanism.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import banner, save, table
from repro.configs import get_config
from repro.core import pareto, subnet as sn
from repro.core.pareto import pareto_subnets
from repro.models import lm
from repro.serving import profiler
from tests.conftest import tiny_dense


def measured_actuation_latency() -> dict:
    """Wall-clock control-tuple swap on a real supernet (CPU)."""
    cfg = tiny_dense()
    params = lm.init_model(jax.random.PRNGKey(0), cfg)
    pts = pareto_subnets(cfg)
    ctrls = [sn.make_control(cfg, p.sub) for p in pts]
    stacked = {k: jnp.stack([jnp.asarray(c[k]) for c in ctrls]) for k in ctrls[0]}
    toks = jnp.ones((4, 16), jnp.int32)

    @jax.jit
    def step(idx):
        ctrl = {k: v[idx] for k, v in stacked.items()}
        return lm.prefill(params, cfg, {"tokens": toks}, ctrl)

    # warm both subnets (one compile serves all — assert no retrace)
    jax.block_until_ready(step(jnp.int32(0)))
    jax.block_until_ready(step(jnp.int32(len(pts) - 1)))

    n = 200
    t0 = time.perf_counter()
    for i in range(n):
        jax.block_until_ready(step(jnp.int32(i % len(pts))))
    t_switch = (time.perf_counter() - t0) / n
    t0 = time.perf_counter()
    for _ in range(n):
        jax.block_until_ready(step(jnp.int32(0)))
    t_same = (time.perf_counter() - t0) / n
    return {"steady_same_subnet_s": t_same, "steady_switching_s": t_switch,
            "actuation_overhead_s": max(t_switch - t_same, 0.0)}


def run() -> dict:
    banner("bench_actuation (paper Fig 1a / Fig 5b)")
    cfg = get_config("ofa_resnet")
    hw = profiler.RTX2080TI
    pts = pareto.uniform_sample(pareto_subnets(cfg), 6)

    rows = []
    for p in pts:
        wb = pareto.subnet_weight_bytes(cfg, p.sub, resident=False)
        f = pareto.subnet_flops(cfg, p.sub)
        t_load = profiler.loading_latency(hw, wb)
        t_inf16 = profiler.model_latency(hw, f, wb, 16)
        rows.append([f"{p.acc:.2f}%", f"{p.gflops:.2f}",
                     f"{wb/2**20:.0f} MB", f"{t_load*1e3:.1f} ms",
                     f"{t_inf16*1e3:.1f} ms", f"{t_load/t_inf16:.1f}x"])
    print(table(["subnet acc", "GFLOPs", "weights", "load", "infer B=16",
                 "load/infer"], rows))

    act = measured_actuation_latency()
    print(f"\nSubNetAct actuation (measured, real JAX supernet): "
          f"{act['actuation_overhead_s']*1e6:.0f} us overhead per switch "
          f"(steady-state step {act['steady_same_subnet_s']*1e3:.2f} ms)")
    mean_load = float(np.mean([profiler.loading_latency(
        hw, pareto.subnet_weight_bytes(cfg, p.sub, resident=False))
        for p in pts]))
    speedup = mean_load / max(act["actuation_overhead_s"], 1e-7)
    print(f"actuation is {speedup:.0f}x faster than on-demand loading "
          f"(mean over the 6 subnets; paper Fig 5b: orders of magnitude)")

    payload = {
        "loading_vs_inference": [
            {"acc": p.acc, "gflops": p.gflops,
             "load_s": profiler.loading_latency(
                 hw, pareto.subnet_weight_bytes(cfg, p.sub, resident=False)),
             "infer16_s": profiler.model_latency(
                 hw, pareto.subnet_flops(cfg, p.sub),
                 pareto.subnet_weight_bytes(cfg, p.sub, resident=False), 16)}
            for p in pts],
        "actuation": act,
        "claims": {
            "load_exceeds_infer_b16": all(
                profiler.loading_latency(
                    hw, pareto.subnet_weight_bytes(cfg, p.sub, resident=False))
                > profiler.model_latency(
                    hw, pareto.subnet_flops(cfg, p.sub),
                    pareto.subnet_weight_bytes(cfg, p.sub, resident=False), 16)
                for p in pts),
            "actuation_orders_of_magnitude_faster": speedup > 100,
        },
    }
    save("actuation", payload)
    return payload


if __name__ == "__main__":
    run()
