"""Reactive replica autoscaling vs static provisioning (ROADMAP
"replica autoscaling", the INFaaS direction; Salmani et al. show
adaptive policies + horizontal scaling dominate either alone).

The claims that gate, on BOTH acceptance traces (bursty r7000 CV^2=8
and the MAF-like workload):

  * **SLO parity** — the autoscaled cluster (queue_pressure policy,
    starting at the mean-provisioned replica count) holds SLO
    attainment within 2 points of a statically MAX-provisioned
    cluster;
  * **efficiency** — at <= 0.6x the static-max replica-seconds (the
    provisioned capacity-time integral), i.e. reactive scaling buys
    near-max attainment for well under max cost;
  * **lifecycle soundness** — every query is conserved across all
    scale events and the committed replica count never leaves
    [min, max].

A slo_headroom cell (the lagging, outcome-observing policy) and a
static mean-provisioned cell are reported for context.

--smoke (CI): seconds-long traces; the perf thresholds are reported
but only the structural claims gate, since tiny traces neither
saturate nor leave room to scale.
"""
from __future__ import annotations

import argparse
import sys
from collections import Counter

from benchmarks.common import banner, save, table
from repro.configs import get_config
from repro.serving import metrics, policies, profiler, simulator, traces
from repro.serving.autoscaler import AutoscaleConfig

RATE, CV2 = 7000, 8
MAF_RATE = 6400
WORKERS_PER_REPLICA = 2
MIN_R, INIT_R, MAX_R = 2, 4, 8
SLO_MARGIN = 0.02                       # pts of attainment vs static max
RS_FACTOR = 0.6                         # replica-seconds vs static max


def _run(arr, prof, n_replicas, autoscale=None):
    ccfg = simulator.ClusterConfig(
        n_replicas=n_replicas, workers_per_replica=WORKERS_PER_REPLICA,
        placement="round_robin", slo=0.036, autoscale=autoscale)
    res = simulator.simulate_cluster(arr, prof, policies.SlackFit(), ccfg)
    st = res.stats()
    events = Counter(e.kind for e in res.scale_events)
    return {
        "slo": res.slo_attainment, "acc": res.mean_acc,
        "goodput": metrics.goodput(res.queries, res.duration),
        "p99_ms": res.latency_p99 * 1e3,
        "replica_seconds": res.replica_seconds,
        # static runs also carry spans ({rid: duration}), so the
        # efficiency figure is always present in stats()
        "goodput_per_rs": st["goodput_per_replica_second"],
        "imbalance": st["load_imbalance"],
        "spawns": events.get("spawn", 0),
        "decommissions": events.get("decommission", 0),
        "replicas_total": res.n_replicas,
        "resolved": sum(1 for q in res.queries
                        if q.finish is not None or q.dropped),
        "n": len(res.queries),
        "bounds_ok": all(
            MIN_R <= e.n_committed <= MAX_R for e in res.scale_events
            if e.kind in ("spawn", "ready", "decommission")),
    }


def run(duration: float = 8.0, maf_duration: float = 20.0,
        smoke: bool = False) -> dict:
    banner("bench_autoscaling (ROADMAP replica autoscaling)")
    prof = profiler.build_profile(get_config("ofa_resnet"))
    auto_qp = AutoscaleConfig(min_replicas=MIN_R, max_replicas=MAX_R)
    auto_sh = AutoscaleConfig(min_replicas=MIN_R, max_replicas=MAX_R,
                              policy="slo_headroom")

    cells, claims = {}, {}
    for trace, arr in [
        ("bursty", traces.bursty_trace(RATE * 0.2, RATE * 0.8, CV2,
                                       duration, seed=13)),
        ("maf", traces.maf_like_trace(MAF_RATE, maf_duration, seed=13)),
    ]:
        grid = {
            "static_max": _run(arr, prof, MAX_R),
            "static_mean": _run(arr, prof, INIT_R),
            "autoscale_qp": _run(arr, prof, INIT_R, autoscale=auto_qp),
            "autoscale_sh": _run(arr, prof, INIT_R, autoscale=auto_sh),
        }
        cells[trace] = grid
        smax, auto = grid["static_max"], grid["autoscale_qp"]
        rows = [[k, f"{c['slo']:.4f}", f"{c['acc']:.2f}",
                 f"{c['replica_seconds']:.1f}", f"{c['goodput_per_rs']:.0f}",
                 f"{c['spawns']}/{c['decommissions']}"]
                for k, c in grid.items()]
        print(f"\n{trace} (r{RATE if trace == 'bursty' else MAF_RATE}, "
              f"{len(arr)} queries):")
        print(table(["cell", "SLO", "acc", "replica-s", "goodput/rs",
                     "spawn/decom"], rows))
        ratio = auto["replica_seconds"] / max(smax["replica_seconds"], 1e-9)
        print(f"  autoscale vs static-max: SLO {auto['slo']:.4f} vs "
              f"{smax['slo']:.4f}, replica-seconds ratio {ratio:.3f} "
              f"(gate <= {RS_FACTOR})")
        claims[f"{trace}_slo_within_2pts_of_static_max"] = (
            auto["slo"] >= smax["slo"] - SLO_MARGIN)
        claims[f"{trace}_replica_seconds_leq_0.6x_static_max"] = (
            ratio <= RS_FACTOR)

    structural = {
        "all_queries_accounted": all(
            c["resolved"] == c["n"]
            for grid in cells.values() for c in grid.values()),
        "replica_count_within_bounds": all(
            c["bounds_ok"] for grid in cells.values()
            for c in grid.values()),
        "autoscaler_actually_scaled": all(
            grid["autoscale_qp"]["spawns"]
            + grid["autoscale_qp"]["decommissions"] > 0
            for grid in cells.values()),
        "metrics_finite": all(
            c["p99_ms"] == c["p99_ms"] and c["imbalance"] == c["imbalance"]
            and c["goodput_per_rs"] == c["goodput_per_rs"]
            for grid in cells.values() for c in grid.values()),
    }
    gated = dict(structural) if smoke else {**structural, **claims}
    payload = {"cells": cells, "smoke": smoke,
               "config": {"min": MIN_R, "init": INIT_R, "max": MAX_R,
                          "workers_per_replica": WORKERS_PER_REPLICA,
                          "slo_margin": SLO_MARGIN, "rs_factor": RS_FACTOR},
               "perf_claims_informational": claims if smoke else None,
               "claims": gated}
    save("autoscaling", payload)
    return payload


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--duration", type=float, default=8.0)
    ap.add_argument("--maf-duration", type=float, default=20.0)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny trace; gate only structural claims")
    args = ap.parse_args(argv)
    if args.smoke:
        args.duration = min(args.duration, 1.5)
        args.maf_duration = min(args.maf_duration, 3.0)
    payload = run(args.duration, args.maf_duration, smoke=args.smoke)
    failures = [k for k, ok in payload["claims"].items() if not ok]
    if failures:
        print(f"\nFAILED claims: {failures}")
        return 1
    print("\nall autoscaling claims PASS")
    return 0


if __name__ == "__main__":
    sys.exit(main())
