"""Paper Fig 8: SuperServe vs Clipper+ (6 fixed points) vs INFaaS over
the bursty grid lambda_v x CV^2, 36 ms SLO. The headline numbers
(accuracy gain at matched SLO attainment; SLO-attainment factor at
matched accuracy) are computed exactly as the paper states them."""
from __future__ import annotations

import numpy as np

from benchmarks.common import banner, save, table
from repro.configs import get_config
from repro.serving import policies, profiler, simulator, traces

LAMBDA_V = (2950, 4900, 5550)
CV2 = (2, 4, 8)
LAMBDA_B = 1500
DURATION = 5.0


def _policies(prof):
    pols = [policies.SlackFit(), policies.INFaaSMinCost()]
    idxs = np.linspace(0, prof.n_pareto - 1, 6).round().astype(int)
    for i in idxs:
        pols.append(policies.ClipperFixed(int(i), f"clipper+({prof.accs[i]:.2f})"))
    return pols


def headline(results: dict) -> dict:
    """Paper-style headline: (a) accuracy gain vs the best baseline at
    SLO >= 0.999; (b) SLO-attainment factor vs baselines at >= SlackFit
    accuracy."""
    acc_gains, slo_factors = [], []
    for cell, rows in results.items():
        sf = next(r for r in rows if r["policy"] == "slackfit")
        if sf["slo"] >= 0.999:
            base = [r for r in rows if r["policy"] != "slackfit"
                    and r["slo"] >= 0.999]
            if base:
                acc_gains.append(sf["acc"] - max(r["acc"] for r in base))
        near = [r for r in rows if r["policy"] != "slackfit"
                and r["acc"] >= sf["acc"] - 0.05]
        if near:
            best = max(r["slo"] for r in near)
            if best > 0:
                slo_factors.append(sf["slo"] / best)
    return {
        "max_acc_gain_at_999_slo": max(acc_gains) if acc_gains else None,
        "mean_acc_gain_at_999_slo": float(np.mean(acc_gains)) if acc_gains else None,
        "max_slo_factor_at_same_acc": max(slo_factors) if slo_factors else None,
    }


def run(duration: float = DURATION) -> dict:
    banner("bench_bursty_grid (paper Fig 8)")
    cfg = get_config("ofa_resnet")
    prof = profiler.build_profile(cfg)
    scfg = simulator.SimConfig(n_workers=8, slo=0.036)
    results = {}
    for lam_v in LAMBDA_V:
        for cv2 in CV2:
            arr = traces.bursty_trace(LAMBDA_B, lam_v, cv2, duration, seed=11)
            rows = []
            for pol in _policies(prof):
                res = simulator.simulate(arr, prof, pol, scfg)
                rows.append({"policy": pol.name,
                             "slo": res.slo_attainment, "acc": res.mean_acc,
                             "p50_ms": res.latency_p50 * 1e3,
                             "p99_ms": res.latency_p99 * 1e3})
            results[f"lv{lam_v}_cv{cv2}"] = rows

    # print one representative cell + the headline
    cell = results[f"lv{LAMBDA_V[-1]}_cv{CV2[-1]}"]
    print(table(["policy", "SLO attainment", "mean acc", "p50 ms", "p99 ms"],
                [[r["policy"], f"{r['slo']:.4f}", f"{r['acc']:.2f}",
                  f"{r['p50_ms']:.1f}", f"{r['p99_ms']:.1f}"]
                 for r in cell]))
    h = headline(results)
    print(f"\nheadline: +{h['max_acc_gain_at_999_slo']:.2f}% acc at 0.999 SLO "
          f"(paper: +4.33); {h['max_slo_factor_at_same_acc']:.2f}x SLO at same "
          f"acc (paper: 2.06x)")
    sf_all = [r for rows in results.values() for r in rows
              if r["policy"] == "slackfit"]
    payload = {"grid": results, "headline": h,
               "claims": {
                   "slackfit_high_slo_everywhere":
                       min(r["slo"] for r in sf_all) > 0.995,
                   "acc_gain_positive": (h["max_acc_gain_at_999_slo"] or 0) > 1.0,
               }}
    save("bursty_grid", payload)
    return payload


if __name__ == "__main__":
    run()
