"""Multi-replica scale-out: goodput vs replica count, and placement-
policy shoot-out on the MAF trace (ROADMAP "serving scale-out").

Two claims gate:
  * engine-per-replica scale-out is near-linear — goodput on the
    acceptance bursty trace (r7000, CV^2=8) grows >= 3.5x from 1 to 4
    replica groups (2 workers each);
  * replica-aware placement beats load-oblivious round-robin where
    balance is non-trivial — on the MAF trace over a *heterogeneous*
    cluster (unequal worker pools: homogeneous pools + smooth arrivals
    make round-robin optimal by construction), power-of-two-choices
    achieves p99 latency <= round-robin at equal-or-better SLO
    attainment.

A replica-death cell (informational + conservation claim) shows the
coordinator re-routing a dead replica's queue to survivors.

--smoke (CI): seconds-long traces; the perf thresholds above are
reported but only structural claims (conservation, every replica used,
finite metrics) gate, since tiny traces don't saturate.
"""
from __future__ import annotations

import argparse
import sys

from benchmarks.common import banner, save, table
from repro.configs import get_config
from repro.serving import metrics, policies, profiler, simulator, traces

RATE, CV2 = 7000, 8
REPLICAS = (1, 2, 4, 8)
WORKERS_PER_REPLICA = 2
HETERO_POOLS = (4, 2, 2, 1)
PLACEMENTS = ("round_robin", "least_loaded", "power_of_two", "slack_aware")


def _cell(arr, prof, ccfg, res=None) -> dict:
    if res is None:
        res = simulator.simulate_cluster(arr, prof, policies.SlackFit(), ccfg)
    st = res.stats()
    return {"slo": res.slo_attainment, "acc": res.mean_acc,
            "goodput": metrics.goodput(res.queries, res.duration),
            "p50_ms": res.latency_p50 * 1e3, "p99_ms": res.latency_p99 * 1e3,
            "imbalance": st["load_imbalance"],
            "replicas_used": sorted({int(q.replica) for q in res.queries}),
            "resolved": sum(1 for q in res.queries
                            if q.finish is not None or q.dropped),
            "n": len(res.queries)}


def run(duration: float = 8.0, maf_duration: float = 20.0,
        smoke: bool = False) -> dict:
    banner("bench_cluster_scaleout (ROADMAP serving scale-out)")
    prof = profiler.build_profile(get_config("ofa_resnet"))

    # -- 1) goodput vs replica count, bursty acceptance trace ----------
    arr = traces.bursty_trace(RATE * 0.2, RATE * 0.8, CV2, duration, seed=13)
    scale, rows = {}, []
    for n in REPLICAS:
        ccfg = simulator.ClusterConfig(
            n_replicas=n, workers_per_replica=WORKERS_PER_REPLICA,
            placement="round_robin", slo=0.036)
        scale[n] = _cell(arr, prof, ccfg)
        ratio = scale[n]["goodput"] / max(scale[1]["goodput"], 1e-9)
        rows.append([n, f"{scale[n]['goodput']:.0f}", f"{ratio:.2f}x",
                     f"{scale[n]['slo']:.4f}", f"{scale[n]['acc']:.2f}"])
    print(table(["replicas", "goodput q/s", "vs 1", "SLO", "acc"], rows))
    speedup4 = scale[4]["goodput"] / max(scale[1]["goodput"], 1e-9)

    # -- 2) placement shoot-out, MAF over a heterogeneous cluster ------
    maf = traces.maf_like_trace(6400, maf_duration, seed=13)
    placed, rows = {}, []
    for pl in PLACEMENTS:
        ccfg = simulator.ClusterConfig(
            n_replicas=len(HETERO_POOLS),
            workers_per_replica=list(HETERO_POOLS),
            placement=pl, slo=0.036)
        placed[pl] = _cell(maf, prof, ccfg)
        c = placed[pl]
        rows.append([pl, f"{c['slo']:.4f}", f"{c['acc']:.2f}",
                     f"{c['p99_ms']:.2f}", f"{c['imbalance']:.3f}"])
    print(f"\nMAF r6400 on heterogeneous pools {HETERO_POOLS}:")
    print(table(["placement", "SLO", "acc", "p99 ms", "imbalance"], rows))

    # -- 3) replica death: orphans re-routed to survivors --------------
    death_arr = traces.bursty_trace(400, 1600, CV2, min(duration, 4.0),
                                    seed=13)
    t_death = min(duration, 4.0) / 3
    ccfg = simulator.ClusterConfig(
        n_replicas=3, workers_per_replica=2, placement="least_loaded",
        slo=0.036, replica_deaths={1: t_death})
    dres = simulator.simulate_cluster(death_arr, prof, policies.SlackFit(),
                                      ccfg)
    death = _cell(death_arr, prof, ccfg, res=dres)
    death["dead_replica_quiet_after_death"] = all(
        q.replica != 1 for q in dres.queries
        if q.finish is not None and q.finish > t_death)
    print(f"\nreplica death @t={t_death:.2f}s: "
          f"SLO {death['slo']:.4f}, {death['resolved']}/{death['n']} "
          f"resolved, survivors served replicas {death['replicas_used']}")

    rr, p2c = placed["round_robin"], placed["power_of_two"]
    structural = {
        "all_queries_accounted": all(
            c["resolved"] == c["n"]
            for c in [*scale.values(), *placed.values(), death]),
        "every_replica_used_at_8": scale[8]["replicas_used"] == list(range(8)),
        "death_orphans_reach_survivors":
            death["dead_replica_quiet_after_death"] and death["slo"] > 0,
        "metrics_finite": all(
            c["p99_ms"] == c["p99_ms"] and c["imbalance"] == c["imbalance"]
            for c in [*scale.values(), *placed.values(), death]),
    }
    perf = {
        "goodput_scales_3_5x_at_4_replicas": speedup4 >= 3.5,
        # both cells must actually serve (an empty set's p99 is a
        # well-defined 0.0 — gating on it alone would pass vacuously)
        "p2c_p99_leq_round_robin_on_maf":
            p2c["slo"] > 0 and rr["slo"] > 0
            and p2c["p99_ms"] <= rr["p99_ms"],
        "p2c_slo_no_worse_than_round_robin": p2c["slo"] >= rr["slo"] - 1e-3,
    }
    print(f"\nscale-out: {speedup4:.2f}x goodput at 4 replicas "
          f"(>= 3.5x required); p2c p99 {p2c['p99_ms']:.2f}ms vs "
          f"round-robin {rr['p99_ms']:.2f}ms")
    claims = dict(structural) if smoke else {**structural, **perf}
    payload = {"scale": {str(k): v for k, v in scale.items()},
               "placement": placed, "replica_death": death,
               "speedup_at_4": speedup4, "smoke": smoke,
               "perf_claims_informational": perf if smoke else None,
               "claims": claims}
    save("cluster_scaleout", payload)
    return payload


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--duration", type=float, default=8.0)
    ap.add_argument("--maf-duration", type=float, default=20.0)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny trace; gate only structural claims")
    args = ap.parse_args(argv)
    if args.smoke:
        args.duration = min(args.duration, 1.0)
        args.maf_duration = min(args.maf_duration, 2.0)
    payload = run(args.duration, args.maf_duration, smoke=args.smoke)
    failures = [k for k, ok in payload["claims"].items() if not ok]
    if failures:
        print(f"\nFAILED claims: {failures}")
        return 1
    print("\nall cluster scale-out claims PASS")
    return 0


if __name__ == "__main__":
    sys.exit(main())
