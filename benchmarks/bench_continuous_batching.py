"""Continuous batching (in-flight joins, paper §5) vs decision-time
batching, through the shared scheduling engine.

Decision-time batching forms a batch once, when a worker frees up;
continuous batching keeps an under-filled batch open within the
policy's latency budget, admits queries that arrive in the window (up
to the profile's realizable batch sizes), and re-consults the policy on
every join. Compared on the acceptance bursty trace (rate 7000, CV^2 8)
and the MAF-like trace; the claim is SLO attainment no worse with
continuous batching and no accuracy regression.
"""
from __future__ import annotations

from benchmarks.common import banner, save, table
from repro.configs import get_config
from repro.serving import policies, profiler, simulator, traces

RATE = 7000
CV2 = 8
DURATION = 8.0
ACC_TOL = 0.05          # accuracy points; "no regression" tolerance


def _run(arr, prof, continuous: bool, n_workers: int = 8):
    scfg = simulator.SimConfig(n_workers=n_workers, slo=0.036,
                               continuous_batching=continuous)
    res = simulator.simulate(arr, prof, policies.SlackFit(), scfg)
    return {"mode": "continuous" if continuous else "decision-time",
            "slo": res.slo_attainment, "acc": res.mean_acc,
            "p50_ms": res.latency_p50 * 1e3, "p99_ms": res.latency_p99 * 1e3,
            "join_rate": res.n_joins / max(len(arr), 1),
            "open_batches": res.n_open_batches}


def run(duration: float = DURATION) -> dict:
    banner("bench_continuous_batching (ROADMAP in-flight joins)")
    cfg = get_config("ofa_resnet")
    prof = profiler.build_profile(cfg)

    cells = {
        # acceptance cell: bursty, rate 7000, CV^2 8 (serve.py's split)
        f"bursty_r{RATE}_cv{CV2}": (
            traces.bursty_trace(RATE * 0.2, RATE * 0.8, CV2, duration, seed=13),
            8),
        # small pool near saturation: drain-then-burst cycles are where
        # in-flight joins consolidate the stray B=1 dispatches
        "bursty_r1500_cv8_2w": (
            traces.bursty_trace(300, 1200, 8, duration, seed=13), 2),
        "maf_r6400": (traces.maf_like_trace(6400, duration, seed=13), 8),
    }

    results, rows = {}, []
    for name, (arr, n_workers) in cells.items():
        dt = _run(arr, prof, continuous=False, n_workers=n_workers)
        cb = _run(arr, prof, continuous=True, n_workers=n_workers)
        results[name] = {"decision_time": dt, "continuous": cb}
        for r in (dt, cb):
            rows.append([name, r["mode"], f"{r['slo']:.4f}", f"{r['acc']:.2f}",
                         f"{r['p50_ms']:.1f}", f"{r['p99_ms']:.1f}",
                         f"{r['join_rate']:.3f}"])
    print(table(["trace", "batching", "SLO", "acc", "p50 ms", "p99 ms",
                 "join rate"], rows))

    key = f"bursty_r{RATE}_cv{CV2}"
    dt, cb = results[key]["decision_time"], results[key]["continuous"]
    print(f"\nbursty r{RATE} cv{CV2}: continuous {cb['slo']:.4f} SLO / "
          f"{cb['acc']:.2f} acc vs decision-time {dt['slo']:.4f} / "
          f"{dt['acc']:.2f}")
    claims = {
        "cb_slo_no_worse_on_bursty": cb["slo"] >= dt["slo"],
        "cb_no_accuracy_regression_on_bursty": cb["acc"] >= dt["acc"] - ACC_TOL,
        "cb_slo_no_worse_on_maf":
            results["maf_r6400"]["continuous"]["slo"]
            >= results["maf_r6400"]["decision_time"]["slo"],
        "cb_no_accuracy_regression_on_maf":
            results["maf_r6400"]["continuous"]["acc"]
            >= results["maf_r6400"]["decision_time"]["acc"] - ACC_TOL,
        "joins_happen_somewhere":
            any(c["continuous"]["join_rate"] > 0 for c in results.values()),
    }
    payload = {"cells": results, "claims": claims}
    save("continuous_batching", payload)
    return payload


if __name__ == "__main__":
    run()
