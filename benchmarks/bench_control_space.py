"""Paper Fig 13 / §A.4: the control-parameter space — latency heatmap
over (accuracy x batch) for six FLOPs-uniform pareto subnets, and the
bucket-occupancy histogram (I3: choices thin out at high latency)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import banner, save, table
from repro.configs import get_config
from repro.core.pareto import pareto_subnets, uniform_sample
from repro.serving import profiler


def run() -> dict:
    banner("bench_control_space (paper Fig 13)")
    cfg = get_config("ofa_resnet")
    prof = profiler.build_profile(cfg)
    pts = pareto_subnets(cfg)
    six = uniform_sample(pts, 6)
    six_idx = [pts.index(p) for p in six]

    rows = []
    for i in six_idx:
        rows.append([f"{prof.accs[i]:.2f}%"] +
                    [f"{prof.lat[i, j]*1e3:.1f}" for j in range(len(prof.batches))])
    print(table(["acc \\ B"] + [str(b) for b in prof.batches], rows))

    # monotonicity checks (P1, P2) + P3 slope growth
    p1 = bool((np.diff(prof.lat, axis=1) >= -1e-12).all())
    order = np.argsort(prof.accs)
    p2 = bool((np.diff(prof.lat[order], axis=0) >= -1e-9).all())
    gaps = prof.lat[order, -1] - prof.lat[order, 0]
    p3 = bool((np.diff(gaps) >= -1e-9).all())

    sizes = [len(m) for m in prof.bucket_members]
    print("\nbucket occupancy (low->high latency):", sizes)
    i3 = float(np.mean(sizes[: len(sizes) // 3])) >= \
        float(np.mean(sizes[-len(sizes) // 3:]))
    print(f"P1={p1} P2={p2} P3={p3} I3(choices thin out)={i3}")

    payload = {
        "heatmap": {f"{prof.accs[i]:.2f}":
                    [float(x) for x in prof.lat[i]] for i in six_idx},
        "batches": list(prof.batches),
        "bucket_occupancy": sizes,
        "claims": {"P1": p1, "P2": p2, "P3": p3, "I3": bool(i3)},
    }
    save("control_space", payload)
    return payload


if __name__ == "__main__":
    run()
