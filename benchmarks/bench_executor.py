"""Compiled-path executor benchmark: the perf trajectory of
serving/executor.py that ``tools/bench_diff.py`` gates PR-over-PR.

Four sections:

1. **Cold compile vs warmed actuation** — wall-clock of AOT-compiling
   one (batch, seq) bucket vs one warmed prefill through it. The paper's
   SubNetAct pitch in one ratio: actuation is a control-tuple swap, not
   a compile. Timing claim (full runs only): warmed actuation is
   >= 50x faster than the cold compile.
2. **Bucketing bounds the jit cache** — a sweep of distinct raw
   (batch, seq) shapes, far more shapes than buckets. Structural
   claims: total compiles equal the touched buckets (strictly fewer
   than raw shapes), and the power-of-two right-padding factor stays
   <= 4x (under 2x per dim).
3. **MAF-trace replay** — batch sizes derived from the MAF-like
   arrival trace, cycling across subnets, against a warmed executor.
   Structural claims (the ISSUE acceptance probe): >= 3 subnets and
   >= 3 distinct batch shapes served with ZERO XLA compilations and a
   bucket hit rate >= 0.9.
4. **Executor-backed Router** — the real-execution serving plane
   end-to-end on a measured profile. Structural claims: every query
   resolves, and the serve phase is compile-free.

Claims split by kind, mirroring ``results/bench_baseline/tolerances.json``:
structural claims are identical between ``--smoke`` and full runs; the
cold/warm ratio is timing and only asserted in full runs (CI smoke
skips it via ``bench_diff --skip-timing`` + the omitted claim).
"""
from __future__ import annotations

import asyncio
import time

import numpy as np

import jax

from benchmarks.common import banner, emit_bench_json, save, table, time_fn
from repro import compat
from repro.configs.base import ArchConfig, ElasticSpec, Stage
from repro.serving.executor import ExecutorConfig, SubnetExecutor, bucket_of

BATCH_BUCKETS = (1, 2, 4, 8)
SEQ_BUCKETS = (8, 16)
COLD_WARM_GATE = 50.0
HIT_RATE_GATE = 0.9
PAD_FACTOR_GATE = 4.0


def _bench_cfg() -> ArchConfig:
    return ArchConfig(
        name="bench-executor-supernet", family="dense",
        stages=(Stage(("attn", "mlp"), repeat=3),),
        d_model=64, n_heads=4, n_kv_heads=2, d_ff=256, vocab_size=128,
        head_dim=16, dtype="float32",
        elastic=ElasticSpec(depth_fracs=(1 / 3, 2 / 3, 1.0),
                            ffn_fracs=(0.5, 1.0), head_fracs=(0.5, 1.0)),
    )


def _fresh_executor(max_entries: int = 16) -> SubnetExecutor:
    cfg = _bench_cfg()
    from repro.models import lm
    params = lm.init_model(jax.random.PRNGKey(0), cfg)
    return SubnetExecutor(params, cfg, exec_cfg=ExecutorConfig(
        batch_buckets=BATCH_BUCKETS, seq_buckets=SEQ_BUCKETS,
        max_entries=max_entries))


def _cold_vs_warm(warmup: int, iters: int):
    ex = _fresh_executor()
    t0 = time.perf_counter()
    ex.prefill(0, np.ones((2, 8), np.int32))       # compiles bucket (2, 8)
    cold_s = time.perf_counter() - t0
    warm_s = time_fn(lambda: ex.prefill(1, np.ones((2, 8), np.int32)),
                     warmup=warmup, iters=max(iters, 3))
    probe_ok = compat.compile_events() is not None
    recompiles = None
    if probe_ok:
        with compat.CompileCounter() as cc:
            for idx in (0, ex.n_subnets // 2, ex.n_subnets - 1):
                ex.prefill(idx, np.ones((2, 8), np.int32))
        recompiles = cc.count
    out = {"cold_compile_ms": cold_s * 1e3, "warm_actuation_ms": warm_s * 1e3,
           "cold_over_warm": cold_s / max(warm_s, 1e-9),
           "actuation_recompiles": (float(recompiles)
                                    if recompiles is not None else -1.0)}
    print(table(["cold compile ms", "warm actuation ms", "ratio",
                 "recompiles across 3 subnets"],
                [[f"{out['cold_compile_ms']:.1f}",
                  f"{out['warm_actuation_ms']:.3f}",
                  f"{out['cold_over_warm']:.0f}x",
                  "n/a" if recompiles is None else recompiles]]))
    return out, (recompiles == 0 if probe_ok else True)


def _bucketing(smoke: bool):
    ex = _fresh_executor()
    raw_shapes = [(b, s) for b in (1, 2, 3, 4, 5, 7, 8)
                  for s in ((5, 8, 11) if not smoke else (5, 11))]
    pad_factors = []
    for b, s in raw_shapes:
        ex.prefill(b % ex.n_subnets, np.ones((b, s), np.int32))
        bb = bucket_of(b, BATCH_BUCKETS)
        sb = bucket_of(s, SEQ_BUCKETS)
        pad_factors.append((bb * sb) / (b * s))
    buckets_touched = {(bucket_of(b, BATCH_BUCKETS), bucket_of(s, SEQ_BUCKETS))
                       for b, s in raw_shapes}
    c = ex.counters()
    out = {"raw_shapes": float(len(raw_shapes)),
           "buckets_touched": float(len(buckets_touched)),
           "compiles": c["compiles"],
           "max_pad_factor": max(pad_factors),
           "hit_rate": c["hit_rate"]}
    print(table(["raw shapes", "buckets", "compiles", "max pad factor"],
                [[len(raw_shapes), len(buckets_touched),
                  int(c["compiles"]), f"{max(pad_factors):.2f}x"]]))
    return out, c["compiles"] == len(buckets_touched) < len(raw_shapes)


def _maf_replay(smoke: bool):
    from repro.serving import traces
    ex = _fresh_executor()
    ex.warmup(batches=BATCH_BUCKETS, seqs=SEQ_BUCKETS)
    arr = traces.maf_like_trace(400.0, 1.0 if smoke else 4.0, seed=11)
    # group arrivals into 25ms windows; each window's count (capped at
    # the largest bucket) is one batch — the trace's burstiness becomes
    # batch-shape diversity
    edges = np.floor(np.asarray(arr) / 0.025).astype(int)
    sizes = [min(int(n), BATCH_BUCKETS[-1])
             for n in np.bincount(edges) if n > 0]
    subnets_used, shapes_used = set(), set()
    probe_ok = compat.compile_events() is not None
    base = ex.counters()
    with compat.CompileCounter() as cc:
        for i, b in enumerate(sizes):
            idx = i % ex.n_subnets
            seq = 5 + (i % 3) * 4                  # 5 / 9 / 13 tokens
            ex.prefill(idx, np.ones((b, seq), np.int32))
            subnets_used.add(idx)
            shapes_used.add((b, seq))
    c = ex.counters()
    # serve-phase hit rate: exclude the warmup lattice's own misses
    lookups = (c["hits"] + c["misses"]) - (base["hits"] + base["misses"])
    hit_rate = (c["hits"] - base["hits"]) / max(lookups, 1.0)
    out = {"n_batches": float(len(sizes)),
           "subnets_used": float(len(subnets_used)),
           "shapes_used": float(len(shapes_used)),
           "serve_compiles": float(cc.count) if probe_ok else -1.0,
           "hit_rate": hit_rate}
    print(f"maf replay: {len(sizes)} batches, {len(subnets_used)} subnets, "
          f"{len(shapes_used)} shapes, compiles={cc.count if probe_ok else 'n/a'}, "
          f"serve hit rate {hit_rate:.3f}")
    zero = cc.count == 0 if probe_ok else True
    return out, {
        "maf_replay_zero_compiles": zero,
        "maf_replay_spans_space": (len(subnets_used) >= 3
                                   and len(shapes_used) >= 3),
        "maf_replay_hit_rate": hit_rate >= HIT_RATE_GATE,
    }


def _router_serving(smoke: bool):
    from repro.serving import policies, runtime
    ex = _fresh_executor()
    ex.warmup(batches=(1, 2, 4), seqs=(8,))
    prof = ex.measured_profile(batches=(1, 2, 4), seq_len=8,
                               warmup=0, iters=1)
    n = 16 if smoke else 48
    slo = float(prof.lat[-1, 0] * 25)
    probe_ok = compat.compile_events() is not None

    async def go():
        router = runtime.Router(prof, policies.SlackFit(),
                                ex.make_workers(2), executor=ex)
        await router.start()
        futs = []
        for i in range(n):
            futs.append(await router.submit(
                np.full((7,), i % ex.cfg.vocab_size, np.int32), slo_s=slo))
            if i % 4 == 3:
                await asyncio.sleep(float(prof.lat[0, 0]))
        await asyncio.gather(*futs)
        await router.drain()
        return router.stats()

    with compat.CompileCounter() as cc:
        st = asyncio.run(go())
    resolved = st["served"] + st.get("dropped", 0.0)
    out = {"n_queries": float(n), "served": st["served"],
           "slo_attainment": st["slo_attainment"],
           "serve_compiles": float(cc.count) if probe_ok else -1.0,
           "executor_hit_rate": st["executor"]["hit_rate"]}
    print(f"router serving: {n} queries, served={st['served']:.0f}, "
          f"SLO {st['slo_attainment']:.3f}, "
          f"compiles={cc.count if probe_ok else 'n/a'}")
    return out, {
        "router_resolves_all_queries": resolved >= n,
        "router_serving_compile_free": (cc.count == 0 if probe_ok
                                        else True),
    }


def run(smoke: bool = False) -> dict:
    banner("bench_executor (compiled-path serving perf trajectory)"
           + (" [smoke]" if smoke else ""))
    warmup, iters = (1, 1) if smoke else (2, 5)

    coldwarm, actuation_free = _cold_vs_warm(warmup, iters)
    bucketing, bounded = _bucketing(smoke)
    maf, maf_claims = _maf_replay(smoke)
    router, router_claims = _router_serving(smoke)

    payload = {
        "cold_warm": coldwarm, "bucketing": bucketing, "maf": maf,
        "router": router,
        "claims": {
            # structural: stable across hosts/modes, gated in CI smoke
            "actuation_never_recompiles": actuation_free,
            "compiles_bounded_by_buckets": bounded,
            "padding_factor_bounded":
                bucketing["max_pad_factor"] <= PAD_FACTOR_GATE,
            **maf_claims, **router_claims,
        },
    }
    if not smoke:
        # timing: full runs only (CI smoke skips via --skip-timing +
        # the omitted claim)
        payload["claims"]["warm_actuation_ge_50x_cold_compile"] = (
            coldwarm["cold_over_warm"] >= COLD_WARM_GATE)
    save("executor", payload)
    return payload


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="structural claims only; single timing iteration")
    args = ap.parse_args()
    payload = run(smoke=args.smoke)
    path = emit_bench_json("executor", payload)
    print(f"\nwrote {path}")
    bad = [c for c, ok in payload["claims"].items() if not ok]
    raise SystemExit(1 if bad else 0)
