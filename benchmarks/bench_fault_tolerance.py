"""Paper Fig 11a: transparent fault tolerance — 8 workers, one killed
every 12 s down to 50% capacity; trace statistically unchanged
(lambda=3500, CV^2=2); SuperServe actuates lower-accuracy subnets and
holds SLO attainment ~0.999."""
from __future__ import annotations

import numpy as np

from benchmarks.common import banner, save, table
from repro.configs import get_config
from repro.serving import policies, profiler, simulator, traces


def run() -> dict:
    banner("bench_fault_tolerance (paper Fig 11a)")
    cfg = get_config("ofa_resnet")
    prof = profiler.build_profile(cfg)
    arr = traces.bursty_trace(700, 2800, 2, duration=60.0, seed=21)
    scfg = simulator.SimConfig(
        n_workers=8, slo=0.036,
        fault_times={7: 12.0, 6: 24.0, 5: 36.0, 4: 48.0})
    res = simulator.simulate(arr, prof, policies.SlackFit(), scfg)
    s = res.series(6.0)
    rows = [[f"{r[0]:.0f}", f"{r[1]:.0f}", f"{r[2]:.1f}", f"{r[3]:.2f}"]
            for r in s]
    print(table(["t (s)", "qps", "mean batch", "mean acc"], rows))
    print(f"\nSLO attainment with 4/8 workers killed: {res.slo_attainment:.4f} "
          f"(paper: ~0.999)")
    acc_start, acc_end = float(s[0, 3]), float(s[-2, 3])
    payload = {
        "slo_attainment": res.slo_attainment,
        "mean_acc": res.mean_acc,
        "series": s.tolist(),
        "claims": {
            "slo_held_above_999": res.slo_attainment >= 0.999,
            "accuracy_actuated_down": acc_end < acc_start,
        },
    }
    save("fault_tolerance", payload)
    return payload


if __name__ == "__main__":
    run()
