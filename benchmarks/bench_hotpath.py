"""Hot-path regression benchmark: the numbers ``tools/bench_diff.py``
gates PR-over-PR.

Four sections, one per layer of the serving hot path:

1. **Prefill kernel sweep** — block-skipping ``flash_attention_ref``
   vs the dense oracle at growing causal lengths (jitted, warmup +
   median-of-k via :func:`benchmarks.common.time_fn`). The headline
   gate: at the longest causal length the skipping path must be >= 2x
   the dense path, while agreeing numerically.
2. **Decode sweep** — block-skipping cached decode vs the dense cache
   scan at early/late positions in a long cache.
3. **Engine overhead-per-query** — wall-clock of the serving-engine
   event loop (:func:`repro.serving.simulator.simulate`) divided by
   queries handled; model compute is profiled latency, so this isolates
   scheduler/queue bookkeeping.
4. **Cluster event-loop throughput** — queries per wall-second through
   :func:`repro.serving.simulator.simulate_cluster`.

Claims split by kind, mirroring ``results/bench_baseline/tolerances.json``:

* *structural* (timing-insensitive; what CI's perf-smoke gates): skip
  vs dense numerics agreement, the live-block fraction actually
  shrinking, pallas-triton registration, the engine resolving every
  query. Identical between ``--smoke`` and full runs — the simulator
  sections use the same seeded traces in both modes.
* *timing* (full runs only; CI skips via ``bench_diff --skip-timing``):
  the >= 2x prefill gate. ``--smoke`` drops timing iterations to 1 and
  omits the timing claim so a noisy shared runner can't flake it.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import banner, emit_bench_json, save, table, time_fn
from repro.kernels import ops as _ops  # noqa: F401 — populates the registry
from repro.kernels import ref
from repro.kernels.dispatch import DISPATCHER
from repro.kernels.ref import _live_kv_range

PREFILL_LENGTHS = (512, 1024, 2048)
PREFILL_BLOCK = 256
DECODE_SMAX = 4096
DECODE_BLOCK = 256
DECODE_INDICES = (64, DECODE_SMAX - 1)
SPEEDUP_GATE = 2.0
_TOL = dict(rtol=2e-3, atol=2e-3)


def _mk_qkv(S, d=64, Hq=8, Hkv=4, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    return (jax.random.normal(ks[0], (1, Hq, S, d), jnp.float32),
            jax.random.normal(ks[1], (1, Hkv, S, d), jnp.float32),
            jax.random.normal(ks[2], (1, Hkv, S, d), jnp.float32))


def _live_fraction(S: int, block: int) -> float:
    """Fraction of kv blocks the skipping prefill visits (causal)."""
    n = -(-S // block)
    live = sum(hi - lo for qi in range(n)
               for lo, hi in [_live_kv_range(qi * block,
                                             min((qi + 1) * block, S),
                                             n, block, True, 0, None)])
    return live / (n * n)


def _prefill_sweep(warmup: int, iters: int):
    rows, out, agree_all = [], {}, True
    for S in PREFILL_LENGTHS:
        q, k, v = _mk_qkv(S)
        dense = jax.jit(lambda q, k, v: ref.flash_attention_dense_ref(
            q, k, v, causal=True))
        skip = jax.jit(lambda q, k, v: ref.flash_attention_ref(
            q, k, v, causal=True, q_block=PREFILL_BLOCK,
            kv_block=PREFILL_BLOCK))
        agree = bool(np.allclose(np.asarray(dense(q, k, v)),
                                 np.asarray(skip(q, k, v)), **_TOL))
        agree_all &= agree
        td = time_fn(lambda: jax.block_until_ready(dense(q, k, v)),
                     warmup=warmup, iters=iters)
        ts = time_fn(lambda: jax.block_until_ready(skip(q, k, v)),
                     warmup=warmup, iters=iters)
        out[f"S{S}"] = {"dense_ms": td * 1e3, "skip_ms": ts * 1e3,
                        "speedup": td / max(ts, 1e-9),
                        "live_frac": _live_fraction(S, PREFILL_BLOCK)}
        rows.append([S, f"{td*1e3:.2f}", f"{ts*1e3:.2f}",
                     f"{td/max(ts,1e-9):.2f}x",
                     f"{out[f'S{S}']['live_frac']:.3f}",
                     "yes" if agree else "NO"])
    print(table(["S (causal)", "dense ms", "skip ms", "speedup",
                 "live frac", "agree"], rows))
    return out, agree_all


def _decode_sweep(warmup: int, iters: int):
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (1, 8, 1, 64), jnp.float32)
    kc = jax.random.normal(ks[1], (1, 4, DECODE_SMAX, 64), jnp.float32)
    vc = jax.random.normal(ks[2], (1, 4, DECODE_SMAX, 64), jnp.float32)
    dense = jax.jit(lambda i: ref.decode_attention_dense_ref(q, kc, vc, i))
    skip = jax.jit(lambda i: ref.decode_attention_ref(
        q, kc, vc, i, kv_block=DECODE_BLOCK))
    rows, out, agree_all = [], {}, True
    for idx in DECODE_INDICES:
        i = jnp.int32(idx)
        agree = bool(np.allclose(np.asarray(dense(i)), np.asarray(skip(i)),
                                 **_TOL))
        agree_all &= agree
        td = time_fn(lambda: jax.block_until_ready(dense(i)),
                     warmup=warmup, iters=iters)
        ts = time_fn(lambda: jax.block_until_ready(skip(i)),
                     warmup=warmup, iters=iters)
        out[f"idx{idx}"] = {"dense_ms": td * 1e3, "skip_ms": ts * 1e3,
                            "speedup": td / max(ts, 1e-9)}
        rows.append([idx, f"{td*1e3:.3f}", f"{ts*1e3:.3f}",
                     f"{td/max(ts,1e-9):.2f}x", "yes" if agree else "NO"])
    print(table([f"idx (Smax={DECODE_SMAX})", "dense ms", "skip ms",
                 "speedup", "agree"], rows))
    return out, agree_all


def _engine_overhead(warmup: int, iters: int):
    from repro.configs import get_config
    from repro.serving import policies, profiler, simulator, traces
    prof = profiler.build_profile(get_config("ofa_resnet"))
    arr = traces.bursty_trace(800, 3200, 8.0, 4.0, seed=13)
    scfg = simulator.SimConfig(n_workers=8, slo=0.036)
    res_box = {}

    def go():
        res_box["res"] = simulator.simulate(arr, prof, policies.SlackFit(),
                                            scfg)

    wall = time_fn(go, warmup=warmup, iters=iters)
    res = res_box["res"]
    n = len(res.queries)
    resolved = sum(1 for qq in res.queries
                   if qq.finish is not None or qq.dropped)
    out = {"wall_s": wall, "n_queries": float(n),
           "overhead_us_per_query": wall / max(n, 1) * 1e6,
           "slo_attainment": res.slo_attainment,
           "resolved_frac": resolved / max(n, 1)}
    print(f"engine event loop: {n} queries in {wall*1e3:.0f} ms wall "
          f"-> {out['overhead_us_per_query']:.1f} us/query "
          f"(SLO {res.slo_attainment:.4f})")
    return out


def _cluster_throughput(warmup: int, iters: int):
    from repro.configs import get_config
    from repro.serving import policies, profiler, simulator, traces
    prof = profiler.build_profile(get_config("ofa_resnet"))
    arr = traces.bursty_trace(800, 3200, 8.0, 4.0, seed=17)
    ccfg = simulator.ClusterConfig(n_replicas=2, workers_per_replica=4,
                                   placement="least_loaded", slo=0.036)
    res_box = {}

    def go():
        res_box["res"] = simulator.simulate_cluster(arr, prof,
                                                    policies.SlackFit(), ccfg)

    wall = time_fn(go, warmup=warmup, iters=iters)
    res = res_box["res"]
    n = len(res.queries)
    out = {"wall_s": wall, "n_queries": float(n),
           "event_qps": n / max(wall, 1e-9),
           "slo_attainment": res.slo_attainment}
    print(f"cluster event loop: {n} queries in {wall*1e3:.0f} ms wall "
          f"-> {out['event_qps']:.0f} q/s (SLO {res.slo_attainment:.4f})")
    return out


def run(smoke: bool = False) -> dict:
    banner("bench_hotpath (kernel/engine/cluster perf trajectory)"
           + (" [smoke]" if smoke else ""))
    warmup, iters = (1, 1) if smoke else (2, 5)

    prefill, prefill_agree = _prefill_sweep(warmup, iters)
    decode, decode_agree = _decode_sweep(warmup, iters)
    engine = _engine_overhead(warmup, iters)
    cluster = _cluster_throughput(warmup, iters)

    triton_kernels = sum(
        1 for name in DISPATCHER.kernels()
        if "pallas-triton" in DISPATCHER.registered_tiers(name))
    longest = f"S{PREFILL_LENGTHS[-1]}"
    payload = {
        "prefill": prefill, "decode": decode, "engine": engine,
        "cluster": cluster,
        "tiers": {"pallas_triton_kernels": float(triton_kernels)},
        "claims": {
            # structural: stable across hosts/modes, gated in CI smoke
            "prefill_skip_matches_dense": prefill_agree,
            "decode_skip_matches_dense": decode_agree,
            "prefill_skips_dead_blocks":
                prefill[longest]["live_frac"] <= 0.75,
            "pallas_triton_tier_registered": triton_kernels >= 3,
            "engine_resolves_all_queries":
                engine["resolved_frac"] >= 1.0,
        },
    }
    if not smoke:
        # timing: gated only in full runs (CI smoke skips via
        # bench_diff --skip-timing + the omitted claim)
        payload["claims"]["ref_skip_speedup_ge_2x"] = (
            prefill[longest]["speedup"] >= SPEEDUP_GATE)
    save("hotpath", payload)
    return payload


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="structural claims only; single timing iteration")
    args = ap.parse_args()
    payload = run(smoke=args.smoke)
    path = emit_bench_json("hotpath", payload)
    print(f"\nwrote {path}")
    bad = [c for c, ok in payload["claims"].items() if not ok]
    raise SystemExit(1 if bad else 0)
