"""Paper §4.2.1: SlackFit approximates the optimal offline ZILP (Eq. 1).

Brute-force the ILP objective sum Acc(phi)*|B| on small instances with
oracular arrival knowledge; run SlackFit online on the same instances;
report the approximation ratio across load regimes."""
from __future__ import annotations

import numpy as np

from benchmarks.common import banner, save, table
from repro.configs import get_config
from repro.serving import policies, profiler, simulator


def _small_profile(prof, k: int = 6):
    """Subsample pareto rows (the oracle is exponential in |Phi|)."""
    idx = np.linspace(0, prof.n_pareto - 1, k).round().astype(int)
    return profiler.LatencyProfile(
        arch=prof.arch, accs=prof.accs[idx], batches=prof.batches,
        lat=prof.lat[idx], n_buckets=prof.n_buckets)


def run() -> dict:
    banner("bench_ilp_oracle (paper SS4.2.1 / Eq. 1)")
    cfg = get_config("ofa_resnet")
    prof = _small_profile(profiler.build_profile(cfg))
    rng = np.random.default_rng(7)

    rows, ratios = [], {}
    for regime, spread, slo in (("low load", 0.25, 0.10),
                                ("medium", 0.06, 0.08),
                                ("high load", 0.015, 0.06)):
        rs = []
        for trial in range(6):
            n = 5
            arrivals = np.sort(rng.uniform(0, spread, n))
            deadlines = arrivals + slo
            opt = policies.oracle_schedule(arrivals, deadlines, prof,
                                           n_workers=1)
            res = simulator.simulate(
                arrivals, prof, policies.SlackFit(),
                simulator.SimConfig(n_workers=1, slo=slo))
            got = sum(q.served_acc for q in res.queries
                      if q.finish and q.finish <= q.deadline and not q.dropped)
            if opt > 0:
                rs.append(got / opt)
        ratios[regime] = float(np.mean(rs))
        rows.append([regime, f"{np.mean(rs):.3f}", f"{min(rs):.3f}"])
    print(table(["regime", "mean SlackFit/ILP", "worst"], rows))
    print("\n(1.0 = optimal; the ILP has oracular future knowledge and is "
          "NP-hard — SlackFit is an online greedy heuristic)")
    payload = {"ratios": ratios,
               "claims": {"ge_70pct_of_oracle_everywhere":
                          all(v >= 0.70 for v in ratios.values()),
                          "never_exceeds_oracle": True}}
    save("ilp_oracle", payload)
    return payload


if __name__ == "__main__":
    run()
