"""Paper Fig 10: the real-world (MAF-derived) workload — 24h Azure
Functions trace shape-preservingly shrunk to ~120s at ~6400 qps mean,
periodic spikes to ~8750 qps. SuperServe headline: 4.67% higher
accuracy at the same SLO attainment / 2.85x SLO attainment at the same
accuracy vs Clipper+/INFaaS; plus the Fig 10b system dynamics."""
from __future__ import annotations

import numpy as np

from benchmarks.common import banner, save, table
from repro.configs import get_config
from repro.serving import policies, profiler, simulator, traces


def run(duration: float = 60.0) -> dict:
    banner("bench_maf (paper Fig 10)")
    cfg = get_config("ofa_resnet")
    prof = profiler.build_profile(cfg)
    arr = traces.maf_like_trace(6400, duration, seed=42)
    rate, cv2 = traces.trace_stats(arr)
    print(f"trace: {len(arr)} queries, mean {rate:.0f} qps, CV^2={cv2:.1f}")

    scfg = simulator.SimConfig(n_workers=8, slo=0.036)
    pols = [policies.SlackFit(), policies.INFaaSMinCost()]
    idxs = np.linspace(0, prof.n_pareto - 1, 6).round().astype(int)
    pols += [policies.ClipperFixed(int(i), f"clipper+({prof.accs[i]:.2f})")
             for i in idxs]

    rows = []
    for pol in pols:
        res = simulator.simulate(arr, prof, pol, scfg)
        rows.append({"policy": pol.name, "slo": res.slo_attainment,
                     "acc": res.mean_acc})
        if pol.name == "slackfit":
            dyn = res.series(2.0)
    print(table(["policy", "SLO", "acc"],
                [[r["policy"], f"{r['slo']:.5f}", f"{r['acc']:.2f}"] for r in rows]))

    sf = rows[0]
    base999 = [r for r in rows[1:] if r["slo"] >= sf["slo"] - 1e-4]
    acc_gain = sf["acc"] - max(r["acc"] for r in base999) if base999 else None
    near = [r for r in rows[1:] if r["acc"] >= sf["acc"] - 0.05 and r["slo"] > 0]
    slo_factor = sf["slo"] / max(r["slo"] for r in near) if near else None
    print(f"\nheadline: +{acc_gain:.2f}% acc at same SLO (paper: +4.65); "
          f"{slo_factor:.2f}x SLO at same acc (paper: 2.85x)")

    # Fig 10b dynamics: accuracy dips during qps spikes
    spikes = dyn[dyn[:, 1] > np.percentile(dyn[:, 1], 85)]
    calm = dyn[dyn[:, 1] < np.percentile(dyn[:, 1], 25)]
    print(f"dynamics: acc {calm[:,3].mean():.2f} in valleys vs "
          f"{spikes[:,3].mean():.2f} in spikes; batch {calm[:,2].mean():.1f} "
          f"-> {spikes[:,2].mean():.1f}")

    payload = {
        "results": rows,
        "acc_gain_same_slo": acc_gain,
        "slo_factor_same_acc": slo_factor,
        "dynamics": dyn.tolist(),
        "claims": {
            "slackfit_slo_five_nines": sf["slo"] >= 0.999,
            "acc_gain_positive": (acc_gain or 0) > 1.0,
            "slo_factor_gt_2": (slo_factor or 0) > 2.0,
            "accuracy_adapts_to_spikes":
                bool(calm[:, 3].mean() > spikes[:, 3].mean()),
        },
    }
    save("maf", payload)
    return payload


if __name__ == "__main__":
    run()
