"""Paper Fig 4 + Fig 5a: SubNetAct memory savings.

Exact parameter-byte accounting: (a) loading discrete baseline models
(the paper's four ResNets / six extracted subnets) vs one resident
SuperNet serving ~500 subnets; (b) the SubnetNorm bookkeeping overhead
ratio (non-shared norm tables vs shared weights).
"""
from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import banner, save, table
from repro.configs import get_config
from repro.core import calibrate, pareto, subnet as sn
from repro.core.pareto import pareto_subnets, uniform_sample

# Hand-tuned torchvision baselines (params in millions) — paper Fig 1a set.
BASELINE_MODELS = {
    "ResNet-18": 11.7e6, "ResNet-34": 21.8e6, "ResNet-50": 25.6e6,
    "ResNet-101": 44.5e6, "Wide-ResNet-101": 126.9e6, "ConvNeXt-L": 197.8e6,
}


def run() -> dict:
    banner("bench_memory (paper Fig 4 / Fig 5a)")
    cfg = get_config("ofa_resnet")
    pts = pareto_subnets(cfg)
    six = uniform_sample(pts, 6)

    resident = pareto.subnet_weight_bytes(cfg, None, resident=True)
    resnets4 = sum(list(BASELINE_MODELS.values())[:4]) * 4
    six_bytes = sum(pareto.subnet_weight_bytes(cfg, p.sub, resident=False)
                    for p in six)

    # SubnetNorm bookkeeping on the real conv supernet structure
    r = cfg.replace(img_size=32, n_classes=100)
    from repro.models import convnet
    p = convnet.init_convnet(jax.random.PRNGKey(0), r)
    norm_bytes = calibrate.norm_table_bytes(p)
    shared_bytes = calibrate.shared_weight_bytes(p)
    n_subnets = cfg.elastic.num_subnets
    per_subnet_norm = norm_bytes / n_subnets
    ratio = shared_bytes / per_subnet_norm

    rows = [
        ["4 discrete ResNets (fp32)", f"{resnets4/2**20:.0f} MB", "4"],
        [f"6 extracted subnets", f"{six_bytes/2**20:.0f} MB", "6"],
        [f"SubNetAct supernet (resident)", f"{resident/2**20:.0f} MB",
         f"{len(pts)} (all pareto) / {n_subnets} total"],
    ]
    print(table(["deployment", "device memory", "servable models"], rows))
    saving_vs_six = six_bytes / resident
    print(f"\nmemory saving vs 6 extracted subnets: {saving_vs_six:.2f}x "
          f"(paper: up to 2.6x)")
    print(f"SubnetNorm bookkeeping: shared weights / per-subnet norm tables "
          f"= {ratio:.0f}x (paper: ~500x smaller)")

    payload = {
        "resident_supernet_bytes": resident,
        "four_resnets_bytes": resnets4,
        "six_subnets_bytes": six_bytes,
        "saving_vs_six_subnets": saving_vs_six,
        "norm_table_bytes_total": norm_bytes,
        "shared_weight_bytes": shared_bytes,
        "shared_over_per_subnet_norm": ratio,
        "n_servable": len(pts),
        "claims": {"saving_gt_2x": saving_vs_six > 2.0,
                   "norm_tables_orders_smaller": ratio > 100},
    }
    save("memory", payload)
    return payload


if __name__ == "__main__":
    run()
