"""Multi-host serving plane (transport="proc", serving/ipc.py):
parity with inproc — over socketpairs AND the TCP listener —
replica-death conservation over real OS processes, live autoscaling of
replica processes, real-execution children, and the reason the
transport exists: worker compute that is GIL-bound inproc runs
genuinely parallel across replica processes.

Cells:
  * parity — identical paced arrivals through an inproc and a proc
    cluster (MaxAcc + round_robin + generous SLO: completion records
    are timing-independent) must produce the same
    (qid, dropped, served_acc, replica) signatures;
  * TCP loopback — the SAME parity bar with every child dialing the
    coordinator's TCP listener through the HMAC handshake, plus a
    bad-token peer bouncing off the front door (handshake_rejects);
  * autoscale — a scripted spawn/decommission cycle on real replica
    processes conserves every query and the forked replica serves;
  * real exec — an execute="real" child builds its SubnetExecutor from
    the wire spec and returns finite logits rows, not payload echoes;
  * death — SIGKILL one replica process mid-run: the coordinator
    re-routes its queue to survivors and every query still resolves
    exactly once;
  * GIL scale-out — workers busy-spin ``work_ms`` of real CPU per
    batch. Inproc, those spins serialize on the GIL no matter how many
    replica groups exist; as processes they overlap. The speedup claim
    (proc makespan beats inproc) gates in full mode only — CI boxes
    are too noisy/small-core for a timing gate, so --smoke reports it
    informationally and gates the structural claims above.
"""
from __future__ import annotations

import argparse
import asyncio
import sys
import time

import numpy as np

from benchmarks.common import banner, save, table
from repro.configs import get_config
from repro.serving import policies, profiler
from repro.serving.autoscaler import AutoscaleConfig
from repro.serving.ipc import PROTOCOL_VERSION, FrameStream, auth_mac
from repro.serving.runtime import ClusterRouter, WorkerHandle
from repro.serving.replica_proc import make_worker_run

SLO_S = 10.0            # generous: no policy drops, records deterministic
PACE_S = 0.004


def _sig(recs):
    """Timing-independent completion signature (latency excluded)."""
    return sorted((r.qid, bool(r.dropped),
                   round(r.served_acc or 0.0, 9), r.replica) for r in recs)


def _spin_groups(n_replicas, workers, work_ms):
    run = make_worker_run(work_ms)
    return [[WorkerHandle(wid=i, run=run) for i in range(workers)]
            for _ in range(n_replicas)]


async def _serve(router, n_queries, pace=PACE_S, slo=SLO_S):
    """Submit ``n_queries`` paced arrivals, drain, return (records,
    makespan seconds). Makespan excludes process spawn (start())."""
    await router.start()
    t0 = time.perf_counter()
    futs = [await router.submit([float(i)], slo_s=slo)
            for i in range(n_queries)
            if not pace or not await asyncio.sleep(pace)]
    await asyncio.gather(*futs)
    await router.drain(60.0)
    return router.records(), time.perf_counter() - t0


def run(smoke: bool = False) -> dict:
    banner("bench_multiproc (proc transport: serving/ipc.py)")
    prof = profiler.build_profile(get_config("ofa_resnet"))
    n_par = 16 if smoke else 32

    # -- 1) parity: proc records == inproc records ---------------------
    recs_in, _ = asyncio.run(_serve(
        ClusterRouter(prof, policies.MaxAcc(), _spin_groups(2, 2, 0.0)),
        n_par))
    recs_proc, _ = asyncio.run(_serve(
        ClusterRouter(prof, policies.MaxAcc(), [2, 2], transport="proc"),
        n_par))
    parity = _sig(recs_proc) == _sig(recs_in)
    used = sorted({r.replica for r in recs_proc})
    print(f"parity over {n_par} paced queries: "
          f"{'MATCH' if parity else 'MISMATCH'} "
          f"(proc replicas used: {used})")

    # -- 1b) TCP loopback: same parity bar through the listener, plus a
    # bad-token peer bouncing off the handshake ------------------------
    async def tcp_run():
        router = ClusterRouter(prof, policies.MaxAcc(), [2, 2],
                               transport="proc", listen="127.0.0.1:0")
        await router.start()
        # an unauthorized peer dials the live front door mid-serve
        host, port = router.listen_addr
        reader, writer = await asyncio.open_connection(host, port)
        intruder = FrameStream(reader, writer)
        ch = await intruder.recv()
        await intruder.send({"t": "auth", "version": PROTOCOL_VERSION,
                             "mac": auth_mac("WRONG-TOKEN", ch["nonce"])})
        reply = await asyncio.wait_for(intruder.recv(), timeout=5.0)
        intruder.close()
        futs = [await router.submit([float(i)], slo_s=SLO_S)
                for i in range(n_par)
                if not await asyncio.sleep(PACE_S)]
        await asyncio.gather(*futs)
        await router.drain(60.0)
        return router.records(), reply, router.handshake_rejects

    recs_tcp, reject, n_rejects = asyncio.run(tcp_run())
    tcp_parity = _sig(recs_tcp) == _sig(recs_in)
    bad_token_rejected = (reject is not None
                          and reject.get("t") == "reject"
                          and n_rejects == 1)
    print(f"tcp loopback: parity "
          f"{'MATCH' if tcp_parity else 'MISMATCH'}, bad token "
          f"{'rejected' if bad_token_rejected else 'NOT rejected'}")

    # -- 1c) live autoscale over proc: scripted spawn/decommission -----
    async def autoscale_run():
        n = 24 if smoke else 40
        cfg = AutoscaleConfig(min_replicas=1, max_replicas=3,
                              policy="scripted", interval=0.05,
                              cooldown=0.0, cold_start=0.05,
                              spawn_workers=2,
                              script=((0.15, +1), (n * 0.05, -1)))
        router = ClusterRouter(prof, policies.MaxAcc(), [2],
                               transport="proc", autoscale=cfg, slo=SLO_S)
        await router.start()
        futs = [await router.submit([float(i)], slo_s=SLO_S)
                for i in range(n)
                if not await asyncio.sleep(0.06)]
        await asyncio.gather(*futs)
        await router.drain(60.0)
        return router, n

    as_router, n_as = asyncio.run(autoscale_run())
    as_recs = as_router.records()
    as_kinds = [e.kind for e in as_router.autoscaler.events]
    autoscale = {
        "n": n_as, "resolved": len(as_recs),
        "dropped": sum(1 for r in as_recs if r.dropped),
        "spawned_replica_served": sum(1 for r in as_recs
                                      if r.replica == 1 and not r.dropped),
        "event_kinds": sorted(set(as_kinds)),
    }
    print(f"autoscale over proc: {autoscale['resolved']}/{n_as} resolved, "
          f"{autoscale['dropped']} dropped, spawned replica served "
          f"{autoscale['spawned_replica_served']}, events {as_kinds}")

    # -- 1d) real execution in the child -------------------------------
    async def real_run():
        arch = "qwen2-1.5b"
        rcfg = get_config(arch).reduced()
        rprof = profiler.build_profile(rcfg)
        router = ClusterRouter(rprof, policies.MaxAcc(), [1],
                               transport="proc", execute="real",
                               arch=arch, seq_len=8, spawn_timeout=300.0)
        await router.start()
        rng = np.random.default_rng(0)
        payloads = rng.integers(0, rcfg.vocab_size, (4, 8))
        futs = [await router.submit(payloads[i].tolist(), slo_s=60.0)
                for i in range(4)]
        results = await asyncio.gather(*futs)
        await router.drain(60.0)
        return rcfg, payloads, results, router.records()

    rcfg, rpay, rres, rrecs = asyncio.run(real_run())
    real_non_echo = all(
        np.asarray(p, float).shape == (rcfg.vocab_size,)
        and np.all(np.isfinite(np.asarray(p, float)))
        and list(map(float, p)) != [float(x) for x in rpay[i]]
        for i, (p, _) in enumerate(rres))
    real_resolved = (len(rrecs) == 4
                     and all(not r.dropped for r in rrecs))
    print(f"real exec: {len(rrecs)}/4 served, logits rows "
          f"{'real' if real_non_echo else 'ECHOED?'} "
          f"(vocab {rcfg.vocab_size})")

    # -- 2) replica death: SIGKILL one process mid-run -----------------
    async def death_run():
        router = ClusterRouter(prof, policies.MaxAcc(), [1, 1],
                               transport="proc", work_ms=100.0)
        await router.start()
        futs = [await router.submit([float(i)], slo_s=SLO_S)
                for i in range(10)
                if not await asyncio.sleep(0.005)]
        await asyncio.sleep(0.05)
        router.kill_replica(0)          # SIGKILL + coordinator re-route
        await asyncio.gather(*futs)
        await router.drain(60.0)
        return router.records()

    drecs = asyncio.run(death_run())
    death = {
        "resolved": len(drecs), "n": 10,
        "served_by_survivor": sum(1 for r in drecs
                                  if not r.dropped and r.replica == 1),
        "dropped": sum(1 for r in drecs if r.dropped),
    }
    print(f"death: {death['resolved']}/10 resolved, "
          f"{death['served_by_survivor']} served by survivor, "
          f"{death['dropped']} dropped")

    # -- 3) GIL scale-out: spin workers, inproc threads vs processes ---
    work_ms = 30.0 if smoke else 60.0
    n_gil = 16 if smoke else 32
    groups = 4
    timings, rows = {}, []
    for name, router in (
            ("inproc", ClusterRouter(prof, policies.MaxAcc(),
                                     _spin_groups(groups, 1, work_ms))),
            ("proc", ClusterRouter(prof, policies.MaxAcc(), [1] * groups,
                                   transport="proc", work_ms=work_ms))):
        recs, makespan = asyncio.run(_serve(router, n_gil, pace=0.002))
        timings[name] = {"makespan_s": makespan,
                         "resolved": len(recs), "n": n_gil,
                         "served": sum(1 for r in recs if not r.dropped)}
        rows.append([name, f"{makespan * 1e3:.0f}",
                     timings[name]["served"], n_gil])
    speedup = timings["inproc"]["makespan_s"] / max(
        timings["proc"]["makespan_s"], 1e-9)
    print(table(["transport", "makespan ms", "served", "queries"], rows))
    print(f"{groups} replicas x {work_ms:.0f}ms CPU spin per batch: "
          f"proc is {speedup:.2f}x faster than GIL-bound inproc")

    structural = {
        "proc_records_match_inproc": parity,
        "tcp_records_match_inproc": tcp_parity,
        "bad_token_rejected": bad_token_rejected,
        "autoscale_conserves_queries": (
            autoscale["resolved"] == autoscale["n"]
            and autoscale["dropped"] == 0),
        "autoscale_full_lifecycle": (
            {"spawn", "ready", "decommission"}
            <= set(autoscale["event_kinds"])),
        "autoscaled_replica_served": autoscale["spawned_replica_served"] > 0,
        "real_exec_non_echo": real_non_echo,
        "real_exec_all_resolved": real_resolved,
        "every_replica_used": used == [0, 1],
        "all_queries_accounted": (
            len(recs_in) == n_par and len(recs_proc) == n_par
            and all(t["resolved"] == t["n"] for t in timings.values())),
        "death_conserves_queries": death["resolved"] == death["n"],
        "death_orphans_reach_survivors": death["served_by_survivor"] > 0,
    }
    perf = {"proc_beats_gil_bound_inproc": speedup >= 1.3}
    claims = dict(structural) if smoke else {**structural, **perf}
    payload = {"parity": {"n": n_par, "match": parity, "replicas_used": used},
               "tcp": {"match": tcp_parity,
                       "handshake_rejects": n_rejects},
               "autoscale": autoscale,
               "real_exec": {"served": len(rrecs),
                             "vocab": int(rcfg.vocab_size),
                             "non_echo": real_non_echo},
               "replica_death": death, "gil_scaleout": timings,
               "speedup": speedup, "work_ms": work_ms, "smoke": smoke,
               "perf_claims_informational": perf if smoke else None,
               "claims": claims}
    save("multiproc", payload)
    return payload


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="smaller cells; gate only structural claims "
                         "(the GIL speedup is reported, not gated)")
    args = ap.parse_args(argv)
    payload = run(smoke=args.smoke)
    failures = [k for k, ok in payload["claims"].items() if not ok]
    if failures:
        print(f"\nFAILED claims: {failures}")
        return 1
    print("\nall multiproc claims PASS")
    return 0


if __name__ == "__main__":
    sys.exit(main())
