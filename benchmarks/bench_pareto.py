"""Paper Fig 2: supernet subnets dominate hand-tuned ResNets at equal
FLOPs (accuracy predictor vs published torchvision accuracies)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import banner, save, table
from repro.configs import get_config
from repro.core.pareto import accuracy_predictor, pareto_subnets

# (GFLOPs, ImageNet top-1) for the paper's hand-tuned comparison set.
HAND_TUNED = {
    "ResNet-18": (1.8, 69.8), "ResNet-34": (3.7, 73.3),
    "ResNet-50": (4.1, 76.1), "ResNet-101": (7.8, 77.4),
}


def run() -> dict:
    banner("bench_pareto (paper Fig 2)")
    cfg = get_config("ofa_resnet")
    pts = pareto_subnets(cfg)

    rows, wins = [], []
    for name, (gf, acc) in HAND_TUNED.items():
        # best subnet at <= same FLOPs
        cands = [p for p in pts if p.gflops <= gf + 0.05]
        best = max(cands, key=lambda p: p.acc) if cands else None
        if best:
            rows.append([name, f"{gf:.1f}", f"{acc:.1f}%",
                         f"{best.gflops:.2f}", f"{best.acc:.2f}%",
                         f"{best.acc - acc:+.2f}"])
            wins.append(best.acc >= acc - 0.6)
    print(table(["baseline", "GF", "top-1", "subnet GF", "subnet top-1",
                 "delta"], rows))
    payload = {
        "pareto": [{"gflops": p.gflops, "acc": p.acc} for p in pts],
        "hand_tuned": HAND_TUNED,
        "claims": {"subnets_dominate_resnets": all(wins) and len(wins) >= 3},
    }
    save("pareto", payload)
    return payload


if __name__ == "__main__":
    run()
