"""Paper Fig 11c / §A.5: policy design space — SlackFit vs MaxAcc vs
MaxBatch across CV^2 at lambda=7050 (1500 + 5550)."""
from __future__ import annotations

from benchmarks.common import banner, save, table
from repro.configs import get_config
from repro.serving import policies, profiler, simulator, traces

CV2 = (2, 4, 8)


def run() -> dict:
    banner("bench_policies (paper Fig 11c / SSA.5)")
    cfg = get_config("ofa_resnet")
    prof = profiler.build_profile(cfg)
    scfg = simulator.SimConfig(n_workers=8, slo=0.036)
    out, rows = {}, []
    for cv2 in CV2:
        arr = traces.bursty_trace(1500, 5550, cv2, duration=5.0, seed=31)
        cell = {}
        for pol in (policies.SlackFit(), policies.MaxBatch(), policies.MaxAcc()):
            res = simulator.simulate(arr, prof, pol, scfg)
            cell[pol.name] = {"slo": res.slo_attainment, "acc": res.mean_acc}
        out[cv2] = cell
        rows.append([cv2] + [f"({cell[p]['slo']:.4f}, {cell[p]['acc']:.2f})"
                             for p in ("slackfit", "maxbatch", "maxacc")])
    print(table(["CV^2", "slackfit (slo,acc)", "maxbatch", "maxacc"], rows))

    sf_best = all(
        out[c]["slackfit"]["slo"] >= out[c]["maxbatch"]["slo"] - 0.002
        and out[c]["slackfit"]["slo"] >= out[c]["maxacc"]["slo"]
        for c in CV2)
    print(f"\nSlackFit best tradeoff across CV^2: {sf_best} "
          f"(paper: maxacc can't keep up; maxbatch drops ~5% at CV^2=8)")
    payload = {"grid": {str(k): v for k, v in out.items()},
               "claims": {"slackfit_best_tradeoff": bool(sf_best),
                          "maxacc_diverges":
                              out[8]["maxacc"]["slo"] < 0.9}}
    save("policies", payload)
    return payload


if __name__ == "__main__":
    run()
