"""Predictive serving plane: forecast-led scaling vs reactive, and
forecast-led join windows at saturation (ROADMAP "predictive scaling
policies" + "joins at saturation", via serving/forecast.py).

The claims that gate, on BOTH acceptance traces (bursty r7000 CV^2=8
and the MAF-like workload):

  * **scaling SLO** — `predictive` scaling holds SLO attainment >= the
    reactive `queue_pressure` baseline (same bounds, same cold start:
    the forecast can only add lead time, never lose reactivity — on an
    unforecastable burst it degrades to exactly the reactive signal);
  * **scaling cost** — at <= 1.0x the reactive baseline's
    replica-seconds (lead time is not bought with capacity);
  * **join unlock** — in saturated cells where spare-capacity-only
    joins stall (join rate under 1%), predictive windows unlock
    in-flight joins (>= 5x the spare-only join count) without
    regressing SLO attainment;
  * **structural soundness** — a never-firing forecaster replays the
    reactive schedule byte-identically, every batch that admitted a
    join launched within its earliest member deadline, and the
    forecast snapshot is finite and complete.

A deep-overload cell (rate ~2x capacity, where EVERY policy is
shedding load and single-window butterflies dominate) is reported for
context, not gated.

--smoke (CI): seconds-long traces; only the structural claims gate.
"""
from __future__ import annotations

import argparse
import math
import sys

from benchmarks.common import banner, save, table
from repro.configs import get_config
from repro.serving import policies, profiler, simulator, traces
from repro.serving.autoscaler import AutoscaleConfig
from repro.serving.forecast import ForecastConfig

RATE, CV2 = 7000, 8
MAF_RATE = 6400
WORKERS_PER_REPLICA = 2
MIN_R, INIT_R, MAX_R = 2, 4, 8
COLD_START = 0.25               # big enough that reactive lag is visible
SLO_TOL = 0.002                 # join-cell non-regression tolerance (pts)
JOIN_UNLOCK = 5.0               # x spare-only joins in stalled cells
STALL_RATE = 0.01               # spare-only join rate that counts as a stall


def _scale_run(arr, prof, policy):
    acfg = AutoscaleConfig(min_replicas=MIN_R, max_replicas=MAX_R,
                           policy=policy, cold_start=COLD_START)
    ccfg = simulator.ClusterConfig(
        n_replicas=INIT_R, workers_per_replica=WORKERS_PER_REPLICA,
        placement="round_robin", slo=0.036, autoscale=acfg)
    res = simulator.simulate_cluster(arr, prof, policies.SlackFit(), ccfg)
    ev = [e.kind for e in res.scale_events]
    return {"slo": res.slo_attainment, "acc": res.mean_acc,
            "replica_seconds": res.replica_seconds,
            "spawns": ev.count("spawn"),
            "decommissions": ev.count("decommission"),
            "forecast": res.forecast}


def _join_run(arr, prof, n_workers, predictive):
    scfg = simulator.SimConfig(n_workers=n_workers, slo=0.036,
                               continuous_batching=True,
                               predictive_joins=predictive)
    res = simulator.simulate(arr, prof, policies.SlackFit(), scfg)
    deadline_ok = all(d.t + d.latency <= d.batch_deadline + 1e-9
                      for d in res.dispatches if d.joined > 0)
    return {"slo": res.slo_attainment, "acc": res.mean_acc,
            "joins": res.n_joins, "join_rate": res.n_joins / max(len(arr), 1),
            "windows": res.n_open_batches,
            "predictive_windows": res.n_predictive_windows,
            "deadline_ok": deadline_ok}


def _replay_claim(prof) -> bool:
    """A coordinator forecaster that can never reach signal makes
    `predictive` replay the `queue_pressure` schedule byte-identically
    (records AND the scale-event timeline)."""
    arr = traces.bursty_trace(400, 1600, 4, 2.0, seed=23)

    def run(policy, forecast=None):
        acfg = AutoscaleConfig(min_replicas=1, max_replicas=6,
                               policy=policy, cooldown=0.2)
        ccfg = simulator.ClusterConfig(
            n_replicas=2, workers_per_replica=2, placement="round_robin",
            slo=0.036, autoscale=acfg, forecast=forecast)
        return simulator.simulate_cluster(arr, prof, policies.SlackFit(),
                                          ccfg)

    base = run("queue_pressure")
    mute = run("predictive", forecast=ForecastConfig(min_arrivals=10**9))
    return (mute.records == base.records
            and [(e.t, e.kind, e.rid) for e in mute.scale_events]
            == [(e.t, e.kind, e.rid) for e in base.scale_events])


def run(duration: float = 8.0, maf_duration: float = 20.0,
        smoke: bool = False) -> dict:
    banner("bench_predictive (ROADMAP predictive scaling + "
           "saturation joins)")
    prof = profiler.build_profile(get_config("ofa_resnet"))

    arrs = {
        "bursty": traces.bursty_trace(RATE * 0.2, RATE * 0.8, CV2,
                                      duration, seed=13),
        "maf": traces.maf_like_trace(MAF_RATE, maf_duration, seed=13),
    }

    # -- predictive vs reactive scaling ---------------------------------
    scaling, claims = {}, {}
    rows = []
    for trace, arr in arrs.items():
        react = _scale_run(arr, prof, "queue_pressure")
        pred = _scale_run(arr, prof, "predictive")
        ratio = (pred["replica_seconds"]
                 / max(react["replica_seconds"], 1e-9))
        scaling[trace] = {"reactive": react, "predictive": pred,
                          "rs_ratio": ratio}
        for name, c in (("reactive", react), ("predictive", pred)):
            rows.append([trace, name, f"{c['slo']:.4f}", f"{c['acc']:.2f}",
                         f"{c['replica_seconds']:.1f}",
                         f"{c['spawns']}/{c['decommissions']}"])
        claims[f"{trace}_predictive_slo_geq_reactive"] = (
            pred["slo"] >= react["slo"] - 1e-9)
        claims[f"{trace}_predictive_replica_seconds_leq_1x"] = (
            ratio <= 1.0 + 1e-9)
    print(table(["trace", "scaling", "SLO", "acc", "replica-s",
                 "spawn/decom"], rows))

    # -- predictive joins at saturation ---------------------------------
    # few-worker pools where the queue drains to empty with no spare
    # worker: the PR 2 spare-capacity gate stalls there (join rate ~0)
    join_cells = {
        "bursty_sat": (arrs["bursty"], 8),
        "maf_sat": (arrs["maf"], 8),
        # deep overload (~2x capacity): reported, NOT gated — every
        # policy is shedding load and butterflies dominate
        "bursty_overload": (
            traces.bursty_trace(600, 2400, CV2, duration, seed=13), 2),
    }
    joins, jrows = {}, []
    for cell, (arr, nw) in join_cells.items():
        spare = _join_run(arr, prof, nw, predictive=False)
        pred = _join_run(arr, prof, nw, predictive=True)
        joins[cell] = {"spare_only": spare, "predictive": pred}
        for name, c in (("spare-only", spare), ("predictive", pred)):
            jrows.append([cell, name, f"{c['slo']:.4f}", f"{c['acc']:.2f}",
                          f"{c['joins']}", f"{c['join_rate']:.3f}",
                          f"{c['predictive_windows']}"])
        if cell == "bursty_overload":
            continue
        stalled = spare["join_rate"] < STALL_RATE
        claims[f"{cell}_spare_only_joins_stall"] = stalled
        claims[f"{cell}_joins_unlocked"] = (
            pred["joins"] >= JOIN_UNLOCK * max(spare["joins"], 1))
        claims[f"{cell}_no_slo_regression"] = (
            pred["slo"] >= spare["slo"] - SLO_TOL)
    print()
    print(table(["cell", "joins", "SLO", "acc", "joined", "join rate",
                 "pred windows"], jrows))

    # -- structural soundness (always gated, smoke included) ------------
    snapshots = [c["predictive"]["forecast"] for c in scaling.values()]
    structural = {
        "never_firing_forecaster_replays_reactive": _replay_claim(prof),
        "joined_batches_meet_deadlines": all(
            c[k]["deadline_ok"] for c in joins.values()
            for k in ("spare_only", "predictive")),
        "forecast_snapshot_finite_and_complete": all(
            s is not None and s["n_observed"] > 0
            and all(v is None or math.isfinite(v) for v in s.values())
            for s in snapshots),
    }
    gated = dict(structural) if smoke else {**structural, **claims}
    payload = {"scaling": scaling, "joins": joins, "smoke": smoke,
               "config": {"min": MIN_R, "init": INIT_R, "max": MAX_R,
                          "workers_per_replica": WORKERS_PER_REPLICA,
                          "cold_start": COLD_START, "slo_tol": SLO_TOL,
                          "join_unlock": JOIN_UNLOCK},
               "perf_claims_informational": claims if smoke else None,
               "claims": gated}
    save("predictive", payload)
    return payload


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--duration", type=float, default=8.0)
    ap.add_argument("--maf-duration", type=float, default=20.0)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny trace; gate only structural claims")
    args = ap.parse_args(argv)
    if args.smoke:
        args.duration = min(args.duration, 1.5)
        args.maf_duration = min(args.maf_duration, 3.0)
    payload = run(args.duration, args.maf_duration, smoke=args.smoke)
    failures = [k for k, ok in payload["claims"].items() if not ok]
    if failures:
        print(f"\nFAILED claims: {failures}")
        return 1
    print("\nall predictive-serving claims PASS")
    return 0


if __name__ == "__main__":
    sys.exit(main())
