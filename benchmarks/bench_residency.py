"""Residency as a first-class layer: subnet-residency-aware placement
(``actuation_aware``) and the sticky scheduling policy
(``slackfit_sticky``) vs residency-blind baselines (ROADMAP
"subnet-residency-aware placement", via serving/residency.py).

All gated cells run the weight-loading regime (``load_on_switch`` — the
Clipper+/INFaaS cost model, paper Fig 1a) on the multi-subnet MAF
trace, where queue pressure walks SlackFit across Pareto points and
every walk pays a full weight page-in. The claims that gate:

  * **placement duel** — with the policy held fixed (slackfit_sticky),
    ``actuation_aware`` placement attains goodput >= ``slack_aware``
    at equal SLO, on both trace seeds: pricing the likely subnet's
    switch cost into routing packs queries onto already-resident
    replicas instead of forcing page-ins on whoever is free;
  * **stacked regime** — the full residency-aware stack (sticky +
    actuation_aware) vs the residency-blind baseline (slackfit +
    slack_aware): ``switch_rate`` drops >= 4x and goodput improves;
  * **sticky engine** — single-engine slackfit_sticky vs slackfit:
    ``switch_rate`` drops >= 4x with no SLO regression;
  * **weight sharing rescues the churn** — the same churny slackfit
    baseline loses nothing under SubNetAct's ~50 us control swap
    (``load_on_switch=False``): residency awareness is exactly the
    price of NOT weight-sharing (paper Fig 1a vs 5b).

Structural claims (always gated, --smoke included):

  * switch accounting reconstructs bit-exactly from the dispatch
    stream (independent residency walk over the records);
  * ``switch_rate`` / ``actuation_seconds`` well-formed in every cell;
  * the gated trace really is multi-subnet (>= 2 distinct Pareto
    points dispatched);
  * cluster residency introspection (``residency_snapshot``) is
    complete, read-only keyed by alive replicas, and residency dies
    with a failed replica.

--smoke (CI): seconds-long traces; only the structural claims gate.
"""
from __future__ import annotations

import argparse
import math
import sys

from benchmarks.common import banner, save, table
from repro.configs import get_config
from repro.serving import cluster, policies, profiler, simulator, traces
from repro.serving.engine import SchedulingEngine
from repro.serving.queue import Query
from repro.serving.residency import ActuationModel

MAF_RATE = 8000                 # cluster cells: 4x2 workers near the knee
SINGLE_RATE = 2000              # single-engine cell: 8 workers, churny
SEEDS = (7, 42)
N_REPLICAS, WORKERS_PER_REPLICA = 4, 2
N_WORKERS = 8                   # single-engine pool
SLO = 0.036
SWITCH_DROP = 4.0               # x drop in switch_rate that counts
SLO_TOL = 0.002                 # sticky non-regression tolerance (pts)


def _cluster_run(arr, prof, pol, placement, load=True):
    ccfg = simulator.ClusterConfig(
        n_replicas=N_REPLICAS, workers_per_replica=WORKERS_PER_REPLICA,
        placement=placement, slo=SLO, load_on_switch=load)
    res = simulator.simulate_cluster(arr, prof, pol, ccfg)
    st = res.stats()
    return {"slo": res.slo_attainment, "acc": res.mean_acc,
            "switch_rate": st["switch_rate"],
            "actuation_seconds": st["actuation_seconds"],
            "n_switches": res.n_switches, "n_dispatches": len(res.dispatches)}


def _single_run(arr, prof, pol, load=True):
    scfg = simulator.SimConfig(n_workers=N_WORKERS, slo=SLO,
                               load_on_switch=load)
    res = simulator.simulate(arr, prof, pol, scfg)
    st = res.stats()
    return res, {"slo": res.slo_attainment, "acc": res.mean_acc,
                 "switch_rate": st["switch_rate"],
                 "actuation_seconds": st["actuation_seconds"],
                 "n_switches": res.n_switches,
                 "n_dispatches": len(res.dispatches)}


def _accounting_reconstructs(res, prof, load) -> bool:
    """Walk the dispatch stream with an independent residency map and
    the same ActuationModel: the switch count must match exactly and
    the booked actuation-seconds bit-for-bit (same accumulation
    order as the tracker's per-launch ``+=``)."""
    model = ActuationModel(load_on_switch=load)
    resident, n_switches, seconds = {}, 0, 0.0
    for d in res.dispatches:
        prev = resident.get(d.worker)
        if prev != d.pareto_idx:
            n_switches += 1
        seconds += model.switch_cost(prof, prev, d.pareto_idx)
        resident[d.worker] = d.pareto_idx
    return n_switches == res.n_switches and seconds == res.actuation_seconds


def _well_formed(cells) -> bool:
    return all(0 <= c["n_switches"] <= c["n_dispatches"]
               and 0.0 <= c["switch_rate"] <= 1.0
               and math.isfinite(c["actuation_seconds"])
               and c["actuation_seconds"] >= 0.0
               for c in cells)


def _introspection_claim(prof) -> bool:
    """residency_snapshot() covers exactly the alive replicas with one
    entry per worker (fresh pools: all None), and a replica death drops
    its residency from the snapshot entirely."""
    engines = [SchedulingEngine(prof, policies.SlackFit(),
                                worker_ids=range(2), replica_id=rid)
               for rid in range(3)]
    coord = cluster.ClusterCoordinator(engines, cluster.ActuationAware())
    snap = coord.residency_snapshot()
    fresh_ok = (set(snap) == {0, 1, 2}
                and all(set(v) == {0, 1}
                        and all(r is None for r in v.values())
                        for v in snap.values()))
    coord.fail_replica(1, now=0.0)
    after = coord.residency_snapshot()
    return fresh_ok and set(after) == {0, 2}


def run(duration: float = 10.0, smoke: bool = False) -> dict:
    banner("bench_residency (ROADMAP subnet-residency-aware placement)")
    prof = profiler.build_profile(get_config("ofa_resnet"))

    # -- placement duel: policy fixed, placements differ ----------------
    placement_cells, claims, rows = {}, {}, []
    for seed in SEEDS:
        arr = traces.maf_like_trace(MAF_RATE, duration, seed=seed)
        cell = {}
        for plc in ("slack_aware", "actuation_aware"):
            cell[plc] = _cluster_run(arr, prof, policies.StickySlackFit(),
                                     plc)
            rows.append([f"maf_s{seed}", plc, f"{cell[plc]['slo']:.4f}",
                         f"{cell[plc]['acc']:.2f}",
                         f"{cell[plc]['switch_rate']:.4f}",
                         f"{cell[plc]['actuation_seconds']:.2f}"])
        placement_cells[f"maf_s{seed}"] = cell
        claims[f"maf_s{seed}_actuation_aware_goodput_geq_slack_aware"] = (
            cell["actuation_aware"]["slo"] >= cell["slack_aware"]["slo"])
    print(table(["cell", "placement", "SLO", "acc", "switch rate",
                 "actuation-s"], rows))

    # -- stacked: residency-aware stack vs residency-blind baseline -----
    arr = traces.maf_like_trace(MAF_RATE, duration, seed=SEEDS[0])
    base = _cluster_run(arr, prof, policies.SlackFit(), "slack_aware")
    stack = _cluster_run(arr, prof, policies.StickySlackFit(),
                         "actuation_aware")
    claims["stack_switch_rate_drops"] = (
        stack["switch_rate"] * SWITCH_DROP <= base["switch_rate"])
    claims["stack_goodput_improves"] = stack["slo"] >= base["slo"]

    # -- sticky engine: single pool, policy is the only difference ------
    arr1 = traces.maf_like_trace(SINGLE_RATE, duration, seed=SEEDS[0])
    res_b, churn = _single_run(arr1, prof, policies.SlackFit())
    res_s, sticky = _single_run(arr1, prof, policies.StickySlackFit())
    claims["sticky_switch_rate_drops"] = (
        sticky["switch_rate"] * SWITCH_DROP <= churn["switch_rate"])
    claims["sticky_no_slo_regression"] = (
        sticky["slo"] >= churn["slo"] - SLO_TOL)

    # -- control-swap regime: weight sharing rescues the churn ----------
    res_w, swap = _single_run(arr1, prof, policies.SlackFit(), load=False)
    claims["weight_sharing_rescues_churny_baseline"] = (
        swap["slo"] >= churn["slo"] + 0.5)

    srows = [["stack(blind)", base["slo"], base["switch_rate"]],
             ["stack(aware)", stack["slo"], stack["switch_rate"]],
             ["engine(slackfit)", churn["slo"], churn["switch_rate"]],
             ["engine(sticky)", sticky["slo"], sticky["switch_rate"]],
             ["engine(slackfit, control-swap)", swap["slo"],
              swap["switch_rate"]]]
    print()
    print(table(["cell", "SLO", "switch rate"],
                [[c, f"{s:.4f}", f"{w:.4f}"] for c, s, w in srows]))

    # -- structural soundness (always gated, smoke included) ------------
    all_cells = ([c[p] for c in placement_cells.values() for p in c]
                 + [base, stack, churn, sticky, swap])
    structural = {
        "switch_accounting_reconstructs_from_dispatches": (
            _accounting_reconstructs(res_b, prof, True)
            and _accounting_reconstructs(res_s, prof, True)
            and _accounting_reconstructs(res_w, prof, False)),
        "switch_metrics_well_formed_all_cells": _well_formed(all_cells),
        "maf_trace_is_multi_subnet": (
            len({d.pareto_idx for d in res_b.dispatches}) >= 2),
        "residency_snapshot_complete_and_dies_with_replica":
            _introspection_claim(prof),
    }
    gated = dict(structural) if smoke else {**structural, **claims}
    payload = {"placement": placement_cells,
               "stack": {"blind": base, "aware": stack},
               "sticky": {"slackfit": churn, "sticky": sticky,
                          "control_swap": swap},
               "smoke": smoke,
               "config": {"maf_rate": MAF_RATE, "single_rate": SINGLE_RATE,
                          "seeds": list(SEEDS), "n_replicas": N_REPLICAS,
                          "workers_per_replica": WORKERS_PER_REPLICA,
                          "n_workers": N_WORKERS, "slo": SLO,
                          "switch_drop": SWITCH_DROP, "slo_tol": SLO_TOL},
               "perf_claims_informational": claims if smoke else None,
               "claims": gated}
    save("residency", payload)
    return payload


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--duration", type=float, default=10.0)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny trace; gate only structural claims")
    args = ap.parse_args(argv)
    if args.smoke:
        args.duration = min(args.duration, 2.5)
    payload = run(args.duration, smoke=args.smoke)
    failures = [k for k, ok in payload["claims"].items() if not ok]
    if failures:
        print(f"\nFAILED claims: {failures}")
        return 1
    print("\nall residency claims PASS")
    return 0


if __name__ == "__main__":
    sys.exit(main())
