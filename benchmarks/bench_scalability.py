"""Paper Fig 11b: linear scaling — max sustained qps at 0.999 SLO as
the worker pool grows (fixed small model, client batches of 8, CV^2=0,
no adaptive batching — the paper's microbenchmark setup)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import banner, save, table
from repro.configs import get_config
from repro.serving import policies, profiler, simulator, traces

WORKERS = (1, 2, 4, 8, 16, 32)


def max_sustained(prof, n_workers: int) -> float:
    pol = policies.ClipperFixed(0)          # smallest subnet (ResNet18-ish)
    scfg = simulator.SimConfig(n_workers=n_workers, slo=0.036)
    lo, hi = 50.0, 12_000.0 * n_workers
    for _ in range(16):
        mid = (lo + hi) / 2
        # clients submit batches of 8 -> model as rate/8 dispatches of 8
        arr = traces.bursty_trace(mid / 8, 0.0, 0.0, duration=2.0, seed=0)
        res = simulator.simulate(arr, prof, pol, scfg)
        if res.slo_attainment >= 0.999:
            lo = mid
        else:
            hi = mid
    return lo


def run() -> dict:
    banner("bench_scalability (paper Fig 11b)")
    cfg = get_config("ofa_resnet")
    prof = profiler.build_profile(cfg, batches=(8,), n_buckets=4)
    rows, out = [], {}
    for w in WORKERS:
        qps = max_sustained(prof, w)
        out[w] = qps
        rows.append([w, f"{qps:.0f}"])
    print(table(["workers", "max qps @ 0.999 SLO"], rows))
    per_worker = {w: q / w for w, q in out.items()}
    lin = per_worker[WORKERS[-1]] / per_worker[WORKERS[0]]
    print(f"\nper-worker throughput ratio (32w vs 1w): {lin:.2f} "
          f"(1.0 = perfectly linear; paper reaches 33k qps)")
    payload = {"qps_by_workers": out, "linearity": lin,
               "claims": {"near_linear": lin > 0.85,
                          "tops_30k_at_32_workers": out[32] > 30_000}}
    save("scalability", payload)
    return payload


if __name__ == "__main__":
    run()
