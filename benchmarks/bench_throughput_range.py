"""Paper Fig 5c: dynamic throughput range — max sustained ingest for
the smallest / median / largest subnet on 8 workers (open-loop arrival,
SLO attainment >= 0.999)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import banner, save, table
from repro.configs import get_config
from repro.serving import policies, profiler, simulator, traces


def max_sustained(prof, pareto_idx: int, n_workers: int = 8,
                  slo: float = 0.036, target: float = 0.999) -> float:
    """Binary-search the highest CV2=0 ingest rate the fixed subnet
    sustains at >= target SLO attainment."""
    pol = policies.ClipperFixed(pareto_idx)
    lo, hi = 100.0, 40_000.0
    scfg = simulator.SimConfig(n_workers=n_workers, slo=slo)
    for _ in range(18):
        mid = (lo + hi) / 2
        arr = traces.bursty_trace(mid, 0.0, 0.0, duration=3.0, seed=0)
        res = simulator.simulate(arr, prof, pol, scfg)
        if res.slo_attainment >= target:
            lo = mid
        else:
            hi = mid
    return lo


def run() -> dict:
    banner("bench_throughput_range (paper Fig 5c)")
    cfg = get_config("ofa_resnet")
    prof = profiler.build_profile(cfg)
    idxs = {"smallest": 0, "median": prof.n_pareto // 2,
            "largest": prof.n_pareto - 1}
    rows, out = [], {}
    for name, i in idxs.items():
        qps = max_sustained(prof, i)
        out[name] = {"acc": float(prof.accs[i]), "max_qps": qps}
        rows.append([name, f"{prof.accs[i]:.2f}%", f"{qps:.0f} qps"])
    print(table(["subnet", "accuracy", "max sustained (8 workers)"], rows))
    rng = out["smallest"]["max_qps"] / out["largest"]["max_qps"]
    print(f"\ndynamic throughput range: {rng:.1f}x across "
          f"{out['largest']['acc'] - out['smallest']['acc']:.1f} accuracy pts "
          f"(paper: ~2-8k qps, ~4x, within ~6 pts)")
    payload = {**out, "range_x": rng,
               "claims": {"range_ge_3x": rng >= 3.0}}
    save("throughput_range", payload)
    return payload


if __name__ == "__main__":
    run()
