"""Shared benchmark plumbing: result persistence, table rendering, and
noise-resistant timing (warmup + median-of-k)."""
from __future__ import annotations

import json
import math
import os
import statistics
import time
from typing import Any, Callable, Dict, List

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "results", "bench")


def time_fn(fn: Callable[[], Any], *, warmup: int = 2, iters: int = 5) -> float:
    """Median-of-``iters`` wall seconds for ``fn()``, after ``warmup``
    untimed calls (absorbs jit compilation and cache warm-up).

    The median (not mean/min) is what ``tools/bench_diff.py`` tolerances
    are written against: robust to a single preempted iteration without
    hiding a real regression the way min does. ``fn`` must block on its
    result (``jax.block_until_ready``) for the number to mean anything.
    """
    for _ in range(max(0, warmup)):
        fn()
    ts = []
    for _ in range(max(1, iters)):
        t0 = time.perf_counter()
        fn()
        ts.append(time.perf_counter() - t0)
    return statistics.median(ts)


def save(name: str, payload: Dict[str, Any]) -> None:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    with open(os.path.join(RESULTS_DIR, f"{name}.json"), "w") as f:
        json.dump(payload, f, indent=1, default=str)


def emit_bench_json(name: str, payload: Dict[str, Any]) -> str:
    """Write the compact claims-first artifact ``BENCH_<name>.json``:
    the bench's claim booleans plus every finite numeric scalar from
    the payload, flattened to dotted keys (lists and strings skipped).
    CI uploads these so a claim regression is diffable without wading
    through the full result payload; returns the written path."""
    claims = dict(payload.get("claims") or {})
    scalars: Dict[str, float] = {}

    def walk(prefix: str, node: Dict[str, Any]) -> None:
        for k, v in node.items():
            key = f"{prefix}.{k}" if prefix else str(k)
            if isinstance(v, dict):
                walk(key, v)
            elif isinstance(v, bool) or v is None:
                continue
            elif isinstance(v, (int, float)) and math.isfinite(v):
                scalars[key] = float(v)

    walk("", {k: v for k, v in payload.items() if k != "claims"})
    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = os.path.join(RESULTS_DIR, f"BENCH_{name}.json")
    with open(path, "w") as f:
        json.dump({"bench": name, "claims": claims, "scalars": scalars},
                  f, indent=1, sort_keys=True)
    return path


def table(headers: List[str], rows: List[List[Any]]) -> str:
    widths = [max(len(str(h)), *(len(str(r[i])) for r in rows)) if rows
              else len(str(h)) for i, h in enumerate(headers)]
    line = " | ".join(str(h).ljust(w) for h, w in zip(headers, widths))
    sep = "-+-".join("-" * w for w in widths)
    body = "\n".join(" | ".join(str(c).ljust(w) for c, w in zip(r, widths))
                     for r in rows)
    return f"{line}\n{sep}\n{body}"


def banner(title: str) -> None:
    print(f"\n{'=' * 72}\n{title}\n{'=' * 72}")
