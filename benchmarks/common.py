"""Shared benchmark plumbing: result persistence + table rendering."""
from __future__ import annotations

import json
import os
from typing import Any, Dict, List

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "results", "bench")


def save(name: str, payload: Dict[str, Any]) -> None:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    with open(os.path.join(RESULTS_DIR, f"{name}.json"), "w") as f:
        json.dump(payload, f, indent=1, default=str)


def table(headers: List[str], rows: List[List[Any]]) -> str:
    widths = [max(len(str(h)), *(len(str(r[i])) for r in rows)) if rows
              else len(str(h)) for i, h in enumerate(headers)]
    line = " | ".join(str(h).ljust(w) for h, w in zip(headers, widths))
    sep = "-+-".join("-" * w for w in widths)
    body = "\n".join(" | ".join(str(c).ljust(w) for c, w in zip(r, widths))
                     for r in rows)
    return f"{line}\n{sep}\n{body}"


def banner(title: str) -> None:
    print(f"\n{'=' * 72}\n{title}\n{'=' * 72}")
