"""Benchmark aggregator: one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only SUBSTR] [--skip SUBSTR]

``--only`` / ``--skip`` match benchmark names by *substring* (e.g.
``--only cluster`` or ``--only maf fault``), so CI can gate on any
subset; the runner exits nonzero when a claim fails, a benchmark
errors, or ``--only`` matches nothing.

Each bench prints its table, persists results/bench/<name>.json, and
returns a ``claims`` dict of paper-claim booleans; the runner prints
the claim scoreboard at the end (EXPERIMENTS.md consumes it).
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback

from benchmarks import (bench_acceleration, bench_actuation,
                        bench_autoscaling, bench_bursty_grid,
                        bench_cluster_scaleout, bench_continuous_batching,
                        bench_executor, bench_hotpath, bench_ilp_oracle,
                        bench_control_space, bench_fault_tolerance,
                        bench_maf, bench_memory, bench_multiproc,
                        bench_pareto, bench_policies, bench_predictive,
                        bench_residency, bench_scalability,
                        bench_throughput_range)
from benchmarks.common import banner, emit_bench_json, save, table

ALL = {
    "actuation": bench_actuation.run,            # Fig 1a / 5b
    "memory": bench_memory.run,                  # Fig 4 / 5a
    "pareto": bench_pareto.run,                  # Fig 2
    "throughput_range": bench_throughput_range.run,   # Fig 5c
    "control_space": bench_control_space.run,    # Fig 13
    "bursty_grid": bench_bursty_grid.run,        # Fig 8
    "continuous_batching": bench_continuous_batching.run,  # §5 in-flight joins
    "cluster_scaleout": bench_cluster_scaleout.run,  # multi-replica plane
    "autoscaling": bench_autoscaling.run,        # reactive replica scaling
    "predictive": bench_predictive.run,          # forecast-led scaling + joins
    "residency": bench_residency.run,            # residency-aware placement
    "acceleration": bench_acceleration.run,      # Fig 9
    "maf": bench_maf.run,                        # Fig 10
    "fault_tolerance": bench_fault_tolerance.run,  # Fig 11a
    "scalability": bench_scalability.run,        # Fig 11b
    "policies": bench_policies.run,              # Fig 11c
    "ilp_oracle": bench_ilp_oracle.run,          # SS4.2.1 Eq. 1
    "hotpath": bench_hotpath.run,                # kernel/engine perf gate
    "executor": bench_executor.run,              # compiled-path serving
    "multiproc": bench_multiproc.run,            # proc transport (ipc.py)
}


def select(only, skip) -> list:
    """Substring-match benchmark names (exact names still match, being
    substrings of themselves)."""
    names = [n for n in ALL
             if only is None or any(s in n for s in only)]
    return [n for n in names if not any(s in n for s in skip)]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", nargs="*", default=None,
                    help="run benchmarks whose name contains any SUBSTR")
    ap.add_argument("--skip", nargs="*", default=[],
                    help="skip benchmarks whose name contains any SUBSTR")
    ap.add_argument("--emit-bench-json", action="store_true",
                    help="also write results/bench/BENCH_<name>.json per "
                         "bench: claims + flattened numeric scalars (the "
                         "compact artifact CI uploads)")
    args = ap.parse_args(argv)

    names = select(args.only, args.skip)
    if not names:
        print(f"--only {args.only} --skip {args.skip} matches no benchmark "
              f"out of: {', '.join(ALL)}")
        return 2
    scoreboard, failures = [], []
    for name in names:
        t0 = time.time()
        try:
            payload = ALL[name]()
            if args.emit_bench_json:
                emit_bench_json(name, payload)
            for claim, ok in (payload.get("claims") or {}).items():
                scoreboard.append([name, claim, "PASS" if ok else "FAIL"])
                if not ok:
                    failures.append((name, claim))
        except Exception as e:  # noqa: BLE001
            traceback.print_exc()
            scoreboard.append([name, "<ran>", f"ERROR: {e!r}"])
            failures.append((name, repr(e)))
        print(f"[{name}: {time.time()-t0:.1f}s]")

    banner("PAPER-CLAIM SCOREBOARD")
    print(table(["benchmark", "claim", "status"], scoreboard))
    save("scoreboard", {"rows": scoreboard,
                        "failures": [list(f) for f in failures]})
    if failures:
        print(f"\n{len(failures)} claim(s) not reproduced")
        return 1
    print("\nall paper claims reproduced")
    return 0


if __name__ == "__main__":
    sys.exit(main())
