"""Elastic fault-tolerant training: train on an 8-device (4x2) mesh,
crash, then RESTORE THE SAME CHECKPOINT ONTO A 4-device (2x2) mesh and
continue — the surviving-pool restart path for node failures.

    PYTHONPATH=src python examples/elastic_restart.py
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig, ElasticSpec, Stage
from repro.distributed.sharding import ShardingPlan
from repro.launch.mesh import make_mesh
from repro.models import lm
from repro.training import checkpoint as ckpt
from repro.training import data, optimizer as opt, supernet

CFG = ArchConfig(
    name="elastic-demo", family="dense",
    stages=(Stage(("attn", "mlp"), repeat=4),),
    d_model=128, n_heads=8, n_kv_heads=4, d_ff=512, vocab_size=1024,
    head_dim=16, dtype="float32",
    elastic=ElasticSpec(depth_fracs=(0.5, 1.0)),
)


def train_steps(mesh, params, state, task, start, n, ocfg):
    plan = ShardingPlan(mesh, CFG)
    step = jax.jit(supernet.make_train_step(CFG, ocfg, n_random=0))
    params = jax.tree.map(jax.device_put, params, plan.params(params))
    with mesh:
        for i in range(start, start + n):
            batch = {k: jax.device_put(jnp.asarray(v),
                                       plan.named(plan.batch_spec(k, v.shape)))
                     for k, v in task.batch(i).items()}
            params, state, m = step(params, state, batch, jax.random.PRNGKey(i))
        loss = float(m["loss"])
    return params, state, loss


def main():
    task = data.SyntheticTask(1024, 32, 8, seed=0, order=1, noise=0.0)
    ocfg = opt.AdamWConfig(lr=5e-3, warmup_steps=5, total_steps=80)
    params = lm.init_model(jax.random.PRNGKey(0), CFG)
    state = opt.init(params)

    mesh_a = make_mesh((4, 2), ("data", "model"))
    print(f"phase 1: training on mesh {dict(mesh_a.shape)} (8 devices)")
    params, state, loss_a = train_steps(mesh_a, params, state, task, 0, 20, ocfg)
    print(f"  step 20 loss {loss_a:.3f}")

    with tempfile.TemporaryDirectory() as d:
        ckpt.save(d, 20, {"params": params, "opt": state}, extra={"step": 20})
        print(f"  checkpoint written on mesh A -> {d}")
        print("  !! simulating loss of half the data-parallel pool")

        mesh_b = make_mesh((2, 2), ("data", "model"))
        plan_b = ShardingPlan(mesh_b, CFG)
        template = {"params": jax.tree.map(np.zeros_like, params),
                    "opt": jax.tree.map(np.zeros_like, state)}
        shardings = {"params": plan_b.params(params),
                     "opt": jax.tree.map(
                         lambda s: plan_b.named(jax.sharding.PartitionSpec()),
                         state)}
        restored, extra = ckpt.restore(d, template, shardings=shardings)
        print(f"phase 2: restored step {extra['step']} onto mesh "
              f"{dict(mesh_b.shape)} (4 devices) — different shardings, "
              f"same bytes")
        params2, state2, loss_b = train_steps(
            mesh_b, restored["params"], restored["opt"], task, 20, 20, ocfg)
        print(f"  step 40 loss {loss_b:.3f} (continued seamlessly: "
              f"{loss_b < loss_a + 0.1})")


if __name__ == "__main__":
    main()
