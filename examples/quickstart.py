"""Quickstart: build a weight-shared SuperNet, run SubNetAct.

    PYTHONPATH=src python examples/quickstart.py

Shows the whole paper in miniature: one set of resident weights, a
control tuple per subnet, instant actuation (no reload/recompile), and
the latency/accuracy menu SlackFit schedules over.
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig, ElasticSpec, Stage
from repro.core import subnet as sn
from repro.core.pareto import pareto_subnets
from repro.models import lm

cfg = ArchConfig(
    name="quickstart-supernet", family="dense",
    stages=(Stage(("attn", "mlp"), repeat=4),),
    d_model=128, n_heads=8, n_kv_heads=4, d_ff=512, vocab_size=512,
    head_dim=16, dtype="float32",
    elastic=ElasticSpec(depth_fracs=(0.5, 0.75, 1.0),
                        ffn_fracs=(0.5, 1.0), head_fracs=(0.5, 1.0)),
)

print(f"SuperNet: {cfg.n_layers} layers, |Phi| = {cfg.elastic.num_subnets} subnets")
params = lm.init_model(jax.random.PRNGKey(0), cfg)
n_params = sum(p.size for p in jax.tree.leaves(params))
print(f"resident weights: {n_params/1e6:.1f}M params (shared by ALL subnets)\n")

# --- the paper's NAS step: Phi -> Phi_pareto ---------------------------
pts = pareto_subnets(cfg)
print(f"Pareto frontier: {len(pts)} subnets")
for p in pts:
    print(f"  acc~{p.acc:.2f}%  {p.gflops*1e3:.1f} MFLOPs/tok  "
          f"D={p.sub.depth_frac:.2f} E={p.sub.ffn_frac:.2f} W={p.sub.head_frac:.2f}")

# --- SubNetAct: actuation is a control tuple, not a model load ---------
ctrls = [sn.make_control(cfg, p.sub) for p in pts]
stacked = {k: jnp.stack([jnp.asarray(c[k]) for c in ctrls]) for k in ctrls[0]}
toks = jnp.ones((4, 32), jnp.int32)


@jax.jit
def actuated_prefill(subnet_idx):
    ctrl = {k: v[subnet_idx] for k, v in stacked.items()}
    return lm.prefill(params, cfg, {"tokens": toks}, ctrl)


print("\ncompiling once...")
jax.block_until_ready(actuated_prefill(jnp.int32(0)))

print("actuating every pareto subnet through ONE compiled executable:")
for i in range(len(pts)):
    t0 = time.perf_counter()
    out = jax.block_until_ready(actuated_prefill(jnp.int32(i)))
    dt = (time.perf_counter() - t0) * 1e3
    print(f"  subnet {i} (acc~{pts[i].acc:.2f}%): step {dt:6.2f} ms "
          f"logits {tuple(out.shape)}")
print("\nno weight movement, no recompilation — that is SubNetAct.")
