"""End-to-end serving driver: a REAL JAX supernet behind the asyncio
router, SlackFit scheduling a bursty open-loop workload, with a
mid-run worker failure.

    PYTHONPATH=src python examples/serve_bursty.py [--queries 400]

This is the paper's Fig 7 architecture live: client -> EDF queue ->
SlackFit -> worker actuates the chosen subnet in place -> predictions
stream back; metrics printed at the end.
"""
import argparse
import asyncio
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig, ElasticSpec, Stage
from repro.core import subnet as sn
from repro.core.pareto import pareto_subnets
from repro.models import lm
from repro.serving import policies, profiler, runtime, traces


def build_supernet():
    cfg = ArchConfig(
        name="served-supernet", family="dense",
        stages=(Stage(("attn", "mlp"), repeat=4),),
        d_model=128, n_heads=8, n_kv_heads=4, d_ff=512, vocab_size=512,
        head_dim=16, dtype="float32",
        elastic=ElasticSpec(depth_fracs=(0.5, 0.75, 1.0), ffn_fracs=(0.5, 1.0)),
    )
    params = lm.init_model(jax.random.PRNGKey(0), cfg)
    pts = pareto_subnets(cfg)
    ctrls = [sn.make_control(cfg, p.sub) for p in pts]
    stacked = {k: jnp.stack([jnp.asarray(c[k]) for c in ctrls])
               for k in ctrls[0]}

    @jax.jit
    def _step(tokens, idx):
        ctrl = {k: v[idx] for k, v in stacked.items()}
        return lm.prefill(params, cfg, {"tokens": tokens}, ctrl)

    def step_fn(subnet_idx, batch):
        return np.asarray(_step(batch, jnp.int32(subnet_idx)))[:, 0]

    def pad(payloads):
        """Pad to the next profiled batch size: the executable is
        compiled per (batch-bucket, ONE control tuple) — an arbitrary
        batch size would put XLA compilation on the critical path."""
        n = len(payloads)
        target = next(b for b in (1, 2, 4, 8, 16) if b >= n) if n <= 16 else n
        x = jnp.stack([jnp.asarray(p) for p in payloads])
        if target > n:
            x = jnp.concatenate([x, jnp.zeros((target - n,) + x.shape[1:],
                                              x.dtype)])
        return x

    return cfg, pts, step_fn, pad


async def main(n_queries: int):
    cfg, pts, step_fn, pad = build_supernet()
    print(f"supernet ready: {len(pts)} pareto subnets "
          f"(acc {pts[0].acc:.2f}-{pts[-1].acc:.2f})")

    # profile on THIS host (the paper's offline Supernet Profiler)
    fns = [(lambda b, i=i: step_fn(i, jnp.ones((b, 16), jnp.int32)))
           for i in range(len(pts))]
    prof = profiler.measure_profile(fns, [p.acc for p in pts],
                                    batches=(1, 2, 4, 8, 16), n_buckets=10)
    print("profiled l_phi(B) [ms]:")
    for i in range(prof.n_pareto):
        print(f"  acc {prof.accs[i]:.2f}: " +
              " ".join(f"{x*1e3:5.1f}" for x in prof.lat[i]))

    # NOTE: this demo host is a single CPU — more than 2 worker
    # threads would contend on the GIL and distort latencies
    workers = runtime.make_supernet_workers(2, step_fn, pad)
    router = runtime.Router(prof, policies.SlackFit(), workers)
    await router.start()

    # open-loop bursty arrivals; SLO sized for host jitter (~25x the
    # B=1 max-subnet latency — the paper's 36ms SLO plays the same role
    # relative to its 2080Ti latencies)
    slo = float(prof.lat[-1, 0] * 25)
    rate = 0.25 / float(prof.lat[0, 0])         # headroom for host jitter
    arr = traces.bursty_trace(rate * 0.3, rate * 0.7, 4.0,
                              duration=n_queries / rate, seed=0)
    print(f"\nserving {len(arr)} queries at ~{rate:.0f} q/s, "
          f"SLO {slo*1e3:.0f} ms, 2 workers")
    t0 = time.perf_counter()
    futs = []
    killed = False
    for i, t in enumerate(arr):
        now = time.perf_counter() - t0
        if t > now:
            await asyncio.sleep(t - now)
        futs.append(await router.submit(
            np.full((16,), i % cfg.vocab_size, np.int32), slo_s=slo))
        if not killed and i > len(arr) // 2:
            print("  !! killing worker 0 mid-run (fault tolerance)")
            router.kill_worker(0)
            killed = True
    await asyncio.gather(*futs)
    await router.drain()
    s = router.stats()
    print(f"\nSLO attainment: {s['slo_attainment']:.4f}   "
          f"mean serving accuracy: {s['mean_acc']:.2f}%   "
          f"served: {s['served']:.0f}")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--queries", type=int, default=400)
    args = ap.parse_args()
    asyncio.run(main(args.queries))
