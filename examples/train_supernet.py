"""Train a weight-shared supernet end-to-end (sandwich rule), then
verify every pareto subnet of the trained weights is servable.

    PYTHONPATH=src python examples/train_supernet.py [--steps 300]

~20M-param dense supernet on the synthetic modular-LM task; prints the
loss curve, checkpoints atomically, and evaluates per-subnet perplexity
at the end (the latency-accuracy menu the serving stack schedules).
"""
import argparse
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig, ElasticSpec, Stage
from repro.core import subnet as sn
from repro.core.pareto import pareto_subnets
from repro.models import lm
from repro.training import data, optimizer as opt
from repro.training.trainer import Trainer, TrainerConfig

CFG = ArchConfig(
    name="train-supernet", family="dense",
    stages=(Stage(("attn", "mlp"), repeat=6),),
    d_model=256, n_heads=8, n_kv_heads=4, d_ff=1024, vocab_size=1024,
    head_dim=32, dtype="float32",
    elastic=ElasticSpec(depth_fracs=(0.5, 1.0), ffn_fracs=(0.5, 1.0)),
)


def main(steps: int):
    task = data.SyntheticTask(vocab_size=CFG.vocab_size, seq_len=64,
                              global_batch=16, seed=0, order=1, noise=0.01)
    n_params = sum(p.size for p in jax.tree.leaves(
        jax.eval_shape(lambda: lm.init_model(jax.random.PRNGKey(0), CFG))))
    print(f"supernet: {n_params/1e6:.1f}M params, "
          f"{CFG.elastic.num_subnets} subnets, {steps} steps")

    with tempfile.TemporaryDirectory() as ckdir:
        tcfg = TrainerConfig(total_steps=steps, ckpt_every=max(steps // 4, 1),
                             ckpt_dir=ckdir)
        ocfg = opt.AdamWConfig(lr=3e-3, warmup_steps=20, total_steps=steps)
        tr = Trainer(CFG, ocfg, tcfg, task, n_random=1)
        st = tr.resume_or_init(jax.random.PRNGKey(0))
        st = tr.run(st)
        print(f"loss: {st.losses[0]:.3f} -> {st.losses[-1]:.3f}  "
              f"(stragglers flagged: {len(st.straggler_steps)})")

        # per-subnet eval: the trained latency-accuracy menu
        print("\nper-subnet eval loss (sandwich training serves them all):")
        batch = {k: jnp.asarray(v) for k, v in task.batch(10_000).items()}
        for p in pareto_subnets(CFG):
            ctrl = sn.make_control(CFG, p.sub)
            loss = float(lm.loss_fn(st.params, CFG, batch, ctrl))
            print(f"  D={p.sub.depth_frac:.2f} E={p.sub.ffn_frac:.2f} "
                  f"({p.gflops*1e3:.1f} MFLOPs/tok): eval loss {loss:.3f}")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    main(ap.parse_args().steps)
