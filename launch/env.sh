# Tuned runtime preset for serving/benchmark runs.
#
#     source launch/env.sh
#     python -m benchmarks.run --only hotpath --emit-bench-json
#
# Safe to source anywhere: every knob is guarded (tcmalloc only when
# the library exists, user-set values win) so the preset degrades to a
# no-op on minimal containers rather than breaking the interpreter.

# bash/zsh know the sourced-file path; plain sh does not — there,
# fall back to $PWD (i.e. source from the repo root)
_REPRO_ROOT="$(cd "$(dirname "${BASH_SOURCE:-$0}")/.." 2>/dev/null && pwd)"
[ -d "${_REPRO_ROOT}/src/repro" ] || _REPRO_ROOT="$(pwd)"
case ":${PYTHONPATH:-}:" in
  *":${_REPRO_ROOT}/src:"*) ;;
  *) export PYTHONPATH="${_REPRO_ROOT}/src${PYTHONPATH:+:$PYTHONPATH}" ;;
esac

# tcmalloc: long-lived serving processes fragment glibc malloc under
# the engine's churn of small batch buffers; tcmalloc holds steady.
# The report threshold silences "large alloc" spam for model weights.
for _lib in /usr/lib/x86_64-linux-gnu/libtcmalloc.so.4 \
            /usr/lib/libtcmalloc.so.4 /usr/lib64/libtcmalloc.so.4; do
  if [ -e "${_lib}" ] && [ -z "${LD_PRELOAD:-}" ]; then
    export LD_PRELOAD="${_lib}"
    export TCMALLOC_LARGE_ALLOC_REPORT_THRESHOLD=60000000000
    break
  fi
done
unset _lib

# quiet the TF/XLA C++ banner noise that otherwise floods bench logs
export TF_CPP_MIN_LOG_LEVEL="${TF_CPP_MIN_LOG_LEVEL:-4}"

# step markers bound each engine dispatch in profiler traces, so
# per-query overhead in bench_hotpath attributes to the right step.
# TPU hosts only: the CPU/GPU XLA flag parser hard-aborts on unknown
# flags, so this must never leak onto a non-TPU machine.
if [ -e /dev/accel0 ] || [ -n "${TPU_NAME:-}" ]; then
  case " ${XLA_FLAGS:-} " in
    *xla_step_marker_location*) ;;
    *) export XLA_FLAGS="--xla_step_marker_location=1${XLA_FLAGS:+ $XLA_FLAGS}" ;;
  esac
fi

# dtype pinning: the kernels accumulate in f32 by construction; x64
# mode would silently double every buffer and halve throughput
export JAX_ENABLE_X64="${JAX_ENABLE_X64:-0}"
export JAX_DEFAULT_DTYPE_BITS="${JAX_DEFAULT_DTYPE_BITS:-32}"

# kernel tier: leave REPRO_KERNEL_TIER unset to probe
# (tpu -> pallas-triton -> interpret -> ref); export it to pin a tier.
unset _REPRO_ROOT
