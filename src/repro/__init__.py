"""SuperServe in JAX: SubNetAct (instant in-place subnet actuation in
weight-shared SuperNets) + SlackFit (fine-grained reactive scheduling),
built as a multi-pod TPU framework. See README.md / DESIGN.md."""

__version__ = "1.0.0"
