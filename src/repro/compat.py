"""Version-adaptive JAX/Pallas compatibility shim.

Every JAX API whose surface has moved across the versions this repo
supports (0.4.3x .. 0.5+) is feature-probed here ONCE, at import, and
exposed behind a stable name. Nothing outside this module may touch
``pltpu.TPUCompilerParams`` / ``pltpu.CompilerParams``,
``jax.sharding.AxisType``, or the ``AbstractMesh`` constructor
directly — the probe results below are the single source of truth.

Probed surfaces
---------------
* Pallas TPU compiler params:  ``TPUCompilerParams`` (<= 0.4.x) vs
  ``CompilerParams`` (newer releases renamed it).
* ``jax.sharding.AbstractMesh``: pair signature
  ``AbstractMesh(((name, size), ...))`` (0.4.37) vs the split
  ``AbstractMesh(shape, axes)`` form of newer releases.
* ``jax.make_mesh``: the ``axis_types=`` kwarg and the
  ``jax.sharding.AxisType`` enum only exist on newer releases.
* Backend capability: whether a TPU backend is attached, and whether
  Pallas interpret mode actually executes on this host (probed by
  running a one-element kernel, not by guessing from the version).
* Compiled-path probes (serving/executor.py): a process-wide XLA
  compile counter riding ``jax.monitoring`` backend-compile events
  (:func:`compile_events` / :class:`CompileCounter` — the proof that
  SubNetAct actuation never recompiles), AOT compilation through the
  ``jit(...).lower(...).compile()`` stages API (:func:`aot_compile`,
  falling back to ``None`` so callers warm eagerly), and whether
  buffer donation is actually honored on this backend
  (:func:`donation_works` — a real donated round trip, not a platform
  guess).

Kernel dispatch tiers
---------------------
The Pallas kernels run through a four-tier fallback chain, resolved
once per process (see :mod:`repro.kernels.dispatch`):

    ``tpu``           — compiled Pallas kernels on a real TPU backend
    ``pallas-triton`` — backend-agnostic Pallas kernels compiled via
                        the Triton lowering on a GPU backend
    ``interpret``     — the TPU kernels under the Pallas interpreter
                        (CPU CI: validates kernel numerics without a TPU)
    ``ref``           — the pure-jnp oracles in :mod:`repro.kernels.ref`

Override with ``REPRO_KERNEL_TIER=tpu|pallas-triton|interpret|ref`` or
:func:`set_kernel_tier`.
"""
from __future__ import annotations

import os
from typing import Optional, Sequence, Tuple

import jax

__all__ = [
    "JAX_VERSION",
    "HAS_PALLAS",
    "HAS_PALLAS_TPU",
    "HAS_PALLAS_TRITON",
    "KERNEL_TIERS",
    "backend",
    "is_tpu_backend",
    "is_gpu_backend",
    "triton_compiler_params_kwargs",
    "tpu_compiler_params",
    "compiler_params_kwargs",
    "make_abstract_mesh",
    "make_mesh",
    "cost_analysis",
    "compile_events",
    "CompileCounter",
    "aot_compile",
    "donation_works",
    "pallas_interpret_works",
    "cpu_subprocess_env",
    "host_devices_env",
    "tier_available",
    "kernel_tier",
    "explicit_kernel_tier",
    "set_kernel_tier",
    "reset_kernel_tier",
]


def _version_tuple(v: str) -> Tuple[int, ...]:
    parts = []
    for p in v.split(".")[:3]:
        digits = "".join(ch for ch in p if ch.isdigit())
        parts.append(int(digits) if digits else 0)
    return tuple(parts)


JAX_VERSION: Tuple[int, ...] = _version_tuple(jax.__version__)


# --------------------------------------------------------------------------
# Pallas import probes
# --------------------------------------------------------------------------

try:
    from jax.experimental import pallas as _pl  # noqa: F401
    HAS_PALLAS = True
except Exception:  # pragma: no cover - pallas always present in-tree
    _pl = None
    HAS_PALLAS = False

try:
    from jax.experimental.pallas import tpu as _pltpu
    HAS_PALLAS_TPU = True
except Exception:  # pragma: no cover
    _pltpu = None
    HAS_PALLAS_TPU = False

try:
    from jax.experimental.pallas import triton as _pltriton
    HAS_PALLAS_TRITON = True
except Exception:  # pragma: no cover - absent on some builds
    _pltriton = None
    HAS_PALLAS_TRITON = False

# The compiler-params dataclass was renamed TPUCompilerParams ->
# CompilerParams across Pallas releases; accept either.
_COMPILER_PARAMS_CLS = None
if HAS_PALLAS_TPU:
    for _name in ("TPUCompilerParams", "CompilerParams"):
        _COMPILER_PARAMS_CLS = getattr(_pltpu, _name, None)
        if _COMPILER_PARAMS_CLS is not None:
            break


def tpu_compiler_params(**kwargs):
    """Instance of whichever Pallas-TPU compiler-params class exists.

    Returns None when no class is available (or none of the requested
    fields are supported) — callers splat :func:`compiler_params_kwargs`
    into ``pl.pallas_call`` so the argument vanishes entirely in that
    case.
    """
    if _COMPILER_PARAMS_CLS is None:
        return None
    fields = getattr(_COMPILER_PARAMS_CLS, "__dataclass_fields__", None)
    if fields is not None:
        kwargs = {k: v for k, v in kwargs.items() if k in fields}
        if not kwargs:
            return None
    try:
        return _COMPILER_PARAMS_CLS(**kwargs)
    except TypeError:
        return None


def compiler_params_kwargs(**kwargs) -> dict:
    """``{"compiler_params": ...}`` for pallas_call, or ``{}``."""
    params = tpu_compiler_params(**kwargs)
    return {"compiler_params": params} if params is not None else {}


def triton_compiler_params_kwargs(**kwargs) -> dict:
    """``{"compiler_params": TritonCompilerParams(...)}`` or ``{}``.

    Unknown fields are dropped (the dataclass gained/lost fields across
    releases); with no Triton module or no surviving fields the kwarg
    vanishes entirely, which is also the right thing under interpret
    mode where compiler params are ignored anyway.
    """
    if _pltriton is None:
        return {}
    cls = getattr(_pltriton, "TritonCompilerParams", None) or \
        getattr(_pltriton, "CompilerParams", None)
    if cls is None:
        return {}
    fields = getattr(cls, "__dataclass_fields__", None)
    if fields is not None:
        kwargs = {k: v for k, v in kwargs.items() if k in fields}
        if not kwargs:
            return {}
    try:
        return {"compiler_params": cls(**kwargs)}
    except TypeError:
        return {}


# --------------------------------------------------------------------------
# Mesh construction
# --------------------------------------------------------------------------


def make_abstract_mesh(shape: Sequence[int], axes: Sequence[str]):
    """``jax.sharding.AbstractMesh`` across both constructor signatures.

    jax 0.4.37 takes one ``((name, size), ...)`` pair tuple; newer
    releases take ``(axis_sizes, axis_names)`` split positionally.
    """
    from jax.sharding import AbstractMesh
    pairs = tuple(zip(tuple(axes), tuple(shape)))
    try:
        return AbstractMesh(pairs)
    except (TypeError, ValueError):
        return AbstractMesh(tuple(shape), tuple(axes))


def make_mesh(shape: Sequence[int], axes: Sequence[str], *, devices=None):
    """``jax.make_mesh`` with auto axis types where the API supports it.

    ``axis_types=`` (and ``jax.sharding.AxisType``) only exist on newer
    releases; on 0.4.37 the plain call already yields Auto axes.
    """
    shape, axes = tuple(shape), tuple(axes)
    kwargs = {} if devices is None else {"devices": devices}
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        try:
            return jax.make_mesh(
                shape, axes,
                axis_types=(axis_type.Auto,) * len(axes), **kwargs)
        except TypeError:
            pass
    return jax.make_mesh(shape, axes, **kwargs)


def cost_analysis(compiled) -> dict:
    """Flat dict from ``compiled.cost_analysis()`` across versions.

    jax 0.4.3x returns a one-element list of dicts (per executable);
    newer releases return the dict directly; either may be empty/None.
    """
    ca = compiled.cost_analysis()
    if ca is None:
        return {}
    if isinstance(ca, (list, tuple)):
        return dict(ca[0]) if ca else {}
    return dict(ca)


# --------------------------------------------------------------------------
# Compiled-path probes: compile counting, AOT compilation, donation
# --------------------------------------------------------------------------

_compile_events = 0
_compile_listener_ok: Optional[bool] = None


def _note_compile_event(*args, **kwargs) -> None:
    """jax.monitoring duration listener. The signature has grown extra
    kwargs across releases, so accept anything and read the event name
    positionally; only backend (XLA) compilations are counted — jaxpr
    tracing and MLIR lowering re-run cheaply on cache hits too."""
    global _compile_events
    event = args[0] if args else kwargs.get("event", "")
    if isinstance(event, str) and "backend_compile" in event:
        _compile_events += 1


def _install_compile_listener() -> bool:
    global _compile_listener_ok
    if _compile_listener_ok is None:
        try:
            from jax import monitoring
            monitoring.register_event_duration_secs_listener(
                _note_compile_event)
            _compile_listener_ok = True
        except Exception:
            _compile_listener_ok = False
    return _compile_listener_ok


def compile_events() -> Optional[int]:
    """Monotone count of XLA backend compilations in this process, or
    ``None`` when the ``jax.monitoring`` surface is unavailable.

    This is the SubNetAct enforcement probe: serving code asserts the
    count does NOT move across subnet actuations (control tuples are
    traced data, never part of the jit cache key)."""
    return _compile_events if _install_compile_listener() else None


class CompileCounter:
    """``with CompileCounter() as cc: ...; cc.count`` — XLA backend
    compilations during the block. ``cc.available`` is False (and
    ``count`` 0) when the monitoring probe is missing; callers gating
    hard guarantees should skip rather than trust a blind counter."""

    def __init__(self):
        self.available = _install_compile_listener()
        self._start = 0
        self.count = 0

    def __enter__(self) -> "CompileCounter":
        self._start = _compile_events
        return self

    def __exit__(self, *exc) -> None:
        if self.available:
            self.count = _compile_events - self._start


def aot_compile(jitted, *args, **kwargs):
    """``jitted.lower(*args, **kwargs).compile()`` behind a probe.

    Returns the compiled executable — ready to call with concrete
    arrays matching the lowered shapes — or ``None`` when the AOT
    stages API is missing or lowering fails on this release; callers
    fall back to eager first-call warmup."""
    lower = getattr(jitted, "lower", None)
    if lower is None:
        return None
    try:
        return lower(*args, **kwargs).compile()
    except Exception:
        return None


_donation_probe: Optional[bool] = None


def donation_works() -> bool:
    """Probe (once) whether buffer donation is honored on this backend.

    An actual donated round trip checking the input buffer was
    consumed — not a platform guess: CPU donation flipped from ignored
    (with a warning) to honored across jaxlib releases, and the only
    trustworthy signal is the input array turning deleted."""
    global _donation_probe
    if _donation_probe is not None:
        return _donation_probe
    try:
        import jax.numpy as jnp
        f = jax.jit(lambda x: x + 1, donate_argnums=(0,))
        x = jnp.ones((8,), jnp.float32)
        jax.block_until_ready(f(x))
        deleted = getattr(x, "is_deleted", None)
        _donation_probe = bool(deleted()) if callable(deleted) else False
    except Exception:
        _donation_probe = False
    return _donation_probe


# --------------------------------------------------------------------------
# Backend capability + kernel tier resolution
# --------------------------------------------------------------------------

KERNEL_TIERS = ("tpu", "pallas-triton", "interpret", "ref")
_TIER_ENV = "REPRO_KERNEL_TIER"
_tier_cache: Optional[str] = None
_explicit_tier: Optional[str] = None
_interpret_probe: Optional[bool] = None


def backend() -> str:
    return jax.default_backend()


def cpu_subprocess_env(**extra) -> dict:
    """Minimal env for spawning a CPU-pinned python subprocess.

    Tests that force ``--xla_force_host_platform_device_count`` are
    CPU-only by construction; without ``JAX_PLATFORMS=cpu`` a host with
    a TPU wheel installed (but no TPU attached) stalls for minutes in
    libtpu's GCP-metadata retry loop before falling back.
    """
    env = {
        "PYTHONPATH": "src",
        "PATH": os.environ.get("PATH", "/usr/bin:/bin"),
        "HOME": os.environ.get("HOME", "/root"),
        "JAX_PLATFORMS": "cpu",
    }
    env.update(extra)
    return env


def host_devices_env(n: int, **extra) -> dict:
    """``cpu_subprocess_env`` plus fake-device pinning: with ``n > 0``
    the child sees ``XLA_FLAGS=--xla_force_host_platform_device_count=n``
    (appended to any inherited XLA_FLAGS), so its *first* jax import
    gets an n-device CPU host — the HomebrewNLP-Jax/olmax idiom that
    lets sharded multi-process tests run on CPU CI without TPUs. Used
    by serving/ipc.py to spawn replica worker processes."""
    env = cpu_subprocess_env(**extra)
    if n and int(n) > 0:
        flags = env.get("XLA_FLAGS", os.environ.get("XLA_FLAGS", ""))
        pin = f"--xla_force_host_platform_device_count={int(n)}"
        env["XLA_FLAGS"] = f"{flags} {pin}".strip()
    return env


def is_tpu_backend() -> bool:
    return backend() == "tpu"


def is_gpu_backend() -> bool:
    # jax.default_backend() says "gpu" on most releases but the platform
    # name underneath is cuda/rocm; accept any of them.
    return backend() in ("gpu", "cuda", "rocm")


def pallas_interpret_works() -> bool:
    """Probe (once) whether Pallas interpret mode runs on this host.

    An actual one-element kernel execution, not a version check: the
    interpreter's own API surface has shifted between releases, and the
    only trustworthy signal is a successful round trip.
    """
    global _interpret_probe
    if _interpret_probe is not None:
        return _interpret_probe
    if not HAS_PALLAS:
        _interpret_probe = False
        return False
    try:
        import jax.numpy as jnp
        from jax.experimental import pallas as pl

        def _copy(x_ref, o_ref):
            o_ref[...] = x_ref[...]

        # The first resolution may happen while tracing a model step;
        # the probe must execute eagerly regardless, or the bool()
        # below sees a tracer and misreports the tier as unavailable.
        with jax.ensure_compile_time_eval():
            x = jnp.ones((8, 128), jnp.float32)
            y = pl.pallas_call(
                _copy, out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
                interpret=True)(x)
            _interpret_probe = bool((y == x).all())
    except Exception:
        _interpret_probe = False
    return _interpret_probe


def tier_available(tier: str) -> bool:
    """Whether a dispatch tier can actually execute on this host."""
    if tier == "tpu":
        return HAS_PALLAS_TPU and is_tpu_backend()
    if tier == "pallas-triton":
        return HAS_PALLAS_TRITON and is_gpu_backend()
    if tier == "interpret":
        # the interpret-tier kernels use pltpu grid specs, so the plain
        # pallas probe alone is not sufficient
        return HAS_PALLAS_TPU and pallas_interpret_works()
    return tier == "ref"


def _env_tier() -> Optional[str]:
    env = os.environ.get(_TIER_ENV, "").strip().lower()
    if not env:
        return None
    if env not in KERNEL_TIERS:
        raise ValueError(
            f"{_TIER_ENV}={env!r}: expected one of {KERNEL_TIERS}")
    if not tier_available(env):
        raise RuntimeError(
            f"{_TIER_ENV}={env!r} requested but that tier is not "
            f"available on this host (backend={backend()!r})")
    return env


def _resolve_tier() -> str:
    env = _env_tier()
    if env is not None:
        return env
    for tier in KERNEL_TIERS:
        if tier_available(tier):
            return tier
    return "ref"


def kernel_tier() -> str:
    """The process-wide kernel dispatch tier, resolved once."""
    global _tier_cache
    if _tier_cache is None:
        _tier_cache = _resolve_tier()
    return _tier_cache


def explicit_kernel_tier() -> Optional[str]:
    """The tier the operator *asked* for (env var or set_kernel_tier),
    or None when the process tier is purely probed. Model hot paths use
    this to honor a forced tier while defaulting interpret-capable CPU
    hosts to the fast pure-JAX path."""
    if _explicit_tier is not None:
        return _explicit_tier
    return _env_tier()


def set_kernel_tier(tier: str) -> str:
    """Config override of the process tier (validated). Returns it."""
    global _tier_cache, _explicit_tier
    if tier not in KERNEL_TIERS:
        raise ValueError(f"unknown kernel tier {tier!r}; "
                         f"expected one of {KERNEL_TIERS}")
    if not tier_available(tier):
        raise RuntimeError(f"kernel tier {tier!r} unavailable on this host "
                           f"(backend={backend()!r})")
    _tier_cache = _explicit_tier = tier
    return tier


def reset_kernel_tier() -> None:
    """Drop the cached/explicit tier (re-resolves on next use)."""
    global _tier_cache, _explicit_tier
    _tier_cache = _explicit_tier = None
