"""Config registry: ``get_config(name)`` / ``list_configs()``.

One module per assigned architecture (exact public-literature dims)
plus the paper's own OFA-ResNet conv supernet.
"""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.configs.base import SHAPES, ArchConfig, ShapeSpec, shape_applicable

_ARCH_MODULES = (
    "zamba2_2p7b",
    "qwen2_vl_7b",
    "mixtral_8x7b",
    "llama4_maverick_400b_a17b",
    "qwen2p5_14b",
    "qwen2_1p5b",
    "h2o_danube_3_4b",
    "stablelm_3b",
    "xlstm_125m",
    "musicgen_medium",
    "ofa_resnet",
)

_REGISTRY: Dict[str, ArchConfig] = {}


def _load() -> None:
    if _REGISTRY:
        return
    for mod_name in _ARCH_MODULES:
        mod = importlib.import_module(f"repro.configs.{mod_name}")
        cfg: ArchConfig = mod.CONFIG
        _REGISTRY[cfg.name] = cfg


def get_config(name: str) -> ArchConfig:
    _load()
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def list_configs() -> List[str]:
    _load()
    return sorted(_REGISTRY)


def assigned_archs() -> List[str]:
    """The 10 graded LM-family architectures (excludes the paper's own)."""
    _load()
    return [n for n in sorted(_REGISTRY) if n != "ofa_resnet"]


__all__ = [
    "ArchConfig", "ShapeSpec", "SHAPES", "shape_applicable",
    "get_config", "list_configs", "assigned_archs",
]
