"""Architecture + shape configuration system.

Every assigned architecture is expressed as an :class:`ArchConfig` made of
repeated *stages* (scan-over-layers friendly), an :class:`ElasticSpec`
describing the SubNetAct control space, and a set of named input shapes.

The FULL configs are only ever lowered with ShapeDtypeStructs (dry-run);
smoke tests instantiate ``reduced()`` variants.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any, Dict, Optional, Tuple

# --------------------------------------------------------------------------
# Elasticity (SubNetAct control space)
# --------------------------------------------------------------------------


@dataclass(frozen=True)
class ElasticSpec:
    """Discrete SubNetAct control space for one architecture.

    ``depth_fracs``  - fraction of repeated units executed (LayerSelect).
    ``ffn_fracs``    - fraction of d_ff channels active (WeightSlice).
    ``head_fracs``   - fraction of *query* head groups active (WeightSlice).
                       KV heads stay fixed (stable cache layout).
    ``topk_options`` - MoE top-k choices (MoE translation of width).
    """

    depth_fracs: Tuple[float, ...] = (1.0,)
    ffn_fracs: Tuple[float, ...] = (1.0,)
    head_fracs: Tuple[float, ...] = (1.0,)
    topk_options: Tuple[int, ...] = ()

    @property
    def num_subnets(self) -> int:
        n = len(self.depth_fracs) * len(self.ffn_fracs) * len(self.head_fracs)
        if self.topk_options:
            n *= len(self.topk_options)
        return n


# --------------------------------------------------------------------------
# Stages (block pattern engine)
# --------------------------------------------------------------------------

# Block kinds understood by models/backbone.py ("conv" is handled by
# models/convnet.py — the paper's own OFA-ResNet supernet, not an LM).
BLOCK_KINDS = (
    "attn",       # self attention (GQA/MHA, RoPE/M-RoPE, optional SWA)
    "mlp",        # dense SwiGLU/GELU FFN (elastic width)
    "moe",        # top-k routed experts (+ optional shared expert)
    "mamba",      # Mamba2 SSD block
    "mlstm",      # xLSTM matrix-memory block
    "slstm",      # xLSTM scalar-memory block
    "conv",       # residual conv block (OFA-ResNet; models/convnet.py)
)


@dataclass(frozen=True)
class Stage:
    """``repeat`` copies of a unit made of ``pattern`` sub-blocks.

    Parameters for each sub-block slot are stacked along a leading
    ``repeat`` axis so the backbone can ``lax.scan`` over them: compile
    time is O(1) in depth.
    """

    pattern: Tuple[str, ...]
    repeat: int

    def __post_init__(self):
        for kind in self.pattern:
            if kind not in BLOCK_KINDS:
                raise ValueError(f"unknown block kind {kind!r}")

    @property
    def layers_per_unit(self) -> int:
        # A "layer" = one attention-ish or mixer-ish sub-block.
        return len(self.pattern)


# --------------------------------------------------------------------------
# Architecture config
# --------------------------------------------------------------------------


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                      # dense|moe|ssm|hybrid|vlm|audio|conv
    stages: Tuple[Stage, ...]
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                # 0 -> d_model // n_heads

    # --- attention extras ---
    qkv_bias: bool = False
    sliding_window: int = 0          # 0 = full attention
    rope_theta: float = 10_000.0
    rotary_pct: float = 1.0          # stablelm uses partial rotary
    mrope_sections: Tuple[int, ...] = ()   # qwen2-vl M-RoPE

    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0                # 0 -> d_ff
    shared_expert: bool = False
    capacity_factor: float = 1.25

    # --- SSM (mamba2) ---
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_conv_width: int = 4
    ssm_chunk: int = 256

    # --- zamba2-style shared attention ---
    shared_attn_period: int = 0      # every k-th mamba unit also runs the
                                     # (weight-shared) attention block

    # --- xLSTM ---
    mlstm_proj_factor: float = 2.0
    slstm_proj_factor: float = 4.0 / 3.0

    # --- norm ---
    norm: str = "rmsnorm"            # rmsnorm|layernorm
    norm_eps: float = 1e-5

    # --- FFN / positions (musicgen uses GELU + sinusoidal) ---
    ffn_act: str = "swiglu"          # swiglu|gelu
    pos_embed: str = "rope"          # rope|sinusoidal

    # --- IO / modality ---
    frontend: str = "token"          # token | embed (precomputed embeddings)
    tie_embeddings: bool = False

    # --- SubNetAct ---
    elastic: ElasticSpec = field(default_factory=ElasticSpec)

    # --- sub-quadratic? (controls long_500k applicability) ---
    subquadratic: bool = False

    # --- conv supernet (paper's own OFA-ResNet arch) ---
    conv_stage_widths: Tuple[int, ...] = ()   # base channels per stage
    img_size: int = 224
    n_classes: int = 0

    # --- misc ---
    dtype: str = "bfloat16"
    notes: str = ""

    # ---------------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def n_layers(self) -> int:
        return sum(s.repeat * s.layers_per_unit for s in self.stages)

    @property
    def resolved_moe_d_ff(self) -> int:
        return self.moe_d_ff or self.d_ff

    def replace(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, **kw)

    # ---------------------------------------------------------------
    def reduced(self) -> "ArchConfig":
        """Tiny same-family variant for CPU smoke tests."""
        stages = tuple(
            Stage(s.pattern, repeat=max(1, min(2, s.repeat))) for s in self.stages
        )
        small = dict(
            stages=stages,
            d_model=128,
            n_heads=4,
            n_kv_heads=min(4, max(1, self.n_kv_heads * 4 // max(self.n_heads, 1))),
            head_dim=32,
            d_ff=256,
            vocab_size=512,
            dtype="float32",
        )
        if self.n_experts:
            small.update(n_experts=4, top_k=min(self.top_k, 2) or 1, moe_d_ff=128)
        if self.ssm_state:
            small.update(ssm_state=16, ssm_chunk=32, ssm_head_dim=16)
        if self.shared_attn_period:
            small.update(shared_attn_period=2)
        if self.sliding_window:
            small.update(sliding_window=64)
        if self.mrope_sections:
            small.update(mrope_sections=(8, 4, 4))
        return self.replace(**small)


# --------------------------------------------------------------------------
# Input shapes (assigned per the task: 4 shapes x 10 archs = 40 cells)
# --------------------------------------------------------------------------


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str          # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524_288, 1),
}


def shape_applicable(cfg: ArchConfig, shape: ShapeSpec) -> Tuple[bool, str]:
    """Whether a dry-run cell applies (long_500k needs sub-quadratic attn)."""
    if shape.name == "long_500k" and not cfg.subquadratic:
        return False, "skip: pure full-attention arch; 512k dense decode excluded by shape spec"
    return True, ""
