"""h2o-danube-3-4b [dense] — llama+mistral mix, SWA.
[arXiv:2401.16818; unverified]

24L d_model=3840 32H (GQA kv=8) d_ff=10240 vocab=32000.
"""
from repro.configs.base import ArchConfig, ElasticSpec, Stage

CONFIG = ArchConfig(
    name="h2o-danube-3-4b",
    family="dense",
    stages=(Stage(("attn", "mlp"), repeat=24),),
    d_model=3840,
    n_heads=32,
    n_kv_heads=8,
    d_ff=10240,
    vocab_size=32_000,
    head_dim=120,                     # 3840 / 32
    sliding_window=4096,
    rope_theta=10_000.0,
    subquadratic=True,                # SWA ⇒ bounded KV cache ⇒ long_500k runs
    elastic=ElasticSpec(
        depth_fracs=(0.5, 0.75, 1.0),
        ffn_fracs=(0.5, 0.75, 1.0),
        head_fracs=(0.5, 1.0),
    ),
)
