"""llama4-maverick-400b-a17b [moe] — 128 experts top-1 + shared expert,
interleaved dense/MoE FFN layers, early fusion (text path modeled; the
fusion frontend is out of assigned scope).
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]

48L d_model=5120 40H (GQA kv=8) d_ff=8192 vocab=202048, MoE 128e top-1.
Each repeat unit = 2 transformer layers: (attn, moe, attn, mlp), so 24
units x 2 = 48 attention layers with FFNs alternating MoE/dense.
"""
from repro.configs.base import ArchConfig, ElasticSpec, Stage

CONFIG = ArchConfig(
    name="llama4-maverick-400b-a17b",
    family="moe",
    stages=(Stage(("attn", "moe", "attn", "mlp"), repeat=24),),
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_ff=8192,
    vocab_size=202_048,
    head_dim=128,
    rope_theta=500_000.0,
    n_experts=128,
    top_k=1,
    moe_d_ff=8192,
    shared_expert=True,
    capacity_factor=1.25,
    subquadratic=False,               # global-attn layers ⇒ long_500k skipped
    elastic=ElasticSpec(
        depth_fracs=(0.5, 0.75, 1.0),
        ffn_fracs=(0.5, 0.75, 1.0),
        head_fracs=(0.5, 1.0),
        topk_options=(1,),            # top-1 arch: k not elastic upward
    ),
)
