"""mixtral-8x7b [moe] — 8 experts top-2, SWA. [arXiv:2401.04088; hf]

32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=32000, MoE 8e top-2.
"""
from repro.configs.base import ArchConfig, ElasticSpec, Stage

CONFIG = ArchConfig(
    name="mixtral-8x7b",
    family="moe",
    stages=(Stage(("attn", "moe"), repeat=32),),
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab_size=32_000,
    head_dim=128,
    sliding_window=4096,
    rope_theta=1_000_000.0,
    n_experts=8,
    top_k=2,
    moe_d_ff=14336,
    capacity_factor=1.25,
    subquadratic=True,                # SWA ⇒ bounded KV cache ⇒ long_500k runs
    elastic=ElasticSpec(
        depth_fracs=(0.5, 0.75, 1.0),
        ffn_fracs=(0.5, 0.75, 1.0),   # per-expert d_ff
        head_fracs=(0.5, 1.0),
        topk_options=(1, 2),          # MoE translation of WeightSlice
    ),
)
