"""musicgen-medium [audio] — decoder-only over EnCodec tokens.
[arXiv:2306.05284; hf]

48L d_model=1536 24H (MHA kv=24) d_ff=6144 vocab=2048. GELU FFN,
sinusoidal positions, LayerNorm. The EnCodec frontend is a stub per the
assignment: ``input_specs()`` provides precomputed frame embeddings for
train/prefill; decode consumes codebook token ids (vocab 2048).
"""
from repro.configs.base import ArchConfig, ElasticSpec, Stage

CONFIG = ArchConfig(
    name="musicgen-medium",
    family="audio",
    stages=(Stage(("attn", "mlp"), repeat=48),),
    d_model=1536,
    n_heads=24,
    n_kv_heads=24,
    d_ff=6144,
    vocab_size=2048,
    head_dim=64,                      # 1536 / 24
    norm="layernorm",
    ffn_act="gelu",
    pos_embed="sinusoidal",
    frontend="embed",
    subquadratic=False,               # full attention ⇒ long_500k skipped
    elastic=ElasticSpec(
        depth_fracs=(0.5, 0.75, 1.0),
        ffn_fracs=(0.5, 0.75, 1.0),
        head_fracs=(0.5, 1.0),
    ),
)
