"""ofa_resnet — the paper's own serving architecture: an OFA-ResNet50
SuperNet [Cai et al., ICLR'20] with SubNetAct operators, including true
BatchNorm SubnetNorm (per-subnet mu/sigma tables).

Pareto subnets span 0.9-7.5 GFLOPs / 73-80% top-1 (paper §6.1); our
accuracy *predictor* in core/pareto.py is fit to exactly that range.
This arch is the paper-reproduction vehicle (benchmarks/), additional
to the 10 assigned LM archs.
"""
from repro.configs.base import ArchConfig, ElasticSpec, Stage

CONFIG = ArchConfig(
    name="ofa_resnet",
    family="conv",
    # 4 stages x max 4 residual conv units each (OFA-ResNet depth space
    # D in {2,3,4} per stage).
    stages=(
        Stage(("conv",), repeat=4),
        Stage(("conv",), repeat=4),
        Stage(("conv",), repeat=4),
        Stage(("conv",), repeat=4),
    ),
    d_model=2048,                     # final feature width
    n_heads=1,
    n_kv_heads=1,
    d_ff=0,
    vocab_size=0,
    conv_stage_widths=(256, 512, 1024, 2048),
    img_size=224,
    n_classes=1000,
    norm="layernorm",                 # (unused; conv path uses BatchNorm tables)
    dtype="float32",
    elastic=ElasticSpec(
        depth_fracs=(0.5, 0.75, 1.0),     # D: 2/3/4 units per stage
        ffn_fracs=(0.45, 0.7, 1.0),       # E: expand-ratio space
        head_fracs=(0.65, 0.8, 1.0),      # W: width-multiplier space
    ),
    notes="Paper's own arch. True BatchNorm SubnetNorm with calibrated "
          "per-subnet (mu, sigma) tables — see models/convnet.py + "
          "core/calibrate.py.",
)
