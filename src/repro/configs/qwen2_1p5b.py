"""qwen2-1.5b [dense] — GQA, QKV bias, tied embeddings.
[arXiv:2407.10671; hf]

28L d_model=1536 12H (GQA kv=2) d_ff=8960 vocab=151936.
"""
from repro.configs.base import ArchConfig, ElasticSpec, Stage

CONFIG = ArchConfig(
    name="qwen2-1.5b",
    family="dense",
    stages=(Stage(("attn", "mlp"), repeat=28),),
    d_model=1536,
    n_heads=12,
    n_kv_heads=2,
    d_ff=8960,
    vocab_size=151_936,
    head_dim=128,
    qkv_bias=True,
    rope_theta=1_000_000.0,
    tie_embeddings=True,
    subquadratic=False,               # full attention ⇒ long_500k skipped
    elastic=ElasticSpec(
        depth_fracs=(0.5, 0.75, 1.0),
        ffn_fracs=(0.5, 0.75, 1.0),
        head_fracs=(0.5, 1.0),        # 12H/2kv ⇒ 6-head groups
    ),
)
