"""qwen2-vl-7b [vlm] — M-RoPE, dynamic resolution. [arXiv:2409.12191; hf]

28L d_model=3584 28H (GQA kv=4) d_ff=18944 vocab=152064. The vision
frontend is a stub per the assignment: ``input_specs()`` provides
precomputed patch embeddings (frontend='embed') for train/prefill.
"""
from repro.configs.base import ArchConfig, ElasticSpec, Stage

CONFIG = ArchConfig(
    name="qwen2-vl-7b",
    family="vlm",
    stages=(Stage(("attn", "mlp"), repeat=28),),
    d_model=3584,
    n_heads=28,
    n_kv_heads=4,
    d_ff=18944,
    vocab_size=152_064,
    head_dim=128,
    qkv_bias=True,
    rope_theta=1_000_000.0,
    mrope_sections=(16, 24, 24),      # temporal/h/w over head_dim/2 = 64 slots
    frontend="embed",
    subquadratic=False,               # full attention ⇒ long_500k skipped
    elastic=ElasticSpec(
        depth_fracs=(0.5, 0.75, 1.0),
        ffn_fracs=(0.5, 0.75, 1.0),
        head_fracs=(0.5, 1.0),        # whole GQA groups (28H/4kv ⇒ 7-head groups)
    ),
)
