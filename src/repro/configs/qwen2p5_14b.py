"""qwen2.5-14b [dense] — GQA, QKV bias. [hf:Qwen/Qwen2.5-0.5B; hf]

48L d_model=5120 40H (GQA kv=8) d_ff=13824 vocab=152064.
"""
from repro.configs.base import ArchConfig, ElasticSpec, Stage

CONFIG = ArchConfig(
    name="qwen2.5-14b",
    family="dense",
    stages=(Stage(("attn", "mlp"), repeat=48),),
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_ff=13824,
    vocab_size=152_064,
    head_dim=128,
    qkv_bias=True,
    rope_theta=1_000_000.0,
    subquadratic=False,               # full attention ⇒ long_500k skipped
    elastic=ElasticSpec(
        depth_fracs=(0.5, 0.75, 1.0),
        ffn_fracs=(0.5, 0.75, 1.0),
        head_fracs=(0.5, 1.0),
    ),
)
