"""stablelm-3b [dense] — MHA (kv=32), partial rotary (25%), LayerNorm.
[hf:stabilityai/stablelm-2-1_6b; unverified]

32L d_model=2560 32H (GQA kv=32) d_ff=6912 vocab=50304.
"""
from repro.configs.base import ArchConfig, ElasticSpec, Stage

CONFIG = ArchConfig(
    name="stablelm-3b",
    family="dense",
    stages=(Stage(("attn", "mlp"), repeat=32),),
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,
    d_ff=6912,
    vocab_size=50_304,
    head_dim=80,                      # 2560 / 32
    rotary_pct=0.25,
    rope_theta=10_000.0,
    norm="layernorm",
    subquadratic=False,               # full attention ⇒ long_500k skipped
    elastic=ElasticSpec(
        depth_fracs=(0.5, 0.75, 1.0),
        ffn_fracs=(0.5, 0.75, 1.0),
        head_fracs=(0.5, 1.0),        # MHA: any head subset (group size 1)
    ),
)
