"""xlstm-125m [ssm] — sLSTM + mLSTM blocks (3:1 interleave).
[arXiv:2405.04517; unverified]

12L d_model=768 4H d_ff=0 vocab=50304. d_ff=0: xLSTM mLSTM blocks have
no separate FFN (up-projection is internal); sLSTM blocks carry a small
post-FFN (proj factor 4/3) per the paper.
"""
from repro.configs.base import ArchConfig, ElasticSpec, Stage

CONFIG = ArchConfig(
    name="xlstm-125m",
    family="ssm",
    stages=(Stage(("mlstm", "mlstm", "mlstm", "slstm"), repeat=3),),
    d_model=768,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab_size=50_304,
    head_dim=192,                     # 768 / 4
    mlstm_proj_factor=2.0,
    slstm_proj_factor=4.0 / 3.0,
    tie_embeddings=True,
    subquadratic=True,                # recurrent ⇒ long_500k runs
    elastic=ElasticSpec(
        depth_fracs=(1.0 / 3.0, 2.0 / 3.0, 1.0),
        ffn_fracs=(0.5, 1.0),         # sLSTM post-FFN width only
        head_fracs=(1.0,),            # recurrent state dims not elastic
    ),
    notes="Recurrent state dims (mLSTM C/n, sLSTM c/n/h/m) are NOT "
          "width-elastic; only depth + sLSTM FFN width are.",
)
