"""zamba2-2.7b [hybrid] — Mamba2 backbone + weight-shared attention
block applied periodically (the arch's own weight-sharing synergizes
with SubNetAct's). [arXiv:2411.15242; hf]

54L d_model=2560 32H (GQA kv=32) d_ff=10240 vocab=32000 ssm_state=64.
"""
from repro.configs.base import ArchConfig, ElasticSpec, Stage

CONFIG = ArchConfig(
    name="zamba2-2.7b",
    family="hybrid",
    stages=(Stage(("mamba",), repeat=54),),
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,
    d_ff=10240,
    vocab_size=32_000,
    head_dim=80,                      # 2560 / 32
    ssm_state=64,
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_chunk=256,
    shared_attn_period=6,             # shared attn+MLP block every 6 mamba units
    tie_embeddings=True,
    subquadratic=True,                # SSM state ⇒ long_500k eligible
    elastic=ElasticSpec(
        depth_fracs=(0.5, 0.75, 1.0),
        ffn_fracs=(0.5, 0.75, 1.0),   # shared-block MLP width; SSM dims fixed
        head_fracs=(0.5, 1.0),        # shared-block q heads
    ),
    notes="Mamba2 + zamba2-style shared transformer block. SSM state dims "
          "are not width-elastic (recurrence integrity).",
)
