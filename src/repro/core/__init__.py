"""SubNetAct core: the control space Phi, the three operators, Pareto
NAS + predictors, and SubnetNorm calibration."""
