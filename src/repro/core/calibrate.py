"""SubnetNorm calibration (paper §3, "SubnetNorm" operator).

Naive LayerSelect/WeightSlice drops subnet accuracy by up to 10% because
shared normalization statistics are wrong for every subnet but the one
they were computed on. SubnetNorm fixes this by *precomputing* per-subnet
(mu_{i,j}, sigma_{i,j}) for each subnet i and norm site j via forward
passes on calibration data — done offline by the Supernet Profiler,
never on the query critical path.

This module implements that calibration for the conv supernet's true
BatchNorm tables. RMSNorm/LayerNorm LMs are *stat-free*: their
SubnetNorm is the per-subnet gamma(/beta) tables trained jointly with
the supernet (training/supernet.py) — there are no activation statistics
to precompute, which we note in DESIGN.md §Changed-assumptions.
"""
from __future__ import annotations

from typing import Dict, Iterable, List, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.core.subnet import SubnetDescriptor, enumerate_space, stage_gates
from repro.models import convnet


def _site_tables(params) -> Dict[str, Dict]:
    """Map site key -> BN table dict inside the param tree (by reference)."""
    sites = {"stem": params["stem"]["bn"]}
    for si, units in enumerate(params["stages"]):
        for r, u in enumerate(units):
            pre = f"s{si}u{r}."
            sites[pre + "bn1"] = u["bn1"]
            sites[pre + "bn2"] = u["bn2"]
            sites[pre + "bn3"] = u["bn3"]
            if "bn_proj" in u:
                sites[pre + "bn_proj"] = u["bn_proj"]
    return sites


def calibrate_convnet(params, cfg: ArchConfig, batches: Iterable[jnp.ndarray],
                      subnets: Sequence[SubnetDescriptor] | None = None,
                      momentum: float = 0.0):
    """Fill the per-subnet BN (mean, var) table rows for every subnet.

    ``batches``: iterable of image batches (B, H, W, 3) — the paper uses
    training data. Returns the updated param tree (functionally).
    """
    subnets = list(subnets if subnets is not None else enumerate_space(cfg))
    batches = list(batches)
    if not batches:
        raise ValueError("calibration requires at least one batch")

    collect = jax.jit(
        lambda p, x, ctrl, gates: convnet.convnet_forward(
            p, cfg, x, ctrl, collect_stats=True, static_gates=gates)[1],
        static_argnames=("gates",))

    # Accumulate per-subnet running stats over the calibration set.
    new_params = params
    for sub in subnets:
        ctrl = convnet.make_conv_control(cfg, sub)
        gates = tuple(bool(g) for g in stage_gates(cfg, sub.depth_frac))
        acc: Dict[str, List] = {}
        for x in batches:
            stats = collect(params, x, ctrl, gates)
            for site, (mu, var) in stats.items():
                acc.setdefault(site, []).append((np.asarray(mu), np.asarray(var)))
        sid = int(sub.subnet_id)
        sites = _site_tables(new_params)
        for site, ms in acc.items():
            mu = np.mean([m for m, _ in ms], axis=0)
            # law of total variance across batches
            var = (np.mean([v for _, v in ms], axis=0)
                   + np.var([m for m, _ in ms], axis=0))
            t = sites[site]
            t["mean"] = t["mean"].at[sid].set(jnp.asarray(mu))
            t["var"] = t["var"].at[sid].set(jnp.asarray(var))
    return new_params


def norm_table_bytes(params) -> int:
    """Bytes of non-shared SubnetNorm bookkeeping (paper Fig. 4 numerator)."""
    total = 0
    for t in _site_tables(params).values():
        total += t["mean"].size * t["mean"].dtype.itemsize
        total += t["var"].size * t["var"].dtype.itemsize
    return total


def shared_weight_bytes(params) -> int:
    """Bytes of shared (non-norm-table) weights (paper Fig. 4 denominator)."""
    site_ids = {id(t["mean"]) for t in _site_tables(params).values()}
    site_ids |= {id(t["var"]) for t in _site_tables(params).values()}
    total = 0
    for leaf in jax.tree_util.tree_leaves(params):
        if id(leaf) not in site_ids:
            total += leaf.size * leaf.dtype.itemsize
    return total
