"""SubNetAct's three operators, as TPU-native JAX primitives.

* :func:`layer_select`  — control-flow gate around a block (paper's
  LayerSelect). ``lax.cond`` on a traced boolean: one executable serves
  every depth; a skipped layer costs a predicate, not FLOPs.
* :func:`subnet_norm`   — normalization with *per-subnet* non-shared
  parameters gathered by ``subnet_id`` (paper's SubnetNorm). For the
  RMSNorm LMs these are per-subnet gain tables; for the conv supernet
  (paper's own arch) true BatchNorm mu/sigma tables.
* :func:`sliced_matmul` / :func:`slice_mask` — WeightSlice. Two modes:
  ``mask``   : full-shape matmul with channel masks (paper-faithful
               routing semantics; zero shape dynamism),
  ``switch`` : ``lax.switch`` over the discrete OFA width options, each
               branch a statically-shaped prefix-slice matmul aliasing
               the same resident weights (real MXU savings, TPU-native).

All control inputs are *values*, never shapes — actuation never
recompiles.
"""
from __future__ import annotations

from functools import partial
from typing import Callable, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax import lax

# --------------------------------------------------------------------------
# LayerSelect
# --------------------------------------------------------------------------


def layer_select(gate, block_fn: Callable, x):
    """Run ``block_fn(x)`` if ``gate`` else identity (pure x -> x blocks).

    A skipped layer costs a predicate, not FLOPs or weight DMA."""
    return lax.cond(gate, block_fn, lambda y: y, x)


def layer_select_pair(gate, block_fn: Callable, x, state):
    """LayerSelect for blocks of signature ``(x, state) -> (x, state)``."""
    return lax.cond(gate, lambda: block_fn(x, state), lambda: (x, state))


# --------------------------------------------------------------------------
# SubnetNorm
# --------------------------------------------------------------------------


def subnet_norm(x, gamma_table, subnet_id, *, beta_table=None, eps: float = 1e-5,
                kind: str = "rmsnorm"):
    """Normalize ``x`` with per-subnet parameters.

    ``gamma_table``: (n_subnets, d) — the non-shared bookkeeping that is
    ~500x smaller than the shared weights (paper Fig. 4). ``subnet_id``
    is a traced int32 scalar: the gather is the whole actuation cost.

    The plain RMS flavor routes through the kernel dispatcher: on TPU
    (or an explicitly forced tier) the Pallas SubnetNorm kernel runs;
    otherwise the XLA path below.
    """
    if kind == "rmsnorm" and beta_table is None:
        from repro.kernels import ops as kops
        y = kops.model_subnet_rmsnorm(x, gamma_table, subnet_id, eps=eps)
        if y is not None:
            return y
    gamma = jnp.take(gamma_table, subnet_id, axis=0)
    xf = x.astype(jnp.float32)
    if kind == "rmsnorm":
        var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        y = xf * lax.rsqrt(var + eps)
    elif kind == "layernorm":
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        y = (xf - mu) * lax.rsqrt(var + eps)
    else:
        raise ValueError(kind)
    y = y * gamma.astype(jnp.float32)
    if beta_table is not None:
        y = y + jnp.take(beta_table, subnet_id, axis=0).astype(jnp.float32)
    return y.astype(x.dtype)


def subnet_batch_norm(x, mean_table, var_table, gamma, beta, subnet_id,
                      eps: float = 1e-5):
    """True BatchNorm SubnetNorm for the conv supernet (paper's arch).

    ``mean_table``/``var_table``: (n_subnets, C) precomputed by
    calibration forward passes (core/calibrate.py). gamma/beta shared.
    x: (B, H, W, C).
    """
    mu = jnp.take(mean_table, subnet_id, axis=0)
    var = jnp.take(var_table, subnet_id, axis=0)
    xf = x.astype(jnp.float32)
    y = (xf - mu) * lax.rsqrt(var + eps) * gamma + beta
    return y.astype(x.dtype)


# --------------------------------------------------------------------------
# WeightSlice
# --------------------------------------------------------------------------


def channel_mask(width: int, active, dtype=jnp.float32):
    """(width,) mask of the first ``active`` channels (OFA channel
    sorting ⇒ importance-ranked prefix)."""
    return (lax.iota(jnp.int32, width) < active).astype(dtype)


def slice_mask(x, active, axis: int = -1):
    """Zero all channels of ``x`` beyond ``active`` along ``axis``."""
    width = x.shape[axis]
    m = channel_mask(width, active, x.dtype)
    shape = [1] * x.ndim
    shape[axis] = width
    return x * m.reshape(shape)


def sliced_matmul(x, w, active_in, active_out, *, mode: str = "mask",
                  in_options: Sequence[int] = (), out_options: Sequence[int] = (),
                  bucket=None, precision=None):
    """WeightSlice matmul: ``y = x[..., :k_in] @ w[:k_in, :k_out]`` with
    output zero-padded to w.shape[-1].

    mask mode:   traced ``active_in/active_out`` (any value), full FLOPs.
    switch mode: ``bucket`` indexes the static (in_options x out_options)
                 grid; each branch is a statically sliced matmul.
    """
    if mode == "mask":
        xm = slice_mask(x, active_in) if active_in is not None else x
        y = jnp.matmul(xm, w, precision=precision)
        return slice_mask(y, active_out) if active_out is not None else y

    if mode == "switch":
        ins = list(in_options) or [w.shape[0]]
        outs = list(out_options) or [w.shape[1]]
        # bucket enumerates the zipped (not crossed) option list when the
        # two dims are driven by the same control knob.
        n = max(len(ins), len(outs))
        ins = ins * n if len(ins) == 1 else ins
        outs = outs * n if len(outs) == 1 else outs

        def make_branch(k_in: int, k_out: int):
            def branch():
                xs = x[..., :k_in]
                ws = lax.slice(w, (0, 0), (k_in, k_out))
                y = jnp.matmul(xs, ws, precision=precision)
                pad = w.shape[1] - k_out
                if pad:
                    y = jnp.pad(y, [(0, 0)] * (y.ndim - 1) + [(0, pad)])
                return y
            return branch

        branches = [make_branch(ki, ko) for ki, ko in zip(ins, outs)]
        return lax.switch(jnp.clip(bucket, 0, n - 1), branches)

    raise ValueError(f"unknown WeightSlice mode {mode!r}")


def switch_over_widths(bucket, options: Sequence[int], fn: Callable[[int], jnp.ndarray]):
    """Generic WeightSlice switch: ``fn(k)`` built per static width k.

    Used to wrap whole sub-blocks (e.g. attention with k active heads)
    where the elastic dim is interior to the computation. All branches
    must return identical shapes.
    """
    opts = list(options)
    branches = [partial(fn, k) for k in opts]
    return lax.switch(jnp.clip(bucket, 0, len(opts) - 1), branches)
