"""Pareto-subnet extraction (the paper's NAS step, §4.2/§5 profiler)
plus the accuracy / latency predictors it consumes.

The paper runs OFA's NAS with latency+accuracy predictors to obtain
Phi_pareto (|Phi_pareto| ~ 1e3 out of |Phi| ~ 1e19) in <= 2 min. Our
control spaces are discrete grids, so "NAS" is exhaustive enumeration +
predictor evaluation + Pareto filtering — the same contract, exact
instead of sampled.

Accuracy predictors are *predictors* (as in the paper): monotone,
FLOPs-based, fit so the conv supernet spans the paper's published
0.9-7.5 GFLOPs / 73-80% top-1 range.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Sequence, Tuple

import numpy as np

from repro.configs.base import ArchConfig
from repro.core.subnet import (SubnetDescriptor, active_ffn, active_heads,
                               count_params, enumerate_space, flops_per_token,
                               stage_gates)

# --------------------------------------------------------------------------
# FLOPs
# --------------------------------------------------------------------------


def conv_flops_per_image(cfg: ArchConfig, sub: SubnetDescriptor | None = None) -> int:
    """Matmul-equivalent FLOPs for one image through the conv supernet."""
    e = sub.ffn_frac if sub else 1.0
    w = sub.head_frac if sub else 1.0
    gates = stage_gates(cfg, sub.depth_frac if sub else 1.0)
    img = cfg.img_size
    hw = (img // 2) ** 2                     # after stem stride 2
    stem_w = max(64, cfg.conv_stage_widths[0] // 4)
    total = 2 * 9 * 3 * stem_w * hw
    cin = stem_w
    gi = 0
    for si, stage in enumerate(cfg.stages):
        cout = cfg.conv_stage_widths[si]
        last = si == len(cfg.stages) - 1
        c_out_active = cout if last else max(8, int(w * cout))
        mid = max(8, int(e * (cout // 4)))
        hw = hw // 4                          # stage entry stride 2
        for r in range(stage.repeat):
            live = bool(gates[gi]) or r == 0
            gi += 1
            if not live:
                continue
            c_in = cin if r == 0 else c_out_active
            total += 2 * hw * (c_in * mid + 9 * mid * mid + mid * c_out_active)
            if r == 0:
                total += 2 * hw * c_in * c_out_active
        cin = cout
    total += 2 * cfg.conv_stage_widths[-1] * cfg.n_classes
    return int(total)


def subnet_flops(cfg: ArchConfig, sub: SubnetDescriptor | None = None) -> int:
    """FLOPs per serving item (token for LMs, image for the conv net)."""
    if cfg.family == "conv":
        return conv_flops_per_image(cfg, sub)
    return flops_per_token(cfg, sub)


def conv_params(cfg: ArchConfig, sub: SubnetDescriptor | None = None,
                resident: bool = True) -> int:
    """Exact conv supernet parameter count. ``resident`` = full shared
    weights in HBM; else the extracted subnet (what Clipper+ loads)."""
    e = 1.0 if (resident or sub is None) else sub.ffn_frac
    w = 1.0 if (resident or sub is None) else sub.head_frac
    gates = stage_gates(cfg, 1.0 if (resident or sub is None) else sub.depth_frac)
    stem_w = max(64, cfg.conv_stage_widths[0] // 4)
    total = 9 * 3 * stem_w
    cin = stem_w
    gi = 0
    for si, stage in enumerate(cfg.stages):
        cout = cfg.conv_stage_widths[si]
        last = si == len(cfg.stages) - 1
        c_out = cout if last else max(8, int(w * cout))
        mid = max(8, int(e * (cout // 4)))
        for r in range(stage.repeat):
            live = bool(gates[gi]) or r == 0
            gi += 1
            if not live:
                continue
            c_in = cin if r == 0 else c_out
            total += c_in * mid + 9 * mid * mid + mid * c_out
            if r == 0:
                total += c_in * c_out
        cin = cout
    total += cfg.conv_stage_widths[-1] * cfg.n_classes
    return int(total)


def subnet_weight_bytes(cfg: ArchConfig, sub: SubnetDescriptor | None = None,
                        resident: bool = True) -> int:
    if cfg.family == "conv":
        return conv_params(cfg, sub, resident=resident) * 4
    itemsize = 2 if cfg.dtype == "bfloat16" else 4
    return count_params(cfg, sub, resident=resident) * itemsize


# --------------------------------------------------------------------------
# Accuracy predictor
# --------------------------------------------------------------------------

# Fit to the paper's published pareto range: 0.9 GF -> 73%, 7.5 GF -> 80%.
_CONV_A, _CONV_B = 81.0, 7.4


def accuracy_predictor(cfg: ArchConfig, sub: SubnetDescriptor) -> float:
    """Predicted task accuracy (%) of a subnet. Monotone in FLOPs with
    diminishing returns (paper Fig. 2 shape)."""
    f = subnet_flops(cfg, sub)
    if cfg.family == "conv":
        gf = f / 1e9
        return float(np.clip(_CONV_A - _CONV_B / max(gf, 1e-3), 50.0, 80.6))
    # LM archs: relative predictor anchored at the max subnet = 80%, the
    # same hyperbolic shape, clipped so the serving range mirrors the
    # paper's 73-80% window.
    f_max = subnet_flops(cfg, None)
    rel = f / max(f_max, 1)
    return float(np.clip(80.0 - 4.0 * (1.0 / max(rel, 1e-3) - 1.0), 70.0, 80.6))


# --------------------------------------------------------------------------
# Pareto filtering (the NAS output)
# --------------------------------------------------------------------------


@dataclass(frozen=True)
class ParetoPoint:
    sub: SubnetDescriptor
    acc: float
    gflops: float
    weight_mb: float


def pareto_filter(points: Sequence[ParetoPoint]) -> List[ParetoPoint]:
    """Keep points not dominated in (min gflops, max acc)."""
    pts = sorted(points, key=lambda p: (p.gflops, -p.acc))
    out: List[ParetoPoint] = []
    best = -np.inf
    for p in pts:
        if p.acc > best + 1e-9:
            out.append(p)
            best = p.acc
    return out


def pareto_subnets(cfg: ArchConfig) -> List[ParetoPoint]:
    """Enumerate Phi, score with the predictors, return Phi_pareto
    (ascending FLOPs/accuracy)."""
    pts = []
    for sub in enumerate_space(cfg):
        pts.append(ParetoPoint(
            sub=sub,
            acc=accuracy_predictor(cfg, sub),
            gflops=subnet_flops(cfg, sub) / 1e9,
            weight_mb=subnet_weight_bytes(cfg, sub, resident=False) / 2**20,
        ))
    return pareto_filter(pts)


def uniform_sample(pareto: Sequence[ParetoPoint], n: int) -> List[ParetoPoint]:
    """n points uniformly spaced w.r.t. FLOPs (paper Fig. 13a samples 6)."""
    if len(pareto) <= n:
        return list(pareto)
    idx = np.linspace(0, len(pareto) - 1, n).round().astype(int)
    return [pareto[i] for i in sorted(set(idx.tolist()))]
