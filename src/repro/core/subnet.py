"""Subnet descriptors, the architecture space Phi, and control tuples.

A *subnet* is a point in the SubNetAct control space (paper Sec. 2.2/3):
``(D, E, W)`` = (depth, expand-ratio, width-multiplier), extended here
with the MoE top-k knob. The host-side :class:`SubnetDescriptor` is pure
metadata; :func:`make_control` lowers it into the device-side control
tuple (small integer arrays) consumed by the jitted step functions.

Actuation == passing a different control tuple. Same compiled
executable, no weight movement, no recompilation.
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.configs.base import ArchConfig, ElasticSpec, Stage

# Round active channel counts to the MXU-friendly lane width.
CHANNEL_ALIGN = 128


def _align(x: int, align: int = CHANNEL_ALIGN) -> int:
    return max(align, int(round(x / align)) * align)


@dataclass(frozen=True, order=True)
class SubnetDescriptor:
    """One subnet phi in Phi: host-side, hashable, orderable."""

    depth_frac: float
    ffn_frac: float
    head_frac: float
    topk: int = 0            # 0 = arch default / not MoE
    subnet_id: int = 0       # index into SubnetNorm tables & profiles

    def key(self) -> Tuple:
        return (self.depth_frac, self.ffn_frac, self.head_frac, self.topk)


def enumerate_space(cfg: ArchConfig) -> List[SubnetDescriptor]:
    """Enumerate Phi for this arch from its ElasticSpec (deterministic)."""
    e = cfg.elastic
    topks: Tuple[int, ...] = e.topk_options or (cfg.top_k,)
    out: List[SubnetDescriptor] = []
    sid = 0
    for d, f, h, k in itertools.product(
        sorted(e.depth_fracs), sorted(e.ffn_fracs), sorted(e.head_fracs), sorted(topks)
    ):
        out.append(SubnetDescriptor(d, f, h, k, subnet_id=sid))
        sid += 1
    return out


def max_subnet(cfg: ArchConfig) -> SubnetDescriptor:
    space = enumerate_space(cfg)
    return max(space, key=lambda s: (s.depth_frac, s.ffn_frac, s.head_frac, s.topk))


def min_subnet(cfg: ArchConfig) -> SubnetDescriptor:
    space = enumerate_space(cfg)
    return min(space, key=lambda s: (s.depth_frac, s.ffn_frac, s.head_frac, s.topk))


# --------------------------------------------------------------------------
# Device-side control tuple
# --------------------------------------------------------------------------


def active_ffn(cfg: ArchConfig, frac: float) -> int:
    return min(cfg.d_ff, _align(cfg.d_ff * frac))


def active_moe_ffn(cfg: ArchConfig, frac: float) -> int:
    return min(cfg.resolved_moe_d_ff, _align(cfg.resolved_moe_d_ff * frac))


def head_group_size(cfg: ArchConfig) -> int:
    """Query heads per KV head (GQA group size; 1 for MHA)."""
    kv = max(cfg.n_kv_heads, 1)
    return cfg.n_heads // kv if cfg.n_heads % kv == 0 else 1


def active_heads(cfg: ArchConfig, frac: float) -> int:
    """Active query heads under WeightSlice.

    GQA (group > 1): slice query heads *within* each KV group — every
    KV head keeps serving, so the cache layout is identical for every
    subnet. MHA (group == 1): prefix of heads (q and k/v drop together).
    """
    group = head_group_size(cfg)
    if group > 1:
        kv = cfg.n_heads // group
        a = max(1, int(round(group * frac)))
        return kv * a
    return max(1, int(round(cfg.n_heads * frac)))


def stage_gates(cfg: ArchConfig, depth_frac: float) -> np.ndarray:
    """Per-repeat-unit boolean gates (LayerSelect input), concatenated
    over stages. Active units are the *first* ceil(frac*repeat) of each
    stage (OFA keeps early layers; late layers are the elastic ones)."""
    gates = []
    for s in cfg.stages:
        n_active = max(1, int(np.ceil(s.repeat * depth_frac)))
        g = np.zeros((s.repeat,), dtype=bool)
        g[:n_active] = True
        gates.append(g)
    return np.concatenate(gates) if gates else np.zeros((0,), dtype=bool)


def make_control(cfg: ArchConfig, sub: SubnetDescriptor) -> Dict[str, np.ndarray]:
    """Lower a descriptor into the device-side control tuple.

    Everything is a *value*, never a shape: jit once, actuate forever.
    ``*_bucket`` fields index the discrete option (for WeightSlice
    switch-mode); ``*_width`` fields carry the channel count (for
    mask-mode and the Pallas sliced kernels).
    """
    e = cfg.elastic
    ffn_opts = sorted(e.ffn_fracs)
    head_opts = sorted(e.head_fracs)
    slstm_ff = int(cfg.slstm_proj_factor * cfg.d_model)
    ctrl = {
        "layer_gate": stage_gates(cfg, sub.depth_frac),
        "ffn_width": np.int32(active_ffn(cfg, sub.ffn_frac)),
        "slstm_ffn_width": np.int32(min(slstm_ff, _align(slstm_ff * sub.ffn_frac, 64))),
        "ffn_bucket": np.int32(ffn_opts.index(sub.ffn_frac)),
        "moe_ffn_width": np.int32(active_moe_ffn(cfg, sub.ffn_frac)),
        "head_width": np.int32(active_heads(cfg, sub.head_frac)),
        "head_bucket": np.int32(head_opts.index(sub.head_frac)),
        "topk": np.int32(sub.topk or cfg.top_k or 0),
        "subnet_id": np.int32(sub.subnet_id),
    }
    return ctrl


def sample_control_jax(cfg: ArchConfig, key):
    """Sample a random subnet's control tuple *inside* jit (sandwich-rule
    supernet training). Mirrors :func:`make_control` with traced values;
    subnet_id uses the same mixed-radix order as :func:`enumerate_space`.
    """
    import jax
    import jax.numpy as jnp

    e = cfg.elastic
    depth_opts = jnp.asarray(sorted(e.depth_fracs), jnp.float32)
    ffn_opts = jnp.asarray(sorted(e.ffn_fracs), jnp.float32)
    head_opts = jnp.asarray(sorted(e.head_fracs), jnp.float32)
    topk_opts = jnp.asarray(sorted(e.topk_options or (cfg.top_k,)), jnp.int32)

    kd, kf, kh, kk = jax.random.split(key, 4)
    di = jax.random.randint(kd, (), 0, len(depth_opts))
    fi = jax.random.randint(kf, (), 0, len(ffn_opts))
    hi = jax.random.randint(kh, (), 0, len(head_opts))
    ki = jax.random.randint(kk, (), 0, len(topk_opts))
    d_frac, f_frac, h_frac = depth_opts[di], ffn_opts[fi], head_opts[hi]

    gates = []
    for s in cfg.stages:
        n_active = jnp.maximum(1, jnp.ceil(s.repeat * d_frac)).astype(jnp.int32)
        gates.append(jnp.arange(s.repeat) < n_active)
    layer_gate = jnp.concatenate(gates) if gates else jnp.zeros((0,), bool)

    def aligned(total: int, frac, align: int = CHANNEL_ALIGN):
        w = jnp.round(total * frac / align) * align
        return jnp.clip(w, min(align, total), total).astype(jnp.int32)

    group = head_group_size(cfg)
    if group > 1:
        kv = cfg.n_heads // group
        per_group = jnp.maximum(1, jnp.round(group * h_frac)).astype(jnp.int32)
        head_width = kv * per_group
    else:
        head_width = jnp.maximum(1, jnp.round(cfg.n_heads * h_frac)).astype(jnp.int32)
    slstm_ff = int(cfg.slstm_proj_factor * cfg.d_model)

    n_f, n_h, n_k = len(ffn_opts), len(head_opts), len(topk_opts)
    sid = ((di * n_f + fi) * n_h + hi) * n_k + ki
    return {
        "layer_gate": layer_gate,
        "ffn_width": aligned(cfg.d_ff, f_frac) if cfg.d_ff else jnp.int32(0),
        "slstm_ffn_width": aligned(slstm_ff, f_frac, 64),
        "ffn_bucket": fi.astype(jnp.int32),
        "moe_ffn_width": aligned(cfg.resolved_moe_d_ff, f_frac)
            if cfg.resolved_moe_d_ff else jnp.int32(0),
        "head_width": head_width.astype(jnp.int32),
        "head_bucket": hi.astype(jnp.int32),
        "topk": topk_opts[ki],
        "subnet_id": sid.astype(jnp.int32),
    }


def width_options(cfg: ArchConfig) -> Dict[str, List[int]]:
    """The discrete channel-count options per elastic dimension —
    these are the static shapes compiled into WeightSlice switch-mode."""
    e = cfg.elastic
    return {
        "ffn": [active_ffn(cfg, f) for f in sorted(e.ffn_fracs)],
        "moe_ffn": [active_moe_ffn(cfg, f) for f in sorted(e.ffn_fracs)],
        "heads": [active_heads(cfg, f) for f in sorted(e.head_fracs)],
    }


# --------------------------------------------------------------------------
# Analytic FLOPs / params per subnet (drives accuracy+latency predictors,
# memory benchmarks, and MODEL_FLOPS in the roofline report)
# --------------------------------------------------------------------------


def _unit_param_flops(cfg: ArchConfig, kind: str, sub: Optional[SubnetDescriptor]):
    """(params, flops_per_token) for one sub-block at a subnet point.

    ``sub=None`` means the full supernet (all channels, all experts
    resident). FLOPs are matmul MACs*2; norms/elementwise ignored.
    """
    d = cfg.d_model
    hd = cfg.resolved_head_dim
    if sub is None:
        heads, ffn, moe_ffn, topk = cfg.n_heads, cfg.d_ff, cfg.resolved_moe_d_ff, cfg.top_k
    else:
        heads = active_heads(cfg, sub.head_frac)
        ffn = active_ffn(cfg, sub.ffn_frac)
        moe_ffn = active_moe_ffn(cfg, sub.ffn_frac)
        topk = sub.topk or cfg.top_k

    if kind == "attn":
        q = d * heads * hd
        kv = 2 * d * cfg.n_kv_heads * hd
        o = heads * hd * d
        p = q + kv + o
        return p, 2 * p
    if kind == "mlp":
        mats = 3 if cfg.ffn_act == "swiglu" else 2   # SwiGLU: gate,up,down; GELU: up,down
        p = mats * d * ffn
        return p, 2 * p
    if kind == "moe":
        p_router = d * cfg.n_experts
        p_expert = 3 * d * moe_ffn
        p_shared = 3 * d * cfg.resolved_moe_d_ff if cfg.shared_expert else 0
        params_resident = p_router + cfg.n_experts * p_expert + p_shared
        flops_active = 2 * (p_router + topk * p_expert + p_shared)
        return params_resident, flops_active
    if kind == "mamba":
        d_in = cfg.ssm_expand * d
        n_h = d_in // cfg.ssm_head_dim
        # in_proj: x, z (2*d_in) + B, C (2*state) + dt (n_h); conv; out_proj.
        p = d * (2 * d_in + 2 * cfg.ssm_state + n_h) + d_in * cfg.ssm_conv_width + d_in * d
        flops = 2 * p + 4 * d_in * cfg.ssm_state   # + SSD state update/read
        return p, flops
    if kind == "mlstm":
        d_in = int(cfg.mlstm_proj_factor * d)
        qk = d_in // 2
        # up-proj (x, z), q/k proj, v==x, learnable skip, out proj.
        p = d * 2 * d_in + d_in * qk * 2 + d_in * d_in + d_in * d + 3 * d_in
        flops = 2 * p
        return p, flops
    if kind == "slstm":
        p = 4 * d * d + int(3 * d * cfg.slstm_proj_factor * d)
        return p, 2 * p
    raise ValueError(kind)


def count_params(cfg: ArchConfig, sub: Optional[SubnetDescriptor] = None,
                 resident: bool = True) -> int:
    """Parameter count. ``resident`` counts the full supernet weights
    (what sits in HBM); ``resident=False`` with a descriptor counts the
    *extracted* subnet (what Clipper+ would load per model)."""
    total = 0
    gates = stage_gates(cfg, sub.depth_frac if sub else 1.0)
    gi = 0
    for s in cfg.stages:
        for r in range(s.repeat):
            live = bool(gates[gi]) if (sub and not resident) else True
            gi += 1
            for kind in s.pattern:
                p, _ = _unit_param_flops(cfg, kind, None if resident else sub)
                if live:
                    total += p
    if cfg.shared_attn_period:
        p, _ = _unit_param_flops(cfg, "attn", None if resident else sub)
        total += p
    emb = cfg.vocab_size * cfg.d_model
    total += emb if cfg.tie_embeddings else 2 * emb
    return int(total)


def flops_per_token(cfg: ArchConfig, sub: Optional[SubnetDescriptor] = None) -> int:
    """Active matmul FLOPs per token for a subnet (or the max net)."""
    total = 0
    gates = stage_gates(cfg, sub.depth_frac if sub else 1.0)
    gi = 0
    for s in cfg.stages:
        for r in range(s.repeat):
            live = bool(gates[gi])
            gi += 1
            if not live:
                continue
            for kind in s.pattern:
                _, f = _unit_param_flops(cfg, kind, sub)
                total += f
            if cfg.shared_attn_period and (r % cfg.shared_attn_period == cfg.shared_attn_period - 1):
                _, f = _unit_param_flops(cfg, "attn", sub)
                total += f
    total += 2 * cfg.vocab_size * cfg.d_model     # lm head
    return int(total)
