"""Distribution layer: ShardingPlan (DP/TP/EP/SP over the production
mesh), explicit shard_map collectives, and elastic resharding."""
