"""Explicit shard_map collectives — the beyond-paper distributed
optimizations.

``seq_sharded_decode``: flash-decode over a *sequence-sharded* KV cache
(SP). Each shard computes partial online-softmax statistics (m, l, o)
over its local cache slice; the cross-shard combine is three tiny
collectives (pmax on m, psum on l and o) instead of all-gathering the
cache — for a 512k-token cache sharded 256 ways that is ~KBs of ICI
traffic instead of GBs.

``ring_allgather_kv``: collective-permute ring all-gather used by the
perf pass to overlap KV movement with per-step compute where SP is not
available.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

NEG_INF = -1e30


def _partial_decode(q, k, v, first_pos, index):
    """Local online-softmax stats for one cache shard.

    q: (B, Hkv, G, d); k/v: (B, Hkv, S_loc, d); first_pos: absolute
    position of this shard's slot 0. Returns (m, l, o)."""
    qf = q.astype(jnp.float32)
    s = jnp.einsum("bhgd,bhkd->bhgk", qf, k.astype(jnp.float32))
    s = s * (q.shape[-1] ** -0.5)
    pos = first_pos + lax.iota(jnp.int32, k.shape[2])
    mask = pos <= index
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    m = s.max(axis=-1)                                   # (B,Hkv,G)
    p = jnp.exp(s - m[..., None]) * mask[None, None, None]
    l = p.sum(axis=-1)
    o = jnp.einsum("bhgk,bhkd->bhgd", p, v.astype(jnp.float32))
    return m, l, o


def seq_sharded_decode(mesh: Mesh, q, k_cache, v_cache, index,
                       seq_axes: Tuple[str, ...] = ("data",)):
    """Decode attention with the KV cache sharded along sequence.

    q: (B, Hq, 1, d) replicated over ``seq_axes``;
    caches: (B, Hkv, S, d) sharded on S over ``seq_axes``.
    Returns (B, Hq, 1, d) replicated over ``seq_axes``.
    """
    B, Hq, _, d = q.shape
    _, Hkv, S, _ = k_cache.shape
    G = Hq // Hkv
    n_shards = 1
    for a in seq_axes:
        n_shards *= mesh.shape[a]
    s_loc = S // n_shards

    ax = seq_axes if len(seq_axes) > 1 else seq_axes[0]

    def body(q_, k_, v_):
        first = lax.axis_index(seq_axes) * s_loc
        q3 = q_.reshape(B, Hkv, G, d)
        m, l, o = _partial_decode(q3, k_, v_, first, index)
        # cross-shard online-softmax combine: 3 tiny collectives
        m_g = lax.pmax(m, ax)
        corr = jnp.exp(m - m_g)
        l_g = lax.psum(l * corr, ax)
        o_g = lax.psum(o * corr[..., None], ax)
        out = o_g / jnp.maximum(l_g, 1e-30)[..., None]
        return out.reshape(B, Hq, 1, d).astype(v_.dtype)

    spec_q = P(None, None, None, None)
    spec_kv = P(None, None, ax, None)
    out = shard_map(body, mesh=mesh,
                    in_specs=(spec_q, spec_kv, spec_kv),
                    out_specs=spec_q, check_rep=False)(q, k_cache, v_cache)
    return out


def seq_sharded_decode_ref(q, k_cache, v_cache, index):
    """Unsharded oracle for the combine (tests)."""
    from repro.kernels.ref import decode_attention_ref
    return decode_attention_ref(q, k_cache, v_cache, index)


def ring_allgather(mesh: Mesh, x, axis: str):
    """Collective-permute ring all-gather along ``axis`` (double-buffered
    building block for overlap experiments; perf pass only)."""
    n = mesh.shape[axis]

    def body(x_):
        def step(i, carry):
            buf, cur = carry
            nxt = lax.ppermute(cur, axis, [(j, (j + 1) % n) for j in range(n)])
            buf = lax.dynamic_update_index_in_dim(
                buf, nxt, (lax.axis_index(axis) - i - 1) % n, 0)
            return buf, nxt
        buf0 = jnp.zeros((n,) + x_.shape, x_.dtype)
        buf0 = lax.dynamic_update_index_in_dim(buf0, x_, lax.axis_index(axis), 0)
        buf, _ = lax.fori_loop(0, n - 1, step, (buf0, x_))
        return buf

    return shard_map(body, mesh=mesh, in_specs=P(axis),
                     out_specs=P(None, axis), check_rep=False)(x)
