"""Elastic scaling: reshard a live pytree (params / optimizer state /
caches) onto a *different* mesh — the mechanism behind
checkpoint-on-mesh-A / restore-on-mesh-B and in-place pool resizing
after node failures.

On real multi-host TPU this goes through jax.device_put with the new
NamedShardings (XLA moves only the bytes that change owners); the same
code path runs here on the CPU placeholder mesh.
"""
from __future__ import annotations

from typing import Any, Callable

import jax
from jax.sharding import Mesh

from repro.distributed.sharding import ShardingPlan


def reshard(tree: Any, new_plan: ShardingPlan,
            shardings_of: Callable[[ShardingPlan, Any], Any]) -> Any:
    """Move ``tree`` onto ``new_plan.mesh`` with the plan's shardings.

    ``shardings_of(plan, tree)`` selects which rule family applies
    (plan.params / plan.cache / plan.replicated).
    """
    shardings = shardings_of(new_plan, tree)
    return jax.tree.map(jax.device_put, tree, shardings)


def reshard_params(tree: Any, new_plan: ShardingPlan) -> Any:
    return reshard(tree, new_plan, lambda p, t: p.params(t))


def shrink_mesh(mesh: Mesh, cfg, *, drop_axis: str = "data", factor: int = 2) -> Mesh:
    """A degraded mesh after losing ``factor``-worth of ``drop_axis``
    (node failures): rebuild from the surviving devices."""
    import numpy as np
    devs = np.asarray(mesh.devices)
    idx = [slice(None)] * devs.ndim
    ax = mesh.axis_names.index(drop_axis)
    idx[ax] = slice(0, devs.shape[ax] // factor)
    return Mesh(devs[tuple(idx)], mesh.axis_names)
