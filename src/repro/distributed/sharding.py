"""ShardingPlan: one object mapping every tensor of an architecture —
parameters, batches, KV/SSM caches, control tuples — to a PartitionSpec
over the production mesh ``(pod, data, model)``.

Posture for 1000+ nodes: all placement is expressed as NamedSharding
rules keyed on tree paths + divisibility, so the same plan scales with
the mesh (a larger mesh only changes axis sizes). TP over ``model``
(attention heads / d_ff / vocab), EP over ``model`` for many-expert
MoE, DP/FSDP over ``(pod, data)``, and SP (sequence sharding) for
decode caches whose batch cannot cover the data axis.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, ShapeSpec


def _path_str(path) -> str:
    out = []
    for p in path:
        if hasattr(p, "key"):
            out.append(str(p.key))
        elif hasattr(p, "idx"):
            out.append(str(p.idx))
        elif hasattr(p, "name"):
            out.append(str(p.name))
    return "/".join(out)


@dataclass
class ShardingPlan:
    # Mesh or AbstractMesh: rule evaluation only reads shape/axis_names,
    # so plans can be built (and unit-tested) without any devices.
    mesh: Any
    cfg: ArchConfig
    # 2D expert sharding (EP over model x FFN over data). Decode-only:
    # per-step activations are tiny, so the extra gather/reduce-scatter
    # over `data` costs ~MBs while resident expert bytes drop by the
    # data-axis size (llama4 decode: 45 GB -> 2.8 GB per device).
    # Train/prefill keep 1D EP — there the activation volume dominates.
    moe_2d: bool = False
    # FSDP / ZeRO-3: additionally shard parameters over the DP axes on
    # their first free divisible dimension; XLA all-gathers each scan
    # step's layer slice just-in-time (latency-hiding overlaps it with
    # the previous layer's compute). For models whose TP-sharded weights
    # alone exceed HBM (llama4 train: 46 GB/device).
    fsdp: bool = False

    @classmethod
    def abstract(cls, shape: Tuple[int, ...], axes: Tuple[str, ...],
                 cfg: ArchConfig, **kwargs) -> "ShardingPlan":
        """Plan over a device-free AbstractMesh (rule tests, planning
        tools on hosts without the target topology). Constructed via
        the compat shim — the AbstractMesh constructor signature moved
        across JAX versions."""
        from repro import compat
        return cls(compat.make_abstract_mesh(shape, axes), cfg, **kwargs)

    # ---- axis helpers -------------------------------------------------
    @property
    def dp_axes(self) -> Tuple[str, ...]:
        return tuple(a for a in ("pod", "data") if a in self.mesh.axis_names)

    @property
    def tp_axis(self) -> str:
        return "model"

    @property
    def dp_size(self) -> int:
        return int(np.prod([self.mesh.shape[a] for a in self.dp_axes]))

    @property
    def tp_size(self) -> int:
        return int(self.mesh.shape[self.tp_axis])

    def _dp_if(self, n: int):
        return self.dp_axes if n % max(self.dp_size, 1) == 0 else None

    def _tp_if(self, n: int):
        return self.tp_axis if n % max(self.tp_size, 1) == 0 else None

    def named(self, spec: P) -> NamedSharding:
        return NamedSharding(self.mesh, spec)

    # ---- parameters ---------------------------------------------------
    def param_spec(self, path: str, shape: Tuple[int, ...]) -> P:
        """TP/EP rules keyed on the leaf name; stacked (scan) leading
        axes are never sharded."""
        name = path.rsplit("/", 1)[-1]
        rank = len(shape)

        def lead(base: Tuple) -> P:
            pad = rank - len(base)
            return P(*([None] * pad + list(base)))

        tp = self.tp_axis
        if name == "embed":
            return P(self._tp_if(shape[0]), None)
        if name == "head":
            return P(None, self._tp_if(shape[1]))
        if name in ("wq", "wk", "wv", "wu", "wg", "w_up", "w_in", "w_x", "swg", "swu"):
            if name in ("wg", "wu") and rank >= 3 and "moe" in path:
                # MoE experts (E, d, f): EP over model when E divides,
                # else TP on the expert FFN dim.
                E, _, f = shape[-3:]
                if E % self.tp_size == 0:
                    if self.moe_2d and f % max(self.dp_size, 1) == 0:
                        return lead((tp, None, self.dp_axes))
                    return lead((tp, None, None))
                return lead((None, None, self._tp_if(f)))
            return lead((None, self._tp_if(shape[-1])))
        if name in ("wo", "wd", "w_out", "w_down", "swd"):
            if name == "wd" and rank >= 3 and "moe" in path:
                E, f, _ = shape[-3:]
                if E % self.tp_size == 0:
                    if self.moe_2d and f % max(self.dp_size, 1) == 0:
                        return lead((tp, self.dp_axes, None))
                    return lead((tp, None, None))
                return lead((None, self._tp_if(f), None))
            return lead((self._tp_if(shape[-2]), None))
        # routers, biases, norm tables, SSM/conv small tensors: replicate
        return P(*([None] * rank))

    def _add_fsdp(self, spec: P, shape: Tuple[int, ...]) -> P:
        """Compose DP onto the first unsharded axis that divides."""
        if not self.fsdp:
            return spec
        entries = list(spec) + [None] * (len(shape) - len(spec))
        for i, (s, ax) in enumerate(zip(shape, entries)):
            if ax is None and s % max(self.dp_size, 1) == 0 and s >= self.dp_size:
                entries[i] = self.dp_axes
                return P(*entries)
        return spec

    def params(self, tree) -> Any:
        """Tree of NamedShardings matching ``tree`` (shapes or arrays)."""
        def one(path, leaf):
            spec = self.param_spec(_path_str(path), leaf.shape)
            return self.named(self._add_fsdp(spec, leaf.shape))
        return jax.tree_util.tree_map_with_path(one, tree)

    # ---- batches ------------------------------------------------------
    def batch_spec(self, name: str, shape: Tuple[int, ...]) -> P:
        B = shape[0]
        dp = self._dp_if(B)
        rest = [None] * (len(shape) - 1)
        if name == "positions" and len(shape) == 3 and shape[0] == 3:
            # M-RoPE position streams: (3, B, S)
            return P(None, self._dp_if(shape[1]), None)
        return P(dp, *rest)

    def batch(self, tree: Dict[str, Any]) -> Dict[str, Any]:
        return {k: self.named(self.batch_spec(k, v.shape)) for k, v in tree.items()}

    # ---- decode caches ------------------------------------------------
    def cache_spec(self, path: str, shape: Tuple[int, ...]) -> P:
        """Caches carry a leading stacked-layer axis (scan layout).

        Attention k/v: (L, B, Hkv, S, hd) — B over DP when divisible,
        else SP: S over DP (the long-context batch=1 case); heads over
        TP when divisible, else S additionally over TP.
        SSM/xLSTM states: (L, B, ...) — B over DP when divisible; the
        mamba head axis over TP when divisible.
        """
        name = path.rsplit("/", 1)[-1]
        if name in ("k", "v") and len(shape) in (4, 5):
            lead: Tuple = (None,) * (len(shape) - 4)
            B, H, S, hd = shape[-4:]
            b_ax = self._dp_if(B)
            h_ax = self._tp_if(H)
            # TP placement preference when heads don't divide: shard
            # head_dim, NOT sequence — a dynamic_update_slice at a
            # traced position on a sequence-sharded cache forces XLA to
            # all-gather the whole cache (temp = cache x tp; measured
            # 112 GB/device on llama4 decode_32k — see EXPERIMENTS.md
            # §Perf iteration 1).
            hd_ax = self._tp_if(hd) if h_ax is None else None
            s_axes = []
            if b_ax is None:
                s_axes.extend(self.dp_axes)
            if h_ax is None and hd_ax is None:
                s_axes.append(self.tp_axis)
            s_ax = tuple(s_axes) if s_axes and S % int(np.prod(
                [self.mesh.shape[a] for a in s_axes])) == 0 else None
            return P(*lead, b_ax, h_ax, s_ax, hd_ax)
        if name == "ssm" and len(shape) == 5:        # (L, B, H, N, Pdim)
            return P(None, self._dp_if(shape[1]), self._tp_if(shape[2]), None, None)
        if name == "conv" and len(shape) == 4:       # (L, B, W, C)
            return P(None, self._dp_if(shape[1]), None, self._tp_if(shape[3]))
        # xlstm states et al: (L, B, ...)
        if len(shape) >= 2:
            return P(None, self._dp_if(shape[1]), *([None] * (len(shape) - 2)))
        return P(*([None] * len(shape)))

    def cache(self, tree) -> Any:
        def one(path, leaf):
            return self.named(self.cache_spec(_path_str(path), leaf.shape))
        return jax.tree_util.tree_map_with_path(one, tree)

    # ---- control tuple / scalars --------------------------------------
    def replicated(self, tree) -> Any:
        return jax.tree.map(
            lambda leaf: self.named(P(*([None] * getattr(leaf, "ndim", len(leaf.shape))))),
            tree)
