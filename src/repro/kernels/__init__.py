"""Pallas TPU kernels (with BlockSpec VMEM tiling) + jit'd dispatch
wrappers (ops.py) + pure-jnp oracles (ref.py)."""
