"""Single-token decode attention over a KV cache as a Pallas TPU kernel.

Decode is memory-bound: the cost is streaming the KV cache HBM->VMEM.
The grid walks kv blocks; blocks entirely beyond the current position
are neither DMA'd (index remap) nor computed (pl.when) — a 32k-slot
cache at position 1k reads ~1k slots. GQA handled by processing all G
query heads of one kv head per grid row (one cache stream feeds G
queries — the whole point of GQA at decode time).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import compat

NEG_INF = -1e30


def _kernel(idx_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            kb: int, nk: int, window: int, smax: int, scale: float):
    ki = pl.program_id(1)
    index = idx_ref[0]

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    k_first = ki * kb
    live = k_first <= index if not window else True

    @pl.when(live)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)                 # (G, d)
        k = k_ref[0, 0].astype(jnp.float32)                 # (kb, d)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
        pos = k_first + lax.iota(jnp.int32, kb)
        if window:
            age = (index - pos) % smax                   # rolling buffer
            mask = age < jnp.minimum(window, index + 1)
        else:
            mask = pos <= index
        s = jnp.where(mask[None, :], s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(-1, keepdims=True))
        p = jnp.exp(s - m_new) * mask[None, :]
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + p.sum(-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + jnp.dot(
            p, v, preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ki == nk - 1)
    def _emit():
        o_ref[0, 0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("window", "kv_block", "interpret"))
def decode_attention(q, k_cache, v_cache, index, *, window: int = 0,
                     kv_block: int = 256, interpret: bool = False):
    """q: (B, Hq, 1, d); caches: (B, Hkv, Smax, d) -> (B, Hq, 1, d)."""
    B, Hq, _, d = q.shape
    _, Hkv, Smax, _ = k_cache.shape
    G = Hq // Hkv
    scale = float(d ** -0.5)

    kb = min(kv_block, Smax)
    pk = (-Smax) % kb
    if pk:
        k_cache = jnp.pad(k_cache, ((0, 0), (0, 0), (0, pk), (0, 0)))
        v_cache = jnp.pad(v_cache, ((0, 0), (0, 0), (0, pk), (0, 0)))
    nk = (Smax + pk) // kb

    q3 = q.reshape(B, Hkv, G, d)
    idx = jnp.asarray(index, jnp.int32).reshape(1)

    def kv_index(bh, ki, idx_s):
        if not window:
            # blocks beyond the live prefix re-map to block 0
            ki = jnp.minimum(ki, lax.div(idx_s[0], kb))
        return (bh // Hkv, bh % Hkv, ki, 0)

    out = pl.pallas_call(
        functools.partial(_kernel, kb=kb, nk=nk, window=window, smax=Smax,
                          scale=scale),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(B * Hkv, nk),
            in_specs=[
                pl.BlockSpec((1, 1, G, d),
                             lambda bh, ki, idx_s: (bh // Hkv, bh % Hkv, 0, 0)),
                pl.BlockSpec((1, 1, kb, d), kv_index),
                pl.BlockSpec((1, 1, kb, d), kv_index),
            ],
            out_specs=pl.BlockSpec((1, 1, G, d),
                                   lambda bh, ki, idx_s: (bh // Hkv, bh % Hkv, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((G, 1), jnp.float32),
                pltpu.VMEM((G, 1), jnp.float32),
                pltpu.VMEM((G, d), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((B, Hkv, G, d), v_cache.dtype),
        interpret=interpret,
        **compat.compiler_params_kwargs(
            dimension_semantics=("parallel", "arbitrary")),
    )(idx, q3, k_cache, v_cache)
    return out.reshape(B, Hq, 1, d)
