"""Capability-probing dispatcher for the Pallas kernels.

One registry maps each kernel name to its implementations per tier:

    ``tpu``           — compiled Pallas kernel (TPU backend attached)
    ``pallas-triton`` — backend-agnostic Pallas kernel lowered through
                        Triton (GPU backend attached)
    ``interpret``     — the TPU Pallas kernel under the interpreter
                        (CPU hosts: validates kernel numerics, slowly)
    ``ref``           — the pure-jnp oracle from :mod:`repro.kernels.ref`

The process tier is resolved once by :func:`repro.compat.kernel_tier`
(``tpu -> pallas-triton -> interpret -> ref`` fallback chain,
overridable via the ``REPRO_KERNEL_TIER`` env var or
:func:`repro.compat.set_kernel_tier`).
A kernel that lacks an implementation at the process tier falls through
to the next tier down the chain, so registering a new backend or kernel
variant is a one-file change: implement + register, and every call site
above (models, serving, launch) picks it up.

Model hot paths use :func:`model_tier` instead of the raw process tier:
an explicit override is honored verbatim, but a *probed* ``interpret``
tier degrades to ``ref`` there — the interpreter is a numerics
validation vehicle, orders of magnitude too slow for model-sized calls.
"""
from __future__ import annotations

from typing import Callable, Dict, Optional, Tuple

from repro import compat


class KernelDispatcher:
    """Name -> {tier -> impl} registry with chain-fallback resolution."""

    def __init__(self):
        self._impls: Dict[str, Dict[str, Callable]] = {}

    def register(self, name: str, tier: str, fn: Callable) -> Callable:
        if tier not in compat.KERNEL_TIERS:
            raise ValueError(f"unknown tier {tier!r}; "
                             f"expected one of {compat.KERNEL_TIERS}")
        self._impls.setdefault(name, {})[tier] = fn
        return fn

    def kernels(self) -> Tuple[str, ...]:
        return tuple(sorted(self._impls))

    def registered_tiers(self, name: str) -> Tuple[str, ...]:
        return tuple(t for t in compat.KERNEL_TIERS
                     if t in self._impls.get(name, {}))

    def resolve(self, name: str,
                tier: Optional[str] = None) -> Tuple[str, Callable]:
        """(tier, impl) for ``name``. ``tier=None`` uses the process
        tier, falling down the chain past unregistered tiers."""
        try:
            impls = self._impls[name]
        except KeyError:
            raise KeyError(f"no kernel named {name!r}; "
                           f"registered: {self.kernels()}") from None
        if tier is not None:
            if tier not in impls:
                raise KeyError(
                    f"kernel {name!r} has no {tier!r} tier; "
                    f"registered tiers: {self.registered_tiers(name)}")
            return tier, impls[tier]
        start = compat.KERNEL_TIERS.index(compat.kernel_tier())
        for cand in compat.KERNEL_TIERS[start:]:
            if cand in impls:
                return cand, impls[cand]
        raise KeyError(f"kernel {name!r} has no tier at or below "
                       f"{compat.kernel_tier()!r}")

    def call(self, name: str, *args, tier: Optional[str] = None, **kwargs):
        _, fn = self.resolve(name, tier)
        return fn(*args, **kwargs)


DISPATCHER = KernelDispatcher()


def register(name: str, tier: str):
    """Decorator: register ``fn`` as the ``tier`` impl of ``name``."""
    def deco(fn: Callable) -> Callable:
        return DISPATCHER.register(name, tier, fn)
    return deco


def coerce_tier(tier: Optional[str], interpret: Optional[bool]) -> Optional[str]:
    """Back-compat: the pre-dispatcher API took ``interpret: bool``."""
    if tier is not None:
        return tier
    if interpret is None:
        return None
    return "interpret" if interpret else "tpu"


def model_tier() -> str:
    """Dispatch tier for model hot paths (forward/decode under jit).

    Explicit override (env/config) wins — honored verbatim, even for
    ``pallas-triton``; otherwise the fastest *compiled* tier available
    on this host (``tpu``, then ``pallas-triton``), else ``ref`` —
    never a probed ``interpret``.
    """
    explicit = compat.explicit_kernel_tier()
    if explicit is not None:
        return explicit
    for tier in ("tpu", "pallas-triton"):
        if compat.tier_available(tier):
            return tier
    return "ref"
