"""Blockwise online-softmax (flash) attention as a Pallas TPU kernel.

TPU adaptation of the paper's serving hot loop for the assigned LM
archs: q/k/v tiles stream HBM->VMEM block-by-block; softmax statistics
(m, l) and the output accumulator live in VMEM scratch across the kv
grid axis. Causally-dead kv blocks are skipped: their DMA is remapped to
block 0 and their compute predicated out, so prefill cost tracks the
~S^2/2 causal triangle rather than S^2.

Supports GQA (Hq % Hkv == 0) via head-index arithmetic in the
index_maps, sliding windows, and a traced valid-KV length (decode /
chunked prefill over a cache).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import compat

NEG_INF = -1e30


def _kernel(lens_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            qb: int, kb: int, nk: int, causal: bool, window: int, scale: float):
    qi, ki = pl.program_id(1), pl.program_id(2)
    kv_len = lens_ref[0]

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_pos = qi * qb + lax.iota(jnp.int32, qb)
    k_first = ki * kb
    # block-level liveness (causal upper-triangle + window lower bound)
    live = k_first < kv_len
    if causal:
        live &= k_first <= q_pos[-1]
    if window:
        live &= (k_first + kb) > (q_pos[0] - window)

    @pl.when(live)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)                  # (qb, d)
        k = k_ref[0, 0].astype(jnp.float32)                  # (kb, d)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
        k_pos = k_first + lax.iota(jnp.int32, kb)
        mask = k_pos[None, :] < kv_len
        if causal:
            mask &= k_pos[None, :] <= q_pos[:, None]
        if window:
            mask &= k_pos[None, :] > q_pos[:, None] - window
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(-1, keepdims=True))
        p = jnp.exp(s - m_new) * mask    # mask again: fully-dead rows
        corr = jnp.exp(m_prev - m_new)   # would otherwise get exp(0)=1
        l_ref[...] = l_ref[...] * corr + p.sum(-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + jnp.dot(
            p, v, preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ki == nk - 1)
    def _emit():
        o_ref[0, 0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "q_block", "kv_block", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    kv_len=None, q_block: int = 256, kv_block: int = 256,
                    scale=None, interpret: bool = False):
    """q: (B, Hq, Sq, d); k/v: (B, Hkv, Sk, d) -> (B, Hq, Sq, d)."""
    B, Hq, Sq, d = q.shape
    _, Hkv, Sk, _ = k.shape
    G = Hq // Hkv
    scale = float(scale if scale is not None else d ** -0.5)

    qb, kb = min(q_block, Sq), min(kv_block, Sk)
    pq, pk = (-Sq) % qb, (-Sk) % kb
    if pq:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pq), (0, 0)))
    if pk:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pk), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pk), (0, 0)))
    Sqp, Skp = Sq + pq, Sk + pk
    nq, nk = Sqp // qb, Skp // kb

    lens = jnp.array([Sk if kv_len is None else kv_len], jnp.int32)

    grid = (B * Hq, nq, nk)
    out = pl.pallas_call(
        functools.partial(_kernel, qb=qb, kb=kb, nk=nk, causal=causal,
                          window=window, scale=scale),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, 1, qb, d),
                             lambda bh, qi, ki, lens: (bh // Hq, bh % Hq, qi, 0)),
                # causally-dead kv blocks re-map to block 0 (no new DMA)
                pl.BlockSpec((1, 1, kb, d),
                             _kv_index(Hq, Hkv, qb, kb, causal)),
                pl.BlockSpec((1, 1, kb, d),
                             _kv_index(Hq, Hkv, qb, kb, causal)),
            ],
            out_specs=pl.BlockSpec((1, 1, qb, d),
                                   lambda bh, qi, ki, lens: (bh // Hq, bh % Hq, qi, 0)),
            scratch_shapes=[
                pltpu.VMEM((qb, 1), jnp.float32),
                pltpu.VMEM((qb, 1), jnp.float32),
                pltpu.VMEM((qb, d), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((B, Hq, Sqp, d), v.dtype),
        interpret=interpret,
        **compat.compiler_params_kwargs(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
    )(lens, q, k, v)
    return out[:, :, :Sq]


def _kv_index(Hq: int, Hkv: int, qb: int, kb: int, causal: bool):
    G = Hq // Hkv
    def index(bh, qi, ki, lens):
        b, h = bh // Hq, (bh % Hq) // G
        if causal:
            # clamp dead blocks (k_start > q_end) back to block 0
            last_live = ((qi + 1) * qb - 1) // kb
            ki = jnp.minimum(ki, last_live)
        return (b, h, ki, 0)
    return index
