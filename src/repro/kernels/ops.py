"""Backend dispatch for the Pallas kernels.

On TPU the real kernels run; everywhere else (this CPU container, unit
tests) they execute in Pallas interpret mode or fall back to the
pure-jnp reference — same semantics either way, asserted by the kernel
sweep tests.
"""
from __future__ import annotations

import functools

import jax

from repro.kernels import ref
from repro.kernels.decode_attention import decode_attention as _decode_pallas
from repro.kernels.flash_attention import flash_attention as _flash_pallas
from repro.kernels.sliced_matmul import sliced_matmul as _sliced_pallas
from repro.kernels.subnet_rmsnorm import subnet_rmsnorm as _rmsnorm_pallas


@functools.lru_cache(maxsize=1)
def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def flash_attention(q, k, v, *, causal=True, window=0, kv_len=None,
                    q_block=256, kv_block=256, interpret=None):
    interp = (not on_tpu()) if interpret is None else interpret
    return _flash_pallas(q, k, v, causal=causal, window=window, kv_len=kv_len,
                         q_block=q_block, kv_block=kv_block, interpret=interp)


def decode_attention(q, k_cache, v_cache, index, *, window=0, kv_block=256,
                     interpret=None):
    interp = (not on_tpu()) if interpret is None else interpret
    return _decode_pallas(q, k_cache, v_cache, index, window=window,
                          kv_block=kv_block, interpret=interp)


def sliced_matmul(x, w, active_in, active_out, *, bm=128, bk=128, bn=128,
                  interpret=None):
    interp = (not on_tpu()) if interpret is None else interpret
    return _sliced_pallas(x, w, active_in, active_out, bm=bm, bk=bk, bn=bn,
                          interpret=interp)


def subnet_rmsnorm(x, gamma_table, subnet_id, *, eps=1e-5, interpret=None):
    interp = (not on_tpu()) if interpret is None else interpret
    return _rmsnorm_pallas(x, gamma_table, subnet_id, eps=eps, interpret=interp)


# references re-exported for tests
flash_attention_ref = ref.flash_attention_ref
decode_attention_ref = ref.decode_attention_ref
sliced_matmul_ref = ref.sliced_matmul_ref
subnet_rmsnorm_ref = ref.subnet_rmsnorm_ref
