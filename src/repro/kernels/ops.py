"""Public kernel entry points, routed through the four-tier dispatcher.

Every kernel resolves to one of the tiers registered in
:mod:`repro.kernels.dispatch` — ``tpu`` (compiled Pallas),
``pallas-triton`` (backend-agnostic Pallas lowered through Triton on
GPU), ``interpret`` (Pallas interpreter; CPU numerics validation),
``ref`` (pure-jnp from :mod:`repro.kernels.ref`, block-skipping for the
attention kernels). The process default comes from
:func:`repro.compat.kernel_tier`; per-call overrides take ``tier=`` (or
the legacy ``interpret=`` bool, mapped to ``interpret``/``tpu``).

The Pallas implementations are only imported when the corresponding
Pallas module itself imports — on a JAX build without it, every kernel
still works at the ``ref`` tier.
"""
from __future__ import annotations

from repro import compat
from repro.kernels import ref
from repro.kernels.dispatch import (DISPATCHER, coerce_tier, model_tier,
                                    register)

if compat.HAS_PALLAS_TPU:
    from repro.kernels.decode_attention import decode_attention as _decode_pallas
    from repro.kernels.flash_attention import flash_attention as _flash_pallas
    from repro.kernels.sliced_matmul import sliced_matmul as _sliced_pallas
    from repro.kernels.subnet_rmsnorm import subnet_rmsnorm as _rmsnorm_pallas

    @register("flash_attention", "tpu")
    def _flash_tpu(q, k, v, *, causal, window, kv_len, q_block, kv_block):
        return _flash_pallas(q, k, v, causal=causal, window=window,
                             kv_len=kv_len, q_block=q_block,
                             kv_block=kv_block, interpret=False)

    @register("flash_attention", "interpret")
    def _flash_interpret(q, k, v, *, causal, window, kv_len, q_block, kv_block):
        return _flash_pallas(q, k, v, causal=causal, window=window,
                             kv_len=kv_len, q_block=q_block,
                             kv_block=kv_block, interpret=True)

    @register("decode_attention", "tpu")
    def _decode_tpu(q, k_cache, v_cache, index, *, window, kv_block):
        return _decode_pallas(q, k_cache, v_cache, index, window=window,
                              kv_block=kv_block, interpret=False)

    @register("decode_attention", "interpret")
    def _decode_interpret(q, k_cache, v_cache, index, *, window, kv_block):
        return _decode_pallas(q, k_cache, v_cache, index, window=window,
                              kv_block=kv_block, interpret=True)

    @register("sliced_matmul", "tpu")
    def _sliced_tpu(x, w, active_in, active_out, *, bm, bk, bn):
        return _sliced_pallas(x, w, active_in, active_out, bm=bm, bk=bk,
                              bn=bn, interpret=False)

    @register("sliced_matmul", "interpret")
    def _sliced_interpret(x, w, active_in, active_out, *, bm, bk, bn):
        return _sliced_pallas(x, w, active_in, active_out, bm=bm, bk=bk,
                              bn=bn, interpret=True)

    @register("subnet_rmsnorm", "tpu")
    def _rmsnorm_tpu(x, gamma_table, subnet_id, *, eps):
        return _rmsnorm_pallas(x, gamma_table, subnet_id, eps=eps,
                               interpret=False)

    @register("subnet_rmsnorm", "interpret")
    def _rmsnorm_interpret(x, gamma_table, subnet_id, *, eps):
        return _rmsnorm_pallas(x, gamma_table, subnet_id, eps=eps,
                               interpret=True)


if compat.HAS_PALLAS_TRITON and compat.HAS_PALLAS:
    from repro.kernels import triton_kernels as _triton

    @register("flash_attention", "pallas-triton")
    def _flash_triton(q, k, v, *, causal, window, kv_len, q_block, kv_block):
        return _triton.flash_attention(q, k, v, causal=causal, window=window,
                                       kv_len=kv_len, q_block=q_block,
                                       kv_block=kv_block)

    @register("sliced_matmul", "pallas-triton")
    def _sliced_triton(x, w, active_in, active_out, *, bm, bk, bn):
        return _triton.sliced_matmul(x, w, active_in, active_out, bm=bm,
                                     bk=bk, bn=bn)

    @register("subnet_rmsnorm", "pallas-triton")
    def _rmsnorm_triton(x, gamma_table, subnet_id, *, eps):
        return _triton.subnet_rmsnorm(x, gamma_table, subnet_id, eps=eps)


@register("flash_attention", "ref")
def _flash_ref(q, k, v, *, causal, window, kv_len, q_block=256, kv_block=256):
    return ref.flash_attention_ref(q, k, v, causal=causal, window=window,
                                   kv_len=kv_len, q_block=q_block,
                                   kv_block=kv_block)


@register("decode_attention", "ref")
def _decode_ref(q, k_cache, v_cache, index, *, window, kv_block=256):
    return ref.decode_attention_ref(q, k_cache, v_cache, index,
                                    window=window, kv_block=kv_block)


@register("sliced_matmul", "ref")
def _sliced_ref(x, w, active_in, active_out, *, bm=0, bk=0, bn=0):
    orig_shape = x.shape
    y = ref.sliced_matmul_ref(x.reshape(-1, x.shape[-1]), w,
                              active_in, active_out)
    return y.reshape(*orig_shape[:-1], w.shape[1])


@register("subnet_rmsnorm", "ref")
def _rmsnorm_ref(x, gamma_table, subnet_id, *, eps):
    return ref.subnet_rmsnorm_ref(x, gamma_table, subnet_id, eps=eps)


# --------------------------------------------------------------------------
# public entry points
# --------------------------------------------------------------------------


def flash_attention(q, k, v, *, causal=True, window=0, kv_len=None,
                    q_block=256, kv_block=256, tier=None, interpret=None):
    return DISPATCHER.call(
        "flash_attention", q, k, v, causal=causal, window=window,
        kv_len=kv_len, q_block=q_block, kv_block=kv_block,
        tier=coerce_tier(tier, interpret))


def decode_attention(q, k_cache, v_cache, index, *, window=0, kv_block=256,
                     tier=None, interpret=None):
    return DISPATCHER.call(
        "decode_attention", q, k_cache, v_cache, index, window=window,
        kv_block=kv_block, tier=coerce_tier(tier, interpret))


def sliced_matmul(x, w, active_in, active_out, *, bm=128, bk=128, bn=128,
                  tier=None, interpret=None):
    return DISPATCHER.call(
        "sliced_matmul", x, w, active_in, active_out, bm=bm, bk=bk, bn=bn,
        tier=coerce_tier(tier, interpret))


def subnet_rmsnorm(x, gamma_table, subnet_id, *, eps=1e-5, tier=None,
                   interpret=None):
    return DISPATCHER.call(
        "subnet_rmsnorm", x, gamma_table, subnet_id, eps=eps,
        tier=coerce_tier(tier, interpret))


# --------------------------------------------------------------------------
# model-grade impls (the wiring used by models/attention + backbone)
# --------------------------------------------------------------------------


def _tier_registered(name: str, tier: str) -> bool:
    return tier in DISPATCHER.registered_tiers(name)


def model_flash_attention(q, k, v, *, causal=True, window=0, q_offset=0,
                          kv_len=None, q_block=512, kv_block=512, scale=None):
    """Full-sequence attention for model forward passes.

    Pallas kernel (TPU or pallas-triton) when the model tier says so;
    the block-skipping XLA path from :mod:`repro.models.attention`
    otherwise (same math, asserted equal by the kernel tests). The
    Pallas kernels do not take ``q_offset``/``scale`` — calls using
    them route to the XLA path on every tier rather than silently
    dropping the arguments. ``q_block``/``kv_block`` plumb through to
    whichever tier serves the call.
    """
    tier = model_tier()
    pallas_ok = isinstance(q_offset, int) and q_offset == 0 and scale is None
    if pallas_ok and tier != "ref" and _tier_registered("flash_attention",
                                                        tier):
        return flash_attention(q, k, v, causal=causal, window=window,
                               kv_len=kv_len, q_block=q_block,
                               kv_block=kv_block, tier=tier)
    from repro.models.attention import flash_attention as xla_flash
    return xla_flash(q, k, v, causal=causal, window=window, q_offset=q_offset,
                     kv_len=kv_len, q_block=q_block, kv_block=kv_block,
                     scale=scale)


def model_decode_attention(q, k_cache, v_cache, *, index, window=0,
                           kv_block=512):
    """Single-token cached decode for model decode steps.

    ``pallas-triton`` registers no decode kernel (the GPU tier covers
    the three hot prefill-path kernels); a tier with no registration
    falls to the XLA path rather than erroring.
    """
    tier = model_tier()
    if tier != "ref" and _tier_registered("decode_attention", tier):
        return decode_attention(q, k_cache, v_cache, index, window=window,
                                kv_block=kv_block, tier=tier)
    from repro.models.attention import decode_attention as xla_decode
    return xla_decode(q, k_cache, v_cache, index=index, window=window)


def model_subnet_rmsnorm(x, gamma_table, subnet_id, *, eps=1e-5):
    """SubnetNorm (RMS flavor) for model blocks; None = use XLA path."""
    tier = model_tier()
    if tier != "ref" and _tier_registered("subnet_rmsnorm", tier):
        return subnet_rmsnorm(x, gamma_table, subnet_id, eps=eps, tier=tier)
    return None


# references re-exported for tests (the *_dense_ref pair are the
# mathematical oracles; the plain *_ref pair block-skip)
flash_attention_ref = ref.flash_attention_ref
flash_attention_dense_ref = ref.flash_attention_dense_ref
decode_attention_ref = ref.decode_attention_ref
decode_attention_dense_ref = ref.decode_attention_dense_ref
sliced_matmul_ref = ref.sliced_matmul_ref
subnet_rmsnorm_ref = ref.subnet_rmsnorm_ref
