"""Pure-jnp implementations of every Pallas kernel.

Two grades live here:

* ``*_dense_ref`` — the *mathematical* oracles: no tiling, no online
  accumulation, O(S^2) memory where applicable. Kernel sweeps
  assert_allclose against these independent implementations.
* ``flash_attention_ref`` / ``decode_attention_ref`` — the served
  ``ref``-tier implementations: kv-block-chunked online-softmax loops
  that *skip* causally-dead and out-of-window blocks entirely, the same
  block-liveness logic as the Pallas kernel in
  :mod:`repro.kernels.flash_attention`. This is the tier CPU CI and
  every non-accelerator user runs, so it must not pay for masked work:
  at long causal sequence lengths the skipping version does ~half the
  FLOPs of the dense oracle (and a window-sized fraction with sliding
  windows). Numerics agreement with the dense oracles is pinned by
  tests/test_dispatch.py (hypothesis) and gated in
  benchmarks/bench_hotpath.py.

``sliced_matmul_ref`` and ``subnet_rmsnorm_ref`` have no dead work to
skip (the matmul masks by traced widths); they stay single-grade.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

NEG_INF = -1e30


def sliced_matmul_ref(x, w, active_in: int, active_out: int):
    """y = x[..., :k_in] @ w[:k_in, :k_out], zero-padded to w.shape[1].

    WeightSlice semantics: channels beyond the active widths contribute
    nothing and produce nothing."""
    K, N = w.shape
    ki = jnp.minimum(active_in, K)
    ko = jnp.minimum(active_out, N)
    xm = x * (jnp.arange(K) < ki).astype(x.dtype)
    y = jnp.matmul(xm.astype(jnp.float32), w.astype(jnp.float32))
    return (y * (jnp.arange(N) < ko).astype(y.dtype)).astype(x.dtype)


def flash_attention_dense_ref(q, k, v, *, causal: bool = True,
                              window: int = 0, kv_len=None, scale=None):
    """Full-softmax attention oracle. q: (B,Hq,Sq,d); k/v: (B,Hkv,Sk,d).

    Materializes the dense Sq x Sk score matrix — ground truth only."""
    B, Hq, Sq, d = q.shape
    _, Hkv, Sk, _ = k.shape
    G = Hq // Hkv
    scale = scale if scale is not None else d ** -0.5
    qf = q.reshape(B, Hkv, G, Sq, d).astype(jnp.float32)
    s = jnp.einsum("bhgqd,bhkd->bhgqk", qf, k.astype(jnp.float32)) * scale
    q_pos = jnp.arange(Sq)
    k_pos = jnp.arange(Sk)
    mask = jnp.ones((Sq, Sk), bool)
    if kv_len is not None:
        mask &= k_pos[None, :] < kv_len
    if causal:
        mask &= k_pos[None, :] <= q_pos[:, None]
    if window:
        mask &= k_pos[None, :] > q_pos[:, None] - window
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    # rows with no valid key attend to nothing (match kernel semantics)
    p = p * mask.any(-1)[None, None, None, :, None]
    o = jnp.einsum("bhgqk,bhkd->bhgqd", p, v.astype(jnp.float32))
    return o.reshape(B, Hq, Sq, d).astype(v.dtype)


def _live_kv_range(q0: int, q1: int, n_k: int, kb: int, causal: bool,
                   window: int, static_kv_len) -> tuple:
    """Static [lo, hi) kv-block range live for q rows [q0, q1).

    Mirrors the Pallas kernel's block liveness: a kv block is dead when
    its first key is past the causal frontier of the *last* q row, or
    its last key is below the window floor of the *first* q row. A
    Python-int ``kv_len`` additionally clamps the top; a traced one is
    handled by the per-element mask instead.
    """
    lo, hi = 0, n_k
    if causal:
        hi = min(hi, (q1 - 1) // kb + 1)
    if window:
        lo = max(lo, (q0 - window + 1) // kb)
    if isinstance(static_kv_len, int):
        hi = min(hi, -(-static_kv_len // kb))
    return lo, hi


def flash_attention_ref(q, k, v, *, causal: bool = True, window: int = 0,
                        kv_len=None, scale=None, q_block: int = 256,
                        kv_block: int = 256):
    """Block-skipping online-softmax attention (the served ref tier).

    Same signature/semantics as :func:`flash_attention_dense_ref` plus
    the chunk sizes; O(q_block * kv_block) score memory. Dead blocks
    contribute exactly zero mass in the dense formulation, so skipping
    them is numerics-preserving up to fp32 accumulation order.
    """
    B, Hq, Sq, d = q.shape
    _, Hkv, Sk, _ = k.shape
    G = Hq // Hkv
    scale = scale if scale is not None else d ** -0.5
    qb = min(q_block, Sq) if q_block else Sq     # 0 = one block (dense)
    kb = min(kv_block, Sk) if kv_block else Sk
    n_q, n_k = -(-Sq // qb), -(-Sk // kb)

    qf = q.reshape(B, Hkv, G, Sq, d).astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    valid_k = None if kv_len is None else jnp.asarray(kv_len, jnp.int32)

    outs = []
    for qi in range(n_q):
        q0, q1 = qi * qb, min((qi + 1) * qb, Sq)
        qblk = qf[:, :, :, q0:q1]
        q_pos = q0 + jnp.arange(q1 - q0)
        lo, hi = _live_kv_range(q0, q1, n_k, kb, causal, window, kv_len)
        m = jnp.full((B, Hkv, G, q1 - q0), NEG_INF, jnp.float32)
        l = jnp.zeros((B, Hkv, G, q1 - q0), jnp.float32)
        acc = jnp.zeros((B, Hkv, G, q1 - q0, d), jnp.float32)
        for ki in range(lo, hi):
            k0, k1 = ki * kb, min((ki + 1) * kb, Sk)
            s = jnp.einsum("bhgqd,bhkd->bhgqk", qblk,
                           kf[:, :, k0:k1]) * scale
            k_pos = k0 + jnp.arange(k1 - k0)
            mask = jnp.ones((q1 - q0, k1 - k0), bool)
            if valid_k is not None:
                mask &= k_pos[None, :] < valid_k
            if causal:
                mask &= k_pos[None, :] <= q_pos[:, None]
            if window:
                mask &= k_pos[None, :] > q_pos[:, None] - window
            s = jnp.where(mask[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(-1))
            # mask again: a fully-dead row has s == m_new == NEG_INF and
            # would otherwise get exp(0) = 1 (the kernel does the same)
            p = jnp.exp(s - m_new[..., None]) * mask[None, None, None]
            corr = jnp.exp(m - m_new)
            l = l * corr + p.sum(-1)
            acc = acc * corr[..., None] + jnp.einsum(
                "bhgqk,bhkd->bhgqd", p, vf[:, :, k0:k1])
            m = m_new
        outs.append(acc / jnp.maximum(l, 1e-30)[..., None])
    o = jnp.concatenate(outs, axis=3)
    return o.reshape(B, Hq, Sq, d).astype(v.dtype)


def decode_attention_dense_ref(q, k_cache, v_cache, index, *,
                               window: int = 0):
    """Single-token attention oracle over the whole cache. q: (B,Hq,1,d);
    caches: (B,Hkv,Smax,d); index = current absolute position."""
    B, Hq, _, d = q.shape
    _, Hkv, Smax, _ = k_cache.shape
    G = Hq // Hkv
    qf = q.reshape(B, Hkv, G, d).astype(jnp.float32)
    s = jnp.einsum("bhgd,bhkd->bhgk", qf, k_cache.astype(jnp.float32)) * d ** -0.5
    pos = jnp.arange(Smax)
    if window:
        age = (index - pos) % Smax
        mask = age < jnp.minimum(window, index + 1)
    else:
        mask = pos <= index
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgk,bhkd->bhgd", p, v_cache.astype(jnp.float32))
    return o.reshape(B, Hq, 1, d).astype(v_cache.dtype)


def decode_attention_ref(q, k_cache, v_cache, index, *, window: int = 0,
                         kv_block: int = 256):
    """Block-skipping cached decode (the served ref tier).

    With ``window == 0`` only positions ``<= index`` are live, so the
    scan covers the shortest static power-of-two-of-``kv_block`` cache
    prefix containing ``index`` (a ``lax.switch`` over dense branches)
    instead of all of Smax — early decode steps stop paying for the
    whole cache, while a full cache costs exactly the dense path. A
    sequential per-block online-softmax loop (the Pallas kernel's shape)
    loses to XLA's single fused contraction on CPU, which is why the
    live *prefix* stays one dense einsum per branch here. Rolling-window
    caches (``window > 0``) are already sized to the window by the model
    layer, and their live set wraps around the buffer, so they use the
    dense path: there is nothing contiguous to skip.
    """
    B, Hq, _, d = q.shape
    _, Hkv, Smax, _ = k_cache.shape
    kb = min(kv_block, Smax) if kv_block else Smax
    if window or kb >= Smax:
        return decode_attention_dense_ref(q, k_cache, v_cache, index,
                                          window=window)
    G = Hq // Hkv
    qf = q.reshape(B, Hkv, G, d).astype(jnp.float32)
    idx = jnp.asarray(index, jnp.int32)

    lengths = []
    L = kb
    while L < Smax:
        lengths.append(L)
        L *= 2
    lengths.append(Smax)

    def branch(L: int):
        def go():
            kc = k_cache[:, :, :L].astype(jnp.float32)
            vc = v_cache[:, :, :L].astype(jnp.float32)
            s = jnp.einsum("bhgd,bhkd->bhgk", qf, kc) * d ** -0.5
            mask = jnp.arange(L) <= idx
            s = jnp.where(mask[None, None, None], s, NEG_INF)
            p = jax.nn.softmax(s, axis=-1)
            return jnp.einsum("bhgk,bhkd->bhgd", p, vc)
        return go

    # smallest prefix with L > index: count the lengths it overflows
    b = sum((idx >= L).astype(jnp.int32) for L in lengths[:-1])
    o = lax.switch(b, [branch(L) for L in lengths])
    return o.reshape(B, Hq, 1, d).astype(v_cache.dtype)


def subnet_rmsnorm_ref(x, gamma_table, subnet_id, eps: float = 1e-5):
    """RMSNorm with the per-subnet gain row (SubnetNorm)."""
    gamma = gamma_table[subnet_id]
    xf = x.astype(jnp.float32)
    y = xf * jax.lax.rsqrt(jnp.mean(xf * xf, -1, keepdims=True) + eps)
    return (y * gamma).astype(x.dtype)
