"""Pure-jnp oracles for every Pallas kernel (the tests' ground truth).

Each function computes the *mathematical* result with no tiling or
online accumulation — O(S^2) memory where applicable — so kernel sweeps
can assert_allclose against an independent implementation.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def sliced_matmul_ref(x, w, active_in: int, active_out: int):
    """y = x[..., :k_in] @ w[:k_in, :k_out], zero-padded to w.shape[1].

    WeightSlice semantics: channels beyond the active widths contribute
    nothing and produce nothing."""
    K, N = w.shape
    ki = jnp.minimum(active_in, K)
    ko = jnp.minimum(active_out, N)
    xm = x * (jnp.arange(K) < ki).astype(x.dtype)
    y = jnp.matmul(xm.astype(jnp.float32), w.astype(jnp.float32))
    return (y * (jnp.arange(N) < ko).astype(y.dtype)).astype(x.dtype)


def flash_attention_ref(q, k, v, *, causal: bool = True, window: int = 0,
                        kv_len=None, scale=None):
    """Full-softmax attention. q: (B,Hq,Sq,d); k/v: (B,Hkv,Sk,d)."""
    B, Hq, Sq, d = q.shape
    _, Hkv, Sk, _ = k.shape
    G = Hq // Hkv
    scale = scale if scale is not None else d ** -0.5
    qf = q.reshape(B, Hkv, G, Sq, d).astype(jnp.float32)
    s = jnp.einsum("bhgqd,bhkd->bhgqk", qf, k.astype(jnp.float32)) * scale
    q_pos = jnp.arange(Sq)
    k_pos = jnp.arange(Sk)
    mask = jnp.ones((Sq, Sk), bool)
    if kv_len is not None:
        mask &= k_pos[None, :] < kv_len
    if causal:
        mask &= k_pos[None, :] <= q_pos[:, None]
    if window:
        mask &= k_pos[None, :] > q_pos[:, None] - window
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    # rows with no valid key attend to nothing (match kernel semantics)
    p = p * mask.any(-1)[None, None, None, :, None]
    o = jnp.einsum("bhgqk,bhkd->bhgqd", p, v.astype(jnp.float32))
    return o.reshape(B, Hq, Sq, d).astype(v.dtype)


def decode_attention_ref(q, k_cache, v_cache, index, *, window: int = 0):
    """Single-token attention over a cache. q: (B,Hq,1,d);
    caches: (B,Hkv,Smax,d); index = current absolute position."""
    B, Hq, _, d = q.shape
    _, Hkv, Smax, _ = k_cache.shape
    G = Hq // Hkv
    qf = q.reshape(B, Hkv, G, d).astype(jnp.float32)
    s = jnp.einsum("bhgd,bhkd->bhgk", qf, k_cache.astype(jnp.float32)) * d ** -0.5
    pos = jnp.arange(Smax)
    if window:
        age = (index - pos) % Smax
        mask = age < jnp.minimum(window, index + 1)
    else:
        mask = pos <= index
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgk,bhkd->bhgd", p, v_cache.astype(jnp.float32))
    return o.reshape(B, Hq, 1, d).astype(v_cache.dtype)


def subnet_rmsnorm_ref(x, gamma_table, subnet_id, eps: float = 1e-5):
    """RMSNorm with the per-subnet gain row (SubnetNorm)."""
    gamma = gamma_table[subnet_id]
    xf = x.astype(jnp.float32)
    y = xf * jax.lax.rsqrt(jnp.mean(xf * xf, -1, keepdims=True) + eps)
    return (y * gamma).astype(x.dtype)
