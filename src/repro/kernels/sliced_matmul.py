"""WeightSlice Pallas TPU kernel: matmul over the *active prefix* of the
contraction and output dimensions.

The SubNetAct insight at kernel level: the active widths arrive as
scalar-prefetch values, the grid's index_map routes inactive K/N blocks
back to block 0 (no fresh DMA) and ``pl.when`` skips their compute —
so a half-width subnet costs ~half the MXU work and ~half the HBM->VMEM
traffic of the full supernet layer, with zero weight movement and zero
recompilation on actuation.

Block sizes are MXU-aligned (multiples of 128 lanes / 8 sublanes).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import compat


def _kernel(nact_ref, x_ref, w_ref, o_ref, acc_ref, *, bk: int, nk: int):
    """Grid: (m, n, k). nact_ref holds (k_blocks_active, n_blocks_active)."""
    mi, ni, ki = pl.program_id(0), pl.program_id(1), pl.program_id(2)
    k_act, n_act = nact_ref[0], nact_ref[1]

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(jnp.logical_and(ki < k_act, ni < n_act))
    def _compute():
        # Partial K block: mask trailing channels of the boundary block.
        x = x_ref[...]
        w = w_ref[...]
        acc_ref[...] += jnp.dot(x.astype(jnp.float32), w.astype(jnp.float32),
                                preferred_element_type=jnp.float32)

    @pl.when(ki == nk - 1)
    def _emit():
        o_ref[...] = jnp.where(ni < n_act, acc_ref[...], 0.0).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bk", "bn", "interpret"))
def sliced_matmul(x, w, active_in, active_out, *, bm: int = 128, bk: int = 128,
                  bn: int = 128, interpret: bool = False):
    """y[..., :active_out] = x[..., :active_in] @ w[:active_in, :active_out].

    ``active_in``/``active_out`` are traced int32 scalars (the WeightSlice
    control inputs). Widths are rounded up to block granularity — the
    core/subnet.py control lowering aligns widths to 128, so blocks are
    exact for every real subnet.
    """
    orig_shape = x.shape
    M = 1
    for s in orig_shape[:-1]:
        M *= s
    K = x.shape[-1]
    N = w.shape[1]
    x2 = x.reshape(M, K)

    pm, pk, pn = (-M) % bm, (-K) % bk, (-N) % bn
    if pm or pk:
        x2 = jnp.pad(x2, ((0, pm), (0, pk)))
    wp = jnp.pad(w, ((0, pk), (0, pn))) if (pk or pn) else w
    Mp, Kp, Np = x2.shape[0], x2.shape[1], wp.shape[1]
    nk = Kp // bk

    # zero channels of x beyond active_in so a partial boundary block
    # contributes nothing (then whole blocks beyond it are skipped)
    x2 = x2 * (lax.iota(jnp.int32, Kp)[None, :] < active_in).astype(x2.dtype)

    nact = jnp.stack([
        lax.div(active_in + bk - 1, bk).astype(jnp.int32),
        lax.div(active_out + bn - 1, bn).astype(jnp.int32),
    ])

    grid = (Mp // bm, Np // bn, nk)
    out = pl.pallas_call(
        functools.partial(_kernel, bk=bk, nk=nk),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                # inactive K blocks re-map to block 0: no fresh DMA
                pl.BlockSpec((bm, bk),
                             lambda m, n, k, nact: (m, jnp.minimum(k, nact[0] - 1))),
                pl.BlockSpec((bk, bn),
                             lambda m, n, k, nact: (jnp.minimum(k, nact[0] - 1),
                                                    jnp.minimum(n, nact[1] - 1))),
            ],
            out_specs=pl.BlockSpec((bm, bn), lambda m, n, k, nact: (m, n)),
            scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        ),
        out_shape=jax.ShapeDtypeStruct((Mp, Np), x.dtype),
        interpret=interpret,
        **compat.compiler_params_kwargs(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
    )(nact, x2, wp)
    out = out[:M, :N]
    # mask the partial boundary block of the output dimension
    out = out * (lax.iota(jnp.int32, N)[None, :] < active_out).astype(out.dtype)
    return out.reshape(*orig_shape[:-1], N)
