"""SubnetNorm as a Pallas TPU kernel: RMSNorm whose gain row is fetched
from the per-subnet table by a scalar-prefetched ``subnet_id``.

This is SubNetAct's actuation cost made explicit at the kernel level:
switching subnets changes *one scalar*, which re-routes a single (1, d)
DMA — no weight movement, no recompilation, < 1 microsecond of extra
traffic (paper Fig 5b's "near-instantaneous actuation").
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(sid_ref, x_ref, g_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    o_ref[...] = (y * g_ref[0].astype(jnp.float32)[None, :]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "eps", "interpret"))
def subnet_rmsnorm(x, gamma_table, subnet_id, *, bm: int = 256,
                   eps: float = 1e-5, interpret: bool = False):
    """x: (..., d); gamma_table: (n_subnets, d); subnet_id: traced int32."""
    orig_shape = x.shape
    d = x.shape[-1]
    M = 1
    for s in orig_shape[:-1]:
        M *= s
    x2 = x.reshape(M, d)
    bm_eff = min(bm, M)
    pm = (-M) % bm_eff
    if pm:
        x2 = jnp.pad(x2, ((0, pm), (0, 0)))
    sid = jnp.asarray(subnet_id, jnp.int32).reshape(1)

    out = pl.pallas_call(
        functools.partial(_kernel, eps=eps),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=((M + pm) // bm_eff,),
            in_specs=[
                pl.BlockSpec((bm_eff, d), lambda i, sid: (i, 0)),
                # the actuation: subnet_id routes the gain-row DMA
                pl.BlockSpec((1, d), lambda i, sid: (sid[0], 0)),
            ],
            out_specs=pl.BlockSpec((bm_eff, d), lambda i, sid: (i, 0)),
        ),
        out_shape=jax.ShapeDtypeStruct((M + pm, d), x.dtype),
        interpret=interpret,
    )(sid, x2, gamma_table)
    return out[:M].reshape(orig_shape)
