"""GPU (``pallas-triton``) tier kernels: flash attention, sliced
matmul, subnet RMSNorm.

Unlike the TPU kernels these use only backend-agnostic Pallas surfaces
(plain ``pl.BlockSpec`` grids, ``pl.load`` with dynamic slices, carried
``fori_loop`` accumulators — no ``pltpu`` grid specs or VMEM scratch),
so the very same kernel bodies compile through the Triton lowering on a
GPU backend *and* run under the Pallas interpreter on CPU, which is how
CI validates their numerics without a GPU (tests/test_dispatch.py).

Scalars that steer the TPU kernels via scalar prefetch (valid kv
length, active widths, subnet id) arrive here as tiny array inputs with
a grid-invariant BlockSpec — the GPU pipeline has no scalar-prefetch
lane, but a (1,)-int32 load per program is free.

Block-liveness mirrors :mod:`repro.kernels.flash_attention`: the kv
loop of each q block runs only over blocks inside the causal frontier
and the sliding window, so prefill cost tracks the ~S^2/2 causal
triangle (and the O(S * window) band with windows) rather than S^2.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

from repro import compat

NEG_INF = -1e30


# --------------------------------------------------------------------------
# flash attention (prefill)
# --------------------------------------------------------------------------


def _flash_kernel(lens_ref, q_ref, k_ref, v_ref, o_ref, *, qb: int, kb: int,
                  nk: int, causal: bool, window: int, scale: float):
    qi = pl.program_id(1)
    kv_len = lens_ref[0]
    q = q_ref[0, 0].astype(jnp.float32)                     # (qb, d)
    q_pos = qi * qb + jnp.arange(qb, dtype=jnp.int32)

    # live kv-block range for this q block (the Pallas-TPU liveness
    # logic, computed per-program since program ids are traced here)
    lo = jnp.int32(0)
    hi = jnp.int32(nk)
    hi = jnp.minimum(hi, lax.div(kv_len + kb - 1, kb))
    if causal:
        hi = jnp.minimum(hi, lax.div(q_pos[-1], kb) + 1)
    if window:
        lo = jnp.maximum(lo, lax.div(q_pos[0] - window + 1, kb))

    def body(ki, carry):
        m, l, acc = carry
        k0 = ki * kb
        # int32 leading indexers, not python ints: the interpret-mode
        # discharge rule only accepts traced scalars or slices
        zero = jnp.int32(0)
        kblk = pl.load(k_ref, (zero, zero, pl.dslice(k0, kb),
                               slice(None))).astype(jnp.float32)
        vblk = pl.load(v_ref, (zero, zero, pl.dslice(k0, kb),
                               slice(None))).astype(jnp.float32)
        s = jnp.dot(q, kblk.T, preferred_element_type=jnp.float32) * scale
        k_pos = k0 + jnp.arange(kb, dtype=jnp.int32)
        mask = k_pos[None, :] < kv_len
        if causal:
            mask &= k_pos[None, :] <= q_pos[:, None]
        if window:
            mask &= k_pos[None, :] > q_pos[:, None] - window
        s = jnp.where(mask, s, NEG_INF)
        m_new = jnp.maximum(m, s.max(-1))
        p = jnp.exp(s - m_new[:, None]) * mask   # fully-dead rows -> 0
        corr = jnp.exp(m - m_new)
        l = l * corr + p.sum(-1)
        acc = acc * corr[:, None] + jnp.dot(
            p, vblk, preferred_element_type=jnp.float32)
        return m_new, l, acc

    d = q.shape[-1]
    m0 = jnp.full((qb,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((qb,), jnp.float32)
    a0 = jnp.zeros((qb, d), jnp.float32)
    m, l, acc = lax.fori_loop(lo, hi, body, (m0, l0, a0))
    o_ref[0, 0] = (acc / jnp.maximum(l, 1e-30)[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "q_block", "kv_block", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    kv_len=None, q_block: int = 128, kv_block: int = 128,
                    scale=None, interpret: bool = False):
    """q: (B, Hq, Sq, d); k/v: (B, Hkv, Sk, d) -> (B, Hq, Sq, d)."""
    B, Hq, Sq, d = q.shape
    _, Hkv, Sk, _ = k.shape
    G = Hq // Hkv
    scale = float(scale if scale is not None else d ** -0.5)

    qb, kb = min(q_block, Sq), min(kv_block, Sk)
    pq, pk = (-Sq) % qb, (-Sk) % kb
    if pq:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pq), (0, 0)))
    if pk:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pk), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pk), (0, 0)))
    Sqp, Skp = Sq + pq, Sk + pk
    nq, nk = Sqp // qb, Skp // kb

    lens = jnp.array([Sk if kv_len is None else kv_len], jnp.int32)

    out = pl.pallas_call(
        functools.partial(_flash_kernel, qb=qb, kb=kb, nk=nk, causal=causal,
                          window=window, scale=scale),
        grid=(B * Hq, nq),
        in_specs=[
            pl.BlockSpec((1,), lambda bh, qi: (0,)),
            pl.BlockSpec((1, 1, qb, d),
                         lambda bh, qi: (bh // Hq, bh % Hq, qi, 0)),
            pl.BlockSpec((1, 1, Skp, d),
                         lambda bh, qi: (bh // Hq, (bh % Hq) // G, 0, 0)),
            pl.BlockSpec((1, 1, Skp, d),
                         lambda bh, qi: (bh // Hq, (bh % Hq) // G, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, qb, d),
                               lambda bh, qi: (bh // Hq, bh % Hq, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hq, Sqp, d), v.dtype),
        interpret=interpret,
        **({} if interpret else
           compat.triton_compiler_params_kwargs(num_warps=4, num_stages=2)),
    )(lens, q, k, v)
    return out[:, :, :Sq]


# --------------------------------------------------------------------------
# sliced matmul (WeightSlice)
# --------------------------------------------------------------------------


def _sliced_kernel(nact_ref, x_ref, w_ref, o_ref, *, bk: int):
    ni = pl.program_id(1)
    k_act, n_act = nact_ref[0], nact_ref[1]
    bm, bn = o_ref.shape

    def body(ki, acc):
        xb = pl.load(x_ref, (slice(None),
                             pl.dslice(ki * bk, bk))).astype(jnp.float32)
        wb = pl.load(w_ref, (pl.dslice(ki * bk, bk),
                             slice(None))).astype(jnp.float32)
        return acc + jnp.dot(xb, wb, preferred_element_type=jnp.float32)

    # inactive N blocks skip the whole K loop, not just the store
    hi = jnp.where(ni < n_act, k_act, 0)
    acc = lax.fori_loop(0, hi, body, jnp.zeros((bm, bn), jnp.float32))
    o_ref[...] = jnp.where(ni < n_act, acc, 0.0).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bk", "bn", "interpret"))
def sliced_matmul(x, w, active_in, active_out, *, bm: int = 64, bk: int = 64,
                  bn: int = 64, interpret: bool = False):
    """y[..., :active_out] = x[..., :active_in] @ w[:active_in, :active_out]."""
    orig_shape = x.shape
    M = 1
    for s in orig_shape[:-1]:
        M *= s
    K = x.shape[-1]
    N = w.shape[1]
    x2 = x.reshape(M, K)

    pm, pk, pn = (-M) % bm, (-K) % bk, (-N) % bn
    if pm or pk:
        x2 = jnp.pad(x2, ((0, pm), (0, pk)))
    wp = jnp.pad(w, ((0, pk), (0, pn))) if (pk or pn) else w
    Mp, Kp, Np = x2.shape[0], x2.shape[1], wp.shape[1]

    # zero channels beyond active_in so the boundary K block is exact
    x2 = x2 * (lax.iota(jnp.int32, Kp)[None, :] < active_in).astype(x2.dtype)

    nact = jnp.stack([
        lax.div(active_in + bk - 1, bk).astype(jnp.int32),
        lax.div(active_out + bn - 1, bn).astype(jnp.int32),
    ])

    out = pl.pallas_call(
        functools.partial(_sliced_kernel, bk=bk),
        grid=(Mp // bm, Np // bn),
        in_specs=[
            pl.BlockSpec((2,), lambda m, n: (0,)),
            pl.BlockSpec((bm, Kp), lambda m, n: (m, 0)),
            pl.BlockSpec((Kp, bn), lambda m, n: (0, n)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda m, n: (m, n)),
        out_shape=jax.ShapeDtypeStruct((Mp, Np), x.dtype),
        interpret=interpret,
        **({} if interpret else
           compat.triton_compiler_params_kwargs(num_warps=4, num_stages=3)),
    )(nact, x2, wp)
    out = out[:M, :N]
    out = out * (lax.iota(jnp.int32, N)[None, :] < active_out).astype(out.dtype)
    return out.reshape(*orig_shape[:-1], N)


# --------------------------------------------------------------------------
# subnet RMSNorm (SubnetNorm)
# --------------------------------------------------------------------------


def _rmsnorm_kernel(sid_ref, x_ref, g_ref, o_ref, *, eps: float):
    sid = sid_ref[0]
    g = pl.load(g_ref, (pl.dslice(sid, 1), slice(None))).astype(jnp.float32)
    x = x_ref[...].astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    o_ref[...] = (x * lax.rsqrt(var + eps) * g).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "eps", "interpret"))
def subnet_rmsnorm(x, gamma_table, subnet_id, *, bm: int = 128,
                   eps: float = 1e-5, interpret: bool = False):
    """x: (..., d); gamma_table: (n_subnets, d); subnet_id: traced int32."""
    orig_shape = x.shape
    d = x.shape[-1]
    M = 1
    for s in orig_shape[:-1]:
        M *= s
    x2 = x.reshape(M, d)
    bm_eff = min(bm, M)
    pm = (-M) % bm_eff
    if pm:
        x2 = jnp.pad(x2, ((0, pm), (0, 0)))
    S = gamma_table.shape[0]
    sid = jnp.asarray(subnet_id, jnp.int32).reshape(1)

    out = pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=((M + pm) // bm_eff,),
        in_specs=[
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((bm_eff, d), lambda i: (i, 0)),
            pl.BlockSpec((S, d), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm_eff, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((M + pm, d), x.dtype),
        interpret=interpret,
        **({} if interpret else
           compat.triton_compiler_params_kwargs(num_warps=4)),
    )(sid, x2, gamma_table)
    return out[:M].reshape(orig_shape)
