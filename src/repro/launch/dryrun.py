import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input
shape) cell on the production meshes with ShapeDtypeStruct inputs (no
allocation), then extract memory_analysis / cost_analysis / collective
bytes for the roofline report.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-1.5b \
        --shape train_4k --mesh single
    PYTHONPATH=src python -m repro.launch.dryrun --all [--mesh both]

Results land in results/dryrun/<arch>__<shape>__<mesh>.json; failures
are sharding bugs by definition and fail loudly.
"""
import argparse
import json
import time
import traceback
from functools import partial

import jax
import jax.numpy as jnp

from repro import compat
from repro.configs import SHAPES, get_config, assigned_archs, shape_applicable
from repro.core import subnet as sn
from repro.distributed.sharding import ShardingPlan
from repro.launch import specs as S
from repro.launch.mesh import make_production_mesh
from repro.models import lm
from repro.roofline import hlo as hlo_mod
from repro.roofline.report import RooflineTerms

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "results", "dryrun")


def _cache_constraints(plan, cfg, cache_tree):
    """Per-stage, per-layer NamedShardings for the decode scan body
    (strip the leading stacked-layer axis from the plan's cache specs)."""
    from jax.sharding import PartitionSpec as P
    out = []
    for si, stage_cache in enumerate(cache_tree["stages"]):
        def one(path, leaf, si=si):
            from repro.distributed.sharding import _path_str
            spec = plan.cache_spec(_path_str(path), leaf.shape)
            return plan.named(P(*spec[1:]))     # drop stacked-layer axis
        out.append(jax.tree_util.tree_map_with_path(one, stage_cache))
    return out


def _step_fn(cfg, kind: str, moe_groups: int, *, slice_mode: str = "mask",
             remat: bool = False, cache_constraints=None, moe_group_axes=None,
             microbatch: int = 0, grad_shardings=None):
    if kind == "train":
        def train_step(params, batch, ctrl):
            def loss(p, b):
                return lm.loss_fn(p, cfg, b, ctrl, slice_mode=slice_mode,
                                  remat=remat, moe_groups=moe_groups,
                                  moe_group_axes=moe_group_axes)

            def shard_grads(g):
                # ZeRO-2: reduce-scatter gradients over DP — without it
                # every device holds the full fp32 grad/accumulator tree
                # (measured 96 GB/device on qwen2.5-14b train_4k)
                if grad_shardings is None:
                    return g
                return jax.tree.map(jax.lax.with_sharding_constraint,
                                    g, grad_shardings)

            if microbatch:
                n = microbatch

                def split(x):
                    return x.reshape((n, x.shape[0] // n) + x.shape[1:])

                mb = jax.tree.map(split, batch)

                def acc(carry, mb_i):
                    l_acc, g_acc = carry
                    l, g = jax.value_and_grad(loss)(params, mb_i)
                    g = shard_grads(g)
                    return (l_acc + l / n,
                            jax.tree.map(lambda a, b2: a + b2 / n, g_acc, g)), None

                zeros = shard_grads(jax.tree.map(
                    lambda p: jnp.zeros(p.shape, jnp.float32), params))
                (l, grads), _ = jax.lax.scan(acc, (0.0, zeros), mb)
            else:
                l, grads = jax.value_and_grad(loss)(params, batch)
                grads = shard_grads(grads)
            # SGD-flavored apply keeps the dry-run optimizer-shape-true
            # without doubling memory vs AdamW moments (reported
            # separately in EXPERIMENTS.md).
            new_params = jax.tree.map(
                lambda p, g: (p.astype(jnp.float32) - 1e-3 * g).astype(p.dtype),
                params, grads)
            return l, new_params
        return train_step
    if kind == "prefill":
        def prefill_step(params, batch, ctrl):
            return lm.prefill(params, cfg, batch, ctrl, slice_mode=slice_mode,
                              moe_groups=moe_groups,
                              moe_group_axes=moe_group_axes)
        return prefill_step

    if kind == "decode_int8":
        from repro.serving import quantize as QZ

        def serve_step_q(q_params, scales, tokens, ctrl, cache, index):
            params = QZ.dequantize_tree(q_params, scales)
            return lm.decode_step(params, cfg, tokens, ctrl, cache, index,
                                  slice_mode=slice_mode,
                                  cache_constraints=cache_constraints)
        return serve_step_q

    def serve_step(params, tokens, ctrl, cache, index):
        return lm.decode_step(params, cfg, tokens, ctrl, cache, index,
                              slice_mode=slice_mode,
                              cache_constraints=cache_constraints)
    return serve_step


def run_cell(arch: str, shape_name: str, mesh_kind: str, *,
             save: bool = True, remat: bool = False,
             microbatch: int = 0, int8_weights: bool = False,
             fsdp: bool = False) -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, why = shape_applicable(cfg, shape)
    if not ok:
        rec = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
               "status": "skipped", "reason": why}
        if save:
            _save(rec)
        return rec

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    plan = ShardingPlan(mesh, cfg, moe_2d=(shape.kind == "decode"),
                        fsdp=fsdp)
    sp = S.input_specs(cfg, shape)
    sh = S.input_shardings(plan, cfg, shape, sp)
    constraints = (_cache_constraints(plan, cfg, sp["cache"])
                   if shape.kind == "decode" else None)
    grad_sh = None
    if shape.kind == "train":
        from repro.training import optimizer as _opt
        grad_sh = _opt.state_shardings(plan, sp["params"])["m"]
    kind = shape.kind
    if int8_weights and kind == "decode":
        kind = "decode_int8"
    step = _step_fn(cfg, kind, moe_groups=plan.dp_size, remat=remat,
                    cache_constraints=constraints,
                    moe_group_axes=plan.dp_axes, microbatch=microbatch,
                    grad_shardings=grad_sh)

    t0 = time.time()
    with mesh:
        if shape.kind in ("train", "prefill"):
            jitted = jax.jit(step, in_shardings=(sh["params"], sh["batch"],
                                                 sh["ctrl"]))
            lowered = jitted.lower(sp["params"], sp["batch"], sp["ctrl"])
        else:
            # pin the output cache to the input layout: donation can
            # only alias when shardings match, otherwise XLA
            # materializes a full re-laid-out cache in temp space
            logits_sh = plan.named(jax.sharding.PartitionSpec(
                plan.dp_axes if shape.global_batch % plan.dp_size == 0
                else None, None, None))
            if int8_weights:
                from repro.serving import quantize as QZ
                q_sp, sc_sp = QZ.quantize_specs(sp["params"])
                sc_sh = plan.replicated(sc_sp)
                jitted = jax.jit(step, in_shardings=(sh["params"], sc_sh,
                                                     sh["tokens"], sh["ctrl"],
                                                     sh["cache"], sh["index"]),
                                 out_shardings=(logits_sh, sh["cache"]),
                                 donate_argnums=(4,))
                lowered = jitted.lower(q_sp, sc_sp, sp["tokens"], sp["ctrl"],
                                       sp["cache"], sp["index"])
            else:
                jitted = jax.jit(step, in_shardings=(sh["params"], sh["tokens"],
                                                     sh["ctrl"], sh["cache"],
                                                     sh["index"]),
                                 out_shardings=(logits_sh, sh["cache"]),
                                 donate_argnums=(3,))
                lowered = jitted.lower(sp["params"], sp["tokens"], sp["ctrl"],
                                       sp["cache"], sp["index"])
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    ma = compiled.memory_analysis()
    ca = compat.cost_analysis(compiled)
    text = compiled.as_text()
    coll_bytes, breakdown = hlo_mod.collective_bytes(text)
    counts = hlo_mod.collective_count(text)
    f32_copy_bytes = _cpu_f32_weight_copies(plan, sp["params"], text)

    terms = RooflineTerms(
        arch=arch, shape=shape_name, mesh=mesh_kind,
        chips=mesh.devices.size,
        hlo_flops_per_device=float(ca.get("flops", 0.0)),
        hlo_bytes_per_device=float(ca.get("bytes accessed", 0.0)),
        collective_bytes_per_device=coll_bytes,
        model_flops_total=S.model_flops(cfg, shape),
        argument_bytes_per_device=float(ma.argument_size_in_bytes),
        temp_bytes_per_device=float(ma.temp_size_in_bytes),
        collective_breakdown=breakdown,
    )
    from repro.kernels.dispatch import model_tier
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
           "status": "ok", "kernel_tier": model_tier(),
           "remat": remat, "microbatch": microbatch,
           "int8_weights": int8_weights, "fsdp": fsdp,
           "lower_s": round(t_lower, 1),
           "compile_s": round(t_compile, 1),
           "collective_counts": counts,
           "output_bytes_per_device": float(ma.output_size_in_bytes),
           # CPU-backend artifact: bf16 dots are promoted to f32, so the
           # compiler materializes f32 copies of bf16 weights that a TPU
           # (native-bf16 MXU) never allocates. Subtract for the
           # TPU-projected temp footprint.
           "cpu_f32_weight_copy_bytes": f32_copy_bytes,
           "temp_bytes_tpu_projected": float(ma.temp_size_in_bytes) - f32_copy_bytes,
           **terms.to_dict()}
    if save:
        _save(rec)
    return rec


def _cpu_f32_weight_copies(plan, param_specs, hlo_text: str) -> float:
    """Bytes of f32 copies of bf16 param leaves present in the HLO
    (each distinct local weight shape counted once — buffer assignment
    reuses allocations across layers of equal shape)."""
    import re
    import numpy as np
    from repro.distributed.sharding import _path_str
    import jax as _jax

    local_shapes = set()
    for path, leaf in _jax.tree_util.tree_leaves_with_path(param_specs):
        if leaf.dtype != jnp.bfloat16:
            continue
        spec = plan.param_spec(_path_str(path), leaf.shape)
        dims = []
        for size, ax in zip(leaf.shape, tuple(spec) + (None,) * len(leaf.shape)):
            n = 1
            if ax is not None:
                for a in (ax if isinstance(ax, tuple) else (ax,)):
                    n *= plan.mesh.shape[a]
            dims.append(size // n)
        if np.prod(dims) * 4 > 64 * 2**20:     # only copies that matter
            local_shapes.add(tuple(dims))
    total = 0.0
    for dims in local_shapes:
        pat = r"f32\[" + ",".join(str(d) for d in dims) + r"\]"
        if re.search(pat, hlo_text):
            total += float(np.prod(dims)) * 4
    return total


def _save(rec: dict) -> None:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    name = f"{rec['arch']}__{rec['shape']}__{rec['mesh']}.json"
    with open(os.path.join(RESULTS_DIR, name), "w") as f:
        json.dump(rec, f, indent=1, default=str)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single", choices=("single", "multi", "both"))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--skip-done", action="store_true")
    ap.add_argument("--remat", action="store_true")
    ap.add_argument("--microbatch", type=int, default=0)
    ap.add_argument("--int8-weights", action="store_true")
    ap.add_argument("--fsdp", action="store_true")
    args = ap.parse_args()

    archs = assigned_archs() if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = ("single", "multi") if args.mesh == "both" else (args.mesh,)

    failures = []
    for arch in archs:
        for shape in shapes:
            for mesh_kind in meshes:
                out = os.path.join(
                    RESULTS_DIR, f"{arch}__{shape}__{mesh_kind}.json")
                if args.skip_done and os.path.exists(out):
                    continue
                tag = f"{arch} x {shape} x {mesh_kind}"
                try:
                    rec = run_cell(arch, shape, mesh_kind, remat=args.remat,
                                   microbatch=args.microbatch,
                                   int8_weights=args.int8_weights,
                                   fsdp=args.fsdp)
                    if rec["status"] == "skipped":
                        print(f"[skip] {tag}: {rec['reason']}", flush=True)
                    else:
                        print(f"[ ok ] {tag}: dominant={rec['dominant']} "
                              f"frac={rec['roofline_fraction']:.3f} "
                              f"compile={rec['compile_s']}s", flush=True)
                except Exception as e:  # noqa: BLE001 - report and continue
                    failures.append((tag, repr(e)))
                    print(f"[FAIL] {tag}: {e!r}", flush=True)
                    traceback.print_exc()
    if failures:
        raise SystemExit(f"{len(failures)} dry-run cells failed: "
                         + "; ".join(t for t, _ in failures))


if __name__ == "__main__":
    main()
