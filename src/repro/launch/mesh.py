"""Production mesh factory.

A FUNCTION, not a module-level constant: importing this module never
touches jax device state. The dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import so the factory can build the (2, 16, 16) multi-pod mesh on CPU.

Mesh construction goes through :mod:`repro.compat` — the
``axis_types=``/``AxisType`` surface only exists on newer JAX releases.
"""
from __future__ import annotations

from repro import compat


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat.make_mesh(shape, axes)


def make_mesh(shape, axes):
    """Arbitrary mesh (tests, examples, degraded pools)."""
    return compat.make_mesh(tuple(shape), tuple(axes))
