"""Serving launcher.

    PYTHONPATH=src python -m repro.launch.serve --arch ofa_resnet \
        --policy slackfit --trace bursty --rate 7000 --cv2 8 --duration 10

Drives the production serving stack at full scale through the
discrete-event engine (the real asyncio runtime is demonstrated by
examples/serve_bursty.py on this host's actual devices). With
``--replicas N`` (N > 1) the same trace is served by the multi-replica
cluster plane — N engines behind the coordinator, placement chosen by
``--placement``. ``--autoscale`` adds the reactive replica autoscaler
(spawn/decommission from load signals, ``--min-replicas`` /
``--max-replicas`` bounds, ``--scale-policy`` signal) and reports
replica-seconds, the scale-event log, and goodput per replica-second.

Predictive serving (serving/forecast.py): ``--scale-policy predictive``
spawns ahead of the arrival forecast crossing capacity (reactive
fallback without signal); ``--predictive-joins`` opens forecast-led
join windows even at saturation; ``--forecast-window`` sets the shared
estimator window. The forecast snapshot rides the output JSON.
"""
from __future__ import annotations

import argparse
import json

from repro.configs import get_config
from repro.serving import cluster, policies, profiler, simulator, traces
from repro.serving.autoscaler import SCALINGS, AutoscaleConfig
from repro.serving.forecast import ForecastConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="ofa_resnet")
    ap.add_argument("--policy", default="slackfit",
                    choices=sorted(policies.ALL_POLICIES) + ["clipper"])
    ap.add_argument("--clipper-idx", type=int, default=-1)
    ap.add_argument("--trace", default="bursty",
                    choices=("bursty", "time_varying", "maf"))
    ap.add_argument("--rate", type=float, default=7000)
    ap.add_argument("--cv2", type=float, default=4)
    ap.add_argument("--tau", type=float, default=500)
    ap.add_argument("--duration", type=float, default=10.0)
    ap.add_argument("--workers", type=int, default=8,
                    help="workers per replica group")
    ap.add_argument("--replicas", type=int, default=1,
                    help="replica groups; >1 serves through the cluster "
                         "coordinator (one engine per replica)")
    ap.add_argument("--placement", default="round_robin",
                    choices=sorted(cluster.PLACEMENTS),
                    help="replica placement policy (cluster mode only)")
    ap.add_argument("--slo-ms", type=float, default=36.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--faults", default="",
                    help="comma list wid:t, e.g. 7:12,6:24 "
                         "(cluster mode: rid.wid:t)")
    ap.add_argument("--replica-deaths", default="",
                    help="comma list rid:t — whole replica groups dying "
                         "(cluster mode only)")
    ap.add_argument("--continuous-batching", action="store_true",
                    help="keep forming batches open to in-flight joins "
                         "within the policy's latency budget (paper §5)")
    ap.add_argument("--predictive-joins", action="store_true",
                    help="forecast-led join windows: hold a forming batch "
                         "even on the last free worker when the arrival "
                         "forecast says a joinable query lands within "
                         "slack (implies in-flight joins)")
    ap.add_argument("--forecast-window", type=float, default=0.25,
                    help="arrival-forecaster sliding window (s), shared "
                         "by predictive joins and predictive scaling")
    ap.add_argument("--autoscale", action="store_true",
                    help="reactive replica autoscaling: spawn/decommission "
                         "replica groups from load signals (forces cluster "
                         "mode; --replicas is the initial count)")
    ap.add_argument("--min-replicas", type=int, default=1)
    ap.add_argument("--max-replicas", type=int, default=8)
    ap.add_argument("--scale-policy", default="queue_pressure",
                    choices=sorted(k for k in SCALINGS if k != "scripted"),
                    help="autoscaling signal (see serving/autoscaler.py)")
    ap.add_argument("--cold-start", default="0.1",
                    help="spawn -> routable actuation cost (s), or 'auto' "
                         "to derive it from the ActuationModel as a full "
                         "weight-load of the heaviest subnet")
    ap.add_argument("--scale-cooldown", type=float, default=0.5,
                    help="min gap before a scale-down (s)")
    ap.add_argument("--load-on-switch", action="store_true",
                    help="charge a full weight page-in per subnet switch "
                         "(the non-weight-shared Clipper+/INFaaS cost "
                         "model) instead of the SubNetAct control swap — "
                         "the regime where --placement actuation_aware "
                         "and --policy slackfit_sticky earn their keep")
    args = ap.parse_args()
    try:
        cold_start = (None if args.cold_start == "auto"
                      else float(args.cold_start))
    except ValueError:
        ap.error(f"--cold-start must be a number or 'auto', "
                 f"got {args.cold_start!r}")

    cfg = get_config(args.arch)
    prof = profiler.build_profile(cfg)
    if args.policy == "clipper":
        idx = args.clipper_idx if args.clipper_idx >= 0 else prof.n_pareto - 1
        pol = policies.ClipperFixed(idx)
    else:
        pol = policies.ALL_POLICIES[args.policy]()

    if args.trace == "bursty":
        arr = traces.bursty_trace(args.rate * 0.2, args.rate * 0.8, args.cv2,
                                  args.duration, args.seed)
    elif args.trace == "time_varying":
        arr = traces.time_varying_trace(args.rate * 0.4, args.rate, args.tau,
                                        args.cv2, args.duration, args.seed)
    else:
        arr = traces.maf_like_trace(args.rate, args.duration, seed=args.seed)

    if args.replicas > 1 or args.autoscale:
        faults = {}
        if args.faults:
            for part in args.faults.split(","):
                rw, t = part.split(":")
                rid, wid = rw.split(".")
                faults[(int(rid), int(wid))] = float(t)
        deaths = {}
        if args.replica_deaths:
            for part in args.replica_deaths.split(","):
                rid, t = part.split(":")
                deaths[int(rid)] = float(t)
        autoscale = None
        if args.autoscale:
            if not (args.min_replicas <= args.replicas
                    <= args.max_replicas):
                ap.error(f"--replicas {args.replicas} must start within "
                         f"[--min-replicas {args.min_replicas}, "
                         f"--max-replicas {args.max_replicas}]")
            autoscale = AutoscaleConfig(
                min_replicas=args.min_replicas,
                max_replicas=args.max_replicas, policy=args.scale_policy,
                cold_start=cold_start, cooldown=args.scale_cooldown,
                # the shared estimator window tunes the FORECAST-led
                # policy only (its reactive fallback stays comparable);
                # a plain reactive run keeps its own default window
                **({"rate_window": args.forecast_window}
                   if args.scale_policy == "predictive" else {}))
        # one shared ForecastConfig for the engines' predictive join
        # windows and (via the coordinator_forecast rule) the
        # coordinator-level forecaster behind --scale-policy predictive
        forecast = (ForecastConfig(window=args.forecast_window)
                    if args.predictive_joins
                    or (autoscale and autoscale.policy == "predictive")
                    else None)
        ccfg = simulator.ClusterConfig(
            n_replicas=args.replicas, workers_per_replica=args.workers,
            placement=args.placement, placement_seed=args.seed,
            slo=args.slo_ms / 1e3, fault_times=faults, replica_deaths=deaths,
            load_on_switch=args.load_on_switch,
            continuous_batching=args.continuous_batching,
            predictive_joins=args.predictive_joins, forecast=forecast,
            autoscale=autoscale)
        res = simulator.simulate_cluster(arr, prof, pol, ccfg)
        st = res.stats()
        extra = {"replicas": args.replicas, "placement": args.placement,
                 "load_imbalance": st["load_imbalance"],
                 "per_replica_served": {r: v["served"]
                                        for r, v in st["replicas"].items()}}
        if res.forecast is not None:
            extra["forecast"] = {k: None if v is None else round(v, 4)
                                 for k, v in res.forecast.items()}
            extra["predictive_windows"] = res.n_predictive_windows
        if args.autoscale:
            extra.update({
                "autoscale_policy": args.scale_policy,
                "replicas_total": res.n_replicas,   # ever existed
                "replica_seconds": res.replica_seconds,
                "goodput_per_replica_second":
                    st.get("goodput_per_replica_second", 0.0),
                "scale_events": [
                    {"t": round(e.t, 4), "kind": e.kind, "rid": e.rid,
                     "committed": e.n_committed, "signal": round(e.signal, 3)}
                    for e in res.scale_events]})
    else:
        faults = {}
        if args.faults:
            for part in args.faults.split(","):
                wid, t = part.split(":")
                faults[int(wid)] = float(t)
        scfg = simulator.SimConfig(n_workers=args.workers,
                                   slo=args.slo_ms / 1e3,
                                   load_on_switch=args.load_on_switch,
                                   fault_times=faults, seed=args.seed,
                                   continuous_batching=args.continuous_batching,
                                   predictive_joins=args.predictive_joins,
                                   forecast=(ForecastConfig(
                                       window=args.forecast_window)
                                       if args.predictive_joins else None))
        res = simulator.simulate(arr, prof, pol, scfg)
        extra = ({"predictive_windows": res.n_predictive_windows}
                 if args.predictive_joins else {})
    st = res.stats()
    out = {"arch": args.arch, "policy": pol.name, "queries": len(arr),
           "continuous_batching": args.continuous_batching,
           "slo_attainment": res.slo_attainment, "mean_acc": res.mean_acc,
           "p50_latency_ms": res.latency_p50 * 1e3,
           "p99_latency_ms": res.latency_p99 * 1e3,
           "join_rate": res.n_joins / max(len(arr), 1),
           "switch_rate": st["switch_rate"],
           "actuation_seconds": st["actuation_seconds"], **extra}
    print(json.dumps(out, indent=1))


if __name__ == "__main__":
    main()
