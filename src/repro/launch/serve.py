"""Serving launcher.

    PYTHONPATH=src python -m repro.launch.serve --arch ofa_resnet \
        --policy slackfit --trace bursty --rate 7000 --cv2 8 --duration 10

Drives the production serving stack at full scale through the
discrete-event engine (the real asyncio runtime is demonstrated by
examples/serve_bursty.py on this host's actual devices). With
``--replicas N`` (N > 1) the same trace is served by the multi-replica
cluster plane — N engines behind the coordinator, placement chosen by
``--placement``. ``--autoscale`` adds the reactive replica autoscaler
(spawn/decommission from load signals, ``--min-replicas`` /
``--max-replicas`` bounds, ``--scale-policy`` signal) and reports
replica-seconds, the scale-event log, and goodput per replica-second.

Predictive serving (serving/forecast.py): ``--scale-policy predictive``
spawns ahead of the arrival forecast crossing capacity (reactive
fallback without signal); ``--predictive-joins`` opens forecast-led
join windows even at saturation; ``--forecast-window`` sets the shared
estimator window. The forecast snapshot rides the output JSON.

Multi-host serving plane (serving/ipc.py): ``--transport proc
--procs K`` serves the trace LIVE through K replica worker processes —
one OS process per replica group behind the IPC front door, placement
still owned by the in-process coordinator. ``--listen HOST:PORT`` (port
0 picks a free one) moves the transport onto TCP with an HMAC-token
handshake (``--token``, auto-generated when unset), the same front door
a REMOTE replica dials: run ``--connect HOST:PORT --token T`` on
another machine to serve as a replica child for that coordinator.
``--autoscale`` runs the live replica autoscaler over the proc
transport (spawn = fork/connect a child priced at cold start,
decommission = drain frame through the coordinator's surrender path),
and ``--execute real`` makes each child build its own AOT-warmed
``SubnetExecutor`` so completions carry real subnet logits. Echo
workers (optionally ``--work-ms`` of real CPU spin per batch) remain
the default stand-in; arrivals are capped at ``--queries``. Still
incompatible with ``--profile measured``, ``--faults`` and
``--replica-deaths`` (fault scripts stay inproc/simulated).

Compiled execution path (serving/executor.py): ``--execute real`` runs
actual subnet forward passes on this host — the reduced config behind
the AOT-warmed, shape-bucketed ``SubnetExecutor``, served by the
asyncio Router/ClusterRouter with the SAME engine/policy/residency
stack as the simulator. ``--profile measured`` replaces the analytic
roofline ``LatencyProfile`` with wall-clock per-(subnet, batch-bucket)
latencies measured through the warmed executor (usable with either
``--execute`` mode). Both need a token-frontend LM arch, e.g.
``--arch qwen2-1.5b``.
"""
from __future__ import annotations

import argparse
import asyncio
import json
import time

import numpy as np

from repro.configs import get_config
from repro.serving import cluster, policies, profiler, simulator, traces
from repro.serving.autoscaler import SCALINGS, AutoscaleConfig
from repro.serving.forecast import ForecastConfig


def _host_latency(executor, subnet_idx: int, seq_len: int,
                  iters: int = 3) -> float:
    """Best-of-k wall-clock for a warmed B=1 prefill on this host."""
    best = float("inf")
    for _ in range(iters):
        t0 = time.perf_counter()
        executor.run_prefill(subnet_idx, np.ones((1, seq_len), np.int32))
        best = min(best, time.perf_counter() - t0)
    return best


def _serve_real(args, cfg, prof, pol, executor, arr, slo_s, rate, warm):
    """Serve ``arr`` with real forward passes through the asyncio
    router(s); scheduling stays entirely inside the unchanged engine."""
    from repro import compat
    from repro.serving import runtime

    async def go():
        rng = np.random.default_rng(args.seed)
        payloads = rng.integers(0, cfg.vocab_size,
                                (len(arr), args.seq_len)).astype(np.int32)
        if args.replicas > 1:
            router = runtime.ClusterRouter(
                prof, pol,
                [executor.make_workers(args.workers)
                 for _ in range(args.replicas)],
                placement=args.placement, placement_seed=args.seed,
                slo=slo_s)
        else:
            router = runtime.Router(prof, pol,
                                    executor.make_workers(args.workers),
                                    executor=executor)
        await router.start()
        base = compat.compile_events()
        t0 = time.perf_counter()
        futs = []
        for i, t in enumerate(arr):
            now = time.perf_counter() - t0
            if t > now:
                await asyncio.sleep(t - now)
            futs.append(await router.submit(payloads[i], slo_s=slo_s))
        await asyncio.gather(*futs)
        await router.drain()
        compiles = (None if base is None
                    else compat.compile_events() - base)
        return router, compiles

    router, serve_compiles = asyncio.run(go())
    st = router.stats()
    recs = router.records()
    lats = sorted(r.finish - r.arrival for r in recs
                  if r.finish is not None)

    def pct(q: float):
        return (lats[min(int(q * len(lats)), len(lats) - 1)] * 1e3
                if lats else None)

    return {"arch": args.arch, "mode": "real",
            "profile": args.profile_mode, "policy": pol.name,
            "queries": len(recs), "replicas": args.replicas,
            "workers": args.workers,
            "rate_qps": round(rate, 1), "slo_ms": round(slo_s * 1e3, 3),
            "slo_attainment": st["slo_attainment"],
            "mean_acc": st["mean_acc"],
            "p50_latency_ms": pct(0.50), "p99_latency_ms": pct(0.99),
            "switch_rate": st["switch_rate"],
            "actuation_seconds": st["actuation_seconds"],
            # SubNetAct live: compiles observed while serving (None if
            # the jax.monitoring probe is unavailable); warmed serving
            # should report 0
            "serve_phase_compiles": serve_compiles,
            "warmup": warm, "executor": executor.counters()}


def _serve_proc(args, cfg, prof, pol, arr, slo_s, rate, autoscale=None):
    """Serve ``arr`` live through one OS process per replica group
    (serving/ipc.py) — socketpair children, or TCP with ``--listen``.
    The coordinator in THIS process still owns admission/placement/
    lifecycle (autoscaling included); the children own scheduling."""
    from repro.serving import runtime

    async def go():
        router = runtime.ClusterRouter(
            prof, pol, [args.workers] * args.procs,
            placement=args.placement, placement_seed=args.seed,
            transport="proc", work_ms=args.work_ms,
            host_devices=args.host_devices,
            listen=args.listen, token=args.token,
            execute=args.execute if args.execute == "real" else "echo",
            arch=args.arch if args.execute == "real" else None,
            seq_len=args.seq_len, seed=args.seed,
            autoscale=autoscale, slo=slo_s,
            spawn_timeout=300.0 if args.execute == "real" else 60.0,
            engine_cfg=(runtime.EngineConfig(
                continuous_batching=args.continuous_batching
                or args.predictive_joins,
                predictive_joins=args.predictive_joins,
                forecast=(ForecastConfig(window=args.forecast_window)
                          if args.predictive_joins else None))
                if args.continuous_batching or args.predictive_joins
                else None))
        await router.start()
        payloads = None
        if args.execute == "real":
            rng = np.random.default_rng(args.seed)
            payloads = rng.integers(
                0, cfg.vocab_size,
                (len(arr), args.seq_len)).astype(np.int32)
        t0 = time.perf_counter()
        futs = []
        for i, t in enumerate(arr):
            now = time.perf_counter() - t0
            if t > now:
                await asyncio.sleep(t - now)
            p = (payloads[i].tolist() if payloads is not None
                 else [float(i)])
            futs.append(await router.submit(p, slo_s=slo_s))
        await asyncio.gather(*futs)
        await router.drain(60.0)
        return router, time.perf_counter() - t0

    router, makespan = asyncio.run(go())
    st = router.stats()
    recs = router.records()
    out = {"arch": args.arch, "mode": "proc", "execute": args.execute,
           "policy": pol.name,
           "queries": len(recs), "procs": args.procs,
           "workers_per_proc": args.workers, "work_ms": args.work_ms,
           "rate_qps": round(rate, 1), "slo_ms": round(slo_s * 1e3, 3),
           "slo_attainment": st["slo_attainment"],
           "mean_acc": st["mean_acc"],
           "p50_latency_ms": st["p50_latency_s"] * 1e3,
           "p99_latency_ms": st["p99_latency_s"] * 1e3,
           "load_imbalance": st["load_imbalance"],
           "per_replica_served": {r: v["served"]
                                  for r, v in st["replicas"].items()},
           "makespan_s": round(makespan, 4),
           # adopted/remote replicas have no local pid
           "replica_pids": [None if ch.proc is None else ch.proc.pid
                            for ch in router._chans]}
    if args.listen:
        out["listen"] = list(router.listen_addr)
        out["handshake_rejects"] = router.handshake_rejects
    if autoscale is not None:
        router.autoscaler.finalize(router.clock.now())
        out.update({
            "autoscale_policy": autoscale.policy,
            "replicas_total": router.coord.n_replicas,   # ever existed
            "replica_seconds": round(router.autoscaler.replica_seconds(),
                                     4),
            "scale_events": [
                {"t": round(e.t, 4), "kind": e.kind, "rid": e.rid,
                 "committed": e.n_committed, "signal": round(e.signal, 3)}
                for e in router.autoscaler.events]})
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="ofa_resnet")
    ap.add_argument("--policy", default="slackfit",
                    choices=sorted(policies.ALL_POLICIES) + ["clipper"])
    ap.add_argument("--clipper-idx", type=int, default=-1)
    ap.add_argument("--trace", default="bursty",
                    choices=("bursty", "time_varying", "maf"))
    ap.add_argument("--rate", type=float, default=None,
                    help="mean arrival rate q/s (default 7000; "
                         "--execute real derives a host-safe rate from "
                         "the profile when unset)")
    ap.add_argument("--cv2", type=float, default=4)
    ap.add_argument("--tau", type=float, default=500)
    ap.add_argument("--duration", type=float, default=10.0)
    ap.add_argument("--execute", default="sim", choices=("sim", "real"),
                    help="sim: discrete-event simulation with profile "
                         "service times (default). real: execute actual "
                         "subnet forward passes on this host through the "
                         "AOT-warmed SubnetExecutor (serving/executor.py) "
                         "behind the asyncio router — reduced config, "
                         "token-frontend LM archs only; incompatible with "
                         "--autoscale/--faults/--replica-deaths")
    ap.add_argument("--profile", dest="profile_mode", default="analytic",
                    choices=("analytic", "measured"),
                    help="latency profile the engine schedules from. "
                         "analytic: deterministic hardware-roofline model "
                         "(profiler.build_profile, default). measured: "
                         "true wall-clock per-(subnet, batch-bucket) "
                         "latencies measured on this host through the "
                         "warmed executor (token-frontend LM archs only; "
                         "uses the reduced config; works with either "
                         "--execute mode)")
    ap.add_argument("--queries", type=int, default=64,
                    help="--execute real / --transport proc: number of "
                         "trace arrivals to serve (kept small — every "
                         "query is a real forward pass or a live IPC "
                         "round trip)")
    ap.add_argument("--seq-len", type=int, default=16,
                    help="--execute real / --profile measured: prompt "
                         "tokens per query (right-padded to the "
                         "executor's seq bucket)")
    ap.add_argument("--workers", type=int, default=8,
                    help="workers per replica group")
    ap.add_argument("--replicas", type=int, default=1,
                    help="replica groups; >1 serves through the cluster "
                         "coordinator (one engine per replica)")
    ap.add_argument("--placement", default="round_robin",
                    choices=sorted(cluster.PLACEMENTS),
                    help="replica placement policy (cluster mode only)")
    ap.add_argument("--transport", default="inproc",
                    choices=("inproc", "proc"),
                    help="proc: serve LIVE through one OS process per "
                         "replica group over the IPC front door "
                         "(serving/ipc.py); inproc keeps the simulated/"
                         "in-process planes (default)")
    ap.add_argument("--procs", type=int, default=2,
                    help="--transport proc: replica worker processes "
                         "(each gets --workers workers)")
    ap.add_argument("--work-ms", type=float, default=0.0,
                    help="--transport proc: real CPU busy-spin per batch "
                         "in the worker processes (0 = pure echo)")
    ap.add_argument("--host-devices", type=int, default=0,
                    help="--transport proc: pin N fake XLA host devices "
                         "per replica process via XLA_FLAGS before the "
                         "child's first jax import (0 = no jax import)")
    ap.add_argument("--listen", default=None, metavar="HOST:PORT",
                    help="--transport proc: open a TCP listener and run "
                         "children through it (port 0 picks a free one); "
                         "remote replicas dial the same address with "
                         "--connect and pass the HMAC handshake")
    ap.add_argument("--token", default=None,
                    help="shared HMAC handshake token for --listen/"
                         "--connect (listener auto-generates one when "
                         "unset; --connect falls back to $REPRO_IPC_TOKEN)")
    ap.add_argument("--connect", default=None, metavar="HOST:PORT",
                    help="run as a REMOTE replica child: dial a "
                         "coordinator started with --listen and serve "
                         "one replica group for it (every other flag is "
                         "ignored — the coordinator's ReplicaSpec "
                         "configures this process)")
    ap.add_argument("--slo-ms", type=float, default=None,
                    help="query SLO (default 36.0; --execute real "
                         "derives ~25x the max-subnet B=1 latency from "
                         "the profile when unset, sized for host jitter)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--faults", default="",
                    help="comma list wid:t, e.g. 7:12,6:24 "
                         "(cluster mode: rid.wid:t)")
    ap.add_argument("--replica-deaths", default="",
                    help="comma list rid:t — whole replica groups dying "
                         "(cluster mode only)")
    ap.add_argument("--continuous-batching", action="store_true",
                    help="keep forming batches open to in-flight joins "
                         "within the policy's latency budget (paper §5)")
    ap.add_argument("--predictive-joins", action="store_true",
                    help="forecast-led join windows: hold a forming batch "
                         "even on the last free worker when the arrival "
                         "forecast says a joinable query lands within "
                         "slack (implies in-flight joins)")
    ap.add_argument("--forecast-window", type=float, default=0.25,
                    help="arrival-forecaster sliding window (s), shared "
                         "by predictive joins and predictive scaling")
    ap.add_argument("--autoscale", action="store_true",
                    help="reactive replica autoscaling: spawn/decommission "
                         "replica groups from load signals (forces cluster "
                         "mode; --replicas is the initial count)")
    ap.add_argument("--min-replicas", type=int, default=1)
    ap.add_argument("--max-replicas", type=int, default=8)
    ap.add_argument("--scale-policy", default="queue_pressure",
                    choices=sorted(k for k in SCALINGS if k != "scripted"),
                    help="autoscaling signal (see serving/autoscaler.py)")
    ap.add_argument("--cold-start", default="0.1",
                    help="spawn -> routable actuation cost (s), or 'auto' "
                         "to derive it from the ActuationModel as a full "
                         "weight-load of the heaviest subnet")
    ap.add_argument("--scale-cooldown", type=float, default=0.5,
                    help="min gap before a scale-down (s)")
    ap.add_argument("--load-on-switch", action="store_true",
                    help="charge a full weight page-in per subnet switch "
                         "(the non-weight-shared Clipper+/INFaaS cost "
                         "model) instead of the SubNetAct control swap — "
                         "the regime where --placement actuation_aware "
                         "and --policy slackfit_sticky earn their keep")
    args = ap.parse_args()
    if args.connect:
        # remote-replica child mode: this process serves frames for a
        # coordinator elsewhere; its ReplicaSpec arrives over the wire
        from repro.serving.replica_proc import main as replica_main
        replica_main(["--connect", args.connect]
                     + (["--token", args.token] if args.token else []))
        return
    try:
        cold_start = (None if args.cold_start == "auto"
                      else float(args.cold_start))
    except ValueError:
        ap.error(f"--cold-start must be a number or 'auto', "
                 f"got {args.cold_start!r}")

    cfg = get_config(args.arch)
    if args.transport == "proc" and (
            args.profile_mode == "measured"
            or args.faults or args.replica_deaths):
        ap.error("--transport proc does not combine with --profile "
                 "measured, --faults or --replica-deaths (fault scripts "
                 "and host-measured profiles stay inproc/simulated)")
    if args.listen and args.transport != "proc":
        ap.error("--listen is the proc transport's TCP front door; "
                 "add --transport proc")
    executor, warm = None, None
    if args.execute == "real" or args.profile_mode == "measured":
        if cfg.family == "conv" or cfg.frontend != "token":
            ap.error(f"--execute real / --profile measured execute the "
                     f"LM path and need a token-frontend arch (try "
                     f"--arch qwen2-1.5b); {args.arch} is "
                     f"family={cfg.family}, frontend={cfg.frontend}")
        if (args.execute == "real" and args.transport != "proc"
                and (args.autoscale or args.faults
                     or args.replica_deaths)):
            ap.error("--execute real does not support --autoscale/"
                     "--faults/--replica-deaths inproc; --transport "
                     "proc runs autoscaled real execution, and the "
                     "simulator covers fault studies")
        cfg = cfg.reduced()             # CPU-executable twin, same family
        if args.transport != "proc":
            # proc + real builds executors inside the children (from
            # the same reduced config); the parent only profiles it
            from repro.serving.executor import build_executor
            executor = build_executor(cfg, seed=args.seed)

    if args.profile_mode == "measured":
        # AOT-warm first so measurement never times a compile
        batches = (1, 2, 4, 8)
        warm = executor.warmup(batches=batches, seqs=(args.seq_len,))
        prof = executor.measured_profile(batches=batches,
                                         seq_len=args.seq_len)
    else:
        prof = profiler.build_profile(cfg)
        if executor is not None:
            # warm every bucket the analytic profile lets the policy
            # choose, so serving stays compile-free
            warm = executor.warmup(batches=prof.batches,
                                   seqs=(args.seq_len,))

    if args.policy == "clipper":
        idx = args.clipper_idx if args.clipper_idx >= 0 else prof.n_pareto - 1
        pol = policies.ClipperFixed(idx)
    else:
        pol = policies.ALL_POLICIES[args.policy]()

    rate = args.rate if args.rate is not None else 7000.0
    slo_ms = args.slo_ms if args.slo_ms is not None else 36.0
    duration = args.duration
    if args.execute == "real" and args.transport == "proc":
        # the children execute; the parent has no executor to time —
        # size pacing for reduced-config CPU forwards served over IPC
        if args.rate is None:
            rate = 20.0
        if args.slo_ms is None:
            slo_ms = 4000.0
        duration = args.queries / max(rate, 1e-9)
    elif args.execute == "real":
        # host-safe pacing: the analytic roofline models the paper's
        # 2080Ti, not this host — derive rate/SLO from latencies
        # actually observed here (examples/serve_bursty.py sizing:
        # SLO ~= 25x the max-subnet B=1 latency, rate leaves 4x
        # headroom on the min-subnet latency)
        lat_fast = _host_latency(executor, 0, args.seq_len)
        lat_slow = _host_latency(executor, executor.n_subnets - 1,
                                 args.seq_len)
        if args.rate is None:
            rate = 0.25 / lat_fast
        if args.slo_ms is None:
            slo_ms = lat_slow * 25 * 1e3
        duration = args.queries / max(rate, 1e-9)

    if args.trace == "bursty":
        arr = traces.bursty_trace(rate * 0.2, rate * 0.8, args.cv2,
                                  duration, args.seed)
    elif args.trace == "time_varying":
        arr = traces.time_varying_trace(rate * 0.4, rate, args.tau,
                                        args.cv2, duration, args.seed)
    else:
        arr = traces.maf_like_trace(rate, duration, seed=args.seed)

    if args.transport == "proc":
        arr = np.asarray(arr, dtype=float)[: args.queries]
        autoscale = None
        if args.autoscale:
            if not (args.min_replicas <= args.procs
                    <= args.max_replicas):
                ap.error(f"--procs {args.procs} must start within "
                         f"[--min-replicas {args.min_replicas}, "
                         f"--max-replicas {args.max_replicas}]")
            autoscale = AutoscaleConfig(
                min_replicas=args.min_replicas,
                max_replicas=args.max_replicas, policy=args.scale_policy,
                cold_start=cold_start, cooldown=args.scale_cooldown,
                **({"rate_window": args.forecast_window}
                   if args.scale_policy == "predictive" else {}))
        out = _serve_proc(args, cfg, prof, pol, arr, slo_ms / 1e3, rate,
                          autoscale)
        print(json.dumps(out, indent=1))
        return

    if args.execute == "real":
        arr = np.asarray(arr, dtype=float)[: args.queries]
        out = _serve_real(args, cfg, prof, pol, executor, arr,
                          slo_ms / 1e3, rate, warm)
        print(json.dumps(out, indent=1))
        return

    if args.replicas > 1 or args.autoscale:
        faults = {}
        if args.faults:
            for part in args.faults.split(","):
                rw, t = part.split(":")
                rid, wid = rw.split(".")
                faults[(int(rid), int(wid))] = float(t)
        deaths = {}
        if args.replica_deaths:
            for part in args.replica_deaths.split(","):
                rid, t = part.split(":")
                deaths[int(rid)] = float(t)
        autoscale = None
        if args.autoscale:
            if not (args.min_replicas <= args.replicas
                    <= args.max_replicas):
                ap.error(f"--replicas {args.replicas} must start within "
                         f"[--min-replicas {args.min_replicas}, "
                         f"--max-replicas {args.max_replicas}]")
            autoscale = AutoscaleConfig(
                min_replicas=args.min_replicas,
                max_replicas=args.max_replicas, policy=args.scale_policy,
                cold_start=cold_start, cooldown=args.scale_cooldown,
                # the shared estimator window tunes the FORECAST-led
                # policy only (its reactive fallback stays comparable);
                # a plain reactive run keeps its own default window
                **({"rate_window": args.forecast_window}
                   if args.scale_policy == "predictive" else {}))
        # one shared ForecastConfig for the engines' predictive join
        # windows and (via the coordinator_forecast rule) the
        # coordinator-level forecaster behind --scale-policy predictive
        forecast = (ForecastConfig(window=args.forecast_window)
                    if args.predictive_joins
                    or (autoscale and autoscale.policy == "predictive")
                    else None)
        ccfg = simulator.ClusterConfig(
            n_replicas=args.replicas, workers_per_replica=args.workers,
            placement=args.placement, placement_seed=args.seed,
            slo=slo_ms / 1e3, fault_times=faults, replica_deaths=deaths,
            load_on_switch=args.load_on_switch,
            continuous_batching=args.continuous_batching,
            predictive_joins=args.predictive_joins, forecast=forecast,
            autoscale=autoscale)
        res = simulator.simulate_cluster(arr, prof, pol, ccfg)
        st = res.stats()
        extra = {"replicas": args.replicas, "placement": args.placement,
                 "load_imbalance": st["load_imbalance"],
                 "per_replica_served": {r: v["served"]
                                        for r, v in st["replicas"].items()}}
        if res.forecast is not None:
            extra["forecast"] = {k: None if v is None else round(v, 4)
                                 for k, v in res.forecast.items()}
            extra["predictive_windows"] = res.n_predictive_windows
        if args.autoscale:
            extra.update({
                "autoscale_policy": args.scale_policy,
                "replicas_total": res.n_replicas,   # ever existed
                "replica_seconds": res.replica_seconds,
                "goodput_per_replica_second":
                    st.get("goodput_per_replica_second", 0.0),
                "scale_events": [
                    {"t": round(e.t, 4), "kind": e.kind, "rid": e.rid,
                     "committed": e.n_committed, "signal": round(e.signal, 3)}
                    for e in res.scale_events]})
    else:
        faults = {}
        if args.faults:
            for part in args.faults.split(","):
                wid, t = part.split(":")
                faults[int(wid)] = float(t)
        scfg = simulator.SimConfig(n_workers=args.workers,
                                   slo=slo_ms / 1e3,
                                   load_on_switch=args.load_on_switch,
                                   fault_times=faults, seed=args.seed,
                                   continuous_batching=args.continuous_batching,
                                   predictive_joins=args.predictive_joins,
                                   forecast=(ForecastConfig(
                                       window=args.forecast_window)
                                       if args.predictive_joins else None))
        res = simulator.simulate(arr, prof, pol, scfg)
        extra = ({"predictive_windows": res.n_predictive_windows}
                 if args.predictive_joins else {})
    st = res.stats()
    out = {"arch": args.arch, "policy": pol.name, "queries": len(arr),
           "continuous_batching": args.continuous_batching,
           "slo_attainment": res.slo_attainment, "mean_acc": res.mean_acc,
           "p50_latency_ms": res.latency_p50 * 1e3,
           "p99_latency_ms": res.latency_p99 * 1e3,
           "join_rate": res.n_joins / max(len(arr), 1),
           "switch_rate": st["switch_rate"],
           "actuation_seconds": st["actuation_seconds"], **extra}
    print(json.dumps(out, indent=1))


if __name__ == "__main__":
    main()
