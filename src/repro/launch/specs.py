"""ShapeDtypeStruct stand-ins + shardings for every dry-run cell.

``input_specs(cfg, shape)`` returns the abstract inputs of the step
function that cell lowers (train_step for ``train_*``, prefill for
``prefill_*``, serve_step/decode for ``decode_*``/``long_*``) — weak-
type-correct, shardable, zero device allocation.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig, ShapeSpec
from repro.core import subnet as sn
from repro.distributed.sharding import ShardingPlan
from repro.models import lm


def sds(shape, dtype) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(tuple(int(s) for s in shape), jnp.dtype(dtype))


def ctrl_specs(cfg: ArchConfig) -> Dict[str, jax.ShapeDtypeStruct]:
    ctrl = sn.make_control(cfg, sn.max_subnet(cfg))
    return {k: sds(np.asarray(v).shape, np.asarray(v).dtype) for k, v in ctrl.items()}


def param_specs(cfg: ArchConfig) -> Any:
    return jax.eval_shape(lambda: lm.init_model(jax.random.PRNGKey(0), cfg))


def batch_specs(cfg: ArchConfig, shape: ShapeSpec, *, with_labels: bool) -> Dict[str, Any]:
    B, S = shape.global_batch, shape.seq_len
    out: Dict[str, Any] = {}
    if cfg.frontend == "embed" and shape.kind != "decode":
        out["embeds"] = sds((B, S, cfg.d_model), cfg.dtype)
    else:
        out["tokens"] = sds((B, S), jnp.int32)
    if with_labels:
        out["labels"] = sds((B, S), jnp.int32)
    return out


def cache_specs(cfg: ArchConfig, shape: ShapeSpec) -> Any:
    return jax.eval_shape(
        lambda: lm.init_cache(cfg, shape.global_batch, shape.seq_len))


def input_specs(cfg: ArchConfig, shape: ShapeSpec) -> Dict[str, Any]:
    """Abstract inputs per cell kind. Keys mirror the step signatures."""
    if shape.kind == "train":
        return {
            "params": param_specs(cfg),
            "batch": batch_specs(cfg, shape, with_labels=True),
            "ctrl": ctrl_specs(cfg),
        }
    if shape.kind == "prefill":
        return {
            "params": param_specs(cfg),
            "batch": batch_specs(cfg, shape, with_labels=False),
            "ctrl": ctrl_specs(cfg),
        }
    # decode: one new token against a seq_len-deep cache
    return {
        "params": param_specs(cfg),
        "tokens": sds((shape.global_batch, 1), jnp.int32),
        "ctrl": ctrl_specs(cfg),
        "cache": cache_specs(cfg, shape),
        "index": sds((), jnp.int32),
    }


def input_shardings(plan: ShardingPlan, cfg: ArchConfig, shape: ShapeSpec,
                    specs: Dict[str, Any]) -> Dict[str, Any]:
    out: Dict[str, Any] = {"params": plan.params(specs["params"]),
                           "ctrl": plan.replicated(specs["ctrl"])}
    if "batch" in specs:
        out["batch"] = plan.batch(specs["batch"])
    if "tokens" in specs:
        out["tokens"] = plan.named(plan.batch_spec("tokens", specs["tokens"].shape))
    if "cache" in specs:
        out["cache"] = plan.cache(specs["cache"])
    if "index" in specs:
        out["index"] = plan.named(jax.sharding.PartitionSpec())
    return out


def attention_flops(cfg: ArchConfig, shape: ShapeSpec) -> float:
    """Quadratic attention FLOPs (score + value matmuls), not part of
    the 6*N*D convention but real compiled work. Causal => /2; sliding
    window bounds the context; SSM/xLSTM layers contribute ~0."""
    n_attn = sum(s.pattern.count("attn") * s.repeat for s in cfg.stages)
    if cfg.shared_attn_period:
        n_attn += sum(s.repeat for s in cfg.stages) // cfg.shared_attn_period
    if n_attn == 0:
        return 0.0
    B, S = shape.global_batch, shape.seq_len
    hd = cfg.resolved_head_dim
    ctx = min(S, cfg.sliding_window) if cfg.sliding_window else S
    if shape.kind == "decode":
        per_layer = 4.0 * B * 1 * ctx * cfg.n_heads * hd
    else:
        per_layer = 4.0 * B * S * (ctx / 2.0) * cfg.n_heads * hd
    mult = 3.0 if shape.kind == "train" else 1.0
    return per_layer * n_attn * mult


def analytic_flops(cfg: ArchConfig, shape: ShapeSpec, *,
                   remat: bool = False) -> float:
    """Lower-bound total FLOPs of the compiled step: MODEL_FLOPS (+1/3
    recompute under remat for train) + quadratic attention. Used to
    correct cost_analysis(), which does not scale lax.scan/while bodies
    by their trip counts on the CPU backend."""
    mf = model_flops(cfg, shape)
    if shape.kind == "train" and remat:
        mf *= 4.0 / 3.0
    return mf + attention_flops(cfg, shape)


def model_flops(cfg: ArchConfig, shape: ShapeSpec) -> float:
    """MODEL_FLOPS for the roofline ratio: 6*N*D train (fwd+bwd),
    2*N*D prefill, 2*N*B decode — N_active for MoE (flops_per_token
    already counts active experts only)."""
    f_tok = sn.flops_per_token(cfg)                 # == 2*N_active
    if shape.kind == "train":
        return 3.0 * f_tok * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return float(f_tok) * shape.global_batch * shape.seq_len
    return float(f_tok) * shape.global_batch
