"""Training launcher: sandwich-rule supernet training with atomic
checkpointing + restart.

    PYTHONPATH=src python -m repro.launch.train --arch qwen2-1.5b \
        --reduced --steps 100 --ckpt-dir /tmp/ck

``--reduced`` trains the CPU-feasible family variant; the full configs
are exercised via the dry-run (ShapeDtypeStructs only). Re-invoking the
same command resumes from the latest valid checkpoint.
"""
from __future__ import annotations

import argparse

import jax

from repro.configs import get_config
from repro.training import data, optimizer as opt
from repro.training.trainer import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--n-random", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=25)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    task = data.SyntheticTask(vocab_size=cfg.vocab_size, seq_len=args.seq_len,
                              global_batch=args.batch, seed=0, order=1,
                              noise=0.01)
    tcfg = TrainerConfig(total_steps=args.steps, ckpt_every=args.ckpt_every,
                         ckpt_dir=args.ckpt_dir)
    ocfg = opt.AdamWConfig(lr=args.lr, warmup_steps=max(args.steps // 10, 1),
                           total_steps=args.steps)
    tr = Trainer(cfg, ocfg, tcfg, task, n_random=args.n_random)
    st = tr.resume_or_init(jax.random.PRNGKey(0))
    if st.step:
        print(f"resumed from checkpoint at step {st.step}")
    st = tr.run(st)
    print(f"done: step {st.step}, loss {st.losses[0]:.3f} -> "
          f"{st.losses[-1]:.3f}, stragglers {len(st.straggler_steps)}")


if __name__ == "__main__":
    main()
