"""Model substrate: attention/FFN/MoE/SSM/xLSTM blocks, the
scan-over-layers backbone, LM step functions, and the conv SuperNet."""
