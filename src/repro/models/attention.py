"""Attention: GQA/MHA with RoPE / M-RoPE / partial-rotary, sliding
window, SubNetAct head elasticity, flash (blockwise online-softmax)
prefill and cached decode.

The blockwise-`lax.scan` implementation here is the XLA path (and the
oracle). Model blocks resolve their default impl through the kernel
dispatcher (`repro.kernels.ops.model_flash_attention` /
`model_decode_attention`): the Pallas TPU kernels on TPU, this XLA path
on CPU hosts — one code path, backend picked per process.
"""
from __future__ import annotations

from functools import partial
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ArchConfig
from repro.core import operators as ops
from repro.models.common import dense_init, ones_table

NEG_INF = -1e30


# --------------------------------------------------------------------------
# Rotary embeddings
# --------------------------------------------------------------------------


def rope_freqs(head_dim_rot: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim_rot, 2, dtype=jnp.float32) / head_dim_rot))


def _rotate_half(x):
    x1, x2 = jnp.split(x, 2, axis=-1)
    return jnp.concatenate([-x2, x1], axis=-1)


def apply_rope(x, positions, theta: float, rotary_pct: float = 1.0,
               mrope_sections: Tuple[int, ...] = ()):
    """x: (B, S, H, hd). positions: (B, S) int32, or (3, B, S) for M-RoPE.

    M-RoPE (qwen2-vl): head_dim/2 frequency slots are partitioned into
    ``mrope_sections`` (temporal, h, w); each section takes its angle
    from the corresponding position stream.
    """
    hd = x.shape[-1]
    rot = int(hd * rotary_pct)
    rot -= rot % 2
    if rot == 0:
        return x
    x_rot, x_pass = x[..., :rot], x[..., rot:]
    inv = rope_freqs(rot, theta)                      # (rot/2,)

    if mrope_sections:
        # positions: (3, B, S); build per-frequency angle source.
        sec = jnp.concatenate([jnp.full((s,), i, jnp.int32) for i, s in enumerate(mrope_sections)])
        sec = sec[: rot // 2]
        pos = jnp.take(positions, sec, axis=0)        # (rot/2, B, S) gathered per freq
        ang = jnp.einsum("fbs,f->bsf", pos.astype(jnp.float32), inv)
    else:
        ang = positions.astype(jnp.float32)[..., None] * inv   # (B, S, rot/2)
    ang = jnp.concatenate([ang, ang], axis=-1)[:, :, None, :]  # (B,S,1,rot)
    x_rot = x_rot * jnp.cos(ang).astype(x.dtype) + _rotate_half(x_rot) * jnp.sin(ang).astype(x.dtype)
    return jnp.concatenate([x_rot, x_pass], axis=-1)


# --------------------------------------------------------------------------
# Blockwise flash attention (XLA path / oracle)
# --------------------------------------------------------------------------


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    q_offset=0, kv_len=None, q_block: int = 512,
                    kv_block: int = 512, scale: Optional[float] = None):
    """Block-skipping online-softmax attention.

    q: (B, Hq, Sq, hd); k/v: (B, Hkv, Sk, hd) with Hq % Hkv == 0.
    ``q_offset``: absolute position of q[0] (decode/chunked prefill).
    ``kv_len``: traced valid KV length (cache); None = all of Sk.
    ``window``: sliding-window size (0 = full).
    Memory: O(Sq_block * Sk_block). Each q block's kv scan covers only
    the blocks inside its causal frontier and sliding window (the same
    liveness logic as the Pallas kernel), so causal prefill tracks the
    ~S^2/2 triangle rather than S^2 — a dead block's softmax mass is
    exactly zero, so skipping is numerics-preserving. The static
    skipping needs a Python-int ``q_offset``; a traced offset keeps the
    full masked scan.
    """
    B, Hq, Sq, hd = q.shape
    _, Hkv, Sk, _ = k.shape
    G = Hq // Hkv
    scale = scale if scale is not None else hd ** -0.5

    qb = min(q_block, Sq) if q_block else Sq
    kb = min(kv_block, Sk) if kv_block else Sk
    n_q, n_k = -(-Sq // qb), -(-Sk // kb)
    pad_q, pad_k = n_q * qb - Sq, n_k * kb - Sk
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad_k), (0, 0)))

    qr = q.reshape(B, Hkv, G, n_q, qb, hd).astype(jnp.float32)
    kr = k.reshape(B, Hkv, n_k, kb, hd).astype(jnp.float32)
    vr = v.reshape(B, Hkv, n_k, kb, hd).astype(jnp.float32)

    q_pos = q_offset + lax.iota(jnp.int32, n_q * qb).reshape(n_q, qb)
    k_pos = lax.iota(jnp.int32, n_k * kb).reshape(n_k, kb)
    valid_k = jnp.asarray(Sk if kv_len is None else kv_len, jnp.int32)
    off_static = q_offset if isinstance(q_offset, int) else None

    def q_step(qblk, qp, lo: int, hi: int):
        def kv_step(carry, ki):
            m, l, acc = carry
            kblk, vblk, kp = ki
            s = jnp.einsum("bhgqd,bhkd->bhgqk", qblk, kblk) * scale
            mask = kp[None, :] < valid_k
            if causal:
                mask &= kp[None, :] <= qp[:, None]
            if window:
                mask &= kp[None, :] > qp[:, None] - window
            s = jnp.where(mask[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))
            # mask again: fully-dead rows would otherwise get exp(0)=1
            p = jnp.exp(s - m_new[..., None]) * mask[None, None, None]
            corr = jnp.exp(m - m_new)
            l = l * corr + p.sum(axis=-1)
            acc = acc * corr[..., None] + jnp.einsum("bhgqk,bhkd->bhgqd", p, vblk)
            return (m_new, l, acc), None

        m0 = jnp.full((B, Hkv, G, qb), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, Hkv, G, qb), jnp.float32)
        a0 = jnp.zeros((B, Hkv, G, qb, hd), jnp.float32)
        (m, l, acc), _ = lax.scan(
            kv_step, (m0, l0, a0),
            (jnp.moveaxis(kr[:, :, lo:hi], 2, 0),
             jnp.moveaxis(vr[:, :, lo:hi], 2, 0), k_pos[lo:hi]))
        return acc / jnp.maximum(l, 1e-30)[..., None]

    outs = []
    for qi in range(n_q):
        lo, hi = 0, n_k
        if off_static is not None:
            q0 = off_static + qi * qb
            if causal:
                hi = min(hi, (q0 + qb - 1) // kb + 1)
            if window:
                lo = min(max(lo, (q0 - window + 1) // kb), n_k)
            if isinstance(kv_len, int):
                hi = min(hi, -(-kv_len // kb))
        if hi <= lo:       # every key dead for this q block
            outs.append(jnp.zeros((B, Hkv, G, qb, hd), jnp.float32))
        else:
            outs.append(q_step(qr[:, :, :, qi], q_pos[qi], lo, hi))
    out = jnp.stack(outs, axis=3).reshape(B, Hq, n_q * qb, hd)
    return out[:, :, :Sq].astype(v.dtype)


def decode_attention(q, k_cache, v_cache, *, index, window: int = 0):
    """Single-step attention over a cache.

    q: (B, Hq, 1, hd); caches: (B, Hkv, Smax, hd); ``index`` = traced
    absolute position of the new token. Rolling caches (window > 0)
    store positions modulo Smax.
    """
    B, Hq, _, hd = q.shape
    _, Hkv, Smax, _ = k_cache.shape
    G = Hq // Hkv
    qf = q.reshape(B, Hkv, G, hd).astype(jnp.float32)
    s = jnp.einsum("bhgd,bhkd->bhgk", qf, k_cache.astype(jnp.float32)) * hd ** -0.5
    pos = lax.iota(jnp.int32, Smax)
    if window:
        slot_age = (index - pos) % Smax                # rolling buffer age
        mask = (slot_age < jnp.minimum(window, index + 1))
    else:
        mask = pos <= index
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgk,bhkd->bhgd", p, v_cache.astype(jnp.float32))
    return o.reshape(B, Hq, 1, hd).astype(v_cache.dtype)


# --------------------------------------------------------------------------
# Attention block (params + forward), SubNetAct-elastic
# --------------------------------------------------------------------------


def init_attention(key, cfg: ArchConfig, dtype) -> Dict:
    d, hd = cfg.d_model, cfg.resolved_head_dim
    Hq, Hkv = cfg.n_heads, cfg.n_kv_heads
    ks = jax.random.split(key, 5)
    p = {
        "wq": dense_init(ks[0], (d, Hq * hd), dtype),
        "wk": dense_init(ks[1], (d, Hkv * hd), dtype),
        "wv": dense_init(ks[2], (d, Hkv * hd), dtype),
        "wo": dense_init(ks[3], (Hq * hd, d), dtype),
        "norm_gamma": ones_table(cfg.elastic.num_subnets, d),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((Hq * hd,), dtype)
        p["bk"] = jnp.zeros((Hkv * hd,), dtype)
        p["bv"] = jnp.zeros((Hkv * hd,), dtype)
    if cfg.norm == "layernorm":
        p["norm_beta"] = jnp.zeros((cfg.elastic.num_subnets, d), jnp.float32)
    return p


def _project_qkv(p, cfg: ArchConfig, x, positions):
    hd = cfg.resolved_head_dim
    B, S, _ = x.shape
    q = x @ p["wq"] + (p.get("bq", 0))
    k = x @ p["wk"] + (p.get("bk", 0))
    v = x @ p["wv"] + (p.get("bv", 0))
    q = q.reshape(B, S, cfg.n_heads, hd)
    k = k.reshape(B, S, cfg.n_kv_heads, hd)
    v = v.reshape(B, S, cfg.n_kv_heads, hd)
    if cfg.pos_embed == "rope":
        q = apply_rope(q, positions, cfg.rope_theta, cfg.rotary_pct, cfg.mrope_sections)
        k = apply_rope(k, positions, cfg.rope_theta, cfg.rotary_pct, cfg.mrope_sections)
    return q, k, v


def head_mask(cfg: ArchConfig, o, head_width):
    """Zero the outputs of inactive query heads. o: (..., Hq, hd).

    GQA: active heads are a per-KV-group prefix (cache layout stays
    identical across subnets); MHA: a global prefix."""
    from repro.core.subnet import head_group_size
    Hq = cfg.n_heads
    group = head_group_size(cfg)
    if group > 1:
        kv = Hq // group
        per_group = head_width // kv
        m = (lax.iota(jnp.int32, Hq) % group) < per_group
    else:
        m = lax.iota(jnp.int32, Hq) < head_width
    shape = [1] * o.ndim
    shape[-2] = Hq
    return o * m.reshape(shape).astype(o.dtype)


def attention_block(p, cfg: ArchConfig, x, ctrl, positions, *,
                    slice_mode: str = "mask", attn_impl=None,
                    q_block: int = 512, kv_block: int = 512):
    """Full-sequence attention with pre-norm. x: (B,S,d) -> (B,S,d).

    ``attn_impl=None`` resolves through the kernel dispatcher (Pallas on
    TPU, the XLA blockwise path otherwise), with ``q_block``/``kv_block``
    plumbed through to whichever tier serves the call; pass an impl
    explicitly to pin a tier (tests, benchmarks) — the block sizes only
    bind to the dispatcher default, since a pinned impl chooses its own.
    """
    if attn_impl is None:
        from repro.kernels.ops import model_flash_attention
        attn_impl = partial(model_flash_attention, q_block=q_block,
                            kv_block=kv_block)
    h = ops.subnet_norm(x, p["norm_gamma"], ctrl["subnet_id"],
                        beta_table=p.get("norm_beta"), eps=cfg.norm_eps, kind=cfg.norm)
    q, k, v = _project_qkv(p, cfg, h, positions)
    B, S, Hq, hd = q.shape
    from repro.core.subnet import head_group_size
    group = head_group_size(cfg)
    kv = Hq // group

    if slice_mode == "switch" and len(cfg.elastic.head_fracs) > 1:
        from repro.core.subnet import width_options
        opts = width_options(cfg)["heads"]

        def branch(kh: int):
            if group > 1:
                # per-KV-group prefix: every KV head keeps serving
                a = kh // kv
                qs = q.reshape(B, S, kv, group, hd)[:, :, :, :a]
                qs = qs.reshape(B, S, kv * a, hd)
                o = attn_impl(qs.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                              v.transpose(0, 2, 1, 3), causal=True,
                              window=cfg.sliding_window)
                o = o.transpose(0, 2, 1, 3).reshape(B, S, kv * a * hd)
                wo = p["wo"].reshape(kv, group, hd, cfg.d_model)[:, :a]
                return o @ wo.reshape(kv * a * hd, cfg.d_model)
            # MHA: q and k/v prefixes drop together
            o = attn_impl(q[:, :, :kh].transpose(0, 2, 1, 3),
                          k[:, :, :kh].transpose(0, 2, 1, 3),
                          v[:, :, :kh].transpose(0, 2, 1, 3),
                          causal=True, window=cfg.sliding_window)
            o = o.transpose(0, 2, 1, 3).reshape(B, S, kh * hd)
            return o @ lax.slice(p["wo"], (0, 0), (kh * hd, cfg.d_model))

        y = ops.switch_over_widths(ctrl["head_bucket"], opts, branch)
    else:
        o = attn_impl(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                      v.transpose(0, 2, 1, 3), causal=True,
                      window=cfg.sliding_window)
        o = o.transpose(0, 2, 1, 3)                   # (B,S,H,hd)
        # WeightSlice(mask): zero the *outputs* of inactive heads —
        # paper-faithful routing (inactive channels contribute nothing).
        o = head_mask(cfg, o, ctrl["head_width"])
        y = o.reshape(B, S, Hq * hd) @ p["wo"]
    return x + y.astype(x.dtype)


def attention_decode(p, cfg: ArchConfig, x, ctrl, cache, index, *,
                     slice_mode: str = "mask", decode_impl=None,
                     kv_block: int = 512):
    """One-token decode. x: (B,1,d); cache: {'k','v'}: (B,Hkv,Smax,hd).

    ``decode_impl=None`` resolves through the kernel dispatcher;
    ``kv_block`` (cache chunk for block-skipping tiers) binds only to
    the dispatcher default."""
    if decode_impl is None:
        from repro.kernels.ops import model_decode_attention
        decode_impl = partial(model_decode_attention, kv_block=kv_block)
    h = ops.subnet_norm(x, p["norm_gamma"], ctrl["subnet_id"],
                        beta_table=p.get("norm_beta"), eps=cfg.norm_eps, kind=cfg.norm)
    B = x.shape[0]
    hd = cfg.resolved_head_dim
    pos_shape = (3, B, 1) if cfg.mrope_sections else (B, 1)
    positions = jnp.broadcast_to(jnp.asarray(index, jnp.int32), pos_shape)
    q, k, v = _project_qkv(p, cfg, h, positions)
    Smax = cache["k"].shape[2]
    slot = index % Smax if cfg.sliding_window else index
    k_cache = lax.dynamic_update_slice(cache["k"], k.transpose(0, 2, 1, 3).astype(cache["k"].dtype),
                                       (0, 0, slot, 0))
    v_cache = lax.dynamic_update_slice(cache["v"], v.transpose(0, 2, 1, 3).astype(cache["v"].dtype),
                                       (0, 0, slot, 0))
    o = decode_impl(q.transpose(0, 2, 1, 3), k_cache, v_cache,
                    index=index, window=cfg.sliding_window)
    o = o.transpose(0, 2, 1, 3)                        # (B,1,H,hd)
    o = head_mask(cfg, o, ctrl["head_width"])
    y = o.reshape(B, 1, cfg.n_heads * hd) @ p["wo"]
    return x + y.astype(x.dtype), {"k": k_cache, "v": v_cache}


def init_attention_cache(cfg: ArchConfig, batch: int, seq_len: int, dtype) -> Dict:
    Smax = min(seq_len, cfg.sliding_window) if cfg.sliding_window else seq_len
    shape = (batch, cfg.n_kv_heads, Smax, cfg.resolved_head_dim)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}
