"""Backbone engine: scan-over-layers execution of Stage patterns with
SubNetAct LayerSelect gating, per-kind caches for decode, zamba2-style
shared attention, and optional remat.

Parameters for each stage are stacked along a leading ``repeat`` axis
(compile time O(1) in depth). The device-side control tuple (``ctrl``)
is pure data — actuating a different subnet never recompiles.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ArchConfig, Stage
from repro.models import attention as attn_mod
from repro.models import ffn as ffn_mod
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models import xlstm as xlstm_mod
from repro.models.common import stack_init

# kind -> (init, full_fn(p,cfg,x,ctrl,pos,...), decode_fn(p,cfg,x,ctrl,cache,idx),
#          cache_init(cfg,batch,seq,dtype) | None)
_REG: Dict[str, Tuple] = {}


def _register(kind, init, full, decode, cache_init):
    _REG[kind] = (init, full, decode, cache_init)


_register(
    "attn", attn_mod.init_attention,
    lambda p, cfg, x, ctrl, pos, sm, impl=None: attn_mod.attention_block(
        p, cfg, x, ctrl, pos, slice_mode=sm, attn_impl=impl),
    lambda p, cfg, x, ctrl, cache, idx, sm: attn_mod.attention_decode(p, cfg, x, ctrl, cache, idx, slice_mode=sm),
    lambda cfg, b, s, dt: attn_mod.init_attention_cache(cfg, b, s, dt),
)
_register(
    "mlp", ffn_mod.init_mlp,
    lambda p, cfg, x, ctrl, pos, sm: ffn_mod.mlp_block(p, cfg, x, ctrl, slice_mode=sm),
    lambda p, cfg, x, ctrl, cache, idx, sm: (ffn_mod.mlp_block(p, cfg, x, ctrl, slice_mode=sm), cache),
    None,
)
_register(
    "moe", moe_mod.init_moe,
    lambda p, cfg, x, ctrl, pos, sm, ng=1, ga=None: moe_mod.moe_block(p, cfg, x, ctrl, slice_mode=sm, n_groups=ng, group_axes=ga),
    lambda p, cfg, x, ctrl, cache, idx, sm: (moe_mod.moe_block(p, cfg, x, ctrl, slice_mode=sm), cache),
    None,
)
_register(
    "mamba", ssm_mod.init_mamba,
    lambda p, cfg, x, ctrl, pos, sm: ssm_mod.mamba_block(p, cfg, x, ctrl, slice_mode=sm),
    lambda p, cfg, x, ctrl, cache, idx, sm: ssm_mod.mamba_decode(p, cfg, x, ctrl, cache, idx),
    lambda cfg, b, s, dt: ssm_mod.init_mamba_cache(cfg, b, dt),
)
_register(
    "mlstm", xlstm_mod.init_mlstm,
    lambda p, cfg, x, ctrl, pos, sm: xlstm_mod.mlstm_block(p, cfg, x, ctrl, slice_mode=sm),
    lambda p, cfg, x, ctrl, cache, idx, sm: xlstm_mod.mlstm_decode(p, cfg, x, ctrl, cache, idx),
    lambda cfg, b, s, dt: xlstm_mod.init_mlstm_cache(cfg, b, dt),
)
_register(
    "slstm", xlstm_mod.init_slstm,
    lambda p, cfg, x, ctrl, pos, sm: xlstm_mod.slstm_block(p, cfg, x, ctrl, slice_mode=sm),
    lambda p, cfg, x, ctrl, cache, idx, sm: xlstm_mod.slstm_decode(p, cfg, x, ctrl, cache, idx),
    lambda cfg, b, s, dt: xlstm_mod.init_slstm_cache(cfg, b, dt),
)


def _slot(j: int, kind: str) -> str:
    return f"{j}:{kind}"


# --------------------------------------------------------------------------
# init
# --------------------------------------------------------------------------


def init_backbone(key, cfg: ArchConfig, dtype) -> Dict:
    params: Dict[str, Any] = {"stages": []}
    keys = jax.random.split(key, len(cfg.stages) + 1)
    for si, stage in enumerate(cfg.stages):
        sp = {}
        skeys = jax.random.split(keys[si], len(stage.pattern))
        for j, kind in enumerate(stage.pattern):
            init = _REG[kind][0]
            sp[_slot(j, kind)] = stack_init(lambda k, kd=kind: _REG[kd][0](k, cfg, dtype),
                                            skeys[j], stage.repeat)
        params["stages"].append(sp)
    if cfg.shared_attn_period:
        k1, k2 = jax.random.split(keys[-1])
        # zamba2-style shared transformer block (attention + MLP), the
        # same weights re-applied every `shared_attn_period` units.
        params["shared_attn"] = attn_mod.init_attention(k1, cfg, dtype)
        if cfg.d_ff:
            params["shared_mlp"] = ffn_mod.init_mlp(k2, cfg, dtype)
    return params


# --------------------------------------------------------------------------
# full-sequence forward (train / prefill)
# --------------------------------------------------------------------------


def backbone_forward(params, cfg: ArchConfig, x, ctrl, positions, *,
                     slice_mode: str = "mask", remat: bool = False,
                     moe_groups: int = 1, moe_group_axes=None,
                     attn_impl=None):
    """x: (B, S, d) -> (B, S, d).

    ``attn_impl=None`` lets each attention block resolve through the
    kernel dispatcher; pass one to pin a tier end-to-end (tests)."""
    gates_all = ctrl["layer_gate"]
    offset = 0
    for si, stage in enumerate(cfg.stages):
        sp = params["stages"][si]
        gates = lax.dynamic_slice_in_dim(gates_all, offset, stage.repeat)
        offset += stage.repeat

        def unit(x, unit_p, gate, r_idx, stage=stage, si=si):
            def body(xx):
                for j, kind in enumerate(stage.pattern):
                    fn = _REG[kind][1]
                    if kind == "moe":
                        xx = fn(unit_p[_slot(j, kind)], cfg, xx, ctrl, positions,
                                slice_mode, moe_groups, moe_group_axes)
                    elif kind == "attn":
                        xx = fn(unit_p[_slot(j, kind)], cfg, xx, ctrl, positions,
                                slice_mode, attn_impl)
                    else:
                        xx = fn(unit_p[_slot(j, kind)], cfg, xx, ctrl, positions,
                                slice_mode)
                return xx

            # LayerSelect: one executable serves every depth.
            x = lax.cond(gate, body, lambda xx: xx, x)
            if cfg.shared_attn_period and "shared_attn" in params:
                use = jnp.logical_and(
                    gate, (r_idx % cfg.shared_attn_period) == cfg.shared_attn_period - 1)
                def shared_block(xx):
                    xx = attn_mod.attention_block(
                        params["shared_attn"], cfg, xx, ctrl, positions,
                        slice_mode=slice_mode, attn_impl=attn_impl)
                    if "shared_mlp" in params:
                        xx = ffn_mod.mlp_block(params["shared_mlp"], cfg, xx,
                                               ctrl, slice_mode=slice_mode)
                    return xx

                x = lax.cond(use, shared_block, lambda xx: xx, x)
            return x

        if remat:
            unit = jax.checkpoint(unit, static_argnums=())

        def scan_body(x, inp):
            unit_p, gate, r_idx = inp
            return unit(x, unit_p, gate, r_idx), None

        x, _ = lax.scan(scan_body, x, (sp, gates, jnp.arange(stage.repeat)))
    return x


# --------------------------------------------------------------------------
# caches
# --------------------------------------------------------------------------


def init_cache(cfg: ArchConfig, batch: int, seq_len: int, dtype) -> Dict:
    """Nested cache pytree. Leading dim of each stage-leaf = repeat."""
    cache: Dict[str, Any] = {"stages": []}
    for stage in cfg.stages:
        sc = {}
        for j, kind in enumerate(stage.pattern):
            ci = _REG[kind][3]
            if ci is None:
                continue
            one = ci(cfg, batch, seq_len, dtype)
            sc[_slot(j, kind)] = jax.tree.map(
                lambda a: jnp.broadcast_to(a, (stage.repeat,) + a.shape).copy(), one)
        cache["stages"].append(sc)
    if cfg.shared_attn_period:
        n_inv = sum(s.repeat for s in cfg.stages) // cfg.shared_attn_period
        n_inv = max(n_inv, 1)
        one = attn_mod.init_attention_cache(cfg, batch, seq_len, dtype)
        cache["shared_attn"] = jax.tree.map(
            lambda a: jnp.broadcast_to(a, (n_inv,) + a.shape).copy(), one)
    return cache


# --------------------------------------------------------------------------
# decode step
# --------------------------------------------------------------------------


def backbone_decode(params, cfg: ArchConfig, x, ctrl, cache, index, *,
                    slice_mode: str = "mask", cache_constraints=None):
    """One-token decode. x: (B, 1, d) -> ((B, 1, d), new_cache).

    ``cache_constraints``: optional per-stage tree of NamedShardings
    (per-layer leaf shapes) applied to each updated cache slice inside
    the scan — without it the SPMD partitioner may choose a bad layout
    for the scan's cache accumulator (measured: a sequence- or
    head_dim-sharded KV cache silently re-materializes replicated,
    +100 GB/device on llama4 decode_32k).
    """
    gates_all = ctrl["layer_gate"]
    offset = 0
    new_cache: Dict[str, Any] = {"stages": [], "shared_attn": cache.get("shared_attn")}
    shared_state = (cache.get("shared_attn"), jnp.int32(0))

    for si, stage in enumerate(cfg.stages):
        sp = params["stages"][si]
        sc = cache["stages"][si]
        gates = lax.dynamic_slice_in_dim(gates_all, offset, stage.repeat)
        offset += stage.repeat
        constraint = cache_constraints[si] if cache_constraints else None

        def scan_body(carry, inp, stage=stage, constraint=constraint):
            x, shared_cache, inv_counter = carry
            unit_p, unit_c, gate, r_idx = inp

            def body(op):
                xx, uc = op
                uc = dict(uc)
                for j, kind in enumerate(stage.pattern):
                    slot = _slot(j, kind)
                    dec = _REG[kind][2]
                    xx, upd = dec(unit_p[slot], cfg, xx, ctrl,
                                  uc.get(slot), index, slice_mode)
                    if slot in uc:
                        uc[slot] = upd
                return xx, uc

            x, unit_c = lax.cond(gate, body, lambda op: op, (x, unit_c))
            if constraint is not None:
                unit_c = jax.tree.map(lax.with_sharding_constraint,
                                      unit_c, constraint)

            if cfg.shared_attn_period and shared_cache is not None:
                use = jnp.logical_and(
                    gate, (r_idx % cfg.shared_attn_period) == cfg.shared_attn_period - 1)

                def do_shared(op):
                    xx, shc, cnt = op
                    ci = jax.tree.map(lambda c: lax.dynamic_index_in_dim(c, cnt, 0, keepdims=False), shc)
                    xx, cn = attn_mod.attention_decode(
                        params["shared_attn"], cfg, xx, ctrl, ci, index,
                        slice_mode=slice_mode)
                    shc = jax.tree.map(
                        lambda c, n: lax.dynamic_update_index_in_dim(c, n, cnt, 0), shc, cn)
                    if "shared_mlp" in params:
                        xx = ffn_mod.mlp_block(params["shared_mlp"], cfg, xx,
                                               ctrl, slice_mode=slice_mode)
                    return xx, shc, cnt + 1

                x, shared_cache, inv_counter = lax.cond(
                    use, do_shared, lambda op: op, (x, shared_cache, inv_counter))
            return (x, shared_cache, inv_counter), unit_c

        (x, shared_cache, counter), updated = lax.scan(
            scan_body, (x,) + shared_state, (sp, sc, gates, jnp.arange(stage.repeat)))
        shared_state = (shared_cache, counter)
        new_cache["stages"].append(updated)

    new_cache["shared_attn"] = shared_state[0]
    if new_cache["shared_attn"] is None:
        new_cache.pop("shared_attn")
    return x, new_cache
