"""Shared model utilities: initialization, dtype policy, param trees."""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np

Params = Dict[str, Any]


def dtype_of(cfg) -> jnp.dtype:
    return jnp.dtype(cfg.dtype)


def dense_init(key, shape, dtype, scale: float = 1.0):
    """Truncated-normal fan-in init (LM standard)."""
    fan_in = shape[0] if len(shape) > 1 else shape[0]
    std = scale / np.sqrt(max(fan_in, 1))
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32) * std).astype(dtype)


def zeros_init(_key, shape, dtype):
    return jnp.zeros(shape, dtype)


def ones_table(n_subnets: int, d: int, dtype=jnp.float32):
    """SubnetNorm gain table, initialized shared (gamma == 1 for every
    subnet); calibration/training specializes rows."""
    return jnp.ones((n_subnets, d), dtype)


def param_bytes(tree) -> int:
    return sum(x.size * x.dtype.itemsize for x in jax.tree_util.tree_leaves(tree))


def param_count(tree) -> int:
    return sum(x.size for x in jax.tree_util.tree_leaves(tree))


def split_keys(key, n: int):
    return jax.random.split(key, n)


def stack_init(init_fn, key, repeat: int):
    """Initialize ``repeat`` copies of a sub-block and stack every leaf
    along a new leading axis (scan-over-layers layout)."""
    keys = jax.random.split(key, repeat)
    return jax.vmap(init_fn)(keys)
