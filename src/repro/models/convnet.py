"""OFA-ResNet SuperNet (the paper's own serving architecture) with
SubNetAct operators, including *true BatchNorm* SubnetNorm: per-subnet
(mu, sigma) tables calibrated offline (core/calibrate.py), exactly the
paper's §3 bookkeeping.

Residual bottleneck units; elastic dims:
  D (depth)         — LayerSelect gates the last units of each stage,
  E (expand ratio)  — WeightSlice on the bottleneck mid channels,
  W (width mult)    — WeightSlice on the stage output channels.

NHWC layout; mask-mode WeightSlice (paper-faithful routing semantics).
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ArchConfig
from repro.core import operators as ops
from repro.core.subnet import SubnetDescriptor, stage_gates
from repro.models.common import dense_init

import numpy as np


def _conv_init(key, kh, kw, cin, cout, dtype=jnp.float32):
    fan_in = kh * kw * cin
    std = (2.0 / fan_in) ** 0.5
    return jax.random.normal(key, (kh, kw, cin, cout), dtype) * std


def _conv(x, w, stride: int = 1):
    return lax.conv_general_dilated(
        x, w, (stride, stride), "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))


def _bn_tables(n_subnets: int, c: int) -> Dict:
    """Per-subnet BatchNorm statistics + shared affine params."""
    return {
        "mean": jnp.zeros((n_subnets, c), jnp.float32),
        "var": jnp.ones((n_subnets, c), jnp.float32),
        "gamma": jnp.ones((c,), jnp.float32),
        "beta": jnp.zeros((c,), jnp.float32),
    }


def _bn(x, t, subnet_id, eps=1e-5):
    return ops.subnet_batch_norm(x, t["mean"], t["var"], t["gamma"], t["beta"],
                                 subnet_id, eps=eps)


def _bn_batch(x, t, stats: Dict, site: str, eps=1e-5):
    """Training-mode BN: normalize with *batch* statistics and record
    them (SubnetNorm calibration, paper §3). x: (B, H, W, C)."""
    xf = x.astype(jnp.float32)
    mu = xf.mean(axis=(0, 1, 2))
    var = xf.var(axis=(0, 1, 2))
    stats[site] = (mu, var)
    y = (xf - mu) * lax.rsqrt(var + eps) * t["gamma"] + t["beta"]
    return y.astype(x.dtype)


def init_convnet(key, cfg: ArchConfig) -> Dict:
    ns = cfg.elastic.num_subnets
    widths = cfg.conv_stage_widths
    keys = jax.random.split(key, 2 + sum(s.repeat for s in cfg.stages))
    stem_w = max(64, widths[0] // 4)
    params: Dict = {
        "stem": {"w": _conv_init(keys[0], 3, 3, 3, stem_w), "bn": _bn_tables(ns, stem_w)},
        "stages": [],
    }
    ki = 1
    cin = stem_w
    for si, stage in enumerate(cfg.stages):
        cout = widths[si]
        mid = cout // 4
        units = []
        for r in range(stage.repeat):
            ks = jax.random.split(keys[ki], 4)
            ki += 1
            u = {
                "w1": _conv_init(ks[0], 1, 1, cin if r == 0 else cout, mid),
                "bn1": _bn_tables(ns, mid),
                "w2": _conv_init(ks[1], 3, 3, mid, mid),
                "bn2": _bn_tables(ns, mid),
                "w3": _conv_init(ks[2], 1, 1, mid, cout),
                "bn3": _bn_tables(ns, cout),
            }
            if r == 0:
                u["proj"] = _conv_init(ks[3], 1, 1, cin, cout)
                u["bn_proj"] = _bn_tables(ns, cout)
            units.append(u)
        params["stages"].append(units)
        cin = cout
    params["head"] = dense_init(keys[-1], (widths[-1], cfg.n_classes), jnp.float32)
    return params


def convnet_forward(params, cfg: ArchConfig, images, ctrl, *,
                    collect_stats: bool = False, static_gates=None):
    """images: (B, H, W, 3) -> logits (B, n_classes).

    ``collect_stats=True`` is the SubnetNorm calibration path: BN uses
    batch statistics and returns them per site. Depth gating is then
    resolved in Python (``static_gates``) — calibration runs offline,
    per subnet, so recompilation is off the critical path (paper §5,
    Supernet Profiler).
    """
    sid = ctrl["subnet_id"]
    gates = static_gates if collect_stats else ctrl["layer_gate"]
    # E / W control: fraction of mid / out channels active.
    e_frac = ctrl["conv_e_frac"]
    w_frac = ctrl["conv_w_frac"]
    stats: Dict = {}

    def bn(x, t, site):
        if collect_stats:
            return _bn_batch(x, t, stats, site)
        return _bn(x, t, sid)

    x = jax.nn.relu(bn(_conv(images, params["stem"]["w"], 2), params["stem"]["bn"], "stem"))
    gi = 0
    for si, stage in enumerate(cfg.stages):
        cout = cfg.conv_stage_widths[si]
        mid = cout // 4
        active_mid = jnp.maximum(8, (e_frac * mid).astype(jnp.int32))
        # W applies to intermediate stages only (final width feeds the head).
        if si < len(cfg.stages) - 1:
            active_out = jnp.maximum(8, (w_frac * cout).astype(jnp.int32))
        else:
            active_out = jnp.int32(cout)
        for r, u in enumerate(params["stages"][si]):
            gate = gates[gi]
            gi += 1
            stride = 2 if r == 0 else 1

            def body(xx, u=u, stride=stride, active_mid=active_mid,
                     active_out=active_out, si=si, r=r):
                pre = f"s{si}u{r}."
                h = jax.nn.relu(bn(_conv(xx, u["w1"], stride), u["bn1"], pre + "bn1"))
                h = ops.slice_mask(h, active_mid)            # WeightSlice(E)
                h = jax.nn.relu(bn(_conv(h, u["w2"]), u["bn2"], pre + "bn2"))
                h = ops.slice_mask(h, active_mid)
                h = bn(_conv(h, u["w3"]), u["bn3"], pre + "bn3")
                if "proj" in u:
                    res = bn(_conv(xx, u["proj"], stride), u["bn_proj"], pre + "bn_proj")
                else:
                    res = xx
                y = jax.nn.relu(res + h)
                return ops.slice_mask(y, active_out)         # WeightSlice(W)

            if r == 0:
                x = body(x)                                  # stage entry always runs
            elif collect_stats:
                if bool(gate):
                    x = body(x)
            else:
                x = lax.cond(gate, body, lambda xx: xx, x)   # LayerSelect(D)
    x = x.mean(axis=(1, 2))                                  # global average pool
    logits = x @ params["head"]
    if collect_stats:
        return logits, stats
    return logits


def make_conv_control(cfg: ArchConfig, sub: SubnetDescriptor) -> Dict[str, np.ndarray]:
    """Conv control tuple: (D, E, W) exactly as the paper's §3 inputs."""
    return {
        "layer_gate": stage_gates(cfg, sub.depth_frac),
        "conv_e_frac": np.float32(sub.ffn_frac),
        "conv_w_frac": np.float32(sub.head_frac),
        "subnet_id": np.int32(sub.subnet_id),
    }
