"""Dense FFN (SwiGLU) with SubNetAct width elasticity (WeightSlice)."""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ArchConfig
from repro.core import operators as ops
from repro.models.common import dense_init, ones_table


def init_mlp(key, cfg: ArchConfig, dtype) -> Dict:
    d, f = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    p = {
        "wu": dense_init(ks[1], (d, f), dtype),
        "wd": dense_init(ks[2], (f, d), dtype),
        "norm_gamma": ones_table(cfg.elastic.num_subnets, d),
    }
    if cfg.ffn_act == "swiglu":
        p["wg"] = dense_init(ks[0], (d, f), dtype)
    if cfg.norm == "layernorm":
        p["norm_beta"] = jnp.zeros((cfg.elastic.num_subnets, d), jnp.float32)
    return p


def mlp_block(p, cfg: ArchConfig, x, ctrl, *, slice_mode: str = "mask"):
    """Pre-norm SwiGLU/GELU FFN with elastic d_ff. x: (..., d) -> (..., d)."""
    h = ops.subnet_norm(x, p["norm_gamma"], ctrl["subnet_id"],
                        beta_table=p.get("norm_beta"), eps=cfg.norm_eps, kind=cfg.norm)

    def act(hh, wg, wu):
        if cfg.ffn_act == "swiglu":
            return jax.nn.silu(hh @ wg) * (hh @ wu)
        return jax.nn.gelu(hh @ wu)

    if slice_mode == "switch" and len(cfg.elastic.ffn_fracs) > 1:
        from repro.core.subnet import width_options
        opts = width_options(cfg)["ffn"]

        def branch(kf: int):
            wg = (lax.slice(p["wg"], (0, 0), (cfg.d_model, kf))
                  if "wg" in p else None)
            wu = lax.slice(p["wu"], (0, 0), (cfg.d_model, kf))
            wd = lax.slice(p["wd"], (0, 0), (kf, cfg.d_model))
            return act(h, wg, wu) @ wd

        y = ops.switch_over_widths(ctrl["ffn_bucket"], opts, branch)
    else:
        a = act(h, p.get("wg"), p["wu"])
        # WeightSlice(mask): zeroing hidden channels beyond the active
        # width makes the down-proj rows for those channels inert.
        a = ops.slice_mask(a, ctrl["ffn_width"])
        y = a @ p["wd"]
    return x + y.astype(x.dtype)
