"""LM wrapper: embeddings, final norm, head, loss, and the three step
functions (train / prefill / decode) that the launcher, dry-run, tests
and serving runtime all share.

``frontend='embed'`` archs (qwen2-vl, musicgen) take precomputed
patch/frame embeddings for train/prefill — the modality frontend is a
stub per the assignment; decode always consumes token ids.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ArchConfig
from repro.core import operators as ops
from repro.models import backbone as bb
from repro.models.common import dense_init, ones_table


def init_model(key, cfg: ArchConfig, dtype=None) -> Dict:
    dtype = jnp.dtype(dtype or cfg.dtype)
    k_emb, k_bb, k_head = jax.random.split(key, 3)
    params = {
        "embed": dense_init(k_emb, (cfg.vocab_size, cfg.d_model), dtype, scale=1.0),
        "backbone": bb.init_backbone(k_bb, cfg, dtype),
        "final_gamma": ones_table(cfg.elastic.num_subnets, cfg.d_model),
    }
    if not cfg.tie_embeddings:
        params["head"] = dense_init(k_head, (cfg.d_model, cfg.vocab_size), dtype)
    return params


def _head(params, cfg: ArchConfig, x, ctrl):
    h = ops.subnet_norm(x, params["final_gamma"], ctrl["subnet_id"],
                        eps=cfg.norm_eps, kind=cfg.norm)
    w = params.get("head")
    if w is None:
        w = params["embed"].T
    return h @ w


def default_positions(cfg: ArchConfig, batch: int, seq: int):
    pos = jnp.broadcast_to(jnp.arange(seq, dtype=jnp.int32), (batch, seq))
    if cfg.mrope_sections:
        return jnp.broadcast_to(pos, (3, batch, seq))
    return pos


def embed_inputs(params, cfg: ArchConfig, batch: Dict[str, Any]):
    """tokens (B,S) int32 or embeds (B,S,d)."""
    if cfg.frontend == "embed" and "embeds" in batch:
        return batch["embeds"].astype(params["embed"].dtype)
    return jnp.take(params["embed"], batch["tokens"], axis=0)


def sinusoid_pos(positions, d: int, dtype):
    """Classic sinusoidal absolute embedding (musicgen). positions (B,S)."""
    half = d // 2
    freq = jnp.exp(-jnp.log(10_000.0) * jnp.arange(half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freq        # (B,S,half)
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1).astype(dtype)


# --------------------------------------------------------------------------
# step functions
# --------------------------------------------------------------------------


def forward(params, cfg: ArchConfig, batch, ctrl, *, slice_mode="mask",
            remat=False, moe_groups=1, moe_group_axes=None, attn_impl=None):
    x = embed_inputs(params, cfg, batch)
    B, S = x.shape[:2]
    positions = batch.get("positions")
    if positions is None:
        positions = default_positions(cfg, B, S)
    if cfg.pos_embed == "sinusoidal":
        pos2d = positions if positions.ndim == 2 else positions[0]
        x = x + sinusoid_pos(pos2d, cfg.d_model, x.dtype)
    x = bb.backbone_forward(params["backbone"], cfg, x, ctrl, positions,
                            slice_mode=slice_mode, remat=remat,
                            moe_groups=moe_groups, moe_group_axes=moe_group_axes,
                            attn_impl=attn_impl)
    return _head(params, cfg, x, ctrl)


def loss_fn(params, cfg: ArchConfig, batch, ctrl, *, slice_mode="mask",
            remat=False, moe_groups=1, moe_group_axes=None, z_loss: float = 1e-4):
    logits = forward(params, cfg, batch, ctrl, slice_mode=slice_mode,
                     remat=remat, moe_groups=moe_groups,
                     moe_group_axes=moe_group_axes).astype(jnp.float32)
    labels = batch["labels"]
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - ll
    mask = batch.get("loss_mask")
    if mask is None:
        mask = jnp.ones_like(nll)
    loss = (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)
    if z_loss:
        loss = loss + z_loss * ((lse * mask) ** 2).sum() / jnp.maximum(mask.sum(), 1.0)
    return loss


def prefill(params, cfg: ArchConfig, batch, ctrl, *, slice_mode="mask",
            moe_groups=1, moe_group_axes=None):
    """Serving prefill: logits for the final position only."""
    logits = forward(params, cfg, batch, ctrl, slice_mode=slice_mode,
                     moe_groups=moe_groups, moe_group_axes=moe_group_axes)
    return logits[:, -1:, :]


def decode_step(params, cfg: ArchConfig, tokens, ctrl, cache, index, *,
                slice_mode="mask", cache_constraints=None):
    """tokens: (B,1) int32; returns (logits (B,1,V), new_cache)."""
    x = jnp.take(params["embed"], tokens, axis=0)
    if cfg.pos_embed == "sinusoidal":
        pos = jnp.broadcast_to(jnp.asarray(index, jnp.int32), tokens.shape)
        x = x + sinusoid_pos(pos, cfg.d_model, x.dtype)
    x, cache = bb.backbone_decode(params["backbone"], cfg, x, ctrl, cache, index,
                                  slice_mode=slice_mode,
                                  cache_constraints=cache_constraints)
    return _head(params, cfg, x, ctrl), cache


def init_cache(cfg: ArchConfig, batch: int, seq_len: int, dtype=None):
    return bb.init_cache(cfg, batch, seq_len, jnp.dtype(dtype or cfg.dtype))


# --------------------------------------------------------------------------
# tiny generate loop (examples / integration tests only)
# --------------------------------------------------------------------------

_DECODE_STEP_CACHE: Dict[Tuple[ArchConfig, str], Any] = {}


def cached_decode_step(cfg: ArchConfig, slice_mode: str = "mask"):
    """Module-level jitted decode step, keyed on ``(cfg, slice_mode)``
    with the control tuple as a *traced* argument: repeated ``generate``
    calls — even actuating different subnets — compile the step exactly
    once per (cfg, geometry) instead of once per call."""
    key = (cfg, slice_mode)
    fn = _DECODE_STEP_CACHE.get(key)
    if fn is None:
        fn = jax.jit(lambda p, t, ctrl, c, i: decode_step(
            p, cfg, t, ctrl, c, i, slice_mode=slice_mode))
        _DECODE_STEP_CACHE[key] = fn
    return fn


def generate(params, cfg: ArchConfig, prompt, ctrl, max_new: int, seq_cap: int = 256):
    """Greedy decode; prompt teacher-forced through the decode path so it
    works uniformly across attention/SSM/xLSTM families."""
    B, P = prompt.shape
    cache = init_cache(cfg, B, seq_cap)
    step = cached_decode_step(cfg)
    tok = prompt[:, :1]
    out = [tok]
    for i in range(P + max_new - 1):
        logits, cache = step(params, tok, ctrl, cache, jnp.int32(i))
        nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
        tok = prompt[:, i + 1: i + 2] if i + 1 < P else nxt
        out.append(tok)
    return jnp.concatenate(out, axis=1)
