"""Mixture-of-Experts with argsort/capacity dispatch (TPU-native,
"dropping" strategy a la MaxText) and SubNetAct elasticity:

* elastic top-k (``ctrl['topk']`` masks routing slots — MoE's
  WeightSlice translation),
* elastic per-expert d_ff (mask or switch mode),
* optional shared expert (llama4-style).

Dispatch is grouped: tokens are reshaped to ``(n_groups, N_g, d)`` and
all sort/scatter ops are vmapped over groups. The ShardingPlan sets
``n_groups`` = the data-axis size so every dispatch op stays *local*
under SPMD — no global sorts, no accidental collectives.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ArchConfig
from repro.core import operators as ops
from repro.models.common import dense_init, ones_table


def init_moe(key, cfg: ArchConfig, dtype) -> Dict:
    d, f, E = cfg.d_model, cfg.resolved_moe_d_ff, cfg.n_experts
    ks = jax.random.split(key, 8)
    p = {
        "router": dense_init(ks[0], (d, E), jnp.float32),
        "wg": dense_init(ks[1], (E, d, f), dtype),
        "wu": dense_init(ks[2], (E, d, f), dtype),
        "wd": dense_init(ks[3], (E, f, d), dtype),
        "norm_gamma": ones_table(cfg.elastic.num_subnets, d),
    }
    if cfg.shared_expert:
        p["swg"] = dense_init(ks[4], (d, f), dtype)
        p["swu"] = dense_init(ks[5], (d, f), dtype)
        p["swd"] = dense_init(ks[6], (f, d), dtype)
    return p


def _capacity(n_tokens: int, cfg: ArchConfig) -> int:
    k_max = max(cfg.top_k, max(cfg.elastic.topk_options or (cfg.top_k,)))
    cap = int(n_tokens * k_max * cfg.capacity_factor / max(cfg.n_experts, 1))
    return max(8, -(-cap // 8) * 8)


def _dispatch_one_group(x, logits, topk_active, cfg: ArchConfig, capacity: int):
    """Dispatch one token group. x: (N, d); logits: (N, E) fp32.

    Returns (slots (E, C, d), combine metadata).
    """
    N, d = x.shape
    E = cfg.n_experts
    k_max = max(cfg.top_k, max(cfg.elastic.topk_options or (cfg.top_k,)))

    gate_logits, eids = lax.top_k(logits, k_max)             # (N, k)
    gates = jax.nn.softmax(gate_logits, axis=-1)
    # SubNetAct elastic top-k: slots >= active k are masked out. The
    # routing table is data; actuating k never touches weights.
    slot_live = lax.iota(jnp.int32, k_max)[None, :] < topk_active
    gates = jnp.where(slot_live, gates, 0.0)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    gates = jnp.where(slot_live, gates, 0.0)

    flat_e = eids.reshape(-1)                                 # (N*k,)
    flat_live = jnp.broadcast_to(slot_live, (N, k_max)).reshape(-1).astype(jnp.int32)
    # Group assignments by expert (stable ⇒ deterministic drop order).
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    sorted_live = flat_live[order]
    idx = lax.iota(jnp.int32, N * k_max)
    first_of_e = jnp.searchsorted(sorted_e, sorted_e, side="left")
    pos_in_e = idx - first_of_e
    keep = (pos_in_e < capacity) & (sorted_live > 0)
    dest = jnp.where(keep, sorted_e * capacity + pos_in_e, E * capacity)  # overflow bucket

    src_token = order // k_max                                # (N*k,)
    gathered = jnp.take(x, src_token, axis=0)                 # (N*k, d)
    slots = jnp.zeros((E * capacity + 1, d), x.dtype).at[dest].set(
        jnp.where(keep[:, None], gathered, 0))
    slots = slots[:-1].reshape(E, capacity, d)
    meta = dict(order=order, src_token=src_token, dest=dest, keep=keep,
                gates=gates.reshape(-1)[order])
    return slots, meta


def _combine_one_group(expert_out, meta, N: int):
    """expert_out: (E, C, d) -> (N, d) weighted combine."""
    E, C, d = expert_out.shape
    flat = expert_out.reshape(E * C, d)
    flat = jnp.concatenate([flat, jnp.zeros((1, d), flat.dtype)], axis=0)
    y_sorted = jnp.take(flat, jnp.minimum(meta["dest"], E * C), axis=0)
    w = (meta["gates"] * meta["keep"]).astype(flat.dtype)[:, None]
    return jnp.zeros((N, d), flat.dtype).at[meta["src_token"]].add(y_sorted * w)


def moe_block(p, cfg: ArchConfig, x, ctrl, *, slice_mode: str = "mask",
              n_groups: int = 1, group_axes=None):
    """Pre-norm MoE. x: (B, S, d) -> (B, S, d).

    ``group_axes``: mesh axis names the group dim is sharded over (the
    DP axes). Constraining it keeps every dispatch sort/scatter LOCAL to
    its data shard — without the constraint the partitioner may gather
    the (G, E, C, d) slot tensor across the mesh (measured +37 GB/device
    of all-gather on mixtral prefill_32k)."""
    from jax.sharding import PartitionSpec as _P

    def pin(t, n_lead_sharded=1):
        if group_axes is None:
            return t
        spec = _P(group_axes, *([None] * (t.ndim - 1)))
        return lax.with_sharding_constraint(t, spec)

    B, S, d = x.shape
    h = ops.subnet_norm(x, p["norm_gamma"], ctrl["subnet_id"], eps=cfg.norm_eps,
                        kind=cfg.norm)
    N = B * S
    n_groups = max(1, min(n_groups, N))
    while N % n_groups:
        n_groups -= 1
    Ng = N // n_groups
    hg = pin(h.reshape(n_groups, Ng, d))
    logits = (hg.astype(jnp.float32) @ p["router"])           # (G, Ng, E)
    cap = _capacity(Ng, cfg)

    slots, meta = jax.vmap(
        lambda xx, ll: _dispatch_one_group(xx, ll, ctrl["topk"], cfg, cap)
    )(hg, logits)                                             # slots: (G,E,C,d)
    slots = pin(slots)

    f = cfg.resolved_moe_d_ff
    if slice_mode == "switch" and len(cfg.elastic.ffn_fracs) > 1:
        from repro.core.subnet import width_options
        opts = width_options(cfg)["moe_ffn"]

        def branch(kf: int):
            wg = lax.slice(p["wg"], (0, 0, 0), (cfg.n_experts, d, kf))
            wu = lax.slice(p["wu"], (0, 0, 0), (cfg.n_experts, d, kf))
            wd = lax.slice(p["wd"], (0, 0, 0), (cfg.n_experts, kf, d))
            a = jax.nn.silu(jnp.einsum("gecd,edf->gecf", slots, wg))
            a = a * jnp.einsum("gecd,edf->gecf", slots, wu)
            return jnp.einsum("gecf,efd->gecd", a, wd)

        out = ops.switch_over_widths(ctrl["ffn_bucket"], opts, branch)
    else:
        a = jax.nn.silu(jnp.einsum("gecd,edf->gecf", slots, p["wg"]))
        a = a * jnp.einsum("gecd,edf->gecf", slots, p["wu"])
        a = ops.slice_mask(a, ctrl["moe_ffn_width"])
        out = jnp.einsum("gecf,efd->gecd", a, p["wd"])

    # combine in the model dtype: an f32 expert output would double the
    # bytes of the cross-model reduction behind the f-sharded wd
    out = pin(out.astype(x.dtype))
    y = jax.vmap(lambda eo, m: _combine_one_group(eo, m, Ng))(out, meta)
    y = pin(y).reshape(B, S, d)

    if cfg.shared_expert:
        a = jax.nn.silu(h @ p["swg"]) * (h @ p["swu"])
        a = ops.slice_mask(a, ctrl["moe_ffn_width"])
        y = y + a @ p["swd"]
    return x + y.astype(x.dtype)
