"""Mamba2 (SSD, state-space duality) block — chunked parallel prefill /
train path and O(1)-state decode path.

Single B/C group (ngroups=1), multi-head states (B, H, N, P) with
N = ssm_state, P = ssm_head_dim. The chunked algorithm is
O(S·Q + S·N·P) per token stream — sub-quadratic, which is what makes
zamba2/xlstm eligible for the long_500k shape.

Width elasticity is *not* applied to state dimensions (recurrence would
be corrupted mid-stream — see DESIGN.md §Arch-applicability); depth
elasticity (LayerSelect) applies at the block level in the backbone.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ArchConfig
from repro.core import operators as ops
from repro.models.common import dense_init, ones_table


def _dims(cfg: ArchConfig):
    d_in = cfg.ssm_expand * cfg.d_model
    n_heads = d_in // cfg.ssm_head_dim
    conv_ch = d_in + 2 * cfg.ssm_state          # conv over [x, B, C]
    return d_in, n_heads, conv_ch


def init_mamba(key, cfg: ArchConfig, dtype) -> Dict:
    d = cfg.d_model
    d_in, H, conv_ch = _dims(cfg)
    N = cfg.ssm_state
    ks = jax.random.split(key, 5)
    proj_out = 2 * d_in + 2 * N + H             # z, x, B, C, dt
    p = {
        "w_in": dense_init(ks[0], (d, proj_out), dtype),
        "conv_w": dense_init(ks[1], (cfg.ssm_conv_width, conv_ch), dtype, scale=1.0),
        "conv_b": jnp.zeros((conv_ch,), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H).astype(jnp.float32)),
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "gated_norm": jnp.ones((d_in,), jnp.float32),
        "w_out": dense_init(ks[2], (d_in, d), dtype),
        "norm_gamma": ones_table(cfg.elastic.num_subnets, d),
    }
    return p


def _split_proj(cfg: ArchConfig, zxbcdt):
    d_in, H, _ = _dims(cfg)
    N = cfg.ssm_state
    z, xc, B_, C_, dt = jnp.split(
        zxbcdt, [d_in, 2 * d_in, 2 * d_in + N, 2 * d_in + 2 * N], axis=-1)
    return z, xc, B_, C_, dt


def _causal_conv(xBC, conv_w, conv_b):
    """Depthwise causal conv. xBC: (B, S, C); conv_w: (W, C)."""
    W = conv_w.shape[0]
    pad = jnp.pad(xBC, ((0, 0), (W - 1, 0), (0, 0)))
    out = sum(pad[:, i: i + xBC.shape[1], :] * conv_w[i] for i in range(W))
    return jax.nn.silu(out + conv_b)


def _gated_out(p, cfg, y, z, x_res):
    d_in, _, _ = _dims(cfg)
    g = y * jax.nn.silu(z)
    gf = g.astype(jnp.float32)
    gf = gf * lax.rsqrt(jnp.mean(jnp.square(gf), -1, keepdims=True) + cfg.norm_eps)
    g = (gf * p["gated_norm"]).astype(y.dtype)
    return x_res + (g @ p["w_out"]).astype(x_res.dtype)


def mamba_block(p, cfg: ArchConfig, x, ctrl, *, slice_mode: str = "mask"):
    """Chunked SSD forward. x: (B, S, d) -> (B, S, d)."""
    Bsz, S, d = x.shape
    d_in, H, _ = _dims(cfg)
    N, P = cfg.ssm_state, cfg.ssm_head_dim
    Q = min(cfg.ssm_chunk, S)
    while S % Q:
        Q -= 1
    nC = S // Q

    h = ops.subnet_norm(x, p["norm_gamma"], ctrl["subnet_id"], eps=cfg.norm_eps,
                        kind=cfg.norm)
    z, xc, B_, C_, dt = _split_proj(cfg, h @ p["w_in"])
    xBC = _causal_conv(jnp.concatenate([xc, B_, C_], -1), p["conv_w"], p["conv_b"])
    xc, B_, C_ = jnp.split(xBC, [d_in, d_in + N], axis=-1)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])       # (B,S,H)
    A = -jnp.exp(p["A_log"])                                          # (H,)
    dA = dt * A                                                       # (B,S,H) < 0

    X = xc.reshape(Bsz, S, H, P).astype(jnp.float32)
    Bc = B_.reshape(Bsz, nC, Q, N).astype(jnp.float32)
    Cc = C_.reshape(Bsz, nC, Q, N).astype(jnp.float32)
    Xc = X.reshape(Bsz, nC, Q, H, P)
    dtc = dt.reshape(Bsz, nC, Q, H)
    g = jnp.cumsum(dA.reshape(Bsz, nC, Q, H), axis=2)                 # (B,c,Q,H)

    # --- intra-chunk (quadratic within chunk only) ---
    CB = jnp.einsum("bcqn,bckn->bcqk", Cc, Bc)
    causal = jnp.tril(jnp.ones((Q, Q), bool))[None, None, :, :, None]
    # mask the exponent BEFORE exp: non-causal entries are exp of large
    # positive values (inf) whose where-gradient would be NaN
    diff = g[:, :, :, None, :] - g[:, :, None, :, :]                  # (B,c,Q,K,H)
    L = jnp.exp(jnp.where(causal, diff, -1e30))
    M = CB[..., None] * L * dtc[:, :, None, :, :]                     # (B,c,Q,K,H)
    Y_intra = jnp.einsum("bcqkh,bckhp->bcqhp", M, Xc)

    # --- chunk boundary states + inter-chunk recurrence ---
    g_last = g[:, :, -1, :]                                           # (B,c,H)
    decay_states = jnp.exp(g_last[:, :, None, :] - g) * dtc           # (B,c,Q,H)
    S_c = jnp.einsum("bcqn,bcqh,bcqhp->bchnp", Bc, decay_states, Xc)  # (B,c,H,N,P)

    def chunk_scan(prev, inp):
        s_c, decay = inp                                              # (B,H,N,P), (B,H)
        new = prev * decay[:, :, None, None] + s_c
        return new, prev

    init = jnp.zeros((Bsz, H, N, P), jnp.float32)
    _, states_prev = lax.scan(chunk_scan, init,
                              (jnp.moveaxis(S_c, 1, 0), jnp.moveaxis(jnp.exp(g_last), 1, 0)))
    states_prev = jnp.moveaxis(states_prev, 0, 1)                     # (B,c,H,N,P)

    Y_inter = jnp.einsum("bcqn,bchnp,bcqh->bcqhp", Cc, states_prev, jnp.exp(g))
    Y = (Y_intra + Y_inter + p["D"][None, None, None, :, None] * Xc)
    Y = Y.reshape(Bsz, S, d_in).astype(x.dtype)
    return _gated_out(p, cfg, Y, z, x)


def init_mamba_cache(cfg: ArchConfig, batch: int, dtype) -> Dict:
    d_in, H, conv_ch = _dims(cfg)
    return {
        "conv": jnp.zeros((batch, cfg.ssm_conv_width - 1, conv_ch), dtype),
        "ssm": jnp.zeros((batch, H, cfg.ssm_state, cfg.ssm_head_dim), jnp.float32),
    }


def mamba_decode(p, cfg: ArchConfig, x, ctrl, cache, index):
    """One-token decode. x: (B,1,d); O(1) state update."""
    Bsz = x.shape[0]
    d_in, H, conv_ch = _dims(cfg)
    N, P = cfg.ssm_state, cfg.ssm_head_dim
    h = ops.subnet_norm(x, p["norm_gamma"], ctrl["subnet_id"], eps=cfg.norm_eps,
                        kind=cfg.norm)
    z, xc, B_, C_, dt = _split_proj(cfg, (h @ p["w_in"])[:, 0])       # (B, *)

    xBC_new = jnp.concatenate([xc, B_, C_], -1)                       # (B, conv_ch)
    window = jnp.concatenate([cache["conv"], xBC_new[:, None]], 1)    # (B, W, C)
    conv_out = jax.nn.silu(jnp.einsum("bwc,wc->bc", window, p["conv_w"]) + p["conv_b"])
    new_conv = window[:, 1:]
    xc, B_, C_ = jnp.split(conv_out, [d_in, d_in + N], axis=-1)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])       # (B,H)
    A = -jnp.exp(p["A_log"])
    decay = jnp.exp(dt * A)                                           # (B,H)
    X = xc.reshape(Bsz, H, P).astype(jnp.float32)
    dBx = jnp.einsum("bn,bh,bhp->bhnp", B_.astype(jnp.float32), dt, X)
    state = cache["ssm"] * decay[:, :, None, None] + dBx
    y = jnp.einsum("bn,bhnp->bhp", C_.astype(jnp.float32), state)
    y = y + p["D"][None, :, None] * X
    y = y.reshape(Bsz, 1, d_in).astype(x.dtype)
    out = _gated_out(p, cfg, y, z[:, None], x)
    return out, {"conv": new_conv, "ssm": state}
