"""xLSTM blocks: mLSTM (matrix memory, parallel/chunked via a
flash-style log-space gated form) and sLSTM (scalar memory, inherently
sequential -> lax.scan over time; O(1)-state decode).

Canonical semantics (the tests' oracle) is the stabilized recurrence of
the xLSTM paper:

    m_t = max(m_{t-1} + logf_t, i_t)
    C_t = e^{m_{t-1}+logf_t-m_t} C_{t-1} + e^{i_t-m_t} k_t v_t^T
    n_t = e^{m_{t-1}+logf_t-m_t} n_{t-1} + e^{i_t-m_t} k_t
    h_t = (C_t^T q_t) / max(|n_t . q_t|, e^{-m_t})

The parallel form used for train/prefill is the exact unrolled
equivalent: exponent e_ij = LF_i - LF_j + i_j (LF = cumsum log f),
running row-max == m_t, computed blockwise (flash) so memory stays
O(block^2). Recurrent *state* dims are not SubNetAct-elastic (see
DESIGN.md §Arch-applicability); depth elasticity applies per block.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ArchConfig
from repro.core import operators as ops
from repro.models.common import dense_init, ones_table

NEG_INF = -1e30


def _mlstm_dims(cfg: ArchConfig):
    d_in = int(cfg.mlstm_proj_factor * cfg.d_model)
    H = cfg.n_heads
    d_qk = d_in // 2
    return d_in, H, d_qk


# --------------------------------------------------------------------------
# mLSTM
# --------------------------------------------------------------------------


def init_mlstm(key, cfg: ArchConfig, dtype) -> Dict:
    d = cfg.d_model
    d_in, H, d_qk = _mlstm_dims(cfg)
    ks = jax.random.split(key, 7)
    return {
        "w_up": dense_init(ks[0], (d, 2 * d_in), dtype),     # x_in, z
        "wq": dense_init(ks[1], (d_in, d_qk), dtype),
        "wk": dense_init(ks[2], (d_in, d_qk), dtype),
        "w_if": dense_init(ks[3], (d_in, 2 * H), jnp.float32),
        "b_if": jnp.concatenate([jnp.zeros((H,)), jnp.linspace(3.0, 6.0, H)]).astype(jnp.float32),
        "w_out": dense_init(ks[4], (d_in, d), dtype),
        "norm_gamma": ones_table(cfg.elastic.num_subnets, d),
        "head_norm": jnp.ones((d_in,), jnp.float32),
    }


def gla_flash(q, k, v, LF, b, *, q_offset=0, block: int = 256):
    """Blockwise gated-linear-attention (the mLSTM parallel form).

    q,k: (B,H,S,dqk); v: (B,H,S,dv); LF: (B,H,S) cumulative log-forget;
    b:  (B,H,S) per-key exponent (i_j - LF_j). Returns (B,H,S,dv).
    """
    B, H, S, dqk = q.shape
    dv = v.shape[-1]
    blk = min(block, S)
    n = -(-S // blk)
    pad = n * blk - S

    def padk(x):
        return jnp.pad(x, [(0, 0)] * (x.ndim - 2) + [(0, pad)] + [(0, 0)] * (x.ndim - 3 == 0))

    if pad:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
        LF = jnp.pad(LF, ((0, 0), (0, 0), (0, pad)))
        b = jnp.pad(b, ((0, 0), (0, 0), (0, pad)), constant_values=NEG_INF)

    qr = jnp.moveaxis(q.reshape(B, H, n, blk, dqk), 2, 0).astype(jnp.float32)
    kr = jnp.moveaxis(k.reshape(B, H, n, blk, dqk), 2, 0).astype(jnp.float32)
    vr = jnp.moveaxis(v.reshape(B, H, n, blk, dv), 2, 0).astype(jnp.float32)
    LFr = jnp.moveaxis(LF.reshape(B, H, n, blk), 2, 0)
    br = jnp.moveaxis(b.reshape(B, H, n, blk), 2, 0)
    pos = lax.iota(jnp.int32, n * blk).reshape(n, blk)

    def q_step(_, qi):
        qblk, LFq, qp = qi

        def kv_step(carry, ki):
            m, l, acc = carry
            kblk, vblk, bk, kp = ki
            e = LFq[..., :, None] + bk[..., None, :]             # (B,H,q,k)
            mask = kp[None, :] <= qp[:, None]
            e = jnp.where(mask[None, None], e, NEG_INF)
            m_new = jnp.maximum(m, e.max(axis=-1))
            w = jnp.exp(e - m_new[..., None])
            s = jnp.einsum("bhqd,bhkd->bhqk", qblk, kblk) * (qblk.shape[-1] ** -0.5)
            p = s * w
            corr = jnp.exp(m - m_new)
            l = l * corr + p.sum(axis=-1)
            acc = acc * corr[..., None] + jnp.einsum("bhqk,bhkd->bhqd", p, vblk)
            return (m_new, l, acc), None

        m0 = jnp.full((B, H, blk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, H, blk), jnp.float32)
        a0 = jnp.zeros((B, H, blk, dv), jnp.float32)
        (m, l, acc), _ = lax.scan(kv_step, (m0, l0, a0), (kr, vr, br, pos))
        den = jnp.maximum(jnp.abs(l), jnp.exp(-m))
        return None, acc / den[..., None]

    _, out = lax.scan(q_step, None, (qr, LFr, pos))
    out = jnp.moveaxis(out, 0, 2).reshape(B, H, n * blk, dv)
    return out[:, :, :S]


def mlstm_block(p, cfg: ArchConfig, x, ctrl, *, slice_mode: str = "mask"):
    B, S, d = x.shape
    d_in, H, d_qk = _mlstm_dims(cfg)
    h = ops.subnet_norm(x, p["norm_gamma"], ctrl["subnet_id"], eps=cfg.norm_eps,
                        kind=cfg.norm)
    up = h @ p["w_up"]
    x_in, z = jnp.split(up, 2, axis=-1)                       # (B,S,d_in)
    q = (x_in @ p["wq"]).reshape(B, S, H, d_qk // H).transpose(0, 2, 1, 3)
    k = (x_in @ p["wk"]).reshape(B, S, H, d_qk // H).transpose(0, 2, 1, 3)
    v = x_in.reshape(B, S, H, d_in // H).transpose(0, 2, 1, 3)

    gates = x_in.astype(jnp.float32) @ p["w_if"] + p["b_if"]  # (B,S,2H)
    i_raw, f_raw = jnp.split(gates, 2, axis=-1)
    lf = jax.nn.log_sigmoid(f_raw)                            # (B,S,H)
    LF = jnp.cumsum(lf, axis=1).transpose(0, 2, 1)            # (B,H,S)
    b = (i_raw - jnp.cumsum(lf, axis=1)).transpose(0, 2, 1)   # i_j - LF_j

    o = gla_flash(q, k, v, LF, b)                             # (B,H,S,dv)
    o = o.transpose(0, 2, 1, 3).reshape(B, S, d_in)
    of = o * lax.rsqrt(jnp.mean(jnp.square(o), -1, keepdims=True) + cfg.norm_eps)
    o = (of * p["head_norm"]).astype(x.dtype)
    y = (o * jax.nn.silu(z)) @ p["w_out"]
    return x + y.astype(x.dtype)


def init_mlstm_cache(cfg: ArchConfig, batch: int, dtype) -> Dict:
    d_in, H, d_qk = _mlstm_dims(cfg)
    return {
        "C": jnp.zeros((batch, H, d_qk // H, d_in // H), jnp.float32),
        "n": jnp.zeros((batch, H, d_qk // H), jnp.float32),
        "m": jnp.full((batch, H), -1e30, jnp.float32),
    }


def mlstm_decode(p, cfg: ArchConfig, x, ctrl, cache, index):
    B = x.shape[0]
    d_in, H, d_qk = _mlstm_dims(cfg)
    h = ops.subnet_norm(x, p["norm_gamma"], ctrl["subnet_id"], eps=cfg.norm_eps,
                        kind=cfg.norm)
    up = (h @ p["w_up"])[:, 0]
    x_in, z = jnp.split(up, 2, axis=-1)
    q = (x_in @ p["wq"]).reshape(B, H, d_qk // H).astype(jnp.float32) * ((d_qk // H) ** -0.5)
    k = (x_in @ p["wk"]).reshape(B, H, d_qk // H).astype(jnp.float32)
    v = x_in.reshape(B, H, d_in // H).astype(jnp.float32)
    gates = x_in.astype(jnp.float32) @ p["w_if"] + p["b_if"]
    i_raw, f_raw = jnp.split(gates, 2, axis=-1)               # (B,H)
    lf = jax.nn.log_sigmoid(f_raw)
    m_new = jnp.maximum(cache["m"] + lf, i_raw)
    fprime = jnp.exp(cache["m"] + lf - m_new)
    iprime = jnp.exp(i_raw - m_new)
    C = cache["C"] * fprime[..., None, None] + iprime[..., None, None] * k[..., :, None] * v[..., None, :]
    nvec = cache["n"] * fprime[..., None] + iprime[..., None] * k
    num = jnp.einsum("bhd,bhdv->bhv", q, C)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", q, nvec)), jnp.exp(-m_new))
    o = (num / den[..., None]).reshape(B, d_in)
    of = o * lax.rsqrt(jnp.mean(jnp.square(o), -1, keepdims=True) + cfg.norm_eps)
    o = (of * p["head_norm"]).astype(x.dtype)
    y = ((o * jax.nn.silu(z))[:, None] @ p["w_out"]).astype(x.dtype)
    return x + y, {"C": C, "n": nvec, "m": m_new}


# --------------------------------------------------------------------------
# sLSTM
# --------------------------------------------------------------------------


def init_slstm(key, cfg: ArchConfig, dtype) -> Dict:
    d = cfg.d_model
    H = cfg.n_heads
    dh = d // H
    d_ff = int(cfg.slstm_proj_factor * d)
    ks = jax.random.split(key, 5)
    return {
        "w_x": dense_init(ks[0], (d, 4 * d), jnp.float32),    # i,f,z,o pre-acts
        "r": dense_init(ks[1], (H, dh, 4 * dh), jnp.float32, scale=0.5),
        "b": jnp.concatenate([jnp.zeros((d,)), jnp.full((d,), 3.0),
                              jnp.zeros((2 * d,))]).astype(jnp.float32),
        "w_up": dense_init(ks[2], (d, d_ff), dtype),
        "w_down": dense_init(ks[3], (d_ff, d), dtype),
        "norm_gamma": ones_table(cfg.elastic.num_subnets, d),
        "ffn_gamma": ones_table(cfg.elastic.num_subnets, d),
    }


def _slstm_cell(p, cfg: ArchConfig, xt, state):
    """One sLSTM step. xt: (B, 4d) pre-activations from input proj."""
    d = cfg.d_model
    H = cfg.n_heads
    dh = d // H
    c, n, hprev, m = state
    rec = jnp.einsum("bhd,hde->bhe", hprev.reshape(-1, H, dh), p["r"]).reshape(-1, 4 * d)
    raw = xt + rec + p["b"]
    i_raw, f_raw, z_raw, o_raw = jnp.split(raw, 4, axis=-1)
    lf = jax.nn.log_sigmoid(f_raw)
    m_new = jnp.maximum(lf + m, i_raw)
    iprime = jnp.exp(i_raw - m_new)
    fprime = jnp.exp(lf + m - m_new)
    c_new = fprime * c + iprime * jnp.tanh(z_raw)
    n_new = fprime * n + iprime
    h_new = jax.nn.sigmoid(o_raw) * c_new / jnp.maximum(n_new, 1e-6)
    return (c_new, n_new, h_new, m_new), h_new


def slstm_block(p, cfg: ArchConfig, x, ctrl, *, slice_mode: str = "mask"):
    B, S, d = x.shape
    h = ops.subnet_norm(x, p["norm_gamma"], ctrl["subnet_id"], eps=cfg.norm_eps,
                        kind=cfg.norm)
    pre = h.astype(jnp.float32) @ p["w_x"]                    # (B,S,4d)
    zero = jnp.zeros((B, d), jnp.float32)
    state0 = (zero, zero, zero, jnp.full((B, d), -1e30, jnp.float32))
    _, hs = lax.scan(lambda s, xt: _slstm_cell(p, cfg, xt, s), state0,
                     jnp.moveaxis(pre, 1, 0))
    y = jnp.moveaxis(hs, 0, 1).astype(x.dtype)
    x = x + y
    # post-FFN (GELU, proj factor 4/3) with elastic width
    hf = ops.subnet_norm(x, p["ffn_gamma"], ctrl["subnet_id"], eps=cfg.norm_eps,
                         kind=cfg.norm)
    a = jax.nn.gelu(hf @ p["w_up"])
    a = ops.slice_mask(a, jnp.minimum(ctrl["slstm_ffn_width"], p["w_up"].shape[1]))
    return x + (a @ p["w_down"]).astype(x.dtype)


def init_slstm_cache(cfg: ArchConfig, batch: int, dtype) -> Dict:
    d = cfg.d_model
    z = jnp.zeros((batch, d), jnp.float32)
    return {"c": z, "n": z, "h": z, "m": jnp.full((batch, d), -1e30, jnp.float32)}


def slstm_decode(p, cfg: ArchConfig, x, ctrl, cache, index):
    h = ops.subnet_norm(x, p["norm_gamma"], ctrl["subnet_id"], eps=cfg.norm_eps,
                        kind=cfg.norm)
    pre = (h.astype(jnp.float32) @ p["w_x"])[:, 0]
    state = (cache["c"], cache["n"], cache["h"], cache["m"])
    (c, n, hh, m), hnew = _slstm_cell(p, cfg, pre, state)
    x = x + hnew[:, None].astype(x.dtype)
    hf = ops.subnet_norm(x, p["ffn_gamma"], ctrl["subnet_id"], eps=cfg.norm_eps,
                         kind=cfg.norm)
    a = jax.nn.gelu(hf @ p["w_up"])
    a = ops.slice_mask(a, jnp.minimum(ctrl["slstm_ffn_width"], p["w_up"].shape[1]))
    x = x + (a @ p["w_down"]).astype(x.dtype)
    return x, {"c": c, "n": n, "h": hh, "m": m}
