"""Roofline analysis: HLO collective-byte parsing + the three-term
(compute / memory / collective) model over TPU v5e constants."""
