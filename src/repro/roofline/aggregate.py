"""Aggregate results/dryrun/*.json into the §Roofline table and pick
the hillclimb cells.

    PYTHONPATH=src python -m repro.roofline.aggregate [--mesh single]
"""
from __future__ import annotations

import argparse
import glob
import json
import os
from typing import Dict, List

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "results", "dryrun")


def load(mesh: str = "single") -> List[Dict]:
    recs = []
    for fp in sorted(glob.glob(os.path.join(RESULTS_DIR, f"*__{mesh}.json"))):
        with open(fp) as f:
            recs.append(json.load(f))
    return recs


def corrected(r: Dict) -> Dict:
    """Correct the CPU-backend artifacts: (a) cost_analysis does not
    scale scan/while bodies by trip count -> floor HLO flops with the
    analytic lower bound; (b) f32 weight copies (bf16-dot promotion)
    inflate temp bytes -> use the TPU-projected figure."""
    from repro.configs import SHAPES, get_config
    from repro.launch import specs as S
    from repro.roofline import hw
    cfg = get_config(r["arch"])
    shape = SHAPES[r["shape"]]
    ana = S.analytic_flops(cfg, shape, remat=bool(r.get("remat")))
    flops_dev = max(r["hlo_flops_per_device"], ana / r["chips"])
    t_comp = flops_dev / hw.PEAK_FLOPS_BF16
    bound = max(t_comp, r["t_memory"], r["t_collective"])
    t_useful = (r["model_flops_total"] / r["chips"]) / hw.PEAK_FLOPS_BF16
    hbm = (r["argument_bytes_per_device"]
           + r.get("temp_bytes_tpu_projected", r["temp_bytes_per_device"])) / 2**30
    return {
        "t_comp": t_comp,
        "useful": r["model_flops_total"] / (flops_dev * r["chips"]),
        "frac": t_useful / max(bound, 1e-12),
        "dominant": max((("compute", t_comp), ("memory", r["t_memory"]),
                         ("collective", r["t_collective"])),
                        key=lambda kv: kv[1])[0],
        "hbm": hbm,
    }


def fmt_table(recs: List[Dict]) -> str:
    head = ("| arch | shape | dominant | t_comp (ms) | t_mem (ms) | "
            "t_coll (ms) | useful/HLO | roofline frac | HBM GB/dev |\n"
            "|---|---|---|---|---|---|---|---|---|")
    rows = []
    for r in recs:
        if r["status"] == "skipped":
            rows.append(f"| {r['arch']} | {r['shape']} | — skipped: "
                        f"{r['reason'].split(';')[0]} | | | | | | |")
            continue
        c = corrected(r)
        rows.append(
            f"| {r['arch']} | {r['shape']} | {c['dominant']} | "
            f"{c['t_comp']*1e3:.2f} | {r['t_memory']*1e3:.2f} | "
            f"{r['t_collective']*1e3:.2f} | {c['useful']:.2f} | "
            f"{c['frac']:.3f} | {c['hbm']:.1f} |")
    return head + "\n" + "\n".join(rows)


def pick_hillclimbs(recs: List[Dict]) -> Dict[str, Dict]:
    ok = [r for r in recs if r["status"] == "ok"]
    worst = min(ok, key=lambda r: corrected(r)["frac"])
    coll = max(ok, key=lambda r: r["t_collective"] /
               max(r["t_compute"], r["t_memory"], 1e-12))
    return {"worst_fraction": worst, "most_collective_bound": coll}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="single")
    args = ap.parse_args()
    recs = load(args.mesh)
    print(fmt_table(recs))
    picks = pick_hillclimbs(recs)
    print("\nhillclimb candidates:")
    for k, r in picks.items():
        print(f"  {k}: {r['arch']} x {r['shape']} "
              f"(frac={r['roofline_fraction']:.3f}, dominant={r['dominant']})")


if __name__ == "__main__":
    main()
