"""Parse collective traffic out of compiled/lowered HLO text.

``cost_analysis()`` does not expose collective bytes, so we walk the
HLO and sum operand/result sizes of every all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute, converting each to
*wire bytes per device* with the standard ring-algorithm factors:

    all-gather        result_bytes * (n-1)/n
    reduce-scatter    input_bytes  * (n-1)/n
    all-reduce        2 * bytes * (n-1)/n      (RS + AG)
    all-to-all        bytes * (n-1)/n
    collective-permute bytes                    (point-to-point)

``n`` comes from the op's replica_groups when present.
"""
from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLL_RE = re.compile(
    r"=\s+((?:\([^)]*\))|(?:[a-z0-9]+\[[0-9,]*\][^\s]*))\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(", re.M)

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_GROUPS_ALT_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _tensor_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d.strip():
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str) -> int:
    m = _GROUPS_ALT_RE.search(line)
    if m:                                     # replica_groups=[G,n]<=...
        return int(m.group(2))
    m = _GROUPS_RE.search(line)
    if m:
        return len([x for x in m.group(1).split(",") if x.strip()])
    return 2


def collective_bytes(hlo_text: str) -> Tuple[float, Dict[str, float]]:
    """(total wire bytes per device, per-op-kind breakdown).

    Skips the '-done' halves of async pairs (counted at '-start').
    """
    per_kind: Dict[str, float] = defaultdict(float)
    for m in re.finditer(
            r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s+=\s+([^\n]*)$", hlo_text, re.M):
        line = m.group(1)
        cm = re.match(
            r"((?:\([^)]*\))|(?:[a-z0-9]+\[[0-9,]*\][^\s]*))\s+"
            r"(all-reduce|all-gather|reduce-scatter|all-to-all|"
            r"collective-permute)(-start|-done)?\(", line)
        if not cm:
            continue
        type_str, kind, phase = cm.group(1), cm.group(2), cm.group(3)
        if phase == "-done":
            continue
        size = _tensor_bytes(type_str)
        n = _group_size(line)
        frac = (n - 1) / max(n, 1)
        if kind == "all-reduce":
            wire = 2 * size * frac
        elif kind == "collective-permute":
            wire = size
        elif kind == "reduce-scatter":
            wire = size * (n - 1)        # result is 1/n of the input
        else:                            # all-gather, all-to-all
            wire = size * frac
        per_kind[kind] += wire
    return float(sum(per_kind.values())), dict(per_kind)


def collective_count(hlo_text: str) -> Dict[str, int]:
    out: Dict[str, int] = defaultdict(int)
    for m in _COLL_RE.finditer(hlo_text):
        out[m.group(2)] += 1
    return dict(out)
