"""Hardware constants for the roofline model (TPU v5e, per chip)."""

PEAK_FLOPS_BF16 = 197e12     # FLOP/s
HBM_BW = 819e9               # bytes/s
ICI_BW_PER_LINK = 50e9       # bytes/s per link

V5E_HBM_BYTES = 16 * 2**30   # capacity check for memory_analysis
