"""Roofline terms from a dry-run cell (paper-grading §Roofline).

All inputs are PER-DEVICE (cost_analysis() on a partitioned executable
reports per-device flops/bytes; roofline/hlo.py sums per-device wire
bytes), so the terms are simply value / unit-rate — no extra division
by chip count.
"""
from __future__ import annotations

from dataclasses import asdict, dataclass
from typing import Dict, Optional

from repro.roofline import hw


@dataclass
class RooflineTerms:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops_per_device: float
    hlo_bytes_per_device: float
    collective_bytes_per_device: float
    model_flops_total: float            # 6*N*D (dense) / 6*N_active*D (MoE)
    argument_bytes_per_device: float = 0.0
    temp_bytes_per_device: float = 0.0
    collective_breakdown: Optional[Dict[str, float]] = None

    # ---- the three terms (seconds) ------------------------------------
    @property
    def t_compute(self) -> float:
        return self.hlo_flops_per_device / hw.PEAK_FLOPS_BF16

    @property
    def t_memory(self) -> float:
        return self.hlo_bytes_per_device / hw.HBM_BW

    @property
    def t_collective(self) -> float:
        return self.collective_bytes_per_device / hw.ICI_BW_PER_LINK

    @property
    def dominant(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def bound_time(self) -> float:
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / total HLO FLOPs — remat/redundancy waste."""
        total_hlo = self.hlo_flops_per_device * self.chips
        return self.model_flops_total / max(total_hlo, 1.0)

    @property
    def roofline_fraction(self) -> float:
        """Useful-compute time / achievable step time: how close the
        step is to the compute roofline if perfectly overlapped."""
        t_useful = (self.model_flops_total / self.chips) / hw.PEAK_FLOPS_BF16
        return t_useful / max(self.bound_time, 1e-12)

    def fits_hbm(self) -> bool:
        resident = self.argument_bytes_per_device + self.temp_bytes_per_device
        return resident <= hw.V5E_HBM_BYTES

    def to_dict(self) -> Dict:
        d = asdict(self)
        d.update(t_compute=self.t_compute, t_memory=self.t_memory,
                 t_collective=self.t_collective, dominant=self.dominant,
                 useful_flops_ratio=self.useful_flops_ratio,
                 roofline_fraction=self.roofline_fraction,
                 fits_hbm=self.fits_hbm())
        return d


def format_row(r: RooflineTerms) -> str:
    return (f"| {r.arch} | {r.shape} | {r.mesh} | "
            f"{r.t_compute*1e3:.2f} | {r.t_memory*1e3:.2f} | "
            f"{r.t_collective*1e3:.2f} | {r.dominant} | "
            f"{r.useful_flops_ratio:.2f} | {r.roofline_fraction:.3f} |")


HEADER = ("| arch | shape | mesh | t_comp (ms) | t_mem (ms) | t_coll (ms) "
          "| dominant | useful/HLO | roofline frac |\n"
          "|---|---|---|---|---|---|---|---|---|")
