"""SuperServe serving layer: profiler, EDF queue, scheduling policies
(SlackFit et al.), trace generators, and ONE transport-agnostic
scheduling engine (serving/engine.py: admission, EDF, policy
invocation, continuous batching, actuation accounting, fault
re-enqueue) behind two transports — the discrete-event simulator
(virtual clock) and the asyncio router/worker runtime hosting a
SubNetAct supernet (wall clock).

Scale-out (serving/cluster.py): N replica groups — one engine each —
behind a ClusterCoordinator with pluggable replica placement
(round-robin / least-loaded / power-of-two / slack-aware) and
replica-death re-routing; both transports grow cluster counterparts
(simulate_cluster, ClusterRouter) over one shared event loop."""
