"""SuperServe serving layer: profiler, EDF queue, scheduling policies
(SlackFit et al.), discrete-event simulator, trace generators, and the
asyncio router/worker runtime hosting a SubNetAct supernet."""
