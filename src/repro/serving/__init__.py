"""SuperServe serving layer: profiler, EDF queue, scheduling policies
(SlackFit et al.), trace generators, and ONE transport-agnostic
scheduling engine (serving/engine.py: admission, EDF, policy
invocation, continuous batching, actuation accounting, fault
re-enqueue) behind two transports — the discrete-event simulator
(virtual clock) and the asyncio router/worker runtime hosting a
SubNetAct supernet (wall clock).

Scale-out (serving/cluster.py): N replica groups — one engine each —
behind a ClusterCoordinator with pluggable replica placement
(round-robin / least-loaded / power-of-two / slack-aware) and
replica-death re-routing; both transports grow cluster counterparts
(simulate_cluster, ClusterRouter) over one shared event loop.

Autoscaling (serving/autoscaler.py): a ClusterAutoscaler rides on the
coordinator's replica-lifecycle surface and spawns / gracefully
decommissions replica groups from pluggable load signals
(queue_pressure / predictive / slo_headroom), with cold-start
actuation, replica-seconds accounting, and a scale-event log — same
control loop on both transports, so autoscaled schedules stay
deterministic.

Forecasting (serving/forecast.py): one deterministic, clock-agnostic
ArrivalForecaster (windowed rate + Holt trend + CV² burst detector)
feeds the predictive scaling policy, the engine's predictive join
windows at saturation, and coordinator forecast introspection.
Layering rule: forecasting state lives in forecast.py only —
coordinator/engines own and feed it, policies consume it, transports
never mutate it.

Residency (serving/residency.py): a per-engine ResidencyTracker owns
which subnet each worker last actuated, and an ActuationModel prices
switches (SubNetAct control swap vs full weight page-in) and replica
cold starts from one physical model. Consumers: the actuation_aware
placement, the slackfit_sticky policy, autoscaler cold-start
derivation, and the switch_rate / actuation_seconds metrics. Layering
rule: residency state lives in residency.py only — the engine is its
sole writer (actuate on launch, forget on death), everything else
reads; residency-blind configs replay pre-refactor schedules
bit-for-bit.

Multi-host plane (serving/ipc.py + serving/replica_proc.py):
``ClusterRouter(transport="proc")`` runs each replica group as its own
OS process behind a length-prefixed JSON frame protocol (seq-verified,
heartbeat dead-peer detection, typed FrameError taxonomy) over either
an inherited socketpair or a coordinator-side TCP listener with an
HMAC-token challenge/auth handshake — remote children join via
``replica_proc --connect`` and are adopted with ``adopt_replica()``.
The live ClusterAutoscaler drives this transport too (spawn = fork or
TCP-connect a process, decommission = drain frame through the
coordinator's surrender path), and ``execute="real"`` children build
their own AOT-warmed SubnetExecutor so completions carry real
predictions. XLA host-device pinning via compat.host_devices_env.
Layering rule: the parent-side coordinator keeps sole ownership of
admission/placement/lifecycle; children own scheduling through a full
in-process Router; the transport only serializes placement decisions
out and completion records back — inproc/proc record parity (over
both front doors) is the gate (tests/test_ipc.py,
benchmarks/bench_multiproc.py)."""
