"""SuperServe serving layer: profiler, EDF queue, scheduling policies
(SlackFit et al.), trace generators, and ONE transport-agnostic
scheduling engine (serving/engine.py: admission, EDF, policy
invocation, continuous batching, actuation accounting, fault
re-enqueue) behind two transports — the discrete-event simulator
(virtual clock) and the asyncio router/worker runtime hosting a
SubNetAct supernet (wall clock)."""
