"""Reactive replica autoscaling on the cluster plane (ROADMAP "replica
autoscaling", the INFaaS direction).

SubNetAct's near-instantaneous actuation (paper §5) makes *reactive*
control viable: instead of provisioning the cluster for the bursty
trace's peak, a ``ClusterAutoscaler`` rides on the PR 3
``ClusterCoordinator`` and spawns / decommissions whole replica groups
from live load signals. The division of labor extends PR 2/3's rule:
*scheduling* lives in the engine, *placement AND scaling* live in the
coordinator layer — transports (simulator / asyncio cluster router /
the proc transport's IPC front door, where ``engine_factory`` returns a
coordinator-side ``ReplicaProxy`` and spawn means forking a replica
process) stay thin and drive the same autoscaler through the same
coordinator, so autoscaled schedules remain transport-independent and
deterministic.

Lifecycle invariants (property-tested in tests/test_autoscaler.py):

  * **conservation** — scaling never loses or duplicates a query:
    decommission reuses the replica-death surrender/drain path (the
    queue is re-routed through placement, in EDF order; in-flight
    batches finish on the old replica — a scale-down never black-holes
    work);
  * **bounds** — the committed replica count (routable + warming)
    stays within ``[min_replicas, max_replicas]``;
  * **cooldown** — every decommission trails the previous scale event
    by at least ``cooldown`` (scale-up is deliberately undamped: the
    reactive story is spawning *into* a burst; hysteresis between the
    up/down thresholds plus the down-only cooldown damp flapping);
  * **cold start** — a spawned replica pays ``cold_start`` seconds of
    actuation before it becomes routable: capacity is committed (and
    billed in ``replica_seconds``) at spawn time but serves only after
    warm-up.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from repro.serving.cluster import ClusterCoordinator
from repro.serving.engine import SchedulingEngine
from repro.serving.forecast import ForecastConfig


# --------------------------------------------------------------------------
# Config + event log
# --------------------------------------------------------------------------


@dataclass
class AutoscaleConfig:
    """Knobs shared by both transports. Signal thresholds are expressed
    relative to the SLO so one config tracks any deadline regime."""

    min_replicas: int = 1
    max_replicas: int = 8
    policy: str = "queue_pressure"
    interval: float = 0.05          # control-loop period (s)
    # scale-DOWN damping: a decommission must trail the previous scale
    # event by at least this long. Scale-UP is deliberately undamped —
    # SubNetAct-style reactivity is the whole point — and over-spawning
    # is checked by counting warming capacity into the pressure signal.
    cooldown: float = 0.50
    # spawn -> routable actuation cost (s); None derives it from the
    # cluster's own ActuationModel (serving/residency.py) as a full
    # weight-load of the heaviest subnet — replica cold start and
    # per-batch switch cost then share one physical model
    cold_start: Optional[float] = 0.10
    # workers per spawned replica; None -> the transport's per-replica
    # worker count (heterogeneous clusters must set it explicitly)
    spawn_workers: Optional[int] = None
    # deadline regime the thresholds are relative to; None -> inherit
    # the transport's SLO (ClusterConfig.slo / serve --slo-ms)
    slo: Optional[float] = None
    # queue_pressure: a capacity controller on the observed arrival
    # rate (workers needed = rate / (util_target * profile max
    # throughput)) with a queue-backlog kicker for bursts faster than
    # the rate window. Scale up when needed workers exceed committed
    # ones or queued work per worker exceeds up_pressure SLOs; scale
    # down when utilization falls under down_util (hysteresis band =
    # the 1.0..down_util gap, plus the down-only cooldown).
    up_pressure: float = 1.5
    util_target: float = 0.55       # sustainable fraction of max tput
    down_util: float = 0.75
    rate_window: float = 0.25       # arrival-rate estimation window (s)
    # slo_headroom: sliding-window attainment target + slack headroom
    # (fraction of slo) that marks the cluster over-provisioned
    window: float = 1.0
    target_attainment: float = 0.985
    headroom: float = 0.5
    # predictive: how far ahead the coordinator forecaster is read when
    # sizing capacity; None -> cold_start + interval, i.e. exactly the
    # lead time a spawn decided now needs to turn routable before the
    # forecast load lands
    horizon: Optional[float] = None
    # scripted policy (tests): explicit (time, +1 | -1) events
    script: Sequence[Tuple[float, int]] = ()

    def validate(self) -> "AutoscaleConfig":
        if self.min_replicas < 1:
            raise ValueError("min_replicas must be >= 1")
        if self.max_replicas < self.min_replicas:
            raise ValueError("max_replicas must be >= min_replicas")
        if self.interval <= 0:
            raise ValueError("interval must be > 0")
        if ((self.cold_start is not None and self.cold_start < 0)
                or self.cooldown < 0):
            raise ValueError("cold_start/cooldown must be >= 0")
        if self.horizon is not None and self.horizon < 0:
            raise ValueError("horizon must be >= 0")
        return self


@dataclass(frozen=True)
class ScaleEvent:
    """One autoscaler lifecycle event, logged for metrics/benchmarks
    and asserted over by the property tests."""

    t: float
    kind: str                       # spawn | ready | decommission | death
    rid: int
    n_routable: int                 # routable replicas after the event
    n_committed: int                # routable + warming after the event
    signal: float = 0.0             # policy signal at decision time
    drained: Tuple[int, ...] = ()   # qids re-routed off (decommission)
    ready_at: Optional[float] = None  # spawn only: when it turns routable


# --------------------------------------------------------------------------
# Scaling policies
# --------------------------------------------------------------------------


class ScalingPolicy:
    """Pluggable scale-decision API: ``decide`` sees the coordinator
    (read-only) and the routable replicas, returns ``(delta, signal)``
    with delta in {+1, 0, -1}. Bounds, cooldown, victim selection, and
    actuation belong to the ``ClusterAutoscaler``, not the policy.
    ``decide`` may be consulted several times within one control tick
    (the multi-spawn loop). ``epoch`` is stamped by the autoscaler on
    its first tick: the clock origin (0 under virtual time, the start
    timestamp under wall clock) for policies with time-anchored state.
    """

    name: str = "base"
    epoch: float = 0.0

    def reset(self) -> None:
        pass

    def decide(self, coord: ClusterCoordinator,
               routable: Sequence[Tuple[int, SchedulingEngine]],
               now: float, warming_workers: int = 0) -> Tuple[int, float]:
        """``warming_workers`` counts capacity already committed but
        still cold-starting, so a burst doesn't over-spawn while the
        first reinforcements warm up."""
        raise NotImplementedError


class QueuePressure(ScalingPolicy):
    """Scale on aggregate demand vs drain capacity, two signals:

    * **sustained** — workers needed for the observed arrival rate
      (last ``rate_window`` seconds of the master admission list) at a
      sustainable ``util_target`` fraction of the profile's best
      queries/sec/worker. More needed than committed (warming counts)
      -> spawn; utilization under ``down_util`` -> decommission.
    * **burst kicker** — aggregate queued work (every replica's
      ``queue_depth``, valued at the fastest control choice, per
      worker) above ``up_pressure`` SLOs means the backlog will
      outlive deadlines before the rate window even notices -> spawn
      now.

    The 1.0..``down_util`` gap is the hysteresis band; the autoscaler's
    down-only cooldown adds the damping."""

    name = "queue_pressure"

    def __init__(self, slo: float, up_pressure: float, util_target: float,
                 down_util: float, rate_window: float):
        self.slo = max(float(slo), 1e-9)
        self.up_pressure = float(up_pressure)
        self.util_target = float(util_target)
        self.down_util = float(down_util)
        self.rate_window = float(rate_window)
        self._tput: Optional[float] = None  # best q/s/worker, from profile

    def _max_tput(self, engine: SchedulingEngine) -> float:
        if self._tput is None:
            prof = engine.profile
            self._tput = max(
                prof.batches[bi] / float(prof.lat[pi, bi])
                for pi in range(prof.lat.shape[0])
                for bi in range(len(prof.batches)))
        return self._tput

    def _arrival_rate(self, coord, now: float) -> float:
        lo, n = now - self.rate_window, 0
        for q in reversed(coord.queries):   # admission(=arrival)-ordered
            if q.arrival <= lo:
                break
            n += 1
        # normalize by elapsed-since-epoch when the window hasn't
        # filled yet (epoch, not raw now: the wall clock's origin is
        # arbitrary) so an opening burst reads at full rate
        return n / max(min(self.rate_window, now - self.epoch), 1e-9)

    def _demand_rate(self, coord, now: float) -> float:
        """The arrivals/sec the capacity controller sizes for — the
        single hook ``Predictive`` overrides, so there is exactly ONE
        decide body to keep the hysteresis/kicker semantics in."""
        return self._arrival_rate(coord, now)

    def decide(self, coord, routable, now, warming_workers=0):
        workers = (sum(max(len(e.residency), 1) for _, e in routable)
                   + warming_workers)
        sustainable = self._max_tput(routable[0][1]) * self.util_target
        need = self._demand_rate(coord, now) / max(sustainable, 1e-9)
        usig = need / max(workers, 1)
        queued = sum(e.queue_depth() for _, e in routable)
        qsig = (queued * routable[0][1].min_service
                / max(workers, 1)) / self.slo
        if usig > 1.0 or qsig > self.up_pressure:
            return 1, max(usig, qsig)
        if usig < self.down_util and len(routable) > 1:
            return -1, usig
        return 0, usig


class Predictive(QueuePressure):
    """Forecast-led scaling (ROADMAP "predictive scaling policies"):
    read the coordinator's shared ``ArrivalForecaster`` ``horizon``
    seconds ahead — the cold start plus one control period, i.e. the
    lead time a spawn decided *now* needs before the forecast load
    lands — and size capacity for that forecast rate, so reinforcements
    finish warming as the burst arrives instead of after it.

    Inherits ``QueuePressure`` as its reactive floor: with no
    forecaster on the coordinator, or before the forecaster has signal
    (fewer than ``min_arrivals`` observations, or an idle window), it
    IS queue_pressure — a forecaster that never fires must replay the
    reactive schedule byte-identically (guarded in
    tests/test_autoscaler.py). The queued-work burst kicker stays
    active either way: a burst faster than any forecast window is a
    reactive problem, not a forecasting one.

    The utilization signal is ``max(rate_now, forecast_at_horizon)``,
    driving both directions: on a rising trend the forecast leads (the
    paper-story spawn-before-the-burst), on a falling or flat one it
    degrades to exactly the reactive signal — so an unforecastable
    trace costs nothing (the bench_predictive <= 1.0x replica-seconds
    gate) and a forecastable one is served ahead of time."""

    name = "predictive"

    def __init__(self, slo: float, up_pressure: float, util_target: float,
                 down_util: float, rate_window: float, horizon: float):
        super().__init__(slo, up_pressure, util_target, down_util,
                         rate_window)
        self.horizon = float(horizon)

    def _demand_rate(self, coord, now: float) -> float:
        # the demand signal is the WORSE of now and the forecast at the
        # actuation horizon: on a rising trend the forecast leads
        # (spawn before the load lands), on a falling one the current
        # rate still holds the floor (never trim into a burst that
        # hasn't finished draining) — so predictive is exactly reactive
        # plus lead time, and a flat forecast changes nothing. The
        # whole decide body (thresholds, hysteresis, burst kicker)
        # stays QueuePressure's.
        fc = getattr(coord, "forecaster", None)
        if fc is None or not fc.has_signal(now):
            return super()._demand_rate(coord, now)
        return max(fc.rate(now), fc.forecast(now, self.horizon))


class SLOHeadroom(ScalingPolicy):
    """Scale on *observed outcomes* over a sliding window: attainment
    under ``target`` means deadlines are already slipping — spawn;
    attainment at target with mean slack headroom above ``headroom`` of
    the SLO means the cluster is over-provisioned — decommission. A
    lagging signal compared to queue pressure (it waits for misses),
    kept as the observational alternative."""

    name = "slo_headroom"

    def __init__(self, slo: float, window: float, target: float,
                 headroom: float):
        self.slo = max(float(slo), 1e-9)
        self.window = float(window)
        self.target = float(target)
        self.headroom = float(headroom)

    def decide(self, coord, routable, now, warming_workers=0):
        if warming_workers:
            return 0, 1.0               # reinforcements already on the way
        lo = now - self.window
        ok = miss = 0
        slack_sum = 0.0
        # master list is admission(=arrival)-ordered: scan the tail
        for q in reversed(coord.queries):
            if q.arrival < lo:
                break
            if q.dropped or (q.finish is not None and q.finish > q.deadline):
                miss += 1
            elif q.finish is not None:
                ok += 1
                slack_sum += q.deadline - q.finish
            elif q.deadline < now:      # still unresolved but already late
                miss += 1
        resolved = ok + miss
        attainment = ok / resolved if resolved else 1.0
        if resolved and attainment < self.target:
            return 1, attainment
        mean_headroom = (slack_sum / ok) / self.slo if ok else 0.0
        if (resolved and attainment >= self.target
                and mean_headroom > self.headroom and len(routable) > 1):
            return -1, attainment
        return 0, attainment


class Scripted(ScalingPolicy):
    """Deterministic test harness: replay explicit (time, delta) scale
    events — times relative to the autoscaler's epoch — one per
    control tick once due (re-consults within the same tick return
    hold, so the multi-spawn loop can't double-consume). An event the
    autoscaler clamps away (bounds, cooldown) is dropped, not retried
    — scripts describe attempts, the clamps stay authoritative (the
    bounds property tests rely on exactly that). Lets the property
    tests drive arbitrary spawn/decommission sequences through the
    exact production actuation path."""

    name = "scripted"

    def __init__(self, script: Sequence[Tuple[float, int]]):
        self.script = sorted((float(t), int(d)) for t, d in script)

    def reset(self) -> None:
        self._pending = list(self.script)
        self._consumed_at: Optional[float] = None

    def decide(self, coord, routable, now, warming_workers=0):
        if (self._pending and self._pending[0][0] <= now - self.epoch
                and self._consumed_at != now):
            self._consumed_at = now
            _, delta = self._pending.pop(0)
            return (1 if delta > 0 else -1), float(delta)
        return 0, 0.0


SCALINGS: Dict[str, str] = {
    "queue_pressure": "aggregate backlog vs drain capacity (leading)",
    "predictive": "forecast crossing capacity, cold_start ahead "
                  "(queue_pressure fallback without signal)",
    "slo_headroom": "windowed attainment + slack headroom (lagging)",
    "scripted": "explicit (t, +1/-1) event list (tests)",
}


def make_scaling(cfg: AutoscaleConfig, slo: float,
                 cold_start: Optional[float] = None) -> ScalingPolicy:
    """``cold_start`` is the *resolved* spawn actuation (the
    ClusterAutoscaler passes its ActuationModel-derived value when
    ``cfg.cold_start`` is None) — the predictive horizon must match
    what a spawn actually pays."""
    if cold_start is None:
        cold_start = cfg.cold_start if cfg.cold_start is not None else 0.0
    if cfg.policy == "queue_pressure":
        return QueuePressure(slo, cfg.up_pressure, cfg.util_target,
                             cfg.down_util, cfg.rate_window)
    if cfg.policy == "predictive":
        horizon = (cfg.horizon if cfg.horizon is not None
                   else cold_start + cfg.interval)
        return Predictive(slo, cfg.up_pressure, cfg.util_target,
                          cfg.down_util, cfg.rate_window, horizon)
    if cfg.policy == "slo_headroom":
        return SLOHeadroom(slo, cfg.window, cfg.target_attainment,
                           cfg.headroom)
    if cfg.policy == "scripted":
        return Scripted(cfg.script)
    raise ValueError(f"unknown scaling policy {cfg.policy!r}; "
                     f"choose from {sorted(SCALINGS)}")


def coordinator_forecast(autoscale: Optional[AutoscaleConfig],
                         explicit: Optional[ForecastConfig]
                         ) -> Optional[ForecastConfig]:
    """THE defaulting rule for the coordinator-level ForecastConfig,
    stated once so both transports construct identical forecasters (a
    transport-local default would silently break schedule parity): an
    explicit config wins; otherwise a forecast-led scaling policy gets
    a default forecaster windowed at its own ``rate_window`` (forecast
    and reactive fallback then read comparable rates); otherwise no
    coordinator forecaster at all."""
    if explicit is not None:
        return explicit
    if autoscale is not None and autoscale.policy == "predictive":
        return ForecastConfig(window=autoscale.rate_window)
    return None


# --------------------------------------------------------------------------
# The autoscaler
# --------------------------------------------------------------------------


class ClusterAutoscaler:
    """Reactive replica lifecycle on top of a ``ClusterCoordinator``.

    The autoscaler owns the decision loop (policy + bounds + cooldown +
    victim selection), the lifecycle bookkeeping (warming replicas,
    per-replica active spans -> ``replica_seconds``), and the event
    log. Transports supply ``engine_factory(rid)`` (how a replica group
    is built: a bare engine in the simulator, a full ``Router`` in the
    asyncio plane) and call ``tick``/``activate`` from their own clocks
    — the shared virtual-time heap in ``drive_cluster`` or an asyncio
    task. ``migrate_fn(rid, moved)`` lets the asyncio transport move
    payloads/futures with a decommissioned replica's re-routed queue.
    """

    def __init__(self, coord: ClusterCoordinator, cfg: AutoscaleConfig,
                 engine_factory: Callable[[int], SchedulingEngine],
                 slo: float = 0.036,
                 migrate_fn: Optional[Callable] = None):
        self.coord = coord
        self.cfg = cfg.validate()
        self.engine_factory = engine_factory
        self.migrate_fn = migrate_fn
        # resolve the spawn actuation once, for both transports: an
        # explicit cold_start wins; None prices it through the cluster's
        # own ActuationModel as a full weight-load of the heaviest
        # subnet (serving/residency.py) — the same model the engines
        # charge per-batch switches against
        if cfg.cold_start is not None:
            self.cold_start = float(cfg.cold_start)
        else:
            e0 = coord.engines[0]
            self.cold_start = e0.residency.model.cold_start(e0.profile)
        self.policy = make_scaling(cfg, cfg.slo if cfg.slo is not None
                                   else slo, cold_start=self.cold_start)
        self.policy.reset()
        self.events: List[ScaleEvent] = []
        self._t0: Optional[float] = None        # clock origin (first tick)
        self._last_scale = float("-inf")
        self._warming: Dict[int, float] = {}        # rid -> ready_at
        # rid -> [start, end]; initial replicas are active from the
        # clock origin (0 under virtual time, the start stamp under
        # wall clock — stamped as the epoch on the first tick)
        self._spans: Dict[int, List[Optional[float]]] = {
            rid: [None, None] for rid in range(coord.n_replicas)}

    # -- views -----------------------------------------------------------

    def n_routable(self) -> int:
        return len(self.coord.alive_replicas())

    def n_committed(self) -> int:
        """Replicas the autoscaler is paying for: routable + warming."""
        return self.n_routable() + len(self._warming)

    def anchor(self, t0: float) -> None:
        """Stamp the clock origin: 0 under virtual time (drive_cluster),
        the start timestamp under wall clock (ClusterRouter.start).
        Initial replicas bill from here; idempotent."""
        if self._t0 is None:
            self._t0 = float(t0)
            self.policy.epoch = self._t0
            for span in self._spans.values():
                if span[0] is None:
                    span[0] = self._t0

    # -- control loop ----------------------------------------------------

    def tick(self, now: float) -> List[ScaleEvent]:
        """One control-loop step: consult the policy, clamp to bounds
        and (for scale-down) the cooldown, actuate. Scale-up spawns as
        many replicas as the policy keeps demanding in one tick — the
        policy sees the growing warming capacity between spawns, so a
        2x burst gets its reinforcements immediately instead of one
        per control period; scale-down trims at most one replica per
        tick. Returns the events actuated (transports schedule
        cold-start READY wake-ups for spawns and re-dispatch after
        decommissions)."""
        if self._t0 is None:
            # direct-use fallback: the first tick fires one interval
            # after the clock origin (transports normally anchor() it)
            self.anchor(now - self.cfg.interval)
        out: List[ScaleEvent] = []
        # the floor is an invariant, not a policy suggestion: a cluster
        # started below min_replicas — or wiped out by deaths — is
        # topped back up before the policy is even consulted (the
        # replacements pay the usual cold start before routing resumes)
        while self.n_committed() < self.cfg.min_replicas:
            out.append(self.spawn(now, 0.0))
        routable = self.coord.alive_replicas()
        if not routable:
            return out                  # dead / all-warming: nothing to read
        while True:
            warming_workers = sum(
                len(self.coord.engines[rid].residency)
                for rid in self._warming)
            delta, signal = self.policy.decide(
                self.coord, routable, now, warming_workers=warming_workers)
            committed = self.n_committed()
            if delta > 0:               # scale-up is undamped (reactive)
                if committed >= self.cfg.max_replicas:
                    return out
                out.append(self.spawn(now, signal))
                continue                # re-consult with the new warming
            if out or delta == 0:
                return out
            # scale-down waits out the cooldown after ANY scale event,
            # so a burst's reinforcements aren't torn down the moment
            # it ebbs — and trims one replica at a time
            if (committed <= self.cfg.min_replicas
                    or now - self._last_scale < self.cfg.cooldown):
                return out
            victim = self._pick_victim(routable)
            if victim is not None:
                out.append(self.decommission(victim, now, signal))
            return out

    def _pick_victim(self, routable) -> Optional[int]:
        """Cheapest replica to drain: least outstanding work; ties
        prefer the highest rid (latest spawned goes first)."""
        if len(routable) <= 1:
            return None                 # never decommission the last one
        return min(routable, key=lambda re: (re[1].outstanding(),
                                             -re[0]))[0]

    # -- actuation -------------------------------------------------------

    def spawn(self, now: float, signal: float = 0.0) -> ScaleEvent:
        """Commit a new replica group: the engine exists (and is billed)
        from now, but becomes routable only at ``now + cold_start`` —
        the transport calls ``activate`` then."""
        rid = len(self.coord.engines)
        self.coord.add_replica(self.engine_factory(rid), ready=False)
        ready_at = now + self.cold_start
        self._warming[rid] = ready_at
        self._spans[rid] = [now, None]
        self._last_scale = now
        ev = ScaleEvent(now, "spawn", rid, self.n_routable(),
                        self.n_committed(), signal, ready_at=ready_at)
        self.events.append(ev)
        return ev

    def activate(self, rid: int, now: float) -> List[int]:
        """Cold start paid: mark the replica routable. Returns its
        worker ids so the virtual-time driver can register them."""
        self._warming.pop(rid, None)
        self.coord.mark_ready(rid)
        self.events.append(ScaleEvent(now, "ready", rid, self.n_routable(),
                                      self.n_committed()))
        return sorted(self.coord.engines[rid].residency.workers())

    def decommission(self, rid: int, now: float,
                     signal: float = 0.0) -> ScaleEvent:
        """Graceful scale-down through the PR 3 surrender/drain path:
        the replica stops being routable, its queued work is re-routed
        through placement (EDF order), and in-flight batches finish on
        the old workers — a queue is never black-holed."""
        moved = self.coord.redistribute(rid, now)
        if self.migrate_fn is not None:
            self.migrate_fn(rid, moved)
        self._close_span(rid, now)
        self._last_scale = now
        ev = ScaleEvent(now, "decommission", rid, self.n_routable(),
                        self.n_committed(), signal,
                        drained=tuple(q.qid for q, _ in moved))
        self.events.append(ev)
        return ev

    def on_death(self, rid: int, now: float) -> None:
        """A replica died (fault injection) out from under the
        autoscaler: close its billing span and log it."""
        self._warming.pop(rid, None)
        self._close_span(rid, now)
        self.events.append(ScaleEvent(now, "death", rid, self.n_routable(),
                                      self.n_committed()))

    # -- accounting ------------------------------------------------------

    def _close_span(self, rid: int, now: float) -> None:
        span = self._spans.get(rid)
        if span is not None and span[1] is None:
            if span[0] is None:         # closed before the first tick
                span[0] = self._t0 if self._t0 is not None else 0.0
            span[1] = max(now, span[0])

    def finalize(self, t_end: float) -> None:
        """Close every open span at ``t_end`` (end of a run)."""
        for span in self._spans.values():
            if span[0] is None:         # never ticked: bill from origin
                span[0] = self._t0 if self._t0 is not None else 0.0
            if span[1] is None:
                span[1] = max(t_end, span[0])

    def replica_spans(self, t_end: Optional[float] = None
                      ) -> Dict[int, float]:
        """Per-replica active seconds. Open spans are valued up to
        ``t_end`` without being mutated (mid-run snapshots); call
        ``finalize`` for the terminal accounting instead."""
        out: Dict[int, float] = {}
        for rid, span in sorted(self._spans.items()):
            start = span[0] if span[0] is not None else self._t0
            if start is None:           # pre-anchor snapshot: key still
                out[rid] = 0.0          # present, nothing billed yet
                continue
            end = span[1] if span[1] is not None else \
                (t_end if t_end is not None else start)
            out[rid] = max(end - start, 0.0)
        return out

    def replica_seconds(self) -> float:
        return sum(self.replica_spans().values())
