"""Multi-replica serving plane: cluster coordinator + replica-aware
placement (ROADMAP "serving scale-out").

The paper's router (§5) schedules one worker pool; a datacenter runs
many. This module converts the serving stack from "the engine" to "a
set of engines behind a coordinator":

  * each **replica group** runs its own, unchanged ``SchedulingEngine``
    (EDF queue, policy invocation, continuous batching, fault
    re-enqueue — exactly the PR 2 core, per replica);
  * a **ClusterCoordinator** owns global admission and routes every
    query to one replica via a pluggable ``PlacementPolicy``
    (round-robin, least-loaded, power-of-two-choices, slack-aware);
  * replica death drains the dead replica's EDF queue — including the
    in-flight queries its worker faults re-enqueued — back through the
    coordinator, which re-routes the orphans to survivors.

Division of labor, extending PR 2's rule: *scheduling* logic lives in
the engine only; *placement* logic lives in the coordinator only.
Transports stay thin: ``drive_cluster`` below is the one discrete-event
loop shared by the ``ClusterSimulator`` (serving/simulator.py) and the
``ClusterRouter``'s parity mode (serving/runtime.py) — a single event
heap across all replicas, so multi-replica schedules are exactly as
deterministic as single-replica ones.
"""
from __future__ import annotations

import heapq
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

from repro.serving.engine import (EV_ARRIVAL, EV_FAULT, EV_FREE, EV_LAUNCH,
                                  CompletionRecord, Dispatch, EngineConfig,
                                  SchedulingEngine, VirtualClock,
                                  completion_records)
from repro.serving.metrics import cluster_summarize
from repro.serving.policies import Policy
from repro.serving.profiler import LatencyProfile
from repro.serving.queue import Query

# replica-death events carry this sentinel instead of a worker id
ALL_WORKERS = -1


# --------------------------------------------------------------------------
# Placement policies
# --------------------------------------------------------------------------


class PlacementPolicy:
    """Pluggable replica-selection API. ``choose`` sees the *alive*
    replicas as ``(rid, engine)`` pairs and must return one of the
    offered rids; engines are read-only here (introspection methods
    ``queue_depth`` / ``inflight_depth`` / ``work_ahead`` /
    ``projected_drain`` only — placement never touches a queue)."""

    name: str = "base"

    def reset(self, n_replicas: int, seed: int = 0) -> None:
        pass

    def choose(self, replicas: Sequence[Tuple[int, SchedulingEngine]],
               q: Query, now: float) -> int:
        raise NotImplementedError


class RoundRobin(PlacementPolicy):
    """Cycle through alive replicas in rid order — the classic
    load-oblivious baseline."""

    name = "round_robin"

    def reset(self, n_replicas: int, seed: int = 0) -> None:
        self._i = 0

    def choose(self, replicas, q, now):
        rid = replicas[self._i % len(replicas)][0]
        self._i += 1
        return rid


class LeastLoaded(PlacementPolicy):
    """Join the replica with the smallest total outstanding load
    (queued + in-flight queries); ties break toward the lowest rid."""

    name = "least_loaded"

    def choose(self, replicas, q, now):
        return min(replicas,
                   key=lambda re: (re[1].queue_depth()
                                   + re[1].inflight_depth(), re[0]))[0]


class PowerOfTwo(PlacementPolicy):
    """Power-of-two-choices (Mitzenmacher): sample two replicas, join
    the less loaded — near-optimal balance at O(1) state. Seeded rng so
    cluster schedules stay deterministic and transport-independent."""

    name = "power_of_two"

    def reset(self, n_replicas: int, seed: int = 0) -> None:
        self._rng = np.random.default_rng(seed)

    def choose(self, replicas, q, now):
        if len(replicas) == 1:
            return replicas[0][0]
        i, j = self._rng.choice(len(replicas), size=2, replace=False)
        a, b = replicas[int(i)], replicas[int(j)]
        ka = (a[1].queue_depth() + a[1].inflight_depth(), a[0])
        kb = (b[1].queue_depth() + b[1].inflight_depth(), b[0])
        return a[0] if ka <= kb else b[0]


class SlackAware(PlacementPolicy):
    """Deadline-aware routing: a *tight* query (slack under
    ``tight_mult`` fastest-service times — which covers the paper's
    36 ms SLO regime at the default) goes to the replica that can
    *start it* soonest (``projected_start``: in-flight work plus only
    the EDF queue ahead of its deadline, weighted by pool capacity —
    queued later-deadline work doesn't repel a tight query, since EDF
    serves it first anyway); with generous slack the queue joined
    barely matters, so relaxed queries round-robin to keep load
    spread."""

    name = "slack_aware"

    def __init__(self, tight_mult: float = 10.0):
        self.tight_mult = tight_mult

    def reset(self, n_replicas: int, seed: int = 0) -> None:
        self._i = 0

    def choose(self, replicas, q, now):
        slack = q.deadline - now
        if slack < self.tight_mult * replicas[0][1].min_service:
            return min(replicas,
                       key=lambda re: (re[1].projected_start(q.deadline, now),
                                       re[0]))[0]
        rid = replicas[self._i % len(replicas)][0]
        self._i += 1
        return rid


PLACEMENTS: Dict[str, type] = {
    "round_robin": RoundRobin,
    "least_loaded": LeastLoaded,
    "power_of_two": PowerOfTwo,
    "slack_aware": SlackAware,
}


def make_placement(name: str) -> PlacementPolicy:
    try:
        return PLACEMENTS[name]()
    except KeyError:
        raise ValueError(f"unknown placement {name!r}; "
                         f"choose from {sorted(PLACEMENTS)}") from None


# --------------------------------------------------------------------------
# Coordinator
# --------------------------------------------------------------------------


class ClusterCoordinator:
    """Global admission + replica routing over N per-replica engines.

    The coordinator owns the master query list (each query admitted to
    the cluster exactly once, however many replicas it visits after
    deaths), the placement policy, and replica liveness. All scheduling
    *within* a replica stays in that replica's engine."""

    def __init__(self, engines: Sequence[SchedulingEngine],
                 placement: PlacementPolicy, placement_seed: int = 0):
        if not engines:
            raise ValueError("a cluster needs at least one replica")
        self.engines = list(engines)
        self.alive: List[bool] = [True] * len(self.engines)
        self.placement = placement
        placement.reset(len(self.engines), seed=placement_seed)
        self.queries: List[Query] = []      # master admission list

    # -- liveness / views ----------------------------------------------

    @property
    def n_replicas(self) -> int:
        return len(self.engines)

    def alive_replicas(self) -> List[Tuple[int, SchedulingEngine]]:
        return [(rid, e) for rid, e in enumerate(self.engines)
                if self.alive[rid]]

    # -- admission -----------------------------------------------------

    def select(self, q: Query, now: float) -> int:
        """Placement decision only: which alive replica should take
        ``q``. The asyncio ClusterRouter admits through the chosen
        replica's own lock, so selection and admission are split."""
        replicas = self.alive_replicas()
        if not replicas:
            raise RuntimeError("no alive replicas left in the cluster")
        return int(self.placement.choose(replicas, q, now))

    def route(self, q: Query, now: float) -> int:
        """Place an existing query on an alive replica (no master-list
        append — the re-route path)."""
        rid = self.select(q, now)
        self.engines[rid].admit(q)          # stamps q.replica = rid
        return rid

    def admit(self, q: Query, now: float) -> Optional[int]:
        """Cluster front door: record the query once and route it.
        With every replica dead there is nowhere to route — the query
        is dropped (recorded, never served) and None returned."""
        self.queries.append(q)
        if not any(self.alive):
            q.dropped = True
            return None
        return self.route(q, now)

    # -- replica death -------------------------------------------------

    def should_decommission(self, rid: int) -> bool:
        """THE decommission rule, stated once for both transports: an
        alive replica whose worker pool is gone can never serve again —
        leave it routable and it black-holes every query placed on
        it."""
        return self.alive[rid] and not self.engines[rid].worker_model

    def fail_replica(self, rid: int, now: float) -> List[Tuple[Query, int]]:
        """Replica ``rid`` died: fault every worker (re-enqueueing its
        in-flight queries through the engine's own fault path), then
        drain the replica's queue back through placement. Returns the
        re-routed ``(query, new_rid)`` pairs, in EDF order."""
        eng = self.engines[rid]
        for wid in list(eng.worker_model):
            eng.fault(wid)
        return self.redistribute(rid, now)

    def redistribute(self, rid: int, now: float) -> List[Tuple[Query, int]]:
        """Drain-and-re-route the (already worker-faulted) replica's
        queue; used directly by the asyncio ClusterRouter, whose
        ``kill_worker`` handles the per-worker fault bookkeeping. When
        the whole cluster is dead the orphans are dropped instead."""
        self.alive[rid] = False
        orphans = self.engines[rid].surrender_queue()
        if not any(self.alive):
            for q in orphans:
                q.dropped = True
            return []
        return [(q, self.route(q, now)) for q in orphans]

    # -- accounting ----------------------------------------------------

    def abandon_pending(self) -> List[Query]:
        out: List[Query] = []
        for eng in self.engines:
            out.extend(eng.abandon_pending())
        return out

    def records(self) -> List[CompletionRecord]:
        return completion_records(self.queries)

    def stats(self) -> Dict[str, float]:
        return cluster_summarize(
            self.queries, n_replicas=self.n_replicas,
            n_joins=sum(e.n_joins for e in self.engines))


# --------------------------------------------------------------------------
# Shared discrete-event loop (virtual time, all replicas on one heap)
# --------------------------------------------------------------------------


def drive_cluster(coord: ClusterCoordinator, queries: Sequence[Query],
                  worker_ids: Dict[int, Iterable[int]],
                  replica_deaths: Optional[Dict[int, float]] = None,
                  fault_times: Optional[Dict[Tuple[int, int], float]] = None,
                  clock: Optional[VirtualClock] = None,
                  service_fn=None) -> None:
    """Run the whole cluster to quiescence under one virtual clock.

    The multi-replica analogue of ``engine.drive``: ONE event heap
    ordered ``(t, kind, rid, ident)`` spans every replica, so
    simultaneous events across replicas resolve deterministically and a
    1-replica cluster replays the single-engine loop event-for-event.
    ``service_fn(rid, dispatch, now) -> latency`` optionally perturbs
    the engine's expected service time (simulator stragglers).
    Replica deaths enter as FAULT events with the ``ALL_WORKERS``
    sentinel; per-worker faults as ``(rid, wid)``.
    """
    events: List = [(q.arrival, EV_ARRIVAL, 0, q.qid) for q in queries]
    for rid, t in (replica_deaths or {}).items():
        events.append((float(t), EV_FAULT, int(rid), ALL_WORKERS))
    for (rid, wid), t in (fault_times or {}).items():
        events.append((float(t), EV_FAULT, int(rid), int(wid)))
    heapq.heapify(events)
    idle: Dict[int, List[int]] = {rid: list(wids)
                                  for rid, wids in worker_ids.items()}
    dead_workers: set = set()               # (rid, wid)
    qmap = {q.qid: q for q in queries}

    def push(t: float, kind: int, rid: int, ident: int) -> None:
        heapq.heappush(events, (t, kind, rid, ident))

    def start(rid: int, d: Dispatch, now: float) -> None:
        eng = coord.engines[rid]
        eng.launch(d, now)
        lat = d.service if service_fn is None else service_fn(rid, d, now)
        d.t_finish = now + lat
        push(d.t_finish, EV_FREE, rid, d.wid)

    def dispatch_all(rid: int, now: float) -> None:
        eng = coord.engines[rid]
        free = idle[rid]
        while free and len(eng.edf):
            wid = free.pop(0)
            d = eng.next_dispatch(wid, now)
            if d is None:
                free.insert(0, wid)
                break
            if d.open:
                push(d.launch_at, EV_LAUNCH, rid, wid)
            else:
                start(rid, d, now)
        for d in eng.try_join(now):
            start(rid, d, now)

    while events:
        now, kind, rid, ident = heapq.heappop(events)
        if clock is not None:
            clock.advance_to(now)
        if kind == EV_ARRIVAL:
            target = coord.admit(qmap[ident], now)
            if target is not None:      # None: whole cluster dead, dropped
                dispatch_all(target, now)
        elif kind == EV_FREE:
            if (rid, ident) in dead_workers or not coord.alive[rid]:
                continue
            eng = coord.engines[rid]
            d = eng.inflight.get(ident)
            if d is not None and d.launched:
                eng.complete(d, d.t_finish)
            elif d is not None and not d.queries:
                eng.inflight.pop(ident, None)
            idle[rid].append(ident)
            dispatch_all(rid, now)
        elif kind == EV_LAUNCH:
            d = coord.engines[rid].open_batches.get(ident)
            if (d is not None and not d.launched and not d.faulted
                    and d.launch_at == now):
                start(rid, d, now)
        elif kind == EV_FAULT:
            if ident == ALL_WORKERS:        # whole replica dies
                for wid in list(idle[rid]) + [
                        w for w in coord.engines[rid].worker_model]:
                    dead_workers.add((rid, wid))
                idle[rid].clear()
                coord.fail_replica(rid, now)
                # orphans were re-routed through placement: wake every
                # surviving replica, in rid order, deterministically
                for other, _ in coord.alive_replicas():
                    dispatch_all(other, now)
            else:
                dead_workers.add((rid, ident))
                if ident in idle[rid]:
                    idle[rid].remove(ident)
                coord.engines[rid].fault(ident)
                if coord.should_decommission(rid):
                    # last worker gone: re-route the queue (incl. the
                    # just-re-enqueued batch) to survivors
                    coord.redistribute(rid, now)
                    for other, _ in coord.alive_replicas():
                        dispatch_all(other, now)
                elif coord.alive[rid]:
                    dispatch_all(rid, now)


# --------------------------------------------------------------------------
# Construction helpers
# --------------------------------------------------------------------------


def replica_worker_counts(n_replicas: int,
                          workers_per_replica) -> List[int]:
    """Normalize an int (homogeneous) or per-replica sequence
    (heterogeneous pools — where load-aware placement earns its keep)
    into one worker count per replica."""
    if n_replicas < 1:
        raise ValueError("n_replicas must be >= 1")
    if isinstance(workers_per_replica, int):
        counts = [workers_per_replica] * n_replicas
    else:
        counts = [int(w) for w in workers_per_replica]
        if len(counts) != n_replicas:
            raise ValueError(f"{len(counts)} worker counts for "
                             f"{n_replicas} replicas")
    if any(c < 1 for c in counts):
        raise ValueError("every replica needs at least one worker")
    return counts


def build_engines(profile: LatencyProfile, policy: Policy,
                  n_replicas: int, workers_per_replica,
                  cfg: Optional[EngineConfig] = None
                  ) -> List[SchedulingEngine]:
    """One engine per replica group, each with a *cloned* policy (per-
    replica policy state never couples replicas) and its own worker-id
    space 0..k-1. ``workers_per_replica`` is an int or a per-replica
    sequence."""
    counts = replica_worker_counts(n_replicas, workers_per_replica)
    return [SchedulingEngine(profile, policy.clone(),
                             cfg or EngineConfig(),
                             worker_ids=range(counts[rid]),
                             replica_id=rid)
            for rid in range(n_replicas)]
