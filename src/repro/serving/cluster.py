"""Multi-replica serving plane: cluster coordinator + replica-aware
placement (ROADMAP "serving scale-out").

The paper's router (§5) schedules one worker pool; a datacenter runs
many. This module converts the serving stack from "the engine" to "a
set of engines behind a coordinator":

  * each **replica group** runs its own, unchanged ``SchedulingEngine``
    (EDF queue, policy invocation, continuous batching, fault
    re-enqueue — exactly the PR 2 core, per replica);
  * a **ClusterCoordinator** owns global admission and routes every
    query to one replica via a pluggable ``PlacementPolicy``
    (round-robin, least-loaded, power-of-two-choices, slack-aware,
    actuation-aware);
  * replica death drains the dead replica's EDF queue — including the
    in-flight queries its worker faults re-enqueued — back through the
    coordinator, which re-routes the orphans to survivors.

Division of labor, extending PR 2's rule: *scheduling* logic lives in
the engine only; *placement and scaling* logic live in the coordinator
layer only (placement here, the reactive replica lifecycle in
serving/autoscaler.py riding on this coordinator's ``add_replica`` /
``mark_ready`` / ``redistribute`` surface). Transports stay thin:
``drive_cluster`` below is the one discrete-event loop shared by the
``ClusterSimulator`` (serving/simulator.py) and the ``ClusterRouter``'s
parity mode (serving/runtime.py) — a single event heap across all
replicas (scale ticks included), so multi-replica schedules are exactly
as deterministic as single-replica ones.
"""
from __future__ import annotations

import heapq
from collections import deque
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

from repro.serving.engine import (EV_ARRIVAL, EV_FAULT, EV_FREE, EV_LAUNCH,
                                  CompletionRecord, Dispatch, EngineConfig,
                                  SchedulingEngine, VirtualClock,
                                  completion_records)
from repro.serving.forecast import ArrivalForecaster, ForecastConfig
from repro.serving.metrics import cluster_summarize
from repro.serving.policies import Policy
from repro.serving.profiler import LatencyProfile
from repro.serving.queue import Query

# replica-death events carry this sentinel instead of a worker id
ALL_WORKERS = -1

# cluster-only event kinds, continuing engine.py's EV_* numbering so
# simultaneous events keep a deterministic total order: a replica
# becoming ready (cold start paid) processes before the scale tick
# that might read it, and both after all serving events at that time
EV_READY, EV_SCALE = 4, 5


# --------------------------------------------------------------------------
# Placement policies
# --------------------------------------------------------------------------


class PlacementPolicy:
    """Pluggable replica-selection API. ``choose`` sees the *alive*
    replicas as ``(rid, engine)`` pairs and must return one of the
    offered rids; engines are read-only here (introspection methods
    ``queue_depth`` / ``inflight_depth`` / ``work_ahead`` /
    ``projected_drain`` / ``resident_subnets`` /
    ``projected_switch_cost`` only — placement never touches a queue
    and never actuates a subnet)."""

    name: str = "base"

    def reset(self, n_replicas: int, seed: int = 0) -> None:
        pass

    def choose(self, replicas: Sequence[Tuple[int, SchedulingEngine]],
               q: Query, now: float) -> int:
        raise NotImplementedError


class RoundRobin(PlacementPolicy):
    """Cycle through alive replicas in rid order — the classic
    load-oblivious baseline."""

    name = "round_robin"

    def reset(self, n_replicas: int, seed: int = 0) -> None:
        self._i = 0

    def choose(self, replicas, q, now):
        rid = replicas[self._i % len(replicas)][0]
        self._i += 1
        return rid


class LeastLoaded(PlacementPolicy):
    """Join the replica with the smallest total outstanding load
    (queued + in-flight queries); ties break toward the lowest rid."""

    name = "least_loaded"

    def choose(self, replicas, q, now):
        return min(replicas, key=lambda re: (re[1].outstanding(), re[0]))[0]


class PowerOfTwo(PlacementPolicy):
    """Power-of-two-choices (Mitzenmacher): sample two replicas, join
    the less loaded — near-optimal balance at O(1) state. Seeded rng so
    cluster schedules stay deterministic and transport-independent."""

    name = "power_of_two"

    def reset(self, n_replicas: int, seed: int = 0) -> None:
        self._rng = np.random.default_rng(seed)

    def choose(self, replicas, q, now):
        if len(replicas) == 1:
            return replicas[0][0]
        i, j = self._rng.choice(len(replicas), size=2, replace=False)
        a, b = replicas[int(i)], replicas[int(j)]
        ka = (a[1].outstanding(), a[0])
        kb = (b[1].outstanding(), b[0])
        return a[0] if ka <= kb else b[0]


class SlackAware(PlacementPolicy):
    """Deadline-aware routing: a *tight* query goes to the replica that
    can *start it* soonest (``projected_start``: in-flight work plus
    only the EDF queue ahead of its deadline, weighted by pool capacity
    — queued later-deadline work doesn't repel a tight query, since EDF
    serves it first anyway); with generous slack the queue joined
    barely matters, so relaxed queries round-robin to keep load spread.

    What counts as *tight* is learned from the observed slack
    distribution (ROADMAP open item): the threshold is the midpoint of
    the rolling 25th/75th-percentile slacks over the last ``window``
    placements, so a bimodal trace splits between its own modes instead
    of on a fixed multiple of the fastest service time (which misroutes
    whenever both modes sit on the same side of it). A query at the
    threshold counts as tight (``<=``), so a degenerate uniform-slack
    trace — e.g. every query at the paper's 36 ms SLO — routes every
    query by earliest start; skewed mixes likewise err toward *tight*,
    which costs a start-estimate scan, never a misroute. Until
    ``min_history`` slacks are seen the fixed ``tight_mult`` x
    fastest-service fallback applies (and is the whole rule when
    ``adaptive=False``)."""

    name = "slack_aware"

    def __init__(self, tight_mult: float = 10.0, adaptive: bool = True,
                 window: int = 256, min_history: int = 32):
        self.tight_mult = tight_mult
        self.adaptive = adaptive
        self.window = int(window)
        self.min_history = int(min_history)

    def reset(self, n_replicas: int, seed: int = 0) -> None:
        self._i = 0
        self._slacks: deque = deque(maxlen=self.window)
        self._thr: Optional[float] = None   # cached learned threshold
        self._n_seen = 0

    def _threshold(self, min_service: float) -> float:
        if self.adaptive and self._thr is not None:
            return self._thr
        return self.tight_mult * min_service

    def _observe(self, slack: float) -> None:
        """Record a placement-time slack; refresh the learned threshold
        every ``min_history`` observations — the distribution moves
        slowly by construction, and a per-query percentile sort would
        put O(window log window) on the placement hot path."""
        self._slacks.append(slack)
        self._n_seen += 1
        if (self._n_seen >= self.min_history
                and self._n_seen % self.min_history == 0):
            lo, hi = np.percentile(self._slacks, (25, 75))
            self._thr = float(lo + hi) / 2.0

    def choose(self, replicas, q, now):
        slack = q.deadline - now
        thr = self._threshold(replicas[0][1].min_service)
        if self.adaptive:
            self._observe(slack)
        if slack <= thr:
            return min(replicas,
                       key=lambda re: (re[1].projected_start(q.deadline, now),
                                       re[0]))[0]
        rid = replicas[self._i % len(replicas)][0]
        self._i += 1
        return rid


class ActuationAware(PlacementPolicy):
    """Residency-aware routing (ROADMAP "actuation-stationary
    serving"): score every routable replica by when it could *start*
    the query (``projected_start`` — the slack_aware tight-path signal)
    plus ``blend`` times the projected *switch cost* of actuating the
    subnet the query would demand there (``likely_subnet`` x the
    replica's cheapest residency match, both from the engine's
    residency introspection). Route to the cheapest sum, ties toward
    the lowest rid.

    In the SubNetAct regime a switch is a ~50 µs control swap, so this
    degrades gracefully toward slack_aware's earliest-start rule; in
    the weight-loading regime (``load_on_switch``, the Clipper+/INFaaS
    cost model) a switch is a full page-in, and keeping queries on
    replicas already resident on their subnet is the difference between
    batches that meet their deadline and batches that burn it on PCIe.
    Placement stays read-only: residency is consulted, never mutated —
    only the chosen replica's engine actuates at launch."""

    name = "actuation_aware"

    def __init__(self, blend: float = 1.0):
        self.blend = float(blend)

    def choose(self, replicas, q, now):
        slack = max(q.deadline - now, 0.0)

        def score(re):
            rid, e = re
            pi = e.likely_subnet(slack)
            return (e.projected_start(q.deadline, now)
                    + self.blend * e.projected_switch_cost(pi), rid)

        return min(replicas, key=score)[0]


PLACEMENTS: Dict[str, type] = {
    "round_robin": RoundRobin,
    "least_loaded": LeastLoaded,
    "power_of_two": PowerOfTwo,
    "slack_aware": SlackAware,
    "actuation_aware": ActuationAware,
}


def make_placement(name: str) -> PlacementPolicy:
    try:
        return PLACEMENTS[name]()
    except KeyError:
        raise ValueError(f"unknown placement {name!r}; "
                         f"choose from {sorted(PLACEMENTS)}") from None


# --------------------------------------------------------------------------
# Coordinator
# --------------------------------------------------------------------------


class ClusterCoordinator:
    """Global admission + replica routing over N per-replica engines.

    The coordinator owns the master query list (each query admitted to
    the cluster exactly once, however many replicas it visits after
    deaths), the placement policy, and replica liveness. All scheduling
    *within* a replica stays in that replica's engine."""

    def __init__(self, engines: Sequence[SchedulingEngine],
                 placement: PlacementPolicy, placement_seed: int = 0,
                 forecast: Optional[ForecastConfig] = None):
        if not engines:
            raise ValueError("a cluster needs at least one replica")
        self.engines = list(engines)
        self.alive: List[bool] = [True] * len(self.engines)
        # routable = alive AND ready; a replica spawned by the
        # autoscaler is alive-but-warming (cold start) until mark_ready
        self.ready: List[bool] = [True] * len(self.engines)
        self.placement = placement
        placement.reset(len(self.engines), seed=placement_seed)
        self.queries: List[Query] = []      # master admission list
        # cluster-level arrival forecaster (serving/forecast.py): fed
        # once per cluster admission, consumed by forecast-led scaling
        # policies and surfaced through forecast_snapshot — forecasting
        # state lives in the forecaster only, transports never mutate it
        self.forecaster: Optional[ArrivalForecaster] = (
            ArrivalForecaster(forecast) if forecast is not None else None)

    # -- liveness / views ----------------------------------------------

    @property
    def n_replicas(self) -> int:
        return len(self.engines)

    def alive_replicas(self) -> List[Tuple[int, SchedulingEngine]]:
        """Routable replicas: alive and past their cold start."""
        return [(rid, e) for rid, e in enumerate(self.engines)
                if self.alive[rid] and self.ready[rid]]

    # -- replica lifecycle (the autoscaler's surface) -------------------

    def add_replica(self, engine: SchedulingEngine,
                    ready: bool = True) -> int:
        """Register a new replica group. ``ready=False`` keeps it
        unroutable until ``mark_ready`` (the cold-start window). The
        "engine" only needs the coordinator surface — the proc
        transport registers ``ReplicaProxy`` stand-ins here, both for
        autoscaler spawns and for replicas adopted from remote hosts,
        so placement and lifecycle never notice the process (or host)
        boundary."""
        rid = len(self.engines)
        self.engines.append(engine)
        self.alive.append(True)
        self.ready.append(bool(ready))
        return rid

    def mark_ready(self, rid: int) -> None:
        self.ready[rid] = True

    # -- admission -----------------------------------------------------

    def select(self, q: Query, now: float) -> int:
        """Placement decision only: which alive replica should take
        ``q``. The asyncio ClusterRouter admits through the chosen
        replica's own lock, so selection and admission are split."""
        replicas = self.alive_replicas()
        if not replicas:
            raise RuntimeError("no alive replicas left in the cluster")
        return int(self.placement.choose(replicas, q, now))

    def route(self, q: Query, now: float) -> int:
        """Place an existing query on an alive replica (no master-list
        append — the re-route path)."""
        rid = self.select(q, now)
        self.engines[rid].admit(q)          # stamps q.replica = rid
        return rid

    def observe(self, q: Query) -> None:
        """Feed the cluster-level forecaster one admission. Split from
        ``admit`` because the asyncio front door appends to the master
        list itself (its admission goes through the chosen replica's
        lock) — both paths must observe exactly once per arrival."""
        if self.forecaster is not None:
            self.forecaster.observe(q.arrival)

    def admit(self, q: Query, now: float) -> Optional[int]:
        """Cluster front door: record the query once and route it.
        With no routable replica (every one dead, or the survivors all
        still warming) there is nowhere to route — the query is dropped
        (recorded, never served) and None returned."""
        self.queries.append(q)
        self.observe(q)
        if not self.alive_replicas():
            q.dropped = True
            return None
        return self.route(q, now)

    # -- replica death -------------------------------------------------

    def should_decommission(self, rid: int) -> bool:
        """THE decommission rule, stated once for both transports: an
        alive replica whose worker pool is gone can never serve again —
        leave it routable and it black-holes every query placed on
        it."""
        return self.alive[rid] and not len(self.engines[rid].residency)

    def fail_replica(self, rid: int, now: float) -> List[Tuple[Query, int]]:
        """Replica ``rid`` died: fault every worker (re-enqueueing its
        in-flight queries through the engine's own fault path), then
        drain the replica's queue back through placement. Returns the
        re-routed ``(query, new_rid)`` pairs, in EDF order."""
        eng = self.engines[rid]
        for wid in eng.residency.workers():
            eng.fault(wid)
        return self.redistribute(rid, now)

    def redistribute(self, rid: int, now: float) -> List[Tuple[Query, int]]:
        """Drain-and-re-route the replica's queue back through
        placement: THE surrender/drain path, shared by replica death
        (workers already faulted, so the re-enqueued in-flight queries
        are surrendered too) and by the autoscaler's graceful
        decommission (workers untouched — their in-flight batches
        finish on the old replica). With no routable replica left the
        orphans are dropped instead of black-holed."""
        self.alive[rid] = False
        orphans = self.engines[rid].surrender_queue()
        if not self.alive_replicas():
            for q in orphans:
                q.dropped = True
            return []
        return [(q, self.route(q, now)) for q in orphans]

    # -- forecast introspection -----------------------------------------

    def forecast_snapshot(self, now: float
                          ) -> Optional[Dict[str, Optional[float]]]:
        """Read-only forecast bundle (rate / trend / ETA / CV^2 / burst
        flag) at ``now``; None when no forecaster is configured. Both
        transports surface this (ClusterResult.forecast,
        ClusterRouter.stats) — reading it never perturbs the state."""
        if self.forecaster is None:
            return None
        return self.forecaster.snapshot(now)

    # -- residency introspection ----------------------------------------

    def residency_snapshot(self) -> Dict[int, Dict[int, Optional[int]]]:
        """Cluster-wide residency map, rid -> (worker -> resident
        subnet), over alive replicas — read-only (per-replica copies),
        for benchmarks and operator introspection."""
        return {rid: e.resident_subnets()
                for rid, e in enumerate(self.engines) if self.alive[rid]}

    # -- accounting ----------------------------------------------------

    def abandon_pending(self) -> List[Query]:
        out: List[Query] = []
        for eng in self.engines:
            out.extend(eng.abandon_pending())
        return out

    def records(self) -> List[CompletionRecord]:
        return completion_records(self.queries)

    def stats(self) -> Dict[str, float]:
        return cluster_summarize(
            self.queries, n_replicas=self.n_replicas,
            n_joins=sum(e.n_joins for e in self.engines),
            n_switches=sum(e.residency.n_switches for e in self.engines),
            n_dispatches=sum(e.residency.n_launches for e in self.engines),
            actuation_seconds=sum(e.residency.actuation_seconds
                                  for e in self.engines))


# --------------------------------------------------------------------------
# Shared discrete-event loop (virtual time, all replicas on one heap)
# --------------------------------------------------------------------------


def drive_cluster(coord: ClusterCoordinator, queries: Sequence[Query],
                  worker_ids: Dict[int, Iterable[int]],
                  replica_deaths: Optional[Dict[int, float]] = None,
                  fault_times: Optional[Dict[Tuple[int, int], float]] = None,
                  clock: Optional[VirtualClock] = None,
                  service_fn=None, autoscaler=None) -> None:
    """Run the whole cluster to quiescence under one virtual clock.

    The multi-replica analogue of ``engine.drive``: ONE event heap
    ordered ``(t, kind, rid, ident)`` spans every replica, so
    simultaneous events across replicas resolve deterministically and a
    1-replica cluster replays the single-engine loop event-for-event.
    ``service_fn(rid, dispatch, now) -> latency`` optionally perturbs
    the engine's expected service time (simulator stragglers).
    Replica deaths enter as FAULT events with the ``ALL_WORKERS``
    sentinel; per-worker faults as ``(rid, wid)``.

    With a ``ClusterAutoscaler`` (serving/autoscaler.py), periodic
    SCALE ticks run its control loop on this same heap: a spawn
    schedules a READY event after the cold start (only then do the new
    workers join the idle pool), a decommission re-routes the victim's
    queue through placement and wakes the survivors — while the
    victim's in-flight batches still complete (graceful drain). Ticks
    stop once arrivals are exhausted and all work has drained, so the
    loop still quiesces.
    """
    events: List = [(q.arrival, EV_ARRIVAL, 0, q.qid) for q in queries]
    for rid, t in (replica_deaths or {}).items():
        events.append((float(t), EV_FAULT, int(rid), ALL_WORKERS))
    for (rid, wid), t in (fault_times or {}).items():
        events.append((float(t), EV_FAULT, int(rid), int(wid)))
    t_last_arrival = max((q.arrival for q in queries), default=0.0)
    if autoscaler is not None:
        autoscaler.anchor(0.0)          # virtual time starts at 0
        events.append((autoscaler.cfg.interval, EV_SCALE, -1, 0))
    heapq.heapify(events)
    idle: Dict[int, List[int]] = {rid: list(wids)
                                  for rid, wids in worker_ids.items()}
    dead_workers: set = set()               # (rid, wid)
    qmap = {q.qid: q for q in queries}

    def push(t: float, kind: int, rid: int, ident: int) -> None:
        heapq.heappush(events, (t, kind, rid, ident))

    def start(rid: int, d: Dispatch, now: float) -> None:
        eng = coord.engines[rid]
        eng.launch(d, now)
        lat = d.service if service_fn is None else service_fn(rid, d, now)
        d.t_finish = now + lat
        push(d.t_finish, EV_FREE, rid, d.wid)

    def dispatch_all(rid: int, now: float) -> None:
        eng = coord.engines[rid]
        free = idle[rid]
        while free and len(eng.edf):
            wid = free.pop(0)
            d = eng.next_dispatch(wid, now)
            if d is None:
                free.insert(0, wid)
                break
            if d.open:
                push(d.launch_at, EV_LAUNCH, rid, wid)
            else:
                start(rid, d, now)
        for d in eng.try_join(now):
            start(rid, d, now)

    while events:
        now, kind, rid, ident = heapq.heappop(events)
        if clock is not None:
            clock.advance_to(now)
        if kind == EV_ARRIVAL:
            target = coord.admit(qmap[ident], now)
            if target is not None:      # None: whole cluster dead, dropped
                dispatch_all(target, now)
        elif kind == EV_FREE:
            # dead workers (their replica died) discard the batch; a
            # merely-decommissioned replica's workers are NOT dead —
            # their in-flight batches complete (graceful scale-down
            # drain), so only the per-worker death set gates here
            if (rid, ident) in dead_workers:
                continue
            eng = coord.engines[rid]
            d = eng.inflight.get(ident)
            if d is not None and d.launched:
                eng.complete(d, d.t_finish)
            elif d is not None and not d.queries:
                eng.inflight.pop(ident, None)
            idle[rid].append(ident)
            dispatch_all(rid, now)
        elif kind == EV_LAUNCH:
            d = coord.engines[rid].open_batches.get(ident)
            if (d is not None and not d.launched and not d.faulted
                    and d.launch_at == now):
                start(rid, d, now)
        elif kind == EV_FAULT:
            if rid >= len(coord.engines):   # fault injected for a rid
                continue                    # the autoscaler never spawned
            if ident == ALL_WORKERS:        # whole replica dies
                for wid in list(idle.get(rid, [])) + \
                        coord.engines[rid].residency.workers():
                    dead_workers.add((rid, wid))
                idle.get(rid, []).clear()
                was_alive = coord.alive[rid]
                coord.fail_replica(rid, now)
                if autoscaler is not None and was_alive:
                    autoscaler.on_death(rid, now)
                # orphans were re-routed through placement: wake every
                # surviving replica, in rid order, deterministically
                for other, _ in coord.alive_replicas():
                    dispatch_all(other, now)
            else:
                dead_workers.add((rid, ident))
                if ident in idle.get(rid, []):
                    idle[rid].remove(ident)
                coord.engines[rid].fault(ident)
                if coord.should_decommission(rid):
                    # last worker gone: re-route the queue (incl. the
                    # just-re-enqueued batch) to survivors
                    coord.redistribute(rid, now)
                    if autoscaler is not None:
                        autoscaler.on_death(rid, now)
                    for other, _ in coord.alive_replicas():
                        dispatch_all(other, now)
                elif coord.alive[rid]:
                    dispatch_all(rid, now)
                elif len(coord.engines[rid].edf):
                    # the fault re-enqueued an in-flight batch onto an
                    # already-decommissioned replica (scale-down racing
                    # a worker death): surrender it again — the queue
                    # must never silently strand
                    coord.redistribute(rid, now)
                    for other, _ in coord.alive_replicas():
                        dispatch_all(other, now)
        elif kind == EV_READY:              # cold start paid: join the pool
            if not coord.alive[rid]:
                continue                    # died while still warming
            idle[rid] = autoscaler.activate(rid, now)
            dispatch_all(rid, now)
        elif kind == EV_SCALE:
            for ev in autoscaler.tick(now):
                if ev.kind == "spawn":
                    idle[ev.rid] = []       # workers join at READY
                    push(ev.ready_at, EV_READY, ev.rid, 0)
                else:                       # decommission: queue re-routed —
                    for other, _ in coord.alive_replicas():
                        dispatch_all(other, now)   # wake the survivors
            if now <= t_last_arrival or any(
                    len(e.edf) or e.inflight for e in coord.engines):
                push(now + autoscaler.cfg.interval, EV_SCALE, -1, 0)


# --------------------------------------------------------------------------
# Construction helpers
# --------------------------------------------------------------------------


def replica_worker_counts(n_replicas: int,
                          workers_per_replica) -> List[int]:
    """Normalize an int (homogeneous) or per-replica sequence
    (heterogeneous pools — where load-aware placement earns its keep)
    into one worker count per replica."""
    if n_replicas < 1:
        raise ValueError("n_replicas must be >= 1")
    if isinstance(workers_per_replica, int):
        counts = [workers_per_replica] * n_replicas
    else:
        counts = [int(w) for w in workers_per_replica]
        if len(counts) != n_replicas:
            raise ValueError(f"{len(counts)} worker counts for "
                             f"{n_replicas} replicas")
    if any(c < 1 for c in counts):
        raise ValueError("every replica needs at least one worker")
    return counts


def build_engines(profile: LatencyProfile, policy: Policy,
                  n_replicas: int, workers_per_replica,
                  cfg: Optional[EngineConfig] = None
                  ) -> List[SchedulingEngine]:
    """One engine per replica group, each with a *cloned* policy (per-
    replica policy state never couples replicas) and its own worker-id
    space 0..k-1. ``workers_per_replica`` is an int or a per-replica
    sequence."""
    counts = replica_worker_counts(n_replicas, workers_per_replica)
    return [SchedulingEngine(profile, policy.clone(),
                             cfg or EngineConfig(),
                             worker_ids=range(counts[rid]),
                             replica_id=rid)
            for rid in range(n_replicas)]
