"""Transport-agnostic scheduling core (paper §5) shared by the asyncio
``Router`` and the discrete-event ``Simulator``.

The paper describes ONE router architecture — global EDF queue, policy
invocation on worker availability, SubNetAct actuation — and this module
is its single implementation: admission + infeasible-query drop, EDF
ordering, policy invocation, batch formation, actuation-cost accounting
(control-swap vs weight-loading), fault handling with in-flight
re-enqueue, and per-query completion records. Time is injected (a
``Clock``), so the same core runs under wall clock with real JAX
workers (serving/runtime.py) and under virtual time (serving/
simulator.py and the parity tests).

Continuous batching (ROADMAP "in-flight joins"): when a dispatch drains
the queue below the policy's chosen batch size, the batch stays *open*
for a policy-chosen join window; queries arriving inside the window
join the forming batch (up to the profile's largest realizable batch
size), and the policy is re-consulted on every join so the subnet
choice can ride the batch up the Pareto frontier. A join is admitted
only if the batch still meets its earliest member deadline at launch.
"""
from __future__ import annotations

import heapq
import time
from dataclasses import dataclass
from typing import Callable, Dict, Iterable, List, Optional, Sequence

from repro.serving.forecast import ArrivalForecaster, ForecastConfig
from repro.serving.metrics import summarize
from repro.serving.policies import Policy
from repro.serving.profiler import (RTX2080TI, SUBNETACT_ACTUATION_S,
                                    HardwareProfile, LatencyProfile)
from repro.serving.queue import EDFQueue, Query
from repro.serving.residency import ActuationModel, ResidencyTracker


# --------------------------------------------------------------------------
# Clocks
# --------------------------------------------------------------------------


class WallClock:
    """Monotonic wall clock — the asyncio router's default."""

    def now(self) -> float:
        return time.perf_counter()


class VirtualClock:
    """Manually-advanced clock — the simulator's and the parity tests'."""

    def __init__(self, t: float = 0.0):
        self._t = float(t)

    def now(self) -> float:
        return self._t

    def advance_to(self, t: float) -> None:
        if t > self._t:
            self._t = float(t)


# --------------------------------------------------------------------------
# Engine state
# --------------------------------------------------------------------------


@dataclass
class EngineConfig:
    actuation_delay: float = SUBNETACT_ACTUATION_S
    load_on_switch: bool = False        # pay weight-loading on model change
    hw: HardwareProfile = RTX2080TI
    drop_infeasible: bool = True
    continuous_batching: bool = False
    max_join_window: float = 0.25       # hard cap (s) on batch-forming time
    # predictive join windows (ROADMAP "joins at saturation"): hold a
    # forming batch open — even on the pool's LAST free worker — when
    # the engine's arrival forecaster says a joinable arrival lands
    # within the batch's slack budget. Implies in-flight joins; with
    # predictive_joins=False the spare-capacity-only PR 2 gate is the
    # whole rule (pinned in tests/test_engine.py).
    predictive_joins: bool = False
    join_eta_factor: float = 2.0        # window = eta_factor * forecast ETA
    # overload guard: no predictive window within this many forecast
    # windows of an infeasible-drop (drops = the engine's own overload
    # signal; holding the last worker while shedding load turns every
    # held capacity-second into misses behind it)
    drop_guard: float = 1.0
    forecast: Optional[ForecastConfig] = None   # None -> defaults


@dataclass
class Dispatch:
    """One batch bound to one worker, from formation to completion."""

    wid: int
    queries: List[Query]
    pareto_idx: int
    batch_deadline: float = float("inf")  # earliest member deadline
    open: bool = False                  # still admitting in-flight joins
    launch_at: Optional[float] = None   # when an open batch must launch
    joined: int = 0                     # queries admitted after formation
    # filled by SchedulingEngine.launch()
    launched: bool = False
    t_launch: Optional[float] = None
    service: Optional[float] = None     # expected service latency (s)
    acc: Optional[float] = None
    # transport-owned actual finish time (may differ from t_launch +
    # service under stragglers)
    t_finish: Optional[float] = None
    faulted: bool = False


@dataclass
class DispatchRecord:
    t: float
    worker: int
    batch: int
    pareto_idx: int
    acc: float
    latency: float
    queue_len: int
    replica: int = 0
    # continuous-batching introspection: members admitted after batch
    # formation, and the earliest member deadline the launch was checked
    # against — the deadline-soundness property (tests/test_engine.py)
    # asserts t + latency <= batch_deadline whenever joined > 0
    joined: int = 0
    batch_deadline: float = float("inf")


@dataclass(frozen=True)
class CompletionRecord:
    """Per-query outcome — the parity unit between router and simulator."""

    qid: int
    arrival: float
    deadline: float
    finish: Optional[float]
    served_acc: Optional[float]
    dropped: bool
    replica: int = 0


def completion_records(queries: Iterable[Query]) -> List[CompletionRecord]:
    return [CompletionRecord(q.qid, q.arrival, q.deadline, q.finish,
                             q.served_acc, q.dropped, q.replica)
            for q in sorted(queries, key=lambda q: q.qid)]


class SchedulingEngine:
    """The shared scheduling state machine. Callers (transports) own
    time and execution; the engine owns every scheduling decision."""

    def __init__(self, profile: LatencyProfile, policy: Policy,
                 cfg: Optional[EngineConfig] = None,
                 worker_ids: Iterable[int] = (),
                 on_drop: Optional[Callable[[Query], None]] = None,
                 replica_id: int = 0):
        self.profile = profile
        self.policy = policy
        self.cfg = cfg or EngineConfig()
        self.on_drop = on_drop
        self.replica_id = int(replica_id)
        policy.reset()
        self.min_service = float(profile.lat.min())
        self.edf = EDFQueue()
        self.queries: List[Query] = []          # every admitted query
        # single owner of per-worker subnet residency and switch-cost
        # estimation (serving/residency.py); the engine is the only
        # writer — everything else (placement, policies, autoscaler)
        # reads through it
        self.residency = ResidencyTracker(
            profile,
            ActuationModel(actuation_delay=self.cfg.actuation_delay,
                           load_on_switch=self.cfg.load_on_switch,
                           hw=self.cfg.hw),
            worker_ids=worker_ids)
        self.inflight: Dict[int, Dispatch] = {}   # forming or executing
        self.open_batches: Dict[int, Dispatch] = {}
        self.dispatches: List[DispatchRecord] = []
        self.n_joins = 0                        # queries joined in flight
        self.n_open_batches = 0                 # batches that opened a window
        self.n_predictive_windows = 0           # opened with no spare worker
        # in-flight joins are live if either flavor is on; the engine's
        # own forecaster exists only for predictive windows (fed at
        # admission — transports never touch it)
        self._batching = bool(self.cfg.continuous_batching
                              or self.cfg.predictive_joins)
        self.forecaster: Optional[ArrivalForecaster] = (
            ArrivalForecaster(self.cfg.forecast)
            if self.cfg.predictive_joins else None)
        self._last_drop_t = float("-inf")   # predictive-window overload gate

    # -- admission -----------------------------------------------------

    def admit(self, q: Query) -> None:
        q.replica = self.replica_id
        self.queries.append(q)
        self.edf.push(q)
        if self.forecaster is not None:
            self.forecaster.observe(q.arrival)

    def drop_expired(self, now: float) -> List[Query]:
        """Drop queries that cannot meet their deadline even at the
        fastest control choice (the paper's infeasible-query drop)."""
        if not self.cfg.drop_infeasible:
            return []
        dropped = self.edf.drop_expired(now, self.min_service)
        if dropped:
            self._last_drop_t = now
        if self.on_drop is not None:
            for q in dropped:
                self.on_drop(q)
        return dropped

    # -- batch formation -----------------------------------------------

    def next_dispatch(self, wid: int, now: float) -> Optional[Dispatch]:
        """Worker ``wid`` is available: drop infeasible queries, consult
        the policy, and form a batch. The returned dispatch is either
        closed (caller launches it immediately) or open to in-flight
        joins until ``launch_at``. Returns None when nothing remains."""
        self.drop_expired(now)
        if not len(self.edf):
            return None
        slack = self.edf.head_slack(now)
        dec = self.policy.choose(self.profile, slack, len(self.edf),
                                 residency=self.residency.view(wid))
        if dec is None:
            return None
        batch = self.edf.pop_batch(dec.batch_size)
        d = Dispatch(wid=wid, queries=batch, pareto_idx=dec.pareto_idx,
                     batch_deadline=min(q.deadline for q in batch))
        self.inflight[wid] = d
        # Open a join window with spare capacity (the PR 2 rule: holding
        # the pool's LAST free worker would delay the very queries a
        # window is meant to batch) — or, with predictive joins, even on
        # the last worker when the forecast says a joinable arrival
        # lands within the slack budget (the saturation case where
        # spare-capacity-only joins stall: waiting one forecast ETA
        # grows the batch instead of burning a dispatch on it).
        if (self._batching and not len(self.edf)
                and len(batch) < self.profile.batches[-1]):
            # Size the budget for the batch's *next realizable size at
            # its current subnet*: waiting longer than (slack − that
            # grown batch's service time) would endanger the deadline.
            est = self._service_estimate(wid, d.pareto_idx,
                                         self._next_batch(len(batch)))
            budget = min(d.batch_deadline - now - est,
                         dec.join_window, self.cfg.max_join_window)
            window, predicted = 0.0, False
            if len(self.residency) > len(self.inflight):
                window = budget
            elif (self.forecaster is not None
                    # never hold the last worker while shedding load: a
                    # recent infeasible-drop means the pool is in
                    # overload, where every held capacity-second turns
                    # into deadline misses behind it (the deep-overload
                    # regression guard, see tests/test_engine.py)
                    and now - self._last_drop_t
                    >= self.cfg.drop_guard * self.forecaster.cfg.window):
                eta = self.forecaster.eta(now)
                if (self.forecaster.has_signal(now) and eta is not None
                        and eta <= budget):
                    window = min(self.cfg.join_eta_factor * eta, budget)
                    predicted = True
            if window > 1e-9:
                d.open = True
                d.launch_at = now + window
                self.open_batches[wid] = d
                self.n_open_batches += 1
                if predicted:
                    self.n_predictive_windows += 1
        return d

    def _next_batch(self, size: int) -> int:
        """Smallest profiled batch size strictly above ``size``."""
        for b in self.profile.batches:
            if b > size:
                return b
        return self.profile.batches[-1]

    def try_join(self, now: float) -> List[Dispatch]:
        """Continuous batching: admit queued queries into open batches.
        Each join re-consults the policy (the subnet choice rides the
        batch up the Pareto frontier) and is accepted only if the batch
        still meets its earliest deadline at launch. Returns batches
        that filled up (or turned urgent) and must launch *now*."""
        if not self._batching or not self.open_batches:
            return []
        ready: List[Dispatch] = []
        max_b = self.profile.batches[-1]
        for wid, d in list(self.open_batches.items()):
            if d.launched or d.faulted:
                continue
            while len(self.edf) and len(d.queries) < max_b:
                head = self.edf.peek()
                bd = min(d.batch_deadline, head.deadline)
                size = len(d.queries) + 1
                # keep waiting until launch_at if the grown batch still
                # fits: prefer the re-consulted (load-adaptive) policy
                # choice, else keep the batch's current subnet. Under
                # wall clock the window may have already expired (the
                # launch timer not yet fired) — never assess feasibility
                # at a launch time in the past.
                pi = self._feasible_pi(wid, d, size, bd,
                                       max(d.launch_at, now))
                if pi is not None:
                    self._join(d, pi, bd)
                    continue
                # grown batch too slow to keep waiting — join only if
                # launching immediately still meets the deadline
                pi = self._feasible_pi(wid, d, size, bd, now)
                if pi is not None:
                    self._join(d, pi, bd)
                # joined or not, stop holding the worker: launch immediately
                # so capacity frees earliest (degrades to decision-time)
                d.launch_at = now
                ready.append(d)
                break
            if len(d.queries) >= max_b and not any(r is d for r in ready):
                d.launch_at = now
                ready.append(d)
        return ready

    def _feasible_pi(self, wid: int, d: Dispatch, size: int, bd: float,
                     t_launch: float) -> Optional[int]:
        """Subnet for the grown batch launching at ``t_launch``: the
        re-consulted policy choice if deadline-feasible (the batch rides
        the Pareto frontier with the policy — up in light moments, down
        under pressure), else the batch's current subnet if *it* still
        fits; None when the join is infeasible either way."""
        dec = self.policy.choose(self.profile, bd - t_launch, size,
                                 residency=self.residency.view(wid))
        if dec is not None and t_launch + self._service_estimate(
                wid, dec.pareto_idx, size) <= bd:
            return dec.pareto_idx
        if t_launch + self._service_estimate(
                wid, d.pareto_idx, size) <= bd:
            return d.pareto_idx
        return None

    def hold(self, wid: int) -> Dispatch:
        """Mark a worker busy without a real batch (the simulator's
        backup-batch hedging) so the spare-capacity gate and fault
        handling see it; released when its FREE event fires."""
        d = Dispatch(wid=wid, queries=[], pareto_idx=-1)
        self.inflight[wid] = d
        return d

    def _join(self, d: Dispatch, pareto_idx: int, batch_deadline: float) -> None:
        q = self.edf.pop()
        d.queries.append(q)
        d.batch_deadline = batch_deadline
        d.pareto_idx = pareto_idx
        d.joined += 1
        self.n_joins += 1

    def _service_estimate(self, wid: int, pi: int, batch_size: int) -> float:
        lat = self.profile.latency(pi, max(batch_size, 1))
        return self.residency.penalized(lat, wid, pi)

    # -- actuation + completion ----------------------------------------

    def launch(self, d: Dispatch, now: float) -> Dispatch:
        """Close batch formation: compute expected service latency and
        account actuation cost (SubNetAct control-swap vs model-switch
        weight loading) against the worker's resident subnet."""
        eff_b = len(d.queries)
        lat = self._service_estimate(d.wid, d.pareto_idx, eff_b)
        self.residency.actuate(d.wid, d.pareto_idx)
        d.t_launch = now
        d.service = lat
        d.acc = float(self.profile.accs[d.pareto_idx])
        d.open = False
        d.launched = True
        self.open_batches.pop(d.wid, None)
        self.dispatches.append(DispatchRecord(now, d.wid, eff_b, d.pareto_idx,
                                              d.acc, lat, len(self.edf),
                                              replica=self.replica_id,
                                              joined=d.joined,
                                              batch_deadline=d.batch_deadline))
        return d

    def complete(self, d: Dispatch, finish: float) -> List[Query]:
        """Stamp per-query completion records for a finished batch."""
        if d.faulted:
            return []
        for q in d.queries:
            q.finish = finish
            q.served_acc = d.acc
        if self.inflight.get(d.wid) is d:
            del self.inflight[d.wid]
        return d.queries

    # -- faults --------------------------------------------------------

    def fault(self, wid: int) -> List[Query]:
        """Worker died: transparently re-enqueue its in-flight (forming
        or executing) queries so survivors re-serve them (Fig 11a)."""
        self.open_batches.pop(wid, None)
        self.residency.forget(wid)
        d = self.inflight.pop(wid, None)
        if d is None:
            return []
        d.faulted = True
        for q in d.queries:
            q.finish = None
            q.served_acc = None
            self.edf.push(q)
        return d.queries

    def surrender_queue(self) -> List[Query]:
        """Hand every queued query back, most urgent first, without
        marking anything dropped (replica-death path: the coordinator
        re-routes the orphans to surviving replicas). Call after
        ``fault()`` has pushed in-flight queries back into the queue so
        they are surrendered too."""
        return self.edf.drain()

    # -- placement introspection ---------------------------------------
    # Read-only views the cluster coordinator's placement policies use;
    # never consulted by the engine's own scheduling path.

    def queue_depth(self) -> int:
        return len(self.edf)

    def inflight_depth(self) -> int:
        """Queries currently bound to workers (forming or executing)."""
        return sum(len(d.queries) for d in self.inflight.values())

    def outstanding(self) -> int:
        """Total unfinished load: queued + in-flight queries (the
        load-aware placement and autoscaler victim-selection signal)."""
        return len(self.edf) + self.inflight_depth()

    def work_ahead(self, deadline: float) -> int:
        """Queued queries that EDF would serve before an arrival with
        ``deadline``."""
        return self.edf.count_more_urgent(deadline)

    def projected_start(self, deadline: float, now: float) -> float:
        """Deterministic estimate (s) of when an arrival with
        ``deadline`` could start on this replica: remaining in-flight
        service plus the EDF work *ahead of it* (queued queries with
        later deadlines would be served after it, so they don't delay
        it) at the fastest control choice, spread over the worker pool.
        An optimistic lower bound — placement only needs a consistent
        relative ordering across replicas, not truth."""
        busy = 0.0
        for d in self.inflight.values():
            if d.t_finish is not None:
                busy += max(0.0, d.t_finish - now)
            elif d.service is not None:
                busy += d.service
            else:
                busy += self.min_service
        ahead = self.work_ahead(deadline) * self.min_service
        return (busy + ahead) / max(len(self.residency), 1)

    def resident_subnets(self) -> Dict[int, Optional[int]]:
        """Worker -> resident subnet map (read-only copy), alongside
        ``queue_depth``/``work_ahead`` in the placement surface."""
        return self.residency.residency()

    def likely_subnet(self, slack: float) -> int:
        """Subnet the policy would pick for an arrival with ``slack``
        joining this replica's queue — the placement-side estimate of
        what routing a query here would actuate. Read-only and
        worker-independent (no residency bias), so it prices the
        *demand*, not a particular worker."""
        dec = self.policy.choose(self.profile, slack,
                                 self.queue_depth() + 1)
        if dec is not None:
            return dec.pareto_idx
        return int(self.profile.lat[:, 0].argmin())

    def projected_switch_cost(self, pi: int) -> float:
        """Cheapest actuation cost any of this replica's workers would
        pay to serve subnet ``pi`` (0.0 when one is already resident)."""
        return self.residency.min_switch_cost(pi)

    def projected_drain(self, now: float) -> float:
        """Estimate (s) of when this replica would drain ALL queued +
        in-flight work (the start estimate for an arrival behind
        everything)."""
        return self.projected_start(float("inf"), now)

    # -- accounting ----------------------------------------------------

    def abandon_pending(self) -> List[Query]:
        """Mark still-queued queries dropped (router drain path)."""
        out = self.edf.drain()
        for q in out:
            q.dropped = True
        return out

    def records(self) -> List[CompletionRecord]:
        return completion_records(self.queries)

    def stats(self) -> Dict[str, float]:
        return summarize(self.queries, n_joins=self.n_joins,
                         n_switches=self.residency.n_switches,
                         n_dispatches=self.residency.n_launches,
                         actuation_seconds=self.residency.actuation_seconds)


# --------------------------------------------------------------------------
# Deterministic event-driven driver (virtual time)
# --------------------------------------------------------------------------

# event kinds, ordered so simultaneous events process deterministically
EV_ARRIVAL, EV_FAULT, EV_FREE, EV_LAUNCH = 0, 1, 2, 3

# service_fn(dispatch, now, idle_worker_ids, push_event) -> actual latency
ServiceFn = Callable[[Dispatch, float, List[int], Callable], float]


def drive(engine: SchedulingEngine, queries: Sequence[Query],
          worker_ids: Iterable[int],
          fault_times: Optional[Dict[int, float]] = None,
          service_fn: Optional[ServiceFn] = None,
          clock: Optional[VirtualClock] = None) -> None:
    """Run the engine to quiescence under virtual time.

    This is the one discrete-event loop behind both the Simulator and
    the Router's parity mode. ``service_fn`` lets the simulator perturb
    the engine's expected latency (stragglers, backup-batch hedging);
    the default is the engine's own estimate. ``push_event`` hands the
    hook ``(t, kind, ident)`` insertion for backup-batch FREE events.
    """
    events: List = [(q.arrival, EV_ARRIVAL, q.qid) for q in queries]
    for wid, t in (fault_times or {}).items():
        events.append((float(t), EV_FAULT, int(wid)))
    heapq.heapify(events)
    idle: List[int] = list(worker_ids)
    dead: set = set()
    qmap = {q.qid: q for q in queries}

    def push(t: float, kind: int, ident: int) -> None:
        heapq.heappush(events, (t, kind, ident))

    def start(d: Dispatch, now: float) -> None:
        engine.launch(d, now)
        lat = d.service if service_fn is None else service_fn(d, now, idle, push)
        d.t_finish = now + lat
        push(d.t_finish, EV_FREE, d.wid)

    def dispatch_all(now: float) -> None:
        while idle and len(engine.edf):
            wid = idle.pop(0)
            d = engine.next_dispatch(wid, now)
            if d is None:
                idle.insert(0, wid)
                break
            if d.open:
                push(d.launch_at, EV_LAUNCH, wid)
            else:
                start(d, now)
        for d in engine.try_join(now):
            start(d, now)

    while events:
        now, kind, ident = heapq.heappop(events)
        if clock is not None:
            clock.advance_to(now)
        if kind == EV_ARRIVAL:
            engine.admit(qmap[ident])
            dispatch_all(now)
        elif kind == EV_FREE:
            if ident in dead:
                continue
            d = engine.inflight.get(ident)
            if d is not None and d.launched:
                engine.complete(d, d.t_finish)
            elif d is not None and not d.queries:
                engine.inflight.pop(ident, None)   # held hedge backup
            idle.append(ident)
            dispatch_all(now)
        elif kind == EV_LAUNCH:
            d = engine.open_batches.get(ident)
            # launch_at must match the event time: a stale event (its
            # batch already launched early) must not fire a *newer* open
            # batch that happens to occupy the same worker
            if (d is not None and not d.launched and not d.faulted
                    and d.launch_at == now):
                start(d, now)
        elif kind == EV_FAULT:
            dead.add(ident)
            if ident in idle:
                idle.remove(ident)
            engine.fault(ident)
            dispatch_all(now)
