"""Compiled-path subnet executor: AOT-warmed, shape-bucketed real
execution behind the serving plane (ISSUE 8 tentpole).

The paper's core claim is that SubNetAct actuates any point in the
latency-accuracy space *near-instantaneously* because switching subnets
is a control-tuple change, not a model load. This module is that claim
as an execution layer:

* **Traced-control actuation** — one jitted step wraps
  ``models/lm.forward``/``prefill``/``decode_step`` with the *stacked*
  control tuples and the subnet index passed as traced data. The jit
  cache is keyed on shapes only, so actuating a different subnet never
  recompiles (enforced by the ``compat.CompileCounter`` probe in
  tests/test_executor.py and benchmarks/bench_executor.py).
* **Shape buckets** — raw ``(batch, seq)`` shapes are right-padded up
  to configured power-of-two buckets, so the jit cache is bounded by
  the bucket lattice instead of growing with every distinct request
  shape. Right-padding is exact, not approximate: every LM family here
  is causal, so positions ``< length`` never see the pad, and the
  final-position logits are gathered at each row's true ``length - 1``
  (a traced index — no recompile per length).
* **Bounded cache** — compiled executables live in an LRU keyed
  ``(kind, bucket_batch, bucket_seq, tier)`` with an eviction cap and
  hit/miss/compile/eviction counters (surfaced via
  ``Router.stats()["executor"]``).
* **AOT lattice warmup** — :meth:`SubnetExecutor.warmup` pre-compiles
  every bucket the profiler says the policy can choose through
  ``compat.aot_compile`` (``jit(...).lower(...).compile()``), off the
  serving critical path; on releases without the stages API it falls
  back to eager first-call warmup. The first production query never
  pays XLA compile.
* **Buffer donation** — the decode cache is donated back to XLA where
  ``compat.donation_works()`` says the backend honors it, so steady
  decode runs in place instead of reallocating the KV cache per step.

Layering rule: the executor is pure *execution* — it owns compiled
artifacts, padding, and counters, and nothing else. Scheduling stays in
``serving/engine.py``; the executor plugs into the unchanged stack as
``make_supernet_workers`` workers (:meth:`make_workers`) and feeds
``profiler.measure_profile`` (:meth:`measured_profile`) so the engine /
policies / residency layers serve from *measured* latencies without
changing a line.
"""
from __future__ import annotations

import threading
import time
from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from repro import compat
from repro.configs.base import ArchConfig
from repro.core import subnet as sn
from repro.core.pareto import ParetoPoint, pareto_subnets
from repro.kernels.dispatch import model_tier
from repro.models import lm

__all__ = ["ExecutorConfig", "SubnetExecutor", "DecodeCache",
           "bucket_of", "build_executor", "build_serving_executor"]


def bucket_of(n: int, buckets: Sequence[int]) -> int:
    """Smallest configured bucket >= ``n``; beyond the largest bucket,
    the next power of two (the cache still grows only log2-many keys,
    never one per raw shape)."""
    if n <= 0:
        raise ValueError(f"bucket_of: need n >= 1, got {n}")
    for b in buckets:
        if b >= n:
            return int(b)
    p = 1
    while p < n:
        p <<= 1
    return p


@dataclass(frozen=True)
class ExecutorConfig:
    """Bucket lattice + cache policy for one :class:`SubnetExecutor`."""

    batch_buckets: Tuple[int, ...] = (1, 2, 4, 8, 16, 32, 64)
    seq_buckets: Tuple[int, ...] = (16, 32, 64, 128, 256)
    max_entries: int = 32               # LRU cap on compiled executables
    donate_cache: Optional[bool] = None  # None -> compat.donation_works()
    use_aot: bool = True                # AOT warmup via compat.aot_compile
    slice_mode: str = "mask"

    def __post_init__(self):
        for name in ("batch_buckets", "seq_buckets"):
            bs = getattr(self, name)
            if not bs or any(b <= 0 for b in bs) or list(bs) != sorted(bs):
                raise ValueError(f"{name} must be sorted positive ints, "
                                 f"got {bs}")
        if self.max_entries < 1:
            raise ValueError("max_entries must be >= 1")


@dataclass
class DecodeCache:
    """A bucketed KV/state cache plus the geometry it was built at.

    With donation enabled the underlying ``state`` is consumed by the
    decode step that receives it — keep only the cache the step
    returns."""

    batch: int                          # bucketed batch
    seq_cap: int                        # bucketed cache capacity
    state: Any = field(repr=False, default=None)


class _Entry:
    """One compiled (or jit-wrapped) executable in the LRU."""

    __slots__ = ("fn", "aot")

    def __init__(self, fn: Callable, aot: bool):
        self.fn = fn
        self.aot = aot


class SubnetExecutor:
    """Executes real subnet forward passes for the serving plane.

    One instance hosts one supernet (``params`` + ``cfg``) and the
    stacked control tuples of its Pareto subnets; every worker thread
    of a replica shares it (weight-shared, SubNetwork-stationary), so
    the compiled executables and their counters are process-global per
    supernet."""

    def __init__(self, params: Dict, cfg: ArchConfig,
                 points: Optional[Sequence[ParetoPoint]] = None,
                 exec_cfg: Optional[ExecutorConfig] = None):
        self.params = params
        self.cfg = cfg
        self.points: List[ParetoPoint] = list(points or pareto_subnets(cfg))
        ctrls = [sn.make_control(cfg, p.sub) for p in self.points]
        # actuation == indexing this stack with a traced int32 — the
        # whole SubNetAct property hangs on ctrl being data, not shape
        self.stacked_ctrl = {k: jnp.stack([jnp.asarray(c[k]) for c in ctrls])
                             for k in ctrls[0]}
        self.xcfg = exec_cfg or ExecutorConfig()
        self.donate = (self.xcfg.donate_cache
                       if self.xcfg.donate_cache is not None
                       else compat.donation_works())
        self._cache: "OrderedDict[Tuple, _Entry]" = OrderedDict()
        self._lock = threading.RLock()
        self._counters = {"hits": 0, "misses": 0, "compiles": 0,
                          "evictions": 0, "aot_compiles": 0}

    # -- introspection ---------------------------------------------------

    @property
    def n_subnets(self) -> int:
        return len(self.points)

    def accs(self) -> List[float]:
        return [p.acc for p in self.points]

    def counters(self) -> Dict[str, float]:
        """Hit/miss/compile/eviction counters plus current cache size
        (read via ``Router.stats()["executor"]`` on an executor-backed
        router)."""
        with self._lock:
            out = {k: float(v) for k, v in self._counters.items()}
            out["entries"] = float(len(self._cache))
            out["hit_rate"] = (out["hits"] / (out["hits"] + out["misses"])
                               if out["hits"] + out["misses"] else 0.0)
            return out

    def cache_keys(self) -> List[Tuple]:
        with self._lock:
            return list(self._cache.keys())

    # -- bucketed public steps -------------------------------------------

    def prefill(self, subnet_idx: int, tokens,
                lengths: Optional[Sequence[int]] = None) -> np.ndarray:
        """Final-position logits for a (B, S) int32 token batch.

        Pads to the (batch, seq) bucket, executes the compiled entry
        with the subnet index and per-row true lengths as traced data,
        and returns the (B, vocab) logits gathered at each row's last
        real position. Any (B, S) is accepted; only the bucket shape
        touches the jit cache."""
        tokens = np.asarray(tokens, dtype=np.int32)
        if tokens.ndim != 2:
            raise ValueError(f"prefill wants (B, S) tokens, "
                             f"got shape {tokens.shape}")
        B, S = tokens.shape
        Bb = bucket_of(B, self.xcfg.batch_buckets)
        Sb = bucket_of(S, self.xcfg.seq_buckets)
        lens = np.full((Bb,), Sb, np.int32)
        lens[:B] = S if lengths is None else np.asarray(lengths, np.int32)
        if (Bb, Sb) != (B, S):
            padded = np.zeros((Bb, Sb), np.int32)
            padded[:B, :S] = tokens
            tokens = padded
        fn = self._get("prefill", Bb, Sb)
        out = fn(self.params, self.stacked_ctrl, tokens,
                 np.int32(subnet_idx), lens)
        # host copy + host slice: a device-side out[:B] would compile a
        # tiny gather per (bucket, B) pair, breaking zero-compile serving
        return np.asarray(out)[:B]

    def init_cache(self, batch: int, seq_cap: int) -> DecodeCache:
        """Fresh decode cache at the bucketed (batch, capacity)."""
        Bb = bucket_of(batch, self.xcfg.batch_buckets)
        Sb = bucket_of(seq_cap, self.xcfg.seq_buckets)
        state = lm.init_cache(self.cfg, Bb, Sb, dtype=self.cfg.dtype)
        return DecodeCache(batch=Bb, seq_cap=Sb, state=state)

    def decode_step(self, subnet_idx: int, tokens, cache: DecodeCache,
                    index: int) -> Tuple[np.ndarray, DecodeCache]:
        """One decode step: (B, 1) int32 tokens against ``cache``.

        Returns ``(logits (B, vocab), new_cache)``. With donation on,
        ``cache.state`` is consumed in place — use the returned cache."""
        tokens = np.asarray(tokens, dtype=np.int32)
        B = tokens.shape[0]
        if B > cache.batch:
            raise ValueError(f"batch {B} exceeds cache batch {cache.batch}")
        if B < cache.batch:
            tokens = np.concatenate(
                [tokens, np.zeros((cache.batch - B, 1), np.int32)])
        fn = self._get("decode", cache.batch, cache.seq_cap)
        logits, state = fn(self.params, self.stacked_ctrl, tokens,
                           cache.state, np.int32(subnet_idx),
                           np.int32(index))
        return (np.asarray(logits)[:B, 0],
                DecodeCache(cache.batch, cache.seq_cap, state))

    # -- warmup ----------------------------------------------------------

    def warmup(self, batches: Optional[Sequence[int]] = None,
               seqs: Optional[Sequence[int]] = None,
               decode: bool = False) -> Dict[str, float]:
        """AOT-compile the bucket lattice off the serving critical path.

        ``batches`` defaults to the configured batch buckets — pass the
        profile's realizable batch sizes so exactly the buckets the
        policy can choose get compiled. Raises if the lattice exceeds
        the LRU cap (a warmed entry that is evicted before first use
        would silently put compilation back on the critical path)."""
        t0 = time.perf_counter()
        bbs = sorted({bucket_of(b, self.xcfg.batch_buckets)
                      for b in (batches or self.xcfg.batch_buckets)})
        sbs = sorted({bucket_of(s, self.xcfg.seq_buckets)
                      for s in (seqs or self.xcfg.seq_buckets[:1])})
        kinds = ("prefill", "decode") if decode else ("prefill",)
        lattice = [(k, b, s) for k in kinds for b in bbs for s in sbs]
        if len(lattice) > self.xcfg.max_entries:
            raise ValueError(
                f"warmup lattice of {len(lattice)} buckets exceeds "
                f"max_entries={self.xcfg.max_entries}; raise the cap or "
                f"shrink the lattice")
        compiled = 0
        for kind, b, s in lattice:
            before = self._counters["compiles"]
            self._get(kind, b, s)
            compiled += self._counters["compiles"] - before
        return {"n_buckets": float(len(lattice)),
                "n_compiled": float(compiled),
                "seconds": time.perf_counter() - t0}

    # -- serving-stack adapters ------------------------------------------

    def run_prefill(self, subnet_idx: int, batch) -> np.ndarray:
        """``step_fn`` for :func:`runtime.make_supernet_workers`:
        ``batch`` is the padded (B, S) token array; blocks on the
        result (worker threads hand numpy back to the event loop)."""
        return np.asarray(self.prefill(int(subnet_idx), batch))

    @staticmethod
    def pad_batch(payloads: List[Any]) -> np.ndarray:
        """``pad_batch`` for make_supernet_workers: stack token rows —
        padding to shape buckets happens inside the executor."""
        return np.stack([np.asarray(p, dtype=np.int32) for p in payloads])

    def make_workers(self, n: int):
        """``n`` WorkerHandles sharing this executor (weight-shared,
        one jit cache): the real-execution twin of the simulated
        service-time workers."""
        from repro.serving.runtime import make_supernet_workers
        return make_supernet_workers(n, self.run_prefill, self.pad_batch)

    def profile_step_fns(self, seq_len: int) -> List[Callable[[int], None]]:
        """Per-subnet ``fn(batch)`` closures for
        :func:`profiler.measure_profile` (each blocks on its result)."""
        def mk(i: int):
            return lambda b: self.run_prefill(
                i, np.ones((b, seq_len), np.int32))
        return [mk(i) for i in range(self.n_subnets)]

    def measured_profile(self, batches: Sequence[int] = (1, 2, 4, 8),
                         seq_len: int = 16, **kw):
        """Measured ``LatencyProfile`` over this executor's subnets —
        true wall-clock per (subnet, batch bucket) on this host, ready
        to drop into the unchanged engine/policy/residency stack. Run
        :meth:`warmup` first so measurement never times a compile."""
        from repro.serving.profiler import measure_profile
        return measure_profile(self.profile_step_fns(seq_len), self.accs(),
                               batches=tuple(batches), **kw)

    # -- compiled-entry cache --------------------------------------------

    def _get(self, kind: str, Bb: int, Sb: int) -> Callable:
        key = (kind, Bb, Sb, model_tier())
        with self._lock:
            entry = self._cache.get(key)
            if entry is not None:
                self._cache.move_to_end(key)
                self._counters["hits"] += 1
                return entry.fn
            self._counters["misses"] += 1
            entry = self._build(kind, Bb, Sb)
            self._cache[key] = entry
            self._counters["compiles"] += 1
            if entry.aot:
                self._counters["aot_compiles"] += 1
            while len(self._cache) > self.xcfg.max_entries:
                self._cache.popitem(last=False)
                self._counters["evictions"] += 1
            return entry.fn

    def _build(self, kind: str, Bb: int, Sb: int) -> _Entry:
        cfg, slice_mode = self.cfg, self.xcfg.slice_mode
        if kind == "prefill":
            def fn(params, stacked, tokens, idx, lengths):
                ctrl = {k: v[idx] for k, v in stacked.items()}
                logits = lm.forward(params, cfg, {"tokens": tokens}, ctrl,
                                    slice_mode=slice_mode)
                # causal families: the pad never influences positions
                # < length, so gathering at length-1 IS the unpadded
                # answer (pinned per tier in tests/test_executor.py)
                pos = jnp.clip(lengths - 1, 0, tokens.shape[1] - 1)
                return jnp.take_along_axis(
                    logits, pos[:, None, None], axis=1)[:, 0]
            jitted = jax.jit(fn)
            shaped = (self._shaped(self.params), self._shaped(self.stacked_ctrl),
                      jax.ShapeDtypeStruct((Bb, Sb), jnp.int32),
                      jax.ShapeDtypeStruct((), jnp.int32),
                      jax.ShapeDtypeStruct((Bb,), jnp.int32))
        elif kind == "decode":
            def fn(params, stacked, tokens, cache, idx, index):  # noqa: F811
                ctrl = {k: v[idx] for k, v in stacked.items()}
                return lm.decode_step(params, cfg, tokens, ctrl, cache,
                                      index, slice_mode=slice_mode)
            jitted = jax.jit(fn, donate_argnums=(3,) if self.donate else ())
            state = lm.init_cache(cfg, Bb, Sb, dtype=cfg.dtype)
            shaped = (self._shaped(self.params), self._shaped(self.stacked_ctrl),
                      jax.ShapeDtypeStruct((Bb, 1), jnp.int32),
                      self._shaped(state),
                      jax.ShapeDtypeStruct((), jnp.int32),
                      jax.ShapeDtypeStruct((), jnp.int32))
        else:
            raise ValueError(f"unknown step kind {kind!r}")
        if self.xcfg.use_aot:
            compiled = compat.aot_compile(jitted, *shaped)
            if compiled is not None:
                return _Entry(compiled, aot=True)
        # eager fallback: compile on first call (warmup() still pulls
        # this off the critical path by touching every bucket)
        if kind == "prefill":
            jitted(self.params, self.stacked_ctrl,
                   np.zeros((Bb, Sb), np.int32), np.int32(0),
                   np.full((Bb,), Sb, np.int32))
        return _Entry(jitted, aot=False)

    @staticmethod
    def _shaped(tree):
        return jax.tree.map(
            lambda a: jax.ShapeDtypeStruct(jnp.shape(a), jnp.asarray(a).dtype),
            tree)


def build_executor(cfg: ArchConfig, seed: int = 0,
                   exec_cfg: Optional[ExecutorConfig] = None,
                   ) -> SubnetExecutor:
    """Init supernet params for ``cfg`` and wrap them in an executor
    (the ``launch/serve.py --execute real`` entry point)."""
    params = lm.init_model(jax.random.PRNGKey(seed), cfg)
    return SubnetExecutor(params, cfg, exec_cfg=exec_cfg)


def build_serving_executor(arch: str, seq_len: int = 16,
                           batches: Sequence[int] = (1, 2, 4, 8),
                           seed: int = 0) -> SubnetExecutor:
    """Registry-name entry point for serving children
    (``replica_proc --execute real``): build the supernet executor for
    ``arch``'s REDUCED config — the CPU-executable twin whose small
    vocab also keeps per-completion logits safely under the IPC frame
    cap — and AOT-warm the ``batches`` x ``seq_len`` lattice so the
    first submit frame never pays an XLA compile. The coordinator must
    profile the same reduced config for Pareto-set agreement."""
    from repro.configs import get_config
    cfg = get_config(arch).reduced()
    ex = build_executor(cfg, seed=seed)
    ex.warmup(batches=tuple(batches), seqs=(int(seq_len),))
    return ex
