"""Short-horizon arrival-rate forecasting (ROADMAP "predictive scaling
policies" + "predictive join windows").

SuperServe's reactive policies act when load *has already* shifted; the
paper's claim that SubNetAct "unlocks the design space of fine-grained,
reactive scheduling policies" extends naturally to *predictive* ones —
but only if both transports can share one deterministic forecast. This
module is that shared capability: an ``ArrivalForecaster`` whose state
is a pure function of the observed arrival timestamps.

Design rules (the layering rule this PR adds to the ROADMAP):

  * **forecasting state lives here only** — the coordinator and the
    engine own a forecaster and feed it at admission; scaling policies
    (serving/autoscaler.py ``Predictive``) and the engine's predictive
    join windows *consume* it; transports never mutate it;
  * **clock-agnostic** — ``observe(t)`` takes the arrival timestamp
    (virtual or wall), never reads a clock of its own;
  * **deterministic + query-pure** — the same arrival sequence yields a
    byte-identical forecast series, and read methods (``rate`` /
    ``trend`` / ``forecast`` / ``eta`` / ``cv2`` / ``snapshot``) never
    mutate state, so *when* a transport happens to ask cannot perturb
    what a later query returns (property-tested in
    tests/test_forecast.py).

Estimator: a sliding-window rate (count of arrivals in the trailing
``window`` seconds — decays to exactly zero on an idle stream) plus a
Holt double-exponential (level + trend) smoother with time-aware gains
(irregular sampling: the gain compounds per elapsed window, so a gap of
k windows discounts history like k unit steps would), and a burst
detector estimating CV^2 over the recent inter-arrival gaps (the
paper's burstiness knob for its bursty traces).
"""
from __future__ import annotations

from bisect import bisect_right, insort
from collections import deque
from dataclasses import dataclass
from typing import Dict, List, Optional


@dataclass(frozen=True)
class ForecastConfig:
    """Knobs shared by every forecaster consumer (engine-level join
    windows, the coordinator-level scaling forecaster)."""

    window: float = 0.25        # sliding-window width (s)
    alpha: float = 0.5          # Holt level gain per elapsed window
    beta: float = 0.3           # Holt trend gain per elapsed window
    min_arrivals: int = 8       # observations before there is "signal"
    burst_cv2: float = 4.0      # CV^2 above which the burst detector fires
    cv2_gaps: int = 64          # inter-arrival gaps in the CV^2 estimate
    max_horizon: float = 1.0    # clamp on forecast extrapolation (s)

    def validate(self) -> "ForecastConfig":
        if self.window <= 0:
            raise ValueError("window must be > 0")
        if not (0.0 < self.alpha <= 1.0) or not (0.0 < self.beta <= 1.0):
            raise ValueError("alpha/beta must be in (0, 1]")
        if self.min_arrivals < 1:
            raise ValueError("min_arrivals must be >= 1")
        if self.cv2_gaps < 2:
            raise ValueError("cv2_gaps must be >= 2")
        if self.max_horizon < 0:
            raise ValueError("max_horizon must be >= 0")
        return self


class ArrivalForecaster:
    """Deterministic short-horizon arrival-rate estimator.

    ``observe(t)`` records one arrival (timestamps are expected
    near-monotone; slightly stale ones — a re-routed query carrying its
    original arrival — are merged in order and cannot corrupt the
    estimate). All other methods are read-only. History older than two
    windows behind the newest observation is pruned, so reads are exact
    for any ``now`` from one window behind the newest arrival onward —
    i.e. for every caller whose clock doesn't run *behind* the arrivals
    it already admitted.
    """

    def __init__(self, cfg: Optional[ForecastConfig] = None):
        self.cfg = (cfg or ForecastConfig()).validate()
        self._times: List[float] = []   # sorted, pruned to the last 2 windows
        self._epoch: Optional[float] = None     # first observed arrival
        self._latest: float = float("-inf")     # newest observed arrival
        self.n_observed: int = 0
        # Holt state, advanced only by observe()
        self._level: float = 0.0
        self._trend: float = 0.0
        self._t_holt: Optional[float] = None
        self._gaps: deque = deque(maxlen=self.cfg.cv2_gaps)

    # -- writes (admission path only) -----------------------------------

    def observe(self, t: float) -> None:
        """Record one arrival at timestamp ``t``."""
        t = float(t)
        if self._epoch is None:
            self._epoch = t
        else:
            self._gaps.append(max(t - self._latest, 0.0))
        if t >= self._latest:
            self._times.append(t)
            self._latest = t
        else:                           # stale (re-routed) arrival
            insort(self._times, t)
        lo = self._latest - 2.0 * self.cfg.window
        keep = bisect_right(self._times, lo)
        if keep:
            del self._times[:keep]
        self.n_observed += 1
        self._update_holt(t)

    def _update_holt(self, t: float) -> None:
        r = self.rate(max(t, self._latest))
        if self._t_holt is None:
            # initialize at the first NON-zero rate observation: seeding
            # the level at the degenerate single-arrival rate of 0 would
            # ramp the level through the whole warm-up and leave a large
            # phantom trend decaying for several windows after
            if r > 0.0:
                self._level, self._trend, self._t_holt = r, 0.0, t
            return
        dt = max(t - self._t_holt, 0.0)
        if dt <= 0.0:
            # simultaneous arrival: refresh the level, trend unchanged
            # (a zero-dt slope is undefined)
            self._level = ((1.0 - self.cfg.alpha) * self._level
                           + self.cfg.alpha * r)
            return
        steps = dt / self.cfg.window
        a = 1.0 - (1.0 - self.cfg.alpha) ** steps
        b = 1.0 - (1.0 - self.cfg.beta) ** steps
        pred = self._level + self._trend * dt
        level = (1.0 - a) * pred + a * r
        self._trend = (1.0 - b) * self._trend + b * (level - self._level) / dt
        self._level = level
        self._t_holt = t

    # -- reads (pure) ----------------------------------------------------

    def rate(self, now: float) -> float:
        """Arrivals/sec over ``(now - window, now]``. Before the first
        window has elapsed, k arrivals since the first span k-1 gaps,
        so the opening segment is normalized as ``(k-1)/elapsed`` — an
        opening burst reads at full rate (the reactive QueuePressure
        idea) without the division-by-~0 blowup at the very first
        arrival. Exactly 0.0 once the stream has been idle for a full
        window."""
        if self._epoch is None or now < self._epoch:
            return 0.0
        w = self.cfg.window
        lo = bisect_right(self._times, now - w)
        hi = bisect_right(self._times, now)
        n = hi - lo
        if n == 0:
            return 0.0
        elapsed = now - self._epoch
        if elapsed >= w:
            return n / w
        if n < 2:
            return 0.0
        return (n - 1) / max(elapsed, 1e-9)

    def prev_rate(self, now: float) -> float:
        """Arrivals/sec over the window before the current one,
        ``(now - 2*window, now - window]`` (the raw slope's baseline;
        0.0 before that window has fully elapsed)."""
        if self._epoch is None or now - self.cfg.window < self._epoch:
            return 0.0
        w = self.cfg.window
        lo = bisect_right(self._times, now - 2.0 * w)
        hi = bisect_right(self._times, now - w)
        return (hi - lo) / w

    def slope(self, now: float) -> float:
        """Raw windowed rate change (arrivals/sec^2): current window
        minus the previous one, over one window."""
        return (self.rate(now) - self.prev_rate(now)) / self.cfg.window

    def trend(self, now: float) -> float:
        """Holt-smoothed rate change (arrivals/sec^2). Gated to 0 when
        the current window is empty: a stale trend extrapolated from an
        idle stream would forecast arrivals out of nothing."""
        if self.rate(now) <= 0.0:
            return 0.0
        return self._trend

    def forecast(self, now: float, horizon: float = 0.0) -> float:
        """Forecast arrivals/sec at ``now + horizon``: the windowed rate
        extrapolated along the smoothed trend, clamped non-negative and
        to ``max_horizon``. Exactly 0.0 on an idle stream."""
        r = self.rate(now)
        if r <= 0.0:
            return 0.0
        h = min(max(float(horizon), 0.0), self.cfg.max_horizon)
        return max(0.0, r + self.trend(now) * h)

    def smoothed(self, now: float, horizon: float = 0.0) -> float:
        """Holt-smoothed forecast at ``now + horizon``: the smoothed
        level extrapolated along the smoothed trend from its last
        update. Less reactive than ``forecast`` (the raw windowed rate)
        but immune to single-window spikes — the right read for
        capacity decisions, where a spike is the backlog kicker's job
        and a phantom spawn costs a whole cold start + cooldown cycle.
        Exactly 0.0 on an idle stream, like ``forecast``."""
        if self.rate(now) <= 0.0 or self._t_holt is None:
            return 0.0
        h = min(max(float(horizon), 0.0), self.cfg.max_horizon)
        dt = max(now - self._t_holt, 0.0) + h
        return max(0.0, self._level + self._trend * dt)

    def eta(self, now: float) -> Optional[float]:
        """Expected seconds until the next arrival (1/rate), or None on
        an idle stream — the predictive join window's signal."""
        r = self.rate(now)
        return 1.0 / r if r > 0.0 else None

    def cv2(self, now: float) -> float:
        """Squared coefficient of variation of the recent inter-arrival
        gaps (cv2=0 uniform, ~1 Poisson, >1 bursty); 0.0 until two gaps
        have been seen."""
        if len(self._gaps) < 2:
            return 0.0
        gaps = list(self._gaps)
        mean = sum(gaps) / len(gaps)
        if mean <= 1e-12:
            return 0.0
        var = sum((g - mean) ** 2 for g in gaps) / len(gaps)
        return var / (mean * mean)

    def bursty(self, now: float) -> bool:
        """Burst detector: enough signal and the gap CV^2 estimate above
        the configured threshold."""
        return self.has_signal(now) and self.cv2(now) >= self.cfg.burst_cv2

    def has_signal(self, now: float) -> bool:
        """Enough observations to act on, and the stream not idle —
        consumers (the ``predictive`` scaling policy, predictive join
        windows) must fall back to their reactive behavior otherwise."""
        return self.n_observed >= self.cfg.min_arrivals and self.rate(now) > 0

    def snapshot(self, now: float) -> Dict[str, Optional[float]]:
        """Introspection bundle (coordinator/serve.py surface). Every
        value is JSON-safe: an idle stream's undefined ETA is None
        (-> null), never inf (json.dumps would emit the non-RFC
        ``Infinity`` token and break strict parsers on the artifact)."""
        return {
            "t": float(now),
            "n_observed": float(self.n_observed),
            "rate": self.rate(now),
            "trend": self.trend(now),
            "slope": self.slope(now),
            "forecast_1w": self.forecast(now, self.cfg.window),
            "eta": self.eta(now),
            "cv2": self.cv2(now),
            "bursty": float(self.bursty(now)),
            "has_signal": float(self.has_signal(now)),
        }
