"""IPC front door for the multi-host serving plane (ROADMAP
"multi-process, multi-host serving plane").

The inproc ``ClusterRouter`` hosts every replica group in one Python
process; this module splits the transport so each replica group runs in
its own OS process (``serving/replica_proc.py`` is the child
entrypoint) — on this host over an inherited socketpair, or on ANY host
over TCP — behind a length-prefixed JSON-over-socket protocol:

  * **frames** — ``config`` / ``hello`` / ``submit`` / ``completion`` /
    ``kill`` / ``drain`` / ``drained`` / ``stats`` / ``heartbeat``
    (plus the TCP-only ``challenge`` / ``auth`` / ``reject`` handshake
    frames), each a JSON object with a ``t`` kind and a per-direction
    monotonic ``seq`` (gap or replay -> ``OutOfOrderFrame``); the wire
    format is a 4-byte big-endian length prefix + UTF-8 JSON body, with
    a hard frame-size cap (``OversizedFrame``), EOF-mid-frame detection
    (``TruncatedFrame``) and body validation (``MalformedFrame``);
  * **transport** — ``ClusterRouter(transport="proc")`` spawns local
    children over socketpairs (trusted: the fd is inherited, no
    handshake); ``listen="HOST:PORT"`` additionally opens a TCP
    listener, spawns local children through it, and lets REMOTE
    children (``replica_proc --connect HOST:PORT --token ...``) be
    *adopted* into the cluster (``adopt_replica``) after an
    HMAC-SHA256 challenge/response handshake: the coordinator sends a
    nonce + protocol version, the child answers with
    ``HMAC(token, nonce:version)``, and a bad/missing token or a
    version mismatch is rejected (``reject`` frame, counted in
    ``handshake_rejects``) before any serving frame flows;
  * **dead-peer detection** — children heartbeat on an interval; the
    coordinator's per-replica watchdog (plus EOF/ConnectionError on
    either stream) feeds peer death into the *existing*
    drain-and-re-route path: ``ClusterCoordinator.redistribute`` is
    still THE surrender path (the PR 3 rule), the proc transport just
    re-serializes the orphans to the survivors;
  * **lifecycle** — the coordinator process stays the sole owner of
    admission, placement, and lifecycle. The live ``ClusterAutoscaler``
    (serving/autoscaler.py) rides the proc transport exactly as it
    rides inproc: spawn = fork/connect a child priced at the usual cold
    start (routable only after both the handshake AND the cold start
    complete), decommission = a ``drain`` frame through the
    coordinator's surrender path — transports never spawn/kill replicas
    behind the coordinator's back (the PR 4 rule). A ``ReplicaProxy``
    stands in for the remote engine on the coordinator's placement
    surface; the child's ``Router``/engine owns all scheduling *within*
    the replica, exactly as inproc;
  * **execution** — children serve echo/spin workers by default;
    ``execute="real"`` makes each child build a ``SubnetExecutor``
    (serving/executor.py) from the wire spec's arch name, so completion
    frames carry real subnet logits and measured latencies instead of
    echoes.

Clock skew never crosses the boundary: a ``submit`` frame carries the
query's *remaining* SLO, the child recomputes arrival/deadline on its
own wall clock, and the coordinator stamps the master query's finish at
completion-frame receipt (end-to-end latency, IPC included).

Parity bar (tests/test_ipc.py, benchmarks/bench_multiproc.py): a proc
cluster — socketpair or TCP — on a deterministic paced trace reproduces
the inproc ``ClusterRouter``'s completion records — same qids
served/dropped, same served accuracies, same replica assignments —
modulo wall-clock latencies.

Known limits (also in README "Multi-host serving"): payloads must be
JSON-serializable; policies must be registry-constructible by name
(``ALL_POLICIES[name]()``); a completion racing a replica kill or a
graceful decommission may be re-served by a survivor (at-least-once on
death/decommission, exactly-once otherwise); ``execute="real"``
requires the coordinator's ``LatencyProfile`` to be built from the SAME
reduced config the children build (``get_config(arch).reduced()``) so
both sides agree on the Pareto subnet set.
"""
from __future__ import annotations

import asyncio
import hashlib
import hmac
import json
import secrets
import subprocess
import sys
import time
import traceback
from collections import deque
from dataclasses import asdict, dataclass
from pathlib import Path
from typing import Any, Deque, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.serving.autoscaler import (AutoscaleConfig, ClusterAutoscaler,
                                      coordinator_forecast)
from repro.serving.cluster import ClusterCoordinator, make_placement
from repro.serving.engine import EngineConfig, WallClock
from repro.serving.forecast import ForecastConfig
from repro.serving.policies import ALL_POLICIES, Policy
from repro.serving.profiler import HardwareProfile, LatencyProfile
from repro.serving.queue import Query
from repro.serving.residency import ActuationModel
from repro.serving.runtime import ClusterRouter

# -- wire format -----------------------------------------------------------

HEADER_BYTES = 4
MAX_FRAME = 8 << 20                     # 8 MiB: no serving frame is close
HEARTBEAT_S = 0.25                      # child -> parent liveness interval
DEAD_AFTER_BEATS = 8                    # missed beats before declared dead
KILL_ALL = -1                           # kill-frame wid sentinel: whole pool
PROTOCOL_VERSION = 1                    # bumped on incompatible frame changes
HANDSHAKE_TIMEOUT_S = 10.0              # challenge -> auth wait on accept
TOKEN_ENV = "REPRO_IPC_TOKEN"           # token env var (kept off argv/ps)


class FrameError(Exception):
    """Base of the protocol error taxonomy."""


class TruncatedFrame(FrameError):
    """Peer closed (or stream ended) in the middle of a frame."""


class MalformedFrame(FrameError):
    """Body is not valid UTF-8 JSON, or not a ``{"t": ..., "seq": ...}``
    object."""


class OversizedFrame(FrameError):
    """Declared length exceeds the frame-size cap."""


class OutOfOrderFrame(FrameError):
    """Sequence number is not the expected next one (drop or replay)."""


def auth_mac(token: str, nonce: str,
             version: int = PROTOCOL_VERSION) -> str:
    """The handshake response: HMAC-SHA256 over the server's nonce AND
    the protocol version, keyed by the shared token — binding the
    version into the MAC means a version-spoofing auth frame fails the
    MAC check even before the explicit version comparison."""
    msg = f"{nonce}:{version}".encode("utf-8")
    return hmac.new(token.encode("utf-8"), msg, hashlib.sha256).hexdigest()


def to_jsonable(x: Any) -> Any:
    """Best-effort conversion of payloads/stats to JSON-safe values
    (numpy scalars/arrays -> python; unknown leaves -> repr)."""
    if isinstance(x, np.generic):
        return x.item()
    if isinstance(x, np.ndarray):
        return x.tolist()
    if isinstance(x, dict):
        return {str(k): to_jsonable(v) for k, v in x.items()}
    if isinstance(x, (list, tuple)):
        return [to_jsonable(v) for v in x]
    if x is None or isinstance(x, (bool, int, float, str)):
        return x
    return repr(x)


def encode_frame(frame: Dict[str, Any], seq: int,
                 max_frame: int = MAX_FRAME) -> bytes:
    """Stamp ``seq`` and serialize to ``<4-byte len><json body>``."""
    obj = dict(frame)
    obj["seq"] = int(seq)
    body = json.dumps(obj, separators=(",", ":")).encode("utf-8")
    if len(body) > max_frame:
        raise OversizedFrame(
            f"{len(body)}-byte frame exceeds the {max_frame}-byte cap")
    return len(body).to_bytes(HEADER_BYTES, "big") + body


class FrameDecoder:
    """Incremental length-prefixed JSON frame parser.

    Synchronous and transport-free — the same decode path backs the
    asyncio ``FrameStream`` and the protocol unit tests, so the error
    taxonomy is pinned once. ``feed`` returns every complete frame the
    new bytes finish; ``eof`` raises ``TruncatedFrame`` if the stream
    ended mid-frame."""

    def __init__(self, max_frame: int = MAX_FRAME, expect_seq: bool = True):
        self.max_frame = max_frame
        self.expect_seq = expect_seq
        self._buf = bytearray()
        self._need: Optional[int] = None
        self._rx_seq = -1

    def feed(self, data: bytes) -> List[Dict[str, Any]]:
        self._buf.extend(data)
        out: List[Dict[str, Any]] = []
        while True:
            if self._need is None:
                if len(self._buf) < HEADER_BYTES:
                    break
                self._need = int.from_bytes(self._buf[:HEADER_BYTES], "big")
                del self._buf[:HEADER_BYTES]
                if self._need > self.max_frame:
                    raise OversizedFrame(
                        f"peer declared a {self._need}-byte frame "
                        f"(cap {self.max_frame})")
            if len(self._buf) < self._need:
                break
            body = bytes(self._buf[:self._need])
            del self._buf[:self._need]
            self._need = None
            out.append(self._decode(body))
        return out

    def _decode(self, body: bytes) -> Dict[str, Any]:
        try:
            obj = json.loads(body.decode("utf-8"))
        except (UnicodeDecodeError, json.JSONDecodeError) as e:
            raise MalformedFrame(f"undecodable frame body: {e}") from None
        if not isinstance(obj, dict) or not isinstance(obj.get("t"), str):
            raise MalformedFrame("frame is not an object with a 't' kind")
        if self.expect_seq:
            seq = obj.get("seq")
            if not isinstance(seq, int) or isinstance(seq, bool):
                raise MalformedFrame("frame missing an integer 'seq'")
            if seq != self._rx_seq + 1:
                raise OutOfOrderFrame(
                    f"got seq {seq}, expected {self._rx_seq + 1}")
            self._rx_seq = seq
        return obj

    def eof(self) -> None:
        if self._need is not None or self._buf:
            raise TruncatedFrame(
                f"peer closed mid-frame ({len(self._buf)} bytes buffered, "
                f"{'header' if self._need is None else self._need} pending)")


class FrameStream:
    """Asyncio send/recv of frames over one (reader, writer) pair, with
    per-direction monotonic sequence numbers (assigned on send, verified
    on receive by the shared ``FrameDecoder``)."""

    def __init__(self, reader: asyncio.StreamReader,
                 writer: asyncio.StreamWriter,
                 max_frame: int = MAX_FRAME):
        self._r = reader
        self._w = writer
        self._tx_seq = 0
        self._tx_lock = asyncio.Lock()
        self._decoder = FrameDecoder(max_frame=max_frame)
        # a deque, not a list: one read() burst can finish hundreds of
        # frames under bursty traffic, and popping a list head is O(n)
        # per frame — O(n^2) per burst
        self._pending: Deque[Dict[str, Any]] = deque()
        self.last_rx = time.monotonic()     # watchdog signal (any bytes)

    async def send(self, frame: Dict[str, Any]) -> None:
        async with self._tx_lock:
            data = encode_frame(frame, self._tx_seq)
            self._tx_seq += 1
            self._w.write(data)
            await self._w.drain()

    async def recv(self) -> Optional[Dict[str, Any]]:
        """Next frame, or None on clean EOF at a frame boundary. Raises
        the ``FrameError`` taxonomy on protocol violations."""
        while not self._pending:
            chunk = await self._r.read(1 << 16)
            if not chunk:
                self._decoder.eof()
                return None
            self.last_rx = time.monotonic()
            self._pending.extend(self._decoder.feed(chunk))
        return self._pending.popleft()

    def close(self) -> None:
        try:
            self._w.close()
        except Exception:
            pass


async def heartbeat_loop(stream: FrameStream,
                         interval: float = HEARTBEAT_S,
                         errors: Optional[Dict[str, int]] = None) -> None:
    """Child-side liveness beacon; cancelled at shutdown.

    A send that hits a dead/backpressured connection must NOT die with
    an unobserved exception — the child would silently stop beating
    while still serving, and the parent's watchdog would declare a live
    replica dead after ``DEAD_AFTER_BEATS``. Connection failures end
    the loop cleanly instead, counted into ``errors`` (surfaced through
    the child's ``stats`` counters as ``heartbeat_send_errors``)."""
    while True:
        await asyncio.sleep(interval)
        try:
            await stream.send({"t": "heartbeat", "now": time.monotonic()})
        except (ConnectionError, OSError, RuntimeError):
            if errors is not None:
                errors["heartbeat_send_errors"] = (
                    errors.get("heartbeat_send_errors", 0) + 1)
            return


# -- replica spec (what crosses the process boundary at spawn) -------------


@dataclass
class _WeightOnlyPoint:
    """Stand-in for a ParetoPoint on the wire: the residency layer's
    ActuationModel reads only ``weight_mb`` (and falls back to a default
    footprint when absent), so the subnet descriptor stays parent-side."""
    weight_mb: float
    acc: float = 0.0
    gflops: float = 0.0
    sub: Any = None


def profile_to_wire(profile: LatencyProfile) -> Dict[str, Any]:
    return {
        "arch": profile.arch,
        "accs": np.asarray(profile.accs, float).tolist(),
        "batches": list(profile.batches),
        "lat": np.asarray(profile.lat, float).tolist(),
        "n_buckets": int(profile.n_buckets),
        "weight_mb": [float(p.weight_mb) for p in profile.points] or None,
        "point_accs": [float(p.acc) for p in profile.points] or None,
    }


def profile_from_wire(spec: Dict[str, Any]) -> LatencyProfile:
    points = []
    if spec.get("weight_mb"):
        accs = spec.get("point_accs") or [0.0] * len(spec["weight_mb"])
        points = [_WeightOnlyPoint(weight_mb=w, acc=a)
                  for w, a in zip(spec["weight_mb"], accs)]
    return LatencyProfile(
        arch=spec["arch"], accs=np.asarray(spec["accs"], float),
        batches=tuple(int(b) for b in spec["batches"]),
        lat=np.asarray(spec["lat"], float), points=points,
        n_buckets=int(spec["n_buckets"]))


def engine_cfg_to_wire(cfg: Optional[EngineConfig]) -> Optional[Dict]:
    if cfg is None:
        return None
    d = asdict(cfg)
    d["hw"] = asdict(cfg.hw)
    d["forecast"] = asdict(cfg.forecast) if cfg.forecast else None
    return d


def engine_cfg_from_wire(d: Optional[Dict]) -> Optional[EngineConfig]:
    if d is None:
        return None
    d = dict(d)
    d["hw"] = HardwareProfile(**d["hw"])
    d["forecast"] = ForecastConfig(**d["forecast"]) if d["forecast"] else None
    return EngineConfig(**d)


@dataclass
class ReplicaSpec:
    """Declarative replica-process recipe: everything the child needs to
    build its ``Router`` — locally spawned or adopted from a remote
    host. Worker ``run`` callables never cross the boundary: the child
    hosts either an echo worker with an optional CPU spin
    (``execute="echo"``, the scale-out benchmark's stand-in) or a real
    ``SubnetExecutor`` built from ``arch``'s reduced config
    (``execute="real"``)."""

    profile: Dict[str, Any]             # profile_to_wire output
    policy: str                         # ALL_POLICIES key
    n_workers: int = 1
    engine_cfg: Optional[Dict] = None   # engine_cfg_to_wire output
    work_ms: float = 0.0                # synthetic per-batch CPU spin
    host_devices: int = 0               # XLA fake-device pinning (0 = off)
    heartbeat_s: float = HEARTBEAT_S
    execute: str = "echo"               # "echo" | "real" (SubnetExecutor)
    arch: Optional[str] = None          # execute="real": config registry key
    seq_len: int = 16                   # execute="real": tokens per payload
    seed: int = 0                       # execute="real": supernet init seed

    def to_wire(self) -> Dict[str, Any]:
        return asdict(self)

    @classmethod
    def from_wire(cls, d: Dict[str, Any]) -> "ReplicaSpec":
        return cls(**d)


# -- coordinator-side replica stand-in -------------------------------------


class _ProxyResidency:
    """The slice of ``ResidencyTracker`` the coordinator reads on a
    remote replica: worker count/ids for the decommission rule
    (``should_decommission``: a replica with no workers can never serve)
    the aggregate switch counters (refreshed from child stats), and the
    cluster's ``ActuationModel`` so the autoscaler can derive replica
    cold start (``AutoscaleConfig.cold_start=None``) exactly as it does
    from an inproc engine's tracker."""

    def __init__(self, n_workers: int, model: ActuationModel):
        self._wids = list(range(n_workers))
        self.model = model
        self.n_switches = 0
        self.n_launches = 0
        self.actuation_seconds = 0.0

    def __len__(self) -> int:
        return len(self._wids)

    def workers(self) -> List[int]:
        return list(self._wids)

    def remove(self, wid: int) -> None:
        if wid in self._wids:
            self._wids.remove(wid)

    def clear(self) -> None:
        self._wids.clear()


class ReplicaProxy:
    """Coordinator-side stand-in for a remote replica's engine.

    Satisfies exactly the surface ``ClusterCoordinator`` (and the
    ``ClusterAutoscaler`` riding it) consumes — ``admit`` / ``fault`` /
    ``surrender_queue`` / ``abandon_pending``, the residency view, and
    the placement introspection methods. All introspection is the
    *parent's* view (master queries pending on the replica), not the
    child's live queue state: round_robin placement is exact; load-aware
    placements and scaling signals see pending counts (documented
    limit). Scheduling still happens only in the child's engine."""

    def __init__(self, replica_id: int, n_workers: int,
                 profile: LatencyProfile, front: "ProcClusterRouter"):
        self.replica_id = replica_id
        self.profile = profile
        self.min_service = float(profile.lat.min())
        self.residency = _ProxyResidency(n_workers, front._actuation_model)
        self.n_joins = 0
        self.pending: Dict[int, Query] = {}     # qid -> outstanding master q
        self.child_stats: Optional[Dict[str, Any]] = None
        self._front = front

    # -- coordinator surface -------------------------------------------

    def admit(self, q: Query) -> None:
        q.replica = self.replica_id
        self.pending[q.qid] = q
        self._front._send_submit(self.replica_id, q)

    def fault(self, wid: int) -> None:
        self.residency.remove(wid)

    def surrender_queue(self) -> List[Query]:
        """Orphans in EDF order (deadline, then FIFO seq/qid) — the
        re-route path re-places them deterministically."""
        out = sorted(self.pending.values(),
                     key=lambda q: (q.deadline, q.seq, q.qid))
        self.pending.clear()
        return out

    def abandon_pending(self) -> List[Query]:
        return []

    # -- placement introspection (parent-side view) --------------------

    def outstanding(self) -> int:
        return len(self.pending)

    def queue_depth(self) -> int:
        return len(self.pending)

    def inflight_depth(self) -> int:
        return 0

    def work_ahead(self, deadline: float) -> int:
        return sum(1 for q in self.pending.values()
                   if q.deadline <= deadline)

    def projected_start(self, deadline: float, now: float) -> float:
        return (self.work_ahead(deadline) * self.min_service
                / max(len(self.residency), 1))

    def resident_subnets(self) -> Dict[int, Optional[int]]:
        return dict.fromkeys(self.residency.workers())

    def likely_subnet(self, slack: float) -> int:
        return int(self.profile.lat[:, 0].argmin())

    def projected_switch_cost(self, pi: int) -> float:
        return 0.0

    def refresh(self, counters: Dict[str, Any]) -> None:
        """Fold a child stats/drained frame's raw counters into the
        coordinator-side aggregates (cluster_summarize reads these)."""
        self.child_stats = counters
        self.n_joins = int(counters.get("n_joins", self.n_joins))
        res = self.residency
        res.n_switches = int(counters.get("n_switches", res.n_switches))
        res.n_launches = int(counters.get("n_launches", res.n_launches))
        res.actuation_seconds = float(
            counters.get("actuation_seconds", res.actuation_seconds))


# -- per-replica channel ----------------------------------------------------


class _Channel:
    """Parent-side bookkeeping for one replica process: subprocess
    handle (None for replicas adopted from a remote host — their
    lifetime belongs to that host), frame stream, sync-callable outbox,
    and its asyncio tasks."""

    def __init__(self, rid: int, proc: Optional[subprocess.Popen] = None):
        self.rid = rid
        self.proc = proc
        self.stream: Optional[FrameStream] = None
        self.outbox: "asyncio.Queue[Optional[dict]]" = asyncio.Queue()
        self.tasks: List[asyncio.Task] = []
        self.hello: Dict[str, Any] = {}
        self.drained = asyncio.Event()
        self.stats_ready = asyncio.Event()
        self.protocol_error: Optional[FrameError] = None

    def stop(self, kill: bool = True) -> None:
        for t in self.tasks:
            t.cancel()
        self.tasks.clear()
        if self.stream is not None:
            self.stream.close()
        if kill and self.proc is not None and self.proc.poll() is None:
            self.proc.kill()


def _src_root() -> str:
    # the child must import repro from the same tree as the parent
    import repro
    return str(Path(repro.__file__).resolve().parent.parent)


def spawn_replica_proc(spec: ReplicaSpec) -> subprocess.Popen:
    """Start one replica worker process connected by a socketpair.

    The env comes from ``compat.host_devices_env`` (CPU-pinned,
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` when the spec
    pins fake devices) — set *before* the child ever imports jax, which
    is the whole point of the process split on CPU CI. The parent-side
    socket rides on ``proc._ipc_sock``. The inherited fd is trusted:
    no handshake (only a process the coordinator itself spawned can
    hold the other end)."""
    import socket as socketlib

    from repro.compat import host_devices_env   # deferred: imports jax
    parent_sock, child_sock = socketlib.socketpair()
    env = host_devices_env(spec.host_devices, PYTHONPATH=_src_root())
    proc = subprocess.Popen(
        [sys.executable, "-m", "repro.serving.replica_proc",
         "--fd", str(child_sock.fileno())],
        pass_fds=(child_sock.fileno(),), env=env)
    child_sock.close()
    proc._ipc_sock = parent_sock                # type: ignore[attr-defined]
    return proc


def spawn_replica_proc_tcp(spec: ReplicaSpec, addr: Tuple[str, int],
                           token: str) -> subprocess.Popen:
    """Start one replica worker process that dials the coordinator's
    TCP listener and authenticates — the same spawn path a remote host
    runs by hand (``replica_proc --connect HOST:PORT --token ...``).
    The token travels in the child env (``REPRO_IPC_TOKEN``), never on
    argv, so it stays out of process listings."""
    from repro.compat import host_devices_env   # deferred: imports jax
    env = host_devices_env(spec.host_devices, PYTHONPATH=_src_root())
    env[TOKEN_ENV] = token
    host, port = addr
    return subprocess.Popen(
        [sys.executable, "-m", "repro.serving.replica_proc",
         "--connect", f"{host}:{port}"], env=env)


# -- the proc-transport cluster front door ---------------------------------


class ProcClusterRouter(ClusterRouter):
    """``ClusterRouter`` with ``transport="proc"``: same public surface
    (``start`` / ``submit`` / ``kill_worker`` / ``kill_replica`` /
    ``drain`` / ``stats`` / ``records``), but every replica group is a
    separate OS process serving frames through ``replica_proc.py`` —
    over inherited socketpairs, or over TCP with ``listen="HOST:PORT"``
    (port 0 picks a free one; resolved address in ``listen_addr``, the
    shared token in ``token``, auto-generated when not given).

    The coordinator (this process) remains the sole owner of admission,
    placement, and lifecycle; the transport is a thin shim — serialize
    the payload, forward the placement decision as a ``submit`` frame,
    stream ``completion`` frames back onto the master queries. Replica
    death (kill, EOF, heartbeat loss) funnels into
    ``ClusterCoordinator.redistribute`` exactly like inproc, and the
    live autoscaler drives spawn/decommission through the same
    coordinator hooks as the inproc plane."""

    def __init__(self, profile: LatencyProfile, policy: Policy,
                 replicas: Sequence, clock=None,
                 engine_cfg: Optional[EngineConfig] = None,
                 placement: str = "round_robin", placement_seed: int = 0,
                 autoscale: Optional[AutoscaleConfig] = None,
                 worker_factory=None, slo: float = 0.036,
                 forecast: Optional[ForecastConfig] = None,
                 transport: str = "proc", work_ms: float = 0.0,
                 host_devices: int = 0, heartbeat_s: float = HEARTBEAT_S,
                 spawn_timeout: float = 60.0,
                 listen: Optional[str] = None, token: Optional[str] = None,
                 execute: str = "echo", arch: Optional[str] = None,
                 seq_len: int = 16, seed: int = 0):
        if transport != "proc":
            raise ValueError(f"ProcClusterRouter is the proc transport "
                             f"(got transport={transport!r})")
        if clock is not None and not isinstance(clock, WallClock):
            raise ValueError("the proc transport is wall-clock only "
                             "(virtual parity runs stay inproc)")
        if type(policy) is not ALL_POLICIES.get(policy.name):
            raise ValueError(
                f"policy {type(policy).__name__} is not registry-"
                f"constructible (ALL_POLICIES[{policy.name!r}]()); the "
                f"replica process rebuilds policies by name")
        if execute not in ("echo", "real"):
            raise ValueError(f"execute must be 'echo' or 'real', "
                             f"got {execute!r}")
        if execute == "real" and not arch:
            raise ValueError(
                "execute='real' needs arch=<config registry name>: the "
                "child builds its SubnetExecutor from "
                "get_config(arch).reduced() — build the coordinator's "
                "profile from the same reduced config")
        if token is not None and listen is None:
            raise ValueError("token only applies with listen= "
                             "(socketpair children inherit a trusted fd)")
        self.profile = profile
        self.clock = clock if clock is not None else WallClock()
        counts = [len(g) if isinstance(g, (list, tuple)) else int(g)
                  for g in replicas]
        if not counts or any(c < 1 for c in counts):
            raise ValueError("every replica needs at least one worker")
        self.spec = ReplicaSpec(
            profile=profile_to_wire(profile), policy=policy.name,
            engine_cfg=engine_cfg_to_wire(engine_cfg), work_ms=work_ms,
            host_devices=host_devices, heartbeat_s=heartbeat_s,
            execute=execute, arch=arch, seq_len=seq_len, seed=seed)
        self._counts = counts
        self._spawn_timeout = spawn_timeout
        # the TCP front door: parsed listen request, resolved address
        # after _start_listener, the shared HMAC token, and the pairing
        # queues matching authenticated connections to spawn/adopt calls
        self._listen_req: Optional[Tuple[str, int]] = None
        if listen is not None:
            host, _, port = str(listen).rpartition(":")
            if not host or not port.lstrip("-").isdigit():
                raise ValueError(f"listen must be 'HOST:PORT', "
                                 f"got {listen!r}")
            self._listen_req = (host, int(port))
        self.token = token
        if self._listen_req is not None and self.token is None:
            self.token = secrets.token_hex(16)
        self._server: Optional[asyncio.AbstractServer] = None
        self.listen_addr: Optional[Tuple[str, int]] = None
        self.handshake_rejects = 0
        self._pending_conns: Deque[FrameStream] = deque()
        self._conn_waiters: Deque[asyncio.Future] = deque()
        # the cluster's one ActuationModel (residency.py): proxies carry
        # it so autoscaler cold-start derivation works over proc too
        ecfg = engine_cfg or EngineConfig()
        self._actuation_model = ActuationModel(
            actuation_delay=ecfg.actuation_delay,
            load_on_switch=ecfg.load_on_switch, hw=ecfg.hw)
        self.proxies = [ReplicaProxy(rid, n, profile, self)
                        for rid, n in enumerate(counts)]
        self.coord = ClusterCoordinator(
            self.proxies, make_placement(placement),
            placement_seed=placement_seed,
            forecast=coordinator_forecast(autoscale, forecast))
        self.autoscaler = None
        self._autoscale_errors = 0
        self._scale_task: Optional[asyncio.Task] = None
        self._spawn_workers = counts[0]
        if autoscale is not None:
            if len(counts) > autoscale.max_replicas:
                raise ValueError(
                    f"{len(counts)} initial replicas exceed "
                    f"max_replicas={autoscale.max_replicas}")
            if autoscale.spawn_workers is None and len(set(counts)) > 1:
                raise ValueError(
                    "heterogeneous worker pools need an explicit "
                    "AutoscaleConfig.spawn_workers")
            if autoscale.spawn_workers:
                self._spawn_workers = autoscale.spawn_workers
            self.autoscaler = ClusterAutoscaler(
                self.coord, autoscale, self._spawn_proxy, slo=slo,
                migrate_fn=self._on_decommission)
        self._qid = 0
        self._started = False
        self._closing = False
        self._chans: List[_Channel] = []
        self._futs: Dict[int, asyncio.Future] = {}
        self._payloads: Dict[int, Any] = {}
        # qid index over the master list: drain resolves leftovers via
        # this instead of a linear scan of coord.queries per qid
        self._by_qid: Dict[int, Query] = {}
        self._all_done = asyncio.Event()
        self._all_done.set()

    # -- lifecycle ------------------------------------------------------

    async def start(self) -> None:
        if self._listen_req is not None:
            await self._start_listener()
        for rid in range(len(self._counts)):
            self._chans.append(_Channel(rid))
            await self._connect_child(rid)
        self._started = True
        if self.autoscaler is not None:
            self.autoscaler.anchor(self.clock.now())
            self._scale_task = asyncio.get_running_loop().create_task(
                self._autoscale_loop())

    async def _start_listener(self) -> Tuple[str, int]:
        """Open the TCP front door (idempotent); resolves port 0 to the
        kernel-assigned port and returns the bound address."""
        if self._server is None:
            host, port = self._listen_req
            self._server = await asyncio.start_server(
                self._on_tcp_connect, host, port)
            sockname = self._server.sockets[0].getsockname()
            self.listen_addr = (sockname[0], int(sockname[1]))
        return self.listen_addr

    async def _on_tcp_connect(self, reader: asyncio.StreamReader,
                              writer: asyncio.StreamWriter) -> None:
        """Accept path: challenge/auth handshake, then hand the stream
        to whichever spawn/adopt call is waiting for a child (or park
        it for the next one). Rejected peers never reach pairing."""
        stream = FrameStream(reader, writer)
        nonce = secrets.token_hex(16)
        try:
            await stream.send({"t": "challenge", "nonce": nonce,
                               "version": PROTOCOL_VERSION})
            auth = await asyncio.wait_for(stream.recv(),
                                          timeout=HANDSHAKE_TIMEOUT_S)
        except (FrameError, ConnectionError, OSError,
                asyncio.TimeoutError):
            stream.close()
            return
        ok, reason = self._verify_auth(auth, nonce)
        if not ok:
            self.handshake_rejects += 1
            try:
                await stream.send({"t": "reject", "reason": reason})
            except (ConnectionError, OSError, RuntimeError):
                pass
            stream.close()
            return
        while self._conn_waiters:
            fut = self._conn_waiters.popleft()
            if not fut.done():
                fut.set_result(stream)
                return
        self._pending_conns.append(stream)

    def _verify_auth(self, auth: Optional[Dict[str, Any]],
                     nonce: str) -> Tuple[bool, str]:
        if auth is None or auth.get("t") != "auth":
            return False, f"expected an auth frame, got {auth!r}"
        if auth.get("version") != PROTOCOL_VERSION:
            return False, (
                f"protocol version mismatch: coordinator speaks "
                f"{PROTOCOL_VERSION}, peer sent {auth.get('version')!r}")
        mac = auth.get("mac")
        if not isinstance(mac, str) or not hmac.compare_digest(
                mac, auth_mac(self.token, nonce)):
            return False, "bad or missing token (HMAC mismatch)"
        return True, ""

    async def _await_child_conn(self, timeout: float) -> FrameStream:
        if self._pending_conns:
            return self._pending_conns.popleft()
        fut = asyncio.get_running_loop().create_future()
        self._conn_waiters.append(fut)
        return await asyncio.wait_for(fut, timeout)

    async def _connect_child(self, rid: int) -> None:
        """Bring replica ``rid``'s child up on the configured transport:
        fork over a socketpair, or fork-and-dial through the TCP
        listener (same handshake a remote child passes)."""
        ch = self._chans[rid]
        spec = ReplicaSpec(**{**self.spec.to_wire(),
                              "n_workers": self._counts[rid]})
        if self._listen_req is None:
            ch.proc = spawn_replica_proc(spec)
            sock = ch.proc._ipc_sock        # type: ignore[attr-defined]
            reader, writer = await asyncio.open_connection(sock=sock)
            stream = FrameStream(reader, writer)
        else:
            ch.proc = spawn_replica_proc_tcp(spec, self.listen_addr,
                                             self.token)
            stream = await self._await_child_conn(self._spawn_timeout)
        await self._attach(ch, stream, spec)

    async def _attach(self, ch: _Channel, stream: FrameStream,
                      spec: ReplicaSpec) -> None:
        """Shared spawn/adopt tail: config/hello exchange, then the
        channel's pump tasks take over the stream."""
        ch.stream = stream
        await stream.send(
            {"t": "config", "rid": ch.rid, "spec": spec.to_wire()})
        hello = await asyncio.wait_for(stream.recv(),
                                       timeout=self._spawn_timeout)
        if hello is None or hello.get("t") != "hello":
            raise MalformedFrame(
                f"replica {ch.rid}: expected hello, got {hello!r}")
        ch.hello = hello
        loop = asyncio.get_running_loop()
        ch.tasks = [loop.create_task(self._send_loop(ch)),
                    loop.create_task(self._read_loop(ch)),
                    loop.create_task(self._watchdog(ch))]

    async def adopt_replica(self, n_workers: int = 1,
                            timeout: Optional[float] = None) -> int:
        """Admit a REMOTE child into the cluster: wait for the next
        authenticated TCP connection (a ``replica_proc --connect``
        started on another host), register it as a new ready replica,
        and return its rid. The adopted process belongs to its own
        host — ``kill_replica``/shutdown close its stream rather than
        SIGKILLing a pid the coordinator doesn't own."""
        if self._listen_req is None:
            raise ValueError("adopt_replica needs listen= (the TCP "
                             "front door remote children dial)")
        await self._start_listener()
        stream = await self._await_child_conn(
            timeout if timeout is not None else self._spawn_timeout)
        rid = len(self.proxies)
        self._counts.append(n_workers)
        proxy = ReplicaProxy(rid, n_workers, self.profile, self)
        self.proxies.append(proxy)
        ch = _Channel(rid)
        self._chans.append(ch)
        self.coord.add_replica(proxy, ready=True)
        if self.autoscaler is not None:
            # adopted capacity bills from adoption (span parallels the
            # autoscaler's own spawns so replica_spans stays total)
            self.autoscaler._spans.setdefault(
                rid, [self.clock.now(), None])
        spec = ReplicaSpec(**{**self.spec.to_wire(),
                              "n_workers": n_workers})
        await self._attach(ch, stream, spec)
        return rid

    # -- live autoscaling (coordinator-owned lifecycle) -----------------

    def _spawn_proxy(self, rid: int) -> ReplicaProxy:
        """Autoscaler ``engine_factory``: register the coordinator-side
        stand-in synchronously (the autoscaler's spawn bookkeeping is
        sync); the control loop forks/connects the actual child right
        after the tick returns."""
        assert len(self.proxies) == rid == len(self._chans)
        self._counts.append(self._spawn_workers)
        proxy = ReplicaProxy(rid, self._spawn_workers, self.profile, self)
        self.proxies.append(proxy)
        self._chans.append(_Channel(rid))
        return proxy

    async def _autoscale_loop(self) -> None:
        """Live control loop: the proc twin of the inproc
        ``ClusterRouter._autoscale_loop``. Spawn events fork/connect a
        replica process, then schedule activation at ``ready_at`` — a
        spawned replica turns routable only once BOTH the cold start
        has elapsed and its child finished the handshake. Tick errors
        are counted (``stats()['autoscale_errors']``) and tolerated up
        to ``AUTOSCALE_MAX_CONSEC`` consecutive failures."""
        cfg = self.autoscaler.cfg
        loop = asyncio.get_running_loop()
        consecutive = 0
        while True:
            await asyncio.sleep(cfg.interval)
            try:
                for ev in self.autoscaler.tick(self.clock.now()):
                    if ev.kind == "spawn":
                        try:
                            await self._connect_child(ev.rid)
                        except Exception:
                            # stillborn child: never routable — book the
                            # death so it can't warm (and bill) forever
                            self.coord.alive[ev.rid] = False
                            self.autoscaler.on_death(ev.rid,
                                                     self.clock.now())
                            raise
                        loop.call_later(
                            max(ev.ready_at - self.clock.now(), 0.0),
                            self._activate, ev.rid)
                    # decommission: tick already re-routed the queue and
                    # asked the child to drain via _on_decommission
                consecutive = 0
            except Exception:           # noqa: BLE001 — keep scaling alive
                traceback.print_exc()
                self._autoscale_errors += 1
                consecutive += 1
                if consecutive >= self.AUTOSCALE_MAX_CONSEC:
                    raise

    def _activate(self, rid: int) -> None:
        """Cold start paid: the spawned replica becomes routable (a
        replica that died mid-warm-up stays down)."""
        if self.coord.alive[rid]:
            self.autoscaler.activate(rid, self.clock.now())

    def _on_decommission(self, rid: int, moved) -> None:
        """Autoscaler ``migrate_fn``: payloads and futures live parent-
        side keyed by qid, so nothing migrates — the redistribute that
        preceded this call already re-serialized the orphans to the
        survivors through ``ReplicaProxy.admit``. What remains is the
        child's retirement: a ``drain`` frame (its in-flight batches
        finish; their completions arrive stale and are ignored), then a
        background reap."""
        ch = self._chans[rid]
        if ch.stream is not None:
            ch.outbox.put_nowait({"t": "drain", "timeout": 10.0})
            try:
                asyncio.get_running_loop().create_task(self._reap(ch))
            except RuntimeError:
                ch.stop()               # no loop: hard stop

    async def _reap(self, ch: _Channel) -> None:
        try:
            await asyncio.wait_for(ch.drained.wait(), timeout=15.0)
        except asyncio.TimeoutError:
            pass
        ch.stop()
        if ch.proc is not None:
            try:
                await asyncio.to_thread(ch.proc.wait, 5.0)
            except subprocess.TimeoutExpired:
                ch.proc.kill()

    # -- admission (coordinator-owned, frame-forwarded) -----------------

    async def submit(self, payload: Any, slo_s: float) -> asyncio.Future:
        now = self.clock.now()
        q = Query(deadline=now + slo_s, seq=0, arrival=now, qid=self._qid)
        self._qid += 1
        self.coord.queries.append(q)
        self.coord.observe(q)
        fut = asyncio.get_running_loop().create_future()
        if not self.coord.alive_replicas():
            q.dropped = True
            fut.set_result((None, 0.0))
            return fut
        self._futs[q.qid] = fut
        self._payloads[q.qid] = payload
        self._by_qid[q.qid] = q
        self._all_done.clear()
        rid = self.coord.select(q, now)
        self.proxies[rid].admit(q)
        return fut

    def _send_submit(self, rid: int, q: Query) -> None:
        """Proxy admission hook (sync — also called from the coordinator
        re-route path): enqueue a submit frame carrying the *remaining*
        SLO, so a re-routed query's deadline naturally shrinks."""
        slo = q.deadline - self.clock.now()
        self._chans[rid].outbox.put_nowait(
            {"t": "submit", "qid": q.qid, "slo": slo,
             "payload": to_jsonable(self._payloads.get(q.qid))})

    # -- frame plumbing -------------------------------------------------

    async def _send_loop(self, ch: _Channel) -> None:
        while True:
            frame = await ch.outbox.get()
            if frame is None:
                return
            try:
                await ch.stream.send(frame)
            except (ConnectionError, RuntimeError, OSError):
                self._on_death(ch.rid, "send failed")
                return

    async def _read_loop(self, ch: _Channel) -> None:
        reason = "eof"
        try:
            while True:
                frame = await ch.stream.recv()
                if frame is None:
                    break
                t = frame["t"]
                if t == "completion":
                    self._on_completion(ch.rid, frame)
                elif t == "stats":
                    self.proxies[ch.rid].refresh(
                        frame.get("counters", {}))
                    ch.stats_ready.set()
                elif t == "drained":
                    self.proxies[ch.rid].refresh(
                        frame.get("counters", {}))
                    ch.drained.set()
                # heartbeats need no handling: recv stamped last_rx
        except FrameError as e:
            ch.protocol_error = e
            reason = f"protocol error: {e}"
        except (ConnectionError, OSError) as e:
            reason = f"connection lost: {e}"
        finally:
            self._on_death(ch.rid, reason)

    async def _watchdog(self, ch: _Channel) -> None:
        """Dead-peer detection: a silent child (no frames, no
        heartbeats) is declared dead and its work re-routed."""
        dead_after = self.spec.heartbeat_s * DEAD_AFTER_BEATS
        while True:
            await asyncio.sleep(self.spec.heartbeat_s)
            if time.monotonic() - ch.stream.last_rx > dead_after:
                self._on_death(ch.rid, "heartbeat timeout")
                return

    # -- completion / death ---------------------------------------------

    def _on_completion(self, rid: int, frame: Dict[str, Any]) -> None:
        qid = frame.get("qid")
        q = self.proxies[rid].pending.pop(qid, None)
        if q is None:
            return      # re-routed away meanwhile: stale completion
        if frame.get("dropped"):
            q.dropped = True
            q.timed_out = bool(frame.get("timed_out"))
        else:
            # master finish stamped at receipt: end-to-end, IPC included
            q.finish = self.clock.now()
            q.served_acc = frame.get("acc")
        self._resolve(qid, (frame.get("pred"), frame.get("acc") or 0.0)
                      if not frame.get("dropped") else (None, 0.0))

    def _resolve(self, qid: int, result) -> None:
        self._payloads.pop(qid, None)
        self._by_qid.pop(qid, None)
        fut = self._futs.pop(qid, None)
        if fut is not None and not fut.done():
            fut.set_result(result)
        if not self._futs:
            self._all_done.set()

    def _on_death(self, rid: int, reason: str) -> None:
        """Funnel every death signal (kill, EOF, protocol error,
        heartbeat loss) into the coordinator's one surrender path:
        ``redistribute`` re-routes the orphans through placement, the
        proxies' ``admit`` re-serializes them to the survivors. With no
        survivor left the orphans drop — their futures still resolve.

        During shutdown (``drain`` in flight, ``_closing`` set) the
        redistribute is skipped: the "survivors" have already acked
        ``drained`` and exited their serve loops, so re-routed submit
        frames would vanish into dead sockets and sit unresolved until
        the drain timeout misclassified them as ``timed_out``. Shutdown
        orphans resolve immediately as dropped shutdown loss instead
        (``timed_out`` stays False: they were lost to a death, not to
        the drain deadline)."""
        ch = self._chans[rid]
        ch.stop()
        if not self.coord.alive[rid]:
            return
        proxy = self.proxies[rid]
        proxy.residency.clear()         # no workers left on a dead peer
        if self._closing:
            self.coord.alive[rid] = False
            for q in list(proxy.pending.values()):
                q.dropped = True
                self._resolve(q.qid, (None, 0.0))
            proxy.pending.clear()
            return
        snapshot = list(proxy.pending.values())
        self.coord.redistribute(rid, self.clock.now())
        for q in snapshot:
            if q.dropped:               # no survivors took it
                self._resolve(q.qid, (None, 0.0))
        if self.autoscaler is not None:
            # mirror the inproc _book_death: close the billing span and
            # forget a still-warming victim
            self.autoscaler.on_death(rid, self.clock.now())

    # -- fault injection -------------------------------------------------

    def kill_worker(self, rid: int, wid: int) -> None:
        """Mirror the inproc path: fault one remote worker; when the
        pool empties the replica is decommissioned (its process killed)
        and its queue re-routed."""
        self.proxies[rid].fault(wid)
        if self.coord.should_decommission(rid):
            self._on_death(rid, "last worker killed")
        elif self.coord.alive[rid]:
            self._chans[rid].outbox.put_nowait({"t": "kill", "wid": wid})

    def kill_replica(self, rid: int) -> None:
        """Hard replica death: SIGKILL the process (close the stream
        for adopted replicas — their pid belongs to another host), then
        drain-and-re-route immediately (the EOF path then finds it
        already dead and no-ops)."""
        ch = self._chans[rid]
        if ch.proc is not None:
            ch.proc.kill()
        elif ch.stream is not None:
            ch.stream.close()
        self._on_death(rid, "killed")

    # -- shutdown --------------------------------------------------------

    async def drain(self, timeout: float = 10.0) -> None:
        """Ask every live child to drain, wait (event-driven) for all
        outstanding futures, then reap. Queries still unresolved at the
        deadline resolve as dropped AND ``timed_out`` — the same
        shutdown-loss marking as the inproc ``Router.drain``."""
        self._closing = True
        if self._scale_task is not None:
            self._scale_task.cancel()
            self._scale_task = None
        deadline = time.monotonic() + timeout
        for ch in self._chans:
            if self.coord.alive[ch.rid] and ch.stream is not None:
                ch.outbox.put_nowait({"t": "drain", "timeout": timeout})
        try:
            await asyncio.wait_for(self._all_done.wait(),
                                   timeout=max(deadline - time.monotonic(),
                                               0.001))
            expired = False
        except asyncio.TimeoutError:
            expired = True
        for ch in self._chans:
            if self.coord.alive[ch.rid] and ch.stream is not None:
                try:
                    await asyncio.wait_for(
                        ch.drained.wait(),
                        timeout=max(deadline - time.monotonic(), 0.001))
                except asyncio.TimeoutError:
                    pass
        for qid in list(self._futs):
            q = self._by_qid.get(qid)
            if q is not None:
                q.dropped = True
                q.timed_out = expired
            self._resolve(qid, (None, 0.0))
        for proxy in self.proxies:
            proxy.pending.clear()
        for ch in self._chans:
            ch.stop()
            if ch.proc is not None:
                try:
                    ch.proc.wait(timeout=5.0)
                except subprocess.TimeoutExpired:
                    ch.proc.kill()
        for fut in self._conn_waiters:
            fut.cancel()
        self._conn_waiters.clear()
        for stream in self._pending_conns:
            stream.close()
        self._pending_conns.clear()
        if self._server is not None:
            self._server.close()
            self._server = None

    async def refresh_stats(self, timeout: float = 5.0) -> None:
        """Pull live counters from every alive child into the proxies,
        so the inherited ``stats()`` aggregates real child numbers."""
        waits = []
        for ch in self._chans:
            if self.coord.alive[ch.rid] and ch.stream is not None:
                ch.stats_ready.clear()
                ch.outbox.put_nowait({"t": "stats"})
                waits.append(ch.stats_ready.wait())
        if waits:
            await asyncio.wait([asyncio.ensure_future(w) for w in waits],
                               timeout=timeout)

    # -- surfaces that do not cross the boundary -------------------------

    def run_virtual(self, *a, **kw):
        raise NotImplementedError(
            "run_virtual is the inproc parity path; the proc transport "
            "is wall-clock only (its parity bar is tests/test_ipc.py)")
