"""Success metrics (paper §6.1): SLO attainment (R1) and mean serving
accuracy over SLO-satisfying queries (R2), plus end-to-end latency
percentiles and continuous-batching join counters."""
from __future__ import annotations

from typing import Dict, Iterable, List, Sequence, Tuple

import numpy as np

from repro.serving.queue import Query


def slo_attainment(queries: Sequence[Query]) -> float:
    """Fraction of queries completed within their deadline (drops and
    re-enqueue losses count as misses)."""
    if not queries:
        return 1.0
    ok = sum(1 for q in queries
             if q.finish is not None and q.finish <= q.deadline and not q.dropped)
    return ok / len(queries)


def mean_serving_accuracy(queries: Sequence[Query]) -> float:
    """Mean profiled accuracy over queries that satisfied their SLO."""
    accs = [q.served_acc for q in queries
            if q.finish is not None and q.finish <= q.deadline
            and not q.dropped and q.served_acc is not None]
    return float(np.mean(accs)) if accs else 0.0


def goodput(queries: Sequence[Query], duration: float) -> float:
    ok = sum(1 for q in queries
             if q.finish is not None and q.finish <= q.deadline and not q.dropped)
    return ok / max(duration, 1e-9)


def latency_percentiles(queries: Sequence[Query],
                        ps: Tuple[float, ...] = (50, 99)) -> List[float]:
    lats = [q.finish - q.arrival for q in queries
            if q.finish is not None and not q.dropped]
    if not lats:
        return [float("nan")] * len(ps)
    return [float(np.percentile(lats, p)) for p in ps]


def summarize(queries: Sequence[Query], n_joins: int = 0) -> Dict[str, float]:
    """One-stop serving report: SLO attainment, mean serving accuracy,
    p50/p99 end-to-end latency, and the continuous-batching join rate
    (fraction of queries admitted into an already-forming batch)."""
    p50, p99 = latency_percentiles(queries)
    resolved = sum(1 for q in queries if q.finish is not None or q.dropped)
    return {
        "slo_attainment": slo_attainment(queries),
        "mean_acc": mean_serving_accuracy(queries),
        "served": float(resolved),
        "p50_latency_s": p50,
        "p99_latency_s": p99,
        "join_rate": n_joins / len(queries) if len(queries) else 0.0,
    }
