"""Success metrics (paper §6.1): SLO attainment (R1) and mean serving
accuracy over SLO-satisfying queries (R2), plus end-to-end latency
percentiles, continuous-batching join counters, and cluster-level
per-replica / load-imbalance aggregation.

Every function is total: empty or all-dropped query sets yield
well-defined finite values (0.0 for latency percentiles and
imbalance), never NaN or a ZeroDivisionError."""
from __future__ import annotations

from typing import Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

from repro.serving.queue import Query


def slo_attainment(queries: Sequence[Query]) -> float:
    """Fraction of queries completed within their deadline (drops and
    re-enqueue losses count as misses)."""
    if not queries:
        return 1.0
    ok = sum(1 for q in queries
             if q.finish is not None and q.finish <= q.deadline and not q.dropped)
    return ok / len(queries)


def mean_serving_accuracy(queries: Sequence[Query]) -> float:
    """Mean profiled accuracy over queries that satisfied their SLO."""
    accs = [q.served_acc for q in queries
            if q.finish is not None and q.finish <= q.deadline
            and not q.dropped and q.served_acc is not None]
    return float(np.mean(accs)) if accs else 0.0


def goodput(queries: Sequence[Query], duration: float) -> float:
    ok = sum(1 for q in queries
             if q.finish is not None and q.finish <= q.deadline and not q.dropped)
    return ok / max(duration, 1e-9)


def latency_percentiles(queries: Sequence[Query],
                        ps: Tuple[float, ...] = (50, 99)) -> List[float]:
    lats = [q.finish - q.arrival for q in queries
            if q.finish is not None and not q.dropped]
    if not lats:
        return [0.0] * len(ps)                # total on empty/all-dropped
    return [float(np.percentile(lats, p)) for p in ps]


def summarize(queries: Sequence[Query], n_joins: int = 0,
              n_switches: int = 0, n_dispatches: int = 0,
              actuation_seconds: float = 0.0) -> Dict[str, float]:
    """One-stop serving report: SLO attainment, mean serving accuracy,
    p50/p99 end-to-end latency, the continuous-batching join rate
    (fraction of queries admitted into an already-forming batch), and
    the residency accounting — ``switch_rate`` (fraction of batch
    launches that actuated a different subnet than the worker's
    resident one) and total ``actuation_seconds`` paid on switches."""
    p50, p99 = latency_percentiles(queries)
    resolved = sum(1 for q in queries if q.finish is not None or q.dropped)
    return {
        "slo_attainment": slo_attainment(queries),
        "mean_acc": mean_serving_accuracy(queries),
        "served": float(resolved),
        "p50_latency_s": p50,
        "p99_latency_s": p99,
        "join_rate": n_joins / len(queries) if len(queries) else 0.0,
        "switch_rate": n_switches / n_dispatches if n_dispatches else 0.0,
        "actuation_seconds": float(actuation_seconds),
    }


# --------------------------------------------------------------------------
# Cluster aggregation (multi-replica serving plane)
# --------------------------------------------------------------------------


def per_replica_stats(queries: Sequence[Query],
                      replica_ids: Optional[Iterable[int]] = None
                      ) -> Dict[int, Dict[str, float]]:
    """``summarize`` per replica group (keyed by the replica that last
    admitted each query — re-routed queries count where they landed).
    ``replica_ids`` names every replica that existed (autoscaled runs:
    the span keys), so replicas that served nothing still report a
    well-defined all-zero row instead of silently vanishing."""
    by_rid: Dict[int, List[Query]] = {int(r): []
                                      for r in (replica_ids or ())}
    for q in queries:
        by_rid.setdefault(q.replica, []).append(q)
    return {rid: summarize(qs) for rid, qs in sorted(by_rid.items())}


def load_imbalance(queries: Sequence[Query], n_replicas: int = 0,
                   replica_spans: Optional[Dict[int, float]] = None) -> float:
    """Placement-quality metric: max/mean − 1 of per-replica serving
    load (0.0 = perfectly balanced).

    Static clusters compare raw per-replica query *counts*;
    ``n_replicas`` forces the denominator so full-run replicas that
    received nothing count. With ``replica_spans`` (rid -> active
    seconds, the autoscaled path) the comparison is per-replica query
    *rates* (queries per active second): a replica that existed for a
    tenth of the run is judged on its rate over that tenth, not
    punished as a 0-query phantom — and zero-lifetime replicas are
    excluded entirely. Degenerate cases are defined exactly: no
    queries -> 0.0, and a single (counted) replica -> 0.0, since a
    lone replica cannot be imbalanced against itself."""
    if not queries:
        return 0.0
    counts: Dict[int, int] = {}
    for q in queries:
        counts[q.replica] = counts.get(q.replica, 0) + 1
    if replica_spans is not None:
        rates = [counts.get(rid, 0) / span
                 for rid, span in replica_spans.items() if span > 1e-12]
        if len(rates) <= 1:
            return 0.0
        mean = sum(rates) / len(rates)
        return max(rates) / mean - 1.0 if mean > 0 else 0.0
    n = max(n_replicas, len(counts), 1)
    if n <= 1:
        return 0.0
    mean = len(queries) / n
    return max(counts.values()) / mean - 1.0 if mean > 0 else 0.0


def cluster_summarize(queries: Sequence[Query], n_replicas: int = 0,
                      n_joins: int = 0,
                      replica_spans: Optional[Dict[int, float]] = None,
                      n_switches: int = 0, n_dispatches: int = 0,
                      actuation_seconds: float = 0.0
                      ) -> Dict[str, float]:
    """Aggregate serving report plus the load-imbalance metric; the
    per-replica breakdown rides under the ``replicas`` key. With
    ``replica_spans`` (autoscaled runs) the report adds the provisioned
    ``replica_seconds`` and the goodput-per-replica-second efficiency
    figure (SLO-satisfying completions per unit of capacity-time).
    The switch counters aggregate every replica's residency tracker, so
    ``switch_rate`` is cluster-wide (switches per batch launch)."""
    out = summarize(queries, n_joins=n_joins, n_switches=n_switches,
                    n_dispatches=n_dispatches,
                    actuation_seconds=actuation_seconds)
    out["load_imbalance"] = load_imbalance(queries, n_replicas,
                                           replica_spans=replica_spans)
    out["replicas"] = per_replica_stats(
        queries, replica_ids=replica_spans.keys() if replica_spans else None)
    if replica_spans:
        total = sum(replica_spans.values())
        ok = sum(1 for q in queries
                 if q.finish is not None and q.finish <= q.deadline
                 and not q.dropped)
        out["replica_seconds"] = total
        out["goodput_per_replica_second"] = ok / total if total > 0 else 0.0
    return out
