"""Scheduling policies (paper §4, §A.3-A.5).

All policies are greedy-w.r.t.-time: invoked when a worker frees up,
they map (head-of-EDF slack, queue length) -> a control decision
(pareto-subnet, batch size). Sub-millisecond decision making comes from
the bucketed profile (SlackFit: O(1) bucket + O(1) lookup; MaxAcc /
MaxBatch: O(log B) + O(log S) binary searches).

Also here: the Zero-one ILP objective (Eq. 1) as a brute-force *offline
oracle* on small instances, used by tests/benchmarks to show SlackFit
approximates it (§4.2.1).
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import lru_cache
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.serving.profiler import LatencyProfile


@dataclass(frozen=True)
class Decision:
    pareto_idx: int
    batch_size: int
    # continuous batching: how long the dispatched batch may stay open
    # to in-flight joins (the residual slack after the chosen tuple's
    # latency — waiting longer would endanger the head deadline).
    join_window: float = 0.0


def _join_window(profile: LatencyProfile, pi: int, bi: int,
                 slack: float) -> float:
    return max(0.0, float(slack) - float(profile.lat[pi, bi]))


class Policy:
    """Pluggable policy API (paper §5: 'scheduler provides pluggable
    APIs for different policy implementations').

    ``residency`` is an optional read-only view of the candidate
    worker's subnet residency (serving/residency.py ``ResidencyView``:
    ``.resident`` + ``.switch_cost(pi)``). Residency-blind policies —
    every baseline here — ignore it, which keeps their schedules
    bit-identical to the pre-residency stack; residency-aware variants
    (``slackfit_sticky``) consult it to prefer the resident subnet."""

    name: str = "base"

    def choose(self, profile: LatencyProfile, slack: float,
               queue_len: int, residency=None) -> Optional[Decision]:
        raise NotImplementedError

    def reset(self) -> None:  # per-run state, if any
        pass

    def clone(self) -> "Policy":
        """Fresh instance with the same configuration. Each replica's
        engine owns its own policy object (engines reset and may mutate
        policy state), so a cluster clones the prototype per replica —
        shared mutable policy state must never couple replicas."""
        return type(self)()


class SlackFit(Policy):
    """Bucketed slack-fitting (paper §4.2): pick the latency bucket
    closest-below the head-of-queue slack; within it, the max-batch
    control tuple (over realizable batch sizes)."""

    name = "slackfit"

    def choose(self, profile, slack, queue_len, residency=None):
        pi, bi = profile.choose_slackfit(slack, queue_len)
        return Decision(pi, profile.batches[bi],
                        _join_window(profile, pi, bi, slack))


class MaxBatch(Policy):
    """§A.5: maximize batch first (on the smallest subnet), then pick
    the largest subnet that still fits the slack at that batch.
    O(log B) + O(log S) binary searches on the monotone profile."""

    name = "maxbatch"

    def choose(self, profile, slack, queue_len, residency=None):
        lat = profile.lat
        cap = profile.cap_batch_idx(queue_len)
        # largest realizable B such that the *fastest* subnet fits
        fastest = int(lat[:, 0].argmin())
        fit = np.where(lat[fastest, :cap + 1] <= slack)[0]
        bi = int(fit[-1]) if len(fit) else 0
        # then largest accuracy at that B
        order = np.argsort(profile.accs)
        pi = fastest
        for cand in order:
            if lat[cand, bi] <= slack:
                pi = int(cand)
        return Decision(pi, profile.batches[bi],
                        _join_window(profile, pi, bi, slack))


class MaxAcc(Policy):
    """§A.5: maximize accuracy first (at B=1), then batch."""

    name = "maxacc"

    def choose(self, profile, slack, queue_len, residency=None):
        lat = profile.lat
        cap = profile.cap_batch_idx(queue_len)
        order = np.argsort(profile.accs)
        pi = int(lat[:, 0].argmin())
        for cand in order:
            if lat[cand, 0] <= slack:
                pi = int(cand)
        fit = np.where(lat[pi, :cap + 1] <= slack)[0]
        bi = int(fit[-1]) if len(fit) else 0
        return Decision(pi, profile.batches[bi],
                        _join_window(profile, pi, bi, slack))


class ClipperFixed(Policy):
    """Clipper+/Clockwork/TF-serving baseline (§6.1): a single,
    user-selected accuracy point with adaptive (slack-fitted) batching."""

    def __init__(self, pareto_idx: int, label: Optional[str] = None):
        self.pareto_idx = pareto_idx
        self.name = label or f"clipper+({pareto_idx})"

    def clone(self) -> "ClipperFixed":
        return ClipperFixed(self.pareto_idx, self.name)

    def choose(self, profile, slack, queue_len, residency=None):
        cap = profile.cap_batch_idx(queue_len)
        lat = profile.lat[self.pareto_idx]
        fit = np.where(lat[:cap + 1] <= slack)[0]
        bi = int(fit[-1]) if len(fit) else 0
        return Decision(self.pareto_idx, profile.batches[bi],
                        _join_window(profile, self.pareto_idx, bi, slack))


class INFaaSMinCost(Policy):
    """INFaaS baseline without accuracy thresholds (§6.1): always the
    most cost-efficient = minimum-accuracy model (confirmed with the
    INFaaS authors in the paper), with adaptive batching."""

    name = "infaas"

    def choose(self, profile, slack, queue_len, residency=None):
        pi = int(np.argmin(profile.accs))
        cap = profile.cap_batch_idx(queue_len)
        lat = profile.lat[pi]
        fit = np.where(lat[:cap + 1] <= slack)[0]
        bi = int(fit[-1]) if len(fit) else 0
        return Decision(pi, profile.batches[bi],
                        _join_window(profile, pi, bi, slack))


class StickySlackFit(SlackFit):
    """Residency-aware SlackFit (actuation-stationary serving, the
    "subgraph stationary" direction of Behnam et al. 2023): keep the
    worker on its resident subnet when that subnet still meets the
    slack target at the chosen batch size, instead of actuating
    whichever tuple SlackFit's bucket landed on.

    Stickiness never sacrifices accuracy for free: the resident subnet
    is preferred only when it gives at least the accuracy SlackFit
    chose, OR when the chosen subnet plus its switch cost would miss
    the slack anyway (the weight-loading regime, where a switch costs
    a full page-in and stationarity is the difference between meeting
    and missing the deadline). With no residency view this IS SlackFit,
    bit for bit."""

    name = "slackfit_sticky"

    def choose(self, profile, slack, queue_len, residency=None):
        dec = super().choose(profile, slack, queue_len)
        if dec is None or residency is None:
            return dec
        res = residency.resident
        if res is None or res == dec.pareto_idx:
            return dec
        bi = int(np.searchsorted(profile.batches, dec.batch_size))
        if profile.lat[res, bi] > slack:
            return dec                   # resident can't meet the target
        chosen_with_switch = (float(profile.lat[dec.pareto_idx, bi])
                              + residency.switch_cost(dec.pareto_idx))
        if (profile.accs[res] >= profile.accs[dec.pareto_idx]
                or chosen_with_switch > slack):
            return Decision(res, dec.batch_size,
                            _join_window(profile, res, bi, slack))
        return dec


ALL_POLICIES = {
    "slackfit": SlackFit,
    "maxbatch": MaxBatch,
    "maxacc": MaxAcc,
    "infaas": INFaaSMinCost,
    "slackfit_sticky": StickySlackFit,
}


# --------------------------------------------------------------------------
# Offline oracle (Eq. 1 ZILP, brute-force on small instances)
# --------------------------------------------------------------------------


def oracle_schedule(arrivals: Sequence[float], deadlines: Sequence[float],
                    profile: LatencyProfile, n_workers: int = 1,
                    max_queries: int = 10) -> float:
    """Maximum achievable ILP objective  sum Acc(phi) * |B|  over all
    EDF-prefix batch schedules (exact for the single-worker case under
    the ILP's constraint 1e; used as an upper-bound oracle in tests).

    Queries are sorted by deadline; a batch is a prefix of the remaining
    set (optimal schedules for the per-batch-earliest-deadline
    constraint 1e never benefit from skipping a more urgent query into a
    later batch unless it is dropped, which prefix enumeration with
    drops covers).
    """
    n = len(arrivals)
    if n > max_queries:
        raise ValueError(f"oracle is brute-force; {n} > {max_queries}")
    order = np.argsort(deadlines)
    arr = tuple(float(arrivals[i]) for i in order)
    ddl = tuple(float(deadlines[i]) for i in order)
    lat = profile.lat
    accs = profile.accs
    batches = profile.batches

    @lru_cache(maxsize=None)
    def best(i: int, free_times: Tuple[float, ...]) -> float:
        if i >= n:
            return 0.0
        # option 1: drop query i
        res = best(i + 1, free_times)
        # option 2: serve batch = queries i .. i+b-1 on some worker/subnet
        for w in range(len(free_times)):
            for b in range(1, n - i + 1):
                start = max(free_times[w], max(arr[i:i + b]))
                d_batch = ddl[i]                      # earliest deadline (1e)
                for pi in range(lat.shape[0]):
                    # smallest profiled batch size >= b
                    bi = int(np.searchsorted(batches, b))
                    if bi >= len(batches):
                        continue
                    fin = start + lat[pi, bi]
                    if fin <= d_batch:
                        ft = list(free_times)
                        ft[w] = fin
                        val = accs[pi] * b + best(i + b, tuple(sorted(ft)))
                        res = max(res, val)
        return res

    return best(0, tuple([0.0] * n_workers))
