"""Supernet Profiler (paper §5): latency profiles l_phi(B) over the
Pareto subnets, and the bucketed control space SlackFit operates on.

Profiling is *apriori, off the critical path*. Two sources:
  * analytic — a roofline-style latency model parameterized by a
    HardwareProfile (used by the simulator; the RTX2080Ti profile is
    calibrated so the conv supernet reproduces the paper's Fig 5c
    2-8k QPS dynamic range and Fig 13a bucket structure);
  * measured — wall-clock profiling of the jitted step function on this
    host (used by the real asyncio runtime in serving/runtime.py).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.configs.base import ArchConfig
from repro.core.pareto import ParetoPoint, pareto_subnets, subnet_flops, subnet_weight_bytes

DEFAULT_BATCHES = (1, 2, 4, 8, 16, 32, 64)


@dataclass(frozen=True)
class HardwareProfile:
    name: str
    effective_flops: float      # sustained FLOP/s at B=1 in the model
    hbm_bw: float               # bytes/s, weight-streaming floor
    dispatch_overhead: float    # seconds per dispatched batch
    load_bw: float              # host->device bytes/s (model *loading*,
                                # incl. allocation/setup — paper Fig 1a)
    marginal_frac: float = 0.15 # marginal cost of one extra batch item
                                # relative to the single-item pass


# Calibrated so ofa_resnet reproduces the paper's measured structure:
# Fig 5c (8 workers sustain ~2000 qps on the largest subnet, ~8-9k on
# the smallest), Fig 13a P3 (small nets nearly batch-flat — memory/
# launch bound; large nets batch-linear — compute bound), and Fig 1a
# (loading a model takes longer than B=16 inference on it).
RTX2080TI = HardwareProfile("rtx2080ti", 0.433e12, 308e9, 0.001, 1.5e9,
                            marginal_frac=0.15)
# TPU v5e serving point (effective bf16 serving throughput).
TPU_V5E = HardwareProfile("tpu-v5e", 60e12, 819e9, 0.0005, 50e9,
                          marginal_frac=0.3)


def model_latency(hw: HardwareProfile, flops_per_item: float,
                  weight_bytes: float, batch: int) -> float:
    """Affine-in-batch latency with a weight-streaming floor:

        t(B) = c0 + max( weights/bw,  (f/X) * ((1-m) + m*B) )

    Monotone in batch (P1) and FLOPs (P2); the per-batch slope m*f/X
    grows with model FLOPs, reproducing the paper's P3 (small subnets
    are nearly batch-flat, large subnets batch-linear)."""
    m = hw.marginal_frac
    t_mem = weight_bytes / hw.hbm_bw
    t_comp = flops_per_item * ((1.0 - m) + m * batch) / hw.effective_flops
    return hw.dispatch_overhead + max(t_mem, t_comp)


def loading_latency(hw: HardwareProfile, weight_bytes: float) -> float:
    """Time to page a model's weights onto the device (what Clipper+/
    INFaaS-style switching pays; SubNetAct pays ~0)."""
    return weight_bytes / hw.load_bw


# SubNetAct actuation cost: a control-tuple swap (paper Fig 5b, < 1ms).
SUBNETACT_ACTUATION_S = 50e-6


@dataclass
class LatencyProfile:
    """The (B x phi_pareto) control space + SlackFit's latency buckets."""

    arch: str
    accs: np.ndarray                      # (P,) accuracy per pareto subnet
    batches: Tuple[int, ...]              # (NB,)
    lat: np.ndarray                       # (P, NB) seconds
    points: List[ParetoPoint] = field(default_factory=list)
    n_buckets: int = 32

    # filled by __post_init__
    bucket_edges: np.ndarray = field(init=False)
    bucket_best: List[Optional[Tuple[int, int]]] = field(init=False)
    bucket_members: List[List[Tuple[int, int]]] = field(init=False)

    def __post_init__(self):
        lo, hi = float(self.lat.min()), float(self.lat.max())
        # Log-spaced buckets (paper Fig 13b uses power-of-two latency
        # buckets): fine granularity where tuples cluster (low latency),
        # coarse where choices thin out (I3).
        self.bucket_edges = np.geomspace(lo, hi * 1.0001, self.n_buckets + 1)
        members: List[List[Tuple[int, int]]] = [[] for _ in range(self.n_buckets)]
        for pi in range(self.lat.shape[0]):
            for bi in range(self.lat.shape[1]):
                k = int(np.searchsorted(self.bucket_edges, self.lat[pi, bi],
                                        side="right") - 1)
                k = min(max(k, 0), self.n_buckets - 1)
                members[k].append((pi, bi))
        self.bucket_members = members
        # per-(bucket, batch-cap) best tuple: max batch size (the paper's
        # "opt for a high throughput choice"); ties -> max accuracy
        # (utility Acc*|B|, Lemma A.1).
        nb = len(self.batches)
        self.bucket_best = []
        for mem in members:
            row: List[Optional[Tuple[int, int]]] = []
            for cap in range(nb):
                feas = [t for t in mem if t[1] <= cap]
                row.append(max(feas, key=lambda t: (self.batches[t[1]],
                                                    self.accs[t[0]]))
                           if feas else None)
            self.bucket_best.append(row)

    # -- O(1)/O(log) queries used by the policies ----------------------
    def latency(self, pi: int, batch: int) -> float:
        """l_phi(B) for arbitrary B (interpolate between profiled points)."""
        b = np.asarray(self.batches)
        if batch <= b[0]:
            return float(self.lat[pi, 0])
        j = int(np.searchsorted(b, batch, side="left"))
        if j >= len(b):
            return float(self.lat[pi, -1] * batch / b[-1])
        if b[j] == batch:
            return float(self.lat[pi, j])
        w = (batch - b[j - 1]) / (b[j] - b[j - 1])
        return float(self.lat[pi, j - 1] * (1 - w) + self.lat[pi, j] * w)

    def bucket_of(self, slack: float) -> int:
        """Bucket with latency closest-to-and-below ``slack`` (O(1))."""
        k = int(np.searchsorted(self.bucket_edges, slack, side="right") - 1)
        return min(max(k, 0), self.n_buckets - 1)

    def cap_batch_idx(self, queue_len: Optional[int]) -> int:
        """Largest useful batch index: the smallest profiled batch that
        covers the current queue (a control choice cannot batch queries
        that do not exist)."""
        if queue_len is None:
            return len(self.batches) - 1
        j = int(np.searchsorted(self.batches, max(queue_len, 1)))
        return min(j, len(self.batches) - 1)

    def choose_slackfit(self, slack: float,
                        queue_len: Optional[int] = None) -> Tuple[int, int]:
        """(pareto_idx, batch_idx) per the paper §4.2: the bucket whose
        latency range is closest-to-and-below ``slack`` (every choice in
        it satisfies the head deadline), then the max-batch member over
        realizable batch sizes. If slack falls inside/below the lowest
        bucket, the head may miss regardless — still take the lowest
        bucket's max-batch choice, which drains the queue fastest so the
        successors (later deadlines) meet theirs.
        """
        cap = self.cap_batch_idx(queue_len)
        # largest k with upper edge <= slack (bucket "less than slack")
        k = int(np.searchsorted(self.bucket_edges[1:], slack, side="right") - 1)
        k = min(max(k, 0), self.n_buckets - 1)
        while k >= 0:
            best = self.bucket_best[k][cap]
            if best is not None:
                return best
            k -= 1
        # all buckets empty below cap (cannot happen: B=1 tuples exist)
        return int(self.lat[:, 0].argmin()), 0

    @property
    def n_pareto(self) -> int:
        return len(self.accs)


def build_profile(cfg: ArchConfig, hw: HardwareProfile = RTX2080TI,
                  batches: Sequence[int] = DEFAULT_BATCHES,
                  n_buckets: int = 32) -> LatencyProfile:
    """Analytic profile over Phi_pareto (the simulator's ground truth)."""
    points = pareto_subnets(cfg)
    accs = np.array([p.acc for p in points])
    lat = np.zeros((len(points), len(batches)))
    for i, p in enumerate(points):
        f = subnet_flops(cfg, p.sub)
        wb = subnet_weight_bytes(cfg, p.sub, resident=False)
        for j, b in enumerate(batches):
            lat[i, j] = model_latency(hw, f, wb, b)
    return LatencyProfile(arch=cfg.name, accs=accs, batches=tuple(batches),
                          lat=lat, points=points, n_buckets=n_buckets)


def measure_profile(step_fns: Sequence[Callable[[int], None]],
                    accs: Sequence[float],
                    batches: Sequence[int] = (1, 2, 4, 8),
                    warmup: int = 1, iters: int = 3,
                    n_buckets: int = 12, arch: str = "measured",
                    monotonize: bool = True) -> LatencyProfile:
    """Wall-clock profile: ``step_fns[i](batch)`` runs subnet i on this
    host. The supported measured path — ``launch/serve.py --profile
    measured`` feeds ``SubnetExecutor.profile_step_fns`` through here
    (warm the executor first so no sample times a compile) and serves
    from the result; the quickstart example does the same by hand.

    ``monotonize`` enforces the P1/P2 structure (cummax along batch and
    accuracy) — measurement jitter that inverts the profile would
    otherwise scramble SlackFit's bucket choices."""
    lat = np.zeros((len(step_fns), len(batches)))
    for i, fn in enumerate(step_fns):
        for j, b in enumerate(batches):
            for _ in range(warmup):
                fn(b)
            t0 = time.perf_counter()
            for _ in range(iters):
                fn(b)
            lat[i, j] = (time.perf_counter() - t0) / iters
    if monotonize:
        order = np.argsort(np.asarray(accs))
        lat[order] = np.maximum.accumulate(lat[order], axis=0)    # P2
        lat = np.maximum.accumulate(lat, axis=1)                  # P1
    return LatencyProfile(arch=arch, accs=np.asarray(accs, float),
                          batches=tuple(batches), lat=lat, n_buckets=n_buckets)
