"""Weight-only int8 for the decode path (beyond-paper §Perf lever A5).

Decode is weight-streaming-bound: every step reads all resident weights
once. Storing matmul weights as int8 with per-output-channel scales
halves the HBM bytes per step; dequantization happens after the
HBM->VMEM stream (on TPU the convert fuses into the consumer matmul),
so wire/HBM traffic is int8 while compute stays bf16.

SubNetAct composes cleanly: quantization is per-channel along the SAME
output axes WeightSlice slices, so every subnet of the quantized
supernet is exactly the quantized version of that subnet.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

# leaves worth quantizing: big matmul weights (>= min_elems, rank >= 2)
MIN_ELEMS = 1 << 16


def _is_weight(leaf) -> bool:
    return (hasattr(leaf, "ndim") and leaf.ndim >= 2
            and leaf.size >= MIN_ELEMS
            and leaf.dtype in (jnp.bfloat16, jnp.float32, jnp.dtype("bfloat16"),
                               jnp.dtype("float32")))


def quantize_tree(params: Any) -> Tuple[Any, Any]:
    """-> (q_tree, scale_tree). Non-weight leaves pass through in q_tree
    with a None scale."""
    def q(leaf):
        if not _is_weight(leaf):
            return leaf, None
        f = leaf.astype(jnp.float32)
        amax = jnp.max(jnp.abs(f), axis=tuple(range(leaf.ndim - 1)),
                       keepdims=True)                     # per out-channel
        scale = jnp.maximum(amax / 127.0, 1e-12)
        qv = jnp.clip(jnp.round(f / scale), -127, 127).astype(jnp.int8)
        return qv, scale.astype(jnp.float32)

    flat, tdef = jax.tree_util.tree_flatten(params)
    pairs = [q(l) for l in flat]
    return (tdef.unflatten([p[0] for p in pairs]),
            tdef.unflatten([p[1] if p[1] is not None else jnp.zeros(())
                            for p in pairs]))


def dequantize_tree(q_tree: Any, scale_tree: Any, dtype=jnp.bfloat16) -> Any:
    def dq(qv, scale):
        if qv.dtype != jnp.int8:
            return qv
        return (qv.astype(jnp.float32) * scale).astype(dtype)

    return jax.tree.map(dq, q_tree, scale_tree)


def quantized_bytes(q_tree: Any) -> int:
    return sum(l.size * l.dtype.itemsize for l in jax.tree.leaves(q_tree))


def quantize_specs(param_specs: Any) -> Tuple[Any, Any]:
    """ShapeDtypeStruct version for the dry-run (no allocation)."""
    def q(leaf):
        if not _is_weight(leaf):
            return leaf, jax.ShapeDtypeStruct((), jnp.float32)
        scale_shape = tuple(1 for _ in leaf.shape[:-1]) + (leaf.shape[-1],)
        return (jax.ShapeDtypeStruct(leaf.shape, jnp.int8),
                jax.ShapeDtypeStruct(scale_shape, jnp.float32))

    flat, tdef = jax.tree_util.tree_flatten(param_specs)
    pairs = [q(l) for l in flat]
    return (tdef.unflatten([p[0] for p in pairs]),
            tdef.unflatten([p[1] for p in pairs]))
