"""Queries and the router's global earliest-deadline-first queue
(paper §5: "queries ... are enqueued to a global EDF queue")."""
from __future__ import annotations

import heapq
from dataclasses import dataclass, field
from typing import List, Optional


@dataclass(order=True)
class Query:
    deadline: float
    seq: int = field(compare=True)          # FIFO tie-break
    arrival: float = field(compare=False, default=0.0)
    qid: int = field(compare=False, default=0)
    # replica group that (last) admitted the query; stamped by the
    # engine so completion records carry serving placement
    replica: int = field(compare=False, default=0)
    # True once a queue has assigned ``seq``: a re-pushed query (fault
    # re-enqueue, replica-death re-route) keeps its first-assigned seq
    # so it never loses its FIFO tie-break position to later arrivals
    seq_assigned: bool = field(compare=False, default=False)
    # filled at completion
    finish: Optional[float] = field(compare=False, default=None)
    served_acc: Optional[float] = field(compare=False, default=None)
    dropped: bool = field(compare=False, default=False)
    # dropped because the router drained (shutdown timeout) with the
    # query still unresolved — distinct from the policy's infeasible
    # drops, so operators can tell overload from shutdown loss
    timed_out: bool = field(compare=False, default=False)


class EDFQueue:
    """Earliest-deadline-first priority queue with O(log n) push/pop and
    O(1) head-slack lookup (§A.3: "sub-ms O(1) EDF queue lookup")."""

    def __init__(self):
        self._heap: List[Query] = []
        self._next_seq = 0

    def push(self, q: Query) -> None:
        if not q.seq_assigned:
            q.seq = self._next_seq
            q.seq_assigned = True
            self._next_seq += 1
        else:
            # re-push: keep the first-assigned seq so a fault-re-enqueued
            # or drain-re-routed query retains its FIFO position at an
            # equal deadline; advance this queue's counter past it so
            # genuinely-later arrivals still sort behind it
            self._next_seq = max(self._next_seq, q.seq + 1)
        heapq.heappush(self._heap, q)

    def pop(self) -> Query:
        return heapq.heappop(self._heap)

    def peek(self) -> Optional[Query]:
        return self._heap[0] if self._heap else None

    def head_slack(self, now: float) -> Optional[float]:
        """Remaining slack of the most urgent query (SlackFit's signal)."""
        return self._heap[0].deadline - now if self._heap else None

    def pop_batch(self, n: int) -> List[Query]:
        """Dequeue the n most urgent queries (clamped to queue length;
        n <= 0 dequeues nothing)."""
        return [heapq.heappop(self._heap)
                for _ in range(min(max(n, 0), len(self._heap)))]

    def drain(self) -> List[Query]:
        """Dequeue everything, most urgent first (router shutdown)."""
        return self.pop_batch(len(self._heap))

    def count_more_urgent(self, deadline: float) -> int:
        """Queries that would be served before a hypothetical arrival
        with ``deadline`` (EDF order). O(n) heap scan — placement
        introspection only, never on the per-query scheduling path."""
        return sum(1 for q in self._heap if q.deadline <= deadline)

    def drop_expired(self, now: float, min_service: float) -> List[Query]:
        """Drop queries that cannot possibly meet their deadline even at
        the fastest control choice (the paper's infeasible-query drop)."""
        dropped = []
        while self._heap and self._heap[0].deadline - now < min_service:
            q = heapq.heappop(self._heap)
            q.dropped = True
            dropped.append(q)
        return dropped

    def __len__(self) -> int:
        return len(self._heap)

    def __bool__(self) -> bool:
        return bool(self._heap)
