"""Replica worker-process entrypoint for the proc transport
(serving/ipc.py).

Two front doors, one serve loop:

* ``python -m repro.serving.replica_proc --fd N`` — local child over an
  inherited socketpair (trusted fd, no handshake);
* ``python -m repro.serving.replica_proc --connect HOST:PORT
  [--token T]`` — dial a coordinator's TCP listener from ANY host,
  answer its HMAC challenge (token from ``--token`` or the
  ``REPRO_IPC_TOKEN`` env var), and serve once admitted. A ``reject``
  frame (bad token, version mismatch) exits with a diagnostic.

Either way the first serving frame (``config``) carries a
``ReplicaSpec``, from which the child builds one full ``Router`` — its
own ``SchedulingEngine``, policy (rebuilt by registry name), worker
pool, and wall clock — then answers ``submit`` frames with
``completion`` frames as futures resolve, heartbeating in between.

Execution: ``spec.execute == "echo"`` serves echo workers with an
optional CPU spin (the scale-out benchmark's stand-in);
``spec.execute == "real"`` builds a ``SubnetExecutor`` in-child from
``get_config(spec.arch).reduced()`` (serving/executor.py), so
completion frames carry real subnet logits and the engine's batch
latencies are real forward passes.

Device pinning: the parent spawns this process with
``XLA_FLAGS=--xla_force_host_platform_device_count=N`` already in the
env (``compat.host_devices_env`` — the HomebrewNLP-Jax/olmax idiom), so
when the spec asks for fake devices (or real execution) the child's
*first* jax import sees the flag and CPU CI gets an N-device host
without TPUs. Nothing in this module (or the serving stack it imports)
touches jax otherwise — the import happens here, after the flag is set,
or not at all.

Scheduling stays engine-owned: the child's router drops infeasible
queries, forms batches, and re-enqueues on worker faults exactly as
inproc; the parent only learns outcomes through completion frames.
"""
from __future__ import annotations

import argparse
import asyncio
import os
import socket
import time
from typing import Any, List, Optional

from repro.serving.ipc import (PROTOCOL_VERSION, TOKEN_ENV, FrameStream,
                               MalformedFrame, ReplicaSpec, auth_mac,
                               heartbeat_loop, engine_cfg_from_wire,
                               profile_from_wire, to_jsonable, KILL_ALL)
from repro.serving.policies import ALL_POLICIES
from repro.serving.queue import Query
from repro.serving.runtime import Router, WorkerHandle


def make_worker_run(work_ms: float):
    """Echo worker with an optional busy-spin: ``work_ms`` of real CPU
    per batch stands in for model execution, so the scale-out benchmark
    measures genuine multi-core parallelism (an inproc cluster's worker
    threads serialize this spin on the GIL; processes don't)."""

    def run(pareto_idx: int, payloads: List[Any]) -> List[Any]:
        if work_ms > 0:
            t_end = time.perf_counter() + work_ms / 1e3
            while time.perf_counter() < t_end:
                pass
        return list(payloads)

    return run


def make_real_workers(spec: ReplicaSpec) -> List[WorkerHandle]:
    """``execute="real"``: build the in-child ``SubnetExecutor`` from
    the wire spec — the arch's REDUCED config, AOT-warmed on the
    (1,2,4,8) x seq_len bucket lattice — and wrap its subnets as the
    worker pool. The coordinator's wire profile must schedule the same
    Pareto set the executor serves, or accuracies/subnet indices would
    silently disagree across the boundary."""
    from repro.serving.executor import build_serving_executor
    ex = build_serving_executor(spec.arch, seq_len=spec.seq_len,
                                seed=spec.seed)
    profile = profile_from_wire(spec.profile)
    if ex.n_subnets != profile.lat.shape[0]:
        raise ValueError(
            f"executor serves {ex.n_subnets} pareto subnets but the wire "
            f"profile schedules {profile.lat.shape[0]}: build the "
            f"coordinator's profile from the SAME reduced config "
            f"(get_config({spec.arch!r}).reduced())")
    return ex.make_workers(spec.n_workers)


def build_router(spec: ReplicaSpec, rid: int) -> Router:
    profile = profile_from_wire(spec.profile)
    policy = ALL_POLICIES[spec.policy]()
    if spec.execute == "real":
        workers = make_real_workers(spec)
    else:
        workers = [WorkerHandle(wid=i, run=make_worker_run(spec.work_ms))
                   for i in range(spec.n_workers)]
    return Router(profile, policy,
                  workers, engine_cfg=engine_cfg_from_wire(spec.engine_cfg),
                  replica_id=rid)


def _counters(router: Router, hb_errors: Optional[dict] = None) -> dict:
    eng = router.engine
    return {
        "n_joins": int(eng.n_joins),
        "n_switches": int(eng.residency.n_switches),
        "n_launches": int(eng.residency.n_launches),
        "actuation_seconds": float(eng.residency.actuation_seconds),
        "heartbeat_send_errors": int(
            (hb_errors or {}).get("heartbeat_send_errors", 0)),
        "stats": to_jsonable(router.stats()),
    }


async def serve(stream: FrameStream,
                cfg_frame: Optional[dict] = None) -> None:
    """The serve loop, transport-agnostic: ``cfg_frame`` is the already-
    received config when the TCP handshake consumed the stream head."""
    cfg = cfg_frame if cfg_frame is not None else await stream.recv()
    if cfg is None or cfg.get("t") != "config":
        raise MalformedFrame(f"expected a config frame, got {cfg!r}")
    spec = ReplicaSpec.from_wire(cfg["spec"])
    rid = int(cfg.get("rid", 0))

    devices: Optional[int] = None
    if spec.host_devices:
        # first jax import in this process: XLA_FLAGS (set by the
        # parent's env) takes effect here and nowhere earlier
        import jax
        devices = len(jax.devices())

    router = build_router(spec, rid)
    await router.start()
    await stream.send({"t": "hello", "rid": rid, "pid": os.getpid(),
                       "n_workers": spec.n_workers, "devices": devices,
                       "execute": spec.execute})

    hb_errors: dict = {}
    hb = asyncio.create_task(
        heartbeat_loop(stream, spec.heartbeat_s, errors=hb_errors))
    inflight: set = set()

    async def run_one(frame: dict) -> None:
        now = router.clock.now()
        q = Query(deadline=now + float(frame["slo"]), seq=0, arrival=now,
                  qid=int(frame["qid"]))
        fut = await router.submit_query(q, frame.get("payload"))
        pred, acc = await fut
        await stream.send({
            "t": "completion", "qid": q.qid,
            "dropped": bool(q.dropped), "timed_out": bool(q.timed_out),
            "acc": None if q.dropped else float(acc),
            "latency": (q.finish - q.arrival
                        if q.finish is not None else None),
            "pred": to_jsonable(pred)})

    try:
        while True:
            frame = await stream.recv()
            if frame is None:
                break                   # parent gone: exit quietly
            t = frame["t"]
            if t == "submit":
                task = asyncio.create_task(run_one(frame))
                inflight.add(task)
                task.add_done_callback(inflight.discard)
            elif t == "kill":
                wid = int(frame.get("wid", KILL_ALL))
                wids = ([w.wid for w in router.workers]
                        if wid == KILL_ALL else [wid])
                for w in wids:
                    router.kill_worker(w)
            elif t == "stats":
                await stream.send({"t": "stats",
                                   "counters": _counters(router,
                                                         hb_errors)})
            elif t == "drain":
                await router.drain(float(frame.get("timeout", 10.0)))
                # flush every pending completion before acking the drain
                if inflight:
                    await asyncio.gather(*list(inflight),
                                         return_exceptions=True)
                await stream.send({"t": "drained",
                                   "counters": _counters(router,
                                                         hb_errors)})
                break
            # unknown kinds are ignored: additive protocol evolution
    finally:
        hb.cancel()
        stream.close()


async def serve_fd(fd: int) -> None:
    sock = socket.socket(fileno=fd)
    reader, writer = await asyncio.open_connection(sock=sock)
    await serve(FrameStream(reader, writer))


async def serve_tcp(host: str, port: int, token: str) -> None:
    """Dial the coordinator's listener and run its handshake: recv
    ``challenge`` (nonce + protocol version), answer ``auth`` with
    ``HMAC(token, nonce:version)``, then the next frame is either a
    ``reject`` (exit with its reason) or the ``config`` that starts the
    serve loop."""
    reader, writer = await asyncio.open_connection(host, port)
    stream = FrameStream(reader, writer)
    challenge = await stream.recv()
    if challenge is None or challenge.get("t") != "challenge":
        raise MalformedFrame(
            f"expected a challenge frame, got {challenge!r}")
    version = challenge.get("version")
    if version != PROTOCOL_VERSION:
        stream.close()
        raise SystemExit(
            f"protocol version mismatch: coordinator speaks {version!r}, "
            f"this child speaks {PROTOCOL_VERSION}")
    await stream.send({"t": "auth", "version": PROTOCOL_VERSION,
                       "mac": auth_mac(token, challenge.get("nonce") or "")})
    first = await stream.recv()
    if first is None or first.get("t") == "reject":
        stream.close()
        reason = (first or {}).get("reason", "connection closed")
        raise SystemExit(f"coordinator rejected the handshake: {reason}")
    await serve(stream, cfg_frame=first)


def main(argv: Optional[List[str]] = None) -> None:
    p = argparse.ArgumentParser(
        description="serve one replica group for a proc-transport "
                    "coordinator (local --fd or remote --connect)")
    p.add_argument("--fd", type=int, default=None,
                   help="inherited socketpair fd connected to the "
                        "coordinator process (local spawn)")
    p.add_argument("--connect", default=None, metavar="HOST:PORT",
                   help="dial the coordinator's TCP listener instead of "
                        "inheriting a socket (remote replica)")
    p.add_argument("--token", default=None,
                   help="shared HMAC token for the --connect handshake "
                        f"(default: ${TOKEN_ENV})")
    args = p.parse_args(argv)
    if (args.fd is None) == (args.connect is None):
        p.error("exactly one of --fd (inherited socketpair) or "
                "--connect HOST:PORT (TCP) is required")
    if args.fd is not None:
        asyncio.run(serve_fd(args.fd))
        return
    host, _, port = args.connect.rpartition(":")
    if not host or not port.isdigit():
        p.error(f"--connect wants HOST:PORT, got {args.connect!r}")
    token = (args.token if args.token is not None
             else os.environ.get(TOKEN_ENV, ""))
    asyncio.run(serve_tcp(host, int(port), token))


if __name__ == "__main__":
    main()
