"""Replica worker-process entrypoint for the proc transport
(serving/ipc.py).

``python -m repro.serving.replica_proc --fd N`` serves one replica
group over the inherited socket: the first frame (``config``) carries a
``ReplicaSpec``, from which the child builds one full ``Router`` — its
own ``SchedulingEngine``, policy (rebuilt by registry name), worker
pool, and wall clock — then answers ``submit`` frames with
``completion`` frames as futures resolve, heartbeating in between.

Device pinning: the parent spawns this process with
``XLA_FLAGS=--xla_force_host_platform_device_count=N`` already in the
env (``compat.host_devices_env`` — the HomebrewNLP-Jax/olmax idiom), so
when the spec asks for fake devices the child's *first* jax import sees
the flag and CPU CI gets an N-device host without TPUs. Nothing in this
module (or the serving stack it imports) touches jax otherwise — the
import happens here, after the flag is set, or not at all.

Scheduling stays engine-owned: the child's router drops infeasible
queries, forms batches, and re-enqueues on worker faults exactly as
inproc; the parent only learns outcomes through completion frames.
"""
from __future__ import annotations

import argparse
import asyncio
import os
import socket
import time
from typing import Any, List, Optional

from repro.serving.ipc import (FrameStream, MalformedFrame, ReplicaSpec,
                               heartbeat_loop, engine_cfg_from_wire,
                               profile_from_wire, to_jsonable, KILL_ALL)
from repro.serving.policies import ALL_POLICIES
from repro.serving.queue import Query
from repro.serving.runtime import Router, WorkerHandle


def make_worker_run(work_ms: float):
    """Echo worker with an optional busy-spin: ``work_ms`` of real CPU
    per batch stands in for model execution, so the scale-out benchmark
    measures genuine multi-core parallelism (an inproc cluster's worker
    threads serialize this spin on the GIL; processes don't)."""

    def run(pareto_idx: int, payloads: List[Any]) -> List[Any]:
        if work_ms > 0:
            t_end = time.perf_counter() + work_ms / 1e3
            while time.perf_counter() < t_end:
                pass
        return list(payloads)

    return run


def build_router(spec: ReplicaSpec, rid: int) -> Router:
    profile = profile_from_wire(spec.profile)
    policy = ALL_POLICIES[spec.policy]()
    workers = [WorkerHandle(wid=i, run=make_worker_run(spec.work_ms))
               for i in range(spec.n_workers)]
    return Router(profile, policy,
                  workers, engine_cfg=engine_cfg_from_wire(spec.engine_cfg),
                  replica_id=rid)


def _counters(router: Router) -> dict:
    eng = router.engine
    return {
        "n_joins": int(eng.n_joins),
        "n_switches": int(eng.residency.n_switches),
        "n_launches": int(eng.residency.n_launches),
        "actuation_seconds": float(eng.residency.actuation_seconds),
        "stats": to_jsonable(router.stats()),
    }


async def serve(sock: socket.socket) -> None:
    reader, writer = await asyncio.open_connection(sock=sock)
    stream = FrameStream(reader, writer)
    cfg = await stream.recv()
    if cfg is None or cfg.get("t") != "config":
        raise MalformedFrame(f"expected a config frame, got {cfg!r}")
    spec = ReplicaSpec.from_wire(cfg["spec"])
    rid = int(cfg.get("rid", 0))

    devices: Optional[int] = None
    if spec.host_devices:
        # first jax import in this process: XLA_FLAGS (set by the
        # parent's env) takes effect here and nowhere earlier
        import jax
        devices = len(jax.devices())

    router = build_router(spec, rid)
    await router.start()
    await stream.send({"t": "hello", "rid": rid, "pid": os.getpid(),
                       "n_workers": spec.n_workers, "devices": devices})

    hb = asyncio.create_task(heartbeat_loop(stream, spec.heartbeat_s))
    inflight: set = set()

    async def run_one(frame: dict) -> None:
        now = router.clock.now()
        q = Query(deadline=now + float(frame["slo"]), seq=0, arrival=now,
                  qid=int(frame["qid"]))
        fut = await router.submit_query(q, frame.get("payload"))
        pred, acc = await fut
        await stream.send({
            "t": "completion", "qid": q.qid,
            "dropped": bool(q.dropped), "timed_out": bool(q.timed_out),
            "acc": None if q.dropped else float(acc),
            "latency": (q.finish - q.arrival
                        if q.finish is not None else None),
            "pred": to_jsonable(pred)})

    try:
        while True:
            frame = await stream.recv()
            if frame is None:
                break                   # parent gone: exit quietly
            t = frame["t"]
            if t == "submit":
                task = asyncio.create_task(run_one(frame))
                inflight.add(task)
                task.add_done_callback(inflight.discard)
            elif t == "kill":
                wid = int(frame.get("wid", KILL_ALL))
                wids = ([w.wid for w in router.workers]
                        if wid == KILL_ALL else [wid])
                for w in wids:
                    router.kill_worker(w)
            elif t == "stats":
                await stream.send({"t": "stats",
                                   "counters": _counters(router)})
            elif t == "drain":
                await router.drain(float(frame.get("timeout", 10.0)))
                # flush every pending completion before acking the drain
                if inflight:
                    await asyncio.gather(*list(inflight),
                                         return_exceptions=True)
                await stream.send({"t": "drained",
                                   "counters": _counters(router)})
                break
            # unknown kinds are ignored: additive protocol evolution
    finally:
        hb.cancel()
        stream.close()


def main(argv: Optional[List[str]] = None) -> None:
    p = argparse.ArgumentParser(
        description="serve one replica group over an inherited socket")
    p.add_argument("--fd", type=int, required=True,
                   help="inherited socketpair fd connected to the "
                        "coordinator process")
    args = p.parse_args(argv)
    sock = socket.socket(fileno=args.fd)
    asyncio.run(serve(sock))


if __name__ == "__main__":
    main()
