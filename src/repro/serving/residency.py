"""Subnet residency and actuation-cost estimation — the single owner
of "which subnet is resident on which worker" (ROADMAP
"actuation-stationary serving").

SubNetAct's core asset (paper §5, Fig 5b) is that switching subnets on
a weight-shared supernet is a *control-tuple swap* (~50 µs), not a
model load; Clipper+/INFaaS-style serving pays a full weight page-in
per switch instead (Fig 1a). Both regimes are one cost model here:

  * ``ActuationModel`` — what a switch costs: the control-swap delay,
    plus (in the ``load_on_switch`` weight-loading regime) paging the
    target subnet's weights over the host->device link. Also prices a
    replica **cold start** as a full supernet weight-load, so the
    autoscaler's spawn actuation and the engine's per-batch actuation
    share one physical model.
  * ``ResidencyTracker`` — per-worker resident subnet, updated only at
    batch launch (``actuate``) and worker death (``forget``), with
    switch/actuation accounting (``n_switches``, ``actuation_seconds``)
    feeding the ``switch_rate`` metric.
  * ``ResidencyView`` — the read-only, per-worker slice handed to
    scheduling policies so residency-aware variants (e.g.
    ``slackfit_sticky``) can prefer the resident subnet when it meets
    the slack target.

Layering rule (the PR 2/3 pattern, extended): residency state lives in
this module only. The engine owns one tracker per worker pool and is
the only writer; placement policies (``actuation_aware`` in
serving/cluster.py), scheduling policies, the autoscaler, and metrics
all *read* it through the engine's introspection surface. The
"subgraph stationary" direction of Behnam et al. 2023 and
CascadeServe's switch-cost-aware routing (PAPERS.md) both reduce to
keeping this state accurate and consulting it before actuating.

Replay guarantee: with residency-blind configuration (the default
policies and placements) the tracker reproduces the engine's
pre-refactor inlined actuation math bit-for-bit — ``penalized`` adds
the control-swap delay and the weight-load cost in the exact historical
operation order (guarded by tests/test_residency.py).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterable, List, Optional

from repro.serving.profiler import (RTX2080TI, SUBNETACT_ACTUATION_S,
                                    HardwareProfile, LatencyProfile,
                                    loading_latency)

# weight footprint assumed for profiles without Pareto points (measured
# profiles from profiler.measure_profile) — the engine's historical
# fallback, kept verbatim for bit-stable replay
DEFAULT_WEIGHT_BYTES = 100e6


@dataclass(frozen=True)
class ActuationModel:
    """What actuating a subnet costs, in both serving regimes.

    ``switch_cost`` prices moving a worker from its ``resident`` subnet
    to ``target``: zero when already resident, else the control-swap
    ``actuation_delay`` (SubNetAct), plus a full weight page-in of the
    target when ``load_on_switch`` models a non-weight-shared stack.
    ``cold_start`` prices bringing up a whole replica: loading the
    heaviest subnet's weights (the supernet superset) over the same
    host->device link — the autoscaler consumes this when
    ``AutoscaleConfig.cold_start`` is None."""

    actuation_delay: float = SUBNETACT_ACTUATION_S
    load_on_switch: bool = False
    hw: HardwareProfile = RTX2080TI

    def weight_bytes(self, profile: LatencyProfile, pi: int) -> float:
        return (profile.points[pi].weight_mb * 2**20
                if profile.points else DEFAULT_WEIGHT_BYTES)

    def load_cost(self, profile: LatencyProfile, pi: int) -> float:
        """Full weight page-in of subnet ``pi`` (what a model *switch*
        pays without weight sharing — paper Fig 1a)."""
        return loading_latency(self.hw, self.weight_bytes(profile, pi))

    def switch_cost(self, profile: LatencyProfile, resident: Optional[int],
                    target: int) -> float:
        if resident == target:
            return 0.0
        cost = self.actuation_delay
        if self.load_on_switch:
            cost += self.load_cost(profile, target)
        return cost

    def penalized(self, latency: float, profile: LatencyProfile,
                  resident: Optional[int], target: int) -> float:
        """Service ``latency`` plus the actuation penalty, accumulated
        in the engine's exact historical operation order (sequential
        ``+=``) so residency-blind schedules replay bit-for-bit."""
        if resident != target:
            latency += self.actuation_delay
            if self.load_on_switch:
                latency += self.load_cost(profile, target)
        return latency

    def cold_start(self, profile: LatencyProfile) -> float:
        """Replica spawn -> routable: a full weight-load of the
        heaviest subnet (the supernet's resident superset)."""
        wb = max((p.weight_mb * 2**20 for p in profile.points),
                 default=DEFAULT_WEIGHT_BYTES)
        return loading_latency(self.hw, wb)


class ResidencyView:
    """Read-only residency slice for ONE worker, handed to scheduling
    policies: the resident subnet and the projected cost of actuating
    any other. Policies must never mutate residency — they consume this
    view, the engine's ``launch`` commits the actual actuation."""

    __slots__ = ("_tracker", "wid")

    def __init__(self, tracker: "ResidencyTracker", wid: int):
        self._tracker = tracker
        self.wid = wid

    @property
    def resident(self) -> Optional[int]:
        return self._tracker.resident(self.wid)

    def switch_cost(self, pi: int) -> float:
        return self._tracker.switch_cost(self.wid, pi)


class ResidencyTracker:
    """Per-worker resident subnet for one worker pool (one engine).

    The engine is the single writer: ``actuate`` on batch launch,
    ``forget`` on worker death, ``register`` when a pool is built.
    Everything else — policies, placement, the autoscaler, metrics —
    reads. ``None`` means the worker has never actuated (a fresh pool),
    so its first dispatch always pays a switch, matching the engine's
    historical accounting."""

    def __init__(self, profile: LatencyProfile,
                 model: Optional[ActuationModel] = None,
                 worker_ids: Iterable[int] = ()):
        self.profile = profile
        self.model = model if model is not None else ActuationModel()
        self._resident: Dict[int, Optional[int]] = {
            int(w): None for w in worker_ids}
        self.n_switches = 0             # launches that changed subnet
        self.n_launches = 0             # all launches
        self.actuation_seconds = 0.0    # total switch cost paid

    # -- pool membership (engine-owned) ---------------------------------

    def register(self, wid: int) -> None:
        self._resident.setdefault(int(wid), None)

    def forget(self, wid: int) -> None:
        """Worker died: its residency is gone with it."""
        self._resident.pop(wid, None)

    def workers(self) -> List[int]:
        return list(self._resident)

    def __len__(self) -> int:
        return len(self._resident)

    def __contains__(self, wid: int) -> bool:
        return wid in self._resident

    # -- residency reads -------------------------------------------------

    def resident(self, wid: int) -> Optional[int]:
        return self._resident.get(wid)

    def residency(self) -> Dict[int, Optional[int]]:
        """Copy of the full worker -> resident-subnet map (placement
        and cluster introspection; mutating the copy changes nothing)."""
        return dict(self._resident)

    def resident_count(self, pi: int) -> int:
        """Workers currently resident on subnet ``pi``."""
        return sum(1 for r in self._resident.values() if r == pi)

    def view(self, wid: int) -> ResidencyView:
        return ResidencyView(self, wid)

    # -- cost projection --------------------------------------------------

    def switch_cost(self, wid: int, pi: int) -> float:
        """Projected cost of serving subnet ``pi`` on worker ``wid``
        (0.0 when already resident)."""
        return self.model.switch_cost(self.profile,
                                      self._resident.get(wid), pi)

    def min_switch_cost(self, pi: int) -> float:
        """Cheapest way this pool could serve subnet ``pi``: zero if
        any worker is already resident on it. An empty (dead) pool
        prices as a cold never-actuated worker — placement never offers
        dead replicas, so this is a defensive bound, not a route."""
        if not self._resident:
            return self.model.switch_cost(self.profile, None, pi)
        return min(self.model.switch_cost(self.profile, r, pi)
                   for r in self._resident.values())

    def penalized(self, latency: float, wid: int, pi: int) -> float:
        """Expected service latency including the actuation penalty
        against ``wid``'s resident subnet (bit-identical to the
        pre-refactor inlined engine math)."""
        return self.model.penalized(latency, self.profile,
                                    self._resident.get(wid), pi)

    # -- commit ------------------------------------------------------------

    def actuate(self, wid: int, pi: int) -> float:
        """Batch launch on ``wid`` with subnet ``pi``: commit the
        residency change and book the switch cost actually paid.
        Returns that cost (0.0 when the worker was already resident)."""
        prev = self._resident.get(wid)
        cost = self.model.switch_cost(self.profile, prev, pi)
        self.n_launches += 1
        if prev != pi:
            self.n_switches += 1
        self.actuation_seconds += cost
        self._resident[int(wid)] = int(pi)
        return cost

    # -- accounting ---------------------------------------------------------

    @property
    def switch_rate(self) -> float:
        """Fraction of launches that actuated a different subnet than
        the worker's resident one (0.0 with no launches)."""
        return self.n_switches / self.n_launches if self.n_launches else 0.0

    def snapshot(self) -> Dict[str, float]:
        """Introspection bundle for stats/benchmarks (read-only)."""
        return {"n_workers": float(len(self._resident)),
                "n_launches": float(self.n_launches),
                "n_switches": float(self.n_switches),
                "switch_rate": self.switch_rate,
                "actuation_seconds": self.actuation_seconds}
