"""Asyncio transport for the shared scheduling engine (paper §5),
hosting a *real* JAX supernet via SubNetAct.

All scheduling decisions live in ``serving/engine.py``; this module
supplies wall-clock time, real worker execution (``asyncio.to_thread``
so the event loop keeps routing), and async plumbing: event-driven
scheduling (an ``asyncio.Condition`` signaled on submit/completion —
no sleep-polling), continuous-batching join windows, and transparent
fault handling (a worker killed mid-batch has its in-flight queries
re-enqueued and re-served by survivors, mirroring the simulator).

For deterministic tests, ``Router.run_virtual`` drives the *same*
engine on a ``VirtualClock`` through the shared event loop — the
parity path proving router and simulator schedule identically.
"""
from __future__ import annotations

import asyncio
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence

import numpy as np

from repro.serving.engine import (CompletionRecord, Dispatch, EngineConfig,
                                  SchedulingEngine, VirtualClock, WallClock,
                                  drive)
from repro.serving.policies import Policy
from repro.serving.profiler import LatencyProfile
from repro.serving.queue import Query


@dataclass
class ServedQuery:
    query: Query
    payload: Any                       # model input (e.g. token array row)
    # resolves to (prediction, acc); created by the running loop in
    # submit() — a Future is not a valid dataclass default value.
    done: Optional[asyncio.Future] = field(default=None)


@dataclass
class WorkerHandle:
    """One worker hosting the supernet. ``run(subnet_idx, payloads)``
    executes the actuated subnet on a batch and returns predictions."""

    wid: int
    run: Callable[[int, List[Any]], Any]
    alive: bool = True
    current_subnet: int = -1


class Router:
    """Asynchronous router: enqueue -> schedule -> dispatch -> respond.

    The engine owns every scheduling decision; the router owns time
    (injected clock), futures, and execution."""

    def __init__(self, profile: LatencyProfile, policy: Policy,
                 workers: Sequence[WorkerHandle],
                 clock=None, engine_cfg: Optional[EngineConfig] = None):
        self.profile = profile
        self.policy = policy
        self.workers = list(workers)
        self.clock = clock if clock is not None else WallClock()
        self.engine = SchedulingEngine(
            profile, policy, engine_cfg or EngineConfig(),
            worker_ids=[w.wid for w in self.workers], on_drop=self._on_drop)
        self._payloads: Dict[int, ServedQuery] = {}
        self._idle: List[WorkerHandle] = []
        self._open_events: Dict[int, asyncio.Event] = {}
        self._work = asyncio.Condition()
        self._task: Optional[asyncio.Task] = None
        self._qid = 0
        self._closed = False

    # -- legacy surface -------------------------------------------------

    @property
    def edf(self):
        return self.engine.edf

    @property
    def completed(self) -> List[Query]:
        """Queries with a resolved outcome (served or dropped)."""
        return [q for q in self.engine.queries
                if q.finish is not None or q.dropped]

    # -- async serving path ---------------------------------------------

    async def start(self):
        self._idle = [w for w in self.workers if w.alive]
        self._task = asyncio.create_task(self._schedule_loop())

    async def submit(self, payload: Any, slo_s: float) -> asyncio.Future:
        now = self.clock.now()
        q = Query(deadline=now + slo_s, seq=0, arrival=now, qid=self._qid)
        self._qid += 1
        sq = ServedQuery(q, payload, asyncio.get_running_loop().create_future())
        self._payloads[q.qid] = sq
        async with self._work:
            self.engine.admit(q)
            if not self._idle:
                # no idle capacity: the query may join a forming batch
                for d in self.engine.try_join(now):
                    ev = self._open_events.get(d.wid)
                    if ev is not None:
                        ev.set()        # batch filled/urgent: launch now
            self._work.notify_all()
        return sq.done

    def kill_worker(self, wid: int):
        """Fault injection: worker stops accepting batches (heartbeat
        loss). Its in-flight queries are transparently re-enqueued so
        survivors re-serve them; SlackFit absorbs the capacity loss by
        actuating down."""
        for w in self.workers:
            if w.wid == wid:
                w.alive = False
        self._idle = [w for w in self._idle if w.wid != wid]
        requeued = self.engine.fault(wid)
        ev = self._open_events.get(wid)
        if ev is not None:
            ev.set()                    # abort a forming batch's window
        if requeued:
            try:
                asyncio.get_running_loop().create_task(self._notify())
            except RuntimeError:
                pass                    # no loop: nothing to wake

    async def _notify(self):
        async with self._work:
            self._work.notify_all()

    def _on_drop(self, q: Query):
        sq = self._payloads.pop(q.qid, None)
        if sq is not None and not sq.done.done():
            sq.done.set_result((None, 0.0))

    async def _schedule_loop(self):
        while True:
            async with self._work:
                await self._work.wait_for(
                    lambda: self._closed
                    or (bool(self._idle) and len(self.engine.edf) > 0))
                if self._closed:
                    return
                worker = self._idle.pop(0)
            if not worker.alive:
                continue
            d = self.engine.next_dispatch(worker.wid, self.clock.now())
            if d is None:
                # drops emptied the queue, or the policy declined to
                # schedule: park until new work/capacity arrives rather
                # than spinning on an unchanged queue
                async with self._work:
                    self._idle.append(worker)
                    if len(self.engine.edf) > 0 and not self._closed:
                        await self._work.wait()
                continue
            if d.open:
                asyncio.create_task(self._form_and_run(worker, d))
            else:
                asyncio.create_task(self._run_batch(worker, d))

    async def _form_and_run(self, worker: WorkerHandle, d: Dispatch):
        """Hold an open batch for its join window (continuous batching):
        launch early if joins fill it, on fault, or at window expiry."""
        ev = asyncio.Event()
        self._open_events[d.wid] = ev
        try:
            while not ev.is_set() and not d.faulted:
                delay = d.launch_at - self.clock.now()
                if delay <= 0:
                    break
                try:
                    await asyncio.wait_for(ev.wait(), timeout=delay)
                except asyncio.TimeoutError:
                    break
        finally:
            self._open_events.pop(d.wid, None)
        if d.faulted:
            return                      # queries already re-enqueued
        await self._run_batch(worker, d)

    async def _run_batch(self, worker: WorkerHandle, d: Dispatch):
        if d.faulted:                   # killed between formation and start
            await self._notify()
            return
        if not d.launched:
            self.engine.launch(d, self.clock.now())
        # payloads may be gone for queries resolved by an early drain()
        pairs = [(q, self._payloads.get(q.qid)) for q in d.queries]
        payloads = [sq.payload for _, sq in pairs if sq is not None]
        if payloads:
            # SubNetAct actuation == a different control tuple; executed
            # in a thread so the event loop keeps routing.
            preds = await asyncio.to_thread(worker.run, d.pareto_idx, payloads)
            worker.current_subnet = d.pareto_idx
        else:
            preds = []
        fin = self.clock.now()
        if d.faulted:
            # worker died mid-batch: the engine already re-enqueued the
            # queries — discard the (lost) result and wake the scheduler
            await self._notify()
            return
        self.engine.complete(d, fin)
        arr = np.asarray(preds)
        i = 0
        for q, sq in pairs:
            if sq is None:
                continue
            self._payloads.pop(q.qid, None)
            if not sq.done.done():
                sq.done.set_result((arr[i], d.acc))
            i += 1
        async with self._work:
            if worker.alive:
                self._idle.append(worker)
            self._work.notify_all()

    async def drain(self, timeout: float = 10.0):
        t0 = time.perf_counter()
        while self._payloads and time.perf_counter() - t0 < timeout:
            await asyncio.sleep(0.01)
        self._closed = True
        async with self._work:
            self._work.notify_all()
        if self._task is not None:
            self._task.cancel()
        # account dropped-but-unresolved queries (still queued, forming,
        # or lost to a dead worker)
        self.engine.abandon_pending()
        for sq in self._payloads.values():
            sq.query.dropped = True
            if not sq.done.done():
                sq.done.set_result((None, 0.0))
        self._payloads.clear()

    def stats(self) -> Dict[str, float]:
        return self.engine.stats()

    def records(self) -> List[CompletionRecord]:
        return self.engine.records()

    # -- deterministic parity path --------------------------------------

    def run_virtual(self, arrivals: Sequence[float], slo_s: float,
                    fault_times: Optional[Dict[int, float]] = None
                    ) -> List[CompletionRecord]:
        """Drive this router's engine to quiescence on its VirtualClock:
        the same shared event loop as the simulator, with service times
        from the engine (no real execution). Used by parity tests to
        prove router and simulator produce identical per-query
        schedules through the shared core."""
        if not isinstance(self.clock, VirtualClock):
            raise TypeError("run_virtual requires a VirtualClock router")
        queries = [Query(deadline=float(t) + slo_s, seq=i,
                         arrival=float(t), qid=i)
                   for i, t in enumerate(arrivals)]
        drive(self.engine, queries,
              [w.wid for w in self.workers if w.alive],
              fault_times=fault_times, clock=self.clock)
        return self.engine.records()


def make_supernet_workers(n: int, step_fn: Callable[[int, Any], Any],
                          pad_batch: Callable[[List[Any]], Any]) -> List[WorkerHandle]:
    """Workers sharing one jitted supernet step. ``step_fn(subnet_idx,
    batch_array)`` must be jit-compiled with the control tuple as data
    so actuation never recompiles."""
    def run(subnet_idx: int, payloads: List[Any]):
        return step_fn(subnet_idx, pad_batch(payloads))
    return [WorkerHandle(wid=i, run=run) for i in range(n)]
