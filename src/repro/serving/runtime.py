"""Asyncio transport for the shared scheduling engine (paper §5),
hosting a *real* JAX supernet via SubNetAct.

All scheduling decisions live in ``serving/engine.py``; this module
supplies wall-clock time, real worker execution (``asyncio.to_thread``
so the event loop keeps routing), and async plumbing: event-driven
scheduling (an ``asyncio.Condition`` signaled on submit/completion —
no sleep-polling), continuous-batching join windows, and transparent
fault handling (a worker killed mid-batch has its in-flight queries
re-enqueued and re-served by survivors, mirroring the simulator).

For deterministic tests, ``Router.run_virtual`` drives the *same*
engine on a ``VirtualClock`` through the shared event loop — the
parity path proving router and simulator schedule identically.

Scale-out: a ``Router`` is the single-replica transport; the
``ClusterRouter`` below composes N of them behind one asyncio front
door, with placement delegated to ``serving/cluster.py``'s coordinator
(and a matching ``run_virtual`` cluster parity path).
"""
from __future__ import annotations

import asyncio
import time
import traceback
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence

import numpy as np

from repro.serving.autoscaler import (AutoscaleConfig, ClusterAutoscaler,
                                      coordinator_forecast)
from repro.serving.cluster import (ClusterCoordinator, drive_cluster,
                                   make_placement)
from repro.serving.forecast import ForecastConfig
from repro.serving.engine import (CompletionRecord, Dispatch, EngineConfig,
                                  SchedulingEngine, VirtualClock, WallClock,
                                  drive)
from repro.serving.metrics import cluster_summarize
from repro.serving.policies import Policy
from repro.serving.profiler import LatencyProfile
from repro.serving.queue import Query


@dataclass
class ServedQuery:
    query: Query
    payload: Any                       # model input (e.g. token array row)
    # resolves to (prediction, acc); created by the running loop in
    # submit() — a Future is not a valid dataclass default value.
    done: Optional[asyncio.Future] = field(default=None)


@dataclass
class WorkerHandle:
    """One worker hosting the supernet. ``run(subnet_idx, payloads)``
    executes the actuated subnet on a batch and returns predictions.

    The worker's *resident subnet* is deliberately NOT stored here: the
    engine's ``ResidencyTracker`` (serving/residency.py) is the single
    owner of that state, committed at ``engine.launch`` — a transport
    copy could disagree with the scheduler's accounting (the historical
    ``current_subnet`` duplication, regression-tested in
    tests/test_residency.py). Read ``Router.resident_subnet(wid)``."""

    wid: int
    run: Callable[[int, List[Any]], Any]
    alive: bool = True


class Router:
    """Asynchronous router: enqueue -> schedule -> dispatch -> respond.

    The engine owns every scheduling decision; the router owns time
    (injected clock), futures, and execution."""

    def __init__(self, profile: LatencyProfile, policy: Policy,
                 workers: Sequence[WorkerHandle],
                 clock=None, engine_cfg: Optional[EngineConfig] = None,
                 replica_id: int = 0, executor=None):
        self.profile = profile
        self.policy = policy
        self.workers = list(workers)
        # optional serving/executor.py SubnetExecutor backing the
        # workers: pure execution — the engine never consults it, the
        # router only surfaces its counters through stats()
        self.executor = executor
        self.clock = clock if clock is not None else WallClock()
        self.engine = SchedulingEngine(
            profile, policy, engine_cfg or EngineConfig(),
            worker_ids=[w.wid for w in self.workers], on_drop=self._on_drop,
            replica_id=replica_id)
        self._payloads: Dict[int, ServedQuery] = {}
        self._idle: List[WorkerHandle] = []
        self._open_events: Dict[int, asyncio.Event] = {}
        self._work = asyncio.Condition()
        self._task: Optional[asyncio.Task] = None
        self._qid = 0
        self._closed = False

    # -- legacy surface -------------------------------------------------

    @property
    def edf(self):
        return self.engine.edf

    @property
    def completed(self) -> List[Query]:
        """Queries with a resolved outcome (served or dropped)."""
        return [q for q in self.engine.queries
                if q.finish is not None or q.dropped]

    # -- async serving path ---------------------------------------------

    async def start(self):
        self._idle = [w for w in self.workers if w.alive]
        self._task = asyncio.create_task(self._schedule_loop())

    async def submit(self, payload: Any, slo_s: float,
                     qid: Optional[int] = None) -> asyncio.Future:
        """Enqueue one query. ``qid`` lets a cluster front door assign
        globally-unique ids; standalone routers number locally."""
        now = self.clock.now()
        if qid is None:
            qid = self._qid
            self._qid += 1
        q = Query(deadline=now + slo_s, seq=0, arrival=now, qid=qid)
        return await self.submit_query(q, payload)

    async def submit_query(self, q: Query, payload: Any) -> asyncio.Future:
        """Admit a pre-built query to *this* replica (the ClusterRouter
        places the query first, then hands it to the chosen replica)."""
        now = self.clock.now()
        sq = ServedQuery(q, payload, asyncio.get_running_loop().create_future())
        self._payloads[q.qid] = sq
        async with self._work:
            self.engine.admit(q)
            if not self._idle:
                # no idle capacity: the query may join a forming batch
                self.offer_joins()
            self._work.notify_all()
        return sq.done

    def offer_joins(self):
        """Offer queued queries to open forming batches (continuous
        batching), launching any batch that fills or turns urgent. Also
        called after a cluster migration lands queries in this
        replica's queue."""
        for d in self.engine.try_join(self.clock.now()):
            ev = self._open_events.get(d.wid)
            if ev is not None:
                ev.set()                # batch filled/urgent: launch now

    def kill_worker(self, wid: int):
        """Fault injection: worker stops accepting batches (heartbeat
        loss). Its in-flight queries are transparently re-enqueued so
        survivors re-serve them; SlackFit absorbs the capacity loss by
        actuating down."""
        for w in self.workers:
            if w.wid == wid:
                w.alive = False
        self._idle = [w for w in self._idle if w.wid != wid]
        requeued = self.engine.fault(wid)
        ev = self._open_events.get(wid)
        if ev is not None:
            ev.set()                    # abort a forming batch's window
        if requeued:
            try:
                asyncio.get_running_loop().create_task(self._notify())
            except RuntimeError:
                pass                    # no loop: nothing to wake

    async def _notify(self):
        async with self._work:
            self._work.notify_all()

    def _on_drop(self, q: Query):
        sq = self._payloads.pop(q.qid, None)
        if sq is not None and not sq.done.done():
            sq.done.set_result((None, 0.0))
        if sq is not None and not self._payloads:
            # a drop may be the event that resolves the last outstanding
            # query (e.g. the whole queue expired): wake an event-driven
            # drain() waiting on the _work condition
            try:
                asyncio.get_running_loop().create_task(self._notify())
            except RuntimeError:
                pass                    # no loop: nothing waits

    async def _schedule_loop(self):
        while True:
            async with self._work:
                await self._work.wait_for(
                    lambda: self._closed
                    or (bool(self._idle) and len(self.engine.edf) > 0))
                if self._closed:
                    return
                worker = self._idle.pop(0)
            if not worker.alive:
                continue
            d = self.engine.next_dispatch(worker.wid, self.clock.now())
            if d is None:
                # drops emptied the queue, or the policy declined to
                # schedule: park until new work/capacity arrives rather
                # than spinning on an unchanged queue
                async with self._work:
                    self._idle.append(worker)
                    if len(self.engine.edf) > 0 and not self._closed:
                        await self._work.wait()
                continue
            if d.open:
                asyncio.create_task(self._form_and_run(worker, d))
            else:
                asyncio.create_task(self._run_batch(worker, d))

    async def _form_and_run(self, worker: WorkerHandle, d: Dispatch):
        """Hold an open batch for its join window (continuous batching):
        launch early if joins fill it, on fault, or at window expiry."""
        ev = asyncio.Event()
        self._open_events[d.wid] = ev
        try:
            while not ev.is_set() and not d.faulted:
                delay = d.launch_at - self.clock.now()
                if delay <= 0:
                    break
                try:
                    await asyncio.wait_for(ev.wait(), timeout=delay)
                except asyncio.TimeoutError:
                    break
        finally:
            self._open_events.pop(d.wid, None)
        if d.faulted:
            return                      # queries already re-enqueued
        await self._run_batch(worker, d)

    async def _run_batch(self, worker: WorkerHandle, d: Dispatch):
        if d.faulted:                   # killed between formation and start
            await self._notify()
            return
        if not d.launched:
            self.engine.launch(d, self.clock.now())
        # payloads may be gone for queries resolved by an early drain()
        pairs = [(q, self._payloads.get(q.qid)) for q in d.queries]
        payloads = [sq.payload for _, sq in pairs if sq is not None]
        if payloads:
            # SubNetAct actuation == a different control tuple; executed
            # in a thread so the event loop keeps routing.
            preds = await asyncio.to_thread(worker.run, d.pareto_idx, payloads)
        else:
            preds = []
        fin = self.clock.now()
        if d.faulted:
            # worker died mid-batch: the engine already re-enqueued the
            # queries — discard the (lost) result and wake the scheduler
            await self._notify()
            return
        self.engine.complete(d, fin)
        arr = np.asarray(preds)
        i = 0
        for q, sq in pairs:
            if sq is None:
                continue
            self._payloads.pop(q.qid, None)
            if not sq.done.done():
                sq.done.set_result((arr[i], d.acc))
            i += 1
        async with self._work:
            if worker.alive:
                self._idle.append(worker)
            self._work.notify_all()

    async def drain(self, timeout: float = 10.0):
        """Wait for every outstanding query to resolve, then shut the
        schedule loop down. Event-driven: waits on the ``_work``
        condition (notified at batch completion and at emptying drops),
        so the drain wakes the instant the last query resolves instead
        of sleep-polling up to 10 ms past it. Queries still unresolved
        when ``timeout`` expires are resolved as dropped AND marked
        ``timed_out`` — the shutdown-loss path, distinct from the
        policy's infeasible drops."""
        deadline = time.perf_counter() + timeout
        async with self._work:
            while self._payloads:
                remaining = deadline - time.perf_counter()
                if remaining <= 0:
                    break
                try:
                    await asyncio.wait_for(self._work.wait(),
                                           timeout=remaining)
                except asyncio.TimeoutError:
                    break
        expired = bool(self._payloads)
        self._closed = True
        async with self._work:
            self._work.notify_all()
        if self._task is not None:
            self._task.cancel()
        # account dropped-but-unresolved queries (still queued, forming,
        # or lost to a dead worker)
        self.engine.abandon_pending()
        for sq in self._payloads.values():
            sq.query.dropped = True
            sq.query.timed_out = expired
            if not sq.done.done():
                sq.done.set_result((None, 0.0))
        self._payloads.clear()

    def resident_subnet(self, wid: int) -> Optional[int]:
        """The subnet resident on worker ``wid`` per the engine's
        residency tracker — the transport's single source of truth for
        'what is loaded where' (the engine actuates at launch, before
        the batch executes)."""
        return self.engine.residency.resident(wid)

    def stats(self) -> Dict[str, float]:
        st = self.engine.stats()
        st["timed_out"] = float(sum(1 for q in self.engine.queries
                                    if q.timed_out))
        if self.executor is not None:
            st["executor"] = self.executor.counters()
        return st

    def records(self) -> List[CompletionRecord]:
        return self.engine.records()

    # -- deterministic parity path --------------------------------------

    def run_virtual(self, arrivals: Sequence[float], slo_s: float,
                    fault_times: Optional[Dict[int, float]] = None
                    ) -> List[CompletionRecord]:
        """Drive this router's engine to quiescence on its VirtualClock:
        the same shared event loop as the simulator, with service times
        from the engine (no real execution). Used by parity tests to
        prove router and simulator produce identical per-query
        schedules through the shared core."""
        if not isinstance(self.clock, VirtualClock):
            raise TypeError("run_virtual requires a VirtualClock router")
        queries = [Query(deadline=float(t) + slo_s, seq=i,
                         arrival=float(t), qid=i)
                   for i, t in enumerate(arrivals)]
        drive(self.engine, queries,
              [w.wid for w in self.workers if w.alive],
              fault_times=fault_times, clock=self.clock)
        return self.engine.records()


# --------------------------------------------------------------------------
# Cluster front door: N single-replica Routers behind one coordinator
# --------------------------------------------------------------------------


class ClusterRouter:
    """Asyncio multi-replica serving plane.

    Each replica group is a full ``Router`` (one engine, its own worker
    pool, its own schedule loop); this class is the single front door
    that places every incoming query on one replica via the cluster
    coordinator's ``PlacementPolicy`` and fans ``submit`` out to the
    chosen replica. Placement logic lives in the coordinator only;
    scheduling stays inside each replica's engine (the PR 2 rule,
    extended).

    Replica death (``kill_replica``) kills every worker in the group —
    re-enqueueing its in-flight queries through the engine's own fault
    path — then drains the dead replica's queue back through the
    coordinator, which re-routes the orphans (payloads and futures
    travel with them) to surviving replicas.

    With an ``AutoscaleConfig`` the cluster additionally runs a
    ``ClusterAutoscaler`` (serving/autoscaler.py): a live asyncio
    control loop spawns whole Router replicas (cold start before they
    turn routable) and gracefully decommissions them — queued work
    re-routes with its payloads/futures, in-flight batches finish on
    the old workers. ``run_virtual`` drives the same autoscaler on the
    shared virtual heap for parity with ``simulate_cluster``.
    """

    # consecutive live-autoscale tick failures tolerated before the
    # control loop re-raises (scaling dead, not unlucky)
    AUTOSCALE_MAX_CONSEC = 3

    def __new__(cls, *args, **kwargs):
        # transport switch: "inproc" (default) keeps every replica in
        # this process; "proc" dispatches to serving/ipc.py's
        # ProcClusterRouter — one OS process per replica group behind
        # the IPC front door (socketpair locally, or TCP with
        # listen=/token= for remote replicas), same public surface,
        # same coordinator ownership of admission/placement/lifecycle,
        # including the live autoscaler.
        transport = kwargs.get("transport", "inproc")
        if transport not in ("inproc", "proc"):
            raise ValueError(f"unknown transport {transport!r}; "
                             f"choose from ['inproc', 'proc']")
        if cls is ClusterRouter and transport == "proc":
            from repro.serving.ipc import ProcClusterRouter
            return object.__new__(ProcClusterRouter)
        return object.__new__(cls)

    def __init__(self, profile: LatencyProfile, policy: Policy,
                 replicas: Sequence[Sequence[WorkerHandle]],
                 clock=None, engine_cfg: Optional[EngineConfig] = None,
                 placement: str = "round_robin", placement_seed: int = 0,
                 autoscale: Optional[AutoscaleConfig] = None,
                 worker_factory: Optional[Callable[[int],
                                          List[WorkerHandle]]] = None,
                 slo: float = 0.036,
                 forecast: Optional[ForecastConfig] = None,
                 transport: str = "inproc", **_proc_only):
        if _proc_only:
            raise TypeError("arguments only valid with transport='proc': "
                            f"{sorted(_proc_only)}")
        # ``slo`` is the deadline regime the autoscaler's thresholds
        # normalize to (when AutoscaleConfig.slo is None) — match the
        # slo_s you submit/run_virtual with, as simulate_cluster's
        # autoscaler inherits ClusterConfig.slo the same way
        self.profile = profile
        self.clock = clock if clock is not None else WallClock()
        self._policy_proto = policy
        self._engine_cfg = engine_cfg
        self.routers = [
            Router(profile, policy.clone(), group, clock=self.clock,
                   engine_cfg=engine_cfg, replica_id=rid)
            for rid, group in enumerate(replicas)]
        # the coordinator-level forecaster must be constructed by the
        # SAME defaulting rule as simulate_cluster (coordinator_forecast)
        # or forecast-led schedules would diverge between transports
        self.coord = ClusterCoordinator(
            [r.engine for r in self.routers], make_placement(placement),
            placement_seed=placement_seed,
            forecast=coordinator_forecast(autoscale, forecast))
        self._qid = 0
        self._started = False
        self._scale_task: Optional[asyncio.Task] = None
        self._autoscale_errors = 0
        # autoscaling: spawned replica groups come from worker_factory
        # (default: spawn_workers clones of the first group's run fn,
        # wids 0..k-1 to mirror the simulator's spawned pools)
        self._worker_factory = worker_factory
        if worker_factory is None and replicas and replicas[0]:
            run0 = replicas[0][0].run
            k = (autoscale.spawn_workers if autoscale
                 and autoscale.spawn_workers else len(replicas[0]))
            self._worker_factory = lambda rid: [
                WorkerHandle(wid=i, run=run0) for i in range(k)]
        self.autoscaler = None
        if autoscale is not None:
            if self._worker_factory is None:
                raise ValueError(
                    "autoscaling needs a worker_factory (none given and "
                    "no first replica group to clone one from)")
            if len(self.routers) > autoscale.max_replicas:
                raise ValueError(
                    f"{len(self.routers)} initial replicas exceed "
                    f"max_replicas={autoscale.max_replicas}")
            if (autoscale.spawn_workers is None and worker_factory is None
                    and len({len(g) for g in replicas}) > 1):
                raise ValueError(
                    "heterogeneous worker pools need an explicit "
                    "AutoscaleConfig.spawn_workers or a worker_factory")
            self.autoscaler = ClusterAutoscaler(
                self.coord, autoscale, self._spawn_replica_engine,
                slo=slo, migrate_fn=self._migrate)

    def _spawn_replica_engine(self, rid: int):
        """Autoscaler hook: a spawned replica group is a full Router
        (its engine registers with the coordinator). In the live plane
        the autoscale loop starts it; in the virtual parity path
        drive_cluster drives the engine directly."""
        r = Router(self.profile, self._policy_proto.clone(),
                   self._worker_factory(rid), clock=self.clock,
                   engine_cfg=self._engine_cfg, replica_id=rid)
        assert len(self.routers) == rid
        self.routers.append(r)
        return r.engine

    # -- async serving path ---------------------------------------------

    async def start(self):
        for r in self.routers:
            await r.start()
        self._started = True
        if self.autoscaler is not None:
            self.autoscaler.anchor(self.clock.now())
            self._scale_task = asyncio.create_task(self._autoscale_loop())

    async def _autoscale_loop(self):
        """Live control loop (wall clock): the asyncio twin of the
        SCALE/READY events drive_cluster puts on the virtual heap. A
        failing tick must not silently end autoscaling for the rest of
        the run, so single errors are counted
        (``stats()['autoscale_errors']``), reported, and the loop keeps
        going — but ``AUTOSCALE_MAX_CONSEC`` consecutive failures mean
        the control loop is dead, not unlucky, and the exception is
        re-raised instead of scaling silently going dark."""
        cfg = self.autoscaler.cfg
        loop = asyncio.get_running_loop()
        consecutive = 0
        while True:
            await asyncio.sleep(cfg.interval)
            try:
                for ev in self.autoscaler.tick(self.clock.now()):
                    if ev.kind == "spawn":
                        await self.routers[ev.rid].start()
                        loop.call_later(
                            max(ev.ready_at - self.clock.now(), 0.0),
                            self._activate, ev.rid)
                    # decommission: tick already re-routed the queue
                    # and migrated payloads/futures via _migrate
                consecutive = 0
            except Exception:           # noqa: BLE001 — keep scaling alive
                traceback.print_exc()
                self._autoscale_errors += 1
                consecutive += 1
                if consecutive >= self.AUTOSCALE_MAX_CONSEC:
                    raise

    def _activate(self, rid: int):
        """Cold start paid: the spawned replica becomes routable (a
        replica killed mid-warm-up stays down)."""
        if self.coord.alive[rid]:
            self.autoscaler.activate(rid, self.clock.now())

    async def submit(self, payload: Any, slo_s: float) -> asyncio.Future:
        now = self.clock.now()
        q = Query(deadline=now + slo_s, seq=0, arrival=now, qid=self._qid)
        self._qid += 1
        self.coord.queries.append(q)
        self.coord.observe(q)           # one forecast observation per arrival
        if not self.coord.alive_replicas():
            # coordinator semantics (cluster.py admit): nowhere to
            # route — record the query and resolve it as dropped
            q.dropped = True
            fut = asyncio.get_running_loop().create_future()
            fut.set_result((None, 0.0))
            return fut
        rid = self.coord.select(q, now)
        fut = await self.routers[rid].submit_query(q, payload)
        if not self.coord.alive[rid]:
            # the replica died between placement and admission (the
            # await may suspend on the replica's lock): pull the
            # just-admitted query back out and re-route it
            self._rescue(rid)
        return fut

    def kill_worker(self, rid: int, wid: int):
        self.routers[rid].kill_worker(wid)
        if self.coord.should_decommission(rid):
            self._rescue(rid)
            self._book_death(rid)
        elif (not self.coord.alive[rid]
                and len(self.routers[rid].engine.edf)):
            # fault re-enqueued onto an already-decommissioned replica:
            # surrender the queue again (payloads travel with it)
            self._rescue(rid)

    def kill_replica(self, rid: int):
        """Whole replica group dies: fault every worker, then re-route
        its queued + re-enqueued queries (with their payloads/futures)
        to survivors through the placement policy."""
        was_alive = self.coord.alive[rid]
        r = self.routers[rid]
        for w in list(r.workers):
            r.kill_worker(w.wid)        # may already _rescue on the last
        if self.coord.alive[rid]:
            self._rescue(rid)
        if was_alive:
            self._book_death(rid)

    def _book_death(self, rid: int):
        """Mirror drive_cluster's EV_FAULT bookkeeping on the live
        path: the autoscaler must close the dead replica's billing
        span (and forget it if it was still warming), or
        replica_seconds overstates and a dead warming replica would
        inflate n_committed forever."""
        if self.autoscaler is not None:
            self.autoscaler.on_death(rid, self.clock.now())

    def _rescue(self, rid: int):
        """Drain replica ``rid``'s queue back through the coordinator
        (marking it dead), migrating payloads and futures to the
        re-routed replicas. Safe to call again on an already-dead
        replica — the late-admission race in ``submit`` needs exactly
        that to re-route a query that landed after the death."""
        self._migrate(rid, self.coord.redistribute(rid, self.clock.now()))

    def _migrate(self, rid: int, moved):
        """Move the payloads/futures of re-routed queries to their new
        replicas and wake those schedulers. Shared by the death path
        (``_rescue``) and the autoscaler's graceful decommission (its
        ``migrate_fn`` hook). A no-op before ``start`` — the virtual
        parity path drives bare engines and owns dispatch itself."""
        if not self._started:
            return
        r = self.routers[rid]
        woken = set()
        for q, target in moved:
            sq = r._payloads.pop(q.qid, None)
            if sq is not None:
                self.routers[target]._payloads[q.qid] = sq
            woken.add(target)
        # total-cluster death: redistribute dropped the orphans — their
        # futures must still resolve
        for q in list(r.engine.queries):
            if q.dropped:
                sq = r._payloads.pop(q.qid, None)
                if sq is not None and not sq.done.done():
                    sq.done.set_result((None, 0.0))
        for target in woken:
            tr = self.routers[target]
            if not tr._idle:
                # migrated queries may join a survivor's forming batch
                # (mirrors submit_query and drive_cluster's
                # dispatch-after-redistribute)
                tr.offer_joins()
        try:
            loop = asyncio.get_running_loop()
            for target in woken:
                loop.create_task(self.routers[target]._notify())
        except RuntimeError:
            pass                        # no loop: nothing to wake

    async def drain(self, timeout: float = 10.0):
        if self._scale_task is not None:
            self._scale_task.cancel()
            self._scale_task = None
        await asyncio.gather(*(r.drain(timeout) for r in self.routers))

    def stats(self) -> Dict[str, float]:
        if self.autoscaler is not None:
            st = cluster_summarize(
                self.coord.queries, n_replicas=self.coord.n_replicas,
                n_joins=sum(e.n_joins for e in self.coord.engines),
                replica_spans=self.autoscaler.replica_spans(
                    self.clock.now()),
                n_switches=sum(e.residency.n_switches
                               for e in self.coord.engines),
                n_dispatches=sum(e.residency.n_launches
                                 for e in self.coord.engines),
                actuation_seconds=sum(e.residency.actuation_seconds
                                      for e in self.coord.engines))
        else:
            st = self.coord.stats()
        if self.autoscaler is not None:
            st["autoscale_errors"] = float(self._autoscale_errors)
        snap = self.coord.forecast_snapshot(self.clock.now())
        if snap is not None:
            st["forecast"] = snap
        return st

    def records(self) -> List[CompletionRecord]:
        return self.coord.records()

    # -- deterministic parity path --------------------------------------

    def run_virtual(self, arrivals: Sequence[float], slo_s: float,
                    replica_deaths: Optional[Dict[int, float]] = None,
                    fault_times: Optional[Dict[tuple, float]] = None
                    ) -> List[CompletionRecord]:
        """Drive the whole cluster to quiescence on its VirtualClock
        through the shared event loop in serving/cluster.py — the
        parity path proving ClusterRouter and ClusterSimulator place
        and schedule identically, autoscaling included (scale ticks
        ride the same virtual heap; spawned Routers contribute their
        engines without ever starting an asyncio loop)."""
        if not isinstance(self.clock, VirtualClock):
            raise TypeError("run_virtual requires a VirtualClock cluster")
        queries = [Query(deadline=float(t) + slo_s, seq=i,
                         arrival=float(t), qid=i)
                   for i, t in enumerate(arrivals)]
        drive_cluster(
            self.coord, queries,
            {rid: [w.wid for w in r.workers if w.alive]
             for rid, r in enumerate(self.routers)},
            replica_deaths=replica_deaths, fault_times=fault_times,
            clock=self.clock, autoscaler=self.autoscaler)
        if self.autoscaler is not None:
            # close open spans at the same nominal horizon the
            # simulator bills to (last arrival + drain margin), so both
            # transports report identical replica_seconds for
            # identical schedules
            t_end = (max(arrivals) if len(arrivals) else 0.0) + 4 * slo_s
            self.autoscaler.finalize(float(t_end))
        return self.coord.records()


def make_supernet_workers(n: int, step_fn: Callable[[int, Any], Any],
                          pad_batch: Callable[[List[Any]], Any]) -> List[WorkerHandle]:
    """Workers sharing one jitted supernet step. ``step_fn(subnet_idx,
    batch_array)`` must be jit-compiled with the control tuple as data
    so actuation never recompiles."""
    def run(subnet_idx: int, payloads: List[Any]):
        return step_fn(subnet_idx, pad_batch(payloads))
    return [WorkerHandle(wid=i, run=run) for i in range(n)]
