"""Asyncio router + worker runtime (paper §5) hosting a *real* JAX
supernet via SubNetAct.

The router owns the global EDF queue and invokes the pluggable policy
whenever a worker signals availability and the queue is non-empty; the
worker actuates the chosen subnet *in place* by passing a different
control tuple to the same jitted executable — no reload, no recompile
(SubNetAct). Mirrors the paper's C++/gRPC architecture with in-process
asyncio semantics (async submission, callbacks, worker heartbeats).
"""
from __future__ import annotations

import asyncio
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence

import numpy as np

from repro.serving.metrics import mean_serving_accuracy, slo_attainment
from repro.serving.policies import Policy
from repro.serving.profiler import LatencyProfile
from repro.serving.queue import EDFQueue, Query


@dataclass
class ServedQuery:
    query: Query
    payload: Any                       # model input (e.g. token array row)
    # resolves to (prediction, acc); created by the running loop in
    # submit() — a Future is not a valid dataclass default value.
    done: Optional[asyncio.Future] = field(default=None)


@dataclass
class WorkerHandle:
    """One worker hosting the supernet. ``run(subnet_idx, payloads)``
    executes the actuated subnet on a batch and returns predictions."""

    wid: int
    run: Callable[[int, List[Any]], Any]
    alive: bool = True
    current_subnet: int = -1


class Router:
    """Asynchronous router: enqueue -> schedule -> dispatch -> respond."""

    def __init__(self, profile: LatencyProfile, policy: Policy,
                 workers: Sequence[WorkerHandle]):
        self.profile = profile
        self.policy = policy
        self.workers = list(workers)
        self.edf = EDFQueue()
        self._payloads: Dict[int, ServedQuery] = {}
        self._idle: asyncio.Queue = asyncio.Queue()
        self._qid = 0
        self.completed: List[Query] = []
        self._closed = False

    async def start(self):
        for w in self.workers:
            if w.alive:
                self._idle.put_nowait(w)
        self._task = asyncio.create_task(self._schedule_loop())

    async def submit(self, payload: Any, slo_s: float) -> asyncio.Future:
        now = time.perf_counter()
        q = Query(deadline=now + slo_s, seq=0, arrival=now, qid=self._qid)
        self._qid += 1
        sq = ServedQuery(q, payload, asyncio.get_running_loop().create_future())
        self._payloads[q.qid] = sq
        self.edf.push(q)
        return sq.done

    def kill_worker(self, wid: int):
        """Fault injection: worker stops accepting batches (heartbeat
        loss); SlackFit absorbs the capacity loss by actuating down."""
        for w in self.workers:
            if w.wid == wid:
                w.alive = False

    async def _schedule_loop(self):
        loop = asyncio.get_running_loop()
        while not self._closed:
            worker: WorkerHandle = await self._idle.get()
            if not worker.alive:
                continue            # dead workers leave the pool
            while not len(self.edf) and not self._closed:
                await asyncio.sleep(0.0005)
            if self._closed:
                return
            now = time.perf_counter()
            dropped = self.edf.drop_expired(now, float(self.profile.lat[:, 0].min()))
            for q in dropped:
                sq = self._payloads.pop(q.qid, None)
                if sq is not None:
                    self.completed.append(q)
                    if not sq.done.done():
                        sq.done.set_result((None, 0.0))
            if not len(self.edf):
                self._idle.put_nowait(worker)
                continue
            slack = self.edf.head_slack(now)
            dec = self.policy.choose(self.profile, slack, len(self.edf))
            batch = self.edf.pop_batch(dec.batch_size)
            sqs = [self._payloads.pop(q.qid) for q in batch]
            acc = float(self.profile.accs[dec.pareto_idx])
            loop.create_task(self._run_batch(worker, dec.pareto_idx, sqs, acc))

    async def _run_batch(self, worker: WorkerHandle, subnet_idx: int,
                         sqs: List[ServedQuery], acc: float):
        payloads = [s.payload for s in sqs]
        # SubNetAct actuation == a different control tuple; executed in a
        # thread so the event loop keeps routing.
        preds = await asyncio.to_thread(worker.run, subnet_idx, payloads)
        worker.current_subnet = subnet_idx
        fin = time.perf_counter()
        for i, s in enumerate(sqs):
            s.query.finish = fin
            s.query.served_acc = acc
            self.completed.append(s.query)
            if not s.done.done():
                s.done.set_result((np.asarray(preds)[i], acc))
        if worker.alive:
            self._idle.put_nowait(worker)

    async def drain(self, timeout: float = 10.0):
        t0 = time.perf_counter()
        while self._payloads and time.perf_counter() - t0 < timeout:
            await asyncio.sleep(0.01)
        self._closed = True
        self._task.cancel()
        # account dropped-but-unresolved queries
        for s in self._payloads.values():
            s.query.dropped = True
            self.completed.append(s.query)
            if not s.done.done():
                s.done.set_result((None, 0.0))
        self._payloads.clear()

    def stats(self) -> Dict[str, float]:
        return {
            "slo_attainment": slo_attainment(self.completed),
            "mean_acc": mean_serving_accuracy(self.completed),
            "served": float(len(self.completed)),
        }


def make_supernet_workers(n: int, step_fn: Callable[[int, Any], Any],
                          pad_batch: Callable[[List[Any]], Any]) -> List[WorkerHandle]:
    """Workers sharing one jitted supernet step. ``step_fn(subnet_idx,
    batch_array)`` must be jit-compiled with the control tuple as data
    so actuation never recompiles."""
    def run(subnet_idx: int, payloads: List[Any]):
        return step_fn(subnet_idx, pad_batch(payloads))
    return [WorkerHandle(wid=i, run=run) for i in range(n)]
