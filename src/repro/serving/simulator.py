"""Discrete-event transport for the shared scheduling engine (paper §5
architecture, §6 experiments).

All scheduling decisions — admission + infeasible-drop, EDF ordering,
policy invocation, batch formation (incl. continuous-batching joins),
actuation-cost accounting, fault re-enqueue — live in
``serving/engine.py``; this module only supplies virtual time and the
simulation-specific service model: per-batch latency from the profiler,
stragglers with optional backup-batch hedging, and worker fault events.
The asyncio runtime (serving/runtime.py) drives the *same* engine under
wall clock with real JAX workers.

Multi-replica: ``simulate_cluster`` runs N replica groups (one engine
each) behind a ``ClusterCoordinator`` on the single shared event loop
in ``serving/cluster.py`` — placement decisions live in the
coordinator, scheduling stays per-replica, and the whole cluster is as
deterministic as one engine.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.serving.autoscaler import (AutoscaleConfig, ClusterAutoscaler,
                                      ScaleEvent, coordinator_forecast)
from repro.serving.forecast import ForecastConfig
from repro.serving.cluster import (ClusterCoordinator, build_engines,
                                   drive_cluster, make_placement,
                                   replica_worker_counts)
from repro.serving.engine import (EV_FREE, CompletionRecord, DispatchRecord,
                                  Dispatch, EngineConfig, SchedulingEngine,
                                  completion_records, drive)
from repro.serving.metrics import (cluster_summarize, latency_percentiles,
                                   mean_serving_accuracy, slo_attainment,
                                   summarize)
from repro.serving.profiler import (SUBNETACT_ACTUATION_S, HardwareProfile,
                                    LatencyProfile, RTX2080TI)
from repro.serving.policies import Policy
from repro.serving.queue import Query


@dataclass
class SimConfig:
    n_workers: int = 8
    slo: float = 0.036                      # paper's 36ms default
    actuation_delay: float = SUBNETACT_ACTUATION_S
    load_on_switch: bool = False            # pay weight-loading on model change
    hw: HardwareProfile = RTX2080TI
    straggler_prob: float = 0.0
    straggler_factor: float = 3.0
    hedging: bool = False                   # backup-batch straggler mitigation
    hedge_trigger: float = 2.0              # x expected latency
    fault_times: Dict[int, float] = field(default_factory=dict)
    drop_infeasible: bool = True
    continuous_batching: bool = False       # in-flight joins (paper §5)
    max_join_window: float = 0.25           # cap (s) on batch-forming time
    predictive_joins: bool = False          # forecast-led windows at saturation
    forecast: Optional[ForecastConfig] = None   # None -> defaults
    seed: int = 0

    def engine_config(self) -> EngineConfig:
        return EngineConfig(actuation_delay=self.actuation_delay,
                            load_on_switch=self.load_on_switch, hw=self.hw,
                            drop_infeasible=self.drop_infeasible,
                            continuous_batching=self.continuous_batching,
                            max_join_window=self.max_join_window,
                            predictive_joins=self.predictive_joins,
                            forecast=self.forecast)


@dataclass
class SimResult:
    queries: List[Query]
    dispatches: List[DispatchRecord]
    duration: float
    n_joins: int = 0                        # queries joined in flight
    n_open_batches: int = 0                 # batches that opened a window
    n_predictive_windows: int = 0           # opened with no spare worker
    # residency accounting (serving/residency.py tracker counters)
    n_switches: int = 0                     # launches that changed subnet
    actuation_seconds: float = 0.0          # total switch cost paid

    @property
    def slo_attainment(self) -> float:
        return slo_attainment(self.queries)

    @property
    def mean_acc(self) -> float:
        return mean_serving_accuracy(self.queries)

    @property
    def latency_p50(self) -> float:
        return latency_percentiles(self.queries)[0]

    @property
    def latency_p99(self) -> float:
        return latency_percentiles(self.queries)[1]

    @property
    def records(self) -> List[CompletionRecord]:
        return completion_records(self.queries)

    def stats(self) -> Dict[str, float]:
        return summarize(self.queries, n_joins=self.n_joins,
                         n_switches=self.n_switches,
                         n_dispatches=len(self.dispatches),
                         actuation_seconds=self.actuation_seconds)

    def series(self, window: float = 1.0):
        """Per-window (t, qps, mean batch, mean acc) system dynamics."""
        if not self.queries:
            return np.zeros((0, 4))
        t_end = self.duration
        edges = np.arange(0.0, t_end + window, window)
        arr = np.array([q.arrival for q in self.queries])
        qps, _ = np.histogram(arr, edges)
        rows = []
        for i in range(len(edges) - 1):
            lo, hi = edges[i], edges[i + 1]
            ds = [d for d in self.dispatches if lo <= d.t < hi]
            rows.append((lo, qps[i] / window,
                         float(np.mean([d.batch for d in ds])) if ds else 0.0,
                         float(np.mean([d.acc for d in ds])) if ds else 0.0))
        return np.asarray(rows)


def simulate(arrivals: Sequence[float], profile: LatencyProfile,
             policy: Policy, cfg: SimConfig) -> SimResult:
    rng = np.random.default_rng(cfg.seed)

    queries = [Query(deadline=float(t) + cfg.slo, seq=i, arrival=float(t), qid=i)
               for i, t in enumerate(arrivals)]
    duration = (float(arrivals[-1]) if len(arrivals) else 0.0) + 4 * cfg.slo

    engine = SchedulingEngine(profile, policy, cfg.engine_config(),
                              worker_ids=range(cfg.n_workers))

    def service(d: Dispatch, now: float, idle: List[int], push) -> float:
        """Simulation-owned service model: the engine's expectation,
        perturbed by stragglers, mitigated by backup-batch hedging."""
        lat = d.service
        if cfg.straggler_prob and rng.random() < cfg.straggler_prob:
            lat *= cfg.straggler_factor
            if cfg.hedging and idle:
                # backup batch on a spare worker after the trigger
                bwid = idle.pop(0)
                engine.hold(bwid)       # busy for the spare-capacity gate
                backup_fin = now + cfg.hedge_trigger * d.service + d.service
                lat = min(lat, backup_fin - now)
                push(backup_fin, EV_FREE, bwid)
        return lat

    drive(engine, queries, range(cfg.n_workers),
          fault_times=cfg.fault_times, service_fn=service)

    return SimResult(queries=queries, dispatches=engine.dispatches,
                     duration=duration, n_joins=engine.n_joins,
                     n_open_batches=engine.n_open_batches,
                     n_predictive_windows=engine.n_predictive_windows,
                     n_switches=engine.residency.n_switches,
                     actuation_seconds=engine.residency.actuation_seconds)


# --------------------------------------------------------------------------
# Cluster simulation (N replica groups behind one coordinator)
# --------------------------------------------------------------------------


@dataclass
class ClusterConfig:
    """Knobs for the multi-replica plane; per-replica scheduling knobs
    mirror ``SimConfig`` (stragglers/hedging stay single-replica sim
    features for now — the cluster service model is the engine's)."""

    n_replicas: int = 2
    # int (homogeneous) or per-replica sequence (heterogeneous pools)
    workers_per_replica: object = 4
    placement: str = "round_robin"
    placement_seed: int = 0
    slo: float = 0.036
    actuation_delay: float = SUBNETACT_ACTUATION_S
    load_on_switch: bool = False
    hw: HardwareProfile = RTX2080TI
    drop_infeasible: bool = True
    continuous_batching: bool = False
    max_join_window: float = 0.25
    predictive_joins: bool = False          # forecast-led windows at saturation
    # shared ForecastConfig: engine-level (predictive joins) AND
    # coordinator-level (predictive scaling / introspection). None ->
    # engine defaults; the coordinator forecaster then exists only when
    # the scaling policy is forecast-led (coordinator_forecast rule)
    forecast: Optional[ForecastConfig] = None
    # fault injection: whole replicas and/or single workers
    replica_deaths: Dict[int, float] = field(default_factory=dict)
    fault_times: Dict[Tuple[int, int], float] = field(default_factory=dict)
    # reactive replica autoscaling (serving/autoscaler.py); None keeps
    # the replica count static (byte-identical to the pre-autoscaler
    # cluster plane — guarded in tests/test_autoscaler.py)
    autoscale: Optional[AutoscaleConfig] = None

    def engine_config(self) -> EngineConfig:
        return EngineConfig(actuation_delay=self.actuation_delay,
                            load_on_switch=self.load_on_switch, hw=self.hw,
                            drop_infeasible=self.drop_infeasible,
                            continuous_batching=self.continuous_batching,
                            max_join_window=self.max_join_window,
                            predictive_joins=self.predictive_joins,
                            forecast=self.forecast)


@dataclass
class ClusterResult:
    queries: List[Query]                    # master list, cluster order
    dispatches: List[DispatchRecord]        # all replicas, time order
    duration: float
    n_replicas: int                         # replicas that ever existed
    n_joins: int = 0
    n_predictive_windows: int = 0           # windows opened with no spare
    # residency accounting, aggregated across every replica's tracker
    n_switches: int = 0
    actuation_seconds: float = 0.0
    # autoscaling accounting: per-replica active seconds (static runs
    # bill every replica for the whole duration) + the scale-event log
    replica_spans: Dict[int, float] = field(default_factory=dict)
    scale_events: List[ScaleEvent] = field(default_factory=list)
    # coordinator forecast snapshot at the end of the run (None when no
    # coordinator forecaster was configured)
    forecast: Optional[Dict[str, float]] = None

    @property
    def replica_seconds(self) -> float:
        """Total provisioned capacity-time — the denominator of the
        goodput-per-replica-second efficiency figure."""
        return sum(self.replica_spans.values())

    @property
    def slo_attainment(self) -> float:
        return slo_attainment(self.queries)

    @property
    def mean_acc(self) -> float:
        return mean_serving_accuracy(self.queries)

    @property
    def latency_p50(self) -> float:
        return latency_percentiles(self.queries)[0]

    @property
    def latency_p99(self) -> float:
        return latency_percentiles(self.queries)[1]

    @property
    def records(self) -> List[CompletionRecord]:
        return completion_records(self.queries)

    def stats(self) -> Dict[str, float]:
        return cluster_summarize(self.queries, n_replicas=self.n_replicas,
                                 n_joins=self.n_joins,
                                 replica_spans=self.replica_spans,
                                 n_switches=self.n_switches,
                                 n_dispatches=len(self.dispatches),
                                 actuation_seconds=self.actuation_seconds)


def simulate_cluster(arrivals: Sequence[float], profile: LatencyProfile,
                     policy: Policy, ccfg: ClusterConfig) -> ClusterResult:
    """Virtual-clock cluster simulation: one coordinator, N per-replica
    engines (the prototype ``policy`` is cloned per replica), a single
    shared event heap. A 1-replica cluster replays ``simulate``'s
    schedule record-for-record (guarded by tests/test_cluster.py).

    With ``ccfg.autoscale``, a ``ClusterAutoscaler`` runs its control
    loop on the same heap: spawned replicas get ``spawn_workers``
    workers (default: the static per-replica count) after paying the
    cold start; decommissions re-route the victim's queue through
    placement while its in-flight batches drain."""
    queries = [Query(deadline=float(t) + ccfg.slo, seq=i,
                     arrival=float(t), qid=i)
               for i, t in enumerate(arrivals)]
    # max(), not arrivals[-1]: arrivals need not be pre-sorted, and the
    # router parity path bills replica spans to this same horizon
    duration = (float(max(arrivals)) if len(arrivals) else 0.0) + 4 * ccfg.slo

    counts = replica_worker_counts(ccfg.n_replicas, ccfg.workers_per_replica)
    engines = build_engines(profile, policy, ccfg.n_replicas, counts,
                            ccfg.engine_config())
    coord = ClusterCoordinator(engines, make_placement(ccfg.placement),
                               placement_seed=ccfg.placement_seed,
                               forecast=coordinator_forecast(ccfg.autoscale,
                                                             ccfg.forecast))

    autoscaler = None
    if ccfg.autoscale is not None:
        acfg = ccfg.autoscale
        if ccfg.n_replicas > acfg.max_replicas:
            raise ValueError(
                f"{ccfg.n_replicas} initial replicas exceed "
                f"max_replicas={acfg.max_replicas}")
        if acfg.spawn_workers is None and len(set(counts)) > 1:
            raise ValueError(
                "heterogeneous worker pools need an explicit "
                "AutoscaleConfig.spawn_workers (no sane default size "
                "for spawned replicas)")
        spawn_workers = (acfg.spawn_workers if acfg.spawn_workers
                         else counts[0])
        ecfg = ccfg.engine_config()

        def engine_factory(rid: int) -> SchedulingEngine:
            return SchedulingEngine(profile, policy.clone(), ecfg,
                                    worker_ids=range(spawn_workers),
                                    replica_id=rid)

        autoscaler = ClusterAutoscaler(coord, acfg, engine_factory,
                                       slo=ccfg.slo)

    drive_cluster(coord, queries,
                  {rid: range(counts[rid])
                   for rid in range(ccfg.n_replicas)},
                  replica_deaths=ccfg.replica_deaths,
                  fault_times=ccfg.fault_times,
                  autoscaler=autoscaler)

    if autoscaler is not None:
        autoscaler.finalize(duration)
        spans = autoscaler.replica_spans()
        scale_events = list(autoscaler.events)
    else:
        spans = {rid: duration for rid in range(coord.n_replicas)}
        scale_events = []
    dispatches = sorted((d for e in coord.engines for d in e.dispatches),
                        key=lambda d: (d.t, d.replica, d.worker))
    return ClusterResult(queries=coord.queries, dispatches=dispatches,
                         duration=duration, n_replicas=coord.n_replicas,
                         n_joins=sum(e.n_joins for e in coord.engines),
                         n_predictive_windows=sum(e.n_predictive_windows
                                                  for e in coord.engines),
                         replica_spans=spans, scale_events=scale_events,
                         forecast=coord.forecast_snapshot(duration),
                         n_switches=sum(e.residency.n_switches
                                        for e in coord.engines),
                         actuation_seconds=sum(e.residency.actuation_seconds
                                               for e in coord.engines))
