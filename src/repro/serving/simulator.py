"""Deterministic discrete-event simulator of the SuperServe router +
worker pool (paper §5 architecture, §6 experiments).

Models: global EDF queue, policy invocation on worker-availability,
per-batch service latency from the profiler, SubNetAct actuation vs.
model-switch loading costs, worker faults with in-flight re-enqueue
(transparent fault tolerance, Fig 11a), stragglers with optional
backup-batch hedging, and full per-query accounting.
"""
from __future__ import annotations

import heapq
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.serving.metrics import mean_serving_accuracy, slo_attainment
from repro.serving.policies import Decision, Policy
from repro.serving.profiler import (SUBNETACT_ACTUATION_S, HardwareProfile,
                                    LatencyProfile, RTX2080TI, loading_latency)
from repro.serving.queue import EDFQueue, Query


@dataclass
class SimConfig:
    n_workers: int = 8
    slo: float = 0.036                      # paper's 36ms default
    actuation_delay: float = SUBNETACT_ACTUATION_S
    load_on_switch: bool = False            # pay weight-loading on model change
    hw: HardwareProfile = RTX2080TI
    straggler_prob: float = 0.0
    straggler_factor: float = 3.0
    hedging: bool = False                   # backup-batch straggler mitigation
    hedge_trigger: float = 2.0              # x expected latency
    fault_times: Dict[int, float] = field(default_factory=dict)
    drop_infeasible: bool = True
    seed: int = 0


@dataclass
class DispatchRecord:
    t: float
    worker: int
    batch: int
    pareto_idx: int
    acc: float
    latency: float
    queue_len: int


@dataclass
class SimResult:
    queries: List[Query]
    dispatches: List[DispatchRecord]
    duration: float

    @property
    def slo_attainment(self) -> float:
        return slo_attainment(self.queries)

    @property
    def mean_acc(self) -> float:
        return mean_serving_accuracy(self.queries)

    def series(self, window: float = 1.0):
        """Per-window (t, qps, mean batch, mean acc) system dynamics."""
        if not self.queries:
            return np.zeros((0, 4))
        t_end = self.duration
        edges = np.arange(0.0, t_end + window, window)
        arr = np.array([q.arrival for q in self.queries])
        qps, _ = np.histogram(arr, edges)
        rows = []
        for i in range(len(edges) - 1):
            lo, hi = edges[i], edges[i + 1]
            ds = [d for d in self.dispatches if lo <= d.t < hi]
            rows.append((lo, qps[i] / window,
                         float(np.mean([d.batch for d in ds])) if ds else 0.0,
                         float(np.mean([d.acc for d in ds])) if ds else 0.0))
        return np.asarray(rows)


# event kinds, ordered so simultaneous events process deterministically
_ARRIVAL, _FAULT, _FREE = 0, 1, 2


def simulate(arrivals: Sequence[float], profile: LatencyProfile,
             policy: Policy, cfg: SimConfig) -> SimResult:
    rng = np.random.default_rng(cfg.seed)
    policy.reset()

    queries = [Query(deadline=float(t) + cfg.slo, seq=i, arrival=float(t), qid=i)
               for i, t in enumerate(arrivals)]
    duration = (float(arrivals[-1]) if len(arrivals) else 0.0) + 4 * cfg.slo

    events: List[Tuple[float, int, int]] = []
    for q in queries:
        heapq.heappush(events, (q.arrival, _ARRIVAL, q.qid))
    for wid, t in cfg.fault_times.items():
        heapq.heappush(events, (float(t), _FAULT, wid))

    edf = EDFQueue()
    idle: List[int] = list(range(cfg.n_workers))
    dead: set = set()
    worker_model: Dict[int, Optional[int]] = {w: None for w in idle}
    inflight: Dict[int, Tuple[float, List[Query]]] = {}
    dispatches: List[DispatchRecord] = []
    min_service = float(profile.lat.min())

    def dispatch(now: float) -> None:
        while idle and len(edf):
            if cfg.drop_infeasible:
                edf.drop_expired(now, min_service)
            if not len(edf):
                return
            slack = edf.head_slack(now)
            dec: Optional[Decision] = policy.choose(profile, slack, len(edf))
            if dec is None:
                return
            wid = idle.pop(0)
            batch = edf.pop_batch(dec.batch_size)
            eff_b = len(batch)
            lat = profile.latency(dec.pareto_idx, eff_b)
            # actuation: SubNetAct control-swap vs model-switch loading
            if worker_model[wid] != dec.pareto_idx:
                lat += cfg.actuation_delay
                if cfg.load_on_switch:
                    wb = (profile.points[dec.pareto_idx].weight_mb * 2**20
                          if profile.points else 100e6)
                    lat += loading_latency(cfg.hw, wb)
                worker_model[wid] = dec.pareto_idx
            expected = lat
            if cfg.straggler_prob and rng.random() < cfg.straggler_prob:
                lat *= cfg.straggler_factor
                if cfg.hedging and idle:
                    # backup batch on a spare worker after the trigger
                    bwid = idle.pop(0)
                    backup_fin = now + cfg.hedge_trigger * expected + expected
                    lat = min(lat, backup_fin - now)
                    inflight[bwid] = (backup_fin, [])
                    heapq.heappush(events, (backup_fin, _FREE, bwid))
            fin = now + lat
            acc = float(profile.accs[dec.pareto_idx])
            for q in batch:
                q.finish = fin
                q.served_acc = acc
            inflight[wid] = (fin, batch)
            dispatches.append(DispatchRecord(now, wid, eff_b, dec.pareto_idx,
                                             acc, lat, len(edf)))
            heapq.heappush(events, (fin, _FREE, wid))

    while events:
        now, kind, ident = heapq.heappop(events)
        if kind == _ARRIVAL:
            edf.push(queries[ident])
            dispatch(now)
        elif kind == _FREE:
            if ident in dead:
                continue
            inflight.pop(ident, None)
            idle.append(ident)
            dispatch(now)
        elif kind == _FAULT:
            dead.add(ident)
            if ident in idle:
                idle.remove(ident)
            # transparent fault tolerance: re-enqueue the in-flight batch
            if ident in inflight:
                _, batch = inflight.pop(ident)
                for q in batch:
                    q.finish = None
                    q.served_acc = None
                    edf.push(q)
            dispatch(now)

    return SimResult(queries=queries, dispatches=dispatches, duration=duration)
