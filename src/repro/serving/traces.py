"""Trace generators (paper §6.1): bursty (gamma inter-arrivals on top of
a steady base), time-varying (mean ingest accelerating lambda1 ->
lambda2 at tau q/s^2), and an MAF-like workload (superposition of many
periodic/bursty per-function streams, shape-preserving shrink of the
Microsoft Azure Functions trace). All seeded/deterministic.
"""
from __future__ import annotations

from typing import Tuple

import numpy as np


def _gamma_interarrivals(rng, rate: float, cv2: float, t_end: float) -> np.ndarray:
    """Arrival times in [0, t_end) with gamma inter-arrivals of mean
    1/rate and squared coefficient of variation cv2 (cv2=0 -> uniform,
    cv2=1 -> Poisson)."""
    if rate <= 0:
        return np.empty(0)
    n_est = int(rate * t_end * 1.5) + 64
    if cv2 <= 1e-9:
        return np.arange(0, t_end, 1.0 / rate)
    shape = 1.0 / cv2
    scale = cv2 / rate
    gaps = rng.gamma(shape, scale, size=n_est)
    t = np.cumsum(gaps)
    while t[-1] < t_end:
        more = np.cumsum(rng.gamma(shape, scale, size=n_est)) + t[-1]
        t = np.concatenate([t, more])
    return t[t < t_end]


def bursty_trace(lambda_b: float, lambda_v: float, cv2: float,
                 duration: float, seed: int = 0) -> np.ndarray:
    """Base arrival at lambda_b (CV^2=0) + variant arrival at lambda_v
    with gamma inter-arrivals (paper Fig 12a construction)."""
    rng = np.random.default_rng(seed)
    base = _gamma_interarrivals(rng, lambda_b, 0.0, duration)
    var = _gamma_interarrivals(rng, lambda_v, cv2, duration)
    return np.sort(np.concatenate([base, var]))


def time_varying_trace(lambda1: float, lambda2: float, tau: float,
                       cv2: float, duration: float, seed: int = 0) -> np.ndarray:
    """Mean ingest accelerates from lambda1 to lambda2 at tau q/s^2,
    then holds; jitter at CV^2 = cv2 throughout (paper §6.2.2)."""
    rng = np.random.default_rng(seed)
    shape = 1.0 / max(cv2, 1e-9)
    t, out = 0.0, []
    while t < duration:
        rate = min(lambda2, lambda1 + tau * t) if lambda2 >= lambda1 else \
            max(lambda2, lambda1 - tau * t)
        rate = max(rate, 1e-6)
        if cv2 <= 1e-9:
            gap = 1.0 / rate
        else:
            gap = rng.gamma(shape, (1.0 / shape) / rate)
        t += gap
        if t < duration:
            out.append(t)
    return np.asarray(out)


def maf_like_trace(mean_rate: float, duration: float, n_functions: int = 200,
                   seed: int = 0, peak_factor: float = 1.37) -> np.ndarray:
    """MAF-like workload (paper §6.3): a rate ENVELOPE built from many
    periodic per-function spike trains with heavy-tailed weights (the
    structure Shahrad et al. report), affinely normalized so the mean is
    ``mean_rate`` and the windowed peak ~ ``peak_factor * mean_rate`` —
    the paper's own shape-preserving shrink (their 6400-qps trace peaks
    at ~8750 ~= 1.37x); arrivals are Poisson within the envelope (the
    paper observes MAF is Poisson-like, CV^2 ~= 1)."""
    rng = np.random.default_rng(seed)
    dt = 0.1
    t_grid = np.arange(0.0, duration, dt)
    env = np.zeros_like(t_grid)
    for _ in range(n_functions):
        w = rng.pareto(1.5) + 0.1               # heavy-tailed function size
        period = rng.uniform(2.0, max(duration / 2, 4.0))
        phase = rng.uniform(0, period)
        width = rng.uniform(0.2, 1.5)           # short invocation bursts
        env += w * (((t_grid - phase) % period) < width)
    # slow diurnal-like modulation underneath
    env += env.mean() * (1.0 + 0.3 * np.sin(2 * np.pi * t_grid / duration))
    # affine normalize: mean -> mean_rate, max -> peak_factor * mean_rate
    a = mean_rate * (peak_factor - 1.0) / max(env.max() - env.mean(), 1e-9)
    b = mean_rate - a * env.mean()
    rate = np.maximum(a * env + b, 0.25 * mean_rate)
    counts = rng.poisson(rate * dt)
    arrivals = np.concatenate([
        t0 + rng.uniform(0, dt, size=c) for t0, c in zip(t_grid, counts) if c
    ]) if counts.sum() else np.empty(0)
    return np.sort(arrivals)


def trace_stats(arrivals: np.ndarray, window: float = 1.0) -> Tuple[float, float]:
    """(mean qps, CV^2 of inter-arrivals)."""
    if len(arrivals) < 2:
        return 0.0, 0.0
    gaps = np.diff(arrivals)
    mean_rate = len(arrivals) / (arrivals[-1] - arrivals[0] + 1e-9)
    cv2 = float(np.var(gaps) / (np.mean(gaps) ** 2 + 1e-12))
    return float(mean_rate), cv2
