"""Training substrate: AdamW (+ZeRO sharded states), sandwich-rule
supernet training, synthetic data, atomic sharded checkpoints with
cross-mesh restore, int8-compressed gradient all-reduce."""
