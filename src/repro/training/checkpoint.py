"""Atomic sharded checkpointing with cross-mesh (elastic) restore.

Layout:  <dir>/step_<N>/
            manifest.json       tree structure, shapes, dtypes, checksums
            <leaf-id>.npy       one file per leaf (host-gathered)
         <dir>/LATEST           points at the last *complete* step

Write protocol: write into ``step_<N>.tmp``, fsync files, then a single
atomic rename + LATEST update — a trainer killed mid-write can never
leave a half checkpoint that restore would accept (manifest checksums
re-verify every leaf). Restore takes a ShardingPlan and device_puts
each leaf with the *new* plan's shardings, so a checkpoint written on
mesh A restores onto mesh B (elastic scaling / shrink-after-failure).
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import tempfile
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np


def _leaf_files(tree) -> Dict[str, Any]:
    flat = jax.tree_util.tree_leaves_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = jax.tree_util.keystr(path).strip("[]'").replace("']['", "/") \
            .replace("'][", "/").replace("]['", "/").replace("][", "/")
        key = key.replace("[", "").replace("]", "").replace("'", "")
        out[key.replace("/", "__") or "leaf"] = leaf
    return out


def save(dirpath: str, step: int, tree: Any, extra: Optional[Dict] = None) -> str:
    """Atomic save. Returns the final checkpoint path."""
    os.makedirs(dirpath, exist_ok=True)
    final = os.path.join(dirpath, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)

    leaves = _leaf_files(tree)
    manifest = {"step": step, "extra": extra or {}, "leaves": {}}
    for name, leaf in leaves.items():
        arr = np.asarray(jax.device_get(leaf))
        fp = os.path.join(tmp, name + ".npy")
        np.save(fp, arr)
        with open(fp, "rb") as f:
            digest = hashlib.sha256(f.read()).hexdigest()
        manifest["leaves"][name] = {
            "shape": list(arr.shape), "dtype": str(arr.dtype), "sha256": digest}
    mf = os.path.join(tmp, "manifest.json")
    with open(mf, "w") as f:
        json.dump(manifest, f, indent=1)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)                      # atomic publish
    latest = os.path.join(dirpath, "LATEST")
    with open(latest + ".tmp", "w") as f:
        f.write(os.path.basename(final))
    os.replace(latest + ".tmp", latest)
    return final


def latest_step(dirpath: str) -> Optional[int]:
    latest = os.path.join(dirpath, "LATEST")
    if not os.path.exists(latest):
        return None
    with open(latest) as f:
        name = f.read().strip()
    path = os.path.join(dirpath, name)
    return int(name.split("_")[1]) if os.path.isdir(path) else None


def restore(dirpath: str, template: Any, *, step: Optional[int] = None,
            shardings: Any = None, verify: bool = True) -> Tuple[Any, Dict]:
    """Restore into the structure of ``template``; device_put with
    ``shardings`` (same tree structure) when given — this is the
    cross-mesh elastic restore path."""
    if step is None:
        step = latest_step(dirpath)
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {dirpath}")
    path = os.path.join(dirpath, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)

    leaves = _leaf_files(template)
    sh_leaves = _leaf_files(shardings) if shardings is not None else {}
    out = {}
    for name, leaf in leaves.items():
        meta = manifest["leaves"][name]
        fp = os.path.join(path, name + ".npy")
        if verify:
            with open(fp, "rb") as f:
                if hashlib.sha256(f.read()).hexdigest() != meta["sha256"]:
                    raise IOError(f"checksum mismatch for {name} in {path}")
        arr = np.load(fp)
        if list(arr.shape) != list(np.shape(leaf)):
            raise ValueError(f"shape mismatch for {name}: "
                             f"{arr.shape} vs {np.shape(leaf)}")
        if name in sh_leaves:
            out[name] = jax.device_put(arr, sh_leaves[name])
        else:
            out[name] = jax.device_put(arr)

    flat, tdef = jax.tree_util.tree_flatten(template)
    names = list(_leaf_files(template).keys())
    restored = tdef.unflatten([out[n] for n in names])
    return restored, manifest["extra"]


def prune(dirpath: str, keep: int = 3) -> None:
    """Garbage-collect old checkpoints, never the newest ``keep``."""
    steps = sorted(d for d in os.listdir(dirpath)
                   if d.startswith("step_") and not d.endswith(".tmp"))
    for d in steps[:-keep]:
        shutil.rmtree(os.path.join(dirpath, d))
