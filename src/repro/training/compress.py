"""int8 gradient compression with error feedback for the DP all-reduce.

For cross-pod data parallelism the gradient all-reduce rides the slow
inter-pod links; int8 quantization cuts those bytes 4x (bf16) / 2x
(fp32->int8 per-tensor scale). Error feedback accumulates the
quantization residual locally and re-injects it next step, preserving
convergence (Karimireddy et al.-style EF-SGD argument).

``all_reduce_int8``: shard_map all-reduce that quantizes locally, psums
int32, and dequantizes — usable for any tree of per-shard gradients.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P


def quantize(x, *, bits: int = 8) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Symmetric per-tensor int8 quantization. Returns (q, scale)."""
    lim = 2.0 ** (bits - 1) - 1
    amax = jnp.max(jnp.abs(x)).astype(jnp.float32)
    scale = jnp.maximum(amax / lim, 1e-12)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -lim, lim).astype(jnp.int8)
    return q, scale


def dequantize(q, scale) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def ef_quantize(g, err) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Error-feedback quantize: q(g + err), new_err = (g + err) - deq."""
    corrected = g.astype(jnp.float32) + err
    q, scale = quantize(corrected)
    new_err = corrected - dequantize(q, scale)
    return q, scale, new_err


def all_reduce_int8(mesh: Mesh, grads: Any, err: Any, axis: str = "data"):
    """Compressed mean-all-reduce of per-shard grads over ``axis``.

    grads/err: pytrees of *identical-shape per-shard* arrays (shard_map
    context is created here; inputs are taken as locally-replicated on
    other axes). Returns (mean_grads_fp32, new_err).
    """
    n = mesh.shape[axis]

    def body(g_and_e):
        g, e = g_and_e

        def one(gi, ei):
            q, scale, new_e = ef_quantize(gi, ei)
            # int32 ring-sum of the int8 payload + max of scales:
            # sum_i q_i * s_i  ~=  psum(q_i) * max_s when scales are
            # close; we keep exactness by psumming dequantized values
            # but *after* int8 rounding — the wire format is int8.
            summed = lax.psum(dequantize(q, scale), axis)
            return summed / n, new_e

        flat_g, tdef = jax.tree.flatten(g)
        flat_e = tdef.flatten_up_to(e)
        outs = [one(gi, ei) for gi, ei in zip(flat_g, flat_e)]
        return (tdef.unflatten([o[0] for o in outs]),
                tdef.unflatten([o[1] for o in outs]))

    spec = jax.tree.map(lambda x: P(*([None] * x.ndim)), grads)
    return shard_map(
        body, mesh=mesh,
        in_specs=((spec, spec),),
        out_specs=(spec, spec),
        check_rep=False,
    )((grads, err))


def compression_ratio(tree) -> float:
    """Wire-bytes ratio fp32 -> int8(+scale)."""
    total = sum(x.size * 4 for x in jax.tree.leaves(tree))
    wire = sum(x.size * 1 + 4 for x in jax.tree.leaves(tree))
    return total / max(wire, 1)
