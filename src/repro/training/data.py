"""Synthetic-but-learnable data pipeline.

Deterministic, seeded, stateless-by-step (batch i is a pure function of
(seed, i)) — so a restarted/rescheduled trainer resumes mid-epoch with
no data-state checkpointing, and any host can produce any shard
(straggler work-stealing at the input layer).

The task: order-k modular language. Token t+1 = (a1*t1 + ... + ak*tk +
b) mod V with a small noise rate. A transformer learns it quickly, so
training curves actually go down — used by the examples and the
end-to-end training test.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterator, Tuple

import numpy as np


@dataclass(frozen=True)
class SyntheticTask:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    order: int = 3
    noise: float = 0.02

    def _coeffs(self) -> np.ndarray:
        rng = np.random.default_rng(self.seed + 17)
        return rng.integers(1, self.vocab_size, size=self.order + 1)

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        """Batch ``step`` — pure function of (seed, step)."""
        rng = np.random.default_rng((self.seed, step))
        V, S, B = self.vocab_size, self.seq_len, self.global_batch
        a = self._coeffs()
        toks = np.zeros((B, S + 1), np.int64)
        toks[:, : self.order] = rng.integers(0, V, size=(B, self.order))
        for t in range(self.order, S + 1):
            nxt = a[-1]
            for j in range(self.order):
                nxt = nxt + a[j] * toks[:, t - 1 - j]
            toks[:, t] = nxt % V
        flip = rng.random((B, S + 1)) < self.noise
        toks = np.where(flip, rng.integers(0, V, size=(B, S + 1)), toks)
        return {
            "tokens": toks[:, :-1].astype(np.int32),
            "labels": toks[:, 1:].astype(np.int32),
        }

    def iterator(self, start_step: int = 0) -> Iterator[Dict[str, np.ndarray]]:
        step = start_step
        while True:
            yield self.batch(step)
            step += 1


def embed_batch(task: SyntheticTask, step: int, d_model: int) -> Dict[str, np.ndarray]:
    """For frontend='embed' archs: tokens -> fixed random embeddings
    (the stubbed modality frontend)."""
    b = task.batch(step)
    rng = np.random.default_rng(task.seed + 99)
    table = rng.standard_normal((task.vocab_size, d_model)).astype(np.float32)
    return {"embeds": table[b["tokens"]], "labels": b["labels"]}
