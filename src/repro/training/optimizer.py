"""AdamW with optional ZeRO-1 state sharding.

Pure-function optimizer (no framework): ``init`` -> state pytree,
``apply`` -> (new_params, new_state). ZeRO-1: the fp32 moments are
sharded over the DP axes (state_shardings) while params stay on their
TP layout — XLA inserts the gather/scatter around the update, which the
latency-hiding scheduler overlaps with the next step's compute.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.distributed.sharding import ShardingPlan


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


def schedule(cfg: AdamWConfig, step):
    """Linear warmup + cosine decay."""
    step = jnp.asarray(step, jnp.float32)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    t = (step - cfg.warmup_steps) / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1)
    t = jnp.clip(t, 0.0, 1.0)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t))
    return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, cos)


def init(params) -> Dict[str, Any]:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return {"m": zeros, "v": jax.tree.map(jnp.copy, zeros),
            "step": jnp.zeros((), jnp.int32)}


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def apply(cfg: AdamWConfig, params, grads, state):
    """One AdamW update (with clipping + decoupled weight decay)."""
    step = state["step"] + 1
    lr = schedule(cfg, step)
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gn, 1e-9))

    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        mh = m / b1c
        vh = v / b2c
        step_dir = mh / (jnp.sqrt(vh) + cfg.eps)
        decay = cfg.weight_decay * p.astype(jnp.float32) if p.ndim >= 2 else 0.0
        new_p = p.astype(jnp.float32) - lr * (step_dir + decay)
        return new_p.astype(p.dtype), m, v

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(state["m"])
    flat_v = tdef.flatten_up_to(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_params = tdef.unflatten([o[0] for o in out])
    new_state = {"m": tdef.unflatten([o[1] for o in out]),
                 "v": tdef.unflatten([o[2] for o in out]),
                 "step": step}
    return new_params, new_state, {"grad_norm": gn, "lr": lr}


def state_shardings(plan: ShardingPlan, params) -> Dict[str, Any]:
    """ZeRO-1: moments sharded over DP on the first axis that divides;
    falls back to the param's own TP spec."""
    dp = plan.dp_axes
    dpn = plan.dp_size

    def one(path, leaf):
        shape = leaf.shape
        for i, s in enumerate(shape):
            if s % max(dpn, 1) == 0 and s >= dpn:
                spec = [None] * len(shape)
                spec[i] = dp
                return NamedSharding(plan.mesh, P(*spec))
        return NamedSharding(plan.mesh, P(*([None] * len(shape))))

    moments = jax.tree_util.tree_map_with_path(one, params)
    return {"m": moments, "v": jax.tree.map(lambda s: s, moments),
            "step": NamedSharding(plan.mesh, P())}
