"""Sandwich-rule supernet training (OFA/BigNAS style — the substrate
the paper assumes: one weight-shared supernet whose every subnet is
servable).

Each step accumulates gradients from (a) the max subnet, (b) the min
subnet, and (c) ``n_random`` sampled subnets — control tuples are
sampled *inside* jit (core.subnet.sample_control_jax), so one compiled
step trains the entire architecture space. The per-subnet SubnetNorm
gamma rows receive gradients only from their own subnet (the gather in
subnet_norm routes them), which is exactly the paper's 'non-shared
bookkeeping trained per subnet'.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.core import subnet as sn
from repro.models import lm


def make_controls(cfg: ArchConfig):
    """Static (max, min) control tuples as jnp trees."""
    cmax = {k: jnp.asarray(v) for k, v in sn.make_control(cfg, sn.max_subnet(cfg)).items()}
    cmin = {k: jnp.asarray(v) for k, v in sn.make_control(cfg, sn.min_subnet(cfg)).items()}
    return cmax, cmin


def sandwich_loss(params, cfg: ArchConfig, batch, rng, *, n_random: int = 1,
                  slice_mode: str = "mask", remat: bool = False,
                  moe_groups: int = 1):
    """Mean loss over {max, min, n_random sampled} subnets."""
    cmax, cmin = make_controls(cfg)
    losses = [
        lm.loss_fn(params, cfg, batch, cmax, slice_mode=slice_mode,
                   remat=remat, moe_groups=moe_groups),
        lm.loss_fn(params, cfg, batch, cmin, slice_mode=slice_mode,
                   remat=remat, moe_groups=moe_groups),
    ]
    keys = jax.random.split(rng, max(n_random, 1))
    for i in range(n_random):
        ctrl = sn.sample_control_jax(cfg, keys[i])
        losses.append(lm.loss_fn(params, cfg, batch, ctrl, slice_mode=slice_mode,
                                 remat=remat, moe_groups=moe_groups))
    return sum(losses) / len(losses)


def make_train_step(cfg: ArchConfig, opt_cfg, *, n_random: int = 1,
                    slice_mode: str = "mask", remat: bool = False,
                    moe_groups: int = 1, microbatch: int = 0):
    """Returns ``step(params, opt_state, batch, rng) -> (params, state,
    metrics)``. ``microbatch``: gradient-accumulation chunks along batch
    dim (0 = off)."""
    from repro.training import optimizer as opt

    def loss_fn(p, batch, rng):
        return sandwich_loss(p, cfg, batch, rng, n_random=n_random,
                             slice_mode=slice_mode, remat=remat,
                             moe_groups=moe_groups)

    def step(params, opt_state, batch, rng):
        if microbatch:
            n = microbatch

            def split(x):
                return x.reshape((n, x.shape[0] // n) + x.shape[1:])

            mb = jax.tree.map(split, batch)

            def acc_fn(carry, mb_i):
                loss_acc, grad_acc = carry
                l, g = jax.value_and_grad(loss_fn)(params, mb_i, rng)
                return (loss_acc + l / n,
                        jax.tree.map(lambda a, b: a + b / n, grad_acc, g)), None

            zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (loss, grads), _ = jax.lax.scan(acc_fn, (0.0, zeros), mb)
        else:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch, rng)
        params2, opt_state2, m = opt.apply(opt_cfg, params, grads, opt_state)
        m["loss"] = loss
        return params2, opt_state2, m

    return step
