"""Trainer driver: periodic atomic checkpoints, crash/preemption
restart from the latest valid step, straggler detection via per-step
time outliers, and elastic restore onto a different mesh.

Designed so the *loop* is restartable at any instant:
  * data is stateless-by-step (training/data.py),
  * checkpoints are atomic (training/checkpoint.py),
  * restore consumes a ShardingPlan, so the surviving mesh after a
    failure can differ from the one that wrote the checkpoint.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.training import checkpoint as ckpt
from repro.training import data as data_mod
from repro.training import optimizer as opt
from repro.training import supernet


@dataclass
class TrainerConfig:
    total_steps: int = 100
    ckpt_every: int = 20
    ckpt_dir: str = "/tmp/repro_ckpt"
    keep: int = 3
    straggler_factor: float = 3.0      # step > factor * median -> flagged
    log_every: int = 10


@dataclass
class TrainerState:
    params: Any
    opt_state: Any
    step: int = 0
    straggler_steps: List[int] = field(default_factory=list)
    losses: List[float] = field(default_factory=list)


class Trainer:
    def __init__(self, cfg, opt_cfg: opt.AdamWConfig, tcfg: TrainerConfig,
                 task: data_mod.SyntheticTask, *, n_random: int = 1,
                 step_fn: Optional[Callable] = None, plan=None):
        self.cfg = cfg
        self.opt_cfg = opt_cfg
        self.tcfg = tcfg
        self.task = task
        self.plan = plan
        self.step_fn = step_fn or jax.jit(
            supernet.make_train_step(cfg, opt_cfg, n_random=n_random))

    # -- lifecycle -----------------------------------------------------
    def init_state(self, key) -> TrainerState:
        from repro.models import lm
        params = lm.init_model(key, self.cfg)
        return TrainerState(params=params, opt_state=opt.init(params))

    def resume_or_init(self, key) -> TrainerState:
        """Restart-from-failure entry point."""
        st = self.init_state(key)
        last = ckpt.latest_step(self.tcfg.ckpt_dir)
        if last is not None:
            shardings = None
            if self.plan is not None:
                shardings = {"params": self.plan.params(st.params),
                             "opt": opt.state_shardings(self.plan, st.params)}
            tree, extra = ckpt.restore(
                self.tcfg.ckpt_dir, {"params": st.params, "opt": st.opt_state},
                shardings=shardings)
            st.params, st.opt_state = tree["params"], tree["opt"]
            st.step = int(extra.get("step", last))
        return st

    # -- loop ----------------------------------------------------------
    def run(self, st: TrainerState, *, until: Optional[int] = None,
            crash_at: Optional[int] = None) -> TrainerState:
        """Run to ``until`` (or total_steps). ``crash_at`` simulates a
        hard failure (tests/examples) AFTER that step's compute, before
        its checkpoint."""
        until = until or self.tcfg.total_steps
        times: List[float] = []
        while st.step < until:
            batch = {k: jnp.asarray(v) for k, v in self.task.batch(st.step).items()}
            t0 = time.perf_counter()
            st.params, st.opt_state, metrics = self.step_fn(
                st.params, st.opt_state, batch, jax.random.PRNGKey(st.step))
            jax.block_until_ready(metrics["loss"])
            dt = time.perf_counter() - t0
            st.step += 1
            st.losses.append(float(metrics["loss"]))
            # straggler detection: compare against the running median
            times.append(dt)
            if len(times) >= 8:
                med = float(np.median(times[-32:]))
                if dt > self.tcfg.straggler_factor * med:
                    st.straggler_steps.append(st.step)
            if crash_at is not None and st.step == crash_at:
                raise RuntimeError(f"simulated node failure at step {st.step}")
            if st.step % self.tcfg.ckpt_every == 0 or st.step == until:
                ckpt.save(self.tcfg.ckpt_dir, st.step,
                          {"params": st.params, "opt": st.opt_state},
                          extra={"step": st.step})
                ckpt.prune(self.tcfg.ckpt_dir, keep=self.tcfg.keep)
        return st
