"""Property-testing shim: real ``hypothesis`` when installed, otherwise
a minimal deterministic fallback.

The tier-1 suite must collect and pass on bare hosts (the CI container
has no hypothesis wheel). The fallback implements the exact strategy
surface the tests use — ``floats, integers, lists, tuples,
sampled_from`` plus ``given``/``settings`` — drawing seeded
pseudo-random examples (no shrinking, no edge-case database). Each test
gets a stable per-test seed, so failures reproduce run-to-run.

Install the real thing with ``pip install -r requirements-dev.txt`` to
get shrinking and adversarial example generation.
"""
from __future__ import annotations

import os

# REPRO_MAX_EXAMPLES caps every property test's example count (both
# branches below honor it). Set by tools/serving_coverage.py: line
# coverage doesn't need 200 repetitions of the same lines, and the
# stdlib tracer makes each one ~40x slower. Unset in tier-1 CI.
_EXAMPLE_CAP = int(os.environ.get("REPRO_MAX_EXAMPLES", "0"))

try:
    from hypothesis import given  # noqa: F401
    from hypothesis import settings as _hyp_settings
    from hypothesis import strategies  # noqa: F401
    HAVE_HYPOTHESIS = True

    def settings(*args, **kwargs):
        if _EXAMPLE_CAP and kwargs.get("max_examples"):
            kwargs["max_examples"] = min(kwargs["max_examples"],
                                         _EXAMPLE_CAP)
        return _hyp_settings(*args, **kwargs)
except ModuleNotFoundError:
    import functools
    import inspect
    import random
    import types

    HAVE_HYPOTHESIS = False
    _DEFAULT_MAX_EXAMPLES = 25

    class _Strategy:
        def __init__(self, draw):
            self.draw = draw          # rng -> value

    def _floats(min_value: float, max_value: float, **_) -> _Strategy:
        return _Strategy(lambda rng: rng.uniform(min_value, max_value))

    def _integers(min_value: int, max_value: int, **_) -> _Strategy:
        return _Strategy(lambda rng: rng.randint(min_value, max_value))

    def _sampled_from(elements) -> _Strategy:
        elements = list(elements)
        return _Strategy(lambda rng: rng.choice(elements))

    def _lists(elements: _Strategy, *, min_size: int = 0,
               max_size: int = 10, **_) -> _Strategy:
        def draw(rng):
            n = rng.randint(min_size, max_size)
            return [elements.draw(rng) for _ in range(n)]
        return _Strategy(draw)

    def _tuples(*elems: _Strategy) -> _Strategy:
        return _Strategy(lambda rng: tuple(e.draw(rng) for e in elems))

    strategies = types.SimpleNamespace(
        floats=_floats, integers=_integers, lists=_lists, tuples=_tuples,
        sampled_from=_sampled_from)

    def settings(max_examples: int = _DEFAULT_MAX_EXAMPLES, **_):
        if _EXAMPLE_CAP:
            max_examples = min(max_examples, _EXAMPLE_CAP)

        def deco(fn):
            fn._max_examples = max_examples
            return fn
        return deco

    def given(*arg_strats: _Strategy, **kw_strats: _Strategy):
        def deco(fn):
            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                n = getattr(wrapper, "_max_examples",
                            getattr(fn, "_max_examples",
                                    _DEFAULT_MAX_EXAMPLES))
                rng = random.Random(fn.__qualname__)   # stable per test
                for _ in range(n):
                    drawn = [s.draw(rng) for s in arg_strats]
                    drawn_kw = {k: s.draw(rng) for k, s in kw_strats.items()}
                    fn(*args, *drawn, **kwargs, **drawn_kw)

            # Hide the strategy-supplied parameters from pytest, which
            # would otherwise look for fixtures of the same names.
            sig = inspect.signature(fn)
            params = [p for p in sig.parameters.values()
                      if p.name not in kw_strats]
            if arg_strats:
                params = params[:-len(arg_strats)]
            wrapper.__signature__ = sig.replace(parameters=params)
            return wrapper
        return deco
