"""Shared fixtures. NOTE: no XLA_FLAGS here — unit/smoke tests must see
the real single CPU device; multi-device tests spawn subprocesses."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import ArchConfig, ElasticSpec, Stage


def tiny_dense(**kw) -> ArchConfig:
    base = dict(
        name="tiny-dense", family="dense",
        stages=(Stage(("attn", "mlp"), repeat=3),),
        d_model=64, n_heads=4, n_kv_heads=2, d_ff=256, vocab_size=128,
        head_dim=16, dtype="float32",
        elastic=ElasticSpec(depth_fracs=(1 / 3, 2 / 3, 1.0),
                            ffn_fracs=(0.5, 1.0), head_fracs=(0.5, 1.0)),
    )
    base.update(kw)
    return ArchConfig(**base)


@pytest.fixture(scope="session")
def dense_cfg():
    return tiny_dense()


@pytest.fixture(scope="session")
def dense_params(dense_cfg):
    from repro.models import lm
    return lm.init_model(jax.random.PRNGKey(0), dense_cfg)


@pytest.fixture(scope="session")
def token_batch():
    key = jax.random.PRNGKey(7)
    toks = jax.random.randint(key, (2, 16), 0, 128)
    return {"tokens": toks, "labels": jnp.roll(toks, -1, axis=1)}
