"""Autoscaler scaling invariants (hypothesis property tests + units).

The lifecycle properties the cluster plane must keep under ARBITRARY
spawn/decommission sequences (driven through the production actuation
path by the Scripted policy):

  * query conservation — every admitted query completes or is recorded
    as a drop, never lost, never duplicated;
  * replica bounds — the committed count stays within [min, max];
  * cooldown — every decommission trails the previous scale event by
    at least the cooldown;
  * EDF order — a decommissioned replica's drained queue re-routes
    most-urgent-first;
  * cold start — a spawned replica serves nothing before its READY;
  * disabled == static — an autoscaler that never acts replays the
    autoscaler-less cluster schedule byte-identically.
"""
import numpy as np
import pytest

from tests._hypothesis_compat import given, settings, strategies as st

from repro.configs import get_config
from repro.serving import cluster, policies, profiler, simulator, traces
from repro.serving.autoscaler import (SCALINGS, AutoscaleConfig,
                                      ClusterAutoscaler, Predictive,
                                      QueuePressure, Scripted,
                                      coordinator_forecast, make_scaling)
from repro.serving.engine import SchedulingEngine
from repro.serving.forecast import ForecastConfig
from repro.serving.queue import Query

PROF = profiler.build_profile(get_config("ofa_resnet"))
ARR = traces.bursty_trace(400, 1600, 4, 2.0, seed=23)

SCRIPT_EVENTS = st.lists(
    st.tuples(st.floats(0.0, 0.6), st.sampled_from([1, -1])),
    min_size=1, max_size=12)


def _sim(arr, init, acfg, **ccfg_kw):
    ccfg = simulator.ClusterConfig(
        n_replicas=init, workers_per_replica=2, placement="round_robin",
        slo=0.036, autoscale=acfg, **ccfg_kw)
    return simulator.simulate_cluster(arr, PROF, policies.SlackFit(), ccfg)


def _scripted(script, min_r=1, max_r=5, cold_start=0.02, cooldown=0.0,
              interval=0.01):
    return AutoscaleConfig(min_replicas=min_r, max_replicas=max_r,
                           policy="scripted", script=script,
                           cooldown=cooldown, cold_start=cold_start,
                           interval=interval)


class TestScalingInvariants:
    """The acceptance property: conservation + bounds + EDF drain order
    over 200+ generated scale-event sequences."""

    @given(st.integers(0, 10_000), SCRIPT_EVENTS, st.integers(1, 3))
    @settings(max_examples=200, deadline=None)
    def test_conservation_bounds_and_edf_order(self, seed, script, init):
        rng = np.random.default_rng(seed)
        arr = np.sort(rng.uniform(0, 0.5, size=int(rng.integers(1, 120))))
        res = _sim(arr, init, _scripted(script))

        # conservation: every query resolves exactly once, none lost
        assert len(res.queries) == len(arr)
        served = sum(1 for q in res.queries
                     if q.finish is not None and not q.dropped)
        dropped = sum(1 for q in res.queries if q.dropped)
        assert served + dropped == len(arr)
        # ... and none duplicated (one record per qid)
        qids = [r.qid for r in res.records]
        assert qids == sorted(set(qids)) and len(qids) == len(arr)

        # committed replica count within [min, max] after every
        # policy-driven lifecycle event
        for e in res.scale_events:
            if e.kind in ("spawn", "ready", "decommission"):
                assert 1 <= e.n_committed <= 5

        # decommission-drained queries keep EDF (deadline) order
        qmap = {q.qid: q for q in res.queries}
        for e in res.scale_events:
            if e.kind == "decommission":
                deadlines = [qmap[qid].deadline for qid in e.drained]
                assert deadlines == sorted(deadlines)

    @given(st.integers(0, 10_000), SCRIPT_EVENTS)
    @settings(max_examples=40, deadline=None)
    def test_conservation_with_continuous_batching(self, seed, script):
        """Scale events racing join windows still conserve queries."""
        rng = np.random.default_rng(seed)
        arr = np.sort(rng.uniform(0, 0.4, size=int(rng.integers(1, 100))))
        res = _sim(arr, 2, _scripted(script), continuous_batching=True)
        served = sum(1 for q in res.queries
                     if q.finish is not None and not q.dropped)
        dropped = sum(1 for q in res.queries if q.dropped)
        assert served + dropped == len(arr)

    @given(st.integers(0, 10_000))
    @settings(max_examples=25, deadline=None)
    def test_cooldown_respected(self, seed):
        """Reactive policy runs: every decommission trails the previous
        scale event (spawn or decommission) by >= cooldown."""
        rng = np.random.default_rng(seed)
        arr = np.sort(rng.uniform(0, 1.0, size=int(rng.integers(50, 400))))
        cooldown = 0.15
        acfg = AutoscaleConfig(min_replicas=1, max_replicas=5,
                               cooldown=cooldown, interval=0.01)
        res = _sim(arr, 2, acfg)
        prev = None
        for e in res.scale_events:
            if e.kind == "decommission":
                assert prev is None or e.t - prev >= cooldown - 1e-12
            if e.kind in ("spawn", "decommission"):
                prev = e.t

    @given(st.integers(0, 10_000))
    @settings(max_examples=25, deadline=None)
    def test_cold_start_gates_serving(self, seed):
        """A spawned replica dispatches nothing before its READY."""
        rng = np.random.default_rng(seed)
        arr = np.sort(rng.uniform(0, 0.5, size=int(rng.integers(20, 150))))
        res = _sim(arr, 1, _scripted([(0.1, 1), (0.2, 1)],
                                     cold_start=0.05))
        ready_at = {e.rid: e.t for e in res.scale_events
                    if e.kind == "ready"}
        for d in res.dispatches:
            if d.replica in ready_at:
                assert d.t >= ready_at[d.replica]


class TestScalingBounds:
    def test_spawns_clamped_at_max(self):
        res = _sim(ARR, 1, _scripted([(0.05 * i, 1) for i in range(12)],
                                     max_r=3))
        assert res.n_replicas <= 3
        assert max(e.n_committed for e in res.scale_events) == 3

    def test_decommissions_clamped_at_min(self):
        res = _sim(ARR, 3, _scripted([(0.05 * i, -1) for i in range(12)],
                                     min_r=2))
        decoms = [e for e in res.scale_events if e.kind == "decommission"]
        assert len(decoms) == 1                   # 3 -> 2, then clamped
        assert min(e.n_committed for e in res.scale_events) == 2

    def test_floor_is_topped_up_not_just_gated(self):
        """min_replicas is an invariant, not only a scale-down gate: a
        cluster started below the floor spawns up to it on the first
        tick, whatever the policy says."""
        quiet = traces.bursty_trace(50, 20, 1, 1.0, seed=3)
        res = _sim(quiet, 1, AutoscaleConfig(min_replicas=3,
                                             max_replicas=6))
        spawns = [e for e in res.scale_events if e.kind == "spawn"]
        assert len(spawns) >= 2                   # 1 -> 3 at least
        assert res.scale_events[-1].n_committed >= 3
        assert all(e.n_committed >= 1 for e in res.scale_events)

    def test_total_death_respawns_to_the_floor(self):
        """A cluster wiped out by deaths is topped back up to
        min_replicas: after the replacements' cold start, service
        resumes instead of dropping every remaining arrival."""
        rng = np.random.default_rng(0)
        arr = np.sort(rng.uniform(0, 1.0, size=200))
        res = _sim(arr, 1, AutoscaleConfig(min_replicas=1, max_replicas=4),
                   replica_deaths={0: 0.1})
        kinds = [e.kind for e in res.scale_events]
        assert "death" in kinds and "spawn" in kinds
        served = sum(1 for q in res.queries
                     if q.finish is not None and not q.dropped)
        dropped = sum(1 for q in res.queries if q.dropped)
        assert served + dropped == 200            # conserved
        # queries arriving after the replacement's cold start are served
        assert any(q.arrival > 0.3 and q.finish is not None
                   for q in res.queries)
        assert served > 100

    def test_initial_count_above_max_rejected(self):
        with pytest.raises(ValueError):
            _sim(ARR, 5, AutoscaleConfig(min_replicas=1, max_replicas=3))

    def test_config_validation(self):
        with pytest.raises(ValueError):
            AutoscaleConfig(min_replicas=0).validate()
        with pytest.raises(ValueError):
            AutoscaleConfig(min_replicas=3, max_replicas=2).validate()
        with pytest.raises(ValueError):
            AutoscaleConfig(interval=0.0).validate()
        with pytest.raises(ValueError):
            make_scaling(AutoscaleConfig(policy="nope"), slo=0.036)


class TestDisabledEquivalence:
    """The static-replica acceptance guarantee: an autoscaler that never
    acts replays the autoscaler-less (PR 3) schedule byte-identically,
    and autoscale=None is exactly the PR 3 code path."""

    def test_never_acting_autoscaler_is_byte_identical(self):
        base = _sim(ARR, 3, None)
        idle = _sim(ARR, 3, _scripted([], min_r=3, max_r=3))
        assert idle.records == base.records
        assert [(d.t, d.replica, d.worker, d.batch, d.pareto_idx)
                for d in idle.dispatches] == \
               [(d.t, d.replica, d.worker, d.batch, d.pareto_idx)
                for d in base.dispatches]
        assert idle.scale_events == []

    def test_min_equals_max_pins_reactive_policy(self):
        """min == max leaves the reactive policy no room: same
        schedule as no autoscaler at all."""
        base = _sim(ARR, 2, None)
        pinned = _sim(ARR, 2, AutoscaleConfig(min_replicas=2,
                                              max_replicas=2))
        assert pinned.records == base.records
        assert all(e.kind not in ("spawn", "decommission")
                   for e in pinned.scale_events)


class TestReactivePolicies:
    def test_queue_pressure_scales_up_out_of_overload(self):
        """Starting under-provisioned on a hot trace, queue_pressure
        spawns and beats the static under-provisioned cluster."""
        hot = traces.bursty_trace(1400, 5600, 8, 2.0, seed=7)
        static = _sim(hot, 1, None)
        auto = _sim(hot, 1, AutoscaleConfig(min_replicas=1, max_replicas=6))
        assert any(e.kind == "spawn" for e in auto.scale_events)
        assert auto.slo_attainment > static.slo_attainment + 0.2

    def test_queue_pressure_scales_down_when_idle(self):
        """A trace that goes quiet gets its reinforcements trimmed."""
        quiet = traces.bursty_trace(400, 100, 1, 3.0, seed=7)
        auto = _sim(quiet, 4, AutoscaleConfig(min_replicas=1,
                                              max_replicas=6))
        assert any(e.kind == "decommission" for e in auto.scale_events)
        assert auto.replica_seconds < 4 * auto.duration

    def test_slo_headroom_scales_up_on_misses(self):
        hot = traces.bursty_trace(1400, 5600, 8, 2.0, seed=7)
        auto = _sim(hot, 1, AutoscaleConfig(min_replicas=1, max_replicas=6,
                                            policy="slo_headroom",
                                            window=0.5))
        assert any(e.kind == "spawn" for e in auto.scale_events)

    def test_decommission_picks_least_loaded_highest_rid(self):
        """Victim selection: least outstanding work, ties to the
        highest (latest-spawned) rid."""
        engines = [SchedulingEngine(PROF, policies.SlackFit(),
                                    worker_ids=range(2), replica_id=rid)
                   for rid in range(3)]
        for i in range(5):
            engines[0].admit(Query(deadline=1.0, seq=0, qid=i))
        coord = cluster.ClusterCoordinator(engines, cluster.RoundRobin())
        auto = ClusterAutoscaler(
            coord, AutoscaleConfig(min_replicas=1, max_replicas=3,
                                   policy="scripted", script=[(0.0, -1)],
                                   cooldown=0.0),
            engine_factory=lambda rid: SchedulingEngine(
                PROF, policies.SlackFit(), worker_ids=range(2),
                replica_id=rid))
        events = auto.tick(auto.cfg.interval)
        assert [e.kind for e in events] == ["decommission"]
        assert events[0].rid == 2       # 1 and 2 empty: highest rid goes

    def test_decommission_rejoins_queue_through_placement(self):
        """The drained queue lands on survivors (EDF order), nothing
        marked dropped."""
        engines = [SchedulingEngine(PROF, policies.SlackFit(),
                                    worker_ids=range(2), replica_id=rid)
                   for rid in range(2)]
        heavy = [Query(deadline=1.0 + i, seq=0, qid=i) for i in range(6)]
        light = [Query(deadline=5.0 + i, seq=0, qid=10 + i)
                 for i in range(3)]
        for q in heavy:
            engines[0].admit(q)
        for q in light:
            engines[1].admit(q)
        coord = cluster.ClusterCoordinator(engines, cluster.RoundRobin())
        coord.queries.extend(heavy + light)
        auto = ClusterAutoscaler(
            coord, AutoscaleConfig(min_replicas=1, max_replicas=2,
                                   policy="scripted", script=[(0.0, -1)],
                                   cooldown=0.0),
            engine_factory=lambda rid: None)
        (ev,) = auto.tick(auto.cfg.interval)
        assert ev.kind == "decommission" and ev.rid == 1  # lighter one
        assert list(ev.drained) == [q.qid for q in light]  # EDF order
        assert engines[0].queue_depth() == 9             # re-routed
        assert not any(q.dropped for q in light)

    def test_scripted_relative_times_anchor_at_epoch(self):
        pol = Scripted([(0.5, 1)])
        pol.reset()
        pol.epoch = 100.0               # wall-clock style origin
        assert pol.decide(None, [(0, None)], 100.4)[0] == 0
        assert pol.decide(None, [(0, None)], 100.6)[0] == 1


class TestPredictiveScaling:
    """The forecast-led policy (ISSUE 5): spawns ride the forecast,
    the reactive queue_pressure floor is preserved byte-identically
    when the forecaster never fires."""

    def test_predictive_registered_and_horizon_defaults(self):
        assert "predictive" in SCALINGS
        pol = make_scaling(AutoscaleConfig(policy="predictive",
                                           cold_start=0.2, interval=0.05),
                           slo=0.036)
        assert isinstance(pol, Predictive)
        assert isinstance(pol, QueuePressure)   # the reactive fallback IS it
        assert pol.horizon == pytest.approx(0.25)
        explicit = make_scaling(AutoscaleConfig(policy="predictive",
                                                horizon=0.4), slo=0.036)
        assert explicit.horizon == pytest.approx(0.4)
        with pytest.raises(ValueError):
            AutoscaleConfig(horizon=-1.0).validate()

    def test_coordinator_forecast_defaulting_rule(self):
        """Both transports construct the coordinator forecaster through
        this one rule — explicit config wins, predictive policy gets a
        rate_window-matched default, anything else gets none."""
        explicit = ForecastConfig(window=0.7)
        assert coordinator_forecast(None, explicit) is explicit
        assert coordinator_forecast(
            AutoscaleConfig(policy="queue_pressure"), None) is None
        fc = coordinator_forecast(
            AutoscaleConfig(policy="predictive", rate_window=0.4), None)
        assert fc is not None and fc.window == pytest.approx(0.4)

    def test_never_firing_forecaster_replays_reactive_schedule(self):
        """THE fallback invariant: a coordinator forecaster that can
        never reach signal makes `predictive` replay the queue_pressure
        schedule byte-identically — records, dispatches, AND the scale
        event timeline with its signal values."""
        def acfg(policy):
            return AutoscaleConfig(min_replicas=1, max_replicas=6,
                                   policy=policy, cooldown=0.2)
        base = _sim(ARR, 2, acfg("queue_pressure"))
        mute = _sim(ARR, 2, acfg("predictive"),
                    forecast=ForecastConfig(min_arrivals=10**9))
        assert mute.records == base.records
        assert [(d.t, d.replica, d.worker, d.batch, d.pareto_idx)
                for d in mute.dispatches] == \
               [(d.t, d.replica, d.worker, d.batch, d.pareto_idx)
                for d in base.dispatches]
        assert [(e.t, e.kind, e.rid, e.signal) for e in mute.scale_events] \
            == [(e.t, e.kind, e.rid, e.signal) for e in base.scale_events]
        # non-vacuous: the reactive baseline really scaled here
        assert any(e.kind == "spawn" for e in base.scale_events)
        # and the muted forecaster really observed yet never fired
        assert mute.forecast is not None
        assert mute.forecast["n_observed"] == len(ARR)
        assert mute.forecast["has_signal"] == 0.0

    def test_predictive_spawns_ahead_on_a_forecastable_ramp(self):
        """On a smooth accelerating ramp the forecast crosses capacity
        before the observed rate does: predictive's first spawn lands
        earlier than reactive's, and attainment doesn't suffer. The
        thresholds are set so the *utilization* signal is the binding
        one for both policies (a twitchy backlog kicker would fire
        first on transient queue spikes and mask the forecast lead)."""
        ramp = traces.time_varying_trace(100, 4000, 500, 1.0, 6.0, seed=5)

        def acfg(policy):
            return AutoscaleConfig(min_replicas=1, max_replicas=8,
                                   policy=policy, cold_start=0.25,
                                   util_target=0.3, up_pressure=4.0)
        reactive = _sim(ramp, 1, acfg("queue_pressure"))
        predictive = _sim(ramp, 1, acfg("predictive"))
        t_r = min(e.t for e in reactive.scale_events if e.kind == "spawn")
        t_p = min(e.t for e in predictive.scale_events if e.kind == "spawn")
        assert t_p < t_r
        assert predictive.slo_attainment >= reactive.slo_attainment

    @given(st.integers(0, 10_000))
    @settings(max_examples=25, deadline=None)
    def test_conservation_with_predictive_scaling_and_joins(self, seed):
        """The PR 4 conservation property extended: forecast-led
        scaling racing predictive join windows still resolves every
        query exactly once."""
        rng = np.random.default_rng(seed)
        arr = np.sort(rng.uniform(0, 0.6, size=int(rng.integers(20, 300))))
        res = _sim(arr, 1,
                   AutoscaleConfig(min_replicas=1, max_replicas=4,
                                   policy="predictive", cooldown=0.1),
                   continuous_batching=True, predictive_joins=True)
        served = sum(1 for q in res.queries
                     if q.finish is not None and not q.dropped)
        dropped = sum(1 for q in res.queries if q.dropped)
        assert served + dropped == len(arr)
        qids = [r.qid for r in res.records]
        assert qids == sorted(set(qids)) and len(qids) == len(arr)
        for e in res.scale_events:
            if e.kind in ("spawn", "ready", "decommission"):
                assert 1 <= e.n_committed <= 4


class TestReplicaSecondsAccounting:
    def test_static_runs_bill_full_duration(self):
        res = _sim(ARR, 3, None)
        assert res.replica_spans == {rid: res.duration for rid in range(3)}
        assert res.replica_seconds == pytest.approx(3 * res.duration)

    def test_transient_replica_billed_spawn_to_decommission(self):
        res = _sim(ARR, 1, _scripted([(0.5, 1), (1.2, -1)],
                                     cold_start=0.05))
        spawn = next(e for e in res.scale_events if e.kind == "spawn")
        decom = next(e for e in res.scale_events
                     if e.kind == "decommission")
        assert decom.rid == spawn.rid
        assert res.replica_spans[spawn.rid] == \
            pytest.approx(decom.t - spawn.t)
        assert res.replica_spans[0] == pytest.approx(res.duration)

    def test_stats_reports_efficiency_figure(self):
        res = _sim(ARR, 2, AutoscaleConfig(min_replicas=1, max_replicas=4))
        st_ = res.stats()
        assert st_["replica_seconds"] == pytest.approx(res.replica_seconds)
        ok = sum(1 for q in res.queries if q.finish is not None
                 and q.finish <= q.deadline and not q.dropped)
        assert st_["goodput_per_replica_second"] == \
            pytest.approx(ok / res.replica_seconds)
