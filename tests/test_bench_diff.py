"""tools/bench_diff.py: the perf-regression gate must actually gate.

Covers the acceptance matrix: identical artifacts pass, an injected
timing regression fails, an improvement passes (and is reported), a
flipped claim fails, ``--skip-timing`` skips exactly the timing-kind
metrics while still gating structural ones, and missing files/keys warn
rather than fail (partial runs stay usable).
"""
from __future__ import annotations

import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "tools"))
import bench_diff  # noqa: E402

BASE = {
    "bench": "hotpath",
    "claims": {"skip_matches_dense": True, "speedup_ge_2x": True},
    "scalars": {
        "prefill.S2048.skip_ms": 100.0,
        "prefill.S2048.speedup": 2.5,
        "prefill.S2048.live_frac": 0.5625,
        "engine.n_queries": 1000.0,
    },
}

TOL = {
    "default": {"kind": "timing", "direction": "both", "rel_tol": 0.5},
    "metrics": [
        {"pattern": "*.skip_ms", "kind": "timing", "direction": "lower",
         "rel_tol": 0.3},
        {"pattern": "*.speedup", "kind": "timing", "direction": "higher",
         "rel_tol": 0.2},
        {"pattern": "*.live_frac", "kind": "structural",
         "direction": "lower", "rel_tol": 0.0, "abs_tol": 1e-9},
        {"pattern": "*.n_queries", "kind": "structural", "direction": "both",
         "rel_tol": 0.0, "abs_tol": 0.0},
    ],
}


def _setup(tmp_path, cur_mutate=None, base=BASE):
    bdir = tmp_path / "baseline"
    cdir = tmp_path / "current"
    bdir.mkdir(exist_ok=True)
    cdir.mkdir(exist_ok=True)
    (bdir / "BENCH_hotpath.json").write_text(json.dumps(base))
    (bdir / "tolerances.json").write_text(json.dumps(TOL))
    cur = json.loads(json.dumps(base))
    if cur_mutate:
        cur_mutate(cur)
    (cdir / "BENCH_hotpath.json").write_text(json.dumps(cur))
    return str(bdir), str(cdir)


def _run(bdir, cdir, *extra):
    return bench_diff.main(["--baseline", bdir, "--current", cdir, *extra])


def test_identical_passes(tmp_path):
    bdir, cdir = _setup(tmp_path)
    assert _run(bdir, cdir) == 0


def test_injected_timing_regression_fails(tmp_path):
    def worse(cur):
        cur["scalars"]["prefill.S2048.skip_ms"] = 150.0   # +50% > 30% band
    bdir, cdir = _setup(tmp_path, worse)
    assert _run(bdir, cdir) == 1


def test_improvement_passes(tmp_path):
    def better(cur):
        cur["scalars"]["prefill.S2048.skip_ms"] = 50.0
        cur["scalars"]["prefill.S2048.speedup"] = 5.0
    bdir, cdir = _setup(tmp_path, better)
    assert _run(bdir, cdir) == 0


def test_within_tolerance_passes(tmp_path):
    def noisy(cur):
        cur["scalars"]["prefill.S2048.skip_ms"] = 120.0   # +20% < 30% band
    bdir, cdir = _setup(tmp_path, noisy)
    assert _run(bdir, cdir) == 0


def test_claim_flip_fails_even_with_skip_timing(tmp_path):
    def flip(cur):
        cur["claims"]["skip_matches_dense"] = False
    bdir, cdir = _setup(tmp_path, flip)
    assert _run(bdir, cdir) == 1
    assert _run(bdir, cdir, "--skip-timing") == 1


def test_skip_timing_skips_timing_but_gates_structural(tmp_path):
    def mixed(cur):
        cur["scalars"]["prefill.S2048.skip_ms"] = 900.0       # timing
        cur["scalars"]["prefill.S2048.live_frac"] = 0.9       # structural
    bdir, cdir = _setup(tmp_path, mixed)
    assert _run(bdir, cdir) == 1
    # structural regression still caught with timing skipped
    assert _run(bdir, cdir, "--skip-timing") == 1

    def timing_only(cur):
        cur["scalars"]["prefill.S2048.skip_ms"] = 900.0
    bdir, cdir = _setup(tmp_path, timing_only)
    assert _run(bdir, cdir) == 1
    assert _run(bdir, cdir, "--skip-timing") == 0


def test_structural_equality_is_exact(tmp_path):
    def drift(cur):
        cur["scalars"]["engine.n_queries"] = 1001.0
    bdir, cdir = _setup(tmp_path, drift)
    assert _run(bdir, cdir) == 1


def test_missing_current_key_warns_not_fails(tmp_path):
    def drop(cur):
        del cur["scalars"]["prefill.S2048.speedup"]
        del cur["claims"]["speedup_ge_2x"]                # smoke omits it
    bdir, cdir = _setup(tmp_path, drop)
    assert _run(bdir, cdir) == 0


def test_missing_current_file_warns_not_fails(tmp_path):
    bdir, cdir = _setup(tmp_path)
    os.remove(os.path.join(cdir, "BENCH_hotpath.json"))
    assert _run(bdir, cdir) == 0


def test_empty_baseline_dir_is_config_error(tmp_path):
    bdir = tmp_path / "empty"
    bdir.mkdir()
    assert bench_diff.main(["--baseline", str(bdir),
                            "--current", str(tmp_path)]) == 2


def test_report_written(tmp_path):
    def worse(cur):
        cur["scalars"]["prefill.S2048.speedup"] = 1.0
    bdir, cdir = _setup(tmp_path, worse)
    report = tmp_path / "report.json"
    assert _run(bdir, cdir, "--report", str(report)) == 1
    data = json.loads(report.read_text())
    assert data["totals"]["regressions"] == 1
    metrics = [r["metric"]
               for r in data["benches"]["hotpath"]["regressions"]]
    assert metrics == ["hotpath.prefill.S2048.speedup"]
