"""Multi-replica serving plane: ClusterRouter/ClusterSimulator parity
for every placement policy (incl. replica death), single-replica
equivalence with the pre-cluster engine, placement semantics, and the
asyncio cluster front door."""
import asyncio

import numpy as np
import pytest

from repro.configs import get_config
from repro.serving import cluster, policies, profiler, simulator, traces
from repro.serving.autoscaler import AutoscaleConfig
from repro.serving.engine import EngineConfig, SchedulingEngine, VirtualClock
from repro.serving.forecast import ForecastConfig
from repro.serving.queue import Query
from repro.serving.runtime import ClusterRouter, WorkerHandle

PROF = profiler.build_profile(get_config("ofa_resnet"))
ARR = traces.bursty_trace(400, 1600, 4, 2.0, seed=23)


def _groups(n_replicas, workers_per_replica):
    return [[WorkerHandle(wid=i, run=lambda idx, p: np.zeros(len(p)))
             for i in range(workers_per_replica)]
            for _ in range(n_replicas)]


def _virtual_cluster(n_replicas, workers_per_replica, placement,
                     continuous=False, autoscale=None):
    return ClusterRouter(
        PROF, policies.SlackFit(), _groups(n_replicas, workers_per_replica),
        clock=VirtualClock(), placement=placement,
        engine_cfg=EngineConfig(continuous_batching=continuous),
        autoscale=autoscale)


class TestClusterParity:
    """Acceptance: ClusterRouter (virtual clock) and ClusterSimulator
    produce identical per-query completion records for every placement
    policy, including a replica-death scenario — both are transports
    over the same coordinator + engines."""

    @pytest.mark.parametrize("placement", sorted(cluster.PLACEMENTS))
    def test_parity_with_replica_death(self, placement):
        deaths = {1: 0.8}
        ccfg = simulator.ClusterConfig(
            n_replicas=3, workers_per_replica=2, placement=placement,
            slo=0.036, replica_deaths=deaths)
        sim = simulator.simulate_cluster(ARR, PROF, policies.SlackFit(), ccfg)
        router = _virtual_cluster(3, 2, placement)
        recs = router.run_virtual(ARR, slo_s=0.036, replica_deaths=deaths)
        assert len(recs) == len(ARR)
        assert recs == sim.records
        assert router.stats()["slo_attainment"] == sim.slo_attainment

    def test_parity_with_continuous_batching(self):
        ccfg = simulator.ClusterConfig(
            n_replicas=2, workers_per_replica=2, placement="round_robin",
            slo=0.036, continuous_batching=True)
        sim = simulator.simulate_cluster(ARR, PROF, policies.SlackFit(), ccfg)
        router = _virtual_cluster(2, 2, "round_robin", continuous=True)
        assert router.run_virtual(ARR, slo_s=0.036) == sim.records
        assert (sum(e.n_joins for e in router.coord.engines) == sim.n_joins)

    def test_parity_with_worker_level_fault(self):
        faults = {(0, 1): 0.5}
        ccfg = simulator.ClusterConfig(
            n_replicas=2, workers_per_replica=2, placement="least_loaded",
            slo=0.036, fault_times=faults)
        sim = simulator.simulate_cluster(ARR, PROF, policies.SlackFit(), ccfg)
        router = _virtual_cluster(2, 2, "least_loaded")
        recs = router.run_virtual(ARR, slo_s=0.036, fault_times=faults)
        assert recs == sim.records


class TestAutoscaledParity:
    """Extends the PR 3 guarantee: with autoscaling ENABLED, the
    ClusterRouter (virtual clock) and simulate_cluster still produce
    identical per-query completion records AND identical scale-event
    timelines — scaling lives in the coordinator layer, transports stay
    thin over it."""

    @pytest.mark.parametrize("placement", sorted(cluster.PLACEMENTS))
    def test_parity_with_reactive_autoscaling(self, placement):
        def acfg():
            return AutoscaleConfig(min_replicas=1, max_replicas=6,
                                   cooldown=0.2)
        ccfg = simulator.ClusterConfig(
            n_replicas=2, workers_per_replica=2, placement=placement,
            slo=0.036, autoscale=acfg())
        sim = simulator.simulate_cluster(ARR, PROF, policies.SlackFit(),
                                         ccfg)
        router = _virtual_cluster(2, 2, placement, autoscale=acfg())
        recs = router.run_virtual(ARR, slo_s=0.036)
        assert recs == sim.records
        # non-vacuous: the autoscaler actually scaled on this trace...
        assert any(e.kind == "spawn" for e in sim.scale_events)
        # ... both transports actuated the identical event timeline...
        assert [(e.t, e.kind, e.rid) for e in sim.scale_events] == \
               [(e.t, e.kind, e.rid) for e in router.autoscaler.events]
        # ... and bill identical replica-seconds (same nominal horizon)
        assert router.autoscaler.replica_spans() == sim.replica_spans

    def test_parity_scale_down_racing_inflight_batch(self):
        """A scripted decommission lands while the victim has batches
        in flight: both transports must drain them identically (the
        batches finish on the decommissioned replica; its queue
        re-routes)."""
        def acfg():
            return AutoscaleConfig(
                min_replicas=1, max_replicas=4, policy="scripted",
                script=[(0.25, 1), (0.8, -1)], cooldown=0.0,
                cold_start=0.02)
        ccfg = simulator.ClusterConfig(
            n_replicas=2, workers_per_replica=2, placement="round_robin",
            slo=0.036, autoscale=acfg())
        sim = simulator.simulate_cluster(ARR, PROF, policies.SlackFit(),
                                         ccfg)
        router = _virtual_cluster(2, 2, "round_robin", autoscale=acfg())
        assert router.run_virtual(ARR, slo_s=0.036) == sim.records
        decom = next(e for e in sim.scale_events
                     if e.kind == "decommission")
        # the race really happened: an in-flight batch completed on the
        # decommissioned replica AFTER its decommission...
        assert any(q.replica == decom.rid and not q.dropped
                   and q.finish is not None and q.finish > decom.t
                   for q in sim.queries)
        # ...and nothing was lost to it
        assert all(q.finish is not None or q.dropped for q in sim.queries)

    def test_parity_at_non_default_slo(self):
        """The scaling thresholds normalize to the transport's SLO, so
        parity must hold away from the 36 ms default too (the router
        takes it via its ``slo`` parameter, the simulator via
        ClusterConfig.slo)."""
        def acfg():
            return AutoscaleConfig(min_replicas=1, max_replicas=6,
                                   cooldown=0.2)
        ccfg = simulator.ClusterConfig(
            n_replicas=2, workers_per_replica=2, placement="round_robin",
            slo=0.1, autoscale=acfg())
        sim = simulator.simulate_cluster(ARR, PROF, policies.SlackFit(),
                                         ccfg)
        router = ClusterRouter(
            PROF, policies.SlackFit(), _groups(2, 2), clock=VirtualClock(),
            placement="round_robin", autoscale=acfg(), slo=0.1)
        assert router.run_virtual(ARR, slo_s=0.1) == sim.records
        assert [(e.t, e.kind, e.rid) for e in sim.scale_events] == \
               [(e.t, e.kind, e.rid) for e in router.autoscaler.events]

    def test_parity_with_autoscaling_and_continuous_batching(self):
        def acfg():
            return AutoscaleConfig(min_replicas=1, max_replicas=5,
                                   cooldown=0.2)
        ccfg = simulator.ClusterConfig(
            n_replicas=2, workers_per_replica=2, placement="round_robin",
            slo=0.036, continuous_batching=True, autoscale=acfg())
        sim = simulator.simulate_cluster(ARR, PROF, policies.SlackFit(),
                                         ccfg)
        router = _virtual_cluster(2, 2, "round_robin", continuous=True,
                                  autoscale=acfg())
        assert router.run_virtual(ARR, slo_s=0.036) == sim.records


class TestPredictiveParity:
    """ISSUE 5 acceptance: with the shared forecaster driving BOTH
    predictive scaling and predictive join windows, ClusterRouter and
    simulate_cluster still produce record-for-record identical
    schedules, identical scale-event timelines, and byte-identical
    forecast snapshots — forecasting state lives in the coordinator /
    engine layer, transports stay thin over it."""

    @pytest.mark.parametrize("placement", sorted(cluster.PLACEMENTS))
    def test_parity_with_predictive_scaling_and_joins(self, placement):
        def acfg():
            return AutoscaleConfig(min_replicas=1, max_replicas=6,
                                   policy="predictive", cooldown=0.2)
        ccfg = simulator.ClusterConfig(
            n_replicas=2, workers_per_replica=2, placement=placement,
            slo=0.036, continuous_batching=True, predictive_joins=True,
            autoscale=acfg())
        sim = simulator.simulate_cluster(ARR, PROF, policies.SlackFit(),
                                         ccfg)
        router = ClusterRouter(
            PROF, policies.SlackFit(), _groups(2, 2), clock=VirtualClock(),
            placement=placement,
            engine_cfg=EngineConfig(continuous_batching=True,
                                    predictive_joins=True),
            autoscale=acfg())
        recs = router.run_virtual(ARR, slo_s=0.036)
        assert recs == sim.records
        assert [(e.t, e.kind, e.rid) for e in sim.scale_events] == \
               [(e.t, e.kind, e.rid) for e in router.autoscaler.events]
        # the coordinator forecasters observed identical streams
        assert router.coord.forecast_snapshot(sim.duration) == sim.forecast
        assert sim.forecast is not None
        assert sim.forecast["n_observed"] == len(ARR)
        # non-vacuous: scaling actually happened with forecasting on
        assert any(e.kind == "spawn" for e in sim.scale_events)

    def test_parity_with_predictive_joins_only(self):
        """Predictive windows without autoscaling: per-engine
        forecasters exist on both transports and the schedules (incl.
        the predictive-window counts) stay identical."""
        ccfg = simulator.ClusterConfig(
            n_replicas=2, workers_per_replica=1, placement="round_robin",
            slo=0.05, continuous_batching=True, predictive_joins=True)
        sim = simulator.simulate_cluster(ARR, PROF, policies.SlackFit(),
                                         ccfg)
        router = ClusterRouter(
            PROF, policies.SlackFit(), _groups(2, 1), clock=VirtualClock(),
            placement="round_robin",
            engine_cfg=EngineConfig(continuous_batching=True,
                                    predictive_joins=True))
        assert router.run_virtual(ARR, slo_s=0.05) == sim.records
        assert sum(e.n_predictive_windows for e in router.coord.engines) \
            == sim.n_predictive_windows
        # 1-worker pools: every window is a predictive (no-spare) one
        assert sim.n_predictive_windows == sum(
            e.n_open_batches for e in router.coord.engines)
        assert sim.n_predictive_windows > 0

    def test_explicit_forecast_config_surfaces_without_autoscale(self):
        """ClusterConfig.forecast alone turns on coordinator forecast
        introspection, identically on both transports."""
        fcfg = ForecastConfig(window=0.5)
        ccfg = simulator.ClusterConfig(
            n_replicas=2, workers_per_replica=2, placement="round_robin",
            slo=0.036, forecast=fcfg)
        sim = simulator.simulate_cluster(ARR, PROF, policies.SlackFit(),
                                         ccfg)
        assert sim.forecast is not None
        assert sim.forecast["n_observed"] == len(ARR)
        router = ClusterRouter(
            PROF, policies.SlackFit(), _groups(2, 2), clock=VirtualClock(),
            placement="round_robin", forecast=fcfg)
        router.run_virtual(ARR, slo_s=0.036)
        assert "forecast" in router.stats()
        assert router.coord.forecast_snapshot(sim.duration) == sim.forecast
        # no coordinator forecaster -> no snapshot key
        plain = ClusterRouter(
            PROF, policies.SlackFit(), _groups(2, 2), clock=VirtualClock(),
            placement="round_robin")
        plain.run_virtual(ARR, slo_s=0.036)
        assert "forecast" not in plain.stats()


class TestSingleReplicaUnchanged:
    """A 1-replica cluster replays the pre-refactor single-engine
    schedule record-for-record (the --replicas 1 guarantee; the plain
    router/simulator parity test in test_engine.py guards the engine
    itself)."""

    def test_cluster_of_one_matches_plain_simulate(self):
        res = simulator.simulate(ARR, PROF, policies.SlackFit(),
                                 simulator.SimConfig(n_workers=4, slo=0.036))
        cres = simulator.simulate_cluster(
            ARR, PROF, policies.SlackFit(),
            simulator.ClusterConfig(n_replicas=1, workers_per_replica=4,
                                    slo=0.036))
        assert cres.records == res.records
        assert [(d.t, d.worker, d.batch, d.pareto_idx)
                for d in cres.dispatches] == \
               [(d.t, d.worker, d.batch, d.pareto_idx)
                for d in res.dispatches]

    def test_cluster_of_one_with_continuous_batching(self):
        res = simulator.simulate(
            ARR, PROF, policies.SlackFit(),
            simulator.SimConfig(n_workers=3, slo=0.036,
                                continuous_batching=True))
        cres = simulator.simulate_cluster(
            ARR, PROF, policies.SlackFit(),
            simulator.ClusterConfig(n_replicas=1, workers_per_replica=3,
                                    slo=0.036, continuous_batching=True))
        assert cres.records == res.records


class TestReplicaDeath:
    def test_orphans_rerouted_and_conserved(self):
        """Every query resolves exactly once even when a replica dies
        mid-trace; the dead replica serves nothing after death."""
        deaths = {0: 0.5}
        ccfg = simulator.ClusterConfig(
            n_replicas=3, workers_per_replica=2, placement="round_robin",
            slo=0.036, replica_deaths=deaths)
        res = simulator.simulate_cluster(ARR, PROF, policies.SlackFit(), ccfg)
        assert len(res.queries) == len(ARR)
        served = sum(1 for q in res.queries
                     if q.finish is not None and not q.dropped)
        dropped = sum(1 for q in res.queries if q.dropped)
        assert served + dropped == len(ARR)
        # nothing completes on the dead replica after its death
        assert all(q.replica != 0 for q in res.queries
                   if q.finish is not None and q.finish > 0.5)
        # and some queries originally placed on 0 were re-served elsewhere
        assert any(q.replica != 0 for q in res.queries)

    def test_all_workers_faulted_decommissions_replica(self):
        """Per-worker faults that wipe out a replica's whole pool must
        decommission it (re-routing its queue to survivors) — a
        worker-less 'alive' replica would black-hole every query
        placed on it."""
        faults = {(0, 0): 0.1, (0, 1): 0.1}
        ccfg = simulator.ClusterConfig(
            n_replicas=2, workers_per_replica=2, placement="round_robin",
            slo=0.036, fault_times=faults)
        res = simulator.simulate_cluster(ARR, PROF, policies.SlackFit(), ccfg)
        assert all(q.finish is not None or q.dropped for q in res.queries)
        # replica 0 serves nothing after its pool is gone
        assert all(q.replica == 1 for q in res.queries
                   if q.finish is not None and q.finish > 0.1)
        # and the router transport agrees (parity through the fix)
        router = _virtual_cluster(2, 2, "round_robin")
        recs = router.run_virtual(ARR, slo_s=0.036, fault_times=faults)
        assert recs == res.records

    def test_whole_cluster_death_drops_instead_of_crashing(self):
        """Every replica dead: queued orphans and later arrivals are
        recorded as drops — the simulation still runs to quiescence and
        conserves every query."""
        ccfg = simulator.ClusterConfig(
            n_replicas=2, workers_per_replica=2, placement="round_robin",
            slo=0.036, replica_deaths={0: 0.5, 1: 0.5})
        res = simulator.simulate_cluster(ARR, PROF, policies.SlackFit(), ccfg)
        assert len(res.queries) == len(ARR)
        assert all(q.finish is not None or q.dropped for q in res.queries)
        assert all(q.dropped for q in res.queries if q.arrival > 0.5)
        assert any(q.finish is not None and not q.dropped
                   for q in res.queries)          # pre-death work served

    def test_death_of_last_replica_raises(self):
        eng = SchedulingEngine(PROF, policies.SlackFit(),
                               worker_ids=range(2))
        coord = cluster.ClusterCoordinator([eng], cluster.RoundRobin())
        coord.alive[0] = False
        with pytest.raises(RuntimeError):
            coord.select(Query(deadline=1.0, seq=0), now=0.0)


class TestPlacementSemantics:
    def _coord(self, depths, placement, workers=(2, 2, 2), deadline=1.0):
        """Coordinator with manufactured queue depths per replica."""
        engines = [SchedulingEngine(PROF, policies.SlackFit(),
                                    worker_ids=range(w), replica_id=rid)
                   for rid, w in enumerate(workers)]
        for rid, depth in enumerate(depths):
            for i in range(depth):
                engines[rid].admit(Query(deadline=deadline, seq=0,
                                         qid=1000 * rid + i))
        return cluster.ClusterCoordinator(engines, placement)

    def test_round_robin_cycles(self):
        coord = self._coord([0, 0, 0], cluster.RoundRobin())
        rids = [coord.route(Query(deadline=1.0, seq=0, qid=i), 0.0)
                for i in range(6)]
        assert rids == [0, 1, 2, 0, 1, 2]

    def test_least_loaded_picks_smallest_backlog(self):
        coord = self._coord([5, 0, 3], cluster.LeastLoaded())
        assert coord.select(Query(deadline=1.0, seq=0), 0.0) == 1

    def test_power_of_two_is_seeded_deterministic(self):
        a = self._coord([4, 1, 9], cluster.PowerOfTwo())
        b = self._coord([4, 1, 9], cluster.PowerOfTwo())
        picks_a = [a.select(Query(deadline=1.0, seq=0), 0.0)
                   for _ in range(10)]
        picks_b = [b.select(Query(deadline=1.0, seq=0), 0.0)
                   for _ in range(10)]
        assert picks_a == picks_b
        assert 0 in picks_a or 1 in picks_a      # never always the worst
        assert not all(r == 2 for r in picks_a)

    def test_slack_aware_routes_tight_to_earliest_start(self):
        # queued work is MORE urgent than the probe, so it counts as
        # "ahead" on every replica -> least of it wins
        coord = self._coord([6, 0, 2], cluster.SlackAware(),
                            deadline=PROF.lat.min())
        tight = Query(deadline=PROF.lat.min() * 2, seq=0)  # slack < 10x min
        assert coord.select(tight, 0.0) == 1
        relaxed = Query(deadline=1e6, seq=0)
        first = coord.select(relaxed, 0.0)
        second = coord.select(relaxed, 0.0)
        assert (first, second) == (0, 1)         # round-robin spread

    def test_slack_aware_ignores_later_deadline_backlog(self):
        """EDF serves a tight query before queued later-deadline work,
        so that backlog must not repel it (ties -> lowest rid)."""
        coord = self._coord([6, 0, 2], cluster.SlackAware(), deadline=900.0)
        tight = Query(deadline=PROF.lat.min() * 2, seq=0)
        assert coord.select(tight, 0.0) == 0

    def test_slack_aware_learns_bimodal_threshold(self):
        """ROADMAP fix: the tight/relaxed split is learned from the
        observed slack distribution. A bimodal trace whose modes both
        sit ABOVE the fixed 10x-fastest-service multiple misroutes
        under the fixed rule (the tighter mode round-robins straight
        onto the loaded replica); the rolling-median threshold splits
        the modes correctly."""
        min_s = PROF.lat.min()
        tight_mode, relaxed_mode = 20 * min_s, 2000 * min_s

        # fixed multiple: 20x min_service > 10x threshold -> "relaxed"
        # -> round-robin -> first pick is the loaded replica 0
        fixed = self._coord([6, 0, 2], cluster.SlackAware(adaptive=False),
                            deadline=min_s)
        assert fixed.select(Query(deadline=tight_mode, seq=0), 0.0) == 0

        # adaptive: warm the rolling median on the bimodal mix, then
        # the tighter mode routes by earliest start (empty replica 1)
        adaptive = self._coord([6, 0, 2], cluster.SlackAware(),
                               deadline=min_s)
        for i in range(40):
            d = tight_mode if i % 2 == 0 else relaxed_mode
            adaptive.select(Query(deadline=d, seq=0), 0.0)
        assert adaptive.select(
            Query(deadline=tight_mode, seq=0), 0.0) == 1
        # the relaxed mode still spreads round-robin
        picks = [adaptive.select(Query(deadline=relaxed_mode, seq=0), 0.0)
                 for _ in range(3)]
        assert len(set(picks)) == 3

    def test_slack_aware_uniform_slack_routes_by_start(self):
        """Degenerate (unimodal) distribution: every query at the same
        SLO. The learned median equals that slack, `<=` keeps them all
        tight, so routing matches the paper-regime fixed rule:
        earliest projected start."""
        coord = self._coord([6, 0, 2], cluster.SlackAware(min_history=4),
                            deadline=PROF.lat.min())
        for _ in range(8):
            coord.select(Query(deadline=0.036, seq=0), 0.0)
        assert coord.select(Query(deadline=0.036, seq=0), 0.0) == 1

    def test_projected_drain_reflects_capacity(self):
        """Same backlog, more workers -> shorter projected drain (the
        signal that lets slack-aware placement absorb heterogeneity)."""
        small = SchedulingEngine(PROF, policies.SlackFit(),
                                 worker_ids=range(1))
        big = SchedulingEngine(PROF, policies.SlackFit(),
                               worker_ids=range(4))
        for eng in (small, big):
            for i in range(8):
                eng.admit(Query(deadline=1.0, seq=0, qid=i))
        assert big.projected_drain(0.0) < small.projected_drain(0.0)

    def test_unknown_placement_rejected(self):
        with pytest.raises(ValueError):
            cluster.make_placement("definitely_not_a_placement")

    def test_heterogeneous_worker_counts_validated(self):
        with pytest.raises(ValueError):
            cluster.replica_worker_counts(3, [2, 2])
        with pytest.raises(ValueError):
            cluster.replica_worker_counts(2, [2, 0])
        assert cluster.replica_worker_counts(3, 2) == [2, 2, 2]
        assert cluster.replica_worker_counts(2, [4, 1]) == [4, 1]


class TestClusterRouterAsync:
    """The asyncio front door: one ClusterRouter over N real Routers."""

    def test_spreads_and_serves_all(self):
        async def main():
            cr = ClusterRouter(PROF, policies.SlackFit(), _groups(3, 2),
                               placement="round_robin")
            await cr.start()
            futs = [await cr.submit(np.ones(4), slo_s=2.0)
                    for _ in range(12)]
            results = await asyncio.gather(*futs)
            await cr.drain()
            return cr, results

        cr, results = asyncio.run(main())
        st = cr.stats()
        assert st["served"] == 12
        assert all(p is not None for p, _ in results)
        assert set(st["replicas"]) == {0, 1, 2}   # every replica served
        assert st["load_imbalance"] < 0.5

    def test_kill_replica_reroutes_with_payloads(self):
        async def main():
            cr = ClusterRouter(PROF, policies.SlackFit(), _groups(3, 2),
                               placement="round_robin")
            await cr.start()
            futs = []
            for i in range(18):
                futs.append(await cr.submit(np.ones(4), slo_s=5.0))
                if i == 8:
                    cr.kill_replica(1)
                await asyncio.sleep(0.001)
            results = await asyncio.gather(*futs)
            await cr.drain()
            return cr, results

        cr, results = asyncio.run(main())
        st = cr.stats()
        assert st["served"] == 18                 # nothing lost
        assert all(p is not None for p, _ in results)
        # the dead replica finished nothing submitted after its death
        assert all(q.replica != 1 for q in cr.coord.queries[10:])

    def test_submit_racing_replica_death_is_rescued(self):
        """A replica death landing between placement (coord.select) and
        admission (submit_query suspended on the replica's lock) must
        not black-hole the query: submit re-routes it to a survivor."""
        async def main():
            cr = ClusterRouter(PROF, policies.SlackFit(), _groups(2, 1),
                               placement="round_robin")
            await cr.start()
            r0 = cr.routers[0]
            async with r0._work:       # hold replica 0's admission lock
                task = asyncio.create_task(cr.submit(np.ones(4), slo_s=2.0))
                await asyncio.sleep(0.01)   # select() ran; admission blocked
                cr.kill_replica(0)
            fut = await task
            result = await fut
            await cr.drain(timeout=2.0)
            return cr, result

        cr, result = asyncio.run(main())
        assert result[0] is not None              # served, not lost
        assert cr.coord.queries[0].replica == 1   # by the survivor

    def test_live_autoscale_spawns_and_decommissions(self):
        """The wall-clock autoscale control loop: a scripted spawn
        turns a new Router routable after its cold start and serves
        real queries; the scripted decommission re-routes its queue
        (payloads travel) and every query still resolves."""
        async def main():
            cr = ClusterRouter(
                PROF, policies.SlackFit(), _groups(1, 1),
                placement="round_robin",
                autoscale=AutoscaleConfig(
                    min_replicas=1, max_replicas=3, interval=0.02,
                    cold_start=0.02, cooldown=0.05, policy="scripted",
                    script=[(0.04, 1), (0.30, -1)]))
            await cr.start()
            futs = []
            for _ in range(30):
                futs.append(await cr.submit(np.ones(4), slo_s=2.0))
                await asyncio.sleep(0.015)
            results = await asyncio.gather(*futs)
            await cr.drain()
            return cr, results

        cr, results = asyncio.run(main())
        kinds = [e.kind for e in cr.autoscaler.events]
        assert kinds.count("spawn") == 1 and kinds.count("ready") == 1
        assert kinds.count("decommission") == 1
        st = cr.stats()
        assert st["served"] == 30                 # conservation, live
        assert all(p is not None for p, _ in results)
        # the spawned replica actually served between ready and decom
        assert {q.replica for q in cr.coord.queries} == {0, 1}
        assert st["replica_seconds"] > 0

    def test_live_predictive_autoscale_feeds_forecaster(self):
        """The live asyncio plane: every submission feeds the
        coordinator forecaster exactly once (the front door bypasses
        coord.admit, so it must call coord.observe itself), and the
        wall-clock autoscale loop consults the predictive policy
        without error."""
        async def main():
            cr = ClusterRouter(
                PROF, policies.SlackFit(), _groups(1, 2),
                placement="round_robin",
                autoscale=AutoscaleConfig(
                    min_replicas=1, max_replicas=2, interval=0.02,
                    cold_start=0.02, policy="predictive"))
            await cr.start()
            futs = []
            for _ in range(40):
                futs.append(await cr.submit(np.ones(4), slo_s=2.0))
                await asyncio.sleep(0.003)
            results = await asyncio.gather(*futs)
            await cr.drain()
            return cr, results

        cr, results = asyncio.run(main())
        st = cr.stats()
        assert st["served"] == 40
        assert all(p is not None for p, _ in results)
        assert cr.coord.forecaster is not None
        assert st["forecast"]["n_observed"] == 40.0
        assert st["forecast"]["rate"] >= 0.0

    def test_submit_after_total_death_resolves_as_dropped(self):
        """Coordinator semantics under total cluster failure: the query
        is recorded and its future resolves as dropped — never an
        unhandled exception, never a lost query."""
        async def main():
            cr = ClusterRouter(PROF, policies.SlackFit(), _groups(2, 1),
                               placement="round_robin")
            await cr.start()
            f0 = await cr.submit(np.ones(4), slo_s=2.0)
            cr.kill_worker(0, 0)       # last worker -> decommission
            cr.kill_replica(1)
            f1 = await cr.submit(np.ones(4), slo_s=2.0)
            r0, r1 = await asyncio.gather(f0, f1)
            await cr.drain(timeout=1.0)
            return cr, r0, r1

        cr, r0, r1 = asyncio.run(main())
        assert r1 == (None, 0.0)                  # dropped, resolved
        assert len(cr.coord.queries) == 2         # both recorded
        assert cr.coord.queries[1].dropped
        assert cr.stats()["served"] == 2.0        # both resolved
