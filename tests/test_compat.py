"""Compat shim resolution on the installed JAX + kernel dispatcher
tiers: import sweep over every repro.* module, probe results, tier
fallback chain, and per-kernel agreement between the fallback tiers.
"""
import importlib
import os
import pkgutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import compat
from repro.kernels import ops, ref
from repro.kernels.dispatch import DISPATCHER, coerce_tier, model_tier

KERNELS = ("flash_attention", "decode_attention", "sliced_matmul",
           "subnet_rmsnorm")


# --------------------------------------------------------------------------
# import sweep: every module must import on this JAX version
# --------------------------------------------------------------------------


def _all_repro_modules():
    import repro
    names = ["repro"]
    for mod in pkgutil.walk_packages(repro.__path__, prefix="repro."):
        names.append(mod.name)
    return names


@pytest.mark.parametrize("name", _all_repro_modules())
def test_module_imports(name):
    """No repro.* module may blow up at import time on this host.

    This is the canary for version drift: the seed repo failed here on
    jax 0.4.37 (TPUCompilerParams rename, AxisType, AbstractMesh)."""
    saved = os.environ.get("XLA_FLAGS")
    try:
        importlib.import_module(name)
    finally:
        # repro.launch.dryrun sets XLA_FLAGS at import; don't leak it
        # into later tests' subprocess spawns.
        if saved is None:
            os.environ.pop("XLA_FLAGS", None)
        else:
            os.environ["XLA_FLAGS"] = saved


# --------------------------------------------------------------------------
# shim resolution on the installed version
# --------------------------------------------------------------------------


def test_jax_version_parsed():
    assert compat.JAX_VERSION >= (0, 4)
    assert compat.JAX_VERSION == compat._version_tuple(jax.__version__)


def test_compiler_params_resolve_on_this_version():
    """Whatever this JAX calls the class, the shim must find it."""
    assert compat.HAS_PALLAS and compat.HAS_PALLAS_TPU
    params = compat.tpu_compiler_params(
        dimension_semantics=("parallel", "arbitrary"))
    assert params is not None
    kw = compat.compiler_params_kwargs(
        dimension_semantics=("parallel", "arbitrary"))
    assert set(kw) == {"compiler_params"}
    # unknown fields are dropped, never raised
    assert compat.tpu_compiler_params(not_a_real_field=1) is None


def test_make_abstract_mesh_both_signatures():
    mesh = compat.make_abstract_mesh((2, 16, 16), ("pod", "data", "model"))
    assert tuple(mesh.axis_names) == ("pod", "data", "model")
    assert dict(mesh.shape) == {"pod": 2, "data": 16, "model": 16}


def test_make_mesh_single_device():
    mesh = compat.make_mesh((1,), ("data",))
    assert mesh.shape["data"] == 1


def test_cpu_subprocess_env_pins_backend():
    env = compat.cpu_subprocess_env(EXTRA="x")
    assert env["JAX_PLATFORMS"] == "cpu"
    assert env["PYTHONPATH"] == "src"
    assert env["EXTRA"] == "x"


# --------------------------------------------------------------------------
# tier resolution
# --------------------------------------------------------------------------


def test_process_tier_valid_and_available():
    tier = compat.kernel_tier()
    assert tier in compat.KERNEL_TIERS
    assert compat.tier_available(tier)
    if not compat.is_tpu_backend():
        assert tier != "tpu"


def test_ref_tier_always_available():
    assert compat.tier_available("ref")


def test_interpret_probe_runs_here():
    # this repo's CI floor: the Pallas interpreter must work on CPU
    assert compat.pallas_interpret_works()


def test_set_kernel_tier_validates():
    with pytest.raises(ValueError):
        compat.set_kernel_tier("gpu")
    if not compat.is_tpu_backend():
        with pytest.raises(RuntimeError):
            compat.set_kernel_tier("tpu")


def test_env_override_respected():
    before = compat.kernel_tier()
    saved = os.environ.get("REPRO_KERNEL_TIER")
    os.environ["REPRO_KERNEL_TIER"] = "ref"
    try:
        compat.reset_kernel_tier()
        assert compat.kernel_tier() == "ref"
        assert compat.explicit_kernel_tier() == "ref"
        assert model_tier() == "ref"
    finally:
        if saved is None:
            os.environ.pop("REPRO_KERNEL_TIER", None)
        else:
            os.environ["REPRO_KERNEL_TIER"] = saved
        compat.reset_kernel_tier()
    assert compat.kernel_tier() == before


def test_set_kernel_tier_roundtrip():
    before = compat.kernel_tier()
    try:
        assert compat.set_kernel_tier("ref") == "ref"
        assert compat.kernel_tier() == "ref"
        assert compat.explicit_kernel_tier() == "ref"
    finally:
        compat.reset_kernel_tier()
    assert compat.kernel_tier() == before


def test_model_tier_never_probed_interpret():
    if compat.explicit_kernel_tier() is None:
        assert model_tier() in ("tpu", "pallas-triton", "ref")


def test_coerce_tier_legacy_interpret_flag():
    assert coerce_tier(None, None) is None
    assert coerce_tier(None, True) == "interpret"
    assert coerce_tier(None, False) == "tpu"
    assert coerce_tier("ref", True) == "ref"      # explicit tier wins


# --------------------------------------------------------------------------
# dispatcher registry
# --------------------------------------------------------------------------


def test_all_kernels_registered_all_tiers():
    assert set(KERNELS) <= set(DISPATCHER.kernels())
    for name in KERNELS:
        tiers = DISPATCHER.registered_tiers(name)
        assert "ref" in tiers
        if compat.HAS_PALLAS_TPU:
            assert "tpu" in tiers and "interpret" in tiers


def test_resolve_unknown_kernel_raises():
    with pytest.raises(KeyError):
        DISPATCHER.resolve("not_a_kernel")
    with pytest.raises(ValueError):
        DISPATCHER.register("flash_attention", "not_a_tier", lambda: None)


def test_resolve_falls_down_the_chain():
    DISPATCHER.register("_chain_probe", "ref", lambda: "ref")
    try:
        tier, fn = DISPATCHER.resolve("_chain_probe")
        # process tier here is interpret (CPU) or tpu; either way the
        # only registered tier is ref, and resolution must land on it.
        assert tier == "ref" and fn() == "ref"
    finally:
        DISPATCHER._impls.pop("_chain_probe")


# --------------------------------------------------------------------------
# fallback-tier agreement, one test per kernel
# --------------------------------------------------------------------------

_TOL = dict(rtol=2e-3, atol=2e-3)


def _host_tiers(name):
    """Tiers executable on this host for ``name`` (compiled tiers need
    their accelerator; CPU numerics for pallas-triton are covered via
    interpret mode in tests/test_dispatch.py)."""
    return [t for t in DISPATCHER.registered_tiers(name)
            if t != "ref" and compat.tier_available(t)]


def test_tier_agreement_flash_attention():
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (1, 4, 32, 16), jnp.float32)
    k = jax.random.normal(ks[1], (1, 2, 32, 16), jnp.float32)
    v = jax.random.normal(ks[2], (1, 2, 32, 16), jnp.float32)
    want = ops.flash_attention(q, k, v, tier="ref")
    for tier in _host_tiers("flash_attention"):
        got = ops.flash_attention(q, k, v, q_block=16, kv_block=16, tier=tier)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), **_TOL)


def test_tier_agreement_decode_attention():
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (1, 4, 1, 16), jnp.float32)
    kc = jax.random.normal(ks[1], (1, 2, 64, 16), jnp.float32)
    vc = jax.random.normal(ks[2], (1, 2, 64, 16), jnp.float32)
    want = ops.decode_attention(q, kc, vc, jnp.int32(17), tier="ref")
    for tier in _host_tiers("decode_attention"):
        got = ops.decode_attention(q, kc, vc, jnp.int32(17), kv_block=16,
                                   tier=tier)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), **_TOL)


def test_tier_agreement_sliced_matmul():
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 16, 128), jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(3), (128, 256), jnp.float32)
    ai, ao = jnp.int32(128), jnp.int32(128)
    want = ops.sliced_matmul(x, w, ai, ao, tier="ref")
    assert want.shape == (2, 16, 256)
    for tier in _host_tiers("sliced_matmul"):
        got = ops.sliced_matmul(x, w, ai, ao, tier=tier)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), **_TOL)


def test_tier_agreement_subnet_rmsnorm():
    x = jax.random.normal(jax.random.PRNGKey(4), (24, 128), jnp.float32)
    gt = jax.random.normal(jax.random.PRNGKey(5), (3, 128), jnp.float32)
    for sid in (0, 2):
        want = ops.subnet_rmsnorm(x, gt, jnp.int32(sid), tier="ref")
        for tier in _host_tiers("subnet_rmsnorm"):
            got = ops.subnet_rmsnorm(x, gt, jnp.int32(sid), tier=tier)
            np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                       **_TOL)


def test_model_impls_match_kernel_tiers():
    """The model-grade wrappers agree with the oracle regardless of
    which tier they resolved to on this host."""
    ks = jax.random.split(jax.random.PRNGKey(6), 3)
    q = jax.random.normal(ks[0], (1, 4, 32, 16), jnp.float32)
    k = jax.random.normal(ks[1], (1, 2, 32, 16), jnp.float32)
    v = jax.random.normal(ks[2], (1, 2, 32, 16), jnp.float32)
    got = ops.model_flash_attention(q, k, v)
    want = ref.flash_attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), **_TOL)

    qd = jax.random.normal(ks[0], (1, 4, 1, 16), jnp.float32)
    got = ops.model_decode_attention(qd, k, v, index=jnp.int32(9))
    want = ref.decode_attention_ref(qd, k, v, 9)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), **_TOL)
