"""pallas-triton tier wiring + block-skipping ref numerics.

Three concerns, per the hot-path PR:

* **Registry resolution** — the GPU tier is registered for the three
  hot kernels and sits in the right place in the fallback chain.
* **Probed degradation** — on a CPU host the probed chain lands below
  ``pallas-triton`` (schedules and numerics identical to before the
  tier existed), while ``REPRO_KERNEL_TIER=pallas-triton`` is honored
  verbatim where available and fails *loudly* (never silently
  substituted) where not.
* **Numerics** — the backend-agnostic triton kernel bodies agree with
  the dense oracles under the Pallas interpreter (how CPU CI validates
  GPU kernels), and the block-skipping ref tier agrees with the dense
  oracle across causal/window/kv_len corners (property-tested).
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import compat
from repro.kernels import ops, ref
from repro.kernels.dispatch import DISPATCHER, model_tier

from _hypothesis_compat import given, settings, strategies as st

TRITON_KERNELS = ("flash_attention", "sliced_matmul", "subnet_rmsnorm")
_TOL = dict(rtol=2e-3, atol=2e-3)


# --------------------------------------------------------------------------
# registry resolution
# --------------------------------------------------------------------------


def test_pallas_triton_registered_for_hot_kernels():
    if not compat.HAS_PALLAS_TRITON:
        pytest.skip("no pallas.triton module in this jax build")
    for name in TRITON_KERNELS:
        assert "pallas-triton" in DISPATCHER.registered_tiers(name), name


def test_pallas_triton_explicit_resolution():
    """tier='pallas-triton' resolves to the triton impl (resolution
    only — executing it needs a GPU)."""
    if not compat.HAS_PALLAS_TRITON:
        pytest.skip("no pallas.triton module in this jax build")
    from repro.kernels import triton_kernels
    tier, fn = DISPATCHER.resolve("flash_attention", "pallas-triton")
    assert tier == "pallas-triton"
    assert fn.__module__ == ops.__name__
    # decode_attention deliberately has no GPU registration: the model
    # wrapper must fall to the XLA path, not raise
    assert "pallas-triton" not in DISPATCHER.registered_tiers(
        "decode_attention")


def test_chain_order_has_triton_between_tpu_and_interpret():
    assert compat.KERNEL_TIERS == ("tpu", "pallas-triton", "interpret",
                                   "ref")


# --------------------------------------------------------------------------
# probed degradation on CPU
# --------------------------------------------------------------------------


def test_probed_chain_skips_triton_off_gpu():
    if compat.is_gpu_backend() or compat.is_tpu_backend():
        pytest.skip("accelerator attached; probed chain differs")
    assert not compat.tier_available("pallas-triton")
    assert compat.kernel_tier() in ("interpret", "ref")
    assert model_tier() == "ref"
    tier, _ = DISPATCHER.resolve("flash_attention", None)
    assert tier in ("interpret", "ref")


def test_model_calls_unchanged_by_triton_registration():
    """Registering the GPU tier must leave CPU model numerics and
    routing exactly as they were (the probed-degradation proof)."""
    if compat.explicit_kernel_tier() is not None:
        pytest.skip("explicit tier pinned in this process")
    if compat.is_gpu_backend() or compat.is_tpu_backend():
        pytest.skip("accelerator attached")
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (1, 4, 48, 16), jnp.float32)
    k = jax.random.normal(ks[1], (1, 2, 48, 16), jnp.float32)
    v = jax.random.normal(ks[2], (1, 2, 48, 16), jnp.float32)
    got = ops.model_flash_attention(q, k, v, causal=True)
    from repro.models.attention import flash_attention as xla_flash
    want = xla_flash(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-6, atol=1e-6)


def test_env_override_honored_verbatim(monkeypatch):
    """REPRO_KERNEL_TIER=pallas-triton pins process AND model tier when
    the host can serve it."""
    real_avail = compat.tier_available
    monkeypatch.setattr(compat, "tier_available",
                        lambda t: True if t == "pallas-triton"
                        else real_avail(t))
    monkeypatch.setenv("REPRO_KERNEL_TIER", "pallas-triton")
    compat.reset_kernel_tier()
    try:
        assert compat.kernel_tier() == "pallas-triton"
        assert compat.explicit_kernel_tier() == "pallas-triton"
        assert model_tier() == "pallas-triton"
        tier, _ = DISPATCHER.resolve("flash_attention", None)
        assert tier == "pallas-triton"
        # no GPU registration for decode -> chain falls through, and the
        # model wrapper routes to XLA instead of raising
        tier, _ = DISPATCHER.resolve("decode_attention", None)
        assert tier in ("interpret", "ref")
    finally:
        compat.reset_kernel_tier()


def test_env_override_unavailable_fails_loudly(monkeypatch):
    """An explicit tier the host cannot serve raises instead of being
    silently swapped — 'verbatim or error', never 'verbatim-ish'."""
    if compat.tier_available("pallas-triton"):
        pytest.skip("GPU attached; the override would be legal here")
    monkeypatch.setenv("REPRO_KERNEL_TIER", "pallas-triton")
    compat.reset_kernel_tier()
    try:
        with pytest.raises(RuntimeError):
            compat.kernel_tier()
    finally:
        compat.reset_kernel_tier()


# --------------------------------------------------------------------------
# triton kernel numerics under the interpreter (CPU CI's GPU proxy)
# --------------------------------------------------------------------------


def _skip_without_pallas():
    if not (compat.HAS_PALLAS and compat.HAS_PALLAS_TRITON):
        pytest.skip("pallas/pallas.triton unavailable")


def test_triton_flash_attention_interpret_numerics():
    _skip_without_pallas()
    from repro.kernels.triton_kernels import flash_attention
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    q = jax.random.normal(ks[0], (1, 4, 64, 32), jnp.float32)
    k = jax.random.normal(ks[1], (1, 2, 64, 32), jnp.float32)
    v = jax.random.normal(ks[2], (1, 2, 64, 32), jnp.float32)
    for window in (0, 16):
        for kv_len in (None, 40):
            got = flash_attention(q, k, v, causal=True, window=window,
                                  kv_len=kv_len, q_block=32, kv_block=32,
                                  interpret=True)
            want = ref.flash_attention_dense_ref(q, k, v, causal=True,
                                                 window=window, kv_len=kv_len)
            np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                       **_TOL)


def test_triton_sliced_matmul_interpret_numerics():
    _skip_without_pallas()
    from repro.kernels.triton_kernels import sliced_matmul
    x = jax.random.normal(jax.random.PRNGKey(3), (2, 16, 96), jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(4), (96, 128), jnp.float32)
    for ai, ao in ((96, 128), (48, 80), (33, 1)):
        got = sliced_matmul(x, w, jnp.int32(ai), jnp.int32(ao),
                            bm=32, bk=32, bn=32, interpret=True)
        want = ref.sliced_matmul_ref(
            x.reshape(-1, 96), w, ai, ao).reshape(2, 16, 128)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), **_TOL)


def test_triton_subnet_rmsnorm_interpret_numerics():
    _skip_without_pallas()
    from repro.kernels.triton_kernels import subnet_rmsnorm
    x = jax.random.normal(jax.random.PRNGKey(5), (40, 64), jnp.float32)
    gt = jax.random.normal(jax.random.PRNGKey(6), (3, 64), jnp.float32)
    for sid in (0, 2):
        got = subnet_rmsnorm(x, gt, jnp.int32(sid), bm=16, interpret=True)
        want = ref.subnet_rmsnorm_ref(x, gt, jnp.int32(sid))
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), **_TOL)


# --------------------------------------------------------------------------
# block-skipping ref == dense oracle (property)
# --------------------------------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(sq=st.integers(min_value=1, max_value=96),
       sk=st.integers(min_value=1, max_value=96),
       qb=st.sampled_from([0, 16, 32, 256]),
       kb=st.sampled_from([0, 16, 32, 256]),
       causal=st.sampled_from([True, False]),
       window=st.sampled_from([0, 8, 24]),
       kv_frac=st.floats(min_value=0.1, max_value=1.0))
def test_skip_ref_matches_dense_ref(sq, sk, qb, kb, causal, window, kv_frac):
    ks = jax.random.split(jax.random.PRNGKey(sq * 97 + sk), 3)
    q = jax.random.normal(ks[0], (1, 4, sq, 16), jnp.float32)
    k = jax.random.normal(ks[1], (1, 2, sk, 16), jnp.float32)
    v = jax.random.normal(ks[2], (1, 2, sk, 16), jnp.float32)
    for kv_len in (None, max(1, int(sk * kv_frac)),
                   jnp.int32(max(1, int(sk * kv_frac)))):
        got = ref.flash_attention_ref(q, k, v, causal=causal, window=window,
                                      kv_len=kv_len, q_block=qb, kv_block=kb)
        want = ref.flash_attention_dense_ref(q, k, v, causal=causal,
                                             window=window, kv_len=kv_len)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), **_TOL)


@settings(max_examples=15, deadline=None)
@given(smax=st.sampled_from([64, 96, 200]),
       kb=st.sampled_from([0, 16, 32, 512]),
       window=st.sampled_from([0, 16]),
       idx_frac=st.floats(min_value=0.0, max_value=1.0))
def test_skip_decode_matches_dense_decode(smax, kb, window, idx_frac):
    ks = jax.random.split(jax.random.PRNGKey(smax), 3)
    q = jax.random.normal(ks[0], (1, 4, 1, 16), jnp.float32)
    kc = jax.random.normal(ks[1], (1, 2, smax, 16), jnp.float32)
    vc = jax.random.normal(ks[2], (1, 2, smax, 16), jnp.float32)
    idx = jnp.int32(min(smax - 1, int(smax * idx_frac)))
    got = ref.decode_attention_ref(q, kc, vc, idx, window=window,
                                   kv_block=kb)
    want = ref.decode_attention_dense_ref(q, kc, vc, idx, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), **_TOL)


def test_xla_model_path_matches_dense_with_offset():
    """The block-skipping XLA prefill (models/attention.py) agrees with
    the dense oracle under a static q_offset (chunked prefill)."""
    from repro.models.attention import flash_attention as xla_flash
    ks = jax.random.split(jax.random.PRNGKey(9), 3)
    q = jax.random.normal(ks[0], (1, 4, 32, 16), jnp.float32)
    k = jax.random.normal(ks[1], (1, 2, 96, 16), jnp.float32)
    v = jax.random.normal(ks[2], (1, 2, 96, 16), jnp.float32)
    for off in (0, 64):
        got = xla_flash(q, k, v, causal=True, q_offset=off,
                        q_block=16, kv_block=32)
        qpad = jnp.pad(q, ((0, 0), (0, 0), (off, 0), (0, 0)))
        want = ref.flash_attention_dense_ref(qpad, k, v,
                                             causal=True)[:, :, off:]
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), **_TOL)
