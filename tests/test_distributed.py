"""Sharding plan rules + shard_map collectives + elastic restore.
Multi-device tests run in subprocesses (the main pytest process keeps
the default single CPU device)."""
import json
import subprocess
import sys
import textwrap

import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config


def _abstract_plan(arch, shape=(2, 16, 16), axes=("pod", "data", "model")):
    from repro.distributed.sharding import ShardingPlan
    # compat.make_abstract_mesh under the hood: the AbstractMesh
    # constructor signature differs across JAX versions.
    return ShardingPlan.abstract(shape, axes, get_config(arch))


class TestShardingRules:
    def test_attention_tp(self):
        plan = _abstract_plan("qwen2.5-14b")
        # stacked (repeat, d, Hq*hd)
        assert plan.param_spec("stages/0/0:attn/wq", (48, 5120, 5120)) == \
            P(None, None, "model")
        assert plan.param_spec("stages/0/0:attn/wo", (48, 5120, 5120)) == \
            P(None, "model", None)

    def test_embed_vocab_sharded(self):
        plan = _abstract_plan("qwen2.5-14b")
        assert plan.param_spec("embed", (152064, 5120)) == P("model", None)
        assert plan.param_spec("head", (5120, 152064)) == P(None, "model")

    def test_moe_expert_parallel(self):
        plan = _abstract_plan("llama4-maverick-400b-a17b")
        spec = plan.param_spec("stages/0/1:moe/wg", (24, 128, 5120, 8192))
        assert spec == P(None, "model", None, None)      # EP: 128 experts / 16

    def test_moe_few_experts_ffn_sharded(self):
        plan = _abstract_plan("mixtral-8x7b")
        spec = plan.param_spec("stages/0/1:moe/wg", (32, 8, 4096, 14336))
        assert spec == P(None, None, None, "model")      # 8 experts < 16: TP d_ff

    def test_norm_tables_replicated(self):
        plan = _abstract_plan("qwen2.5-14b")
        assert plan.param_spec("stages/0/0:attn/norm_gamma", (48, 18, 5120)) \
            == P(None, None, None)

    def test_batch_dp(self):
        plan = _abstract_plan("qwen2.5-14b")
        assert plan.batch_spec("tokens", (256, 4096)) == P(("pod", "data"), None)
        # batch=1 cannot cover dp -> replicated
        assert plan.batch_spec("tokens", (1, 1)) == P(None, None)

    def test_cache_sp_fallback(self):
        """B=1 long-context cache: sequence takes the DP axes (SP)."""
        plan = _abstract_plan("zamba2-2.7b")
        spec = plan.cache_spec("stages/0/0:mamba/k",
                               (54, 1, 32, 524288, 80))
        assert spec[1] is None                 # B unshardable
        assert spec[3] == ("pod", "data")      # S over DP

    def test_cache_batch_dp_heads_tp(self):
        plan = _abstract_plan("zamba2-2.7b")
        spec = plan.cache_spec("shared_attn/k", (9, 128, 32, 32768, 80))
        assert spec[1] == ("pod", "data") and spec[2] == "model"


MULTIDEV = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np, json
    from repro.launch.mesh import make_mesh
    from repro.distributed import collectives, elastic
    from repro.distributed.sharding import ShardingPlan
    from repro.configs import get_config

    mesh = make_mesh((4, 2), ("data", "model"))
    # 1) seq-sharded flash-decode combine vs oracle
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (2, 4, 1, 16), jnp.float32)
    kc = jax.random.normal(ks[1], (2, 2, 32, 16), jnp.float32)
    vc = jax.random.normal(ks[2], (2, 2, 32, 16), jnp.float32)
    with mesh:
        y = collectives.seq_sharded_decode(mesh, q, kc, vc, jnp.int32(17))
    yr = collectives.seq_sharded_decode_ref(q, kc, vc, 17)
    err = float(jnp.abs(y - yr).max())
    assert err < 2e-3, err

    # 2) elastic reshard params onto a smaller mesh
    cfg = get_config("qwen2-1.5b").reduced()
    from repro.models import lm
    params = lm.init_model(jax.random.PRNGKey(0), cfg)
    plan = ShardingPlan(mesh, cfg)
    params = jax.tree.map(jax.device_put, params, plan.params(params))
    small = elastic.shrink_mesh(mesh, cfg, drop_axis="data", factor=2)
    plan2 = ShardingPlan(small, cfg)
    params2 = elastic.reshard_params(params, plan2)
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(params2)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32))
    # 3) int8 all-reduce on mesh
    from repro.training import compress
    g = {"w": jnp.ones((16, 16)) * 0.25}
    e = {"w": jnp.zeros((16, 16))}
    with mesh:
        mg, ne = compress.all_reduce_int8(mesh, g, e, axis="data")
    assert float(jnp.abs(mg["w"] - 0.25).max()) < 0.01
    print(json.dumps({"ok": True, "err": err}))
""")


def test_multidevice_collectives_subprocess():
    from repro.compat import cpu_subprocess_env
    r = subprocess.run([sys.executable, "-c", MULTIDEV], capture_output=True,
                       text=True, env=cpu_subprocess_env(),
                       cwd="/root/repo", timeout=300)
    assert r.returncode == 0, r.stderr[-3000:]
    out = json.loads(r.stdout.strip().splitlines()[-1])
    assert out["ok"]


def test_mini_dryrun_subprocess():
    """End-to-end dry-run machinery on a reduced config + 8-device mesh
    (the full 512-device sweep runs via launch/dryrun.py)."""
    script = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import json, jax, jax.numpy as jnp
        from repro.configs import get_config
        from repro.configs.base import ShapeSpec
        from repro.distributed.sharding import ShardingPlan
        from repro.launch import specs as S
        from repro.launch.mesh import make_mesh
        from repro.launch.dryrun import _step_fn
        from repro.roofline import hlo as H
        from repro.roofline.report import RooflineTerms

        cfg = get_config("qwen2-1.5b").reduced()
        shape = ShapeSpec("mini_train", "train", 64, 8)
        mesh = make_mesh((4, 2), ("data", "model"))
        plan = ShardingPlan(mesh, cfg)
        sp = S.input_specs(cfg, shape)
        sh = S.input_shardings(plan, cfg, shape, sp)
        step = _step_fn(cfg, "train", moe_groups=plan.dp_size)
        with mesh:
            lowered = jax.jit(step, in_shardings=(sh["params"], sh["batch"],
                                                  sh["ctrl"])).lower(
                sp["params"], sp["batch"], sp["ctrl"])
            compiled = lowered.compile()
        ma = compiled.memory_analysis()
        from repro import compat
        ca = compat.cost_analysis(compiled)
        cb, bd = H.collective_bytes(compiled.as_text())
        t = RooflineTerms(arch="mini", shape="mini_train", mesh="8dev",
                          chips=8, hlo_flops_per_device=ca["flops"],
                          hlo_bytes_per_device=ca["bytes accessed"],
                          collective_bytes_per_device=cb,
                          model_flops_total=S.model_flops(cfg, shape),
                          argument_bytes_per_device=ma.argument_size_in_bytes,
                          temp_bytes_per_device=ma.temp_size_in_bytes)
        assert t.t_compute > 0 and t.t_memory > 0
        assert cb > 0, "sharded train step must communicate"
        print(json.dumps({"ok": True, "dominant": t.dominant,
                          "coll_bytes": cb}))
    """)
    from repro.compat import cpu_subprocess_env
    r = subprocess.run([sys.executable, "-c", script], capture_output=True,
                       text=True, env=cpu_subprocess_env(),
                       cwd="/root/repo", timeout=600)
    assert r.returncode == 0, r.stderr[-3000:]
    out = json.loads(r.stdout.strip().splitlines()[-1])
    assert out["ok"] and out["coll_bytes"] > 0
