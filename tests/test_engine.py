"""Shared scheduling engine: router/simulator parity through the one
core, continuous-batching join semantics (spare-capacity and
predictive-forecast windows), and EDF queue edge cases."""
import numpy as np

from tests._hypothesis_compat import given, settings, strategies as st

from repro.configs import get_config
from repro.serving import policies, profiler, simulator, traces
from repro.serving.engine import EngineConfig, VirtualClock
from repro.serving.forecast import ForecastConfig
from repro.serving.queue import EDFQueue, Query
from repro.serving.runtime import Router, WorkerHandle

PROF = profiler.build_profile(get_config("ofa_resnet"))


def _virtual_router(n_workers: int, continuous: bool = False,
                    engine_cfg: EngineConfig = None) -> Router:
    workers = [WorkerHandle(wid=i, run=lambda idx, p: np.zeros(len(p)))
               for i in range(n_workers)]
    return Router(PROF, policies.SlackFit(), workers, clock=VirtualClock(),
                  engine_cfg=engine_cfg
                  or EngineConfig(continuous_batching=continuous))


class TestParity:
    """Acceptance: Router (fake clock) and Simulator produce identical
    per-query completion records on a seeded bursty trace because both
    are transports over the same SchedulingEngine."""

    def test_router_matches_simulator_on_bursty_trace(self):
        arr = traces.bursty_trace(1500, 5550, 8, 3.0, seed=17)
        sim = simulator.simulate(arr, PROF, policies.SlackFit(),
                                 simulator.SimConfig(n_workers=4, slo=0.036))
        router = _virtual_router(4)
        recs = router.run_virtual(arr, slo_s=0.036)
        assert len(recs) == len(arr)
        assert recs == sim.records
        assert router.stats()["slo_attainment"] == sim.slo_attainment
        assert router.stats()["mean_acc"] == sim.mean_acc

    def test_parity_with_continuous_batching_and_faults(self):
        arr = traces.bursty_trace(400, 1600, 4, 3.0, seed=23)
        scfg = simulator.SimConfig(n_workers=3, slo=0.036,
                                   continuous_batching=True,
                                   fault_times={2: 1.0})
        sim = simulator.simulate(arr, PROF, policies.SlackFit(), scfg)
        router = _virtual_router(3, continuous=True)
        recs = router.run_virtual(arr, slo_s=0.036, fault_times={2: 1.0})
        assert recs == sim.records
        assert router.engine.n_joins == sim.n_joins


class TestContinuousBatching:
    def test_arrival_inside_window_joins_the_forming_batch(self):
        """Two workers, generous SLO: q0 opens a join window on worker 0
        (worker 1 is spare), q1 takes worker 1, and q2 — arriving with
        no idle capacity left — joins q0's forming batch (same finish).
        A late query after launch is served separately."""
        arr = [0.0, 0.001, 0.002, 0.2]
        scfg = simulator.SimConfig(n_workers=2, slo=0.05,
                                   continuous_batching=True)
        res = simulator.simulate(arr, PROF, policies.SlackFit(), scfg)
        q0, q1, q2, q3 = res.queries
        assert res.n_joins >= 1
        assert q0.finish == q2.finish            # joined the open batch
        assert q3.finish is not None and q3.finish != q0.finish
        assert res.slo_attainment == 1.0
        # the joined batch dispatched once with both queries
        assert any(d.batch == 2 for d in res.dispatches)

    def test_decision_time_batching_never_joins(self):
        arr = [0.0, 0.001, 0.002, 0.2]
        scfg = simulator.SimConfig(n_workers=2, slo=0.05,
                                   continuous_batching=False)
        res = simulator.simulate(arr, PROF, policies.SlackFit(), scfg)
        assert res.n_joins == 0 and res.n_open_batches == 0
        assert res.queries[0].finish != res.queries[2].finish

    def test_no_window_without_spare_capacity(self):
        """Holding the pool's last free worker is never allowed: with a
        single worker, continuous batching degrades to decision-time."""
        arr = [0.0, 0.001, 0.002]
        scfg = simulator.SimConfig(n_workers=1, slo=0.05,
                                   continuous_batching=True)
        res = simulator.simulate(arr, PROF, policies.SlackFit(), scfg)
        assert res.n_open_batches == 0 and res.n_joins == 0

    def test_joins_never_break_feasible_deadlines(self):
        """A join is admitted only if the batch still meets its earliest
        member deadline at launch: under a light feasible load, holding
        batches open must not create SLO misses."""
        arr = traces.bursty_trace(200, 800, 2, 4.0, seed=3)
        for continuous in (False, True):
            res = simulator.simulate(
                arr, PROF, policies.SlackFit(),
                simulator.SimConfig(n_workers=8,
                                    continuous_batching=continuous))
            assert res.slo_attainment > 0.999

    def test_joins_capped_at_profile_max_batch(self):
        """A flood of simultaneous arrivals can never grow a forming
        batch past the largest profiled (realizable) batch size."""
        arr = np.full(200, 0.0)
        scfg = simulator.SimConfig(n_workers=2, slo=1.0,
                                   continuous_batching=True)
        res = simulator.simulate(arr, PROF, policies.SlackFit(), scfg)
        assert max(d.batch for d in res.dispatches) <= PROF.batches[-1]

    def test_policy_decision_carries_join_window(self):
        dec = policies.SlackFit().choose(PROF, 0.05, 1)
        assert dec.join_window >= 0.0
        assert dec.join_window <= 0.05
        # tight slack leaves no room to hold the batch open
        tight = policies.SlackFit().choose(PROF, float(PROF.lat.min()), 1)
        assert tight.join_window <= 1e-9 + float(PROF.lat.min())


class TestPredictiveJoins:
    """Forecast-led join windows at saturation (ROADMAP "joins at
    saturation"): with predictive_joins=False the PR 2 spare-capacity
    gate is pinned as the baseline; with it on, a forecast that a
    joinable arrival lands within slack may hold even the pool's last
    free worker — but never past any member's deadline."""

    REGULAR = np.arange(0.0, 2.0, 0.004)    # steady 250 q/s

    def test_baseline_pinned_saturated_pool_never_opens(self):
        """predictive_joins=False (the default): a single-worker pool
        is the saturation case — spare-capacity-only joins stall, no
        window ever opens. This is the behavior predictive joins exist
        to fix, pinned so the flag's OFF state stays byte-stable."""
        scfg = simulator.SimConfig(n_workers=1, slo=0.1,
                                   continuous_batching=True,
                                   predictive_joins=False)
        res = simulator.simulate(self.REGULAR, PROF, policies.SlackFit(),
                                 scfg)
        assert res.n_open_batches == 0 and res.n_joins == 0
        assert res.n_predictive_windows == 0

    def test_predictive_opens_and_joins_at_saturation(self):
        """Same saturated pool, forecaster on: the regular stream is
        trivially forecastable, so windows open on the last worker and
        arrivals join in flight."""
        scfg = simulator.SimConfig(n_workers=1, slo=0.1,
                                   continuous_batching=True,
                                   predictive_joins=True)
        res = simulator.simulate(self.REGULAR, PROF, policies.SlackFit(),
                                 scfg)
        assert res.n_predictive_windows > 0
        assert res.n_joins > 0
        assert res.slo_attainment == 1.0
        # joined batches really merged: some dispatch carries > 1 query
        assert any(d.joined > 0 and d.batch > 1 for d in res.dispatches)

    def test_never_firing_forecaster_replays_spare_only_schedule(self):
        """A forecaster that can never reach signal (min_arrivals past
        the trace length) replays the spare-capacity-only continuous-
        batching schedule byte-identically — the predictive layer is
        pure addition."""
        arr = traces.bursty_trace(400, 1600, 4, 2.0, seed=23)
        base = simulator.simulate(
            arr, PROF, policies.SlackFit(),
            simulator.SimConfig(n_workers=3, slo=0.036,
                                continuous_batching=True))
        idle = simulator.simulate(
            arr, PROF, policies.SlackFit(),
            simulator.SimConfig(n_workers=3, slo=0.036,
                                continuous_batching=True,
                                predictive_joins=True,
                                forecast=ForecastConfig(
                                    min_arrivals=10**9)))
        assert idle.records == base.records
        assert idle.n_predictive_windows == 0
        assert [(d.t, d.worker, d.batch, d.pareto_idx, d.joined)
                for d in idle.dispatches] == \
               [(d.t, d.worker, d.batch, d.pareto_idx, d.joined)
                for d in base.dispatches]

    def test_router_simulator_parity_with_predictive_joins(self):
        """Both transports drive the same engine: predictive windows
        must not break record-for-record parity."""
        arr = traces.bursty_trace(400, 1600, 4, 2.0, seed=23)
        cfg = simulator.SimConfig(n_workers=2, slo=0.05,
                                  continuous_batching=True,
                                  predictive_joins=True)
        sim = simulator.simulate(arr, PROF, policies.SlackFit(), cfg)
        router = _virtual_router(2, engine_cfg=cfg.engine_config())
        recs = router.run_virtual(arr, slo_s=0.05)
        assert recs == sim.records
        assert router.engine.n_joins == sim.n_joins
        assert router.engine.n_predictive_windows == sim.n_predictive_windows

    @given(st.integers(0, 10_000), st.floats(0.03, 0.12),
           st.integers(1, 3))
    @settings(max_examples=60, deadline=None)
    def test_joins_never_admit_past_deadline(self, seed, slo, n_workers):
        """THE deadline-soundness property: whatever the arrival
        process, a batch that admitted in-flight joins still launches
        within its earliest member deadline (so no member is served
        late *because of* a join)."""
        rng = np.random.default_rng(seed)
        arr = np.sort(rng.uniform(0, 0.5, size=int(rng.integers(10, 250))))
        scfg = simulator.SimConfig(n_workers=n_workers, slo=slo,
                                   continuous_batching=True,
                                   predictive_joins=True)
        res = simulator.simulate(arr, PROF, policies.SlackFit(), scfg)
        for d in res.dispatches:
            if d.joined > 0:
                assert d.t + d.latency <= d.batch_deadline + 1e-9


class TestEDFQueueEdges:
    def test_pop_batch_on_empty_queue(self):
        assert EDFQueue().pop_batch(4) == []

    def test_pop_batch_n_exceeds_len_and_nonpositive(self):
        q = EDFQueue()
        for i in range(3):
            q.push(Query(deadline=float(i), seq=0, arrival=0.0, qid=i))
        assert q.pop_batch(0) == []
        assert q.pop_batch(-2) == []
        got = q.pop_batch(10)
        assert [g.qid for g in got] == [0, 1, 2]
        assert len(q) == 0

    def test_drop_expired_on_empty_queue(self):
        assert EDFQueue().drop_expired(1.0, 0.01) == []

    def test_drop_expired_all_expired(self):
        q = EDFQueue()
        for i in range(4):
            q.push(Query(deadline=0.1 * i, seq=0, arrival=0.0, qid=i))
        dropped = q.drop_expired(now=10.0, min_service=0.01)
        assert len(dropped) == 4 and len(q) == 0
        assert all(d.dropped for d in dropped)

    def test_drain_returns_urgency_order(self):
        q = EDFQueue()
        for i, d in enumerate([0.5, 0.1, 0.9]):
            q.push(Query(deadline=d, seq=0, arrival=0.0, qid=i))
        assert [x.qid for x in q.drain()] == [1, 0, 2]
        assert len(q) == 0
