"""Compile-counter suite for the AOT-warmed subnet executor
(serving/executor.py) and the compat probes behind it.

The load-bearing assertions lean on ``compat.CompileCounter`` — the
``jax.monitoring`` backend-compile listener — so they prove the
SubNetAct property (actuation never recompiles) and the bucketing
property (the jit cache is bounded by the bucket lattice) against the
real XLA compile pipeline, not proxies. Tests that need the probe skip
cleanly on releases without ``jax.monitoring``.
"""
import asyncio

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from conftest import tiny_dense
from repro import compat
from repro.core import subnet as sn
from repro.models import lm
from repro.serving.executor import (DecodeCache, ExecutorConfig,
                                    SubnetExecutor, bucket_of,
                                    build_executor)

needs_probe = pytest.mark.skipif(
    compat.compile_events() is None,
    reason="jax.monitoring compile-event probe unavailable")


# --------------------------------------------------------------------------
# pure bucketing / config plumbing (no compilation)
# --------------------------------------------------------------------------


def test_bucket_of_rounds_up_to_configured_bucket():
    assert bucket_of(1, (1, 2, 4)) == 1
    assert bucket_of(3, (1, 2, 4)) == 4
    assert bucket_of(4, (1, 2, 4)) == 4


def test_bucket_of_beyond_largest_goes_power_of_two():
    assert bucket_of(5, (1, 2, 4)) == 8
    assert bucket_of(9, (1, 2, 4)) == 16
    assert bucket_of(16, (1, 2, 4)) == 16


def test_bucket_of_rejects_nonpositive():
    with pytest.raises(ValueError):
        bucket_of(0, (1, 2))


def test_executor_config_validates():
    with pytest.raises(ValueError):
        ExecutorConfig(batch_buckets=(4, 2, 1))    # not sorted
    with pytest.raises(ValueError):
        ExecutorConfig(seq_buckets=())
    with pytest.raises(ValueError):
        ExecutorConfig(max_entries=0)


# --------------------------------------------------------------------------
# one shared warmed executor for the compile-counting tests
# --------------------------------------------------------------------------


@pytest.fixture(scope="module")
def warmed():
    cfg = tiny_dense()
    xcfg = ExecutorConfig(batch_buckets=(1, 2, 4), seq_buckets=(8, 16),
                          max_entries=16)
    ex = build_executor(cfg, exec_cfg=xcfg)
    ex.warmup(batches=(1, 2, 4), seqs=(8,), decode=True)
    return ex


@needs_probe
def test_warmed_actuation_never_recompiles(warmed):
    """SubNetAct: >= 3 subnets x >= 3 batch shapes after warmup ->
    zero XLA compilations (the control tuple is traced data; raw
    shapes collapse onto warmed buckets)."""
    assert warmed.n_subnets >= 3
    with compat.CompileCounter() as cc:
        for idx in range(3):
            for B in (1, 2, 3):
                out = warmed.prefill(idx, np.ones((B, 7), np.int32))
                assert out.shape == (B, warmed.cfg.vocab_size)
    assert cc.count == 0


@needs_probe
def test_subnets_differ_through_one_executable(warmed):
    """The zero-compile path still actuates: different subnet indices
    give different logits through the same compiled entry."""
    toks = np.arange(8, dtype=np.int32)[None, :] % warmed.cfg.vocab_size
    a = warmed.prefill(0, toks)
    b = warmed.prefill(warmed.n_subnets - 1, toks)
    assert not np.allclose(a, b)


def test_bucket_reuse_hits_cache(warmed):
    before = warmed.counters()
    warmed.prefill(0, np.ones((2, 5), np.int32))   # bucket (2, 8)
    warmed.prefill(1, np.ones((2, 8), np.int32))   # same bucket
    after = warmed.counters()
    assert after["compiles"] == before["compiles"]
    assert after["hits"] == before["hits"] + 2


def test_router_stats_surface_executor_counters(warmed):
    """Router.stats()['executor'] exposes the executor counters; the
    engine's own stat keys are untouched."""
    from repro.serving import policies, runtime

    prof = warmed.measured_profile(batches=(1, 2), seq_len=8,
                                   warmup=0, iters=1)

    async def go():
        router = runtime.Router(prof, policies.SlackFit(),
                                warmed.make_workers(2), executor=warmed)
        await router.start()
        futs = [await router.submit(np.ones((8,), np.int32), slo_s=5.0)
                for _ in range(4)]
        await asyncio.gather(*futs)
        await router.drain()
        return router.stats()

    st = asyncio.run(go())
    assert st["served"] == 4.0
    assert st["executor"]["compiles"] >= 1.0
    assert 0.0 <= st["executor"]["hit_rate"] <= 1.0


@needs_probe
def test_real_router_serving_is_compile_free(warmed):
    """The acceptance probe end-to-end: an executor-backed Router
    serving across subnets and batch shapes triggers zero XLA
    compilations once the buckets are warm."""
    from repro.serving import policies, runtime

    prof = warmed.measured_profile(batches=(1, 2, 4), seq_len=8,
                                   warmup=0, iters=1)

    async def go():
        router = runtime.Router(prof, policies.SlackFit(),
                                warmed.make_workers(2), executor=warmed)
        await router.start()
        futs = []
        for i in range(12):
            futs.append(await router.submit(
                np.full((7,), i, np.int32), slo_s=5.0))
        await asyncio.gather(*futs)
        await router.drain()
        return router.stats()

    with compat.CompileCounter() as cc:
        st = asyncio.run(go())
    assert st["served"] == 12.0
    assert cc.count == 0


# --------------------------------------------------------------------------
# LRU eviction
# --------------------------------------------------------------------------


def test_lru_evicts_at_cap():
    cfg = tiny_dense()
    ex = build_executor(cfg, exec_cfg=ExecutorConfig(
        batch_buckets=(1, 2), seq_buckets=(8, 16), max_entries=2))
    ex.prefill(0, np.ones((1, 8), np.int32))       # (1, 8)
    ex.prefill(0, np.ones((2, 8), np.int32))       # (2, 8)
    ex.prefill(0, np.ones((1, 16), np.int32))      # (1, 16) -> evict (1, 8)
    c = ex.counters()
    assert c["entries"] == 2.0
    assert c["evictions"] == 1.0
    keys = {k[:3] for k in ex.cache_keys()}
    assert ("prefill", 1, 8) not in keys
    # the evicted bucket recompiles on return (counted as a miss)
    before = ex.counters()["compiles"]
    ex.prefill(0, np.ones((1, 8), np.int32))
    assert ex.counters()["compiles"] == before + 1


def test_warmup_refuses_lattice_beyond_cap():
    cfg = tiny_dense()
    ex = build_executor(cfg, exec_cfg=ExecutorConfig(
        batch_buckets=(1, 2), seq_buckets=(8, 16), max_entries=2))
    with pytest.raises(ValueError, match="lattice"):
        ex.warmup(batches=(1, 2), seqs=(8, 16))


# --------------------------------------------------------------------------
# padding-mask numerics: bucketed == unpadded, at every CPU tier
# --------------------------------------------------------------------------


@pytest.mark.parametrize("tier", ["ref", "interpret"])
def test_padded_prefill_matches_unpadded(tier):
    if not compat.tier_available(tier):
        pytest.skip(f"{tier} tier unavailable")
    compat.set_kernel_tier(tier)
    try:
        cfg = tiny_dense()
        ex = build_executor(cfg, exec_cfg=ExecutorConfig(
            batch_buckets=(1, 2, 4), seq_buckets=(8, 16)))
        rng = np.random.default_rng(3)
        toks = rng.integers(0, cfg.vocab_size, (3, 7)).astype(np.int32)
        ctrl = sn.make_control(cfg, ex.points[2].sub)
        ref_out = lm.prefill(ex.params, cfg, {"tokens": jnp.asarray(toks)},
                             ctrl)
        got = ex.prefill(2, toks)                  # pads to (4, 8)
        np.testing.assert_allclose(np.asarray(ref_out)[:, -1, :], got,
                                   rtol=2e-4, atol=2e-4)
    finally:
        compat.reset_kernel_tier()


def test_ragged_lengths_gather_each_rows_last_position():
    """Rows with different true lengths in one bucketed batch each get
    the logits of their own final position."""
    cfg = tiny_dense()
    ex = build_executor(cfg, exec_cfg=ExecutorConfig(
        batch_buckets=(1, 2, 4), seq_buckets=(8,)))
    rng = np.random.default_rng(5)
    full = rng.integers(0, cfg.vocab_size, (2, 8)).astype(np.int32)
    lengths = [5, 8]
    ragged = full.copy()
    ragged[0, 5:] = 0                               # pad tail of row 0
    got = ex.prefill(1, ragged, lengths=lengths)
    ctrl = sn.make_control(cfg, ex.points[1].sub)
    for row, L in enumerate(lengths):
        solo = lm.prefill(ex.params, cfg,
                          {"tokens": jnp.asarray(full[row:row + 1, :L])},
                          ctrl)
        np.testing.assert_allclose(np.asarray(solo)[0, -1, :], got[row],
                                   rtol=2e-4, atol=2e-4)


# --------------------------------------------------------------------------
# decode path: numerics, donation, compile-freedom
# --------------------------------------------------------------------------


def test_decode_matches_reference_and_donates(warmed):
    cfg = warmed.cfg
    toks = np.arange(2, dtype=np.int32)[:, None] + 1
    dc = warmed.init_cache(2, 8)
    assert (dc.batch, dc.seq_cap) == (2, 8)
    with compat.CompileCounter() as cc:
        logits, dc2 = warmed.decode_step(1, toks, dc, 0)
    if cc.available:
        assert cc.count == 0                       # warmed with decode=True
    ctrl = sn.make_control(cfg, warmed.points[1].sub)
    state = lm.init_cache(cfg, 2, 8, dtype=cfg.dtype)
    ref_logits, _ = lm.decode_step(warmed.params, cfg, jnp.asarray(toks),
                                   ctrl, state, jnp.int32(0))
    np.testing.assert_allclose(np.asarray(ref_logits)[:, 0], logits,
                               rtol=2e-4, atol=2e-4)
    assert isinstance(dc2, DecodeCache)
    if warmed.donate:
        # the donated input cache was consumed in place
        assert jax.tree.leaves(dc.state)[0].is_deleted()


def test_decode_pads_small_batches_into_cache_bucket(warmed):
    dc = warmed.init_cache(2, 8)
    logits, _ = warmed.decode_step(0, np.ones((1, 1), np.int32), dc, 0)
    assert logits.shape == (1, warmed.cfg.vocab_size)


# --------------------------------------------------------------------------
# satellite regression: lm.generate compiles the decode step once
# --------------------------------------------------------------------------


@needs_probe
def test_generate_compiles_decode_step_exactly_once():
    cfg = tiny_dense(d_ff=192)     # unique cfg -> cold decode-step cache
    params = lm.init_model(jax.random.PRNGKey(0), cfg)
    from repro.core.pareto import pareto_subnets
    pts = pareto_subnets(cfg)
    prompt = np.arange(4, dtype=np.int32)[None, :] % cfg.vocab_size
    ctrl_a = sn.make_control(cfg, pts[0].sub)
    ctrl_b = sn.make_control(cfg, pts[-1].sub)
    with compat.CompileCounter() as first:
        out_a = lm.generate(params, cfg, jnp.asarray(prompt), ctrl_a,
                            max_new=2, seq_cap=8)
    assert first.count >= 1                        # the one real compile
    with compat.CompileCounter() as again:
        lm.generate(params, cfg, jnp.asarray(prompt), ctrl_a,
                    max_new=2, seq_cap=8)
        # a different subnet rides the same executable: ctrl is traced
        lm.generate(params, cfg, jnp.asarray(prompt), ctrl_b,
                    max_new=2, seq_cap=8)
    assert again.count == 0
    assert out_a.shape == (1, prompt.shape[1] + 2)
