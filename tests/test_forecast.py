"""Arrival-forecaster property suite (hypothesis) + units.

The contracts every forecaster consumer (the ``predictive`` scaling
policy, the engine's predictive join windows) relies on:

  * forecasts are non-negative and finite for ARBITRARY arrival
    sequences, query times, and horizons;
  * a constant-rate stream converges to the true rate within the
    sliding window's quantization tolerance;
  * a step change is fully absorbed within two window lengths;
  * the estimator is deterministic under replay (same arrivals ->
    byte-identical forecast series) and query-pure (reading the
    forecast never perturbs what a later read returns — so *when* a
    transport happens to ask cannot break transport parity);
  * an idle stream decays to exactly zero within one window.
"""
import math

import pytest

from tests._hypothesis_compat import given, settings, strategies as st

from repro.serving.forecast import ArrivalForecaster, ForecastConfig

W = 0.25
CFG = ForecastConfig(window=W)

# arbitrary (sorted) arrival sequences: bursts via tiny gaps, lulls via
# window-sized ones
GAPS = st.lists(st.floats(0.0, 2.0 * W), min_size=1, max_size=80)


def _arrivals(gaps):
    t, out = 0.0, []
    for g in gaps:
        t += g
        out.append(t)
    return out


def _series(fc, queries):
    """The forecast read-surface at each (now, horizon) pair."""
    return [(fc.rate(now), fc.trend(now), fc.forecast(now, h),
             fc.eta(now), fc.cv2(now), fc.has_signal(now))
            for now, h in queries]


class TestForecastProperties:
    @given(GAPS, st.floats(0.0, 1.0), st.floats(0.0, 2.0))
    @settings(max_examples=100, deadline=None)
    def test_non_negative_and_finite(self, gaps, dt_after, horizon):
        fc = ArrivalForecaster(CFG)
        arrivals = _arrivals(gaps)
        for t in arrivals:
            fc.observe(t)
        for now in [*arrivals, arrivals[-1] + dt_after]:
            for h in (0.0, horizon):
                f = fc.forecast(now, h)
                assert f >= 0.0 and math.isfinite(f)
            assert fc.rate(now) >= 0.0 and math.isfinite(fc.rate(now))
            assert math.isfinite(fc.trend(now))
            assert fc.cv2(now) >= 0.0 and math.isfinite(fc.cv2(now))
            eta = fc.eta(now)
            assert eta is None or (eta > 0.0 and math.isfinite(eta))

    @given(st.floats(0.001, 0.05), st.floats(0.0, 1.0))
    @settings(max_examples=60, deadline=None)
    def test_constant_rate_converges(self, gap, probe):
        """A constant-gap stream reads the true rate 1/gap to within the
        window's counting quantization (one arrival per window). The
        query sits at the observation frontier, as in the serving plane
        (a consumer's clock never runs behind admitted arrivals)."""
        fc = ArrivalForecaster(CFG)
        now = 2.0 * W + probe * 2.0 * W
        t = 0.0
        while t <= now:
            fc.observe(t)
            t += gap
        rate = fc.rate(now)
        assert abs(rate - 1.0 / gap) <= 1.0 / W + 1e-9
        # with zero horizon the forecast IS the windowed rate
        assert fc.forecast(now, 0.0) == rate

    @given(st.floats(0.004, 0.05), st.floats(2.0, 8.0), st.floats(0.0, 1.0))
    @settings(max_examples=60, deadline=None)
    def test_step_change_absorbed_within_two_windows(self, gap, factor,
                                                     probe):
        """After a rate step (gap -> gap/factor) at t_step, any query at
        t_step + 2W or later sees only post-step arrivals in both
        windows: the estimate has fully converged to the new rate."""
        fc = ArrivalForecaster(CFG)
        t_step = 4.0 * W
        now = t_step + 2.0 * W + probe * 2.0 * W
        t, new_gap = 0.0, gap / factor
        while t < t_step:
            fc.observe(t)
            t += gap
        while t <= now:
            fc.observe(t)
            t += new_gap
        assert abs(fc.rate(now) - 1.0 / new_gap) <= 1.0 / W + 1e-9

    @given(GAPS)
    @settings(max_examples=100, deadline=None)
    def test_deterministic_under_replay(self, gaps):
        """Same arrivals -> byte-identical forecast series."""
        arrivals = _arrivals(gaps)
        queries = [(t + 0.3 * W, 0.5 * W) for t in arrivals]
        a, b = ArrivalForecaster(CFG), ArrivalForecaster(CFG)
        for t in arrivals:
            a.observe(t)
            b.observe(t)
        assert _series(a, queries) == _series(b, queries)

    @given(GAPS)
    @settings(max_examples=60, deadline=None)
    def test_queries_are_pure(self, gaps):
        """Interleaving extra reads must not perturb later reads: one
        instance is queried after every observation, the other only at
        the end — the final reads agree byte-for-byte."""
        arrivals = _arrivals(gaps)
        chatty, quiet = ArrivalForecaster(CFG), ArrivalForecaster(CFG)
        for t in arrivals:
            chatty.observe(t)
            chatty.snapshot(t + 0.1 * W)    # extra mid-stream reads
            quiet.observe(t)
        final = [(arrivals[-1] + f * W, h)
                 for f in (0.0, 0.5, 1.5) for h in (0.0, W)]
        assert _series(chatty, final) == _series(quiet, final)

    @given(GAPS, st.floats(0.0, 2.0))
    @settings(max_examples=60, deadline=None)
    def test_idle_stream_decays_to_zero(self, gaps, horizon):
        """One full window after the last arrival the rate window is
        empty: rate, trend, and forecast are exactly zero, eta is None,
        and there is no signal."""
        fc = ArrivalForecaster(CFG)
        last = 0.0
        for t in _arrivals(gaps):
            fc.observe(t)
            last = t
        now = last + W + 1e-9
        assert fc.rate(now) == 0.0
        assert fc.trend(now) == 0.0
        assert fc.forecast(now, horizon) == 0.0
        assert fc.eta(now) is None
        assert not fc.has_signal(now)


class TestForecastUnits:
    def test_trend_positive_on_ramp_negative_on_ebb(self):
        up = ArrivalForecaster(CFG)
        t, gap = 0.0, 0.02
        while t < 2.0:                  # accelerating stream
            up.observe(t)
            gap = max(0.002, 0.02 - 0.009 * t)
            t += gap
        assert up.trend(2.0) > 0.0
        ebb = ArrivalForecaster(CFG)
        t, gap = 0.0, 0.002
        while t < 2.0:                  # decelerating stream
            ebb.observe(t)
            gap = min(0.02, 0.002 + 0.009 * t)
            t += gap
        assert ebb.trend(2.0) < 0.0
        # and the forecast leads the windowed rate accordingly
        assert up.forecast(2.0, W) > up.rate(2.0)
        assert ebb.forecast(2.0, W) < ebb.rate(2.0)

    def test_burst_detector_cv2(self):
        uniform = ArrivalForecaster(CFG)
        for i in range(50):
            uniform.observe(i * 0.01)
        assert uniform.cv2(0.5) < 0.1
        assert not uniform.bursty(0.5)
        # 1-in-k spike trains have gap CV^2 -> k-1: 8 back-to-back then
        # a lull reads ~7, comfortably past the 4.0 threshold
        bursty = ArrivalForecaster(CFG)
        t = 0.0
        for burst in range(8):
            for _ in range(8):
                bursty.observe(t)
                t += 1e-4
            t += 0.1
        assert bursty.cv2(t) >= CFG.burst_cv2
        assert bursty.bursty(t - 0.1)   # queried inside the active stream

    def test_eta_is_inverse_rate(self):
        fc = ArrivalForecaster(CFG)
        for i in range(100):
            fc.observe(i * 0.01)
        now = 1.0
        assert fc.eta(now) == pytest.approx(1.0 / fc.rate(now))

    def test_opening_burst_reads_high_without_blowup(self):
        """Arrivals faster than the window fills read at their true
        high rate immediately (the reactive-burst requirement), and the
        very first arrival alone reads 0, not infinity."""
        fc = ArrivalForecaster(CFG)
        fc.observe(0.0)
        assert fc.rate(0.0) == 0.0
        for i in range(1, 11):
            fc.observe(i * 0.001)
        assert fc.rate(0.01) == pytest.approx(1000.0)

    def test_stale_observation_is_merged_not_corrupting(self):
        """A re-routed query's original (older) arrival timestamp lands
        in order and cannot inflate the current window."""
        a, b = ArrivalForecaster(CFG), ArrivalForecaster(CFG)
        times = [0.0, 0.1, 0.2, 0.3, 0.4]
        for t in times:
            a.observe(t)
            b.observe(t)
        a.observe(0.25)                 # stale re-route
        assert a.rate(0.4 + 2 * W) == b.rate(0.4 + 2 * W) == 0.0
        assert a.rate(0.41) >= b.rate(0.41)   # one more in-window arrival

    def test_snapshot_keys_and_flags(self):
        fc = ArrivalForecaster(CFG)
        for i in range(20):
            fc.observe(i * 0.01)
        snap = fc.snapshot(0.2)
        for key in ("t", "n_observed", "rate", "trend", "slope",
                    "forecast_1w", "eta", "cv2", "bursty", "has_signal"):
            assert key in snap
        assert snap["n_observed"] == 20.0
        assert snap["has_signal"] == 1.0

    def test_config_validation(self):
        with pytest.raises(ValueError):
            ForecastConfig(window=0.0).validate()
        with pytest.raises(ValueError):
            ForecastConfig(alpha=0.0).validate()
        with pytest.raises(ValueError):
            ForecastConfig(beta=1.5).validate()
        with pytest.raises(ValueError):
            ForecastConfig(min_arrivals=0).validate()
        with pytest.raises(ValueError):
            ForecastConfig(cv2_gaps=1).validate()
        with pytest.raises(ValueError):
            ForecastConfig(max_horizon=-1.0).validate()

    def test_min_arrivals_gates_signal(self):
        fc = ArrivalForecaster(ForecastConfig(window=W, min_arrivals=50))
        for i in range(49):
            fc.observe(i * 0.001)
        assert not fc.has_signal(0.049)
        fc.observe(0.049)
        assert fc.has_signal(0.049)

    def test_smoothed_tracks_level_and_decays_idle(self):
        fc = ArrivalForecaster(CFG)
        for i in range(200):
            fc.observe(i * 0.01)
        now = 1.99
        # constant stream: smoothed ~ windowed rate, both near 100/s
        assert fc.smoothed(now) == pytest.approx(fc.rate(now), rel=0.15)
        # idle stream: exactly zero, like forecast()
        assert fc.smoothed(now + 10 * W, 1.0) == 0.0
        assert fc.smoothed(now, -5.0) >= 0.0   # horizon clamped

    def test_horizon_clamped_to_max(self):
        fc = ArrivalForecaster(ForecastConfig(window=W, max_horizon=0.5))
        t, gap = 0.0, 0.02
        while t < 2.0:                  # rising rate -> positive trend
            fc.observe(t)
            gap = max(0.002, 0.02 - 0.009 * t)
            t += gap
        assert fc.forecast(2.0, 100.0) == fc.forecast(2.0, 0.5)
