"""Multi-process serving plane (serving/ipc.py + replica_proc.py).

Three layers pinned here:
  * the wire protocol — length-prefixed JSON framing, monotonic
    sequence numbers, and the full FrameError taxonomy (truncated /
    malformed / oversized / out-of-order), on the shared sync decoder;
  * the spec boundary — LatencyProfile / EngineConfig survive the wire
    round trip with scheduling behavior intact;
  * the transport — a proc cluster reproduces the inproc
    ClusterRouter's completion records record-for-record on a
    deterministic paced trace (modulo wall-clock latencies), and
    replica-process death (out-of-band SIGKILL -> dead-peer detection,
    and the kill_replica API) drains and re-routes through the
    coordinator's existing redistribute path."""
import asyncio

import numpy as np
import pytest

from repro.configs import get_config
from repro.serving import policies, profiler
from repro.serving.engine import EngineConfig, VirtualClock
from repro.serving.ipc import (FrameDecoder, FrameError, MalformedFrame,
                               OutOfOrderFrame, OversizedFrame,
                               ProcClusterRouter, TruncatedFrame,
                               encode_frame, engine_cfg_from_wire,
                               engine_cfg_to_wire, profile_from_wire,
                               profile_to_wire, to_jsonable)
from repro.serving.runtime import ClusterRouter, WorkerHandle

PROF = profiler.build_profile(get_config("ofa_resnet"))


# --------------------------------------------------------------------------
# Wire protocol: framing + error taxonomy (sync decoder, no sockets)
# --------------------------------------------------------------------------


class TestFraming:
    def test_roundtrip_many_frames_one_feed(self):
        frames = [{"t": "submit", "qid": i, "payload": [i, i + 1]}
                  for i in range(5)]
        wire = b"".join(encode_frame(f, seq=i)
                        for i, f in enumerate(frames))
        dec = FrameDecoder()
        out = dec.feed(wire)
        assert [f["qid"] for f in out] == list(range(5))
        assert [f["seq"] for f in out] == list(range(5))
        dec.eof()                       # clean boundary: no error

    def test_byte_at_a_time_reassembly(self):
        wire = encode_frame({"t": "stats"}, seq=0)
        dec = FrameDecoder()
        out = []
        for i in range(len(wire)):
            out.extend(dec.feed(wire[i:i + 1]))
        assert len(out) == 1 and out[0]["t"] == "stats"

    def test_truncated_frame_detected_at_eof(self):
        wire = encode_frame({"t": "completion", "qid": 3}, seq=0)
        dec = FrameDecoder()
        assert dec.feed(wire[:-2]) == []
        with pytest.raises(TruncatedFrame):
            dec.eof()

    def test_truncated_header_detected_at_eof(self):
        dec = FrameDecoder()
        assert dec.feed(b"\x00\x00") == []
        with pytest.raises(TruncatedFrame):
            dec.eof()

    def test_malformed_json_body(self):
        body = b"{not json!"
        wire = len(body).to_bytes(4, "big") + body
        with pytest.raises(MalformedFrame):
            FrameDecoder().feed(wire)

    def test_malformed_non_object_body(self):
        body = b"[1,2,3]"
        wire = len(body).to_bytes(4, "big") + body
        with pytest.raises(MalformedFrame):
            FrameDecoder().feed(wire)

    def test_malformed_missing_seq(self):
        body = b'{"t":"submit"}'
        wire = len(body).to_bytes(4, "big") + body
        with pytest.raises(MalformedFrame):
            FrameDecoder().feed(wire)

    def test_oversized_declared_length(self):
        wire = (1 << 30).to_bytes(4, "big")
        with pytest.raises(OversizedFrame):
            FrameDecoder().feed(wire)

    def test_oversized_encode_refused(self):
        with pytest.raises(OversizedFrame):
            encode_frame({"t": "submit", "payload": "x" * 64}, seq=0,
                         max_frame=32)

    def test_out_of_order_sequence(self):
        dec = FrameDecoder()
        dec.feed(encode_frame({"t": "heartbeat"}, seq=0))
        with pytest.raises(OutOfOrderFrame):
            dec.feed(encode_frame({"t": "heartbeat"}, seq=2))

    def test_replayed_sequence(self):
        dec = FrameDecoder()
        dec.feed(encode_frame({"t": "heartbeat"}, seq=0))
        with pytest.raises(OutOfOrderFrame):
            dec.feed(encode_frame({"t": "heartbeat"}, seq=0))

    def test_taxonomy_is_frame_error(self):
        for exc in (TruncatedFrame, MalformedFrame, OversizedFrame,
                    OutOfOrderFrame):
            assert issubclass(exc, FrameError)

    def test_to_jsonable_numpy(self):
        out = to_jsonable({"a": np.float64(1.5), "b": np.arange(3),
                           "c": [np.int32(2)]})
        assert out == {"a": 1.5, "b": [0, 1, 2], "c": [2]}


# --------------------------------------------------------------------------
# Spec boundary: profile / engine config survive the wire
# --------------------------------------------------------------------------


class TestSpecWire:
    def test_profile_roundtrip_preserves_scheduling(self):
        prof2 = profile_from_wire(profile_to_wire(PROF))
        assert prof2.arch == PROF.arch
        np.testing.assert_allclose(prof2.accs, PROF.accs)
        np.testing.assert_allclose(prof2.lat, PROF.lat)
        assert prof2.batches == PROF.batches
        # the bucket structure (what SlackFit schedules from) rebuilds
        # identically from the wire fields
        for slack in (0.001, 0.01, 0.036, 0.1):
            assert (prof2.choose_slackfit(slack, 8)
                    == PROF.choose_slackfit(slack, 8))
        # residency's switch-cost inputs survive too
        assert [p.weight_mb for p in prof2.points] == \
            [p.weight_mb for p in PROF.points]

    def test_engine_cfg_roundtrip(self):
        cfg = EngineConfig(continuous_batching=True, max_join_window=0.5,
                           load_on_switch=True)
        cfg2 = engine_cfg_from_wire(engine_cfg_to_wire(cfg))
        assert cfg2 == cfg
        assert engine_cfg_from_wire(engine_cfg_to_wire(None)) is None


# --------------------------------------------------------------------------
# Transport switch plumbing
# --------------------------------------------------------------------------


def _groups(n_replicas, workers_per_replica):
    return [[WorkerHandle(wid=i, run=lambda idx, p: list(p))
             for i in range(workers_per_replica)]
            for _ in range(n_replicas)]


class TestTransportSwitch:
    def test_proc_transport_dispatches_subclass(self):
        r = ClusterRouter(PROF, policies.MaxAcc(), [1, 1], transport="proc")
        assert isinstance(r, ProcClusterRouter)
        assert isinstance(r, ClusterRouter)

    def test_unknown_transport_rejected(self):
        with pytest.raises(ValueError, match="transport"):
            ClusterRouter(PROF, policies.MaxAcc(), [1], transport="tcp")

    def test_inproc_rejects_proc_only_kwargs(self):
        with pytest.raises(TypeError, match="work_ms"):
            ClusterRouter(PROF, policies.SlackFit(), _groups(1, 1),
                          work_ms=5.0)

    def test_proc_rejects_autoscale(self):
        from repro.serving.autoscaler import AutoscaleConfig
        with pytest.raises(ValueError, match="autoscaler"):
            ClusterRouter(PROF, policies.MaxAcc(), [1], transport="proc",
                          autoscale=AutoscaleConfig())

    def test_proc_rejects_virtual_clock(self):
        with pytest.raises(ValueError, match="wall-clock"):
            ClusterRouter(PROF, policies.MaxAcc(), [1], transport="proc",
                          clock=VirtualClock())

    def test_proc_run_virtual_unsupported(self):
        r = ClusterRouter(PROF, policies.MaxAcc(), [1], transport="proc")
        with pytest.raises(NotImplementedError):
            r.run_virtual([0.0], slo_s=0.036)


# --------------------------------------------------------------------------
# Proc-transport parity + death (real subprocesses)
# --------------------------------------------------------------------------

N_Q = 24
SLO = 10.0                              # generous: no wall-clock drops
PACE = 0.004


def _key(recs):
    """The timing-insensitive completion signature: which queries were
    served/dropped, at which accuracy, on which replica. Wall-clock
    fields (arrival/finish) are excluded by design — that's the
    'modulo wall-clock latencies' in the parity bar."""
    return sorted((r.qid, r.dropped,
                   None if r.served_acc is None
                   else round(float(r.served_acc), 9), r.replica)
                  for r in recs)


async def _run_paced(router):
    await router.start()
    futs = []
    for i in range(N_Q):
        futs.append(await router.submit([float(i)], slo_s=SLO))
        await asyncio.sleep(PACE)
    results = await asyncio.gather(*futs)
    await router.drain(30.0)
    return router.records(), results


class TestProcParity:
    def test_records_match_inproc(self):
        """Acceptance bar: record-for-record completion parity between
        the proc and inproc transports on a deterministic paced trace
        (maxacc + round_robin: accuracy and placement are independent
        of wall-clock batching, so the signature is deterministic)."""
        recs_in, _ = asyncio.run(_run_paced(
            ClusterRouter(PROF, policies.MaxAcc(), _groups(2, 2))))
        recs_proc, results = asyncio.run(_run_paced(
            ClusterRouter(PROF, policies.MaxAcc(), [2, 2],
                          transport="proc")))
        assert len(recs_proc) == N_Q
        assert _key(recs_proc) == _key(recs_in)
        # every future resolved with the served accuracy
        assert all(acc > 0 for _, acc in results)
        # both replicas actually served (round robin over 2)
        assert {r.replica for r in recs_proc} == {0, 1}

    def test_payloads_echo_through_the_wire(self):
        recs, results = asyncio.run(_run_paced(
            ClusterRouter(PROF, policies.MaxAcc(), [1, 1],
                          transport="proc")))
        for i, (pred, _) in enumerate(results):
            assert pred == [float(i)]


class TestProcDeath:
    def test_process_kill_drains_and_reroutes(self):
        """Out-of-band SIGKILL of a replica process: dead-peer
        detection (EOF on its stream) must push its pending queries
        through ClusterCoordinator.redistribute to the survivor — every
        query still resolves, and the orphans finish on replica 1."""
        async def main():
            router = ClusterRouter(PROF, policies.MaxAcc(), [1, 1],
                                   transport="proc", work_ms=150.0)
            await router.start()
            futs = [await router.submit([float(i)], slo_s=30.0)
                    for i in range(8)]
            await asyncio.sleep(0.08)   # replica 0 is mid-batch
            router._chans[0].proc.kill()
            await asyncio.gather(*futs)
            await router.drain(60.0)
            return router
        router = asyncio.run(main())
        recs = router.records()
        assert len(recs) == 8
        assert all(not r.dropped for r in recs)     # conservation
        assert not router.coord.alive[0]
        # round robin sent the even qids to replica 0; the ones still
        # pending at the kill must have been re-routed to replica 1
        assert any(r.qid % 2 == 0 and r.replica == 1 for r in recs)

    def test_kill_replica_api(self):
        """Coordinator-initiated death (the kill_replica surface) takes
        the same redistribute path, synchronously."""
        async def main():
            router = ClusterRouter(PROF, policies.MaxAcc(), [1, 1],
                                   transport="proc", work_ms=100.0)
            await router.start()
            futs = [await router.submit([float(i)], slo_s=30.0)
                    for i in range(6)]
            await asyncio.sleep(0.05)
            router.kill_replica(0)
            assert not router.coord.alive[0]        # immediate, not EOF
            await asyncio.gather(*futs)
            await router.drain(60.0)
            return router
        router = asyncio.run(main())
        recs = router.records()
        assert len(recs) == 6 and all(not r.dropped for r in recs)
        assert any(r.qid % 2 == 0 and r.replica == 1 for r in recs)

    def test_total_cluster_death_drops_resolve(self):
        """Every replica dead: redistribute has nowhere to route — the
        orphans drop, their futures still resolve (no hang)."""
        async def main():
            router = ClusterRouter(PROF, policies.MaxAcc(), [1],
                                   transport="proc", work_ms=200.0)
            await router.start()
            futs = [await router.submit([1.0], slo_s=30.0)
                    for _ in range(4)]
            await asyncio.sleep(0.05)
            router.kill_replica(0)
            results = await asyncio.gather(*futs)
            # dead cluster: further admissions drop immediately
            late = await (await router.submit([9.0], slo_s=30.0))
            await router.drain(5.0)
            return router, results, late
        router, results, late = asyncio.run(main())
        assert late == (None, 0.0)
        assert len(results) == 4        # every future resolved, no hang
        recs = router.records()
        assert len(recs) == 5
        assert all(r.dropped or r.finish is not None for r in recs)
        assert any(r.dropped for r in recs)     # the orphans did drop


class TestHostDevicePinning:
    def test_child_sees_forced_device_count(self):
        """The XLA_FLAGS fake-device idiom: the spec pins N host
        devices, the parent env carries the flag, and the child's first
        jax import reports exactly N devices — multi-device CI on CPU,
        no TPUs."""
        async def main():
            router = ClusterRouter(PROF, policies.MaxAcc(), [1],
                                   transport="proc", host_devices=3)
            await router.start()
            hello = router._chans[0].hello
            await router.drain(10.0)
            return hello
        hello = asyncio.run(main())
        assert hello["devices"] == 3

    def test_host_devices_env_flag(self):
        from repro.compat import host_devices_env
        env = host_devices_env(4)
        assert "--xla_force_host_platform_device_count=4" in env["XLA_FLAGS"]
        assert env["JAX_PLATFORMS"] == "cpu"
        assert "XLA_FLAGS" not in host_devices_env(0)
