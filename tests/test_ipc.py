"""Multi-host serving plane (serving/ipc.py + replica_proc.py).

Four layers pinned here:
  * the wire protocol — length-prefixed JSON framing, monotonic
    sequence numbers, and the full FrameError taxonomy (truncated /
    malformed / oversized / out-of-order), on the shared sync decoder;
  * the spec boundary — LatencyProfile / EngineConfig survive the wire
    round trip with scheduling behavior intact;
  * the transport — a proc cluster (inherited socketpairs AND the TCP
    listener with its HMAC-token handshake) reproduces the inproc
    ClusterRouter's completion records record-for-record on a
    deterministic paced trace (modulo wall-clock latencies); bad-token
    and version-mismatch peers are rejected before any serving frame;
    remote children are adopted through the same front door;
  * lifecycle — replica-process death (out-of-band SIGKILL ->
    dead-peer detection, and the kill_replica API) drains and
    re-routes through the coordinator's existing redistribute path;
    the live autoscaler spawns/decommissions replica PROCESSES without
    losing a query; death racing shutdown resolves every future
    exactly once; execute="real" children return actual subnet logits."""
import asyncio
import subprocess
import sys

import numpy as np
import pytest

from repro.configs import get_config
from repro.serving import policies, profiler
from repro.serving.engine import EngineConfig, VirtualClock
from repro.serving.ipc import (PROTOCOL_VERSION, FrameDecoder, FrameError,
                               FrameStream, MalformedFrame,
                               OutOfOrderFrame, OversizedFrame,
                               ProcClusterRouter, TruncatedFrame, _Channel,
                               auth_mac, encode_frame, engine_cfg_from_wire,
                               engine_cfg_to_wire, heartbeat_loop,
                               profile_from_wire, profile_to_wire,
                               to_jsonable)
from repro.serving.queue import Query
from repro.serving.runtime import ClusterRouter, WorkerHandle

PROF = profiler.build_profile(get_config("ofa_resnet"))


# --------------------------------------------------------------------------
# Wire protocol: framing + error taxonomy (sync decoder, no sockets)
# --------------------------------------------------------------------------


class TestFraming:
    def test_roundtrip_many_frames_one_feed(self):
        frames = [{"t": "submit", "qid": i, "payload": [i, i + 1]}
                  for i in range(5)]
        wire = b"".join(encode_frame(f, seq=i)
                        for i, f in enumerate(frames))
        dec = FrameDecoder()
        out = dec.feed(wire)
        assert [f["qid"] for f in out] == list(range(5))
        assert [f["seq"] for f in out] == list(range(5))
        dec.eof()                       # clean boundary: no error

    def test_byte_at_a_time_reassembly(self):
        wire = encode_frame({"t": "stats"}, seq=0)
        dec = FrameDecoder()
        out = []
        for i in range(len(wire)):
            out.extend(dec.feed(wire[i:i + 1]))
        assert len(out) == 1 and out[0]["t"] == "stats"

    def test_truncated_frame_detected_at_eof(self):
        wire = encode_frame({"t": "completion", "qid": 3}, seq=0)
        dec = FrameDecoder()
        assert dec.feed(wire[:-2]) == []
        with pytest.raises(TruncatedFrame):
            dec.eof()

    def test_truncated_header_detected_at_eof(self):
        dec = FrameDecoder()
        assert dec.feed(b"\x00\x00") == []
        with pytest.raises(TruncatedFrame):
            dec.eof()

    def test_malformed_json_body(self):
        body = b"{not json!"
        wire = len(body).to_bytes(4, "big") + body
        with pytest.raises(MalformedFrame):
            FrameDecoder().feed(wire)

    def test_malformed_non_object_body(self):
        body = b"[1,2,3]"
        wire = len(body).to_bytes(4, "big") + body
        with pytest.raises(MalformedFrame):
            FrameDecoder().feed(wire)

    def test_malformed_missing_seq(self):
        body = b'{"t":"submit"}'
        wire = len(body).to_bytes(4, "big") + body
        with pytest.raises(MalformedFrame):
            FrameDecoder().feed(wire)

    def test_oversized_declared_length(self):
        wire = (1 << 30).to_bytes(4, "big")
        with pytest.raises(OversizedFrame):
            FrameDecoder().feed(wire)

    def test_oversized_encode_refused(self):
        with pytest.raises(OversizedFrame):
            encode_frame({"t": "submit", "payload": "x" * 64}, seq=0,
                         max_frame=32)

    def test_out_of_order_sequence(self):
        dec = FrameDecoder()
        dec.feed(encode_frame({"t": "heartbeat"}, seq=0))
        with pytest.raises(OutOfOrderFrame):
            dec.feed(encode_frame({"t": "heartbeat"}, seq=2))

    def test_replayed_sequence(self):
        dec = FrameDecoder()
        dec.feed(encode_frame({"t": "heartbeat"}, seq=0))
        with pytest.raises(OutOfOrderFrame):
            dec.feed(encode_frame({"t": "heartbeat"}, seq=0))

    def test_taxonomy_is_frame_error(self):
        for exc in (TruncatedFrame, MalformedFrame, OversizedFrame,
                    OutOfOrderFrame):
            assert issubclass(exc, FrameError)

    def test_to_jsonable_numpy(self):
        out = to_jsonable({"a": np.float64(1.5), "b": np.arange(3),
                           "c": [np.int32(2)]})
        assert out == {"a": 1.5, "b": [0, 1, 2], "c": [2]}


# --------------------------------------------------------------------------
# Spec boundary: profile / engine config survive the wire
# --------------------------------------------------------------------------


class TestSpecWire:
    def test_profile_roundtrip_preserves_scheduling(self):
        prof2 = profile_from_wire(profile_to_wire(PROF))
        assert prof2.arch == PROF.arch
        np.testing.assert_allclose(prof2.accs, PROF.accs)
        np.testing.assert_allclose(prof2.lat, PROF.lat)
        assert prof2.batches == PROF.batches
        # the bucket structure (what SlackFit schedules from) rebuilds
        # identically from the wire fields
        for slack in (0.001, 0.01, 0.036, 0.1):
            assert (prof2.choose_slackfit(slack, 8)
                    == PROF.choose_slackfit(slack, 8))
        # residency's switch-cost inputs survive too
        assert [p.weight_mb for p in prof2.points] == \
            [p.weight_mb for p in PROF.points]

    def test_engine_cfg_roundtrip(self):
        cfg = EngineConfig(continuous_batching=True, max_join_window=0.5,
                           load_on_switch=True)
        cfg2 = engine_cfg_from_wire(engine_cfg_to_wire(cfg))
        assert cfg2 == cfg
        assert engine_cfg_from_wire(engine_cfg_to_wire(None)) is None


# --------------------------------------------------------------------------
# Transport switch plumbing
# --------------------------------------------------------------------------


def _groups(n_replicas, workers_per_replica):
    return [[WorkerHandle(wid=i, run=lambda idx, p: list(p))
             for i in range(workers_per_replica)]
            for _ in range(n_replicas)]


class TestTransportSwitch:
    def test_proc_transport_dispatches_subclass(self):
        r = ClusterRouter(PROF, policies.MaxAcc(), [1, 1], transport="proc")
        assert isinstance(r, ProcClusterRouter)
        assert isinstance(r, ClusterRouter)

    def test_unknown_transport_rejected(self):
        with pytest.raises(ValueError, match="transport"):
            ClusterRouter(PROF, policies.MaxAcc(), [1], transport="tcp")

    def test_inproc_rejects_proc_only_kwargs(self):
        with pytest.raises(TypeError, match="work_ms"):
            ClusterRouter(PROF, policies.SlackFit(), _groups(1, 1),
                          work_ms=5.0)

    def test_proc_accepts_autoscale(self):
        """PR 10 closes the guarded gap: the live autoscaler rides the
        proc transport (construction wires a ClusterAutoscaler with the
        proxy-spawning engine factory; the live cycle is exercised by
        TestProcAutoscale)."""
        from repro.serving.autoscaler import AutoscaleConfig
        r = ClusterRouter(PROF, policies.MaxAcc(), [1], transport="proc",
                          autoscale=AutoscaleConfig(max_replicas=3))
        assert r.autoscaler is not None
        assert r.autoscaler.engine_factory == r._spawn_proxy

    def test_proc_autoscale_validates_bounds(self):
        from repro.serving.autoscaler import AutoscaleConfig
        with pytest.raises(ValueError, match="max_replicas"):
            ClusterRouter(PROF, policies.MaxAcc(), [1, 1, 1],
                          transport="proc",
                          autoscale=AutoscaleConfig(max_replicas=2))
        with pytest.raises(ValueError, match="spawn_workers"):
            ClusterRouter(PROF, policies.MaxAcc(), [1, 2],
                          transport="proc",
                          autoscale=AutoscaleConfig(max_replicas=4))

    def test_proc_rejects_bad_execute(self):
        with pytest.raises(ValueError, match="execute"):
            ClusterRouter(PROF, policies.MaxAcc(), [1], transport="proc",
                          execute="gpu")

    def test_proc_real_requires_arch(self):
        with pytest.raises(ValueError, match="arch"):
            ClusterRouter(PROF, policies.MaxAcc(), [1], transport="proc",
                          execute="real")

    def test_token_requires_listen(self):
        with pytest.raises(ValueError, match="listen"):
            ClusterRouter(PROF, policies.MaxAcc(), [1], transport="proc",
                          token="sesame")

    def test_bad_listen_rejected(self):
        with pytest.raises(ValueError, match="HOST:PORT"):
            ClusterRouter(PROF, policies.MaxAcc(), [1], transport="proc",
                          listen="9999")

    def test_proc_rejects_virtual_clock(self):
        with pytest.raises(ValueError, match="wall-clock"):
            ClusterRouter(PROF, policies.MaxAcc(), [1], transport="proc",
                          clock=VirtualClock())

    def test_proc_run_virtual_unsupported(self):
        r = ClusterRouter(PROF, policies.MaxAcc(), [1], transport="proc")
        with pytest.raises(NotImplementedError):
            r.run_virtual([0.0], slo_s=0.036)


# --------------------------------------------------------------------------
# Proc-transport parity + death (real subprocesses)
# --------------------------------------------------------------------------

N_Q = 24
SLO = 10.0                              # generous: no wall-clock drops
PACE = 0.004


def _key(recs):
    """The timing-insensitive completion signature: which queries were
    served/dropped, at which accuracy, on which replica. Wall-clock
    fields (arrival/finish) are excluded by design — that's the
    'modulo wall-clock latencies' in the parity bar."""
    return sorted((r.qid, r.dropped,
                   None if r.served_acc is None
                   else round(float(r.served_acc), 9), r.replica)
                  for r in recs)


async def _run_paced(router):
    await router.start()
    futs = []
    for i in range(N_Q):
        futs.append(await router.submit([float(i)], slo_s=SLO))
        await asyncio.sleep(PACE)
    results = await asyncio.gather(*futs)
    await router.drain(30.0)
    return router.records(), results


class TestProcParity:
    def test_records_match_inproc(self):
        """Acceptance bar: record-for-record completion parity between
        the proc and inproc transports on a deterministic paced trace
        (maxacc + round_robin: accuracy and placement are independent
        of wall-clock batching, so the signature is deterministic)."""
        recs_in, _ = asyncio.run(_run_paced(
            ClusterRouter(PROF, policies.MaxAcc(), _groups(2, 2))))
        recs_proc, results = asyncio.run(_run_paced(
            ClusterRouter(PROF, policies.MaxAcc(), [2, 2],
                          transport="proc")))
        assert len(recs_proc) == N_Q
        assert _key(recs_proc) == _key(recs_in)
        # every future resolved with the served accuracy
        assert all(acc > 0 for _, acc in results)
        # both replicas actually served (round robin over 2)
        assert {r.replica for r in recs_proc} == {0, 1}

    def test_payloads_echo_through_the_wire(self):
        recs, results = asyncio.run(_run_paced(
            ClusterRouter(PROF, policies.MaxAcc(), [1, 1],
                          transport="proc")))
        for i, (pred, _) in enumerate(results):
            assert pred == [float(i)]


class TestProcDeath:
    def test_process_kill_drains_and_reroutes(self):
        """Out-of-band SIGKILL of a replica process: dead-peer
        detection (EOF on its stream) must push its pending queries
        through ClusterCoordinator.redistribute to the survivor — every
        query still resolves, and the orphans finish on replica 1."""
        async def main():
            router = ClusterRouter(PROF, policies.MaxAcc(), [1, 1],
                                   transport="proc", work_ms=150.0)
            await router.start()
            futs = [await router.submit([float(i)], slo_s=30.0)
                    for i in range(8)]
            await asyncio.sleep(0.08)   # replica 0 is mid-batch
            router._chans[0].proc.kill()
            await asyncio.gather(*futs)
            await router.drain(60.0)
            return router
        router = asyncio.run(main())
        recs = router.records()
        assert len(recs) == 8
        assert all(not r.dropped for r in recs)     # conservation
        assert not router.coord.alive[0]
        # round robin sent the even qids to replica 0; the ones still
        # pending at the kill must have been re-routed to replica 1
        assert any(r.qid % 2 == 0 and r.replica == 1 for r in recs)

    def test_kill_replica_api(self):
        """Coordinator-initiated death (the kill_replica surface) takes
        the same redistribute path, synchronously."""
        async def main():
            router = ClusterRouter(PROF, policies.MaxAcc(), [1, 1],
                                   transport="proc", work_ms=100.0)
            await router.start()
            futs = [await router.submit([float(i)], slo_s=30.0)
                    for i in range(6)]
            await asyncio.sleep(0.05)
            router.kill_replica(0)
            assert not router.coord.alive[0]        # immediate, not EOF
            await asyncio.gather(*futs)
            await router.drain(60.0)
            return router
        router = asyncio.run(main())
        recs = router.records()
        assert len(recs) == 6 and all(not r.dropped for r in recs)
        assert any(r.qid % 2 == 0 and r.replica == 1 for r in recs)

    def test_total_cluster_death_drops_resolve(self):
        """Every replica dead: redistribute has nowhere to route — the
        orphans drop, their futures still resolve (no hang)."""
        async def main():
            router = ClusterRouter(PROF, policies.MaxAcc(), [1],
                                   transport="proc", work_ms=200.0)
            await router.start()
            futs = [await router.submit([1.0], slo_s=30.0)
                    for _ in range(4)]
            await asyncio.sleep(0.05)
            router.kill_replica(0)
            results = await asyncio.gather(*futs)
            # dead cluster: further admissions drop immediately
            late = await (await router.submit([9.0], slo_s=30.0))
            await router.drain(5.0)
            return router, results, late
        router, results, late = asyncio.run(main())
        assert late == (None, 0.0)
        assert len(results) == 4        # every future resolved, no hang
        recs = router.records()
        assert len(recs) == 5
        assert all(r.dropped or r.finish is not None for r in recs)
        assert any(r.dropped for r in recs)     # the orphans did drop


class TestHostDevicePinning:
    def test_child_sees_forced_device_count(self):
        """The XLA_FLAGS fake-device idiom: the spec pins N host
        devices, the parent env carries the flag, and the child's first
        jax import reports exactly N devices — multi-device CI on CPU,
        no TPUs."""
        async def main():
            router = ClusterRouter(PROF, policies.MaxAcc(), [1],
                                   transport="proc", host_devices=3)
            await router.start()
            hello = router._chans[0].hello
            await router.drain(10.0)
            return hello
        hello = asyncio.run(main())
        assert hello["devices"] == 3

    def test_host_devices_env_flag(self):
        from repro.compat import host_devices_env
        env = host_devices_env(4)
        assert "--xla_force_host_platform_device_count=4" in env["XLA_FLAGS"]
        assert env["JAX_PLATFORMS"] == "cpu"
        assert "XLA_FLAGS" not in host_devices_env(0)


# --------------------------------------------------------------------------
# TCP transport: listener, HMAC handshake, remote adoption
# --------------------------------------------------------------------------


class TestTcpTransport:
    def test_tcp_records_match_inproc(self):
        """Acceptance bar: the SAME parity signature as the socketpair
        transport, with every child dialing the TCP listener and
        passing the handshake first."""
        recs_in, _ = asyncio.run(_run_paced(
            ClusterRouter(PROF, policies.MaxAcc(), _groups(2, 2))))
        recs_tcp, results = asyncio.run(_run_paced(
            ClusterRouter(PROF, policies.MaxAcc(), [2, 2],
                          transport="proc", listen="127.0.0.1:0")))
        assert len(recs_tcp) == N_Q
        assert _key(recs_tcp) == _key(recs_in)
        assert all(acc > 0 for _, acc in results)
        assert {r.replica for r in recs_tcp} == {0, 1}

    def test_token_autogenerated_with_listen(self):
        r = ClusterRouter(PROF, policies.MaxAcc(), [1], transport="proc",
                          listen="127.0.0.1:0")
        assert isinstance(r.token, str) and len(r.token) >= 16
        explicit = ClusterRouter(PROF, policies.MaxAcc(), [1],
                                 transport="proc", listen="127.0.0.1:0",
                                 token="sesame")
        assert explicit.token == "sesame"

    def test_auth_mac_binds_token_nonce_and_version(self):
        mac = auth_mac("tok", "nonce")
        assert mac == auth_mac("tok", "nonce", version=PROTOCOL_VERSION)
        assert mac != auth_mac("tok", "nonce", version=PROTOCOL_VERSION + 1)
        assert mac != auth_mac("other", "nonce")
        assert mac != auth_mac("tok", "other")


async def _dial(router) -> FrameStream:
    host, port = router.listen_addr
    reader, writer = await asyncio.open_connection(host, port)
    return FrameStream(reader, writer)


class TestHandshake:
    """The listener's challenge/auth gate, exercised with raw streams
    (no child process): rejected peers get a reject frame + EOF and
    never reach connection pairing."""

    def _router(self):
        return ClusterRouter(PROF, policies.MaxAcc(), [1],
                             transport="proc", listen="127.0.0.1:0")

    def _attempt(self, auth_builder):
        async def main():
            router = self._router()
            await router._start_listener()
            try:
                stream = await _dial(router)
                challenge = await stream.recv()
                assert challenge["t"] == "challenge"
                assert challenge["version"] == PROTOCOL_VERSION
                await stream.send(auth_builder(router, challenge))
                reply = await asyncio.wait_for(stream.recv(), timeout=5.0)
                eof = (None if reply is None
                       else await asyncio.wait_for(stream.recv(),
                                                   timeout=5.0))
                await asyncio.sleep(0.05)   # let pairing settle
                return router, reply, eof
            finally:
                router._server.close()
        return asyncio.run(main())

    def test_bad_token_rejected(self):
        router, reply, eof = self._attempt(
            lambda r, ch: {"t": "auth", "version": PROTOCOL_VERSION,
                           "mac": auth_mac("WRONG", ch["nonce"])})
        assert reply["t"] == "reject" and "token" in reply["reason"]
        assert eof is None                  # server closed after reject
        assert router.handshake_rejects == 1
        assert not router._pending_conns

    def test_missing_mac_rejected(self):
        router, reply, _ = self._attempt(
            lambda r, ch: {"t": "auth", "version": PROTOCOL_VERSION})
        assert reply["t"] == "reject" and "token" in reply["reason"]
        assert router.handshake_rejects == 1

    def test_version_mismatch_rejected(self):
        router, reply, _ = self._attempt(
            lambda r, ch: {"t": "auth", "version": 99,
                           "mac": auth_mac(r.token, ch["nonce"],
                                           version=99)})
        assert reply["t"] == "reject"
        assert "version" in reply["reason"]
        assert router.handshake_rejects == 1

    def test_non_auth_frame_rejected(self):
        router, reply, _ = self._attempt(
            lambda r, ch: {"t": "hello", "rid": 0})
        assert reply["t"] == "reject"
        assert router.handshake_rejects == 1

    def test_good_token_admitted_to_pairing(self):
        async def main():
            router = self._router()
            await router._start_listener()
            try:
                stream = await _dial(router)
                ch = await stream.recv()
                await stream.send(
                    {"t": "auth", "version": PROTOCOL_VERSION,
                     "mac": auth_mac(router.token, ch["nonce"])})
                await asyncio.sleep(0.1)    # let the accept task pair
                assert router.handshake_rejects == 0
                assert len(router._pending_conns) == 1
                stream.close()
            finally:
                router._server.close()
        asyncio.run(main())


class TestRemoteAdopt:
    def test_remote_child_adopted_and_serves(self):
        """A replica_proc started OUT OF BAND (the remote-host path:
        own Popen, --connect + --token on argv) is adopted through the
        listener and serves its round-robin share of a paced trace."""
        from repro.compat import host_devices_env
        from repro.serving.ipc import _src_root

        async def main():
            router = ClusterRouter(PROF, policies.MaxAcc(), [1],
                                   transport="proc",
                                   listen="127.0.0.1:0")
            await router.start()
            host, port = router.listen_addr
            proc = subprocess.Popen(
                [sys.executable, "-m", "repro.serving.replica_proc",
                 "--connect", f"{host}:{port}", "--token", router.token],
                env=host_devices_env(0, PYTHONPATH=_src_root()))
            try:
                rid = await router.adopt_replica(n_workers=1,
                                                 timeout=30.0)
                assert rid == 1
                assert router._chans[1].proc is None    # not our pid
                futs = []
                for i in range(8):
                    futs.append(await router.submit([float(i)],
                                                    slo_s=10.0))
                    await asyncio.sleep(PACE)
                results = await asyncio.gather(*futs)
                await router.drain(30.0)
            finally:
                proc.kill()
            return router, results

        router, results = asyncio.run(main())
        recs = router.records()
        assert len(recs) == 8 and all(not r.dropped for r in recs)
        assert {r.replica for r in recs} == {0, 1}
        assert all(pred is not None for pred, _ in results)
        assert router.handshake_rejects == 0


# --------------------------------------------------------------------------
# Live autoscaling over the proc transport
# --------------------------------------------------------------------------


class TestProcAutoscale:
    def test_autoscale_over_proc_conserves_queries(self):
        """A scripted spawn/decommission cycle on real replica
        processes: every query resolves exactly once, nothing drops
        (conservation across both scale events), and the spawned
        process serves real traffic once its cold start elapses."""
        from repro.serving.autoscaler import AutoscaleConfig

        async def main():
            cfg = AutoscaleConfig(
                min_replicas=1, max_replicas=3, policy="scripted",
                interval=0.05, cooldown=0.0, cold_start=0.05,
                spawn_workers=2, script=((0.2, +1), (2.0, -1)))
            router = ClusterRouter(PROF, policies.MaxAcc(), [2],
                                   transport="proc", autoscale=cfg,
                                   slo=10.0)
            await router.start()
            futs = []
            for i in range(40):
                futs.append(await router.submit([float(i)], slo_s=10.0))
                await asyncio.sleep(0.06)
            results = await asyncio.gather(*futs)
            await router.drain(30.0)
            return router, results

        router, results = asyncio.run(main())
        recs = router.records()
        assert len(recs) == 40
        assert len(results) == 40           # every future resolved
        assert all(not r.dropped for r in recs)     # conservation
        kinds = [e.kind for e in router.autoscaler.events]
        assert "spawn" in kinds and "ready" in kinds
        assert "decommission" in kinds
        # the forked replica process actually served traffic
        assert any(r.replica == 1 for r in recs)
        assert router._chans[1].proc is not None
        assert router.stats()["autoscale_errors"] == 0.0


# --------------------------------------------------------------------------
# Real execution in the child (execute="real")
# --------------------------------------------------------------------------


class TestRealExec:
    def test_real_child_returns_logits_not_echo(self):
        """The child builds a SubnetExecutor from the wire spec: each
        completion carries a finite (vocab,) logits row — real forward
        passes, not payload echoes. Slow (~child-side supernet init +
        AOT warmup on CPU), so the cell stays tiny."""
        cfg = get_config("qwen2-1.5b").reduced()
        prof = profiler.build_profile(cfg)

        async def main():
            router = ClusterRouter(prof, policies.MaxAcc(), [1],
                                   transport="proc", execute="real",
                                   arch="qwen2-1.5b", seq_len=8,
                                   spawn_timeout=300.0)
            await router.start()
            rng = np.random.default_rng(0)
            payloads = rng.integers(0, cfg.vocab_size, (4, 8))
            futs = [await router.submit(payloads[i].tolist(), slo_s=60.0)
                    for i in range(4)]
            results = await asyncio.gather(*futs)
            await router.drain(60.0)
            return router, payloads, results

        router, payloads, results = asyncio.run(main())
        recs = router.records()
        assert len(recs) == 4 and all(not r.dropped for r in recs)
        assert router._chans[0].hello["execute"] == "real"
        for i, (pred, acc) in enumerate(results):
            assert acc > 0
            row = np.asarray(pred, dtype=float)
            assert row.shape == (cfg.vocab_size,)
            assert np.all(np.isfinite(row))
            assert row.tolist() != [float(x) for x in payloads[i]]


# --------------------------------------------------------------------------
# Shutdown/death races (no subprocesses: fabricated channels)
# --------------------------------------------------------------------------


def _bare_router(n=2):
    """A proc router with channels but no processes: the death/shutdown
    bookkeeping paths under test never touch a stream."""
    router = ClusterRouter(PROF, policies.MaxAcc(), [1] * n,
                           transport="proc")
    router._chans = [_Channel(rid) for rid in range(n)]
    return router


def _pending_query(router, rid, qid, loop):
    q = Query(deadline=1e9, seq=0, arrival=0.0, qid=qid)
    q.replica = rid
    fut = loop.create_future()
    router.coord.queries.append(q)
    router._futs[qid] = fut
    router._payloads[qid] = [float(qid)]
    router._by_qid[qid] = q
    router.proxies[rid].pending[qid] = q
    router._all_done.clear()
    return q, fut


class TestShutdownRaces:
    def test_death_during_drain_resolves_once_not_timed_out(self):
        """The _closing gate: a replica dying mid-drain must NOT
        redistribute to peers that already acked drained — its orphans
        resolve immediately as dropped shutdown loss (timed_out stays
        False: lost to a death, not to the drain deadline), exactly
        once."""
        async def main():
            router = _bare_router(2)
            loop = asyncio.get_running_loop()
            q, fut = _pending_query(router, 0, 7, loop)
            router._closing = True
            router._on_death(0, "eof during drain")
            assert fut.done() and fut.result() == (None, 0.0)
            assert q.dropped and not q.timed_out
            assert not router.coord.alive[0]
            # no redistribute: the survivor's outbox saw no submit frame
            assert router._chans[1].outbox.qsize() == 0
            assert not router.proxies[1].pending
            assert router._all_done.is_set()
            # the race's second observation (watchdog after EOF) no-ops
            router._on_death(0, "heartbeat timeout")
            assert fut.result() == (None, 0.0)
            # ...and a stale completion from the dead child is ignored
            router._on_completion(0, {"qid": 7, "dropped": False,
                                      "acc": 0.9, "pred": [7.0]})
            assert fut.result() == (None, 0.0)
            return router
        asyncio.run(main())

    def test_death_before_drain_still_redistributes(self):
        """Contrast case: outside shutdown the same death DOES re-route
        through the coordinator — the survivor's outbox gets the
        re-serialized submit and the future stays pending for it."""
        async def main():
            router = _bare_router(2)
            loop = asyncio.get_running_loop()
            q, fut = _pending_query(router, 0, 7, loop)
            router._on_death(0, "eof")
            assert not fut.done()               # survivor will serve it
            assert q.replica == 1
            assert router.proxies[1].pending == {7: q}
            frame = router._chans[1].outbox.get_nowait()
            assert frame["t"] == "submit" and frame["qid"] == 7
            assert frame["payload"] == [7.0]
            return router
        asyncio.run(main())

    def test_stale_completion_after_reroute_ignored(self):
        """Re-routed query: the OLD replica's late completion must not
        resolve the future out from under the new assignment."""
        async def main():
            router = _bare_router(2)
            loop = asyncio.get_running_loop()
            q, fut = _pending_query(router, 0, 3, loop)
            router._on_death(0, "eof")          # re-routes 3 -> replica 1
            router._on_completion(0, {"qid": 3, "dropped": False,
                                      "acc": 0.5, "pred": [9.9]})
            assert not fut.done()               # stale: ignored
            router._on_completion(1, {"qid": 3, "dropped": False,
                                      "acc": 0.75, "pred": [3.0]})
            assert fut.done()
            assert fut.result() == ([3.0], 0.75)
            assert q.served_acc == 0.75
            return router
        asyncio.run(main())

    def test_drain_timeout_leftovers_marked_timed_out(self):
        """Leftover futures at the drain deadline resolve as dropped
        AND timed_out via the qid index (no per-qid linear scan)."""
        async def main():
            router = _bare_router(1)
            loop = asyncio.get_running_loop()
            q, fut = _pending_query(router, 0, 11, loop)
            await router.drain(timeout=0.01)
            assert fut.done() and fut.result() == (None, 0.0)
            assert q.dropped and q.timed_out
            assert not router._by_qid and not router._payloads
            return router
        asyncio.run(main())


class TestHeartbeatRobustness:
    def test_send_failure_ends_loop_and_counts(self):
        """Satellite bugfix: a heartbeat send hitting a dead connection
        exits the loop cleanly (no unobserved exception) and surfaces
        the failure in the counter the child folds into its stats."""
        class _BoomStream:
            async def send(self, frame):
                raise ConnectionError("peer gone")

        errors = {}
        asyncio.run(heartbeat_loop(_BoomStream(), interval=0.001,
                                   errors=errors))
        assert errors == {"heartbeat_send_errors": 1}

    def test_framestream_recv_is_fifo_from_one_burst(self):
        """Satellite bugfix: a single read burst finishing many frames
        must hand them out in order (deque semantics)."""
        async def main():
            reader = asyncio.StreamReader()
            wire = b"".join(encode_frame({"t": "heartbeat", "i": i},
                                         seq=i) for i in range(50))
            reader.feed_data(wire)
            reader.feed_eof()

            class _NullWriter:
                def close(self):
                    pass

            stream = FrameStream(reader, _NullWriter())
            out = [await stream.recv() for _ in range(50)]
            assert [f["i"] for f in out] == list(range(50))
            assert await stream.recv() is None      # clean EOF
        asyncio.run(main())
