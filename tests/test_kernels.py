"""Per-kernel shape/dtype sweeps: Pallas (interpret mode) vs the pure-
jnp oracle in kernels/ref.py."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

KEY = jax.random.PRNGKey(0)


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("M,K,N", [(64, 256, 384), (130, 512, 256), (8, 128, 128)])
def test_sliced_matmul_sweep(M, K, N, dtype):
    x = jax.random.normal(KEY, (M, K), dtype)
    w = jax.random.normal(jax.random.PRNGKey(1), (K, N), dtype)
    for ai, ao in ((K, N), (128, 128), (K // 2, N), (min(200, K), min(300, N))):
        y = ops.sliced_matmul(x, w, jnp.int32(ai), jnp.int32(ao))
        yr = ref.sliced_matmul_ref(x, w, ai, ao)
        np.testing.assert_allclose(np.asarray(y, np.float32),
                                   np.asarray(yr, np.float32), **_tol(dtype))


def test_sliced_matmul_batched_rank3():
    x = jax.random.normal(KEY, (2, 32, 128), jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(1), (128, 128), jnp.float32)
    y = ops.sliced_matmul(x, w, jnp.int32(128), jnp.int32(64))
    assert y.shape == (2, 32, 128)
    yr = ref.sliced_matmul_ref(x.reshape(-1, 128), w, 128, 64).reshape(2, 32, 128)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,Hq,Hkv,Sq,Sk,d", [
    (1, 4, 2, 64, 64, 32),
    (2, 8, 8, 100, 100, 64),
    (1, 4, 1, 32, 128, 32),
])
def test_flash_attention_sweep(B, Hq, Hkv, Sq, Sk, d, dtype):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, Hq, Sq, d), dtype)
    k = jax.random.normal(ks[1], (B, Hkv, Sk, d), dtype)
    v = jax.random.normal(ks[2], (B, Hkv, Sk, d), dtype)
    for window in (0, 16):
        for kv_len in (None, Sk // 2):
            y = ops.flash_attention(q, k, v, causal=True, window=window,
                                    kv_len=kv_len, q_block=32, kv_block=32)
            yr = ref.flash_attention_dense_ref(q, k, v, causal=True,
                                               window=window, kv_len=kv_len)
            np.testing.assert_allclose(np.asarray(y, np.float32),
                                       np.asarray(yr, np.float32), **_tol(dtype))


@pytest.mark.parametrize("B,Hq,Hkv,Smax,d", [(2, 4, 2, 128, 32), (1, 8, 1, 96, 64)])
def test_decode_attention_sweep(B, Hq, Hkv, Smax, d):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, Hq, 1, d), jnp.float32)
    kc = jax.random.normal(ks[1], (B, Hkv, Smax, d), jnp.float32)
    vc = jax.random.normal(ks[2], (B, Hkv, Smax, d), jnp.float32)
    for idx in (0, 5, Smax - 1):
        for window in (0, 16):
            y = ops.decode_attention(q, kc, vc, jnp.int32(idx), window=window,
                                     kv_block=32)
            yr = ref.decode_attention_dense_ref(q, kc, vc, idx, window=window)
            np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                                       rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("M,d,S", [(64, 128, 4), (100, 256, 9), (7, 512, 2)])
def test_subnet_rmsnorm_sweep(M, d, S, dtype):
    x = jax.random.normal(KEY, (M, d), dtype)
    gt = jax.random.normal(jax.random.PRNGKey(1), (S, d), jnp.float32)
    for sid in (0, S - 1):
        y = ops.subnet_rmsnorm(x, gt, jnp.int32(sid))
        yr = ref.subnet_rmsnorm_ref(x, gt, sid)
        np.testing.assert_allclose(np.asarray(y, np.float32),
                                   np.asarray(yr, np.float32), **_tol(dtype))


def test_rmsnorm_kernel_actuation_is_data():
    """Same compiled kernel serves every subnet row (subnet_id traced)."""
    x = jax.random.normal(KEY, (32, 128), jnp.float32)
    gt = jax.random.normal(jax.random.PRNGKey(1), (4, 128), jnp.float32)
    f = jax.jit(lambda sid: ops.subnet_rmsnorm(x, gt, sid))
    outs = [f(jnp.int32(i)) for i in range(4)]
    for i in range(4):
        np.testing.assert_allclose(np.asarray(outs[i]),
                                   np.asarray(ref.subnet_rmsnorm_ref(x, gt, i)),
                                   rtol=2e-3, atol=2e-3)


def test_model_layer_uses_kernel_consistently():
    """models/attention flash path vs kernels path on the same inputs."""
    from repro.models.attention import flash_attention as xla_flash
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (1, 4, 64, 32), jnp.float32)
    k = jax.random.normal(ks[1], (1, 2, 64, 32), jnp.float32)
    v = jax.random.normal(ks[2], (1, 2, 64, 32), jnp.float32)
    y_xla = xla_flash(q, k, v, causal=True)
    y_pallas = ops.flash_attention(q, k, v, causal=True, q_block=32, kv_block=32)
    np.testing.assert_allclose(np.asarray(y_xla), np.asarray(y_pallas),
                               rtol=2e-3, atol=2e-3)
