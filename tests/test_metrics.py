"""Metrics are total functions: empty and all-dropped query sets give
well-defined finite values (regression for the NaN percentiles), and
the cluster aggregation (per-replica stats, load imbalance) is exact on
hand-built cases."""
import math

import pytest

from repro.serving import metrics
from repro.serving.queue import Query


def _q(qid, replica=0, finish=0.02, dropped=False, deadline=0.036):
    return Query(deadline=deadline, seq=0, arrival=0.0, qid=qid,
                 replica=replica, finish=finish, dropped=dropped)


class TestTotalOnDegenerateSets:
    def test_latency_percentiles_empty(self):
        assert metrics.latency_percentiles([]) == [0.0, 0.0]
        assert metrics.latency_percentiles([], ps=(50, 90, 99)) == [0.0] * 3

    def test_latency_percentiles_all_dropped(self):
        qs = [_q(i, finish=None, dropped=True) for i in range(5)]
        assert metrics.latency_percentiles(qs) == [0.0, 0.0]

    def test_summarize_empty_is_finite(self):
        s = metrics.summarize([])
        assert all(isinstance(v, float) and math.isfinite(v)
                   for v in s.values())
        assert s["p50_latency_s"] == 0.0 and s["p99_latency_s"] == 0.0
        assert s["served"] == 0.0 and s["join_rate"] == 0.0

    def test_summarize_all_dropped_is_finite(self):
        qs = [_q(i, finish=None, dropped=True) for i in range(4)]
        s = metrics.summarize(qs)
        assert all(math.isfinite(v) for v in s.values())
        assert s["slo_attainment"] == 0.0 and s["mean_acc"] == 0.0
        assert s["p99_latency_s"] == 0.0

    def test_goodput_zero_duration(self):
        assert metrics.goodput([], 0.0) == 0.0

    def test_cluster_summarize_empty(self):
        s = metrics.cluster_summarize([], n_replicas=4)
        assert s["load_imbalance"] == 0.0
        assert s["replicas"] == {}


class TestClusterAggregation:
    def test_per_replica_stats_partitions(self):
        qs = [_q(0, replica=0), _q(1, replica=0), _q(2, replica=1)]
        per = metrics.per_replica_stats(qs)
        assert sorted(per) == [0, 1]
        assert per[0]["served"] == 2.0 and per[1]["served"] == 1.0

    def test_load_imbalance_balanced_is_zero(self):
        qs = [_q(i, replica=i % 4) for i in range(16)]
        assert metrics.load_imbalance(qs, n_replicas=4) == 0.0

    def test_load_imbalance_skewed(self):
        # 6 on replica 0, 2 on replica 1 -> mean 4, max 6 -> 0.5
        qs = [_q(i, replica=0) for i in range(6)]
        qs += [_q(10 + i, replica=1) for i in range(2)]
        assert metrics.load_imbalance(qs, n_replicas=2) == 0.5

    def test_load_imbalance_counts_empty_replicas(self):
        qs = [_q(i, replica=0) for i in range(8)]
        # all on one of 4 replicas: mean 2, max 8 -> 3.0
        assert metrics.load_imbalance(qs, n_replicas=4) == 3.0
        # without the forced denominator it's a single-replica set
        assert metrics.load_imbalance(qs) == 0.0


class TestTransientReplicaImbalance:
    """Autoscaled runs: replicas that existed only part of the run are
    judged on their serving RATE over their own lifetime, never as
    0-query phantoms dragging the mean (the replica_spans path)."""

    def test_rate_based_imbalance_is_lifetime_fair(self):
        # replica 0: 8 queries over the full 2 s; replica 1 (spawned
        # late): 2 queries over its 0.5 s life. Same 4 q/s rate ->
        # perfectly balanced...
        qs = [_q(i, replica=0) for i in range(8)]
        qs += [_q(10 + i, replica=1) for i in range(2)]
        spans = {0: 2.0, 1: 0.5}
        assert metrics.load_imbalance(qs, replica_spans=spans) == 0.0
        # ...where the count-based rule would report 8/5 - 1 = 0.6
        assert metrics.load_imbalance(qs, n_replicas=2) == \
            pytest.approx(0.6)

    def test_zero_lifetime_replicas_are_excluded(self):
        qs = [_q(i, replica=0) for i in range(8)]
        # a replica with no lifetime can't be a phantom denominator;
        # one surviving rate -> 0.0 by the 1-replica rule
        spans = {0: 2.0, 1: 0.0}
        assert metrics.load_imbalance(qs, replica_spans=spans) == 0.0

    def test_single_replica_is_exactly_zero(self):
        qs = [_q(i, replica=0) for i in range(5)]
        assert metrics.load_imbalance(qs, n_replicas=1) == 0.0
        assert metrics.load_imbalance(qs, replica_spans={0: 3.0}) == 0.0

    def test_zero_records_is_exactly_zero(self):
        assert metrics.load_imbalance([], n_replicas=4) == 0.0
        assert metrics.load_imbalance([], replica_spans={0: 1.0,
                                                         1: 1.0}) == 0.0

    def test_skew_within_lifetimes_still_detected(self):
        # equal lifetimes, unequal load: 6 vs 2 over 1 s each ->
        # rates (6, 2), mean 4, max 6 -> 0.5 (matches the count rule)
        qs = [_q(i, replica=0) for i in range(6)]
        qs += [_q(10 + i, replica=1) for i in range(2)]
        spans = {0: 1.0, 1: 1.0}
        assert metrics.load_imbalance(qs, replica_spans=spans) == 0.5

    def test_per_replica_stats_reports_idle_replicas(self):
        qs = [_q(0, replica=0), _q(1, replica=0)]
        per = metrics.per_replica_stats(qs, replica_ids=[0, 1, 2])
        assert sorted(per) == [0, 1, 2]
        assert per[0]["served"] == 2.0
        assert per[1]["served"] == 0.0 and per[2]["served"] == 0.0
        assert all(math.isfinite(v) for rid in (1, 2)
                   for v in per[rid].values())

    def test_cluster_summarize_with_spans_adds_efficiency(self):
        qs = [_q(i, replica=0) for i in range(4)]
        s = metrics.cluster_summarize(qs, n_replicas=1,
                                      replica_spans={0: 2.0})
        assert s["replica_seconds"] == 2.0
        assert s["goodput_per_replica_second"] == 2.0   # 4 ok / 2 s
        assert 0 in s["replicas"]
