"""Metrics are total functions: empty and all-dropped query sets give
well-defined finite values (regression for the NaN percentiles), and
the cluster aggregation (per-replica stats, load imbalance) is exact on
hand-built cases."""
import math

from repro.serving import metrics
from repro.serving.queue import Query


def _q(qid, replica=0, finish=0.02, dropped=False, deadline=0.036):
    return Query(deadline=deadline, seq=0, arrival=0.0, qid=qid,
                 replica=replica, finish=finish, dropped=dropped)


class TestTotalOnDegenerateSets:
    def test_latency_percentiles_empty(self):
        assert metrics.latency_percentiles([]) == [0.0, 0.0]
        assert metrics.latency_percentiles([], ps=(50, 90, 99)) == [0.0] * 3

    def test_latency_percentiles_all_dropped(self):
        qs = [_q(i, finish=None, dropped=True) for i in range(5)]
        assert metrics.latency_percentiles(qs) == [0.0, 0.0]

    def test_summarize_empty_is_finite(self):
        s = metrics.summarize([])
        assert all(isinstance(v, float) and math.isfinite(v)
                   for v in s.values())
        assert s["p50_latency_s"] == 0.0 and s["p99_latency_s"] == 0.0
        assert s["served"] == 0.0 and s["join_rate"] == 0.0

    def test_summarize_all_dropped_is_finite(self):
        qs = [_q(i, finish=None, dropped=True) for i in range(4)]
        s = metrics.summarize(qs)
        assert all(math.isfinite(v) for v in s.values())
        assert s["slo_attainment"] == 0.0 and s["mean_acc"] == 0.0
        assert s["p99_latency_s"] == 0.0

    def test_goodput_zero_duration(self):
        assert metrics.goodput([], 0.0) == 0.0

    def test_cluster_summarize_empty(self):
        s = metrics.cluster_summarize([], n_replicas=4)
        assert s["load_imbalance"] == 0.0
        assert s["replicas"] == {}


class TestClusterAggregation:
    def test_per_replica_stats_partitions(self):
        qs = [_q(0, replica=0), _q(1, replica=0), _q(2, replica=1)]
        per = metrics.per_replica_stats(qs)
        assert sorted(per) == [0, 1]
        assert per[0]["served"] == 2.0 and per[1]["served"] == 1.0

    def test_load_imbalance_balanced_is_zero(self):
        qs = [_q(i, replica=i % 4) for i in range(16)]
        assert metrics.load_imbalance(qs, n_replicas=4) == 0.0

    def test_load_imbalance_skewed(self):
        # 6 on replica 0, 2 on replica 1 -> mean 4, max 6 -> 0.5
        qs = [_q(i, replica=0) for i in range(6)]
        qs += [_q(10 + i, replica=1) for i in range(2)]
        assert metrics.load_imbalance(qs, n_replicas=2) == 0.5

    def test_load_imbalance_counts_empty_replicas(self):
        qs = [_q(i, replica=0) for i in range(8)]
        # all on one of 4 replicas: mean 2, max 8 -> 3.0
        assert metrics.load_imbalance(qs, n_replicas=4) == 3.0
        # without the forced denominator it's a single-replica set
        assert metrics.load_imbalance(qs) == 0.0
