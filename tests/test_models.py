"""Per-architecture smoke tests (reduced configs, CPU): one forward /
train step asserting output shapes + no NaNs, decode-path consistency,
and SubNetAct actuation consistency (mask vs switch vs full)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import SHAPES, get_config, assigned_archs, shape_applicable
from repro.core import subnet as sn
from repro.models import lm

ARCHS = assigned_archs()


def _batch(cfg, B=2, S=16, seed=0):
    key = jax.random.PRNGKey(seed)
    if cfg.frontend == "embed":
        return {"embeds": jax.random.normal(key, (B, S, cfg.d_model), jnp.float32),
                "labels": jax.random.randint(key, (B, S), 0, cfg.vocab_size)}
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    return {"tokens": toks, "labels": jnp.roll(toks, -1, 1)}


@pytest.mark.parametrize("arch", ARCHS)
class TestArchSmoke:
    def test_forward_and_loss(self, arch):
        cfg = get_config(arch).reduced()
        params = lm.init_model(jax.random.PRNGKey(0), cfg)
        ctrl = sn.make_control(cfg, sn.max_subnet(cfg))
        batch = _batch(cfg)
        logits = lm.forward(params, cfg, batch, ctrl)
        assert logits.shape == (2, 16, cfg.vocab_size)
        assert not jnp.isnan(logits).any()
        loss = lm.loss_fn(params, cfg, batch, ctrl)
        assert jnp.isfinite(loss)

    def test_train_step(self, arch):
        cfg = get_config(arch).reduced()
        params = lm.init_model(jax.random.PRNGKey(0), cfg)
        ctrl = sn.make_control(cfg, sn.max_subnet(cfg))
        batch = _batch(cfg)
        grads = jax.grad(lambda p: lm.loss_fn(p, cfg, batch, ctrl))(params)
        gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                          for g in jax.tree.leaves(grads)))
        assert jnp.isfinite(gn) and float(gn) > 0

    def test_min_subnet_also_finite(self, arch):
        cfg = get_config(arch).reduced()
        params = lm.init_model(jax.random.PRNGKey(0), cfg)
        ctrl = sn.make_control(cfg, sn.min_subnet(cfg))
        assert jnp.isfinite(lm.loss_fn(params, cfg, _batch(cfg), ctrl))

    def test_decode_step(self, arch):
        cfg = get_config(arch).reduced()
        params = lm.init_model(jax.random.PRNGKey(0), cfg)
        ctrl = sn.make_control(cfg, sn.max_subnet(cfg))
        cache = lm.init_cache(cfg, 2, 32)
        logits, cache2 = lm.decode_step(
            params, cfg, jnp.ones((2, 1), jnp.int32), ctrl, cache, jnp.int32(0))
        assert logits.shape == (2, 1, cfg.vocab_size)
        assert not jnp.isnan(logits).any()


@pytest.mark.parametrize("arch", ["qwen2-1.5b", "stablelm-3b", "musicgen-medium"])
def test_decode_matches_prefill(arch):
    """Teacher-forced decode must reproduce the full-sequence forward
    (same subnet) — validates cache correctness."""
    cfg = get_config(arch).reduced()
    if cfg.frontend == "embed":
        cfg = cfg.replace(frontend="token")
    params = lm.init_model(jax.random.PRNGKey(0), cfg)
    ctrl = sn.make_control(cfg, sn.max_subnet(cfg))
    S = 8
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, S), 0, cfg.vocab_size)
    full = lm.forward(params, cfg, {"tokens": toks}, ctrl)
    cache = lm.init_cache(cfg, 1, S)
    outs = []
    for i in range(S):
        lg, cache = lm.decode_step(params, cfg, toks[:, i:i + 1], ctrl, cache,
                                   jnp.int32(i))
        outs.append(lg)
    dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full),
                               rtol=2e-3, atol=2e-3)


def test_actuation_changes_output_depth_only():
    """LayerSelect: depth-0.5 subnet output == truncated-model output."""
    from tests.conftest import tiny_dense
    from repro.configs.base import Stage
    cfg = tiny_dense()
    params = lm.init_model(jax.random.PRNGKey(0), cfg)
    batch = _batch(cfg)
    space = sn.enumerate_space(cfg)
    sub = next(s for s in space
               if (s.depth_frac, s.ffn_frac, s.head_frac) == (1 / 3, 1.0, 1.0))
    ctrl = sn.make_control(cfg, sub)
    out = lm.forward(params, cfg, batch, ctrl)
    # reference: manually run only the first unit
    ctrl_full = sn.make_control(cfg, sn.max_subnet(cfg))
    ctrl_manual = dict(ctrl_full)
    ctrl_manual["layer_gate"] = np.array([True, False, False])
    ctrl_manual["subnet_id"] = ctrl["subnet_id"]
    out2 = lm.forward(params, cfg, batch, ctrl_manual)
    np.testing.assert_allclose(np.asarray(out), np.asarray(out2), rtol=1e-5)


def test_mask_vs_switch_same_subnet():
    """WeightSlice mask-mode (paper-faithful) and switch-mode (TPU-
    optimized) must produce identical logits at every option width."""
    from tests.conftest import tiny_dense
    cfg = tiny_dense()
    params = lm.init_model(jax.random.PRNGKey(0), cfg)
    batch = _batch(cfg)
    for sub in sn.enumerate_space(cfg):
        ctrl = sn.make_control(cfg, sub)
        y_mask = lm.forward(params, cfg, batch, ctrl, slice_mode="mask")
        y_switch = lm.forward(params, cfg, batch, ctrl, slice_mode="switch")
        np.testing.assert_allclose(np.asarray(y_mask), np.asarray(y_switch),
                                   rtol=2e-4, atol=2e-4)


def test_convnet_smoke_and_calibration():
    from repro.configs.base import Stage
    from repro.core import calibrate
    from repro.models import convnet
    cfg = get_config("ofa_resnet")
    r = cfg.replace(stages=tuple(Stage(s.pattern, 2) for s in cfg.stages),
                    conv_stage_widths=(16, 32, 48, 64), img_size=16,
                    n_classes=10, d_model=64)
    params = convnet.init_convnet(jax.random.PRNGKey(0), r)
    space = sn.enumerate_space(r)
    for sub in (space[0], space[-1]):
        ctrl = convnet.make_conv_control(r, sub)
        logits = convnet.convnet_forward(params, r, jnp.ones((2, 16, 16, 3)), ctrl)
        assert logits.shape == (2, 10) and not jnp.isnan(logits).any()
    batches = [jax.random.normal(jax.random.PRNGKey(i), (4, 16, 16, 3))
               for i in range(2)]
    params = calibrate.calibrate_convnet(params, r, batches, space[:2])
    # calibrated rows hold real statistics now
    assert float(jnp.abs(params["stem"]["bn"]["mean"][0]).max()) > 0
    # non-calibrated rows untouched (still zero-mean init)
    assert float(jnp.abs(params["stem"]["bn"]["mean"][3]).max()) == 0


def test_long_500k_applicability_flags():
    longs = {a: shape_applicable(get_config(a), SHAPES["long_500k"])[0]
             for a in ARCHS}
    assert longs["zamba2-2.7b"] and longs["xlstm-125m"]
    assert longs["mixtral-8x7b"] and longs["h2o-danube-3-4b"]     # SWA
    assert not longs["qwen2.5-14b"] and not longs["musicgen-medium"]
    assert sum(longs.values()) == 4
