"""SubNetAct operator semantics (paper §3): LayerSelect, SubnetNorm,
WeightSlice — including mask-mode vs switch-mode equivalence at the
discrete option widths (the two modes must actuate the SAME subnet)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import operators as ops


class TestLayerSelect:
    def test_gate_true_applies_block(self):
        x = jnp.arange(8.0)
        y = ops.layer_select(jnp.bool_(True), lambda v: v * 2, x)
        np.testing.assert_allclose(y, x * 2)

    def test_gate_false_is_identity(self):
        x = jnp.arange(8.0)
        y = ops.layer_select(jnp.bool_(False), lambda v: v * 2, x)
        np.testing.assert_allclose(y, x)

    def test_jit_actuation_no_recompile(self):
        """Gate is data: one trace serves both depths."""
        traces = []

        @jax.jit
        def f(gate, x):
            traces.append(1)
            return ops.layer_select(gate, lambda v: v + 1, x)

        x = jnp.ones(4)
        f(jnp.bool_(True), x)
        f(jnp.bool_(False), x)
        assert len(traces) == 1


class TestSubnetNorm:
    def test_gathers_per_subnet_gamma(self):
        x = jax.random.normal(jax.random.PRNGKey(0), (2, 5, 16))
        table = jnp.stack([jnp.full((16,), 1.0), jnp.full((16,), 2.0)])
        y0 = ops.subnet_norm(x, table, jnp.int32(0))
        y1 = ops.subnet_norm(x, table, jnp.int32(1))
        np.testing.assert_allclose(np.asarray(y1), np.asarray(y0) * 2.0, rtol=1e-5)

    def test_rms_is_normalized(self):
        x = jax.random.normal(jax.random.PRNGKey(1), (128, 64)) * 7.0
        table = jnp.ones((1, 64))
        y = ops.subnet_norm(x, table, jnp.int32(0))
        rms = jnp.sqrt(jnp.mean(y * y, -1))
        np.testing.assert_allclose(np.asarray(rms), 1.0, atol=1e-3)

    def test_batchnorm_tables(self):
        x = jax.random.normal(jax.random.PRNGKey(2), (4, 3, 3, 8))
        mean_t = jnp.stack([x.mean((0, 1, 2)), jnp.zeros(8)])
        var_t = jnp.stack([x.var((0, 1, 2)), jnp.ones(8)])
        g, b = jnp.ones(8), jnp.zeros(8)
        y = ops.subnet_batch_norm(x, mean_t, var_t, g, b, jnp.int32(0))
        np.testing.assert_allclose(np.asarray(y.mean((0, 1, 2))), 0.0, atol=1e-4)
        np.testing.assert_allclose(np.asarray(y.var((0, 1, 2))), 1.0, atol=1e-2)


class TestWeightSlice:
    def test_mask_zeroes_inactive(self):
        x = jnp.ones((2, 8))
        y = ops.slice_mask(x, jnp.int32(3))
        assert float(y[:, :3].sum()) == 6.0
        assert float(y[:, 3:].sum()) == 0.0

    @pytest.mark.parametrize("k_in,k_out", [(4, 8), (8, 4), (8, 8)])
    def test_mask_equals_dense_slice(self, k_in, k_out):
        key = jax.random.PRNGKey(3)
        x = jax.random.normal(key, (5, 8))
        w = jax.random.normal(jax.random.PRNGKey(4), (8, 8))
        y = ops.sliced_matmul(x, w, jnp.int32(k_in), jnp.int32(k_out), mode="mask")
        expect = x[:, :k_in] @ w[:k_in, :k_out]
        np.testing.assert_allclose(np.asarray(y[:, :k_out]), np.asarray(expect),
                                   rtol=1e-5, atol=1e-5)
        assert float(jnp.abs(y[:, k_out:]).sum()) == 0.0

    def test_switch_equals_mask_at_option_widths(self):
        """The TPU-optimized switch mode must actuate the same subnet as
        the paper-faithful mask mode at every discrete option."""
        key = jax.random.PRNGKey(5)
        x = jax.random.normal(key, (6, 16))
        w = jax.random.normal(jax.random.PRNGKey(6), (16, 12))
        ins, outs = [8, 16], [6, 12]
        for b, (ki, ko) in enumerate(zip(ins, outs)):
            y_mask = ops.sliced_matmul(x, w, jnp.int32(ki), jnp.int32(ko),
                                       mode="mask")
            y_switch = ops.sliced_matmul(x, w, None, None, mode="switch",
                                         in_options=ins, out_options=outs,
                                         bucket=jnp.int32(b))
            np.testing.assert_allclose(np.asarray(y_mask), np.asarray(y_switch),
                                       rtol=1e-5, atol=1e-5)

    def test_switch_over_widths(self):
        outs = ops.switch_over_widths(jnp.int32(1), [2, 4],
                                      lambda k: jnp.full((3,), float(k)))
        np.testing.assert_allclose(np.asarray(outs), 4.0)
