"""Pareto NAS + predictors (paper §2.2/§4.2 substrate)."""
import numpy as np
import pytest
from tests._hypothesis_compat import given, settings, strategies as st

from repro.configs import get_config, assigned_archs
from repro.core import pareto
from repro.core.subnet import enumerate_space


class TestPredictors:
    def test_conv_range_matches_paper(self):
        """Paper §6.1: pareto subnets span ~0.9-7.5 GFLOPs, 73-80% acc."""
        cfg = get_config("ofa_resnet")
        pts = pareto.pareto_subnets(cfg)
        gf = [p.gflops for p in pts]
        acc = [p.acc for p in pts]
        assert min(gf) < 2.0 and 6.5 < max(gf) < 8.5
        assert 73.0 <= min(acc) < 78 and 79.5 < max(acc) <= 80.6

    @pytest.mark.parametrize("arch", assigned_archs())
    def test_monotone_acc_in_flops(self, arch):
        cfg = get_config(arch)
        pts = pareto.pareto_subnets(cfg)
        accs = [p.acc for p in pts]
        gfs = [p.gflops for p in pts]
        assert accs == sorted(accs)
        assert gfs == sorted(gfs)


class TestParetoFilter:
    @given(st.lists(st.tuples(st.floats(0.1, 10), st.floats(50, 90)),
                    min_size=1, max_size=40))
    @settings(max_examples=60, deadline=None)
    def test_output_is_nondominated(self, pts_raw):
        pts = [pareto.ParetoPoint(sub=None, acc=a, gflops=g, weight_mb=1.0)
               for g, a in pts_raw]
        out = pareto.pareto_filter(pts)
        for i, p in enumerate(out):
            for q in out:
                if q is p:
                    continue
                assert not (q.gflops <= p.gflops and q.acc > p.acc + 1e-9), \
                    "dominated point survived"
        # sorted ascending
        assert [p.gflops for p in out] == sorted(p.gflops for p in out)
        assert [p.acc for p in out] == sorted(p.acc for p in out)

    def test_uniform_sample(self):
        cfg = get_config("ofa_resnet")
        pts = pareto.pareto_subnets(cfg)
        six = pareto.uniform_sample(pts, 6)
        assert len(six) <= 6
        assert six[0] is pts[0] and six[-1] is pts[-1]


class TestMemoryAccounting:
    def test_resident_supernet_cheaper_than_model_zoo(self):
        """Paper Fig 5a: one resident supernet vs loading each pareto
        subnet separately."""
        cfg = get_config("ofa_resnet")
        pts = pareto.pareto_subnets(cfg)
        resident = pareto.subnet_weight_bytes(cfg, None, resident=True)
        zoo = sum(pareto.subnet_weight_bytes(cfg, p.sub, resident=False)
                  for p in pareto.uniform_sample(pts, 6))
        assert zoo / resident > 2.0, "supernet must be >2x cheaper than 6 models"
