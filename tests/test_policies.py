"""Scheduling policies: Lemma A.1, bucket-structure properties (P1-P3,
I3), SlackFit-vs-oracle approximation on small instances."""
import numpy as np
import pytest
from tests._hypothesis_compat import given, settings, strategies as st

from repro.configs import get_config
from repro.serving import policies, profiler

CFG = get_config("ofa_resnet")
PROF = profiler.build_profile(CFG)


class TestProfileStructure:
    def test_p1_latency_monotone_in_batch(self):
        assert (np.diff(PROF.lat, axis=1) >= -1e-12).all()

    def test_p2_latency_monotone_in_accuracy(self):
        order = np.argsort(PROF.accs)
        lat_sorted = PROF.lat[order]
        assert (np.diff(lat_sorted, axis=0) >= -1e-9).all()

    def test_p3_batch_gaps_grow_with_accuracy(self):
        order = np.argsort(PROF.accs)
        gaps = PROF.lat[order, -1] - PROF.lat[order, 0]
        assert (np.diff(gaps) >= -1e-9).all()

    def test_i3_choices_thin_out_at_high_latency(self):
        sizes = [len(m) for m in PROF.bucket_members]
        assert np.mean(sizes[: len(sizes) // 4]) >= np.mean(sizes[-len(sizes) // 4:])


class TestLemmaA1:
    @given(b=st.integers(1, 64), d=st.floats(0.005, 0.3))
    @settings(max_examples=60, deadline=None)
    def test_pareto_utility_dominates(self, b, d):
        """U(phi_p, B, d) >= U(phi_q, B, d) when latencies are similar
        and phi_p pareto-dominates in accuracy."""
        accs = PROF.accs
        for i in range(len(accs) - 1):
            hi, lo = accs[i + 1], accs[i]
            lat_hi = PROF.latency(i + 1, b)
            u_hi = hi * b if lat_hi < d else 0.0
            u_lo = lo * b if PROF.latency(i, b) < d else 0.0
            if abs(lat_hi - PROF.latency(i, b)) < 1e-4:
                assert u_hi >= u_lo


class TestSlackFit:
    def test_high_slack_prefers_accuracy(self):
        pi, bi = PROF.choose_slackfit(10.0, queue_len=1)
        assert PROF.accs[pi] == PROF.accs.max()

    def test_low_slack_prefers_throughput(self):
        pi_lo, bi_lo = PROF.choose_slackfit(0.008, queue_len=1000)
        pi_hi, bi_hi = PROF.choose_slackfit(0.25, queue_len=1000)
        thr_lo = PROF.batches[bi_lo] / PROF.lat[pi_lo, bi_lo]
        # low slack choice must at least not serve the max-acc net
        assert PROF.accs[pi_lo] < PROF.accs.max()
        assert thr_lo > 0

    def test_chosen_latency_fits_slack_when_feasible(self):
        for slack in (0.012, 0.02, 0.05, 0.1):
            pi, bi = PROF.choose_slackfit(slack, queue_len=10_000)
            assert PROF.lat[pi, bi] <= slack + 1e-9

    def test_queue_cap_limits_batch(self):
        pi, bi = PROF.choose_slackfit(0.25, queue_len=3)
        assert PROF.batches[bi] <= 4      # smallest profiled batch >= 3

    @given(slack=st.floats(0.001, 0.5), qlen=st.integers(1, 500))
    @settings(max_examples=100, deadline=None)
    def test_always_returns_valid_tuple(self, slack, qlen):
        pi, bi = PROF.choose_slackfit(slack, qlen)
        assert 0 <= pi < PROF.n_pareto and 0 <= bi < len(PROF.batches)


class TestOracle:
    def test_slackfit_tracks_oracle_on_small_instances(self):
        """Greedy SlackFit utility within 70% of the brute-force ILP
        objective on tiny instances (and never above it)."""
        rng = np.random.default_rng(0)
        for trial in range(5):
            n = 6
            arrivals = np.sort(rng.uniform(0, 0.05, n))
            deadlines = arrivals + 0.08
            opt = policies.oracle_schedule(arrivals, deadlines, PROF,
                                           n_workers=1)
            # greedy simulate with slackfit on 1 worker
            from repro.serving.simulator import SimConfig, simulate
            res = simulate(arrivals, PROF, policies.SlackFit(),
                           SimConfig(n_workers=1, slo=0.08))
            got = sum(q.served_acc for q in res.queries
                      if q.finish and q.finish <= q.deadline and not q.dropped)
            assert got <= opt + 1e-6
            assert got >= 0.70 * opt, (trial, got, opt)


class TestBaselinePolicies:
    """The §6.1 baseline policies make sane decisions (they back the
    benchmark shoot-outs and serve.py's --policy choices)."""

    def test_maxacc_takes_top_accuracy_under_generous_slack(self):
        dec = policies.MaxAcc().choose(PROF, float(PROF.lat.max()) * 2, 64)
        assert dec.pareto_idx == int(np.argmax(PROF.accs))
        assert dec.batch_size >= 1

    def test_maxacc_fits_the_slack_when_tight(self):
        slack = float(PROF.lat.min()) * 1.01
        dec = policies.MaxAcc().choose(PROF, slack, 4)
        assert float(PROF.lat[dec.pareto_idx, 0]) <= slack

    def test_maxbatch_prefers_batch_then_accuracy(self):
        dec = policies.MaxBatch().choose(PROF, float(PROF.lat.max()), 64)
        fastest = int(PROF.lat[:, 0].argmin())
        fit = np.where(PROF.lat[fastest] <= float(PROF.lat.max()))[0]
        assert dec.batch_size == PROF.batches[int(fit[-1])]

    def test_clipper_fixed_sticks_to_its_subnet(self):
        pol = policies.ClipperFixed(3)
        for slack in (0.01, 0.05, 1.0):
            assert pol.choose(PROF, slack, 16).pareto_idx == 3
        clone = pol.clone()
        assert clone.pareto_idx == 3 and clone.name == pol.name

    def test_infaas_always_min_accuracy(self):
        pol = policies.INFaaSMinCost()
        lo = int(np.argmin(PROF.accs))
        assert pol.choose(PROF, 0.05, 8).pareto_idx == lo
        assert pol.choose(PROF, 5.0, 200).pareto_idx == lo


def test_policy_decision_is_fast():
    """Sub-millisecond control decisions (paper §A.3 requirement)."""
    import time
    pol = policies.SlackFit()
    t0 = time.perf_counter()
    for i in range(1000):
        pol.choose(PROF, 0.02 + (i % 7) * 0.01, 1 + i % 300)
    per_call = (time.perf_counter() - t0) / 1000
    assert per_call < 1e-3, f"{per_call*1e3:.2f} ms per decision"
