"""Weight-only int8 serving mode (EXPERIMENTS §Perf A5)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import subnet as sn
from repro.models import lm
from repro.serving import quantize as QZ
from tests.conftest import tiny_dense


@pytest.fixture(scope="module")
def supernet():
    cfg = tiny_dense(d_model=128, d_ff=512, vocab_size=512)
    params = lm.init_model(jax.random.PRNGKey(0), cfg)
    return cfg, params


def test_roundtrip_error_bound(supernet):
    _, params = supernet
    q, sc = QZ.quantize_tree(params)
    deq = QZ.dequantize_tree(q, sc, dtype=jnp.float32)
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(deq)):
        a = np.asarray(a, np.float32)
        b = np.asarray(b, np.float32)
        if a.ndim >= 2 and a.size >= QZ.MIN_ELEMS:
            # per-channel symmetric int8: |err| <= scale/2 = amax/254
            amax = np.abs(a).max(axis=tuple(range(a.ndim - 1)), keepdims=True)
            assert (np.abs(a - b) <= amax / 254 + 1e-7).all()
        else:
            np.testing.assert_array_equal(a, b)


def test_wire_bytes_halved(supernet):
    _, params = supernet
    q, sc = QZ.quantize_tree(params)
    orig = sum(l.size * l.dtype.itemsize for l in jax.tree.leaves(params))
    wire = QZ.quantized_bytes(q) + QZ.quantized_bytes(sc)
    assert wire < 0.65 * orig


def test_decode_logits_close(supernet):
    """int8 decode must track bf16 decode (weight-only quantization is
    the production-grade lossy point: logits close, argmax preserved
    on a clear-margin prompt)."""
    cfg, params = supernet
    ctrl = sn.make_control(cfg, sn.max_subnet(cfg))
    cache = lm.init_cache(cfg, 2, 16)
    toks = jnp.ones((2, 1), jnp.int32)
    ref, _ = lm.decode_step(params, cfg, toks, ctrl, cache, jnp.int32(0))
    q, sc = QZ.quantize_tree(params)
    deq = QZ.dequantize_tree(q, sc, dtype=jnp.float32)
    got, _ = lm.decode_step(deq, cfg, toks, ctrl, cache, jnp.int32(0))
    err = float(jnp.abs(ref - got).max())
    assert err < 0.25, err


def test_quantize_specs_match_tree(supernet):
    _, params = supernet
    specs = jax.eval_shape(lambda: params)
    q_sp, sc_sp = QZ.quantize_specs(specs)
    q, sc = QZ.quantize_tree(params)
    for a, b in zip(jax.tree.leaves(q_sp), jax.tree.leaves(q)):
        assert a.shape == b.shape and a.dtype == b.dtype
    for a, b in zip(jax.tree.leaves(sc_sp), jax.tree.leaves(sc)):
        assert tuple(a.shape) == tuple(np.shape(b))


def test_subnetact_commutes_with_quantization(supernet):
    """Quantize-then-actuate == actuate-then-quantize at the logits
    level (per-channel scales align with WeightSlice axes)."""
    cfg, params = supernet
    q, sc = QZ.quantize_tree(params)
    deq = QZ.dequantize_tree(q, sc, dtype=jnp.float32)
    batch = {"tokens": jnp.ones((1, 8), jnp.int32)}
    for sub in (sn.min_subnet(cfg), sn.max_subnet(cfg)):
        ctrl = sn.make_control(cfg, sub)
        a = lm.forward(params, cfg, batch, ctrl)
        b = lm.forward(deq, cfg, batch, ctrl)
        assert float(jnp.abs(a - b).max()) < 0.3
