"""Hypothesis property tests: EDF queue invariants + simulator
conservation (every query accounted exactly once)."""
import numpy as np
from tests._hypothesis_compat import given, settings, strategies as st

from repro.configs import get_config
from repro.serving import policies, profiler, simulator
from repro.serving.queue import EDFQueue, Query

PROF = profiler.build_profile(get_config("ofa_resnet"))


@given(st.lists(st.floats(0.0, 100.0), min_size=1, max_size=60))
@settings(max_examples=60, deadline=None)
def test_edf_pops_in_deadline_order(deadlines):
    q = EDFQueue()
    for i, d in enumerate(deadlines):
        q.push(Query(deadline=d, seq=0, arrival=0.0, qid=i))
    popped = [q.pop().deadline for _ in range(len(deadlines))]
    assert popped == sorted(popped)


@given(st.lists(st.sampled_from([0.1, 0.2, 0.3, 0.4]),
                min_size=1, max_size=50))
@settings(max_examples=60, deadline=None)
def test_edf_equal_deadline_ties_pop_fifo(deadlines):
    """Stable ordering: among equal deadlines, the FULL pop sequence
    preserves insertion (FIFO) order — not just the head. Deadlines are
    drawn from a tiny pool so collisions are the common case."""
    q = EDFQueue()
    for i, d in enumerate(deadlines):
        q.push(Query(deadline=d, seq=0, arrival=0.0, qid=i))
    popped = [q.pop() for _ in range(len(deadlines))]
    assert [p.deadline for p in popped] == sorted(deadlines)
    for d in set(deadlines):
        qids = [p.qid for p in popped if p.deadline == d]
        assert qids == sorted(qids)              # insertion order, stable


@given(st.lists(st.sampled_from([0.1, 0.2, 0.3]), min_size=2, max_size=40),
       st.lists(st.sampled_from([0.1, 0.2, 0.3]), min_size=1, max_size=10))
@settings(max_examples=60, deadline=None)
def test_repushed_query_keeps_fifo_position(deadlines, late_deadlines):
    """The re-push invariant (fault re-enqueue / replica-death
    re-route): a query popped and pushed back — even into a DIFFERENT
    queue — keeps its first-assigned seq, so among equal deadlines it
    still pops before every later arrival. The historical bug
    unconditionally reassigned ``seq`` on push, sending re-enqueued
    queries behind arrivals they preceded."""
    src = EDFQueue()
    for i, d in enumerate(deadlines):
        src.push(Query(deadline=d, seq=0, arrival=0.0, qid=i))
    # redistribute: drain the dead queue (EDF order, as surrender_queue
    # does) and re-push everything into the survivor in one pass —
    # arrivals never interleave inside a redistribution (the coordinator
    # loop is synchronous on both transports)
    dst = EDFQueue()
    for q2 in src.drain():
        dst.push(q2)
    # later arrivals land on the survivor after the re-routed queries
    for j, d in enumerate(late_deadlines):
        dst.push(Query(deadline=d, seq=0, arrival=1.0, qid=1000 + j))
    popped = [dst.pop() for _ in range(len(dst))]
    assert [p.deadline for p in popped] == sorted(p.deadline for p in popped)
    for d in {p.deadline for p in popped}:
        qids = [p.qid for p in popped if p.deadline == d]
        originals = [i for i in qids if i < 1000]
        late = [i for i in qids if i >= 1000]
        # every original (re-routed or not) precedes every equal-deadline
        # late arrival, and originals stay in admission order
        assert originals == sorted(originals)
        if originals and late:
            assert max(qids.index(i) for i in originals) < \
                min(qids.index(i) for i in late)


@given(st.lists(st.floats(0.0, 10.0), min_size=1, max_size=40),
       st.floats(0.5, 5.0))
@settings(max_examples=40, deadline=None)
def test_edf_fifo_tie_break_and_slack(deadlines, now):
    q = EDFQueue()
    for i, d in enumerate(deadlines):
        q.push(Query(deadline=d, seq=0, arrival=0.0, qid=i))
    head = q.peek()
    assert q.head_slack(now) == head.deadline - now
    same = [i for i, d in enumerate(deadlines) if d == head.deadline]
    assert head.qid == same[0]                  # FIFO among equal deadlines


@given(st.lists(st.floats(0.0, 5.0), min_size=1, max_size=30),
       st.floats(0.001, 0.02))
@settings(max_examples=30, deadline=None)
def test_drop_expired_exactly_the_infeasible(deadlines, min_service):
    q = EDFQueue()
    now = 2.5
    for i, d in enumerate(deadlines):
        q.push(Query(deadline=d, seq=0, arrival=0.0, qid=i))
    dropped = q.drop_expired(now, min_service)
    assert all(d.deadline - now < min_service for d in dropped)
    assert all(not d2.dropped for d2 in []) or True
    rest = [q.pop() for _ in range(len(q))]
    assert all(r.deadline - now >= min_service for r in rest)
    assert len(dropped) + len(rest) == len(deadlines)


@given(st.integers(0, 10_000), st.integers(1, 8),
       st.sampled_from(["slackfit", "maxbatch", "infaas"]))
@settings(max_examples=20, deadline=None)
def test_simulator_conserves_queries(seed, workers, polname):
    """Every query ends in exactly one of {served, dropped, unfinished}
    and the counts add up — across policies, seeds, pool sizes."""
    rng = np.random.default_rng(seed)
    arr = np.sort(rng.uniform(0, 0.5, size=rng.integers(1, 120)))
    pol = policies.ALL_POLICIES[polname]()
    res = simulator.simulate(arr, PROF, pol,
                             simulator.SimConfig(n_workers=workers, seed=seed))
    assert len(res.queries) == len(arr)
    served = sum(1 for q in res.queries
                 if q.finish is not None and not q.dropped)
    dropped = sum(1 for q in res.queries if q.dropped)
    unfinished = sum(1 for q in res.queries
                     if q.finish is None and not q.dropped)
    assert served + dropped + unfinished == len(arr)
    assert unfinished == 0                       # no faults -> all resolve
    # dispatched batch sizes never exceed what the queue could supply
    assert all(d.batch >= 1 for d in res.dispatches)


@given(st.integers(0, 10_000), st.integers(2, 8),
       st.sampled_from(["slackfit", "maxbatch", "infaas"]))
@settings(max_examples=20, deadline=None)
def test_continuous_batching_conserves_queries(seed, workers, polname):
    """Conservation holds with in-flight joins: a query that joins a
    forming batch is served exactly once, never lost or duplicated."""
    rng = np.random.default_rng(seed)
    arr = np.sort(rng.uniform(0, 0.5, size=rng.integers(1, 120)))
    pol = policies.ALL_POLICIES[polname]()
    res = simulator.simulate(
        arr, PROF, pol,
        simulator.SimConfig(n_workers=workers, seed=seed,
                            continuous_batching=True))
    assert len(res.queries) == len(arr)
    served = sum(1 for q in res.queries
                 if q.finish is not None and not q.dropped)
    dropped = sum(1 for q in res.queries if q.dropped)
    assert served + dropped == len(arr)          # all resolve, exactly once
    assert sum(d.batch for d in res.dispatches) == served
    assert all(d.batch <= PROF.batches[-1] for d in res.dispatches)
