"""Residency layer (serving/residency.py): actuation cost model units,
tracker bookkeeping + conservation properties, the byte-identical
replay regression (residency-blind configs reproduce the pre-refactor
inlined engine math bit-for-bit), the router/engine residency-agreement
regression (the duplicated ``WorkerHandle.current_subnet`` can never
come back), sticky-policy invariants, and actuation-aware placement
semantics."""
import asyncio
import dataclasses
import math

import numpy as np
from tests._hypothesis_compat import given, settings, strategies as st

from repro.configs import get_config
from repro.serving import cluster, policies, profiler, runtime, simulator, traces
from repro.serving.engine import EngineConfig, SchedulingEngine
from repro.serving.profiler import (RTX2080TI, SUBNETACT_ACTUATION_S,
                                    loading_latency)
from repro.serving.queue import Query
from repro.serving.residency import (DEFAULT_WEIGHT_BYTES, ActuationModel,
                                     ResidencyTracker)

PROF = profiler.build_profile(get_config("ofa_resnet"))
ARR = traces.bursty_trace(400, 1600, 4, 2.0, seed=23)


class TestActuationModel:
    def test_same_subnet_is_free(self):
        m = ActuationModel(load_on_switch=True)
        for pi in range(PROF.n_pareto):
            assert m.switch_cost(PROF, pi, pi) == 0.0

    def test_control_swap_costs_the_actuation_delay(self):
        m = ActuationModel()
        assert m.switch_cost(PROF, 0, 1) == SUBNETACT_ACTUATION_S
        assert m.switch_cost(PROF, None, 1) == SUBNETACT_ACTUATION_S

    def test_load_on_switch_adds_exact_weight_page_in(self):
        m = ActuationModel(load_on_switch=True)
        for pi in range(PROF.n_pareto):
            wb = PROF.points[pi].weight_mb * 2**20
            assert (m.switch_cost(PROF, None, pi)
                    == SUBNETACT_ACTUATION_S + loading_latency(RTX2080TI, wb))

    def test_pointless_profile_falls_back_to_legacy_bytes(self):
        # measured profiles (profiler.measure_profile) carry no Pareto
        # points; the historical engine assumed a 100 MB footprint
        bare = dataclasses.replace(PROF, points=[])
        m = ActuationModel(load_on_switch=True)
        assert m.weight_bytes(bare, 0) == DEFAULT_WEIGHT_BYTES
        assert (m.load_cost(bare, 0)
                == loading_latency(RTX2080TI, DEFAULT_WEIGHT_BYTES))

    def test_penalized_matches_sequential_accumulation_order(self):
        # float addition is non-associative: the replay guarantee is
        # that penalized() adds delay then load with sequential +=,
        # exactly as the pre-refactor engine did
        m = ActuationModel(load_on_switch=True)
        for pi in range(PROF.n_pareto):
            lat = float(PROF.lat[pi, 0])
            expect = lat
            expect += SUBNETACT_ACTUATION_S
            expect += m.load_cost(PROF, pi)
            assert m.penalized(lat, PROF, None, pi) == expect
            assert m.penalized(lat, PROF, pi, pi) == lat

    def test_cold_start_is_the_heaviest_subnet_load(self):
        m = ActuationModel()
        heaviest = max(p.weight_mb * 2**20 for p in PROF.points)
        assert m.cold_start(PROF) == loading_latency(RTX2080TI, heaviest)
        assert all(m.cold_start(PROF) >= m.load_cost(PROF, pi)
                   for pi in range(PROF.n_pareto))

    @given(st.floats(1e3, 1e9), st.floats(1e3, 1e9))
    @settings(max_examples=40, deadline=None)
    def test_load_cost_monotone_in_weight_bytes(self, b1, b2):
        lo, hi = sorted((b1, b2))
        assert (loading_latency(RTX2080TI, lo)
                <= loading_latency(RTX2080TI, hi))


class TestResidencyTracker:
    def _tracker(self, n=3, load=False):
        return ResidencyTracker(PROF, ActuationModel(load_on_switch=load),
                                worker_ids=range(n))

    def test_fresh_pool_is_unresident(self):
        tr = self._tracker()
        assert len(tr) == 3 and sorted(tr.workers()) == [0, 1, 2]
        assert all(tr.resident(w) is None for w in tr.workers())
        assert tr.switch_rate == 0.0

    def test_actuate_commits_and_books_the_cost(self):
        tr = self._tracker(load=True)
        cost = tr.actuate(0, 2)
        assert cost == tr.model.switch_cost(PROF, None, 2)
        assert tr.resident(0) == 2
        assert (tr.n_launches, tr.n_switches) == (1, 1)
        assert tr.actuation_seconds == cost
        # relaunching the resident subnet is free and not a switch
        assert tr.actuate(0, 2) == 0.0
        assert (tr.n_launches, tr.n_switches) == (2, 1)
        assert tr.switch_rate == 0.5

    def test_forget_drops_residency_with_the_worker(self):
        tr = self._tracker()
        tr.actuate(1, 0)
        tr.forget(1)
        assert 1 not in tr and len(tr) == 2
        assert tr.resident(1) is None
        # a re-registered worker starts cold again
        tr.register(1)
        assert tr.resident(1) is None

    def test_min_switch_cost_zero_iff_resident_somewhere(self):
        tr = self._tracker(load=True)
        pi = 1
        assert tr.min_switch_cost(pi) == tr.model.switch_cost(PROF, None, pi)
        tr.actuate(2, pi)
        assert tr.min_switch_cost(pi) == 0.0
        assert tr.resident_count(pi) == 1

    def test_empty_pool_prices_a_cold_worker(self):
        tr = ResidencyTracker(PROF, ActuationModel(load_on_switch=True))
        assert (tr.min_switch_cost(0)
                == tr.model.switch_cost(PROF, None, 0))

    def test_snapshot_is_finite_and_complete(self):
        tr = self._tracker(load=True)
        tr.actuate(0, 1)
        snap = tr.snapshot()
        assert set(snap) == {"n_workers", "n_launches", "n_switches",
                             "switch_rate", "actuation_seconds"}
        assert all(math.isfinite(v) and v >= 0 for v in snap.values())

    @given(st.lists(st.tuples(st.sampled_from(["register", "forget",
                                               "actuate"]),
                              st.integers(0, 5), st.integers(0, 3)),
                    min_size=1, max_size=60))
    @settings(max_examples=60, deadline=None)
    def test_residency_keys_conserved_under_any_op_sequence(self, ops):
        """Tracker keys are exactly (registered - forgotten + actuated):
        residency never leaks a dead worker or loses a live one, and
        the accounting stays consistent under arbitrary fault/
        decommission interleavings."""
        tr = ResidencyTracker(PROF, ActuationModel(load_on_switch=True))
        alive = set()
        for op, wid, pi in ops:
            if op == "register":
                tr.register(wid)
                alive.add(wid)
            elif op == "forget":
                tr.forget(wid)
                alive.discard(wid)
            else:
                tr.actuate(wid, pi)     # engine launch: implies membership
                alive.add(wid)
                assert tr.resident(wid) == pi
        assert set(tr.workers()) == alive
        assert 0 <= tr.n_switches <= tr.n_launches
        assert 0.0 <= tr.switch_rate <= 1.0
        assert math.isfinite(tr.actuation_seconds)
        assert tr.actuation_seconds >= 0.0


class TestByteIdenticalReplay:
    """THE refactor regression: with residency-blind configuration the
    new layer must reproduce the pre-refactor inlined engine math
    bit-for-bit. Reimplement the OLD code (sequential ``+=`` against a
    hand-tracked worker->subnet dict) over the dispatch stream and
    demand exact float equality in both actuation regimes."""

    def _replay(self, load_on_switch):
        scfg = simulator.SimConfig(n_workers=4, slo=0.036,
                                   load_on_switch=load_on_switch)
        res = simulator.simulate(ARR, PROF, policies.SlackFit(), scfg)
        assert res.dispatches, "trace must exercise the engine"
        worker_model = {}                      # the old private dict
        for d in res.dispatches:
            lat = PROF.latency(d.pareto_idx, max(d.batch, 1))
            if worker_model.get(d.worker) != d.pareto_idx:
                lat += SUBNETACT_ACTUATION_S   # old inlined actuation
                if load_on_switch:
                    wb = (PROF.points[d.pareto_idx].weight_mb * 2**20
                          if PROF.points else 100e6)
                    lat += loading_latency(RTX2080TI, wb)
            worker_model[d.worker] = d.pareto_idx
            assert lat == d.latency            # exact, not approx
        return res

    def test_control_swap_regime_replays_bit_for_bit(self):
        self._replay(load_on_switch=False)

    def test_weight_load_regime_replays_bit_for_bit(self):
        res = self._replay(load_on_switch=True)
        # and the booked accounting equals an independent walk
        m = ActuationModel(load_on_switch=True)
        resident, seconds = {}, 0.0
        for d in res.dispatches:
            seconds += m.switch_cost(PROF, resident.get(d.worker),
                                     d.pareto_idx)
            resident[d.worker] = d.pareto_idx
        assert seconds == res.actuation_seconds


class TestRouterResidencyAgreement:
    """Satellite regression for the PR 3 duplication: the runtime layer
    no longer keeps its own ``current_subnet`` copy, so the subnet a
    worker ACTUALLY ran last can never disagree with what the engine's
    residency tracker says it runs."""

    def test_worker_handle_has_no_residency_copy(self):
        wh = runtime.WorkerHandle(wid=0, run=lambda idx, p: np.zeros(len(p)))
        assert not hasattr(wh, "current_subnet")

    def test_router_observed_subnets_match_engine_residency(self):
        observed = {}                      # wid -> last ACTUALLY-run subnet

        def make_run(wid):
            def run(idx, payloads):
                observed[wid] = idx
                return np.zeros(len(payloads))
            return run

        async def main():
            workers = [runtime.WorkerHandle(wid=i, run=make_run(i))
                       for i in range(3)]
            router = runtime.Router(PROF, policies.SlackFit(), workers)
            await router.start()
            futs = [await router.submit(np.zeros(8), slo_s=0.5)
                    for _ in range(30)]
            await asyncio.gather(*futs)
            await router.drain()
            return router

        router = asyncio.run(main())
        assert observed, "router must have dispatched"
        for wid, idx in observed.items():
            assert router.resident_subnet(wid) == idx
            assert router.engine.residency.resident(wid) == idx


class TestStickySlackFit:
    def _view(self, resident_pi):
        tr = ResidencyTracker(PROF, ActuationModel(load_on_switch=True),
                              worker_ids=(0,))
        if resident_pi is not None:
            tr.actuate(0, resident_pi)
        return tr.view(0)

    def test_residency_blind_call_is_plain_slackfit(self):
        base, sticky = policies.SlackFit(), policies.StickySlackFit()
        for slack in (1e-4, 1e-3, 1e-2, 0.036, 0.1, 1.0):
            for qlen in (0, 1, 7, 50):
                b = base.choose(PROF, slack, qlen)
                s = sticky.choose(PROF, slack, qlen, residency=None)
                assert (b is None) == (s is None)
                if b is not None:
                    assert (b.pareto_idx, b.batch_size) == \
                        (s.pareto_idx, s.batch_size)

    @given(st.floats(1e-4, 1.0), st.integers(0, 40),
           st.integers(0, 1000))
    @settings(max_examples=80, deadline=None)
    def test_sticky_deviations_are_no_regret(self, slack, qlen, seed):
        """Whenever sticky deviates from plain SlackFit it (a) returns
        the resident subnet, (b) still meets the slack target, and
        (c) only sacrifices accuracy when actuating SlackFit's choice
        would itself blow the slack budget."""
        rng = np.random.default_rng(seed)
        resident = int(rng.integers(0, PROF.n_pareto))
        view = self._view(resident)
        base = policies.SlackFit().choose(PROF, slack, qlen)
        dec = policies.StickySlackFit().choose(PROF, slack, qlen,
                                               residency=view)
        if base is None or dec is None:
            assert (base is None) == (dec is None)
            return
        assert dec.batch_size == base.batch_size
        if dec.pareto_idx == base.pareto_idx:
            return
        assert dec.pareto_idx == resident
        bi = int(np.searchsorted(PROF.batches, base.batch_size))
        assert PROF.lat[resident, bi] <= slack
        base_with_switch = (float(PROF.lat[base.pareto_idx, bi])
                            + view.switch_cost(base.pareto_idx))
        assert (PROF.accs[resident] >= PROF.accs[base.pareto_idx]
                or base_with_switch > slack)

    def test_sticks_to_equal_accuracy_resident(self):
        base = policies.SlackFit().choose(PROF, 0.036, 0)
        assert base is not None
        dec = policies.StickySlackFit().choose(
            PROF, 0.036, 0, residency=self._view(base.pareto_idx))
        assert dec.pareto_idx == base.pareto_idx   # free: already resident


class TestActuationAwarePlacement:
    def _engines(self, load=True):
        cfg = EngineConfig(load_on_switch=load)
        return [SchedulingEngine(PROF, policies.SlackFit(), cfg=cfg,
                                 worker_ids=range(2), replica_id=rid)
                for rid in range(2)]

    def test_prefers_the_already_resident_replica(self):
        engines = self._engines()
        coord = cluster.ClusterCoordinator(engines, cluster.ActuationAware())
        pi = engines[1].likely_subnet(0.036)
        engines[1].residency.actuate(0, pi)    # replica 1 holds the subnet
        assert coord.route(Query(deadline=0.036, seq=0, qid=1), 0.0) == 1

    def test_spills_when_the_resident_replica_is_backed_up(self):
        engines = self._engines()
        pi = engines[0].likely_subnet(0.036)
        engines[0].residency.actuate(0, pi)
        # pile enough queue onto replica 0 that its projected start
        # exceeds the page-in cost of actuating replica 1 from cold
        switch = engines[1].projected_switch_cost(pi)
        depth = 0
        while (engines[0].projected_start(0.036, 0.0)
               - engines[1].projected_start(0.036, 0.0)) <= switch:
            engines[0].admit(Query(deadline=0.036, seq=0, qid=100 + depth))
            depth += 1
            assert depth < 10_000
        coord = cluster.ClusterCoordinator(engines, cluster.ActuationAware())
        assert coord.route(Query(deadline=0.036, seq=0, qid=1), 0.0) == 1

    def test_registered_and_driven_by_simulator(self):
        ccfg = simulator.ClusterConfig(
            n_replicas=2, workers_per_replica=2,
            placement="actuation_aware", slo=0.036, load_on_switch=True)
        res = simulator.simulate_cluster(ARR, PROF,
                                         policies.StickySlackFit(), ccfg)
        assert len(res.queries) == len(ARR)
        st_ = res.stats()
        assert 0.0 <= st_["switch_rate"] <= 1.0
        assert math.isfinite(st_["actuation_seconds"])
